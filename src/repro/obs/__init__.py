"""repro.obs — spans, counters, and trace export for the triangle engine.

The observability layer the timing claims rest on (§V of the paper is
*all* timings).  Three pieces:

* :mod:`repro.obs.tracer` — hierarchical spans with explicit
  ``block_until_ready`` sync points (device time, not async dispatch),
  near-zero cost when disabled.
* :mod:`repro.obs.counters` — process-wide counters/gauges (chunks
  launched, wedges planned, cache hits, capability fallbacks).
* :mod:`repro.obs.export` — Chrome trace-event JSON (Perfetto-viewable)
  and structured JSONL exporters, plus stdlib-only validators.

Typical CLI wiring::

    with obs.trace_to_file(args.trace, meta={"cli": "count"}):
        with obs.span("ingest", cat="io"):
            graph = ...
        tc.count(graph)          # engine emits nested spans itself

and in engine code wrapping device work::

    with obs.span("count.chunk", cat="engine") as sp:
        part = sp.sync(backend.count_chunk(adj, chunk))

Importing this package never imports jax (the stdlib-only CI jobs use
the validators); ``Span.sync`` imports it lazily.
"""
from .counters import (
    Counter,
    Gauge,
    MetricsRegistry,
    counter,
    gauge,
    registry,
)
from .counters import reset as reset_metrics
from .counters import snapshot as metrics_snapshot
from .export import (
    SCHEMA,
    env_fingerprint,
    to_chrome_trace,
    to_jsonl_records,
    trace_to_file,
    validate_chrome_trace,
    validate_jsonl_records,
    write_trace,
)
from .hist import N_BUCKETS, ConcurrentHistogram, Pow2Histogram, RollingHistogram
from .tracer import (
    NOOP_SPAN,
    Span,
    Tracer,
    active,
    enabled,
    span,
    start_tracing,
    stop_tracing,
    sync,
    tracing,
)

__all__ = [
    "Counter",
    "Gauge",
    "MetricsRegistry",
    "N_BUCKETS",
    "NOOP_SPAN",
    "Pow2Histogram",
    "ConcurrentHistogram",
    "RollingHistogram",
    "SCHEMA",
    "Span",
    "Tracer",
    "active",
    "counter",
    "enabled",
    "env_fingerprint",
    "gauge",
    "metrics_snapshot",
    "registry",
    "reset_metrics",
    "span",
    "start_tracing",
    "stop_tracing",
    "sync",
    "to_chrome_trace",
    "to_jsonl_records",
    "trace_to_file",
    "tracing",
    "validate_chrome_trace",
    "validate_jsonl_records",
    "write_trace",
]
