"""Trace exporters: Chrome trace-event JSON and structured JSONL.

Two formats, one event stream:

* **Chrome trace-event JSON** — the ``{"traceEvents": [...]}`` object
  format with complete (``"ph": "X"``) events, loadable directly in
  Perfetto / ``chrome://tracing``.  Nesting is rendered from timestamp
  containment, which the tracer's strictly-ordered ``ts_ns``/``dur_ns``
  pairs guarantee.  Timestamps are microseconds (floats keep the ns
  resolution).
* **JSONL** — one JSON object per line: a ``meta`` header (schema tag +
  environment fingerprint), one ``span`` record per event with raw ns
  fields, and a trailing ``metrics`` record (counters, gauges, jit-trace
  counts).  This is the diff/ingest-friendly form for scripts.

Both validators are stdlib-only (no jax, no jsonschema) so CI's lint-tier
jobs can check artifacts without the accelerator stack installed.
"""
from __future__ import annotations

import json
import os
import platform
import sys
import time

from . import counters as _counters
from .tracer import Tracer

__all__ = [
    "SCHEMA",
    "env_fingerprint",
    "to_chrome_trace",
    "to_jsonl_records",
    "trace_to_file",
    "validate_chrome_trace",
    "validate_jsonl_records",
    "write_trace",
]

SCHEMA = "repro-trace-v1"


def env_fingerprint() -> dict:
    """Where a measurement ran — stamped into every exported artifact.

    jax fields degrade to None when jax is absent (stdlib-only callers),
    never fail.
    """
    fp = {
        "python": platform.python_version(),
        "platform": platform.platform(),
        "machine": platform.machine(),
        "argv0": os.path.basename(sys.argv[0]) if sys.argv else None,
        "unix_time": time.time(),
        "jax": None,
        "jax_backend": None,
        "device_count": None,
    }
    try:
        import jax

        fp["jax"] = jax.__version__
        fp["jax_backend"] = jax.default_backend()
        fp["device_count"] = jax.device_count()
    except Exception:
        pass
    return fp


def to_chrome_trace(tracer: Tracer, *, metrics: dict | None = None,
                    meta: dict | None = None) -> dict:
    """The tracer's events as a Chrome trace-event JSON object."""
    events = [
        {
            "name": "process_name",
            "ph": "M",
            "pid": 0,
            "tid": 0,
            "args": {"name": "repro"},
        }
    ]
    for ev in tracer.events:
        args = dict(ev.get("args") or {})
        args["depth"] = ev["depth"]
        if "error" in ev:
            args["error"] = ev["error"]
        events.append(
            {
                "name": ev["name"],
                "cat": ev["cat"] or "default",
                "ph": "X",
                "ts": ev["ts_ns"] / 1e3,
                "dur": ev["dur_ns"] / 1e3,
                "pid": 0,
                "tid": 0,
                "args": args,
            }
        )
    other = {
        "schema": SCHEMA,
        "env": env_fingerprint(),
        "jit_traces": tracer.jit_traces,
    }
    if metrics is not None:
        other["metrics"] = metrics
    if meta or tracer.meta:
        other["meta"] = {**tracer.meta, **(meta or {})}
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": other,
    }


def to_jsonl_records(tracer: Tracer, *, metrics: dict | None = None,
                     meta: dict | None = None) -> "list[dict]":
    """The tracer's events as JSONL records (header, spans, metrics)."""
    records = [
        {
            "kind": "meta",
            "schema": SCHEMA,
            "env": env_fingerprint(),
            "meta": {**tracer.meta, **(meta or {})},
        }
    ]
    for ev in tracer.events:
        rec = {
            "kind": "span",
            "name": ev["name"],
            "cat": ev["cat"],
            "ts_ns": ev["ts_ns"],
            "dur_ns": ev["dur_ns"],
            "depth": ev["depth"],
        }
        if "args" in ev:
            rec["args"] = ev["args"]
        if "error" in ev:
            rec["error"] = ev["error"]
        records.append(rec)
    records.append(
        {
            "kind": "metrics",
            "metrics": metrics if metrics is not None else _counters.snapshot(),
            "jit_traces": tracer.jit_traces,
        }
    )
    return records


def write_trace(path: str, tracer: Tracer, *, metrics: dict | None = None,
                meta: dict | None = None) -> str:
    """Write the trace to ``path``; extension picks the format.

    ``.jsonl`` → JSONL event log, anything else → Chrome trace JSON.
    """
    if str(path).endswith(".jsonl"):
        body = "\n".join(
            json.dumps(rec, sort_keys=True)
            for rec in to_jsonl_records(tracer, metrics=metrics, meta=meta)
        ) + "\n"
    else:
        body = json.dumps(
            to_chrome_trace(tracer, metrics=metrics, meta=meta), indent=1
        )
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        f.write(body)
    os.replace(tmp, path)
    return str(path)


def trace_to_file(path: str | None, *, meta: dict | None = None):
    """CLI-facing scope: trace everything inside, export on exit.

    ``path=None`` yields a no-op scope so callers can write
    ``with trace_to_file(args.trace):`` unconditionally.  Metrics are
    snapshot at exit, so counters incremented inside the scope land in
    the artifact.
    """
    import contextlib

    from . import tracer as _tracer

    @contextlib.contextmanager
    def _scope():
        if not path:
            yield None
            return
        t = _tracer.start_tracing()
        try:
            yield t
        finally:
            _tracer.stop_tracing()
            write_trace(path, t, metrics=_counters.snapshot(), meta=meta)

    return _scope()


# -- stdlib validators (used by tests and the CI obs-smoke step) -------------


def validate_chrome_trace(obj) -> int:
    """Schema-check a Chrome trace object; returns the span-event count.

    Raises ``ValueError`` on any violation.  Checks exactly the
    properties Perfetto relies on: event list shape, complete-event
    fields, numeric non-negative ts/dur, and proper nesting state (a
    child span must close before its parent).
    """
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        raise ValueError("not a Chrome trace: missing 'traceEvents'")
    events = obj["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("'traceEvents' must be a list")
    spans = []
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i} is not an object")
        ph = ev.get("ph")
        if ph == "M":
            continue
        if ph != "X":
            raise ValueError(f"event {i}: unexpected phase {ph!r}")
        for key in ("name", "ts", "dur", "pid", "tid"):
            if key not in ev:
                raise ValueError(f"event {i}: missing {key!r}")
        if not isinstance(ev["name"], str) or not ev["name"]:
            raise ValueError(f"event {i}: bad name")
        ts, dur = ev["ts"], ev["dur"]
        if not isinstance(ts, (int, float)) or not isinstance(dur, (int, float)):
            raise ValueError(f"event {i}: non-numeric ts/dur")
        if ts < 0 or dur < 0:
            raise ValueError(f"event {i}: negative ts/dur")
        depth = ev.get("args", {}).get("depth")
        if not isinstance(depth, int) or depth < 0:
            raise ValueError(f"event {i}: missing/invalid args.depth")
        spans.append((ts, ts + dur, depth, ev["name"]))
    # spans are recorded in close order (a child's __exit__ runs before its
    # parent's), so a span's parent is the FIRST subsequent span one level
    # shallower; it must strictly contain the child.
    for i, (ts, end, depth, name) in enumerate(spans):
        if depth == 0:
            continue
        parent = next((s for s in spans[i + 1:] if s[2] == depth - 1), None)
        if parent is None:
            raise ValueError(f"span {name!r} at depth {depth} has no parent span")
        if ts < parent[0] - 1e-6 or end > parent[1] + 1e-6:
            raise ValueError(
                f"span {name!r} is not contained in its parent {parent[3]!r}"
            )
    return len(spans)


def validate_jsonl_records(records) -> int:
    """Schema-check parsed JSONL records; returns the span-record count."""
    records = list(records)
    if not records:
        raise ValueError("empty JSONL trace")
    head, tail = records[0], records[-1]
    if head.get("kind") != "meta" or head.get("schema") != SCHEMA:
        raise ValueError("first record must be a meta header with the schema tag")
    if not isinstance(head.get("env"), dict):
        raise ValueError("meta header missing env fingerprint")
    if tail.get("kind") != "metrics" or not isinstance(tail.get("metrics"), dict):
        raise ValueError("last record must be a metrics snapshot")
    n_spans = 0
    for i, rec in enumerate(records[1:-1], start=1):
        if rec.get("kind") != "span":
            raise ValueError(f"record {i}: expected a span record")
        for key in ("name", "ts_ns", "dur_ns", "depth"):
            if key not in rec:
                raise ValueError(f"record {i}: missing {key!r}")
        if rec["ts_ns"] < 0 or rec["dur_ns"] < 0 or rec["depth"] < 0:
            raise ValueError(f"record {i}: negative field")
        n_spans += 1
    return n_spans
