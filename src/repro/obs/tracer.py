"""Hierarchical span tracer for the triangle engine.

The paper's claims are *timings* (§V: 8–15× over CPU, 3.8B triangles in
under 10 s), so the repo needs a way to attribute a run's wall clock to
its phases.  This module is the core of that layer: a context-manager
span API producing nested, exportable timing events.

Three design constraints shape everything here:

* **Near-zero cost when disabled.**  Tracing is off by default; the hot
  path (``obs.span(...)`` in ``run_workload``'s chunk loop) must then
  cost one module-global read and allocate nothing.  ``span()`` returns
  the shared :data:`NOOP_SPAN` singleton when no tracer is active — the
  disabled path never constructs an object.
* **Spans measure device time, not async dispatch.**  JAX dispatches
  kernels asynchronously: wrapping a ``backend.count_chunk`` call in a
  naive timer measures enqueue latency while the actual compute lands in
  whichever later operation blocks (usually the host fold).  A span
  wrapping device work must therefore call :meth:`Span.sync` (which is
  ``jax.block_until_ready`` under an active tracer and the identity
  otherwise) before it closes.  The trilint ``obs_discipline`` pass
  enforces this statically.
* **Import-time stdlib-only.**  ``jax`` is imported lazily inside
  ``sync`` so the exporters and validators run in jax-free contexts
  (the stdlib-only CI lint job validates trace schemas without jax).

Events are recorded as plain dicts (``name``/``cat``/``ts_ns``/
``dur_ns``/``depth``/``args``) relative to the tracer's origin, ready
for the Chrome trace-event / JSONL exporters in :mod:`repro.obs.export`.
A tracer also runs a :class:`repro.check.runtime.CompileAuditor` for its
lifetime, so every exported trace reports how many jit traces the run
minted per kernel.
"""
from __future__ import annotations

import contextlib
import time

__all__ = [
    "NOOP_SPAN",
    "Span",
    "Tracer",
    "active",
    "enabled",
    "span",
    "start_tracing",
    "stop_tracing",
    "sync",
    "tracing",
]


class Span:
    """One live span of an active :class:`Tracer` (context manager).

    Records an event on ``__exit__`` even when the body raises (the
    event then carries an ``error`` key) — a crash mid-phase still
    leaves a closed, exportable span.  Call :meth:`sync` on any value
    backed by device computation before the span closes, so the span
    measures compute rather than async dispatch.
    """

    __slots__ = ("_tracer", "name", "cat", "args", "_t0", "_depth")

    def __init__(self, tracer: "Tracer", name: str, cat: str, args):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.args = dict(args) if args else None
        self._t0 = 0
        self._depth = 0

    def __enter__(self) -> "Span":
        t = self._tracer
        self._depth = t._depth
        t._depth += 1
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        t1 = time.perf_counter_ns()
        t = self._tracer
        t._depth = self._depth
        event = {
            "name": self.name,
            "cat": self.cat,
            "ts_ns": self._t0 - t._origin_ns,
            "dur_ns": t1 - self._t0,
            "depth": self._depth,
        }
        if self.args:
            event["args"] = self.args
        if exc_type is not None:
            event["error"] = exc_type.__name__
        t.events.append(event)
        return False

    def sync(self, value):
        """``jax.block_until_ready(value)`` — the span's sync point.

        Ensures the span's close time covers the device work that
        produced ``value`` instead of just its dispatch.
        """
        import jax

        return jax.block_until_ready(value)

    def set(self, **kwargs) -> "Span":
        """Attach/overwrite args on the span (shows up in exports)."""
        if self.args is None:
            self.args = {}
        self.args.update(kwargs)
        return self


class _NoopSpan:
    """The disabled-mode span: every operation is free and allocation-less.

    A single module-level instance (:data:`NOOP_SPAN`) is shared by all
    disabled ``span()`` calls — tests assert the identity to pin the
    no-allocation guarantee.
    """

    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False

    def sync(self, value):
        return value

    def set(self, **kwargs) -> "_NoopSpan":
        return self


NOOP_SPAN = _NoopSpan()


class Tracer:
    """Collects span events (and jit-trace counts) for one traced region.

    Not thread-safe — the engine is single-threaded host-side, and a
    tracer's span stack is per-process state exactly like the engine's
    ``last_stats``.
    """

    def __init__(self, *, audit_compiles: bool = True):
        self.events: list[dict] = []
        self.meta: dict = {}
        self.jit_traces: dict[str, int] = {}
        self._origin_ns = time.perf_counter_ns()
        self._depth = 0
        self._audit_compiles = audit_compiles
        self._auditor = None

    def span(self, name: str, cat: str = "", args=None) -> Span:
        return Span(self, name, cat, args)

    def instant(self, name: str, cat: str = "", args=None) -> None:
        """Record a zero-duration marker event."""
        event = {
            "name": name,
            "cat": cat,
            "ts_ns": time.perf_counter_ns() - self._origin_ns,
            "dur_ns": 0,
            "depth": self._depth,
        }
        if args:
            event["args"] = dict(args)
        self.events.append(event)

    def wall_s(self) -> float:
        """Seconds from the tracer's origin to now (or to the last event)."""
        return (time.perf_counter_ns() - self._origin_ns) / 1e9

    # -- lifecycle (driven by start_tracing/stop_tracing) -------------------

    def _start(self) -> None:
        if self._audit_compiles:
            try:
                from repro.check.runtime import CompileAuditor

                self._auditor = CompileAuditor()
                self._auditor.__enter__()
            except Exception:  # jax unavailable: tracer still works, no audit
                self._auditor = None
        # re-anchor after the auditor's (possibly first) jax import, so the
        # first span doesn't inherit the import cost as leading dead time
        self._origin_ns = time.perf_counter_ns()

    def _finish(self) -> None:
        if self._auditor is None:
            return
        auditor, self._auditor = self._auditor, None
        auditor.__exit__(None, None, None)
        self.jit_traces = {k: v for k, v in auditor.new_traces.items() if v}


# -- module-level switchboard ------------------------------------------------
#
# One active tracer per process, mirroring how the engine's stats and
# fallback warnings are process-global.  The disabled fast path is a
# single global read.

_ACTIVE: Tracer | None = None


def active() -> Tracer | None:
    """The active tracer, or None when tracing is disabled."""
    return _ACTIVE


def enabled() -> bool:
    return _ACTIVE is not None


def span(name: str, cat: str = "", args=None):
    """A span on the active tracer, or :data:`NOOP_SPAN` when disabled."""
    t = _ACTIVE
    if t is None:
        return NOOP_SPAN
    return t.span(name, cat, args)


def sync(value):
    """Block on ``value`` iff tracing is active (free otherwise)."""
    if _ACTIVE is None:
        return value
    import jax

    return jax.block_until_ready(value)


def start_tracing(tracer: Tracer | None = None) -> Tracer:
    """Install (and start) the process-wide tracer.

    Nested tracing is rejected loudly: two tracers would silently split
    the event stream, and every caller here owns a whole CLI run.
    """
    global _ACTIVE
    if _ACTIVE is not None:
        raise RuntimeError("tracing is already active; stop_tracing() first")
    t = tracer if tracer is not None else Tracer()
    t._start()
    _ACTIVE = t
    return t


def stop_tracing() -> Tracer | None:
    """Uninstall the active tracer (folding in jit-trace counts)."""
    global _ACTIVE
    t, _ACTIVE = _ACTIVE, None
    if t is not None:
        t._finish()
    return t


@contextlib.contextmanager
def tracing(tracer: Tracer | None = None):
    """``with obs.tracing() as t:`` — scoped start/stop."""
    t = start_tracing(tracer)
    try:
        yield t
    finally:
        stop_tracing()
