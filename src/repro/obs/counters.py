"""Process-wide counters and gauges for the triangle engine.

Counters are monotonic event tallies (chunks launched, wedges planned,
`.tricsr` cache hits, capability fallbacks); gauges hold last-written
values (peak wedge buffer, stripe count).  Both are plain attribute
writes on ``__slots__`` objects — cheap enough to leave permanently on
in ``run_workload``'s hot path, unlike spans which gate on an active
tracer.

The registry is module-global and append-only within a process; tests
and the CLI exporters take :func:`snapshot` (a plain dict, ready for
JSON) and may :func:`reset` between measurements.  Stdlib-only.
"""
from __future__ import annotations

__all__ = [
    "Counter",
    "Gauge",
    "MetricsRegistry",
    "counter",
    "gauge",
    "registry",
    "reset",
    "snapshot",
]


class Counter:
    """Monotonic int tally."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def add(self, n: int = 1) -> None:
        self.value += int(n)


class Gauge:
    """Last-written value."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = None

    def set(self, value) -> None:
        self.value = value


class MetricsRegistry:
    """Name → instrument map; instruments are created on first touch."""

    def __init__(self):
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name)
        return g

    def snapshot(self) -> dict:
        """JSON-ready ``{"counters": {...}, "gauges": {...}}``."""
        return {
            "counters": {k: c.value for k, c in sorted(self._counters.items())},
            "gauges": {k: g.value for k, g in sorted(self._gauges.items())},
        }

    def reset(self) -> None:
        self._counters.clear()
        self._gauges.clear()


_REGISTRY = MetricsRegistry()


def registry() -> MetricsRegistry:
    return _REGISTRY


def counter(name: str) -> Counter:
    return _REGISTRY.counter(name)


def gauge(name: str) -> Gauge:
    return _REGISTRY.gauge(name)


def snapshot() -> dict:
    return _REGISTRY.snapshot()


def reset() -> None:
    _REGISTRY.reset()
