"""Pow2-bucket latency histograms with rolling-window aggregation.

``serve_graph`` previously kept every latency sample in a list and
computed p50/p99 once at exit — unbounded memory on long streams and no
visibility until shutdown.  These histograms fix both: a
:class:`Pow2Histogram` is 64 integer buckets (bucket ``b`` holds
durations in ``[2^b, 2^(b+1))`` nanoseconds — the same pow2 bucketing
discipline the engine applies to wedge-buffer shapes), so memory is O(1)
per instrument, merging is element-wise addition, and percentiles come
from bucket interpolation with bounded relative error (a bucket spans a
factor of 2, so a percentile estimate is within 2× and in practice much
closer via linear interpolation inside the bucket).

:class:`RollingHistogram` composes intervals: observations land in the
current interval's histogram, :meth:`RollingHistogram.rotate` seals it
into a bounded deque, and window percentiles merge the last ``window``
intervals — "p99 over the last N reporting intervals", not "p99 since
process start".  Stdlib-only.
"""
from __future__ import annotations

import collections
import threading

__all__ = ["N_BUCKETS", "Pow2Histogram", "ConcurrentHistogram", "RollingHistogram"]

N_BUCKETS = 64  # 2^63 ns ≈ 292 years: every representable latency fits


def _bucket_of(ns: int) -> int:
    if ns <= 0:
        return 0
    return min(int(ns).bit_length() - 1, N_BUCKETS - 1)


class Pow2Histogram:
    """Fixed-size power-of-two latency histogram (nanosecond buckets)."""

    __slots__ = ("counts", "n", "total_ns")

    def __init__(self):
        self.counts = [0] * N_BUCKETS
        self.n = 0
        self.total_ns = 0

    def observe_ns(self, ns: int) -> None:
        self.counts[_bucket_of(ns)] += 1
        self.n += 1
        self.total_ns += int(ns)

    def observe(self, seconds: float) -> None:
        self.observe_ns(int(seconds * 1e9))

    def merge(self, other: "Pow2Histogram") -> "Pow2Histogram":
        for b in range(N_BUCKETS):
            self.counts[b] += other.counts[b]
        self.n += other.n
        self.total_ns += other.total_ns
        return self

    def mean_s(self) -> float:
        return (self.total_ns / self.n) / 1e9 if self.n else 0.0

    def percentile(self, q: float) -> float:
        """Estimated q-th percentile in **seconds** (bucket-interpolated)."""
        if self.n == 0:
            return 0.0
        if not 0.0 < q <= 100.0:
            raise ValueError(f"percentile must be in (0, 100], got {q}")
        # rank of the target sample (1-based), then linear interpolation
        # between the bucket's lower and upper bound
        target = max(1, -(-self.n * q // 100))  # ceil(n*q/100)
        cum = 0
        for b, c in enumerate(self.counts):
            if cum + c >= target:
                lo = float(1 << b) if b else 0.0
                hi = float(1 << (b + 1))
                frac = (target - cum) / c
                return (lo + (hi - lo) * frac) / 1e9
            cum += c
        return float(1 << N_BUCKETS) / 1e9  # unreachable with consistent n

    def percentiles(self, qs=(50.0, 90.0, 99.0)) -> dict:
        return {f"p{int(q)}": self.percentile(q) for q in qs}

    def snapshot_ms(self) -> dict:
        """JSON-ready summary in milliseconds."""
        pct = self.percentiles()
        return {
            "n": self.n,
            "mean_ms": self.mean_s() * 1e3,
            "p50_ms": pct["p50"] * 1e3,
            "p90_ms": pct["p90"] * 1e3,
            "p99_ms": pct["p99"] * 1e3,
        }


class ConcurrentHistogram(Pow2Histogram):
    """A :class:`Pow2Histogram` safe for concurrent observers.

    ``counts[b] += 1`` is a read-modify-write — many client threads
    observing into one shared histogram (the serve load generator's
    per-traffic-class instruments) would drop samples without the lock.
    Reads (:meth:`percentile`, :meth:`snapshot_ms`) stay lock-free: they
    run after the observers join, or tolerate a torn-in-flight view for
    progress reporting.
    """

    __slots__ = ("_lock",)

    def __init__(self):
        super().__init__()
        self._lock = threading.Lock()

    def observe_ns(self, ns: int) -> None:
        with self._lock:
            super().observe_ns(ns)

    def merge(self, other: "Pow2Histogram") -> "Pow2Histogram":
        with self._lock:
            return super().merge(other)


class RollingHistogram:
    """A bounded window of per-interval :class:`Pow2Histogram` instances."""

    __slots__ = ("window", "intervals", "lifetime")

    def __init__(self, window: int = 8):
        if window < 1:
            raise ValueError("window must be >= 1")
        self.window = window
        self.intervals: collections.deque = collections.deque(
            [Pow2Histogram()], maxlen=window
        )
        self.lifetime = Pow2Histogram()

    @property
    def current(self) -> Pow2Histogram:
        return self.intervals[-1]

    def observe(self, seconds: float) -> None:
        ns = int(seconds * 1e9)
        self.intervals[-1].observe_ns(ns)
        self.lifetime.observe_ns(ns)

    def rotate(self) -> Pow2Histogram:
        """Seal the current interval and start a fresh one; returns sealed."""
        sealed = self.intervals[-1]
        self.intervals.append(Pow2Histogram())
        return sealed

    def windowed(self) -> Pow2Histogram:
        """Merged histogram over the retained window (incl. current)."""
        merged = Pow2Histogram()
        for h in self.intervals:
            merged.merge(h)
        return merged
