"""Roofline-term extraction from compiled (AOT) artifacts.

Hardware model: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.

* compute   = HLO_FLOPs_total   / (chips · peak)
* memory    = HLO_bytes_total   / (chips · HBM_bw)
* collective= collective_bytes  / (chips · link_bw)

``cost_analysis`` on the SPMD-partitioned module reports *per-device*
flops/bytes; totals are per-device × chips, so the two formulations agree.
``collective_bytes`` is not in ``cost_analysis``: we parse the optimized
HLO and sum operand bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute (including their
async -start forms).
"""
from __future__ import annotations

import dataclasses
import re

__all__ = ["HW", "Hardware", "collective_stats", "roofline_terms", "RooflineReport"]


@dataclasses.dataclass(frozen=True)
class Hardware:
    peak_flops: float = 197e12   # bf16 FLOP/s per chip
    hbm_bw: float = 819e9        # B/s per chip
    ici_bw: float = 50e9         # B/s per link


HW = Hardware()

_COLLECTIVE_OPS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_stats(hlo_text: str) -> dict:
    """Per-collective-kind operand-byte totals + op counts from HLO text."""
    totals = {k: 0 for k in _COLLECTIVE_OPS}
    counts = {k: 0 for k in _COLLECTIVE_OPS}
    largest = 0
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        kind = m.group(2)
        # operand shapes appear after the opcode's '('
        _, _, operands = line.partition(m.group(2))
        op_bytes = sum(
            _shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(operands)
        )
        totals[kind] += op_bytes
        counts[kind] += 1
        largest = max(largest, op_bytes)
    return {
        "bytes_by_kind": totals,
        "count_by_kind": counts,
        "total_bytes": sum(totals.values()),
        "largest_op_bytes": largest,
    }


@dataclasses.dataclass
class RooflineReport:
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    model_flops: float
    collectives: dict
    hw: Hardware = HW
    xla_flops_per_device: float = 0.0   # raw cost_analysis (loop bodies ×1)
    xla_bytes_per_device: float = 0.0
    by_prim: dict = dataclasses.field(default_factory=dict)

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / self.hw.peak_flops

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / self.hw.hbm_bw

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_device / self.hw.ici_bw

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline estimate: max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """MODEL_FLOPs-based MFU at the roofline step time (the score)."""
        denom = self.step_time_s * self.chips * self.hw.peak_flops
        return self.model_flops / denom if denom else 0.0

    def to_dict(self) -> dict:
        return {
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "model_flops": self.model_flops,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "step_time_s": self.step_time_s,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "collectives": self.collectives,
            "xla_flops_per_device": self.xla_flops_per_device,
            "xla_bytes_per_device": self.xla_bytes_per_device,
            "by_prim": self.by_prim,
        }


def roofline_terms(
    compiled, chips: int, model_flops: float, walker_cost: dict | None = None
) -> RooflineReport:
    """Build the report.

    ``walker_cost`` (from :mod:`repro.launch.flops`) provides loop-aware
    GLOBAL flops/bytes; per-device = global / chips.  The raw
    ``cost_analysis`` numbers (per-device, loop bodies counted once) are
    kept for reference.  Collective bytes always come from the partitioned
    HLO (exact, per-device).
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))
    if walker_cost is not None:
        flops = walker_cost["flops"] / chips
        byts = walker_cost["bytes"] / chips
        by_prim = walker_cost.get("by_prim", {})
    else:
        flops, byts, by_prim = xla_flops, xla_bytes, {}
    stats = collective_stats(compiled.as_text())
    return RooflineReport(
        chips=chips,
        flops_per_device=flops,
        bytes_per_device=byts,
        collective_bytes_per_device=float(stats["total_bytes"]),
        model_flops=model_flops,
        collectives=stats,
        xla_flops_per_device=xla_flops,
        xla_bytes_per_device=xla_bytes,
        by_prim=by_prim,
    )
