"""Jaxpr cost walker: FLOPs + logical HBM traffic with loop multipliers.

XLA's ``compiled.cost_analysis()`` counts every ``while``/``scan`` body
exactly once, which under-counts a scanned-layers transformer by ~depth×.
This walker traverses the *unoptimized* jaxpr instead and multiplies
through ``scan`` lengths (and shard-mapped sub-jaxprs by their mesh
factor), so:

* ``flops``  — exact for ``dot_general``/``ragged_dot`` (2·M·N·K), which
  dominate; elementwise ops count 1 FLOP/element.  Because the jaxpr of a
  ``value_and_grad`` function contains the remat-replayed forward
  explicitly, recompute is included (this is what makes the
  MODEL_FLOPS/HLO_FLOPS ratio catch remat waste).
* ``bytes``  — Σ (operand + result) sizes per primitive: an *unfused*
  upper bound on HBM traffic.  Reshape/bitcast are free; broadcasts count
  output only.  Fusion would lower the true number; sharding, dtype and
  remat changes move this metric in the right direction, which is what
  the §Perf loop needs.

All numbers are GLOBAL (logical shapes); callers divide by chip count for
per-device terms (exact for fully-partitioned tensors, optimistic for
replicated ones — the collective term from the partitioned HLO catches
the replication cost separately).
"""
from __future__ import annotations

import math
from collections import defaultdict

import jax
import jax.extend.core as jex_core
import numpy as np

__all__ = ["jaxpr_cost", "trace_cost"]

_FREE = {
    "reshape", "bitcast_convert_type", "stop_gradient", "copy",
    "squeeze", "expand_dims", "pjit_p",
}


def _size(av) -> int:
    return int(np.prod(av.shape)) if hasattr(av, "shape") else 1


def _bytes(av) -> int:
    if not hasattr(av, "dtype"):
        return 0
    try:
        itemsize = np.dtype(av.dtype).itemsize
    except TypeError:  # extended dtypes (typed PRNG keys etc.)
        itemsize = 8
    return _size(av) * itemsize


def _dot_flops(eqn) -> int:
    ((lc, rc), (lb, rb)) = eqn.params["dimension_numbers"]
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    batch = int(np.prod([lhs.shape[i] for i in lb])) if lb else 1
    contract = int(np.prod([lhs.shape[i] for i in lc])) if lc else 1
    m = int(np.prod([s for i, s in enumerate(lhs.shape) if i not in lc and i not in lb]))
    n = int(np.prod([s for i, s in enumerate(rhs.shape) if i not in rc and i not in rb]))
    return 2 * batch * m * n * contract


def _ragged_dot_flops(eqn) -> int:
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    m, k = lhs.shape[-2], lhs.shape[-1]
    n = rhs.shape[-1]
    return 2 * m * k * n  # each lhs row hits exactly one expert group


def _sub_jaxprs(eqn):
    for v in eqn.params.values():
        if isinstance(v, jex_core.ClosedJaxpr):
            yield v
        elif isinstance(v, jex_core.Jaxpr):
            yield jex_core.ClosedJaxpr(v, ())
        elif isinstance(v, (tuple, list)):
            for x in v:
                if isinstance(x, jex_core.ClosedJaxpr):
                    yield x


def jaxpr_cost(closed, mult: float = 1.0, acc=None) -> dict:
    if acc is None:
        acc = {"flops": 0.0, "bytes": 0.0, "by_prim": defaultdict(float), "bytes_by_prim": defaultdict(float), "warnings": set()}
    for eqn in closed.jaxpr.eqns:
        prim = eqn.primitive.name
        in_b = sum(_bytes(v.aval) for v in eqn.invars if hasattr(v, "aval"))
        out_b = sum(_bytes(v.aval) for v in eqn.outvars)
        if prim in ("scan",):
            length = eqn.params.get("length", 1)
            for sub in _sub_jaxprs(eqn):
                jaxpr_cost(sub, mult * length, acc)
            continue
        if prim in ("while",):
            acc["warnings"].add("while-loop body counted once (unknown trip count)")
            for sub in _sub_jaxprs(eqn):
                jaxpr_cost(sub, mult, acc)
            continue
        if prim in ("shard_map",):
            mesh = eqn.params.get("mesh")
            factor = math.prod(mesh.devices.shape) if mesh is not None else 1
            for sub in _sub_jaxprs(eqn):
                jaxpr_cost(sub, mult * factor, acc)
            continue
        subs = list(_sub_jaxprs(eqn))
        if prim == "cond" and subs:
            # count the most expensive branch
            branch_costs = []
            for sub in subs:
                a = {"flops": 0.0, "bytes": 0.0, "by_prim": defaultdict(float), "warnings": set()}
                jaxpr_cost(sub, mult, a)
                branch_costs.append(a)
            worst = max(branch_costs, key=lambda a: a["flops"])
            acc["flops"] += worst["flops"]
            acc["bytes"] += worst["bytes"]
            for k, v in worst["by_prim"].items():
                acc["by_prim"][k] += v
            for k, v in worst["bytes_by_prim"].items():
                acc["bytes_by_prim"][k] += v
            acc["warnings"] |= worst["warnings"]
            continue
        if subs:  # pjit / remat2 / custom_vjp / closed_call / …
            for sub in subs:
                jaxpr_cost(sub, mult, acc)
            continue
        if prim in _FREE:
            continue
        if prim == "dot_general":
            f = _dot_flops(eqn)
            acc["flops"] += mult * f
            acc["bytes"] += mult * (in_b + out_b)
            acc["by_prim"]["dot_general"] += mult * f
            acc["bytes_by_prim"]["dot_general"] += mult * (in_b + out_b)
            continue
        if prim == "ragged_dot":
            f = _ragged_dot_flops(eqn)
            acc["flops"] += mult * f
            acc["bytes"] += mult * (in_b + out_b)
            acc["by_prim"]["ragged_dot"] += mult * f
            acc["bytes_by_prim"]["ragged_dot"] += mult * (in_b + out_b)
            continue
        if prim == "sort":
            n = max(_size(v.aval) for v in eqn.invars)
            logn = max(1.0, math.log2(max(n, 2)))
            acc["flops"] += mult * n * logn
            acc["bytes"] += mult * (in_b + out_b) * logn
            acc["by_prim"]["sort"] += mult * n * logn
            acc["bytes_by_prim"]["sort"] += mult * (in_b + out_b) * logn
            continue
        if prim in ("gather", "take", "dynamic_slice"):
            # read the touched rows + indices, write the result
            idx_b = _bytes(eqn.invars[1].aval) if len(eqn.invars) > 1 else 0
            b = 2 * out_b + idx_b
            acc["flops"] += mult * _size(eqn.outvars[0].aval) / 4
            acc["bytes"] += mult * b
            acc["bytes_by_prim"]["gather"] += mult * b
            continue
        if prim in ("scatter", "scatter-add", "scatter_add", "scatter-update"):
            upd_b = _bytes(eqn.invars[2].aval) if len(eqn.invars) > 2 else out_b
            idx_b = _bytes(eqn.invars[1].aval) if len(eqn.invars) > 1 else 0
            b = 3 * upd_b + idx_b  # read-modify-write on touched region
            acc["flops"] += mult * upd_b / 4
            acc["bytes"] += mult * b
            acc["bytes_by_prim"]["scatter"] += mult * b
            continue
        if prim == "dynamic_update_slice":
            upd_b = _bytes(eqn.invars[1].aval)
            acc["bytes"] += mult * 2 * upd_b
            acc["bytes_by_prim"]["scatter"] += mult * 2 * upd_b
            continue
        if prim in ("concatenate", "pad"):
            acc["bytes"] += mult * out_b
            acc["bytes_by_prim"]["layout"] += mult * out_b
            continue
        if prim in ("broadcast_in_dim", "iota", "convert_element_type", "transpose",
                    "rev", "slice", "select_n"):
            # layout/fused ops: no HBM round trip charged
            out_n = sum(_size(v.aval) for v in eqn.outvars)
            acc["flops"] += mult * out_n * (0 if prim in ("broadcast_in_dim", "iota") else 1)
            continue
        # generic elementwise / reduce: FLOPs yes, traffic assumed fused
        out_n = sum(_size(v.aval) for v in eqn.outvars)
        acc["flops"] += mult * out_n
        acc["by_prim"]["elementwise"] += mult * out_n
    return acc


def trace_cost(fn, *args) -> dict:
    """Abstract-trace ``fn(*args)`` (ShapeDtypeStructs fine) and walk it."""
    closed = jax.make_jaxpr(fn)(*args)
    acc = jaxpr_cost(closed)
    return {
        "flops": float(acc["flops"]),
        "bytes": float(acc["bytes"]),
        "by_prim": dict(acc["by_prim"]),
        "bytes_by_prim": dict(acc["bytes_by_prim"]),
        "warnings": sorted(acc["warnings"]),
    }
