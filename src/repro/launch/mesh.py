"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches JAX device state — the dry-run driver must set
``XLA_FLAGS`` before the first device query.
"""
from __future__ import annotations

import jax

try:  # jax ≥ 0.5 has explicit axis types
    from jax.sharding import AxisType

    def _mesh(shape, axes):
        return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
except ImportError:  # jax 0.4.x: every axis is implicitly auto
    def _mesh(shape, axes):
        return jax.make_mesh(shape, axes)

__all__ = ["make_production_mesh", "make_local_mesh", "DATA_AXES", "ALL_AXES"]

DATA_AXES = ("pod", "data")   # gradient / batch parallelism axes
ALL_AXES = ("pod", "data", "model")


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_local_mesh(data: int | None = None, model: int = 1):
    """Mesh over whatever devices exist (tests, smoke runs, elastic restart)."""
    n = len(jax.devices())
    if data is None:
        data = n // model
    if data * model > n:
        raise ValueError(f"requested {data}×{model} mesh on {n} devices")
    return _mesh((data, model), ("data", "model"))
