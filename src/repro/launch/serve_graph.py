"""Graph-query service over an edge stream — the ROADMAP serving workload.

Drives :class:`repro.core.IncrementalTriangleCounter` with a request loop
that interleaves update batches (from ``repro.graphs.streams``) with
count / per-node / clustering / transitivity queries, and reports p50/p99
latency for both traffic classes::

    python -m repro.launch.serve_graph --generator kronecker --scale 10
    python -m repro.launch.serve_graph --scale 10 --stream sliding_window \\
        --window 20000 --batch-size 512 --queries-per-batch 8
    python -m repro.launch.serve_graph --scale 12 --max-wedge-chunk 1048576
    python -m repro.launch.serve_graph --scale 10 --method pallas   # Pallas probes
    python -m repro.launch.serve_graph --dataset karate --batch-size 16
    python -m repro.launch.serve_graph --input graph.txt.gz --cache-dir ~/.cache/tricsr

Updates run the batched delta-counting path (only triangles touched by
the batch are recounted); queries read the maintained state, so they are
microseconds regardless of graph size.  Unless ``--no-verify`` is given,
the final maintained count is checked against a from-scratch
``TriangleCounter(method="auto")`` recount of the live edge set and the
process exits non-zero on any mismatch — a speedup from a wrong count is
worthless.  Under overload, exact incremental updates can be traded for
DOULION sparsified recounts (``repro.core.approx``); this loop serves
the exact path.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import IncrementalTriangleCounter, TriangleCounter
from repro.graphs import STREAM_GENERATORS
from repro.launch.count import add_source_arguments, resolve_graph

QUERY_KINDS = ("count", "per_node", "clustering", "transitivity")


def _pct(lat_s, q):
    return float(np.percentile(np.asarray(lat_s) * 1e3, q)) if lat_s else 0.0


def run_service(
    stream,
    *,
    n_nodes: int,
    max_batches: int | None = None,
    queries_per_batch: int = 4,
    max_wedge_chunk: int | None = None,
    method: str = "auto",
    mesh=None,
):
    """Apply ``stream`` batches interleaved with queries; return a report."""
    counter = IncrementalTriangleCounter(
        n_nodes=n_nodes, max_wedge_chunk=max_wedge_chunk, method=method, mesh=mesh
    )
    update_lat, query_lat = [], []
    n_batches = n_inserted = n_deleted = 0
    qi = 0
    for batch in stream:
        if max_batches is not None and n_batches >= max_batches:
            break
        t0 = time.perf_counter()
        counter.apply(insert=batch.insert, delete=batch.delete)
        update_lat.append(time.perf_counter() - t0)
        n_batches += 1
        n_inserted += batch.insert.shape[0]
        n_deleted += batch.delete.shape[0]
        for _ in range(queries_per_batch):
            kind = QUERY_KINDS[qi % len(QUERY_KINDS)]
            qi += 1
            t0 = time.perf_counter()
            if kind == "count":
                _ = counter.count
            elif kind == "per_node":
                _ = counter.per_node()
            elif kind == "clustering":
                _ = counter.clustering()
            else:
                _ = counter.transitivity()
            query_lat.append(time.perf_counter() - t0)
    return counter, dict(
        n_batches=n_batches,
        n_inserted=n_inserted,
        n_deleted=n_deleted,
        n_queries=len(query_lat),
        update_p50_ms=_pct(update_lat, 50),
        update_p99_ms=_pct(update_lat, 99),
        query_p50_ms=_pct(query_lat, 50),
        query_p99_ms=_pct(query_lat, 99),
        updates_per_s=(n_inserted + n_deleted) / max(sum(update_lat), 1e-12),
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    add_source_arguments(ap)
    ap.set_defaults(scale=10)  # serving default: smaller than count.py's
    ap.add_argument("--stream", choices=sorted(STREAM_GENERATORS), default="temporal")
    ap.add_argument("--window", type=int, default=None,
                    help="live-edge window for sliding_window (default: half "
                         "the graph's undirected edges)")
    ap.add_argument("--batch-size", type=int, default=512)
    ap.add_argument("--max-batches", type=int, default=None,
                    help="stop after this many update batches (default: drain)")
    ap.add_argument("--queries-per-batch", type=int, default=4)
    ap.add_argument("--max-wedge-chunk", type=int, default=None,
                    help="wedge-buffer budget per launch, applied to every "
                         "update batch's probe workload")
    ap.add_argument("--method", default="auto",
                    choices=["auto", "wedge_bsearch", "panel", "pallas",
                             "distributed"],
                    help="kernel backend for the bootstrap count and the "
                         "update probes (auto keeps probes on the wedge "
                         "schedule; panel/pallas route them through the "
                         "panel/Pallas backend; distributed stripes them "
                         "§III-E-style over a mesh of all local devices)")
    ap.add_argument("--no-verify", action="store_true",
                    help="skip the final from-scratch oracle recount")
    args = ap.parse_args()
    if args.window is not None and args.window < 1:
        ap.error("--window must be a positive number of live edges")
    if args.batch_size < 1:
        ap.error("--batch-size must be positive")

    mesh = None
    if args.method == "distributed":
        import jax

        devs = jax.devices()
        mesh = jax.sharding.Mesh(np.array(devs), ("edges",))
        print(f"mesh: {len(devs)} device(s) striped on axis 'edges'")

    graph, info = resolve_graph(args)
    # streams consume edge arrays; a cached CSR seed materializes one
    # (the cheap direction — one np.repeat over the memory-mapped CSR)
    edges = graph.edge_array() if hasattr(graph, "edge_array") else graph
    stats = info["graph"]

    if args.stream == "sliding_window":
        window = (args.window if args.window is not None
                  else max(stats["n_edges"] // 2, 1))
        stream = STREAM_GENERATORS[args.stream](
            edges, window=window, batch_size=args.batch_size, seed=args.seed
        )
        print(f"stream: sliding_window(window={window}, batch={args.batch_size})")
    else:
        stream = STREAM_GENERATORS[args.stream](
            edges, batch_size=args.batch_size, seed=args.seed
        )
        print(f"stream: temporal(batch={args.batch_size})")

    counter, rep = run_service(
        stream,
        n_nodes=stats["n_nodes"],
        max_batches=args.max_batches,
        queries_per_batch=args.queries_per_batch,
        max_wedge_chunk=args.max_wedge_chunk,
        method=args.method,
        mesh=mesh,
    )
    if counter.last_update_stats is not None:
        print(f"probe backend: {counter.last_update_stats.probe_method}")
    print(f"served {rep['n_batches']} update batches "
          f"(+{rep['n_inserted']}/-{rep['n_deleted']} edges, "
          f"{rep['updates_per_s']:.0f} edge-updates/s) "
          f"and {rep['n_queries']} queries")
    print(f"update latency: p50 {rep['update_p50_ms']:.2f} ms, "
          f"p99 {rep['update_p99_ms']:.2f} ms")
    print(f"query  latency: p50 {rep['query_p50_ms']:.3f} ms, "
          f"p99 {rep['query_p99_ms']:.3f} ms")
    print(f"live graph: {counter.n_edges} edges, T = {counter.count}")

    if not args.no_verify:
        tc = TriangleCounter(
            method=args.method, max_wedge_chunk=args.max_wedge_chunk, mesh=mesh
        )
        expect = tc.count(counter.current_edges(), n_nodes=counter.n_nodes)
        if counter.count != expect:
            raise SystemExit(
                f"VERIFY FAILED: incremental T={counter.count} != oracle {expect}"
            )
        print(f"verify: from-scratch recount agrees (T = {expect})")


if __name__ == "__main__":
    main()
