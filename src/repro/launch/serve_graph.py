"""Graph-query serving CLI — thin front-end over :mod:`repro.serve`.

The drive loop itself lives in :func:`repro.serve.session.drive_stream`
(single-tenant: update batches from :mod:`repro.graphs.streams`
interleaved with count / per-node / clustering / transitivity queries,
pow2 latency histograms per traffic class, rolling-window interval
reports).  This module keeps the historical CLI surface — every flag
and every ``--json`` report key is unchanged — and adds the
snapshot/resume flags the serving subsystem provides::

    python -m repro.launch.serve_graph --generator kronecker --scale 10
    python -m repro.launch.serve_graph --scale 10 --stream sliding_window \\
        --window 20000 --batch-size 512 --queries-per-batch 8
    python -m repro.launch.serve_graph --dataset karate --batch-size 16
    python -m repro.launch.serve_graph --scale 10 --json \\
        --metrics-out /tmp/serve_metrics.jsonl --report-every 16

    # kill-safe serving: snapshot every 64 batches; a rerun with
    # --resume restores the newest valid snapshot and picks the stream
    # up mid-flight (identical final state to an uninterrupted run)
    python -m repro.launch.serve_graph --scale 10 --max-batches 512 \\
        --snapshot-dir /tmp/serve_snap --snapshot-every 64
    python -m repro.launch.serve_graph --scale 10 --max-batches 1024 \\
        --snapshot-dir /tmp/serve_snap --resume

Unless ``--no-verify`` is given, the final maintained count is checked
against a from-scratch ``TriangleCounter`` recount of the live edge set
and the process exits non-zero on any mismatch — a speedup from a wrong
count is worthless.  The multi-tenant service (admission queues, query
fusion, graph residency) is :class:`repro.serve.GraphService`; its load
generator CLI is ``python -m repro.serve.loadgen``.
"""
from __future__ import annotations

import argparse
import functools
import json
import sys

import numpy as np

from repro import obs
from repro.core import TriangleCounter
from repro.graphs import STREAM_GENERATORS
from repro.launch.count import (
    add_source_arguments,
    add_trace_argument,
    resolve_graph,
)
from repro.serve import SnapshotStore, drive_stream
from repro.serve.session import QUERY_KINDS  # noqa: F401  (legacy re-export)


def run_service(stream, **kwargs):
    """Back-compat alias for :func:`repro.serve.session.drive_stream`."""
    return drive_stream(stream, **kwargs)


def main() -> None:
    ap = argparse.ArgumentParser()
    add_source_arguments(ap)
    ap.set_defaults(scale=10)  # serving default: smaller than count.py's
    ap.add_argument("--stream", choices=sorted(STREAM_GENERATORS), default="temporal")
    ap.add_argument("--window", type=int, default=None,
                    help="live-edge window for sliding_window (default: half "
                         "the graph's undirected edges)")
    ap.add_argument("--batch-size", type=int, default=512)
    ap.add_argument("--max-batches", type=int, default=None,
                    help="stop after this many update batches, counted from "
                         "the stream's start even when resuming (default: "
                         "drain)")
    ap.add_argument("--queries-per-batch", type=int, default=4)
    ap.add_argument("--max-wedge-chunk", type=int, default=None,
                    help="wedge-buffer budget per launch, applied to every "
                         "update batch's probe workload")
    ap.add_argument("--method", default="auto",
                    choices=["auto", "wedge_bsearch", "panel", "pallas",
                             "distributed"],
                    help="kernel backend for the bootstrap count and the "
                         "update probes (auto keeps probes on the wedge "
                         "schedule; panel/pallas route them through the "
                         "panel/Pallas backend; distributed stripes them "
                         "§III-E-style over a mesh of all local devices)")
    ap.add_argument("--no-verify", action="store_true",
                    help="skip the final from-scratch oracle recount")
    ap.add_argument("--report-every", type=int, default=32, metavar="N",
                    help="seal a latency interval every N update batches: "
                         "print rolling-window percentiles and append a "
                         "snapshot to --metrics-out (default: %(default)s)")
    ap.add_argument("--latency-window", type=int, default=8, metavar="K",
                    help="intervals in the rolling percentile window "
                         "(default: %(default)s)")
    ap.add_argument("--metrics-out", default=None, metavar="FILE.jsonl",
                    help="append one JSON latency snapshot per interval "
                         "(plus a final lifetime record)")
    ap.add_argument("--snapshot-dir", default=None, metavar="DIR",
                    help="checkpoint the session state (count, per-node "
                         "incidences, adjacency, stream cursor) into DIR")
    ap.add_argument("--snapshot-every", type=int, default=64, metavar="N",
                    help="snapshot every N applied batches when "
                         "--snapshot-dir is set (default: %(default)s; a "
                         "final snapshot is always written at exit)")
    ap.add_argument("--keep-snapshots", type=int, default=3, metavar="K",
                    help="rolling snapshot retention (default: %(default)s)")
    ap.add_argument("--resume", action="store_true",
                    help="restore the newest valid snapshot from "
                         "--snapshot-dir and resume the stream mid-flight "
                         "(fresh start if none is restorable)")
    ap.add_argument("--json", action="store_true",
                    help="print one machine-readable JSON report on stdout "
                         "(progress lines go to stderr)")
    add_trace_argument(ap)
    args = ap.parse_args()
    if args.window is not None and args.window < 1:
        ap.error("--window must be a positive number of live edges")
    if args.batch_size < 1:
        ap.error("--batch-size must be positive")
    if args.report_every < 1:
        ap.error("--report-every must be positive")
    if args.latency_window < 1:
        ap.error("--latency-window must be positive")
    if args.snapshot_every < 1:
        ap.error("--snapshot-every must be positive")
    if args.keep_snapshots < 1:
        ap.error("--keep-snapshots must be positive")
    if args.resume and args.snapshot_dir is None:
        ap.error("--resume requires --snapshot-dir")

    log = functools.partial(print, file=sys.stderr) if args.json else print
    with obs.trace_to_file(args.trace, meta={"cli": "serve_graph"}):
        _run_serve(args, log)
    if args.trace:
        log(f"trace written to {args.trace}")


def _run_serve(args, log) -> None:
    mesh = None
    if args.method == "distributed":
        import jax

        devs = jax.devices()
        mesh = jax.sharding.Mesh(np.array(devs), ("edges",))
        log(f"mesh: {len(devs)} device(s) striped on axis 'edges'")

    with obs.span("ingest", cat="io"):
        graph, info = resolve_graph(args, log=log)
    # streams consume edge arrays; a cached CSR seed materializes one
    # (the cheap direction — one np.repeat over the memory-mapped CSR)
    edges = graph.edge_array() if hasattr(graph, "edge_array") else graph
    stats = info["graph"]

    if args.stream == "sliding_window":
        window = (args.window if args.window is not None
                  else max(stats["n_edges"] // 2, 1))
        stream = STREAM_GENERATORS[args.stream](
            edges, window=window, batch_size=args.batch_size, seed=args.seed
        )
        log(f"stream: sliding_window(window={window}, batch={args.batch_size})")
    else:
        stream = STREAM_GENERATORS[args.stream](
            edges, batch_size=args.batch_size, seed=args.seed
        )
        log(f"stream: temporal(batch={args.batch_size})")

    store = session = None
    if args.snapshot_dir is not None:
        store = SnapshotStore(args.snapshot_dir, keep=args.keep_snapshots)
        if args.resume:
            hit = store.restore_session(
                "serve_graph",
                max_wedge_chunk=args.max_wedge_chunk,
                method=args.method,
                mesh=mesh,
            )
            if hit is not None:
                session = hit[0]
                log(f"resume: restored snapshot at cursor {session.cursor} "
                    f"({session.counter.n_edges} edges, "
                    f"T = {session.counter.count})")
            else:
                log("resume: no restorable snapshot; starting fresh")

    sink = None
    metrics_file = None
    if args.metrics_out:
        metrics_file = open(args.metrics_out, "a")

        def sink(snap):
            metrics_file.write(json.dumps(snap, sort_keys=True) + "\n")
            metrics_file.flush()

    try:
        counter, rep = drive_stream(
            stream,
            n_nodes=stats["n_nodes"],
            max_batches=args.max_batches,
            queries_per_batch=args.queries_per_batch,
            max_wedge_chunk=args.max_wedge_chunk,
            method=args.method,
            mesh=mesh,
            report_every=args.report_every,
            window_intervals=args.latency_window,
            metrics_sink=sink,
            log=log,
            session=session,
            snapshot_store=store,
            snapshot_every=args.snapshot_every if store is not None else None,
        )
    finally:
        if metrics_file is not None:
            metrics_file.close()
    if counter.last_update_stats is not None:
        log(f"probe backend: {counter.last_update_stats.probe_method}")
    log(f"served {rep['n_batches']} update batches "
        f"(+{rep['n_inserted']}/-{rep['n_deleted']} edges, "
        f"{rep['updates_per_s']:.0f} edge-updates/s) "
        f"and {rep['n_queries']} queries")
    log(f"update latency: p50 {rep['update_p50_ms']:.2f} ms, "
        f"p99 {rep['update_p99_ms']:.2f} ms")
    log(f"query  latency: p50 {rep['query_p50_ms']:.3f} ms, "
        f"p99 {rep['query_p99_ms']:.3f} ms")
    for kind, snap in rep["latency"]["queries"].items():
        log(f"  {kind:13s} n={snap['n']:<6d} p50 {snap['p50_ms']:.3f} ms, "
            f"p90 {snap['p90_ms']:.3f} ms, p99 {snap['p99_ms']:.3f} ms")
    log(f"live graph: {counter.n_edges} edges, T = {counter.count}")
    if store is not None and "resume" in rep:
        log(f"snapshots: {rep['resume']['snapshots_written']} written to "
            f"{args.snapshot_dir} (cursor {rep['resume']['cursor']})")

    verified = None
    if not args.no_verify:
        tc = TriangleCounter(
            method=args.method, max_wedge_chunk=args.max_wedge_chunk, mesh=mesh
        )
        expect = tc.count(counter.current_edges(), n_nodes=counter.n_nodes)
        if counter.count != expect:
            raise SystemExit(
                f"VERIFY FAILED: incremental T={counter.count} != oracle {expect}"
            )
        log(f"verify: from-scratch recount agrees (T = {expect})")
        verified = True

    if args.json:
        out = dict(
            rep,
            triangles=int(counter.count),
            n_edges=int(counter.n_edges),
            probe_method=(counter.last_update_stats.probe_method
                          if counter.last_update_stats is not None else None),
            verified=verified,
            source={k: v for k, v in info.items() if k != "graph"},
            counters=obs.metrics_snapshot()["counters"],
        )
        print(json.dumps(out, indent=None, sort_keys=True))


if __name__ == "__main__":
    main()
