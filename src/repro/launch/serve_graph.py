"""Graph-query service over an edge stream — the ROADMAP serving workload.

Drives :class:`repro.core.IncrementalTriangleCounter` with a request loop
that interleaves update batches (from ``repro.graphs.streams``) with
count / per-node / clustering / transitivity queries, and reports
latency percentiles for both traffic classes::

    python -m repro.launch.serve_graph --generator kronecker --scale 10
    python -m repro.launch.serve_graph --scale 10 --stream sliding_window \\
        --window 20000 --batch-size 512 --queries-per-batch 8
    python -m repro.launch.serve_graph --scale 12 --max-wedge-chunk 1048576
    python -m repro.launch.serve_graph --scale 10 --method pallas   # Pallas probes
    python -m repro.launch.serve_graph --dataset karate --batch-size 16
    python -m repro.launch.serve_graph --input graph.txt.gz --cache-dir ~/.cache/tricsr
    python -m repro.launch.serve_graph --scale 10 --json \\
        --metrics-out /tmp/serve_metrics.jsonl --report-every 16

Latency accounting uses :class:`repro.obs.Pow2Histogram` per query kind
(p50/p90/p99 from 64 power-of-two buckets — O(1) memory on unbounded
streams, unlike the historical keep-every-sample lists), aggregated over
a rolling window of reporting intervals so the periodic lines answer
"p99 over the last N intervals", not "p99 since process start".
``--report-every`` sets the interval (in batches), ``--metrics-out``
appends one JSONL snapshot per interval, ``--json`` prints the final
machine-readable report on stdout, and ``--trace`` exports a
``repro.obs`` trace of the whole run.

Updates run the batched delta-counting path (only triangles touched by
the batch are recounted); queries read the maintained state, so they are
microseconds regardless of graph size.  Unless ``--no-verify`` is given,
the final maintained count is checked against a from-scratch
``TriangleCounter(method="auto")`` recount of the live edge set and the
process exits non-zero on any mismatch — a speedup from a wrong count is
worthless.  Under overload, exact incremental updates can be traded for
DOULION sparsified recounts (``repro.core.approx``); this loop serves
the exact path.
"""
from __future__ import annotations

import argparse
import functools
import json
import sys
import time

import numpy as np

from repro import obs
from repro.core import IncrementalTriangleCounter, TriangleCounter
from repro.graphs import STREAM_GENERATORS
from repro.launch.count import (
    add_source_arguments,
    add_trace_argument,
    resolve_graph,
)
from repro.obs import RollingHistogram

QUERY_KINDS = ("count", "per_node", "clustering", "transitivity")


def _interval_snapshot(kind, interval, n_batches, elapsed_s, update_hist, query_hists):
    """One JSON-ready latency snapshot (``kind`` = "interval" | "final")."""
    return {
        "kind": kind,
        "interval": interval,
        "batches": n_batches,
        "elapsed_s": elapsed_s,
        "update": update_hist.snapshot_ms(),
        "queries": {k: h.snapshot_ms() for k, h in query_hists.items()},
    }


def run_service(
    stream,
    *,
    n_nodes: int,
    max_batches: int | None = None,
    queries_per_batch: int = 4,
    max_wedge_chunk: int | None = None,
    method: str = "auto",
    mesh=None,
    report_every: int | None = None,
    window_intervals: int = 8,
    metrics_sink=None,
    log=None,
):
    """Apply ``stream`` batches interleaved with queries; return a report.

    Latencies land in per-traffic-class pow2 histograms.  Every
    ``report_every`` batches the current interval is sealed: its
    snapshot goes to ``metrics_sink`` (a callable taking one JSON-ready
    dict — the ``--metrics-out`` writer) and ``log`` (if given) prints
    rolling-window percentiles over the last ``window_intervals``
    intervals.  The returned report keeps the historical flat keys
    (``update_p50_ms`` … ``updates_per_s``, now histogram-estimated over
    the whole run) and adds per-query-kind and rolling-window detail
    under ``"latency"``.
    """
    counter = IncrementalTriangleCounter(
        n_nodes=n_nodes, max_wedge_chunk=max_wedge_chunk, method=method, mesh=mesh
    )
    update_hist = RollingHistogram(window_intervals)
    query_hists = {k: RollingHistogram(window_intervals) for k in QUERY_KINDS}
    n_batches = n_inserted = n_deleted = n_queries = 0
    qi = 0
    interval = 0
    t_start = time.perf_counter()

    def seal_interval():
        nonlocal interval
        interval += 1
        sealed_update = update_hist.rotate()
        sealed_queries = {k: h.rotate() for k, h in query_hists.items()}
        if metrics_sink is not None:
            metrics_sink(_interval_snapshot(
                "interval", interval, n_batches,
                time.perf_counter() - t_start, sealed_update, sealed_queries,
            ))
        if log is not None:
            win = update_hist.windowed()
            qwin = {k: h.windowed() for k, h in query_hists.items()}
            qp99 = max((h.percentile(99) for h in qwin.values() if h.n), default=0.0)
            log(f"[interval {interval}] {n_batches} batches; rolling "
                f"update p50 {win.percentile(50)*1e3:.2f} ms / "
                f"p99 {win.percentile(99)*1e3:.2f} ms; "
                f"worst query-kind p99 {qp99*1e3:.3f} ms")

    for batch in stream:
        if max_batches is not None and n_batches >= max_batches:
            break
        t0 = time.perf_counter()
        with obs.span("serve.update", cat="serve",
                      args={"batch": n_batches,
                            "insert": int(batch.insert.shape[0]),
                            "delete": int(batch.delete.shape[0])}):
            counter.apply(insert=batch.insert, delete=batch.delete)
        update_hist.observe(time.perf_counter() - t0)
        n_batches += 1
        n_inserted += batch.insert.shape[0]
        n_deleted += batch.delete.shape[0]
        for _ in range(queries_per_batch):
            kind = QUERY_KINDS[qi % len(QUERY_KINDS)]
            qi += 1
            t0 = time.perf_counter()
            with obs.span("serve.query", cat="serve", args={"kind": kind}):
                if kind == "count":
                    _ = counter.count
                elif kind == "per_node":
                    _ = counter.per_node()
                elif kind == "clustering":
                    _ = counter.clustering()
                else:
                    _ = counter.transitivity()
            query_hists[kind].observe(time.perf_counter() - t0)
            n_queries += 1
        if report_every is not None and n_batches % report_every == 0:
            seal_interval()

    if metrics_sink is not None:
        metrics_sink(_interval_snapshot(
            "final", interval, n_batches, time.perf_counter() - t_start,
            update_hist.lifetime,
            {k: h.lifetime for k, h in query_hists.items()},
        ))

    # whole-run percentiles: merge the per-kind lifetime histograms for
    # the aggregate query figures the historical report shape exposes
    query_all = update_hist.lifetime.__class__()
    for h in query_hists.values():
        query_all.merge(h.lifetime)
    up = update_hist.lifetime
    report = dict(
        n_batches=n_batches,
        n_inserted=n_inserted,
        n_deleted=n_deleted,
        n_queries=n_queries,
        update_p50_ms=up.percentile(50) * 1e3 if up.n else 0.0,
        update_p99_ms=up.percentile(99) * 1e3 if up.n else 0.0,
        query_p50_ms=query_all.percentile(50) * 1e3 if query_all.n else 0.0,
        query_p99_ms=query_all.percentile(99) * 1e3 if query_all.n else 0.0,
        updates_per_s=(n_inserted + n_deleted) / max(up.total_ns / 1e9, 1e-12),
        latency=dict(
            intervals=interval,
            update=up.snapshot_ms(),
            queries={k: h.lifetime.snapshot_ms() for k, h in query_hists.items()},
            window=dict(
                intervals=min(interval + 1, window_intervals),
                update=update_hist.windowed().snapshot_ms(),
                queries={k: h.windowed().snapshot_ms()
                         for k, h in query_hists.items()},
            ),
        ),
    )
    return counter, report


def main() -> None:
    ap = argparse.ArgumentParser()
    add_source_arguments(ap)
    ap.set_defaults(scale=10)  # serving default: smaller than count.py's
    ap.add_argument("--stream", choices=sorted(STREAM_GENERATORS), default="temporal")
    ap.add_argument("--window", type=int, default=None,
                    help="live-edge window for sliding_window (default: half "
                         "the graph's undirected edges)")
    ap.add_argument("--batch-size", type=int, default=512)
    ap.add_argument("--max-batches", type=int, default=None,
                    help="stop after this many update batches (default: drain)")
    ap.add_argument("--queries-per-batch", type=int, default=4)
    ap.add_argument("--max-wedge-chunk", type=int, default=None,
                    help="wedge-buffer budget per launch, applied to every "
                         "update batch's probe workload")
    ap.add_argument("--method", default="auto",
                    choices=["auto", "wedge_bsearch", "panel", "pallas",
                             "distributed"],
                    help="kernel backend for the bootstrap count and the "
                         "update probes (auto keeps probes on the wedge "
                         "schedule; panel/pallas route them through the "
                         "panel/Pallas backend; distributed stripes them "
                         "§III-E-style over a mesh of all local devices)")
    ap.add_argument("--no-verify", action="store_true",
                    help="skip the final from-scratch oracle recount")
    ap.add_argument("--report-every", type=int, default=32, metavar="N",
                    help="seal a latency interval every N update batches: "
                         "print rolling-window percentiles and append a "
                         "snapshot to --metrics-out (default: %(default)s)")
    ap.add_argument("--latency-window", type=int, default=8, metavar="K",
                    help="intervals in the rolling percentile window "
                         "(default: %(default)s)")
    ap.add_argument("--metrics-out", default=None, metavar="FILE.jsonl",
                    help="append one JSON latency snapshot per interval "
                         "(plus a final lifetime record)")
    ap.add_argument("--json", action="store_true",
                    help="print one machine-readable JSON report on stdout "
                         "(progress lines go to stderr)")
    add_trace_argument(ap)
    args = ap.parse_args()
    if args.window is not None and args.window < 1:
        ap.error("--window must be a positive number of live edges")
    if args.batch_size < 1:
        ap.error("--batch-size must be positive")
    if args.report_every < 1:
        ap.error("--report-every must be positive")
    if args.latency_window < 1:
        ap.error("--latency-window must be positive")

    log = functools.partial(print, file=sys.stderr) if args.json else print
    with obs.trace_to_file(args.trace, meta={"cli": "serve_graph"}):
        _run_serve(args, log)
    if args.trace:
        log(f"trace written to {args.trace}")


def _run_serve(args, log) -> None:
    mesh = None
    if args.method == "distributed":
        import jax

        devs = jax.devices()
        mesh = jax.sharding.Mesh(np.array(devs), ("edges",))
        log(f"mesh: {len(devs)} device(s) striped on axis 'edges'")

    with obs.span("ingest", cat="io"):
        graph, info = resolve_graph(args, log=log)
    # streams consume edge arrays; a cached CSR seed materializes one
    # (the cheap direction — one np.repeat over the memory-mapped CSR)
    edges = graph.edge_array() if hasattr(graph, "edge_array") else graph
    stats = info["graph"]

    if args.stream == "sliding_window":
        window = (args.window if args.window is not None
                  else max(stats["n_edges"] // 2, 1))
        stream = STREAM_GENERATORS[args.stream](
            edges, window=window, batch_size=args.batch_size, seed=args.seed
        )
        log(f"stream: sliding_window(window={window}, batch={args.batch_size})")
    else:
        stream = STREAM_GENERATORS[args.stream](
            edges, batch_size=args.batch_size, seed=args.seed
        )
        log(f"stream: temporal(batch={args.batch_size})")

    sink = None
    metrics_file = None
    if args.metrics_out:
        metrics_file = open(args.metrics_out, "a")

        def sink(snap):
            metrics_file.write(json.dumps(snap, sort_keys=True) + "\n")
            metrics_file.flush()

    try:
        counter, rep = run_service(
            stream,
            n_nodes=stats["n_nodes"],
            max_batches=args.max_batches,
            queries_per_batch=args.queries_per_batch,
            max_wedge_chunk=args.max_wedge_chunk,
            method=args.method,
            mesh=mesh,
            report_every=args.report_every,
            window_intervals=args.latency_window,
            metrics_sink=sink,
            log=log,
        )
    finally:
        if metrics_file is not None:
            metrics_file.close()
    if counter.last_update_stats is not None:
        log(f"probe backend: {counter.last_update_stats.probe_method}")
    log(f"served {rep['n_batches']} update batches "
        f"(+{rep['n_inserted']}/-{rep['n_deleted']} edges, "
        f"{rep['updates_per_s']:.0f} edge-updates/s) "
        f"and {rep['n_queries']} queries")
    log(f"update latency: p50 {rep['update_p50_ms']:.2f} ms, "
        f"p99 {rep['update_p99_ms']:.2f} ms")
    log(f"query  latency: p50 {rep['query_p50_ms']:.3f} ms, "
        f"p99 {rep['query_p99_ms']:.3f} ms")
    for kind, snap in rep["latency"]["queries"].items():
        log(f"  {kind:13s} n={snap['n']:<6d} p50 {snap['p50_ms']:.3f} ms, "
            f"p90 {snap['p90_ms']:.3f} ms, p99 {snap['p99_ms']:.3f} ms")
    log(f"live graph: {counter.n_edges} edges, T = {counter.count}")

    verified = None
    if not args.no_verify:
        tc = TriangleCounter(
            method=args.method, max_wedge_chunk=args.max_wedge_chunk, mesh=mesh
        )
        expect = tc.count(counter.current_edges(), n_nodes=counter.n_nodes)
        if counter.count != expect:
            raise SystemExit(
                f"VERIFY FAILED: incremental T={counter.count} != oracle {expect}"
            )
        log(f"verify: from-scratch recount agrees (T = {expect})")
        verified = True

    if args.json:
        out = dict(
            rep,
            triangles=int(counter.count),
            n_edges=int(counter.n_edges),
            probe_method=(counter.last_update_stats.probe_method
                          if counter.last_update_stats is not None else None),
            verified=verified,
            source={k: v for k, v in info.items() if k != "graph"},
            counters=obs.metrics_snapshot()["counters"],
        )
        print(json.dumps(out, indent=None, sort_keys=True))


if __name__ == "__main__":
    main()
