"""Serving launcher: batched prefill + decode loop on local devices.

Demonstrates the full inference path (prefill builds the KV cache; decode
steps extend it) with batched requests and per-phase timing::

    python -m repro.launch.serve --arch qwen2-1.5b --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models import transformer as tfm


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    mod = get_arch(args.arch)
    if mod.FAMILY != "lm":
        raise SystemExit("serve.py drives LM archs; use examples/ for others")
    cfg = mod.smoke_config()
    key = jax.random.PRNGKey(args.seed)
    params = tfm.init_params(key, cfg)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab_size)
    max_len = args.prompt_len + args.gen

    prefill = jax.jit(lambda p, t: tfm.prefill(p, t, cfg))
    decode = jax.jit(
        lambda p, tok, pos, cache: tfm.decode_step(p, tok, pos, cache, cfg),
        donate_argnums=(3,),
    )

    t0 = time.time()
    last_logits, kv = prefill(params, prompts)
    k0, v0 = tfm.init_kv_cache(cfg, args.batch, max_len, dtype=cfg.dtype)
    k0 = jax.lax.dynamic_update_slice(k0, kv[0].astype(k0.dtype), (0, 0, 0, 0, 0))
    v0 = jax.lax.dynamic_update_slice(v0, kv[1].astype(v0.dtype), (0, 0, 0, 0, 0))
    cache = (k0, v0)
    tok = jnp.argmax(last_logits, -1).astype(jnp.int32)
    jax.block_until_ready(tok)
    t_prefill = time.time() - t0

    out = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = decode(params, tok, jnp.int32(args.prompt_len + i), cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    toks = jnp.stack(out, axis=1)
    print(f"prefill: {args.batch}×{args.prompt_len} tokens in {t_prefill*1e3:.1f} ms")
    print(
        f"decode: {args.gen-1} steps × batch {args.batch} in {t_decode*1e3:.1f} ms "
        f"({(args.gen-1)*args.batch/max(t_decode,1e-9):.0f} tok/s)"
    )
    print("sample continuation ids:", toks[0, :16].tolist())


if __name__ == "__main__":
    main()
