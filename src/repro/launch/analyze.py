"""Graph analytics launcher — clustering, transitivity, support, k-truss.

::

    python -m repro.launch.analyze --generator kronecker --scale 10
    python -m repro.launch.analyze --input tests/data/karate.txt --json
    python -m repro.launch.analyze --dataset karate --json --top-k 3
    python -m repro.launch.analyze --scale 12 --max-wedge-chunk 1048576 --no-truss

Shares the graph-source flags (``--input`` / ``--dataset`` /
``--generator`` / ``--cache-dir`` …) with ``count.py`` and
``serve_graph.py`` via :func:`repro.launch.count.add_source_arguments`,
so on-disk graphs go through the same ``.tricsr``-cached out-of-core
ingestion.  The whole report preprocesses the graph exactly once
(:func:`repro.analytics.metrics.graph_report`): count, per-node
clustering, per-edge support and the truss peel all consume one
``OrientedCSR``.

``--json`` prints one machine-readable object on stdout (triangles,
transitivity, clustering profile, support top-k, truss spectrum, engine
stats, per-stage timings); human-readable lines go to stderr.
"""
from __future__ import annotations

import argparse
import functools
import json
import sys
import time

from repro import obs
from repro.analytics import graph_report
from repro.core.engine import METHODS
from repro.launch.count import (
    add_source_arguments,
    add_trace_argument,
    resolve_graph,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    add_source_arguments(ap)
    ap.add_argument("--method", default="auto", choices=list(METHODS[:4]),
                    help="kernel backend for EVERY stage — count, clustering, "
                         "per-edge support, k-truss peel "
                         "(default: auto dispatch)")
    ap.add_argument("--max-wedge-chunk", type=int, default=None,
                    help="wedge-buffer budget per launch (slots); bounds "
                         "every pass — count, clustering, support, truss")
    ap.add_argument("--no-truss", action="store_true",
                    help="skip the k-truss decomposition (the iterative "
                         "peel is the most expensive stage)")
    ap.add_argument("--top-k", type=int, default=5,
                    help="how many top triangle-dense nodes/edges to report "
                         "(default: %(default)s)")
    ap.add_argument("--json", action="store_true",
                    help="print one machine-readable JSON object on stdout "
                         "(progress lines go to stderr)")
    add_trace_argument(ap)
    args = ap.parse_args()
    if args.max_wedge_chunk is not None and args.max_wedge_chunk < 1:
        ap.error("--max-wedge-chunk must be a positive number of wedge slots")
    if args.top_k < 0:
        ap.error("--top-k must be non-negative")

    log = functools.partial(print, file=sys.stderr) if args.json else print
    with obs.trace_to_file(args.trace, meta={"cli": "analyze"}):
        _run_analyze(args, log)
    if args.trace:
        log(f"trace written to {args.trace}")


def _run_analyze(args, log) -> None:
    t0 = time.time()
    with obs.span("ingest", cat="io"):
        graph, info = resolve_graph(args, log=log)
    build_s = time.time() - t0

    report = graph_report(
        graph,
        method=args.method,
        max_wedge_chunk=args.max_wedge_chunk,
        include_truss=not args.no_truss,
        top_k=args.top_k,
    )
    report["source"] = {k: v for k, v in info.items() if k != "graph"}
    report["timings_s"]["build"] = build_s

    expected = info.get("expected_triangles")
    if expected is not None and report["triangles"] != expected:
        raise SystemExit(
            f"ORACLE FAILED: counted {report['triangles']} but "
            f"{info.get('dataset')} has {expected} published triangles"
        )

    es = report["engine"]
    log(f"triangles[{es['method']}] = {report['triangles']}  "
        f"({report['timings_s']['count']*1e3:.1f} ms; {es['n_chunks']} chunk(s), "
        f"peak wedge buffer {es['peak_wedge_buffer']})")
    if es.get("fallback_reason"):
        log(f"note: {es['fallback_reason']}")
    log(f"transitivity = {report['transitivity']:.4f}   "
        f"avg clustering = {report['clustering']['average']:.4f}")
    if report["clustering"]["top_nodes"]:
        tops = ", ".join(f"{d['node']}:{d['triangles']}"
                         for d in report["clustering"]["top_nodes"])
        log(f"top triangle nodes (node:T) = {tops}")
    sup = report["support"]
    log(f"edge support[{sup['method']}]: sum = {sup['sum']} (= 3·T), "
        f"max = {sup['max']}  "
        f"({report['timings_s']['support']*1e3:.1f} ms)")
    if "truss" in report:
        tr = report["truss"]
        spectrum = ", ".join(f"k={k}:{c}" for k, c in sorted(
            tr["spectrum"].items(), key=lambda kv: int(kv[0])))
        log(f"k-truss[{tr['method']}]: max_k = {tr['max_k']} in "
            f"{tr['rounds']} peel round(s); "
            f"trussness spectrum {{{spectrum}}} "
            f"({report['timings_s']['truss']*1e3:.1f} ms)")

    if args.json:
        print(json.dumps(report, indent=None, sort_keys=True))


if __name__ == "__main__":
    main()
