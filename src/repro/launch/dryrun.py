import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede every other import: jax locks the device count on first
# backend initialization.  This module is the ONLY place the 512-device
# fake topology is created; tests and benches see the real (1-CPU) world.

import argparse
import json
import math
import subprocess
import sys
import time


def run_cell(arch: str, shape: str, multi_pod: bool, variant: str = "baseline") -> dict:
    """Lower + compile one (arch × shape × mesh) cell; return the record."""
    import jax  # deferred: after XLA_FLAGS

    from repro.configs import get_arch
    from repro.launch.flops import trace_cost
    from repro.launch.mesh import make_production_mesh
    from repro.launch.roofline import roofline_terms

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = math.prod(mesh.devices.shape)
    spec = get_arch(arch).build_dryrun(shape, mesh, variant=variant)
    t0 = time.time()
    with mesh:
        lowered = spec.lower()
        compiled = lowered.compile()
        walker = trace_cost(spec.step_fn, *spec.args)
    compile_s = time.time() - t0
    mem = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            mem = {
                "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
                "output_bytes": getattr(ma, "output_size_in_bytes", None),
                "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
                "generated_code_bytes": getattr(ma, "generated_code_size_in_bytes", None),
            }
    except Exception as e:  # CPU backend may not implement it
        mem = {"unavailable": str(e)}
    report = roofline_terms(compiled, chips, spec.model_flops, walker_cost=walker)
    rec = {
        "arch": arch,
        "shape": shape,
        "variant": variant,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "multi_pod": multi_pod,
        "chips": chips,
        "description": spec.description,
        "compile_s": round(compile_s, 1),
        "memory_analysis": mem,
        "n_params": spec.n_params,
        "tokens_per_step": spec.tokens_per_step,
        **report.to_dict(),
    }
    return rec


def _fmt(rec: dict) -> str:
    return (
        f"{rec['arch']:22s} {rec['shape']:14s} mesh={rec['mesh']:8s} "
        f"compute={rec['compute_s']:.3e}s memory={rec['memory_s']:.3e}s "
        f"collective={rec['collective_s']:.3e}s bottleneck={rec['bottleneck']:10s} "
        f"roofline_frac={rec['roofline_fraction']:.3f} compile={rec['compile_s']}s"
    )


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run driver")
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--variant", default="baseline",
                    choices=["baseline", "opt", "opt2", "nodeshard"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true", help="run every cell (subprocess-isolated)")
    ap.add_argument("--both-meshes", action="store_true", help="with --all: single+multi pod")
    ap.add_argument("--json", help="append JSONL records here")
    args = ap.parse_args()

    if args.all:
        from repro.configs import ALL_CELLS

        meshes = [False, True] if args.both_meshes else [False]
        failures = []
        for arch, shape in ALL_CELLS:
            for mp in meshes:
                cmd = [
                    sys.executable, "-m", "repro.launch.dryrun",
                    "--arch", arch, "--shape", shape,
                ]
                if mp:
                    cmd.append("--multi-pod")
                if args.json:
                    cmd += ["--json", args.json]
                r = subprocess.run(cmd)
                if r.returncode != 0:
                    failures.append((arch, shape, mp))
        if failures:
            print("FAILED CELLS:", failures)
            sys.exit(1)
        print("ALL CELLS PASSED")
        return

    if not args.arch or not args.shape:
        ap.error("--arch and --shape required (or --all)")
    rec = run_cell(args.arch, args.shape, args.multi_pod, variant=args.variant)
    print(_fmt(rec))
    print("memory_analysis:", rec["memory_analysis"])
    if args.json:
        with open(args.json, "a") as f:
            f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()
