"""Triangle-counting launcher — the paper's Table I as a CLI.

::

    python -m repro.launch.count --generator kronecker --scale 14
    python -m repro.launch.count --generator kronecker --scale 14 --method panel
    python -m repro.launch.count --generator watts_strogatz --n 100000 --k 50
    python -m repro.launch.count --scale 14 --max-wedge-chunk 1048576
    python -m repro.launch.count --scale 12 --distributed   # §III-E striping

    # on-disk graphs: parsed/canonicalized once, .tricsr-cached after
    python -m repro.launch.count --input tests/data/karate.txt --json
    python -m repro.launch.count --input soc-LiveJournal1.txt.gz \\
        --cache-dir ~/.cache/tricsr --max-chunk-edges 4194304
    python -m repro.launch.count --dataset karate --json
    python -m repro.launch.count --dataset com-orkut --download

All counting routes through :class:`repro.core.TriangleCounter` with
``auto`` dispatch as the front door (override with ``--method``);
``--max-wedge-chunk`` bounds the device wedge buffer (memory-bounded edge
partitioning) and ``--max-chunk-edges`` bounds host memory during
parsing/canonicalization.  ``--json`` prints one machine-readable object
on stdout (count, schedule, engine stats, ingest provenance, timings) and
moves the human-readable progress lines to stderr — benchmarks and CI
smokes should consume that instead of scraping text.
"""
from __future__ import annotations

import argparse
import functools
import json
import sys
import time

import numpy as np

from repro import obs
from repro.core import TriangleCounter, count_triangles_numpy
from repro.core.engine import METHODS
from repro.graphs import GRAPH_GENERATORS, graph_stats
from repro.graphs.io import DATASETS, ingest, materialize_dataset


def add_trace_argument(ap: argparse.ArgumentParser) -> None:
    """The shared ``--trace`` flag (count / analyze / serve_graph)."""
    ap.add_argument("--trace", default=None, metavar="OUT",
                    help="export a repro.obs trace of the whole run: "
                         "Chrome trace-event JSON (open in Perfetto / "
                         "chrome://tracing), or a structured JSONL event "
                         "log if OUT ends in .jsonl")


def build_graph(args) -> np.ndarray:
    gen = GRAPH_GENERATORS[args.generator]
    if args.generator == "kronecker":
        return gen(args.scale, edge_factor=args.edge_factor, seed=args.seed)
    if args.generator == "barabasi_albert":
        return gen(args.n, args.m_attach, seed=args.seed)
    if args.generator == "watts_strogatz":
        return gen(args.n, args.k, args.beta, seed=args.seed)
    return gen(args.n, args.m, seed=args.seed)


def add_source_arguments(ap: argparse.ArgumentParser) -> None:
    """Graph-source flags shared by count.py and serve_graph.py."""
    ap.add_argument("--input", default=None, metavar="FILE",
                    help="on-disk edge list (SNAP text / MatrixMarket, "
                         "optionally .gz) ingested via the out-of-core path")
    ap.add_argument("--dataset", default=None, choices=sorted(DATASETS),
                    help="named dataset from the registry (paper Table I "
                         "graphs); offline falls back to a deterministic "
                         "generator of matching scale")
    ap.add_argument("--cache-dir", default=".tricsr-cache",
                    help="directory for .tricsr binary CSR caches and "
                         "downloaded/generated dataset sources "
                         "(default: %(default)s)")
    ap.add_argument("--max-chunk-edges", type=int, default=None,
                    help="host-memory bound for parsing/canonicalization, "
                         "in raw edges per chunk (default: 4M)")
    ap.add_argument("--storage", default="flat", choices=("flat", "compressed"),
                    help="cache format: flat .tricsr mmap, or compressed "
                         ".tricsrz delta/varint neighbor blocks decoded "
                         "chunk-wise into the engine (default: %(default)s)")
    ap.add_argument("--order", default=None, choices=("natural", "degree", "bfs"),
                    help="node relabeling baked into a compressed cache for "
                         "reference locality (default: degree when "
                         "--storage compressed; requires --storage compressed)")
    ap.add_argument("--download", action="store_true",
                    help="allow fetching --dataset sources from the network "
                         "(also enabled by REPRO_ALLOW_DOWNLOAD=1)")
    ap.add_argument("--fallback-scale", type=int, default=None,
                    help="shrink a dataset's Kronecker fallback to this "
                         "scale (offline CI sizing)")
    ap.add_argument("--generator", choices=sorted(GRAPH_GENERATORS), default="kronecker")
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--edge-factor", type=int, default=16)
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--m", type=int, default=1_000_000)
    ap.add_argument("--m-attach", type=int, default=8)
    ap.add_argument("--k", type=int, default=50)
    ap.add_argument("--beta", type=float, default=0.1)
    ap.add_argument("--seed", type=int, default=0)


def resolve_graph(args, log=print):
    """Resolve the CLI's graph source to ``(graph, source_info)``.

    ``graph`` is a canonical edge array (generators) or a cached/ingested
    ``CSRGraph`` (``--input`` / ``--dataset``) — both are accepted
    directly by :class:`repro.core.TriangleCounter`.  ``source_info`` is a
    JSON-ready provenance dict (ingest stats, cache hit, expected count).
    """
    if args.input is not None and args.dataset is not None:
        raise SystemExit("--input and --dataset are mutually exclusive")
    storage = getattr(args, "storage", "flat")
    order = getattr(args, "order", None)
    if order is not None and storage != "compressed":
        raise SystemExit("--order requires --storage compressed (the flat "
                         ".tricsr cannot record the inverse permutation)")
    if order is None:
        order = "degree" if storage == "compressed" else "natural"
    if storage != "flat" and args.input is None and args.dataset is None:
        raise SystemExit("--storage/--order shape the on-disk cache and "
                         "need an --input or --dataset source (generators "
                         "never touch the cache)")
    kwargs = {}
    if storage != "flat":
        kwargs["storage"] = storage
        kwargs["order"] = order
    if args.max_chunk_edges is not None:
        if args.max_chunk_edges < 1:
            raise SystemExit("--max-chunk-edges must be positive")
        kwargs["max_chunk_edges"] = args.max_chunk_edges
    t0 = time.time()
    if args.input is not None:
        try:
            csr, stats = ingest(args.input, cache_dir=args.cache_dir, **kwargs)
        except (FileNotFoundError, ValueError) as e:
            # missing file, unknown format, malformed line, corrupt cache —
            # all user-input problems, all exit cleanly
            raise SystemExit(f"--input: {e}") from None
        info = dict(source="input", ingest=stats.as_dict(), expected_triangles=None)
    elif args.dataset is not None:
        try:
            csr, stats, ds = materialize_dataset(
                args.dataset, args.cache_dir,
                allow_download=True if args.download else None,
                fallback_scale=args.fallback_scale, **kwargs,
            )
        except (ValueError, RuntimeError, OSError) as e:
            # registry misuse, checksum mismatch, network failure — all
            # actionable user-facing conditions, all exit cleanly
            raise SystemExit(f"--dataset: {e}") from None
        # fallback graphs have their own counts; only the real download
        # (or the exact built-in karate graph) honors the published oracle
        real = stats.source_kind == "download" or ds.name == "karate"
        info = dict(
            source="dataset", dataset=ds.name, ingest=stats.as_dict(),
            expected_triangles=ds.triangles if real else None,
        )
    else:
        edges = build_graph(args)
        info = dict(source="generator", generator=args.generator,
                    ingest=None, expected_triangles=None)
        st = graph_stats(edges)
        log(f"graph: {st['n_nodes']} nodes, {st['n_edges']} edges, "
            f"max deg {st['max_degree']}, skew {st['skew']:.1f} "
            f"(built in {time.time()-t0:.2f}s)")
        info["graph"] = st
        return edges, info
    st = csr.stats()
    hit = "cache hit" if stats.cache_hit else (
        f"parsed {stats.raw_edges} raw edges, {stats.spill_runs} spill run(s)")
    log(f"graph: {st['n_nodes']} nodes, {st['n_edges']} edges, "
        f"max deg {st['max_degree']}, skew {st['skew']:.1f} "
        f"({hit}, ready in {time.time()-t0:.2f}s)")
    info["graph"] = st
    return csr, info


def main() -> None:
    ap = argparse.ArgumentParser()
    add_source_arguments(ap)
    ap.add_argument("--method", default=None, choices=list(METHODS),
                    help="counting schedule (default: auto dispatch)")
    ap.add_argument("--max-wedge-chunk", type=int, default=None,
                    help="wedge-buffer budget per launch (slots); enables "
                         "memory-bounded edge partitioning")
    ap.add_argument("--tile-cache", default=None, metavar="FILE",
                    help="versioned tile-autotune cache (JSON) steering the "
                         "pallas kernels' (block_edges, TLv) tiles")
    ap.add_argument("--autotune", action="store_true",
                    help="grid-search tiles for shapes missing from "
                         "--tile-cache (paper §III-D5 sweep) and persist "
                         "the winners")
    ap.add_argument("--baseline", action="store_true", help="also run NumPy CPU baseline")
    ap.add_argument("--distributed", action="store_true", help="shard over local devices")
    ap.add_argument("--clustering", action="store_true",
                    help="deprecated spelling of --transitivity")
    ap.add_argument("--transitivity", action="store_true",
                    help="also report the transitivity ratio (derived from "
                         "the count and wedge total already in hand — free)")
    ap.add_argument("--clustering-summary", action="store_true",
                    help="also report average clustering + the degree-binned "
                         "clustering profile (one extra per-node pass over "
                         "the same CSR; no second ingest/preprocess)")
    ap.add_argument("--json", action="store_true",
                    help="print one machine-readable JSON object on stdout "
                         "(progress lines go to stderr)")
    add_trace_argument(ap)
    args = ap.parse_args()
    if args.max_wedge_chunk is not None and args.max_wedge_chunk < 1:
        ap.error("--max-wedge-chunk must be a positive number of wedge slots")
    if args.distributed:
        if args.method not in (None, "auto", "distributed"):
            ap.error(f"--distributed conflicts with --method {args.method}; "
                     "drop one of the two (--distributed runs the §III-E "
                     "striped schedule over all local devices)")
        args.method = "distributed"
    elif args.method is None:
        args.method = "auto"

    log = functools.partial(print, file=sys.stderr) if args.json else print
    with obs.trace_to_file(args.trace, meta={"cli": "count"}):
        _run_count(args, log)
    if args.trace:
        log(f"trace written to {args.trace}")


def _run_count(args, log) -> None:
    t_build0 = time.time()
    with obs.span("ingest", cat="io"):
        graph, info = resolve_graph(args, log=log)
    build_s = time.time() - t_build0

    mesh = None
    if args.method == "distributed":
        from repro.launch.mesh import make_local_mesh

        mesh = make_local_mesh()
    tuner = None
    if args.tile_cache is not None or args.autotune:
        from repro.core.tuning import AutoTuner

        tuner = AutoTuner(args.tile_cache, tune_on_miss=args.autotune)
    tc = TriangleCounter(method=args.method, max_wedge_chunk=args.max_wedge_chunk,
                         mesh=mesh, tuner=tuner)
    count_input = graph
    if args.clustering_summary:
        # normalize to an OrientedCSR once up front so the count and the
        # extra per-node pass share it — no second ingest/preprocess
        # (`graph` itself stays untouched: the --baseline path needs the
        # raw edge array / CSRGraph, not the oriented NamedTuple)
        from repro.core import prepare_oriented

        csr = prepare_oriented(graph)
        if csr is not None:
            count_input = csr
    t0 = time.time()
    t = tc.count(count_input)
    dt = time.time() - t0
    es = tc.last_stats
    log(f"triangles[{es.method}] = {t}  ({dt*1e3:.1f} ms; "
        f"{es.n_chunks} chunk(s), peak wedge buffer {es.peak_wedge_buffer})")
    if es.fallback_reason:
        log(f"note: {es.fallback_reason}")
    if tuner is not None:
        log(f"tile cache: {tuner.n_hits} hit(s), {tuner.n_tuned} shape(s) tuned")

    expected = info.get("expected_triangles")
    if expected is not None and t != expected:
        raise SystemExit(
            f"ORACLE FAILED: counted {t} but {info.get('dataset')} has "
            f"{expected} published triangles"
        )

    baseline_s = None
    if args.baseline:
        edges = graph.edge_array() if hasattr(graph, "edge_array") else graph
        t0 = time.time()
        tb = count_triangles_numpy(edges)
        baseline_s = time.time() - t0
        log(f"triangles[numpy-cpu] = {tb}  ({baseline_s*1e3:.1f} ms, "
            f"speedup {baseline_s/max(dt,1e-9):.2f}×)")
        assert tb == t

    trans = None
    if args.clustering or args.transitivity or args.clustering_summary:
        # derive from the count and wedge total already in hand — no recount
        wedges = info["graph"]["total_wedges"]
        trans = 3.0 * t / wedges if wedges else 0.0
        log(f"transitivity = {trans:.4f}")

    clustering_summary = None
    if args.clustering_summary:
        from repro.analytics.metrics import (
            clustering_from_counts,
            profile_from_counts,
        )
        from repro.core import degree_histogram

        t0 = time.time()
        deg, _ = degree_histogram(count_input)
        tri = tc.per_node(count_input)  # same CSR as the count — one extra pass
        cc = clustering_from_counts(tri, deg)
        cluster_s = time.time() - t0
        clustering_summary = dict(
            average=float(cc.mean()) if cc.size else 0.0,
            profile=profile_from_counts(tri, deg),
        )
        log(f"avg clustering = {clustering_summary['average']:.4f} "
            f"({cluster_s*1e3:.1f} ms)")

    if args.json:
        out = dict(
            triangles=t,
            method=es.method,
            resolved_method=es.resolved_method,
            stats=dict(
                n_chunks=es.n_chunks,
                peak_wedge_buffer=es.peak_wedge_buffer,
                wedge_budget=es.wedge_budget,
                total_wedges=es.total_wedges,
                n_directed_edges=es.n_directed_edges,
                fallback_reason=es.fallback_reason,
                timings=es.timings,
            ),
            counters=obs.metrics_snapshot()["counters"],
            graph=info.get("graph"),
            source={k: v for k, v in info.items() if k != "graph"},
            timings_s=dict(build=build_s, count=dt, baseline=baseline_s),
            transitivity=trans,
            clustering=clustering_summary,
        )
        print(json.dumps(out, indent=None, sort_keys=True))


if __name__ == "__main__":
    main()
