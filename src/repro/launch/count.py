"""Triangle-counting launcher — the paper's Table I as a CLI.

::

    python -m repro.launch.count --generator kronecker --scale 14
    python -m repro.launch.count --generator watts_strogatz --n 100000 --k 50
    python -m repro.launch.count --generator barabasi_albert --n 20000 --baseline
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import (
    count_triangles,
    count_triangles_distributed,
    count_triangles_numpy,
    transitivity,
)
from repro.graphs import GRAPH_GENERATORS


def build_graph(args) -> np.ndarray:
    gen = GRAPH_GENERATORS[args.generator]
    if args.generator == "kronecker":
        return gen(args.scale, edge_factor=args.edge_factor, seed=args.seed)
    if args.generator == "barabasi_albert":
        return gen(args.n, args.m_attach, seed=args.seed)
    if args.generator == "watts_strogatz":
        return gen(args.n, args.k, args.beta, seed=args.seed)
    return gen(args.n, args.m, seed=args.seed)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--generator", choices=sorted(GRAPH_GENERATORS), default="kronecker")
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--edge-factor", type=int, default=16)
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--m", type=int, default=1_000_000)
    ap.add_argument("--m-attach", type=int, default=8)
    ap.add_argument("--k", type=int, default=50)
    ap.add_argument("--beta", type=float, default=0.1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--method", default="wedge_bsearch",
                    choices=["wedge_bsearch", "panel", "pallas"])
    ap.add_argument("--baseline", action="store_true", help="also run NumPy CPU baseline")
    ap.add_argument("--distributed", action="store_true", help="shard over local devices")
    ap.add_argument("--clustering", action="store_true")
    args = ap.parse_args()

    t0 = time.time()
    edges = build_graph(args)
    print(f"graph: {int(edges.max())+1} nodes, {edges.shape[0]//2} edges "
          f"(built in {time.time()-t0:.2f}s)")

    t0 = time.time()
    t = count_triangles(edges, method=args.method)
    dt = time.time() - t0
    print(f"triangles[{args.method}] = {t}  ({dt*1e3:.1f} ms)")

    if args.distributed:
        import jax
        from repro.launch.mesh import make_local_mesh

        mesh = make_local_mesh()
        t0 = time.time()
        td = count_triangles_distributed(edges, mesh)
        print(f"triangles[distributed x{len(jax.devices())}] = {td} "
              f"({(time.time()-t0)*1e3:.1f} ms)")
        assert td == t

    if args.baseline:
        t0 = time.time()
        tb = count_triangles_numpy(edges)
        dtb = time.time() - t0
        print(f"triangles[numpy-cpu] = {tb}  ({dtb*1e3:.1f} ms, "
              f"speedup {dtb/max(dt,1e-9):.2f}×)")
        assert tb == t

    if args.clustering:
        print(f"transitivity = {transitivity(edges):.4f}")


if __name__ == "__main__":
    main()
