"""Triangle-counting launcher — the paper's Table I as a CLI.

::

    python -m repro.launch.count --generator kronecker --scale 14
    python -m repro.launch.count --generator kronecker --scale 14 --method panel
    python -m repro.launch.count --generator watts_strogatz --n 100000 --k 50
    python -m repro.launch.count --generator barabasi_albert --n 20000 --baseline
    python -m repro.launch.count --scale 14 --max-wedge-chunk 1048576
    python -m repro.launch.count --scale 12 --distributed   # §III-E striping

All counting routes through :class:`repro.core.TriangleCounter` with
``auto`` dispatch as the front door (override with ``--method``);
``--max-wedge-chunk`` bounds the device wedge buffer (memory-bounded edge
partitioning) and the chunk/launch stats are printed after each run.
``--distributed`` routes the count through the striped multi-device
schedule and refuses to combine with a conflicting explicit ``--method``.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import TriangleCounter, count_triangles_numpy
from repro.core.engine import METHODS
from repro.graphs import GRAPH_GENERATORS, graph_stats


def build_graph(args) -> np.ndarray:
    gen = GRAPH_GENERATORS[args.generator]
    if args.generator == "kronecker":
        return gen(args.scale, edge_factor=args.edge_factor, seed=args.seed)
    if args.generator == "barabasi_albert":
        return gen(args.n, args.m_attach, seed=args.seed)
    if args.generator == "watts_strogatz":
        return gen(args.n, args.k, args.beta, seed=args.seed)
    return gen(args.n, args.m, seed=args.seed)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--generator", choices=sorted(GRAPH_GENERATORS), default="kronecker")
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--edge-factor", type=int, default=16)
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--m", type=int, default=1_000_000)
    ap.add_argument("--m-attach", type=int, default=8)
    ap.add_argument("--k", type=int, default=50)
    ap.add_argument("--beta", type=float, default=0.1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--method", default=None, choices=list(METHODS),
                    help="counting schedule (default: auto dispatch)")
    ap.add_argument("--max-wedge-chunk", type=int, default=None,
                    help="wedge-buffer budget per launch (slots); enables "
                         "memory-bounded edge partitioning")
    ap.add_argument("--baseline", action="store_true", help="also run NumPy CPU baseline")
    ap.add_argument("--distributed", action="store_true", help="shard over local devices")
    ap.add_argument("--clustering", action="store_true")
    args = ap.parse_args()
    if args.max_wedge_chunk is not None and args.max_wedge_chunk < 1:
        ap.error("--max-wedge-chunk must be a positive number of wedge slots")
    if args.distributed:
        if args.method not in (None, "auto", "distributed"):
            ap.error(f"--distributed conflicts with --method {args.method}; "
                     "drop one of the two (--distributed runs the §III-E "
                     "striped schedule over all local devices)")
        args.method = "distributed"
    elif args.method is None:
        args.method = "auto"

    t0 = time.time()
    edges = build_graph(args)
    stats = graph_stats(edges)
    print(f"graph: {stats['n_nodes']} nodes, {stats['n_edges']} edges, "
          f"max deg {stats['max_degree']}, skew {stats['skew']:.1f} "
          f"(built in {time.time()-t0:.2f}s)")

    mesh = None
    if args.method == "distributed":
        from repro.launch.mesh import make_local_mesh

        mesh = make_local_mesh()
    tc = TriangleCounter(method=args.method, max_wedge_chunk=args.max_wedge_chunk,
                         mesh=mesh)
    t0 = time.time()
    t = tc.count(edges)
    dt = time.time() - t0
    es = tc.last_stats
    print(f"triangles[{es.method}] = {t}  ({dt*1e3:.1f} ms; "
          f"{es.n_chunks} chunk(s), peak wedge buffer {es.peak_wedge_buffer})")

    if args.baseline:
        t0 = time.time()
        tb = count_triangles_numpy(edges)
        dtb = time.time() - t0
        print(f"triangles[numpy-cpu] = {tb}  ({dtb*1e3:.1f} ms, "
              f"speedup {dtb/max(dt,1e-9):.2f}×)")
        assert tb == t

    if args.clustering:
        # derive from the count and wedge total already in hand — no recount
        trans = 3.0 * t / stats["total_wedges"] if stats["total_wedges"] else 0.0
        print(f"transitivity = {trans:.4f}")


if __name__ == "__main__":
    main()
