"""Training launcher (runs for real on local devices).

Full-scale configs are exercised via the dry-run; this launcher trains the
same code paths at whatever size fits the machine — smoke configs by
default — with the full fault-tolerance stack live: checkpoint/resume,
async saves, straggler monitoring, deterministic resumable data.

Examples::

    python -m repro.launch.train --arch qwen2-1.5b --smoke --steps 20
    python -m repro.launch.train --arch gcn-cora --smoke --steps 30
    python -m repro.launch.train --arch din --smoke --steps 10 --ckpt /tmp/din_ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_arch
from repro.data import TokenPipeline, din_batch, graph_node_features
from repro.distributed import StragglerMonitor
from repro.graphs import kronecker_rmat, edge_array_to_csr
from repro.optim import adamw, apply_updates, constant, cosine_with_warmup


def _train_lm(mod, args):
    from repro.configs.lm_common import make_lm_train_step
    from repro.models import transformer as tfm

    cfg = mod.smoke_config() if args.smoke else mod.full_config()
    params = tfm.init_params(jax.random.PRNGKey(args.seed), cfg)
    lr = constant(1e-3) if args.smoke else cosine_with_warmup(3e-4, 2000, args.steps)
    step_fn, opt_init = make_lm_train_step(cfg, accum=1, lr=lr)
    opt_state = opt_init(params)
    pipe = TokenPipeline(args.batch, args.seq, cfg.vocab_size, seed=args.seed)
    mgr = CheckpointManager(args.ckpt, keep=3) if args.ckpt else None
    start = 0
    if mgr is not None:
        restored = mgr.restore_latest({"params": params, "opt": opt_state})
        if restored is not None:
            tree, start, extra = restored
            params, opt_state = tree["params"], tree["opt"]
            pipe = TokenPipeline.from_state(
                args.batch, args.seq, cfg.vocab_size, extra["data_state"]
            )
            print(f"resumed from step {start}")
    jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
    mon = StragglerMonitor()
    for step in range(start, args.steps):
        batch = next(pipe)
        batch = {k: jnp.asarray(v)[None] for k, v in batch.items()}  # accum dim
        mon.start_step()
        params, opt_state, metrics = jit_step(params, opt_state, batch)
        straggled = mon.end_step()
        if step % args.log_every == 0 or step == args.steps - 1:
            print(
                f"step {step} loss {float(metrics['loss']):.4f} "
                f"gnorm {float(metrics['gnorm']):.3f}"
                + (" [straggler]" if straggled else "")
            )
        if mgr is not None and (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, {"params": params, "opt": opt_state},
                     {"data_state": pipe.state()})
    if mgr is not None:
        mgr.save(args.steps, {"params": params, "opt": opt_state},
                 {"data_state": pipe.state()})
        mgr.wait()
    return float(metrics["loss"])


def _train_gnn(mod, args):
    cfg = mod.smoke_config()
    model = mod.MODEL
    edges = kronecker_rmat(max(8, args.scale), edge_factor=8, seed=args.seed)
    n = int(edges.max()) + 1
    feat, labels = graph_node_features(args.seed, n, cfg.d_in, cfg.d_out)
    pos = np.random.default_rng(args.seed).normal(size=(n, 3)).astype(np.float32)
    params = model.init_params(jax.random.PRNGKey(args.seed), cfg)
    opt_init, opt_update = adamw(constant(1e-2), weight_decay=0.0)
    opt_state = opt_init(params)

    @jax.jit
    def step_fn(params, opt_state, feat, pos, src, dst, labels):
        def loss(p):
            out = model.apply(p, cfg, feat, pos, src, dst)
            logp = jax.nn.log_softmax(out.astype(jnp.float32), axis=-1)
            return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))

        l, grads = jax.value_and_grad(loss)(params)
        updates, opt_state, _ = opt_update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state, l

    src = jnp.asarray(edges[:, 0])
    dst = jnp.asarray(edges[:, 1])
    feat, pos, labels = jnp.asarray(feat), jnp.asarray(pos), jnp.asarray(labels)
    for step in range(args.steps):
        params, opt_state, l = step_fn(params, opt_state, feat, pos, src, dst, labels)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step} loss {float(l):.4f}")
    return float(l)


def _train_din(mod, args):
    from repro.models.recsys import din as din_model

    cfg = mod.smoke_config()
    params = din_model.init_params(jax.random.PRNGKey(args.seed), cfg)
    opt_init, opt_update = adamw(constant(1e-3), weight_decay=0.0)
    opt_state = opt_init(params)

    @jax.jit
    def step_fn(params, opt_state, batch):
        l, grads = jax.value_and_grad(din_model.loss_fn)(params, cfg, batch)
        updates, opt_state, _ = opt_update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state, l

    for step in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in din_batch(
            args.seed, step, args.batch, cfg.seq_len, cfg.n_items, cfg.n_cates
        ).items()}
        params, opt_state, l = step_fn(params, opt_state, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step} loss {float(l):.4f}")
    return float(l)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--scale", type=int, default=9, help="graph scale for GNN archs")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args()
    mod = get_arch(args.arch)
    t0 = time.time()
    if mod.FAMILY == "lm":
        loss = _train_lm(mod, args)
    elif mod.FAMILY == "gnn":
        loss = _train_gnn(mod, args)
    elif mod.FAMILY == "recsys":
        loss = _train_din(mod, args)
    else:
        raise SystemExit(f"arch {args.arch} is not trainable (family {mod.FAMILY})")
    print(f"done: final loss {loss:.4f} in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
