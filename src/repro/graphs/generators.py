"""Synthetic graph generators (the paper's evaluation suite, §IV).

All generators return a canonical edge array (see
:mod:`repro.graphs.formats`): ``(m, 2)`` int32, symmetric, deduplicated, no
self loops.  Everything is deterministic given ``seed``.

The paper evaluates on Kronecker (R-MAT) graphs of scale 16–21,
a Barabási–Albert network and a Watts–Strogatz network; we reproduce all
three families plus Erdős–Rényi as a low-skew control.
"""
from __future__ import annotations

import numpy as np

from .formats import canonicalize_edges

__all__ = [
    "kronecker_rmat",
    "barabasi_albert",
    "watts_strogatz",
    "erdos_renyi",
    "GRAPH_GENERATORS",
]


def kronecker_rmat(
    scale: int,
    edge_factor: int = 16,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    seed: int = 0,
) -> np.ndarray:
    """R-MAT / stochastic Kronecker generator (Graph500 parameters).

    ``n = 2**scale`` vertices, ``edge_factor * n`` sampled edge slots before
    dedup.  Matches the DIMACS-10 Kronecker family used in the paper.
    """
    rng = np.random.default_rng(seed)
    n_edges = edge_factor << scale
    src = np.zeros(n_edges, dtype=np.int64)
    dst = np.zeros(n_edges, dtype=np.int64)
    ab = a + b
    c_norm = c / (1.0 - ab)
    a_norm = a / ab
    for bit in range(scale):
        r1 = rng.random(n_edges)
        r2 = rng.random(n_edges)
        src_bit = r1 > ab
        dst_bit = r2 > np.where(src_bit, c_norm, a_norm)
        src |= src_bit.astype(np.int64) << bit
        dst |= dst_bit.astype(np.int64) << bit
    # Permute vertex labels so degree does not correlate with id.
    perm = rng.permutation(1 << scale)
    return canonicalize_edges(np.stack([perm[src], perm[dst]], axis=1))


def barabasi_albert(n: int, m_attach: int = 8, seed: int = 0) -> np.ndarray:
    """Barabási–Albert preferential attachment.

    Uses the repeated-endpoint-list trick: sampling uniformly from the
    flat list of all edge endpoints is sampling proportional to degree.
    """
    rng = np.random.default_rng(seed)
    if n <= m_attach:
        raise ValueError("need n > m_attach")
    # Seed clique over the first m_attach+1 vertices.
    seed_nodes = np.arange(m_attach + 1)
    src0, dst0 = np.meshgrid(seed_nodes, seed_nodes)
    mask = src0 < dst0
    edges = [np.stack([src0[mask], dst0[mask]], axis=1)]
    endpoints = list(np.concatenate([src0[mask], dst0[mask]]))
    targets_flat = np.array(endpoints, dtype=np.int64)
    # Grow in chunks: amortize the endpoint-list rebuild.
    buf = [targets_flat]
    flat = targets_flat
    for v in range(m_attach + 1, n):
        # sample m_attach distinct targets preferentially
        picks = flat[rng.integers(0, flat.shape[0], size=4 * m_attach)]
        picks = np.unique(picks)[:m_attach]
        while picks.shape[0] < m_attach:  # pragma: no cover - rare fallback
            extra = flat[rng.integers(0, flat.shape[0], size=4 * m_attach)]
            picks = np.unique(np.concatenate([picks, extra]))[:m_attach]
        e = np.stack([np.full(m_attach, v, dtype=np.int64), picks], axis=1)
        edges.append(e)
        buf.append(np.concatenate([e[:, 0], e[:, 1]]))
        if len(buf) >= 64:
            flat = np.concatenate(buf)
            buf = [flat]
        else:
            flat = np.concatenate([flat, buf[-1]])
    return canonicalize_edges(np.concatenate(edges, axis=0))


def watts_strogatz(n: int, k: int = 50, beta: float = 0.1, seed: int = 0) -> np.ndarray:
    """Watts–Strogatz small-world graph: ring lattice + random rewiring."""
    rng = np.random.default_rng(seed)
    if k % 2 != 0:
        raise ValueError("k must be even")
    base = np.arange(n, dtype=np.int64)
    src = np.repeat(base, k // 2)
    offs = np.tile(np.arange(1, k // 2 + 1, dtype=np.int64), n)
    dst = (src + offs) % n
    rewire = rng.random(src.shape[0]) < beta
    dst = np.where(rewire, rng.integers(0, n, size=src.shape[0]), dst)
    return canonicalize_edges(np.stack([src, dst], axis=1))


def erdos_renyi(n: int, m: int, seed: int = 0) -> np.ndarray:
    """G(n, m)-style random graph (sampled with replacement then deduped)."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, size=int(1.1 * m) + 16)
    dst = rng.integers(0, n, size=src.shape[0])
    return canonicalize_edges(np.stack([src, dst], axis=1))


GRAPH_GENERATORS = {
    "kronecker": kronecker_rmat,
    "barabasi_albert": barabasi_albert,
    "watts_strogatz": watts_strogatz,
    "erdos_renyi": erdos_renyi,
}
