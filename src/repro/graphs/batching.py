"""Batched small-graph collation (the GNN ``molecule`` shape).

Graphs are padded to a fixed ``(max_nodes, max_edges)`` and stacked; a
``graph_id`` segment vector drives per-graph readout via ``segment_sum``.
Edges of padded slots point at a sink node with zero features.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import numpy as np

__all__ = ["GraphBatch", "collate_graphs", "random_molecule_batch"]


class GraphBatch(NamedTuple):
    """A batch of B graphs padded to fixed size.

    node_feat:  (B, max_nodes, d)   float32
    positions:  (B, max_nodes, 3)   float32 (for geometric models)
    edge_src:   (B, max_edges)      int32, −1 padded
    edge_dst:   (B, max_edges)      int32, −1 padded
    node_mask:  (B, max_nodes)      bool
    edge_mask:  (B, max_edges)      bool
    """

    node_feat: np.ndarray
    positions: np.ndarray
    edge_src: np.ndarray
    edge_dst: np.ndarray
    node_mask: np.ndarray
    edge_mask: np.ndarray


def collate_graphs(
    graphs: Sequence[dict], max_nodes: int, max_edges: int, d_feat: int
) -> GraphBatch:
    b = len(graphs)
    node_feat = np.zeros((b, max_nodes, d_feat), np.float32)
    positions = np.zeros((b, max_nodes, 3), np.float32)
    edge_src = np.full((b, max_edges), -1, np.int32)
    edge_dst = np.full((b, max_edges), -1, np.int32)
    node_mask = np.zeros((b, max_nodes), bool)
    edge_mask = np.zeros((b, max_edges), bool)
    for i, g in enumerate(graphs):
        n = g["node_feat"].shape[0]
        e = g["edges"].shape[0]
        if n > max_nodes or e > max_edges:
            raise ValueError(f"graph {i} exceeds padding budget ({n},{e})")
        node_feat[i, :n] = g["node_feat"]
        if "positions" in g:
            positions[i, :n] = g["positions"]
        edge_src[i, :e] = g["edges"][:, 0]
        edge_dst[i, :e] = g["edges"][:, 1]
        node_mask[i, :n] = True
        edge_mask[i, :e] = True
    return GraphBatch(node_feat, positions, edge_src, edge_dst, node_mask, edge_mask)


def random_molecule_batch(
    batch: int, n_nodes: int, n_edges: int, d_feat: int, seed: int = 0
) -> GraphBatch:
    """Deterministic synthetic molecule-like batch (radius-graph style)."""
    rng = np.random.default_rng(seed)
    graphs = []
    for i in range(batch):
        pos = rng.normal(size=(n_nodes, 3)).astype(np.float32)
        # connect nearest neighbors until n_edges directed edges exist
        d2 = ((pos[:, None] - pos[None, :]) ** 2).sum(-1)
        np.fill_diagonal(d2, np.inf)
        k = max(1, n_edges // n_nodes)
        nbrs = np.argsort(d2, axis=1)[:, :k]
        src = np.repeat(np.arange(n_nodes), k)
        dst = nbrs.reshape(-1)
        edges = np.stack([src, dst], 1)[:n_edges]
        feat = rng.normal(size=(n_nodes, d_feat)).astype(np.float32)
        graphs.append({"node_feat": feat, "positions": pos, "edges": edges})
    return collate_graphs(graphs, n_nodes, n_edges, d_feat)
