"""Edge streams: reproducible insert/delete batches for dynamic counting.

The serving workload (ROADMAP north star) sees graphs that *change*:
edges arrive with timestamps, old edges expire.  These generators turn
any static canonical edge array into a deterministic stream of
:class:`StreamBatch` updates for
:class:`repro.core.incremental.IncrementalTriangleCounter`:

``temporal_edge_stream``
    Replay the graph as an arrival process — undirected edges shuffled
    into a seeded "timestamp" order, yielded as insert-only batches.
``sliding_window_stream``
    The same arrival order, but only the most recent ``window`` edges
    stay live: each batch pairs the arrivals with the evictions that
    fall out of the window, exercising insert *and* delete paths.

Everything is deterministic given ``seed`` — a stream can be replayed
bit-for-bit for the from-scratch oracle comparison in the tests.
"""
from __future__ import annotations

from typing import Iterator, NamedTuple

import numpy as np

__all__ = [
    "StreamBatch",
    "undirected_pairs",
    "temporal_edge_stream",
    "sliding_window_stream",
    "STREAM_GENERATORS",
]

_EMPTY = np.empty((0, 2), np.int64)


class StreamBatch(NamedTuple):
    """One update batch: arrivals then evictions (applied in that order)."""

    insert: np.ndarray  # (b_i, 2) undirected pairs
    delete: np.ndarray  # (b_d, 2) undirected pairs

    @property
    def size(self) -> int:
        return self.insert.shape[0] + self.delete.shape[0]


def undirected_pairs(edges: np.ndarray) -> np.ndarray:
    """Unique undirected (lo, hi) pairs of an edge array (any direction mix)."""
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    edges = edges[edges[:, 0] != edges[:, 1]]
    if edges.shape[0] == 0:
        return _EMPTY.copy()
    lo = np.minimum(edges[:, 0], edges[:, 1])
    hi = np.maximum(edges[:, 0], edges[:, 1])
    keys = np.unique(lo << np.int64(32) | hi)
    return np.stack([keys >> np.int64(32), keys & np.int64(0xFFFFFFFF)], axis=1)


def temporal_edge_stream(
    edges: np.ndarray, batch_size: int = 256, seed: int = 0
) -> Iterator[StreamBatch]:
    """Replay a static graph as a timestamped arrival stream.

    Shuffles the undirected edges with a seeded permutation (the
    synthetic timestamp order) and yields insert-only batches until the
    whole graph has arrived.
    """
    if batch_size < 1:
        raise ValueError("batch_size must be positive")
    und = undirected_pairs(edges)
    order = np.random.default_rng(seed).permutation(und.shape[0])
    for i in range(0, und.shape[0], batch_size):
        yield StreamBatch(insert=und[order[i : i + batch_size]], delete=_EMPTY)


def sliding_window_stream(
    edges: np.ndarray, window: int, batch_size: int = 256, seed: int = 0
) -> Iterator[StreamBatch]:
    """Arrival stream where only the ``window`` most recent edges stay live.

    Same seeded timestamp order as :func:`temporal_edge_stream`; each
    batch inserts the next arrivals and deletes the oldest live edges
    that the window no longer covers, so after batch ``k`` exactly
    ``min(k·batch_size, window)``-ish edges are live.
    """
    if batch_size < 1:
        raise ValueError("batch_size must be positive")
    if window < 1:
        raise ValueError("window must be positive")
    und = undirected_pairs(edges)
    order = np.random.default_rng(seed).permutation(und.shape[0])
    oldest = 0
    for i in range(0, und.shape[0], batch_size):
        ins = und[order[i : i + batch_size]]
        live_hi = i + ins.shape[0]
        new_oldest = max(0, live_hi - window)
        dele = und[order[oldest:new_oldest]] if new_oldest > oldest else _EMPTY
        oldest = new_oldest
        yield StreamBatch(insert=ins, delete=dele)


STREAM_GENERATORS = {
    "temporal": temporal_edge_stream,
    "sliding_window": sliding_window_stream,
}
