"""Layer-wise uniform neighbor sampling (GraphSAGE ``minibatch_lg`` shape).

Sampling is *with replacement* so every shape is static under ``jit``:
a seed batch of ``B`` nodes with fanouts ``(f₁, f₂, …)`` produces frontiers
of ``B``, ``B·f₁``, ``B·f₁·f₂``, … nodes.  Zero-degree nodes fall back to a
self-loop so aggregation stays well-defined.

The sampler consumes the same CSR arrays the triangle-counting core builds
— one graph representation feeds both the analytics and the training stack.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

__all__ = ["SampledBlocks", "sample_blocks"]


class SampledBlocks(NamedTuple):
    """Frontier node ids per layer, innermost (deepest) last.

    ``frontiers[0]`` is the seed batch ``(B,)``; ``frontiers[l]`` has shape
    ``(B · Πᵢ<ₗ fᵢ,)``.  Layer ``l`` aggregation reduces ``frontiers[l+1]``
    (reshaped ``(-1, f_l)``) into ``frontiers[l]``.
    """

    frontiers: tuple[jax.Array, ...]


@functools.partial(jax.jit, static_argnames=("fanouts",))
def sample_blocks(
    key: jax.Array,
    row_offsets: jax.Array,
    col: jax.Array,
    seeds: jax.Array,
    fanouts: tuple[int, ...],
) -> SampledBlocks:
    """Sample a layered block subgraph rooted at ``seeds``."""
    frontiers = [seeds.astype(jnp.int32)]
    cur = frontiers[0]
    for depth, fanout in enumerate(fanouts):
        key, sub = jax.random.split(key)
        deg = (row_offsets[cur + 1] - row_offsets[cur]).astype(jnp.int32)
        u = jax.random.uniform(sub, (cur.shape[0], fanout))
        pick = (u * jnp.maximum(deg, 1)[:, None]).astype(jnp.int32)
        idx = jnp.clip(row_offsets[cur][:, None] + pick, 0, col.shape[0] - 1)
        nbrs = col[idx]
        # zero-degree fallback: self-loop
        nbrs = jnp.where(deg[:, None] > 0, nbrs, cur[:, None])
        cur = nbrs.reshape(-1)
        frontiers.append(cur)
    return SampledBlocks(frontiers=tuple(frontiers))
