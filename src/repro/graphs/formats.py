"""Edge-array graph format utilities (host side, NumPy).

The paper (§III-A) argues for the *edge array* as the canonical input
format: an ``(m, 2)`` array of vertex-id pairs, no self loops, no
multi-edges, every undirected edge present exactly twice (once per
direction).  All generators and loaders in :mod:`repro.graphs` normalize to
this representation via :func:`canonicalize_edges`.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "canonicalize_edges",
    "validate_node_ids",
    "pack_unique_keys",
    "unpack_keys_canonical",
    "edge_array_to_csr",
    "csr_from_forward_pairs",
    "csr_to_edge_array",
    "undirected_edge_count",
    "validate_edge_array",
    "graph_stats",
    "stats_from_degrees",
]


def validate_node_ids(edges: np.ndarray, *, context: str = "edge list") -> None:
    """Raise ``ValueError`` unless every id is in ``[0, 2**31)``.

    The single guard for every ``lo << 32 | hi`` packed-key site
    (:func:`pack_unique_keys`, the DOULION sparsifier, the incremental
    counter's adjacency, the streaming parsers): outside this range the
    packed key wraps — ``lo << 32`` wraps negative or ≥ 2³¹ ids and ``|``
    with a negative ``hi`` sets the sign bits — silently merging distinct
    edges.  ``context`` lets callers localize the error (e.g. a parser's
    line hint).
    """
    edges = np.asarray(edges)
    if edges.size == 0:
        return
    lo_id, hi_id = int(edges.min()), int(edges.max())
    if lo_id < 0:
        raise ValueError(
            f"negative node id {lo_id} in {context}; node ids must be "
            "non-negative integers"
        )
    if hi_id > 2**31 - 1:
        raise ValueError(
            f"node id {hi_id} exceeds 2**31-1 in {context}; the 64-bit "
            "packed-key sort (§III-D2) requires ids < 2**31"
        )


def pack_unique_keys(edges: np.ndarray) -> np.ndarray:
    """Validate ids, drop self loops, and pack pairs into sorted-unique
    64-bit keys (``lo << 32 | hi`` — the paper's thrust::sort trick,
    §III-D2: a single-key sort instead of a lexicographic pair sort).

    Shared by :func:`canonicalize_edges` and the out-of-core per-chunk
    path (:mod:`repro.graphs.io.external`), so the two stay bit-identical
    by construction.
    """
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    validate_node_ids(edges)
    edges = edges[edges[:, 0] != edges[:, 1]]  # drop self loops
    lo = np.minimum(edges[:, 0], edges[:, 1])
    hi = np.maximum(edges[:, 0], edges[:, 1])
    return np.unique(lo << np.int64(32) | hi)


def unpack_keys_canonical(key: np.ndarray, dtype=np.int32) -> np.ndarray:
    """Sorted-unique packed keys → canonical edge array (fwd block, then
    bwd block — the inverse of :func:`pack_unique_keys`)."""
    lo = (key >> np.int64(32)).astype(dtype)
    hi = (key & np.int64(0xFFFFFFFF)).astype(dtype)
    fwd = np.stack([lo, hi], axis=1)
    bwd = np.stack([hi, lo], axis=1)
    return np.concatenate([fwd, bwd], axis=0)


def canonicalize_edges(edges: np.ndarray, *, dtype=np.int32) -> np.ndarray:
    """Normalize raw edge pairs to the paper's canonical edge array.

    Removes self loops, deduplicates multi-edges, and emits every
    undirected edge exactly twice (both directions).  Input may contain an
    arbitrary mix of directions and duplicates.  Raises ``ValueError`` on
    negative or ≥ 2³¹ node ids, which the key packing cannot represent.
    """
    return unpack_keys_canonical(pack_unique_keys(edges), dtype)


def validate_edge_array(edges: np.ndarray) -> None:
    """Raise ``ValueError`` unless ``edges`` is a canonical edge array."""
    edges = np.asarray(edges)
    if edges.ndim != 2 or edges.shape[1] != 2:
        raise ValueError(f"edge array must be (m, 2); got {edges.shape}")
    if edges.shape[0] % 2 != 0:
        raise ValueError("canonical edge array must have an even number of rows")
    if (edges[:, 0] == edges[:, 1]).any():
        raise ValueError("edge array contains self loops")
    key = edges[:, 0].astype(np.int64) << 32 | edges[:, 1].astype(np.int64)
    if np.unique(key).size != key.size:
        raise ValueError("edge array contains duplicate edges")
    rev = edges[:, 1].astype(np.int64) << 32 | edges[:, 0].astype(np.int64)
    if not np.array_equal(np.sort(key), np.sort(rev)):
        raise ValueError("edge array is not symmetric (each edge must appear twice)")


def undirected_edge_count(edges: np.ndarray) -> int:
    return int(np.asarray(edges).shape[0]) // 2


def edge_array_to_csr(edges: np.ndarray, n_nodes: int | None = None):
    """Convert a canonical edge array to CSR ``(row_offsets, col)``.

    The paper notes (§III-A) this direction requires a sort and is the
    expensive conversion; we provide it for interop and for the GNN stack.
    """
    edges = np.asarray(edges)
    if n_nodes is None:
        n_nodes = int(edges.max()) + 1 if edges.size else 0
    order = np.lexsort((edges[:, 1], edges[:, 0]))
    sorted_edges = edges[order]
    row_offsets = np.searchsorted(sorted_edges[:, 0], np.arange(n_nodes + 1))
    return row_offsets.astype(np.int64), sorted_edges[:, 1].copy()


def csr_from_forward_pairs(lo: np.ndarray, hi: np.ndarray, n_nodes: int):
    """Sort-free undirected CSR from sorted-unique forward pairs.

    ``(lo, hi)`` are the ``lo < hi`` halves of a canonical edge array in
    packed-key order (sorted by ``(lo, hi)``) — exactly what the
    canonicalization pipelines produce.  Output is bit-identical to
    ``edge_array_to_csr(canonical_edges, n_nodes)`` but needs no
    ``lexsort`` over the ``2m`` rows: row ``u`` is [partners < u] ++
    [partners > u], where the first block comes from keys with
    ``hi == u`` (their ``lo`` ascend in scan order) and the second from
    keys with ``lo == u`` (their ``hi`` ascend) — only a stable single-key
    argsort of ``hi`` is needed to group the first block.  This is the
    ingestion fast path: at SNAP scale the pair lexsort's index+copy
    would dwarf the CSR being built.
    """
    lo = np.asarray(lo, dtype=np.int64)
    hi = np.asarray(hi, dtype=np.int64)
    m = lo.shape[0]
    deg_gt = np.bincount(lo, minlength=n_nodes)  # partners greater than u
    deg_lt = np.bincount(hi, minlength=n_nodes)  # partners less than u
    row_offsets = np.zeros(n_nodes + 1, np.int64)
    np.cumsum(deg_lt + deg_gt, out=row_offsets[1:])
    col = np.empty(2 * m, np.int32)
    # greater-than block: keys are grouped by lo with hi ascending, so the
    # in-group rank is position minus the group's start in key order
    lo_group_start = np.concatenate([[0], np.cumsum(deg_gt)])
    rank = np.arange(m, dtype=np.int64) - lo_group_start[lo]
    col[row_offsets[lo] + deg_lt[lo] + rank] = hi
    # less-than block: group by hi (stable keeps lo ascending in-group)
    order = np.argsort(hi, kind="stable")
    hi_group_start = np.concatenate([[0], np.cumsum(deg_lt)])
    hi_sorted = hi[order]
    rank = np.arange(m, dtype=np.int64) - hi_group_start[hi_sorted]
    col[row_offsets[hi_sorted] + rank] = lo[order]
    return row_offsets, col


def csr_to_edge_array(row_offsets: np.ndarray, col: np.ndarray) -> np.ndarray:
    """Single-pass CSR → edge array conversion (the cheap direction)."""
    n = row_offsets.shape[0] - 1
    src = np.repeat(np.arange(n, dtype=col.dtype), np.diff(row_offsets))
    return np.stack([src, col], axis=1)


def stats_from_degrees(deg: np.ndarray, n_nodes: int) -> dict:
    """The :func:`graph_stats` dict computed from an undirected degree
    histogram (shared with ``repro.graphs.io.CSRGraph.stats``, which has
    degrees but no edge array)."""
    deg = np.asarray(deg, dtype=np.int64)
    if deg.size == 0:
        return dict(n_nodes=0, n_edges=0, max_degree=0, mean_degree=0.0,
                    skew=0.0, total_wedges=0)
    mean = float(deg.mean())
    return dict(
        n_nodes=n_nodes,
        n_edges=int(deg.sum()) // 2,
        max_degree=int(deg.max()),
        mean_degree=mean,
        skew=float(deg.max() / max(mean, 1e-9)),
        total_wedges=int((deg * (deg - 1) // 2).sum()),
    )


def graph_stats(edges: np.ndarray) -> dict:
    """Host-side summary statistics of the *undirected* graph.

    Returns ``n_nodes``, ``n_edges`` (undirected), ``max_degree``,
    ``mean_degree``, ``skew`` (max/mean degree — the §III-C load-imbalance
    proxy) and ``total_wedges`` (Σ deg·(deg−1)/2 — the transitivity
    denominator).  Note these are undirected quantities; the engine's
    budgeted workload is the smaller *oriented* Σ deg⁺, reported after a
    run as ``TriangleCounter.last_stats.total_wedges``.
    """
    edges = np.asarray(edges)
    if edges.size == 0:
        return stats_from_degrees(np.empty((0,), np.int64), 0)
    n = int(edges.max()) + 1
    deg = np.bincount(edges[:, 0], minlength=n).astype(np.int64)
    return stats_from_degrees(deg, n)
