"""Edge-array graph format utilities (host side, NumPy).

The paper (§III-A) argues for the *edge array* as the canonical input
format: an ``(m, 2)`` array of vertex-id pairs, no self loops, no
multi-edges, every undirected edge present exactly twice (once per
direction).  All generators and loaders in :mod:`repro.graphs` normalize to
this representation via :func:`canonicalize_edges`.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "canonicalize_edges",
    "edge_array_to_csr",
    "csr_to_edge_array",
    "undirected_edge_count",
    "validate_edge_array",
    "graph_stats",
]


def canonicalize_edges(edges: np.ndarray, *, dtype=np.int32) -> np.ndarray:
    """Normalize raw edge pairs to the paper's canonical edge array.

    Removes self loops, deduplicates multi-edges, and emits every
    undirected edge exactly twice (both directions).  Input may contain an
    arbitrary mix of directions and duplicates.
    """
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    edges = edges[edges[:, 0] != edges[:, 1]]  # drop self loops
    lo = np.minimum(edges[:, 0], edges[:, 1])
    hi = np.maximum(edges[:, 0], edges[:, 1])
    # Packed 64-bit keys: the paper's thrust::sort trick (§III-D2) — a
    # single-key sort instead of a lexicographic pair sort.
    key = lo << np.int64(32) | hi
    key = np.unique(key)
    lo = (key >> np.int64(32)).astype(dtype)
    hi = (key & np.int64(0xFFFFFFFF)).astype(dtype)
    fwd = np.stack([lo, hi], axis=1)
    bwd = np.stack([hi, lo], axis=1)
    return np.concatenate([fwd, bwd], axis=0)


def validate_edge_array(edges: np.ndarray) -> None:
    """Raise ``ValueError`` unless ``edges`` is a canonical edge array."""
    edges = np.asarray(edges)
    if edges.ndim != 2 or edges.shape[1] != 2:
        raise ValueError(f"edge array must be (m, 2); got {edges.shape}")
    if edges.shape[0] % 2 != 0:
        raise ValueError("canonical edge array must have an even number of rows")
    if (edges[:, 0] == edges[:, 1]).any():
        raise ValueError("edge array contains self loops")
    key = edges[:, 0].astype(np.int64) << 32 | edges[:, 1].astype(np.int64)
    if np.unique(key).size != key.size:
        raise ValueError("edge array contains duplicate edges")
    rev = edges[:, 1].astype(np.int64) << 32 | edges[:, 0].astype(np.int64)
    if not np.array_equal(np.sort(key), np.sort(rev)):
        raise ValueError("edge array is not symmetric (each edge must appear twice)")


def undirected_edge_count(edges: np.ndarray) -> int:
    return int(np.asarray(edges).shape[0]) // 2


def edge_array_to_csr(edges: np.ndarray, n_nodes: int | None = None):
    """Convert a canonical edge array to CSR ``(row_offsets, col)``.

    The paper notes (§III-A) this direction requires a sort and is the
    expensive conversion; we provide it for interop and for the GNN stack.
    """
    edges = np.asarray(edges)
    if n_nodes is None:
        n_nodes = int(edges.max()) + 1 if edges.size else 0
    order = np.lexsort((edges[:, 1], edges[:, 0]))
    sorted_edges = edges[order]
    row_offsets = np.searchsorted(sorted_edges[:, 0], np.arange(n_nodes + 1))
    return row_offsets.astype(np.int64), sorted_edges[:, 1].copy()


def csr_to_edge_array(row_offsets: np.ndarray, col: np.ndarray) -> np.ndarray:
    """Single-pass CSR → edge array conversion (the cheap direction)."""
    n = row_offsets.shape[0] - 1
    src = np.repeat(np.arange(n, dtype=col.dtype), np.diff(row_offsets))
    return np.stack([src, col], axis=1)


def graph_stats(edges: np.ndarray) -> dict:
    """Host-side summary statistics of the *undirected* graph.

    Returns ``n_nodes``, ``n_edges`` (undirected), ``max_degree``,
    ``mean_degree``, ``skew`` (max/mean degree — the §III-C load-imbalance
    proxy) and ``total_wedges`` (Σ deg·(deg−1)/2 — the transitivity
    denominator).  Note these are undirected quantities; the engine's
    budgeted workload is the smaller *oriented* Σ deg⁺, reported after a
    run as ``TriangleCounter.last_stats.total_wedges``.
    """
    edges = np.asarray(edges)
    if edges.size == 0:
        return dict(n_nodes=0, n_edges=0, max_degree=0, mean_degree=0.0,
                    skew=0.0, total_wedges=0)
    n = int(edges.max()) + 1
    deg = np.bincount(edges[:, 0], minlength=n).astype(np.int64)
    mean = float(deg.mean())
    return dict(
        n_nodes=n,
        n_edges=edges.shape[0] // 2,
        max_degree=int(deg.max()),
        mean_degree=mean,
        skew=float(deg.max() / max(mean, 1e-9)),
        total_wedges=int((deg * (deg - 1) // 2).sum()),
    )
