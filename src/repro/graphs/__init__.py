"""Graph data pipeline: generators, formats, samplers, batching, streams."""
from .formats import (
    canonicalize_edges,
    edge_array_to_csr,
    csr_to_edge_array,
    undirected_edge_count,
    validate_edge_array,
    graph_stats,
)
from .generators import (
    kronecker_rmat,
    barabasi_albert,
    watts_strogatz,
    erdos_renyi,
    GRAPH_GENERATORS,
)
from .sampling import SampledBlocks, sample_blocks
from .streams import (
    StreamBatch,
    undirected_pairs,
    temporal_edge_stream,
    sliding_window_stream,
    STREAM_GENERATORS,
)
from .batching import GraphBatch, collate_graphs, random_molecule_batch

__all__ = [
    "canonicalize_edges",
    "edge_array_to_csr",
    "csr_to_edge_array",
    "undirected_edge_count",
    "validate_edge_array",
    "graph_stats",
    "kronecker_rmat",
    "barabasi_albert",
    "watts_strogatz",
    "erdos_renyi",
    "GRAPH_GENERATORS",
    "StreamBatch",
    "undirected_pairs",
    "temporal_edge_stream",
    "sliding_window_stream",
    "STREAM_GENERATORS",
    "SampledBlocks",
    "sample_blocks",
    "GraphBatch",
    "collate_graphs",
    "random_molecule_batch",
]
