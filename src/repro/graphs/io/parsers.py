"""Chunked streaming parsers for on-disk edge lists.

Both parsers yield ``(chunk, 2)`` int64 NumPy blocks of at most
``max_chunk_edges`` rows, so peak host memory is bounded regardless of
file size.  Supported formats:

* **SNAP text** (``.txt``, ``.el``, ``.edges``, ``.tsv``, ``.csv`` …):
  one edge per line, two integer ids separated by whitespace, tab or
  comma; ``#`` and ``%`` comment lines and blank lines skipped.  This is
  the format of every snap.stanford.edu download in the paper's Table I.
* **MatrixMarket coordinate** (``.mtx``): ``%%MatrixMarket`` banner,
  ``%`` comments, a ``rows cols nnz`` size line, then 1-based ``i j
  [value]`` entries (converted to 0-based ids; values ignored).
* Either of the above behind **gzip** (``.gz`` suffix), streamed without
  decompressing to disk.

Node ids must be non-negative and < 2³¹ (the canonical pipeline packs
pairs into 64-bit keys and emits int32 arrays); violations raise
``ValueError`` with the offending line number.
"""
from __future__ import annotations

import gzip
import io
import os
from typing import Iterator

import numpy as np

from ..formats import validate_node_ids

__all__ = [
    "DEFAULT_CHUNK_EDGES",
    "sniff_format",
    "iter_edge_chunks",
    "parse_edge_file",
]

DEFAULT_CHUNK_EDGES = 1 << 22  # 4M edges/chunk ≈ 64 MB of int64 pairs

# Read text in fixed-size byte blocks; a chunk of edges is assembled from
# however many blocks it takes.  64 KB keeps the Python-level loop cheap
# while never holding more than one block + one chunk of parsed pairs.
_TEXT_BLOCK_BYTES = 1 << 16

_TEXT_SUFFIXES = {".txt", ".el", ".edges", ".edgelist", ".tsv", ".csv", ".snap"}


def sniff_format(path: str | os.PathLike) -> str:
    """Return ``"mtx"`` or ``"text"`` for ``path`` (``.gz`` stripped)."""
    name = os.fspath(path)
    if name.endswith(".gz"):
        name = name[:-3]
    ext = os.path.splitext(name)[1].lower()
    if ext == ".mtx":
        return "mtx"
    if ext in _TEXT_SUFFIXES or ext == "":
        return "text"
    raise ValueError(
        f"cannot infer edge-list format from {path!r}: expected one of "
        f"{sorted(_TEXT_SUFFIXES | {'.mtx'})} (optionally .gz-compressed)"
    )


def _open_text(path: str | os.PathLike) -> io.TextIOBase:
    # latin-1 never fails to decode, so non-ASCII bytes in comment lines
    # (common in MatrixMarket headers) pass through harmlessly; integer
    # fields are pure ASCII either way and error cleanly in the parser
    if os.fspath(path).endswith(".gz"):
        return io.TextIOWrapper(gzip.open(path, "rb"), encoding="latin-1")
    return open(path, "r", encoding="latin-1", buffering=_TEXT_BLOCK_BYTES)




def _parse_pairs(lines: list[str], *, base: int, first_line_no: int) -> np.ndarray:
    """Vectorized two-column integer parse of non-comment text lines."""
    if not lines:
        return np.empty((0, 2), np.int64)
    # One split per line, then a single bulk str→int64 conversion.  A
    # ragged row (1 or 3+ columns) makes np.array raise rather than
    # re-pair tokens across rows; ids beyond int64 raise OverflowError.
    toks = [ln.replace(",", " ").split() for ln in lines]
    try:
        pairs = np.array(toks, dtype=np.int64)
    except (ValueError, OverflowError):
        pairs = None
    if pairs is None or pairs.ndim != 2 or pairs.shape[1] != 2:
        # Slow path only to locate the malformed line for the error message.
        for off, parts in enumerate(toks):
            ok = len(parts) == 2
            if ok:
                try:
                    np.array(parts, dtype=np.int64)  # parses or raises
                except (ValueError, OverflowError):
                    ok = False
            if not ok:
                raise ValueError(
                    f"line {first_line_no + off}: expected two integer node "
                    f"ids, got {' '.join(parts)!r}"
                )
        raise ValueError(
            f"malformed edge list near line {first_line_no}: columns are "
            "not consistently two integers per row"
        )
    if base:
        pairs = pairs - base
    validate_node_ids(pairs, context=f"edge list near line {first_line_no}")
    return pairs


def _iter_text_chunks(
    fh: io.TextIOBase, max_chunk_edges: int, *, base: int = 0, line_no: int = 0,
) -> Iterator[np.ndarray]:
    """Yield parsed ``(≤max_chunk_edges, 2)`` blocks from an open stream."""
    batch_lines = min(max_chunk_edges, _TEXT_BLOCK_BYTES // 4)
    pending: list[np.ndarray] = []
    pending_rows = 0
    lines: list[str] = []
    first_line_no = line_no + 1

    def drain(final: bool) -> Iterator[np.ndarray]:
        nonlocal pending, pending_rows
        while pending_rows >= max_chunk_edges or (final and pending_rows > 0):
            block = np.concatenate(pending, axis=0) if len(pending) > 1 else pending[0]
            yield block[:max_chunk_edges]
            rest = block[max_chunk_edges:]
            pending = [rest] if rest.size else []
            pending_rows = rest.shape[0]

    for raw in fh:
        line_no += 1
        s = raw.strip()
        if not s or s[0] in "#%":
            continue
        if not lines:
            first_line_no = line_no
        lines.append(s)
        if len(lines) >= batch_lines:
            pairs = _parse_pairs(lines, base=base, first_line_no=first_line_no)
            lines = []
            pending.append(pairs)
            pending_rows += pairs.shape[0]
            yield from drain(final=False)
    if lines:
        pairs = _parse_pairs(lines, base=base, first_line_no=first_line_no)
        pending.append(pairs)
        pending_rows += pairs.shape[0]
    yield from drain(final=True)


def _iter_mtx_chunks(fh: io.TextIOBase, max_chunk_edges: int) -> Iterator[np.ndarray]:
    """MatrixMarket coordinate parser: banner + size line, 1-based entries."""
    banner = fh.readline()
    line_no = 1
    if not banner.startswith("%%MatrixMarket"):
        raise ValueError("not a MatrixMarket file: missing %%MatrixMarket banner")
    fields = banner.split()
    if len(fields) < 4 or fields[1] != "matrix" or fields[2] != "coordinate":
        raise ValueError(f"unsupported MatrixMarket header {banner.strip()!r}: "
                         "only 'matrix coordinate' files hold edge lists")
    value_type = fields[3]
    has_values = value_type != "pattern"
    # size line: first non-comment line after the banner
    for raw in fh:
        line_no += 1
        s = raw.strip()
        if s and s[0] != "%":
            break
    else:
        raise ValueError("MatrixMarket file has no size line")
    parts = s.split()
    if len(parts) != 3 or not all(p.isdigit() for p in parts):
        raise ValueError(f"line {line_no}: malformed MatrixMarket size line {s!r}")
    if not has_values:
        yield from _iter_text_chunks(fh, max_chunk_edges, base=1, line_no=line_no)
        return
    # valued entries: strip the third column per block before the bulk parse
    lines: list[str] = []
    first_line_no = line_no + 1
    for raw in fh:
        line_no += 1
        s = raw.strip()
        if not s or s[0] == "%":
            continue
        if not lines:
            first_line_no = line_no
        cols = s.split()
        if len(cols) < 2:
            raise ValueError(f"line {line_no}: expected 'i j [value]', got {s!r}")
        lines.append(f"{cols[0]} {cols[1]}")
        if len(lines) >= max_chunk_edges:
            yield _parse_pairs(lines, base=1, first_line_no=first_line_no)
            lines = []
    if lines:
        yield _parse_pairs(lines, base=1, first_line_no=first_line_no)


def iter_edge_chunks(
    path: str | os.PathLike,
    max_chunk_edges: int = DEFAULT_CHUNK_EDGES,
    *,
    fmt: str | None = None,
) -> Iterator[np.ndarray]:
    """Stream ``(≤max_chunk_edges, 2)`` int64 edge blocks from ``path``.

    ``fmt`` overrides extension sniffing (``"text"`` or ``"mtx"``).  Raw
    blocks are exactly what the file says — self loops, duplicates and
    both-direction entries are *not* removed here; that is
    :func:`repro.graphs.io.external.canonicalize_edges_external`'s job.
    """
    if max_chunk_edges < 1:
        raise ValueError("max_chunk_edges must be positive")
    fmt = fmt or sniff_format(path)
    with _open_text(path) as fh:
        if fmt == "mtx":
            yield from _iter_mtx_chunks(fh, max_chunk_edges)
        elif fmt == "text":
            yield from _iter_text_chunks(fh, max_chunk_edges)
        else:
            raise ValueError(f"unknown format {fmt!r}; expected 'text' or 'mtx'")


def parse_edge_file(
    path: str | os.PathLike,
    max_chunk_edges: int = DEFAULT_CHUNK_EDGES,
    *,
    fmt: str | None = None,
) -> np.ndarray:
    """Materialize the whole raw edge list (tests / small files only)."""
    chunks = list(iter_edge_chunks(path, max_chunk_edges, fmt=fmt))
    if not chunks:
        return np.empty((0, 2), np.int64)
    return np.concatenate(chunks, axis=0)
