"""The ``.tricsr`` binary CSR cache format.

A graph is parsed and canonicalized once; every later run memory-maps the
cached CSR and is counting within milliseconds.  Layout (little-endian)::

    offset  size  field
    0       8     magic  b"TRICSR\\x01\\n"   (version byte inside the magic)
    8       8     n_nodes               (u64)
    16      8     n_rows = len(col)     (u64; 2 × undirected edge count)
    24      1     row_offsets dtype code (np.dtype(...).num, u8)
    25      1     col dtype code         (u8)
    26      6     reserved (zeros)
    32      8     crc32 of the two payloads (u64, low 32 bits used)
    40      24    reserved (zeros)  — header is a fixed 64 bytes
    64      …     row_offsets payload ((n_nodes+1) × itemsize)
    …       …     col payload          (n_rows × itemsize)

The stored CSR is the **undirected canonical** adjacency (every edge in
both directions, rows sorted): exactly ``edge_array_to_csr`` of the
canonical edge array, so tests can compare bit-for-bit.  Loads default to
``mmap_mode="r"`` and skip the checksum (header + size validation only);
pass ``verify=True`` to pay one full read for the crc — ingest does this
once, right after writing.
"""
from __future__ import annotations

import os
import struct
import zlib
from typing import NamedTuple

import numpy as np

__all__ = [
    "TRICSR_MAGIC",
    "TRICSR_VERSION",
    "CacheError",
    "CSRGraph",
    "save_tricsr",
    "load_tricsr",
]

TRICSR_VERSION = 1
TRICSR_MAGIC = b"TRICSR" + bytes([TRICSR_VERSION]) + b"\n"
_HEADER = struct.Struct("<8sQQBB6xQ24x")
assert _HEADER.size == 64


class CacheError(ValueError):
    """A ``.tricsr`` file is missing, truncated, corrupt, or wrong-version."""


_DTYPE_BY_CODE = {
    np.dtype(t).num: np.dtype(t)
    for t in (np.int32, np.int64, np.uint32, np.uint64)
}


class CSRGraph(NamedTuple):
    """Undirected canonical CSR as loaded from (or destined for) the cache.

    ``row_offsets[u] : row_offsets[u+1]`` indexes ``col`` — the sorted
    neighbors of ``u`` with every undirected edge present in both rows,
    i.e. ``edge_array_to_csr(canonicalize_edges(raw))``.  Arrays may be
    read-only memory maps.
    """

    row_offsets: np.ndarray  # (n_nodes+1,) int64
    col: np.ndarray          # (2m,) int32
    n_nodes: int

    @property
    def n_edges(self) -> int:
        """Undirected edge count."""
        return int(self.col.shape[0]) // 2

    def degrees(self) -> np.ndarray:
        return np.diff(self.row_offsets).astype(np.int64)

    def edge_array(self) -> np.ndarray:
        """Materialize the canonical edge array in CSR (src-major) order."""
        from ..formats import csr_to_edge_array

        return csr_to_edge_array(np.asarray(self.row_offsets), np.asarray(self.col))

    def stats(self) -> dict:
        """Degree statistics without materializing the edge array
        (same dict as :func:`repro.graphs.graph_stats`)."""
        from ..formats import stats_from_degrees

        return stats_from_degrees(self.degrees(), self.n_nodes)


def save_tricsr(path: str | os.PathLike, csr: CSRGraph) -> None:
    """Atomically write ``csr`` to ``path`` (tmp file + rename)."""
    row = np.ascontiguousarray(csr.row_offsets, dtype=np.int64)
    col = np.ascontiguousarray(csr.col, dtype=np.int32)
    if row.shape[0] != csr.n_nodes + 1:
        raise ValueError(
            f"row_offsets has {row.shape[0]} entries for n_nodes={csr.n_nodes}"
        )
    crc = zlib.crc32(col.tobytes(), zlib.crc32(row.tobytes()))
    header = _HEADER.pack(
        TRICSR_MAGIC, csr.n_nodes, col.shape[0],
        row.dtype.num, col.dtype.num, crc,
    )
    tmp = os.fspath(path) + ".tmp"
    with open(tmp, "wb") as fh:
        fh.write(header)
        fh.write(row.tobytes())
        fh.write(col.tobytes())
        fh.flush()
        os.fsync(fh.fileno())
    os.replace(tmp, path)


def load_tricsr(
    path: str | os.PathLike, *, mmap: bool = True, verify: bool = False
) -> CSRGraph:
    """Load a ``.tricsr`` file, memory-mapped unless ``mmap=False``."""
    try:
        with open(path, "rb") as fh:
            raw = fh.read(_HEADER.size)
    except OSError as e:
        raise CacheError(f"cannot read {path}: {e}") from e
    if len(raw) < _HEADER.size:
        raise CacheError(f"{path}: truncated header ({len(raw)} bytes)")
    magic, n_nodes, n_rows, row_code, col_code, crc = _HEADER.unpack(raw)
    if magic[:6] != TRICSR_MAGIC[:6]:
        raise CacheError(f"{path}: not a .tricsr file (bad magic {magic!r})")
    if magic != TRICSR_MAGIC:
        raise CacheError(
            f"{path}: version {magic[6]} != supported {TRICSR_VERSION}; "
            "re-ingest to refresh the cache"
        )
    try:
        row_dtype = _DTYPE_BY_CODE[row_code]
        col_dtype = _DTYPE_BY_CODE[col_code]
    except KeyError as e:
        raise CacheError(f"{path}: unsupported dtype code {e.args[0]}") from None
    row_bytes = (n_nodes + 1) * row_dtype.itemsize
    col_bytes = n_rows * col_dtype.itemsize
    expect = _HEADER.size + row_bytes + col_bytes
    actual = os.path.getsize(path)
    if actual != expect:
        raise CacheError(f"{path}: size {actual} != header-implied {expect}")
    if mmap:
        row = np.memmap(path, dtype=row_dtype, mode="r",
                        offset=_HEADER.size, shape=(n_nodes + 1,))
        col = np.memmap(path, dtype=col_dtype, mode="r",
                        offset=_HEADER.size + row_bytes, shape=(n_rows,))
    else:
        with open(path, "rb") as fh:
            fh.seek(_HEADER.size)
            row = np.frombuffer(fh.read(row_bytes), dtype=row_dtype)
            col = np.frombuffer(fh.read(col_bytes), dtype=col_dtype)
    if verify:
        got = zlib.crc32(np.asarray(col).tobytes(),
                         zlib.crc32(np.asarray(row).tobytes()))
        if got != crc:
            raise CacheError(f"{path}: checksum mismatch (stored {crc:#x}, "
                             f"computed {got:#x}) — cache is corrupt, delete it")
    return CSRGraph(row, col, int(n_nodes))
