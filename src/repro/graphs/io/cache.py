"""The ``.tricsr`` binary CSR cache format.

A graph is parsed and canonicalized once; every later run memory-maps the
cached CSR and is counting within milliseconds.  Layout (little-endian)::

    offset  size  field
    0       8     magic  b"TRICSR\\x01\\n"   (version byte inside the magic)
    8       8     n_nodes               (u64)
    16      8     n_rows = len(col)     (u64; 2 × undirected edge count)
    24      1     row_offsets dtype code (np.dtype(...).num, u8)
    25      1     col dtype code         (u8)
    26      6     reserved (zeros)
    32      8     crc32 of the two payloads (u64, low 32 bits used)
    40      24    reserved (zeros)  — header is a fixed 64 bytes
    64      …     row_offsets payload ((n_nodes+1) × itemsize)
    …       …     col payload          (n_rows × itemsize)

The stored CSR is the **undirected canonical** adjacency (every edge in
both directions, rows sorted): exactly ``edge_array_to_csr`` of the
canonical edge array, so tests can compare bit-for-bit.  Loads default to
``mmap_mode="r"`` and skip the checksum (header + size validation only);
pass ``verify=True`` to pay one full read for the crc — ingest does this
once, right after writing.

Sharded views (``.tricsr.stripe{k}of{N}``)
==========================================

For the §III-E distributed engine each host only needs to *ingest* its
own slab: :func:`save_tricsr_stripes` splits the cache into ``N``
contiguous node-range slabs balanced by neighbor count, each a
self-describing 64-byte-header file (magic ``b"TRISLB\\x01\\n"``) whose
payload is the **absolute** ``row_offsets[lo : hi+1]`` slice plus the
matching ``col`` slice, with a per-slab crc32.  A device memory-maps
only its slab (:func:`load_tricsr_stripe`);
:func:`repro.core.distributed.oriented_csr_from_slabs` orients the slab
set without ever materializing the full ``col`` on one host, and
:func:`assemble_stripes` proves losslessness — the reassembled CSR is
bit-identical to the unsharded cache.
"""
from __future__ import annotations

import os
import struct
import zlib
from typing import NamedTuple

import numpy as np

__all__ = [
    "TRICSR_MAGIC",
    "TRICSR_VERSION",
    "TRISLB_MAGIC",
    "CacheError",
    "CSRGraph",
    "CSRStripe",
    "save_tricsr",
    "load_tricsr",
    "plan_csr_stripes",
    "stripe_path",
    "save_tricsr_stripes",
    "load_tricsr_stripe",
    "load_tricsr_stripes",
    "assemble_stripes",
]

TRICSR_VERSION = 1
TRICSR_MAGIC = b"TRICSR" + bytes([TRICSR_VERSION]) + b"\n"
_HEADER = struct.Struct("<8sQQBB6xQ24x")
assert _HEADER.size == 64

TRISLB_MAGIC = b"TRISLB" + bytes([TRICSR_VERSION]) + b"\n"
# magic, n_nodes, node_lo, node_hi (exclusive), col_len, stripe_index,
# n_stripes, row dtype code, col dtype code, pad, crc — 64 bytes like the
# unsharded header
_SLAB_HEADER = struct.Struct("<8sQQQQIIBB6xQ")
assert _SLAB_HEADER.size == 64


class CacheError(ValueError):
    """A ``.tricsr`` file is missing, truncated, corrupt, or wrong-version."""


_DTYPE_BY_CODE = {
    np.dtype(t).num: np.dtype(t)
    for t in (np.int32, np.int64, np.uint32, np.uint64)
}


class CSRGraph(NamedTuple):
    """Undirected canonical CSR as loaded from (or destined for) the cache.

    ``row_offsets[u] : row_offsets[u+1]`` indexes ``col`` — the sorted
    neighbors of ``u`` with every undirected edge present in both rows,
    i.e. ``edge_array_to_csr(canonicalize_edges(raw))``.  Arrays may be
    read-only memory maps.
    """

    row_offsets: np.ndarray  # (n_nodes+1,) int64
    col: np.ndarray          # (2m,) int32
    n_nodes: int

    @property
    def n_edges(self) -> int:
        """Undirected edge count."""
        return int(self.col.shape[0]) // 2

    def degrees(self) -> np.ndarray:
        return np.diff(self.row_offsets).astype(np.int64)

    def edge_array(self) -> np.ndarray:
        """Materialize the canonical edge array in CSR (src-major) order."""
        from ..formats import csr_to_edge_array

        return csr_to_edge_array(np.asarray(self.row_offsets), np.asarray(self.col))

    def stats(self) -> dict:
        """Degree statistics without materializing the edge array
        (same dict as :func:`repro.graphs.graph_stats`)."""
        from ..formats import stats_from_degrees

        return stats_from_degrees(self.degrees(), self.n_nodes)


def save_tricsr(path: str | os.PathLike, csr: CSRGraph) -> None:
    """Atomically write ``csr`` to ``path`` (tmp file + rename)."""
    row = np.ascontiguousarray(csr.row_offsets, dtype=np.int64)
    col = np.ascontiguousarray(csr.col, dtype=np.int32)
    if row.shape[0] != csr.n_nodes + 1:
        raise ValueError(
            f"row_offsets has {row.shape[0]} entries for n_nodes={csr.n_nodes}"
        )
    crc = zlib.crc32(col.tobytes(), zlib.crc32(row.tobytes()))
    header = _HEADER.pack(
        TRICSR_MAGIC, csr.n_nodes, col.shape[0],
        row.dtype.num, col.dtype.num, crc,
    )
    tmp = os.fspath(path) + ".tmp"
    with open(tmp, "wb") as fh:
        fh.write(header)
        fh.write(row.tobytes())
        fh.write(col.tobytes())
        fh.flush()
        os.fsync(fh.fileno())
    os.replace(tmp, path)


def load_tricsr(
    path: str | os.PathLike, *, mmap: bool = True, verify: bool = False
) -> CSRGraph:
    """Load a ``.tricsr`` file, memory-mapped unless ``mmap=False``."""
    try:
        with open(path, "rb") as fh:
            raw = fh.read(_HEADER.size)
    except OSError as e:
        raise CacheError(f"cannot read {path}: {e}") from e
    if len(raw) < _HEADER.size:
        raise CacheError(f"{path}: truncated header ({len(raw)} bytes)")
    magic, n_nodes, n_rows, row_code, col_code, crc = _HEADER.unpack(raw)
    if magic[:6] != TRICSR_MAGIC[:6]:
        raise CacheError(f"{path}: not a .tricsr file (bad magic {magic!r})")
    if magic != TRICSR_MAGIC:
        raise CacheError(
            f"{path}: version {magic[6]} != supported {TRICSR_VERSION}; "
            "re-ingest to refresh the cache"
        )
    try:
        row_dtype = _DTYPE_BY_CODE[row_code]
        col_dtype = _DTYPE_BY_CODE[col_code]
    except KeyError as e:
        raise CacheError(f"{path}: unsupported dtype code {e.args[0]}") from None
    row_bytes = (n_nodes + 1) * row_dtype.itemsize
    col_bytes = n_rows * col_dtype.itemsize
    expect = _HEADER.size + row_bytes + col_bytes
    actual = os.path.getsize(path)
    if actual != expect:
        raise CacheError(f"{path}: size {actual} != header-implied {expect}")
    if mmap:
        row = np.memmap(path, dtype=row_dtype, mode="r",
                        offset=_HEADER.size, shape=(n_nodes + 1,))
        col = np.memmap(path, dtype=col_dtype, mode="r",
                        offset=_HEADER.size + row_bytes, shape=(n_rows,))
    else:
        with open(path, "rb") as fh:
            fh.seek(_HEADER.size)
            row = np.frombuffer(fh.read(row_bytes), dtype=row_dtype)
            col = np.frombuffer(fh.read(col_bytes), dtype=col_dtype)
    if verify:
        got = zlib.crc32(np.asarray(col).tobytes(),
                         zlib.crc32(np.asarray(row).tobytes()))
        if got != crc:
            raise CacheError(f"{path}: checksum mismatch (stored {crc:#x}, "
                             f"computed {got:#x}) — cache is corrupt, delete it")
    return CSRGraph(row, col, int(n_nodes))


# ---------------------------------------------------------------------------
# sharded slab views (.tricsr.stripe{k}of{N})
# ---------------------------------------------------------------------------


class CSRStripe(NamedTuple):
    """One contiguous node-range slab of an undirected canonical CSR.

    Covers the half-open node range ``[node_lo, node_hi)``:
    ``row_offsets`` is the **absolute** ``row_offsets[node_lo : node_hi+1]``
    slice of the full CSR (so ``row_offsets[0]`` is this slab's global
    ``col`` start, not zero) and ``col`` the matching neighbor slice.
    Arrays may be read-only memory maps over the slab file.
    """

    row_offsets: np.ndarray  # (node_hi - node_lo + 1,) absolute offsets
    col: np.ndarray          # (row_offsets[-1] - row_offsets[0],)
    n_nodes: int             # global node count (all slabs agree)
    node_lo: int
    node_hi: int             # exclusive
    stripe_index: int
    n_stripes: int

    @property
    def n_local_nodes(self) -> int:
        return self.node_hi - self.node_lo

    @property
    def n_cols(self) -> int:
        return int(self.col.shape[0])


def plan_csr_stripes(row_offsets, n_stripes: int) -> list[tuple[int, int]]:
    """Split ``[0, n)`` into ``n_stripes`` contiguous node ranges balanced
    by neighbor (``col``) count.

    Returns half-open ``(node_lo, node_hi)`` pairs covering every node
    exactly once; ranges may be empty on tiny graphs (more stripes than
    rows' worth of work) — empty slabs are valid and round-trip fine.
    """
    if n_stripes < 1:
        raise ValueError("n_stripes must be >= 1")
    row = np.asarray(row_offsets, dtype=np.int64)
    n = row.shape[0] - 1
    total = int(row[-1]) if n >= 0 else 0
    targets = (total * np.arange(1, n_stripes, dtype=np.int64)) // n_stripes
    cuts = np.searchsorted(row, targets, side="left")
    cuts = np.maximum.accumulate(np.clip(cuts, 0, n))
    bounds = np.concatenate([[0], cuts, [n]])
    return [(int(bounds[i]), int(bounds[i + 1])) for i in range(n_stripes)]


def stripe_path(path: str | os.PathLike, k: int, n_stripes: int) -> str:
    """The on-disk name of slab ``k`` of ``n_stripes`` for cache ``path``."""
    return f"{os.fspath(path)}.stripe{k}of{n_stripes}"


def save_tricsr_stripes(
    path: str | os.PathLike, csr: CSRGraph, n_stripes: int
) -> list[str]:
    """Write ``csr`` as ``n_stripes`` slab files next to ``path``.

    Each slab is written atomically (tmp + rename) with its own crc32;
    returns the slab paths in stripe order.  ``path`` itself is not
    touched — the sharded views coexist with the unsharded cache.
    """
    row = np.ascontiguousarray(csr.row_offsets, dtype=np.int64)
    col = np.ascontiguousarray(csr.col, dtype=np.int32)
    if row.shape[0] != csr.n_nodes + 1:
        raise ValueError(
            f"row_offsets has {row.shape[0]} entries for n_nodes={csr.n_nodes}"
        )
    paths = []
    for k, (lo, hi) in enumerate(plan_csr_stripes(row, n_stripes)):
        row_slab = row[lo: hi + 1]
        col_slab = col[int(row[lo]): int(row[hi])]
        crc = zlib.crc32(col_slab.tobytes(), zlib.crc32(row_slab.tobytes()))
        header = _SLAB_HEADER.pack(
            TRISLB_MAGIC, csr.n_nodes, lo, hi, col_slab.shape[0],
            k, n_stripes, row.dtype.num, col.dtype.num, crc,
        )
        target = stripe_path(path, k, n_stripes)
        tmp = target + ".tmp"
        with open(tmp, "wb") as fh:
            fh.write(header)
            fh.write(row_slab.tobytes())
            fh.write(col_slab.tobytes())
            fh.flush()
            os.fsync(fh.fileno())
        os.replace(tmp, target)
        paths.append(target)
    return paths


def load_tricsr_stripe(
    path: str | os.PathLike, *, mmap: bool = True, verify: bool = False
) -> CSRStripe:
    """Load one slab file, memory-mapped unless ``mmap=False``."""
    try:
        with open(path, "rb") as fh:
            raw = fh.read(_SLAB_HEADER.size)
    except OSError as e:
        raise CacheError(f"cannot read {path}: {e}") from e
    if len(raw) < _SLAB_HEADER.size:
        raise CacheError(f"{path}: truncated header ({len(raw)} bytes)")
    (magic, n_nodes, lo, hi, col_len, k, n_stripes,
     row_code, col_code, crc) = _SLAB_HEADER.unpack(raw)
    if magic[:6] != TRISLB_MAGIC[:6]:
        raise CacheError(f"{path}: not a .tricsr slab (bad magic {magic!r})")
    if magic != TRISLB_MAGIC:
        raise CacheError(
            f"{path}: version {magic[6]} != supported {TRICSR_VERSION}; "
            "re-shard to refresh the slabs"
        )
    if not (0 <= lo <= hi <= n_nodes) or not (0 <= k < n_stripes):
        raise CacheError(
            f"{path}: inconsistent slab header (nodes [{lo}, {hi}) of "
            f"{n_nodes}, stripe {k} of {n_stripes})"
        )
    try:
        row_dtype = _DTYPE_BY_CODE[row_code]
        col_dtype = _DTYPE_BY_CODE[col_code]
    except KeyError as e:
        raise CacheError(f"{path}: unsupported dtype code {e.args[0]}") from None
    row_bytes = (hi - lo + 1) * row_dtype.itemsize
    col_bytes = col_len * col_dtype.itemsize
    expect = _SLAB_HEADER.size + row_bytes + col_bytes
    actual = os.path.getsize(path)
    if actual != expect:
        raise CacheError(f"{path}: size {actual} != header-implied {expect}")
    if mmap:
        row = np.memmap(path, dtype=row_dtype, mode="r",
                        offset=_SLAB_HEADER.size, shape=(hi - lo + 1,))
        col = np.memmap(path, dtype=col_dtype, mode="r",
                        offset=_SLAB_HEADER.size + row_bytes, shape=(col_len,))
    else:
        with open(path, "rb") as fh:
            fh.seek(_SLAB_HEADER.size)
            row = np.frombuffer(fh.read(row_bytes), dtype=row_dtype)
            col = np.frombuffer(fh.read(col_bytes), dtype=col_dtype)
    if int(row[-1]) - int(row[0]) != col_len:
        raise CacheError(
            f"{path}: row-offset span {int(row[-1]) - int(row[0])} != "
            f"col payload {col_len}"
        )
    if verify:
        got = zlib.crc32(np.asarray(col).tobytes(),
                         zlib.crc32(np.asarray(row).tobytes()))
        if got != crc:
            raise CacheError(f"{path}: checksum mismatch (stored {crc:#x}, "
                             f"computed {got:#x}) — slab is corrupt, delete it")
    return CSRStripe(row, col, int(n_nodes), int(lo), int(hi),
                     int(k), int(n_stripes))


def load_tricsr_stripes(
    path: str | os.PathLike, n_stripes: int, *,
    mmap: bool = True, verify: bool = False,
) -> list[CSRStripe]:
    """Load all ``n_stripes`` slab views of cache ``path``, in order."""
    return [
        load_tricsr_stripe(stripe_path(path, k, n_stripes),
                           mmap=mmap, verify=verify)
        for k in range(n_stripes)
    ]


def assemble_stripes(stripes) -> CSRGraph:
    """Reassemble slab views into the full CSR (the losslessness oracle).

    Validates that the slabs tile ``[0, n)`` contiguously and agree on
    the global shape; the result is bit-identical to the unsharded cache
    the slabs were split from.
    """
    stripes = sorted(stripes, key=lambda s: int(s.stripe_index))
    if not stripes:
        raise ValueError("no stripes given")
    n = int(stripes[0].n_nodes)
    n_stripes = int(stripes[0].n_stripes)
    if len(stripes) != n_stripes:
        raise CacheError(
            f"have {len(stripes)} slabs of a {n_stripes}-stripe set"
        )
    lo = 0
    for s in stripes:
        if int(s.n_nodes) != n or int(s.n_stripes) != n_stripes:
            raise CacheError("slabs disagree on the global CSR shape")
        if int(s.node_lo) != lo:
            raise CacheError(
                f"slab {s.stripe_index} starts at node {s.node_lo}, "
                f"expected {lo} — slab set is not contiguous"
            )
        lo = int(s.node_hi)
    if lo != n:
        raise CacheError(f"slabs cover [0, {lo}) of {n} nodes")
    row = np.concatenate(
        [np.asarray(s.row_offsets[:-1]) for s in stripes]
        + [np.asarray(stripes[-1].row_offsets[-1:])]
    ).astype(np.int64)
    col = np.concatenate(
        [np.asarray(s.col) for s in stripes]
    ).astype(np.int32) if any(s.n_cols for s in stripes) else np.zeros(0, np.int32)
    if col.shape[0] != int(row[-1]):
        raise CacheError(
            f"assembled col has {col.shape[0]} entries, row offsets imply "
            f"{int(row[-1])}"
        )
    return CSRGraph(row, col, n)
