"""Named datasets: the paper's Table I graphs, with offline fallbacks.

Each :class:`Dataset` names an on-disk edge list by URL (SNAP / DIMACS10
mirrors), its published size and — where the literature has it — the
exact triangle count, which the launchers use as an oracle when counting
the real download.  Because CI runs offline, every entry also carries a
**deterministic fallback**: a seeded generator from
:mod:`repro.graphs.generators` of matching scale (Kronecker/R-MAT for the
power-law graphs) whose edge list is *written to disk and ingested
through the real parser/cache pipeline*, so the out-of-core path is
exercised even when no network exists.

Downloads never happen implicitly: ``materialize_dataset`` only fetches
when ``allow_download=True`` (the CLI flag ``--download``) or the
``REPRO_ALLOW_DOWNLOAD=1`` environment variable is set.  Checksums are
verified when pinned; unpinned downloads record a trust-on-first-use
``.sha256`` sidecar next to the source file and verify against it on any
re-download.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
from typing import Callable

import numpy as np

from ..generators import GRAPH_GENERATORS
from .cache import CSRGraph
from .ingest import IngestStats, ingest
from .parsers import DEFAULT_CHUNK_EDGES

__all__ = [
    "Dataset",
    "DATASETS",
    "get_dataset",
    "materialize_dataset",
    "resolve_to_csr",
    "karate_edges",
]


# Zachary's karate club (the classic 34-node, 78-edge, 45-triangle
# benchmark): bundled inline so ``--dataset karate`` works anywhere,
# and mirrored as the CI fixture tests/data/karate.txt.
_KARATE_EDGES = (
    (0, 1), (0, 2), (0, 3), (0, 4), (0, 5), (0, 6), (0, 7), (0, 8),
    (0, 10), (0, 11), (0, 12), (0, 13), (0, 17), (0, 19), (0, 21), (0, 31),
    (1, 2), (1, 3), (1, 7), (1, 13), (1, 17), (1, 19), (1, 21), (1, 30),
    (2, 3), (2, 7), (2, 8), (2, 9), (2, 13), (2, 27), (2, 28), (2, 32),
    (3, 7), (3, 12), (3, 13), (4, 6), (4, 10), (5, 6), (5, 10), (5, 16),
    (6, 16), (8, 30), (8, 32), (8, 33), (9, 33), (13, 33), (14, 32),
    (14, 33), (15, 32), (15, 33), (18, 32), (18, 33), (19, 33), (20, 32),
    (20, 33), (22, 32), (22, 33), (23, 25), (23, 27), (23, 29), (23, 32),
    (23, 33), (24, 25), (24, 27), (24, 31), (25, 31), (26, 29), (26, 33),
    (27, 33), (28, 31), (28, 33), (29, 32), (29, 33), (30, 32), (30, 33),
    (31, 32), (31, 33), (32, 33),
)


def karate_edges(**_ignored) -> np.ndarray:
    """The exact karate-club edge list (one direction per edge)."""
    return np.asarray(_KARATE_EDGES, dtype=np.int64)


@dataclasses.dataclass(frozen=True)
class Dataset:
    """One named graph: where to get it, what it should look like."""

    name: str
    description: str
    url: str | None                  # None = fallback-only (format we don't parse)
    sha256: str | None               # pinned checksum; None = trust-on-first-use
    fmt: str = "text"                # parser format of the downloaded file
    n_nodes: int | None = None       # published size, for post-ingest sanity
    n_edges: int | None = None       # published undirected edge count
    triangles: int | None = None     # published exact count (oracle), if known
    fallback: tuple[str, dict] | None = None  # (generator, kwargs) for offline


def _kron(scale: int, edge_factor: int = 16) -> tuple[str, dict]:
    return ("kronecker", dict(scale=scale, edge_factor=edge_factor, seed=1503))


_SNAP = "https://snap.stanford.edu/data"

DATASETS: dict[str, Dataset] = {
    d.name: d
    for d in [
        Dataset(
            name="karate",
            description="Zachary's karate club — 34 nodes, 78 edges, 45 triangles",
            url=None, sha256=None,
            n_nodes=34, n_edges=78, triangles=45,
            fallback=("karate", {}),
        ),
        Dataset(
            name="com-amazon",
            description="SNAP com-Amazon co-purchase network",
            url=f"{_SNAP}/bigdata/communities/com-amazon.ungraph.txt.gz",
            sha256=None, n_nodes=334_863, n_edges=925_872, triangles=667_129,
            fallback=_kron(16, 4),
        ),
        Dataset(
            name="com-dblp",
            description="SNAP com-DBLP collaboration network",
            url=f"{_SNAP}/bigdata/communities/com-dblp.ungraph.txt.gz",
            sha256=None, n_nodes=317_080, n_edges=1_049_866, triangles=2_224_385,
            fallback=_kron(16, 4),
        ),
        Dataset(
            name="com-youtube",
            description="SNAP com-Youtube social network",
            url=f"{_SNAP}/bigdata/communities/com-youtube.ungraph.txt.gz",
            sha256=None, n_nodes=1_134_890, n_edges=2_987_624, triangles=3_056_386,
            fallback=_kron(17, 4),
        ),
        Dataset(
            name="roadnet-ca",
            description="SNAP roadNet-CA — California road network (low skew)",
            url=f"{_SNAP}/roadNet-CA.txt.gz",
            sha256=None, n_nodes=1_965_206, n_edges=2_766_607, triangles=120_676,
            fallback=("watts_strogatz", dict(n=1 << 17, k=4, beta=0.05, seed=1503)),
        ),
        Dataset(
            name="soc-livejournal",
            description="SNAP soc-LiveJournal1 — the paper-scale 69M-edge graph",
            url=f"{_SNAP}/soc-LiveJournal1.txt.gz",
            sha256=None, n_nodes=4_847_571, n_edges=68_993_773,
            triangles=285_730_264,
            fallback=_kron(21, 16),
        ),
        Dataset(
            name="com-orkut",
            description="SNAP com-Orkut — 117M edges, 627M triangles",
            url=f"{_SNAP}/bigdata/communities/com-orkut.ungraph.txt.gz",
            sha256=None, n_nodes=3_072_441, n_edges=117_185_083,
            triangles=627_584_181,
            fallback=_kron(21, 28),
        ),
        Dataset(
            name="kron-logn21",
            description="DIMACS10 kron_g500-simple-logn21 — the paper's "
                        "89M-edge, 3.8B-triangle headline graph (Table I); "
                        "METIS source format, so offline Kronecker fallback only",
            url=None, sha256=None,
            n_nodes=1 << 21, n_edges=91_040_932, triangles=3_815_224_577,
            fallback=_kron(21, 43),
        ),
    ]
}


def get_dataset(name: str) -> Dataset:
    try:
        return DATASETS[name]
    except KeyError:
        raise KeyError(
            f"unknown dataset {name!r}; known: {sorted(DATASETS)}"
        ) from None


def _fallback_generator(spec: tuple[str, dict]) -> Callable[[], np.ndarray]:
    gen_name, kwargs = spec
    if gen_name == "karate":
        return karate_edges
    gen = GRAPH_GENERATORS[gen_name]
    return lambda: gen(**kwargs)


def _apply_scale(spec: tuple[str, dict], scale: int | None) -> tuple[str, dict]:
    """Shrink a fallback spec to ``2**scale`` nodes (CI sizing).

    Kronecker takes the scale directly; size-parameterized generators
    (watts_strogatz, barabasi_albert, erdos_renyi) get ``n`` capped at
    ``2**scale``.  The exact built-in graphs (karate) are already tiny
    and ignore it.
    """
    if scale is None:
        return spec
    name, kwargs = spec
    if name == "kronecker":
        return (name, {**kwargs, "scale": scale})
    if "n" in kwargs:
        shrunk = {**kwargs, "n": min(kwargs["n"], 1 << scale)}
        if "m" in kwargs:
            shrunk["m"] = min(kwargs["m"], 8 << scale)
        return (name, shrunk)
    return spec


def _write_fallback_edge_list(ds: Dataset, path: str, scale_override: int | None) -> None:
    """Generate the fallback graph and write it as a SNAP-style text file.

    The write is chunked (~64k lines per ''.join) so formatting a
    paper-scale fallback doesn't go through a per-row Python loop.
    """
    spec = ds.fallback
    if spec is None:
        raise RuntimeError(f"dataset {ds.name!r} has no offline fallback")
    spec = _apply_scale(spec, scale_override)
    edges = np.asarray(_fallback_generator(spec)())
    # one direction per undirected edge, the way SNAP ships its files
    one_dir = edges[edges[:, 0] < edges[:, 1]] if _is_canonical(edges) else edges
    tmp = path + ".tmp"
    with open(tmp, "w", encoding="ascii") as fh:
        fh.write(f"# {ds.name}: deterministic offline fallback "
                 f"({spec[0]} {spec[1]})\n")
        fh.write("# FromNodeId\tToNodeId\n")
        for s in range(0, one_dir.shape[0], 1 << 16):
            block = one_dir[s : s + (1 << 16)]
            fh.write("\n".join(f"{u}\t{v}" for u, v in block.tolist()))
            fh.write("\n")
    os.replace(tmp, path)


def _is_canonical(edges: np.ndarray) -> bool:
    """Heuristic: generators emit both directions; raw lists emit one."""
    if edges.shape[0] % 2 != 0 or edges.shape[0] == 0:
        return False
    return bool((edges[:, 0] < edges[:, 1]).sum() * 2 == edges.shape[0])


def _download(ds: Dataset, dest: str) -> None:
    import urllib.request

    tmp = dest + ".part"
    with urllib.request.urlopen(ds.url, timeout=120) as resp, open(tmp, "wb") as out:
        h = hashlib.sha256()
        while True:
            block = resp.read(1 << 20)
            if not block:
                break
            h.update(block)
            out.write(block)
    digest = h.hexdigest()
    sidecar = dest + ".sha256"
    pinned = ds.sha256
    if pinned is None and os.path.exists(sidecar):
        with open(sidecar) as fh:
            pinned = fh.read().strip() or None
    if pinned is not None and digest != pinned:
        os.unlink(tmp)
        raise RuntimeError(
            f"checksum mismatch for {ds.name}: got {digest}, expected {pinned}"
        )
    with open(sidecar, "w") as fh:
        fh.write(digest + "\n")
    os.replace(tmp, dest)


def resolve_to_csr(
    source: str,
    cache_dir: str | os.PathLike,
    *,
    max_chunk_edges: int = DEFAULT_CHUNK_EDGES,
    fallback_scale: int | None = None,
    allow_download: bool | None = None,
    mmap: bool = True,
    storage: str = "flat",
    order: str = "natural",
) -> tuple[CSRGraph, dict]:
    """Resolve a *source spec* — dataset name or file path — to a CSR.

    The serving layer's graph manager admits graphs by a single string:
    a registry dataset name goes through :func:`materialize_dataset`
    (download / offline fallback / ``.tricsr`` cache hit), anything else
    is treated as an on-disk edge list and goes through
    :func:`~repro.graphs.io.ingest.ingest`.  Returns ``(csr, info)``
    where ``info`` is a JSON-ready provenance dict (the shape the CLIs'
    ``--json`` reports already use: ``source``, ``ingest`` stats, and
    ``expected_triangles`` when the registry pins an oracle).
    """
    if source in DATASETS:
        csr, stats, ds = materialize_dataset(
            source, cache_dir, allow_download=allow_download,
            max_chunk_edges=max_chunk_edges, fallback_scale=fallback_scale,
            mmap=mmap, storage=storage, order=order,
        )
        real = stats.source_kind == "download" or ds.name == "karate"
        info = dict(
            source="dataset", dataset=ds.name, ingest=stats.as_dict(),
            expected_triangles=ds.triangles if real else None,
        )
        return csr, info
    csr, stats = ingest(
        source, cache_dir=cache_dir, max_chunk_edges=max_chunk_edges, mmap=mmap,
        storage=storage, order=order,
    )
    return csr, dict(
        source="input", path=os.fspath(source), ingest=stats.as_dict(),
        expected_triangles=None,
    )


def materialize_dataset(
    name: str,
    cache_dir: str | os.PathLike,
    *,
    allow_download: bool | None = None,
    max_chunk_edges: int = DEFAULT_CHUNK_EDGES,
    fallback_scale: int | None = None,
    mmap: bool = True,
    storage: str = "flat",
    order: str = "natural",
) -> tuple[CSRGraph, IngestStats, Dataset]:
    """Resolve ``name`` to a ready-to-count CSR through the cache.

    Resolution order: existing ``.tricsr`` cache → previously fetched (or
    generated) source file under ``cache_dir/sources/`` → network download
    (only when allowed) → deterministic offline fallback generator.
    ``fallback_scale`` shrinks a Kronecker fallback for CI
    (e.g. ``fallback_scale=10`` turns the 2²¹-node stand-in into 2¹⁰).
    """
    ds = get_dataset(name)
    cache_dir = os.path.expanduser(os.fspath(cache_dir))
    if allow_download and fallback_scale is not None:
        # contradictory request: a shrunk fallback is synthetic by
        # definition — never let it masquerade as the real download.
        # (The ambient REPRO_ALLOW_DOWNLOAD=1 env var is deliberately
        # weaker: with fallback_scale set it defers to the fallback, so a
        # CI matrix can export it once and still size stand-ins.)
        raise ValueError(
            "allow_download and fallback_scale are mutually exclusive: "
            "fallback_scale sizes the synthetic stand-in, downloads fetch "
            "the real graph"
        )
    if allow_download and ds.url is None:
        raise ValueError(
            f"dataset {ds.name!r} has no downloadable source "
            f"({ds.description.split(';')[0]}); drop the download request "
            "to use its deterministic fallback"
        )
    if allow_download is None:
        allow_download = os.environ.get("REPRO_ALLOW_DOWNLOAD", "") == "1"
    src_dir = os.path.join(cache_dir, "sources")
    os.makedirs(src_dir, exist_ok=True)

    real_src = (os.path.join(src_dir, os.path.basename(ds.url))
                if ds.url is not None else None)
    suffix = f"-s{fallback_scale}" if fallback_scale is not None else ""
    fb_src = os.path.join(src_dir, f"{ds.name}-fallback{suffix}.txt")

    if real_src is not None and os.path.exists(real_src) and fallback_scale is None:
        src, kind = real_src, "download"
    elif real_src is not None and allow_download and fallback_scale is None:
        # an explicit download request beats any stale offline fallback —
        # otherwise one offline run would pin the synthetic graph forever
        _download(ds, real_src)
        src, kind = real_src, "download"
    elif os.path.exists(fb_src):
        src, kind = fb_src, "fallback"
    else:
        _write_fallback_edge_list(ds, fb_src, fallback_scale)
        src, kind = fb_src, "fallback"

    csr, stats = ingest(
        src, cache_dir=cache_dir, max_chunk_edges=max_chunk_edges,
        fmt=ds.fmt, mmap=mmap, storage=storage, order=order,
    )
    stats.source_kind = kind
    if kind == "fallback" and ds.fallback is not None and ds.fallback[0] == "karate":
        # the only fallback with a known exact graph — enforce it
        assert csr.n_edges == 78, f"karate fallback produced {csr.n_edges} edges"
    return csr, stats, ds
