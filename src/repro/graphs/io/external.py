"""External-memory canonicalization of streamed edge chunks.

:func:`repro.graphs.canonicalize_edges` packs each undirected pair into a
64-bit key (``lo << 32 | hi`` — the paper's §III-D2 single-key sort
trick) and uniquifies; that requires the whole raw edge set in RAM.  This
module runs the *same* key pipeline chunk-by-chunk:

1. each raw ``(chunk, 2)`` block is cleaned (self loops dropped, ids
   validated) and reduced to a sorted array of unique keys;
2. when the in-memory key buffer exceeds the chunk budget, it is spilled
   to a temporary file as one sorted *run*;
3. the runs are k-way merged (block-buffered, vectorized) back into the
   globally sorted, globally deduplicated key array, which unpacks into a
   canonical edge array **bit-identical** to the in-memory path.

Peak memory is O(``max_chunk_edges``) during the run phase and
O(output + merge buffers) during the merge — the raw edge multiset never
has to fit, which is the property that matters for SNAP-scale inputs
where duplicates and both-direction entries inflate the raw file ~2×+
over the canonical edge set.
"""
from __future__ import annotations

import dataclasses
import os
import tempfile
from typing import Iterable, Iterator

import numpy as np

from ..formats import pack_unique_keys, unpack_keys_canonical

__all__ = ["canonicalize_edges_external", "ExternalSortStats", "merge_sorted_runs"]


@dataclasses.dataclass
class ExternalSortStats:
    """What the external canonicalization actually did (for tests/benchmarks)."""

    raw_edges: int = 0          # rows read from the parser, pre-clean
    kept_edges: int = 0         # Σ per-chunk unique keys (self loops dropped,
                                # deduped within each chunk, not globally)
    spill_runs: int = 0         # sorted runs written to disk (0 = in-memory)
    spilled_keys: int = 0       # total keys across spilled runs
    unique_edges: int = 0       # undirected edges after global dedup
    merge_passes: int = 0       # 1 when runs were merged, else 0


class _RunReader:
    """Block-buffered reader over one sorted int64 run file."""

    def __init__(self, path: str, block_keys: int):
        self._fh = open(path, "rb")
        self._block_bytes = block_keys * 8
        self.block = np.empty((0,), np.int64)
        self.exhausted = False
        self.refill()

    def refill(self) -> None:
        data = self._fh.read(self._block_bytes)
        if not data:
            self.block = np.empty((0,), np.int64)
            self.exhausted = True
            self._fh.close()
        else:
            self.block = np.frombuffer(data, dtype=np.int64)

    def take_upto(self, cut: np.int64) -> np.ndarray:
        """Consume and return the prefix of the current block ≤ ``cut``."""
        n = int(np.searchsorted(self.block, cut, side="right"))
        out = self.block[:n]
        self.block = self.block[n:]
        if self.block.size == 0 and not self.exhausted:
            out = out.copy()  # detach from the buffer we are about to drop
            self.refill()
        return out


def merge_sorted_runs(
    paths: list[str], *, block_keys: int = 1 << 20
) -> Iterator[np.ndarray]:
    """K-way merge of sorted-unique int64 run files, yielding sorted
    globally-unique blocks.

    Each yielded block holds every key ≤ the round's *cut* (the minimum
    over the runs' current block maxima): every run is sorted, so keys
    beyond a run's current block are ≥ its block maximum ≥ cut — nothing
    ≤ cut can appear later, making per-round dedup globally correct.
    """
    readers = [_RunReader(p, block_keys) for p in paths]
    readers = [r for r in readers if r.block.size]
    while readers:
        cut = min(np.int64(r.block[-1]) for r in readers)
        parts = [r.take_upto(cut) for r in readers]
        merged = np.unique(np.concatenate(parts))
        if merged.size:
            yield merged
        readers = [r for r in readers if r.block.size]


def canonicalize_edges_external(
    chunks: Iterable[np.ndarray],
    *,
    max_chunk_edges: int,
    spill_dir: str | os.PathLike | None = None,
    dtype=np.int32,
    stats_out: ExternalSortStats | None = None,
) -> np.ndarray:
    """Canonicalize a stream of raw edge blocks under a bounded key buffer.

    ``chunks`` yields raw ``(r, 2)`` integer blocks (any mix of
    directions, duplicates, self loops).  In-memory key buffers are
    spilled as sorted runs whenever they exceed ``max_chunk_edges`` keys;
    the runs are merged back into the canonical edge array — the same
    rows, in the same order, as ``canonicalize_edges`` on the
    concatenated input.  ``spill_dir`` (a private temp dir by default)
    holds the runs and is cleaned up afterwards.
    """
    if max_chunk_edges < 1:
        raise ValueError("max_chunk_edges must be positive")
    stats = stats_out if stats_out is not None else ExternalSortStats()

    own_tmp = None
    if spill_dir is None:
        own_tmp = tempfile.TemporaryDirectory(prefix="tricsr-runs-")
        spill_dir = own_tmp.name
    os.makedirs(spill_dir, exist_ok=True)

    run_paths: list[str] = []
    buffer: list[np.ndarray] = []
    buffered = 0

    def spill() -> None:
        nonlocal buffer, buffered
        if not buffered:
            return
        keys = np.unique(np.concatenate(buffer)) if len(buffer) > 1 else buffer[0]
        path = os.path.join(spill_dir, f"run-{len(run_paths):05d}.u64")
        keys.tofile(path)
        run_paths.append(path)
        stats.spill_runs += 1
        stats.spilled_keys += keys.size
        buffer, buffered = [], 0

    try:
        for chunk in chunks:
            chunk = np.asarray(chunk)
            stats.raw_edges += chunk.reshape(-1, 2).shape[0]
            keys = pack_unique_keys(chunk)
            stats.kept_edges += keys.size
            if keys.size == 0:
                continue
            buffer.append(keys)
            buffered += keys.size
            if buffered > max_chunk_edges:
                spill()

        if not run_paths:
            # everything fit: pure in-memory finish, no disk round-trip
            if not buffer:
                key = np.empty((0,), np.int64)
            else:
                key = np.unique(np.concatenate(buffer)) if len(buffer) > 1 else buffer[0]
            stats.unique_edges = key.size
            return unpack_keys_canonical(key, dtype)

        spill()  # flush the tail so the merge sees every key
        stats.merge_passes = 1
        block_keys = max(1024, max_chunk_edges // max(len(run_paths), 1))
        merged = list(merge_sorted_runs(run_paths, block_keys=block_keys))
        key = np.concatenate(merged) if merged else np.empty((0,), np.int64)
        stats.unique_edges = key.size
        return unpack_keys_canonical(key, dtype)
    finally:
        for p in run_paths:
            try:
                os.unlink(p)
            except OSError:
                pass
        if own_tmp is not None:
            own_tmp.cleanup()
