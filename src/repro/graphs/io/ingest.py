"""Ingest orchestrator: file → (cached) canonical CSR, bounded memory.

``ingest(path)`` is the one call the launchers use: it checks the
``.tricsr`` cache (keyed on source identity + format version), and on a
miss streams the file through the chunked parser and external
canonicalization, builds the undirected CSR, writes the cache, and
returns the loaded (memory-mapped) :class:`CSRGraph` plus an
:class:`IngestStats` record saying which of that actually happened.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import shutil
import tempfile
import time

import numpy as np

from repro import obs

from ..formats import csr_from_forward_pairs, edge_array_to_csr
from .cache import CSRGraph, CacheError, TRICSR_VERSION, load_tricsr, save_tricsr
from .codec import ORDERINGS, load_tricsrz, save_tricsrz
from .external import ExternalSortStats, canonicalize_edges_external
from .parsers import DEFAULT_CHUNK_EDGES, iter_edge_chunks

__all__ = [
    "ingest",
    "cache_path_for",
    "IngestStats",
    "csr_from_edge_array",
    "STORAGES",
]

STORAGES = ("flat", "compressed")


def _check_storage_order(storage: str, order: str) -> None:
    if storage not in STORAGES:
        raise ValueError(f"unknown storage {storage!r}; known: {STORAGES}")
    if order not in ORDERINGS:
        raise ValueError(f"unknown ordering {order!r}; known: {ORDERINGS}")
    if storage == "flat" and order != "natural":
        raise ValueError(
            "order != 'natural' requires storage='compressed' — the flat "
            ".tricsr has nowhere to record the inverse permutation, so "
            "per-node results could not be mapped back to original ids"
        )


@dataclasses.dataclass
class IngestStats:
    """Provenance of one :func:`ingest` call.

    ``cache_hit`` means the ``.tricsr`` was loaded and **no parsing
    happened at all** (``raw_edges == 0``); the CI smoke and the
    out-of-core oracle test key off this.
    """

    source: str
    cache_path: str | None
    cache_hit: bool
    source_kind: str = "file"   # "file" | "download" | "fallback" (set by registry)
    storage: str = "flat"       # "flat" (.tricsr) | "compressed" (.tricsrz)
    order: str = "natural"      # node ordering baked into the cache
    cache_bytes: int = 0        # on-disk size of the cache file (0 if uncached)
    raw_edges: int = 0
    unique_edges: int = 0
    spill_runs: int = 0
    parse_s: float = 0.0        # parse + canonicalize (0 on hit)
    csr_build_s: float = 0.0
    cache_write_s: float = 0.0
    load_s: float = 0.0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def cache_path_for(
    path: str | os.PathLike,
    cache_dir: str | os.PathLike,
    *,
    storage: str = "flat",
    order: str = "natural",
) -> str:
    """Cache file path for ``path``: name + source-identity digest.

    The digest covers absolute path, size, and mtime_ns (ccache-style
    sloppy identity — content hashing a multi-GB edge list would cost the
    parse we are trying to skip) plus the ``.tricsr`` format version
    **and the storage format / node ordering**: a degree-relabeled
    ``.tricsrz`` and a flat natural-order ``.tricsr`` of the same source
    are different artifacts and must never collide on one cache path, or
    a stale load would hand back the wrong ids.  Touching or replacing
    the source, or upgrading the format, misses cleanly instead of
    serving a stale CSR.
    """
    _check_storage_order(storage, order)
    src = os.path.abspath(os.fspath(path))
    st = os.stat(src)
    ident = (
        f"{src}\x00{st.st_size}\x00{st.st_mtime_ns}\x00v{TRICSR_VERSION}"
        f"\x00{storage}\x00{order}"
    )
    digest = hashlib.sha256(ident.encode()).hexdigest()[:16]
    stem = os.path.basename(src)
    for ext in (".gz", ".txt", ".mtx", ".el", ".edges", ".edgelist", ".tsv", ".csv", ".snap"):
        if stem.endswith(ext):
            stem = stem[: -len(ext)]
    suffix = ".tricsrz" if storage == "compressed" else ".tricsr"
    return os.path.join(os.fspath(cache_dir), f"{stem}-{digest}{suffix}")


def csr_from_edge_array(edges: np.ndarray) -> CSRGraph:
    """Undirected canonical CSR of a canonical edge array.

    Canonical arrays are a forward block (sorted by packed key) followed
    by its mirror, so the sort-free ``csr_from_forward_pairs`` build
    applies — no 2m-row lexsort, which matters at the SNAP scales this
    pipeline ingests.
    """
    edges = np.asarray(edges)
    n_nodes = int(edges.max()) + 1 if edges.size else 0
    m = edges.shape[0] // 2
    lo = edges[:m, 0].astype(np.int64)
    hi = edges[:m, 1].astype(np.int64)
    key = lo << np.int64(32) | hi
    if m == 0 or ((lo < hi).all() and (np.diff(key) > 0).all()):
        # forward half is sorted-unique (lo, hi) pairs — the layout both
        # canonicalization pipelines emit — which fully determines the
        # edge set; a canonical array in any other row order (still valid
        # per validate_edge_array) takes the general lexsort path below
        row, col = csr_from_forward_pairs(lo, hi, n_nodes)
    else:
        row, col = edge_array_to_csr(edges, n_nodes)
    return CSRGraph(np.asarray(row, np.int64), np.asarray(col, np.int32), n_nodes)


def ingest(
    path: str | os.PathLike,
    *,
    cache_dir: str | os.PathLike | None = None,
    max_chunk_edges: int = DEFAULT_CHUNK_EDGES,
    fmt: str | None = None,
    spill_dir: str | os.PathLike | None = None,
    mmap: bool = True,
    storage: str = "flat",
    order: str = "natural",
):
    """Load ``path`` as a canonical CSR, through the cache when possible.

    With ``cache_dir`` set, a valid cache for the current source identity
    short-circuits everything (``stats.cache_hit``); otherwise the file
    is parsed in ``max_chunk_edges`` blocks, canonicalized out-of-core
    (spilling sorted runs next to the cache, or ``spill_dir``), converted
    to CSR, and written back to the cache atomically.

    ``storage="flat"`` (default) returns a memory-mapped
    :class:`CSRGraph` off a ``.tricsr``; ``storage="compressed"`` writes
    a delta/varint ``.tricsrz`` relabeled by ``order``
    (natural/degree/bfs) and returns a
    :class:`~repro.graphs.io.CompressedCSR` whose neighbor blocks decode
    on demand — the engine accepts either directly.
    """
    _check_storage_order(storage, order)
    src = os.path.expanduser(os.fspath(path))
    if not os.path.isfile(src):
        raise FileNotFoundError(
            f"edge list not found: {src!r} (pass a SNAP-style text or "
            "MatrixMarket file, optionally .gz-compressed)"
        )
    compressed = storage == "compressed"
    if compressed and cache_dir is None:
        raise ValueError(
            "storage='compressed' requires a cache_dir: the .tricsrz file "
            "is the artifact the block-decoding CompressedCSR reads from"
        )
    load_cache = (
        (lambda p, verify=False: load_tricsrz(p, mmap=mmap, verify=verify))
        if compressed
        else (lambda p, verify=False: load_tricsr(p, mmap=mmap, verify=verify))
    )
    cache_path = None
    if cache_dir is not None:
        cache_dir = os.path.expanduser(os.fspath(cache_dir))
        os.makedirs(cache_dir, exist_ok=True)
        cache_path = cache_path_for(src, cache_dir, storage=storage, order=order)
        if os.path.exists(cache_path):
            t0 = time.perf_counter()
            try:
                with obs.span("ingest.cache_load", cat="io",
                              args={"path": os.path.basename(cache_path)}):
                    csr = load_cache(cache_path)
            except CacheError:
                pass  # stale/corrupt cache: fall through and rebuild
            else:
                obs.counter("io.tricsr_cache_hits").add()
                stats = IngestStats(source=src, cache_path=cache_path,
                                    cache_hit=True, storage=storage, order=order,
                                    cache_bytes=os.path.getsize(cache_path),
                                    load_s=time.perf_counter() - t0)
                stats.unique_edges = csr.n_edges
                return csr, stats
        obs.counter("io.tricsr_cache_misses").add()

    # Spill sorted runs onto real disk — next to the cache, else next to
    # the source file: the system temp dir is often RAM-backed tmpfs,
    # which would turn "out-of-core" runs back into host memory — the
    # failure this subsystem exists to avoid.  An explicit spill_dir
    # always wins; an unwritable location falls back to the system temp.
    own_spill = None
    if spill_dir is None:
        for parent in (cache_dir, os.path.dirname(src) or "."):
            if parent is None:
                continue
            try:
                own_spill = tempfile.mkdtemp(prefix="spill-", dir=parent)
            except OSError:
                continue
            spill_dir = own_spill
            break

    ext_stats = ExternalSortStats()
    t0 = time.perf_counter()
    try:
        with obs.span("ingest.parse", cat="io",
                      args={"path": os.path.basename(src)}):
            edges = canonicalize_edges_external(
                iter_edge_chunks(src, max_chunk_edges, fmt=fmt),
                max_chunk_edges=max_chunk_edges,
                spill_dir=spill_dir,
                stats_out=ext_stats,
            )
    finally:
        if own_spill is not None:
            shutil.rmtree(own_spill, ignore_errors=True)
    parse_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    with obs.span("ingest.csr_build", cat="io",
                  args={"edges": int(edges.shape[0])}):
        csr = csr_from_edge_array(edges)
    csr_build_s = time.perf_counter() - t0

    cache_write_s = 0.0
    cache_bytes = 0
    if cache_path is not None:
        t0 = time.perf_counter()
        with obs.span("ingest.cache_write", cat="io",
                      args={"storage": storage, "order": order}):
            if compressed:
                save_tricsrz(cache_path, csr, order=order)
            else:
                save_tricsr(cache_path, csr)
        cache_write_s = time.perf_counter() - t0
        cache_bytes = os.path.getsize(cache_path)
        # reload through the cache so callers hold the mmap, not the heap copy
        csr = load_cache(cache_path, verify=True)

    return csr, IngestStats(
        source=src,
        cache_path=cache_path,
        cache_hit=False,
        storage=storage,
        order=order,
        cache_bytes=cache_bytes,
        raw_edges=ext_stats.raw_edges,
        unique_edges=ext_stats.unique_edges,
        spill_runs=ext_stats.spill_runs,
        parse_s=parse_s,
        csr_build_s=csr_build_s,
        cache_write_s=cache_write_s,
    )
