"""The ``.tricsrz`` compressed, locality-ordered CSR cache format.

WebGraph's observation (Boldi & Vigna) applied to the ``.tricsr`` cache:
a canonical undirected CSR stores each row as a *sorted, strictly
increasing* neighbor list, so the list is fully determined by its gaps —
and after relabeling nodes for reference locality the gaps are small.
This module stores the ``col`` payload as per-node-range **neighbor
blocks** of delta + varint codes behind a block index, so consumers
decode individual node ranges on demand instead of memory-mapping a flat
4-byte-per-entry array.  The paper's §III-D argument that layout (not
FLOPs) dominates GPU triangle counting is the same argument in RAM: the
flat cache tops out where host memory does, the compressed cache does
not.

Per-row encoding (all values LEB128 varints, 7 payload bits per byte,
high bit = continuation):

* first neighbor — zigzag of ``col[0] - u`` (signed: a node's first
  neighbor may precede it),
* every later neighbor — ``gap - 1`` where ``gap = col[i] - col[i-1]``
  (gaps are >= 1 in a strictly increasing row, so the codes start at 0).

Rows of one block are concatenated into a single varint stream; the row
lengths needed to split the stream come from ``row_offsets``, which the
file stores as a varint *degree* stream (cumsummed at load — the flat
8-byte-per-node offsets would otherwise dominate the compressed size on
sparse graphs).

Orderings (recorded in the header, with the permutation in the file):

* ``natural`` — ingest order, no permutation stored,
* ``degree``  — degree-descending (stable): hubs get the small ids every
  row references, shrinking first-gaps on skewed graphs,
* ``bfs``     — breadth-first from the highest-degree node (unreached
  components seeded in degree order): neighbors land near each other,
  shrinking within-row gaps on meshes/roads.

The stored ``new_to_old`` permutation (``new_to_old[new_id] = old_id``)
is what maps per-node/support results computed on the relabeled graph
back to original ids — :meth:`CompressedCSR.map_per_node`.

File layout (little-endian)::

    offset  size  field
    0       8     magic  b"TRICSZ\\x01\\n"  (version byte inside the magic)
    8       8     n_nodes                     (u64)
    16      8     n_cols = total neighbors    (u64; 2 x undirected edges)
    24      1     ordering code (0 natural / 1 degree / 2 bfs)
    25      1     flags (bit 0: permutation present)
    26      2     reserved (zeros)
    28      4     nodes_per_block             (u32)
    32      8     n_blocks                    (u64)
    40      8     degree-stream bytes         (u64)
    48      8     payload bytes               (u64)
    56      4     crc32 of the meta region    (u32)
    60      4     crc32 of the payload        (u32)
    64      ...   meta region: degree varint stream, then new_to_old
                  (n x int32, iff flags bit 0), then the block index —
                  (n_blocks+1) x u64 payload byte offsets followed by
                  n_blocks x u32 per-block crc32s
    ...     ...   payload: concatenated per-block varint streams

The meta crc is checked on **every** load (it covers the block index, so
a bit flip there is caught before any offset is trusted); each block's
crc is checked on every :meth:`CompressedCSR.decode_block`.  Truncation
is caught by the exact file-size check.  ``verify=True`` additionally
pays one full payload read for the payload crc.
"""
from __future__ import annotations

import os
import struct
import zlib

import numpy as np

from repro.distributed.compression import ensure_fits_int32

from .cache import CSRGraph, CSRStripe, CacheError, plan_csr_stripes

__all__ = [
    "TRICSRZ_MAGIC",
    "TRICSRZ_VERSION",
    "ORDERINGS",
    "DEFAULT_NODES_PER_BLOCK",
    "encode_varints",
    "decode_varints",
    "order_permutation",
    "relabel_csr",
    "CompressedCSR",
    "save_tricsrz",
    "load_tricsrz",
    "csr_stripes_from_compressed",
    "load_tricsrz_stripe",
]

TRICSRZ_VERSION = 1
TRICSRZ_MAGIC = b"TRICSZ" + bytes([TRICSRZ_VERSION]) + b"\n"
# magic, n_nodes, n_cols, order code, flags, pad, nodes_per_block,
# n_blocks, degree-stream bytes, payload bytes, meta crc32, payload crc32
_HEADER = struct.Struct("<8sQQBB2xIQQQLL")
assert _HEADER.size == 64

ORDERINGS = ("natural", "degree", "bfs")
_ORDER_CODE = {name: i for i, name in enumerate(ORDERINGS)}
_FLAG_PERM = 1

DEFAULT_NODES_PER_BLOCK = 4096

# LEB128 on 64-bit values: at most ceil(64/7) = 10 bytes per code.  A
# longer run cannot come from this encoder — treat it as corruption.
_MAX_VARINT_BYTES = 10


# ---------------------------------------------------------------------------
# varint + zigzag primitives (vectorized; no per-value Python loop)
# ---------------------------------------------------------------------------


def _zigzag(x: np.ndarray) -> np.ndarray:
    """Map signed int64 to unsigned so small magnitudes get short varints."""
    x = np.asarray(x, dtype=np.int64)
    return ((x << 1) ^ (x >> 63)).astype(np.uint64)


def _unzigzag(z: np.ndarray) -> np.ndarray:
    z = np.asarray(z, dtype=np.uint64)
    return ((z >> np.uint64(1)).astype(np.int64)) ^ -(z & np.uint64(1)).astype(np.int64)


def encode_varints(values: np.ndarray) -> np.ndarray:
    """LEB128-encode a uint64 array into a flat uint8 stream.

    Vectorized: byte counts via repeated 7-bit shifts (<= 10 rounds),
    then one gather/shift/mask pass builds every output byte at once.
    """
    v = np.ascontiguousarray(values, dtype=np.uint64)
    if v.size == 0:
        return np.zeros(0, np.uint8)
    nbytes = np.ones(v.size, np.int64)
    t = v >> np.uint64(7)
    while t.any():
        nbytes += (t != 0)
        t >>= np.uint64(7)
    ends = np.cumsum(nbytes)
    starts = ends - nbytes
    total = int(ends[-1])
    pos = np.arange(total, dtype=np.int64) - np.repeat(starts, nbytes)
    chunks = (np.repeat(v, nbytes) >> (np.uint64(7) * pos.astype(np.uint64))) & np.uint64(0x7F)
    cont = pos < np.repeat(nbytes - 1, nbytes)
    return (chunks | (cont.astype(np.uint64) << np.uint64(7))).astype(np.uint8)


def decode_varints(buf: np.ndarray, count: int) -> np.ndarray:
    """Decode exactly ``count`` LEB128 codes consuming the whole buffer.

    Strictness is the corruption gate: a truncated stream (too few
    terminator bytes), trailing garbage, or an over-long code all raise
    :class:`~repro.graphs.io.CacheError` instead of decoding quietly.
    """
    b = np.ascontiguousarray(buf, dtype=np.uint8)
    count = int(count)
    if count == 0:
        if b.size:
            raise CacheError(f"varint stream has {b.size} trailing bytes after 0 codes")
        return np.zeros(0, np.uint64)
    is_last = (b & np.uint8(0x80)) == 0
    ends = np.flatnonzero(is_last)
    if ends.size != count or int(ends[-1]) != b.size - 1:
        raise CacheError(
            f"varint stream is corrupt: {ends.size} codes in {b.size} bytes, "
            f"expected exactly {count} consuming the whole stream"
        )
    starts = np.empty(count, np.int64)
    starts[0] = 0
    starts[1:] = ends[:-1] + 1
    nbytes = ends - starts + 1
    if int(nbytes.max()) > _MAX_VARINT_BYTES:
        raise CacheError(
            f"varint stream is corrupt: {int(nbytes.max())}-byte code exceeds "
            f"the {_MAX_VARINT_BYTES}-byte 64-bit limit"
        )
    pos = np.arange(b.size, dtype=np.int64) - np.repeat(starts, nbytes)
    contrib = (b & np.uint8(0x7F)).astype(np.uint64) << (np.uint64(7) * pos.astype(np.uint64))
    return np.add.reduceat(contrib, starts)


# ---------------------------------------------------------------------------
# per-block row codec
# ---------------------------------------------------------------------------


def _encode_rows(node_lo: int, lens: np.ndarray, col: np.ndarray) -> np.ndarray:
    """Delta-encode the concatenated neighbor lists of rows starting at
    ``node_lo`` (``lens[i]`` neighbors for node ``node_lo + i``) into one
    varint stream."""
    c = np.asarray(col, dtype=np.int64)
    lens = np.asarray(lens, dtype=np.int64)
    if c.size == 0:
        return np.zeros(0, np.uint8)
    nonempty = lens > 0
    starts = (np.cumsum(lens) - lens)[nonempty]
    d = np.empty(c.size, np.int64)
    d[0] = 1  # position 0 is always a row start; overwritten below
    d[1:] = c[1:] - c[:-1]
    vals = d - 1
    start_mask = np.zeros(c.size, bool)
    start_mask[starts] = True
    if vals[~start_mask].size and int(vals[~start_mask].min()) < 0:
        raise CacheError(
            "cannot compress: neighbor lists are not strictly increasing "
            "(the cache stores canonical sorted-unique rows)"
        )
    u = (node_lo + np.flatnonzero(nonempty)).astype(np.int64)
    first = _zigzag(c[starts] - u)
    vals = vals.astype(np.uint64)
    vals[starts] = first
    return encode_varints(vals)


def _decode_rows(node_lo: int, lens: np.ndarray, buf: np.ndarray) -> np.ndarray:
    """Inverse of :func:`_encode_rows`; returns int64 neighbors."""
    lens = np.asarray(lens, dtype=np.int64)
    total = int(lens.sum())
    vals = decode_varints(buf, total)
    if total == 0:
        return np.zeros(0, np.int64)
    nonempty = lens > 0
    starts = (np.cumsum(lens) - lens)[nonempty]
    u = (node_lo + np.flatnonzero(nonempty)).astype(np.int64)
    a = vals.astype(np.int64) + 1  # gap-1 codes back to gaps
    a[starts] = u + _unzigzag(vals[starts])  # absolute first neighbors
    c = np.cumsum(a)
    prev = np.zeros(starts.size, np.int64)
    prev[1:] = c[starts[1:] - 1]
    return c - np.repeat(prev, lens[nonempty])


# ---------------------------------------------------------------------------
# locality relabeling
# ---------------------------------------------------------------------------


def order_permutation(csr: CSRGraph, order: str) -> np.ndarray:
    """``new_to_old`` permutation for ``order`` (int64, len ``n_nodes``).

    ``degree`` is a stable degree-descending argsort; ``bfs`` runs a
    level-synchronous BFS from the highest-degree node, expanding each
    frontier in one vectorized gather and seeding unreached components
    in degree order — both deterministic.
    """
    if order not in ORDERINGS:
        raise ValueError(f"unknown ordering {order!r}; known: {ORDERINGS}")
    row = np.asarray(csr.row_offsets, dtype=np.int64)
    n = csr.n_nodes
    deg = np.diff(row)
    if order == "natural" or n == 0:
        return np.arange(n, dtype=np.int64)
    seeds = np.argsort(-deg, kind="stable").astype(np.int64)
    if order == "degree":
        return seeds
    col = np.asarray(csr.col, dtype=np.int64)
    visited = np.zeros(n, bool)
    out = np.empty(n, np.int64)
    written = 0
    for s in seeds:
        if visited[s]:
            continue
        visited[s] = True
        frontier = np.asarray([s], dtype=np.int64)
        while frontier.size:
            out[written : written + frontier.size] = frontier
            written += frontier.size
            lens = deg[frontier]
            total = int(lens.sum())
            if total == 0:
                break
            base = np.repeat(row[frontier], lens)
            local = np.arange(total, dtype=np.int64) - np.repeat(
                np.cumsum(lens) - lens, lens
            )
            nbrs = col[base + local]
            nbrs = nbrs[~visited[nbrs]]
            # order-preserving unique: keep each node's first encounter
            _, first_idx = np.unique(nbrs, return_index=True)
            frontier = nbrs[np.sort(first_idx)]
            visited[frontier] = True
    assert written == n, "BFS order did not cover every node"
    return out


def relabel_csr(csr: CSRGraph, new_to_old: np.ndarray) -> CSRGraph:
    """Apply a node permutation to an undirected canonical CSR.

    Rows are gathered in new-id order, neighbor ids mapped through the
    inverse permutation, and each row re-sorted — the result is again a
    canonical CSR (sorted strictly increasing rows), of the *same* graph
    up to node names, so triangle counts and truss spectra are invariant.
    """
    row = np.asarray(csr.row_offsets, dtype=np.int64)
    col = np.asarray(csr.col, dtype=np.int64)
    n = csr.n_nodes
    new_to_old = np.asarray(new_to_old, dtype=np.int64)
    if new_to_old.shape != (n,):
        raise ValueError(f"permutation has shape {new_to_old.shape}, graph has {n} nodes")
    old_to_new = np.empty(n, np.int64)
    old_to_new[new_to_old] = np.arange(n, dtype=np.int64)
    deg = np.diff(row)
    new_deg = deg[new_to_old]
    new_row = np.zeros(n + 1, np.int64)
    np.cumsum(new_deg, out=new_row[1:])
    total = col.size
    src_base = np.repeat(row[new_to_old], new_deg)
    local = np.arange(total, dtype=np.int64) - np.repeat(new_row[:-1], new_deg)
    new_col = old_to_new[col[src_base + local]]
    rid = np.repeat(np.arange(n, dtype=np.int64), new_deg)
    sorter = np.argsort(rid * np.int64(max(n, 1)) + new_col, kind="stable")
    ensure_fits_int32(max(n - 1, 0), "relabeled node ids (CSR col dtype)")
    return CSRGraph(new_row, new_col[sorter].astype(np.int32), n)


# ---------------------------------------------------------------------------
# the CompressedCSR handle
# ---------------------------------------------------------------------------


class CompressedCSR:
    """A loaded ``.tricsrz``: flat row offsets, block-decoded neighbors.

    Quacks enough like :class:`~repro.graphs.io.CSRGraph` for callers
    that only need shape/degree information (``n_nodes``, ``n_edges``,
    ``row_offsets``, ``degrees``, ``stats``), but deliberately has **no**
    ``col`` attribute — consumers that need neighbors must go through
    :meth:`decode_block` / :meth:`decode_node_range` (the engine's
    ``prepare_oriented`` does exactly that, one block at a time), or pay
    for the full decode explicitly with :meth:`to_csr`.
    """

    def __init__(
        self,
        path: str | None,
        n_nodes: int,
        row_offsets: np.ndarray,
        order: str,
        new_to_old: np.ndarray | None,
        block_offsets: np.ndarray,
        block_crcs: np.ndarray,
        payload: np.ndarray,
        nodes_per_block: int,
    ):
        self.path = path
        self.n_nodes = int(n_nodes)
        self.row_offsets = row_offsets
        self.order = order
        self.nodes_per_block = int(nodes_per_block)
        self._new_to_old = new_to_old
        self._old_to_new = None
        self._block_offsets = block_offsets
        self._block_crcs = block_crcs
        self._payload = payload

    # -- shape / bookkeeping -------------------------------------------------

    @property
    def n_cols(self) -> int:
        return int(self.row_offsets[-1])

    @property
    def n_edges(self) -> int:
        return self.n_cols // 2

    @property
    def n_blocks(self) -> int:
        return len(self._block_offsets) - 1

    def degrees(self) -> np.ndarray:
        return np.diff(self.row_offsets).astype(np.int64)

    def stats(self) -> dict:
        from ..formats import stats_from_degrees

        return stats_from_degrees(self.degrees(), self.n_nodes)

    def compressed_nbytes(self) -> int:
        """Bytes of the compressed neighbor payload alone."""
        return int(self._payload.shape[0])

    def resident_nbytes(self) -> int:
        """Actual host bytes this handle keeps resident: the materialized
        row offsets, permutation, and block index, plus the (possibly
        memory-mapped) compressed payload — **not** the decoded 4-byte-
        per-neighbor ``col`` this format exists to avoid."""
        total = int(self.row_offsets.nbytes) + int(self._payload.shape[0])
        total += int(self._block_offsets.nbytes) + int(self._block_crcs.nbytes)
        if self._new_to_old is not None:
            total += int(self._new_to_old.nbytes)
        if self._old_to_new is not None:
            total += int(self._old_to_new.nbytes)
        return total

    # -- id mapping ----------------------------------------------------------

    @property
    def new_to_old(self) -> np.ndarray:
        """``new_to_old[new_id] = old_id`` (identity for natural order)."""
        if self._new_to_old is None:
            self._new_to_old = np.arange(self.n_nodes, dtype=np.int64)
        return self._new_to_old

    @property
    def old_to_new(self) -> np.ndarray:
        if self._old_to_new is None:
            inv = np.empty(self.n_nodes, np.int64)
            inv[self.new_to_old] = np.arange(self.n_nodes, dtype=np.int64)
            self._old_to_new = inv
        return self._old_to_new

    def map_per_node(self, values: np.ndarray) -> np.ndarray:
        """Reindex a per-node result from relabeled ids to original ids:
        ``out[original_id] = values[relabeled_id]``."""
        values = np.asarray(values)
        if values.shape[0] != self.n_nodes:
            raise ValueError(
                f"per-node result has {values.shape[0]} entries, graph has "
                f"{self.n_nodes} nodes"
            )
        out = np.empty_like(values)
        out[self.new_to_old] = values
        return out

    # -- block decoding ------------------------------------------------------

    def block_node_range(self, k: int) -> tuple[int, int]:
        """Half-open node range ``[lo, hi)`` covered by block ``k``."""
        if not 0 <= k < self.n_blocks:
            raise IndexError(f"block {k} of {self.n_blocks}")
        lo = k * self.nodes_per_block
        return lo, min(self.n_nodes, lo + self.nodes_per_block)

    def decode_block(self, k: int) -> np.ndarray:
        """Decode block ``k``'s neighbors (int32), crc-checking the slice."""
        lo, hi = self.block_node_range(k)
        o0, o1 = int(self._block_offsets[k]), int(self._block_offsets[k + 1])
        seg = np.asarray(self._payload[o0:o1])
        if zlib.crc32(seg.tobytes()) != int(self._block_crcs[k]):
            raise CacheError(
                f"{self.path or '<tricsrz>'}: block {k} crc mismatch — "
                "payload is corrupt, delete the cache file"
            )
        lens = np.diff(self.row_offsets[lo : hi + 1])
        col = _decode_rows(lo, lens, seg)
        if col.size and not (0 <= int(col.min()) and int(col.max()) < self.n_nodes):
            raise CacheError(
                f"{self.path or '<tricsrz>'}: block {k} decoded neighbor ids "
                f"outside [0, {self.n_nodes}) — payload is corrupt"
            )
        ensure_fits_int32(max(self.n_nodes - 1, 0), "decoded neighbor ids (col dtype)")
        return col.astype(np.int32)

    def decode_node_range(self, lo: int, hi: int) -> np.ndarray:
        """Neighbors of rows ``[lo, hi)``, decoding only touched blocks."""
        if not 0 <= lo <= hi <= self.n_nodes:
            raise ValueError(f"node range [{lo}, {hi}) outside [0, {self.n_nodes})")
        if lo == hi:
            return np.zeros(0, np.int32)
        npb = self.nodes_per_block
        parts = []
        for k in range(lo // npb, (hi + npb - 1) // npb):
            blo, bhi = self.block_node_range(k)
            colb = self.decode_block(k)
            row = self.row_offsets
            s = int(row[max(lo, blo)] - row[blo])
            e = int(row[min(hi, bhi)] - row[blo])
            parts.append(colb[s:e])
        return np.concatenate(parts) if len(parts) > 1 else parts[0]

    # -- full-decode oracles -------------------------------------------------

    def to_csr(self) -> CSRGraph:
        """Decode everything into a flat :class:`CSRGraph` (relabeled ids).

        This is the losslessness oracle — bit-identical to the CSR that
        was compressed — not the serving path; it materializes the full
        4-byte-per-neighbor ``col`` the compressed format avoids.
        """
        cols = [self.decode_block(k) for k in range(self.n_blocks)]
        col = np.concatenate(cols) if cols else np.zeros(0, np.int32)
        return CSRGraph(np.asarray(self.row_offsets, np.int64), col, self.n_nodes)

    def edge_array(self, original_ids: bool = True) -> np.ndarray:
        """Canonical edge array; by default mapped back to original ids
        (the incremental counter bootstraps from this, so its stream of
        inserts/deletes keeps speaking the caller's node names)."""
        edges = self.to_csr().edge_array()
        if original_ids and self.order != "natural" and edges.size:
            edges = self.new_to_old[edges]
        return edges


# ---------------------------------------------------------------------------
# save / load
# ---------------------------------------------------------------------------


def save_tricsrz(
    path: str | os.PathLike,
    csr: CSRGraph,
    *,
    order: str = "degree",
    nodes_per_block: int = DEFAULT_NODES_PER_BLOCK,
) -> None:
    """Relabel ``csr`` by ``order``, compress, and atomically write ``path``."""
    if order not in ORDERINGS:
        raise ValueError(f"unknown ordering {order!r}; known: {ORDERINGS}")
    if nodes_per_block < 1 or nodes_per_block > 0xFFFFFFFF:
        raise ValueError(f"nodes_per_block {nodes_per_block} outside [1, 2^32)")
    n = csr.n_nodes
    if order != "natural":
        perm = order_permutation(csr, order)
        rl = relabel_csr(csr, perm)
    else:
        perm, rl = None, csr
    row = np.ascontiguousarray(rl.row_offsets, dtype=np.int64)
    col = np.ascontiguousarray(rl.col)
    if row.shape[0] != n + 1:
        raise ValueError(f"row_offsets has {row.shape[0]} entries for n_nodes={n}")
    deg_stream = encode_varints(np.diff(row).astype(np.uint64))
    n_blocks = (n + nodes_per_block - 1) // nodes_per_block
    chunks, offsets, crcs = [], [0], []
    for k in range(n_blocks):
        lo = k * nodes_per_block
        hi = min(n, lo + nodes_per_block)
        lens = np.diff(row[lo : hi + 1])
        chunk = _encode_rows(lo, lens, col[int(row[lo]) : int(row[hi])])
        chunks.append(chunk)
        offsets.append(offsets[-1] + chunk.shape[0])
        crcs.append(zlib.crc32(chunk.tobytes()))
    payload = b"".join(c.tobytes() for c in chunks)
    meta = deg_stream.tobytes()
    flags = 0
    if perm is not None:
        ensure_fits_int32(max(n - 1, 0), "permutation entries (int32 storage)")
        meta += perm.astype(np.int32).tobytes()
        flags |= _FLAG_PERM
    meta += np.asarray(offsets, np.uint64).tobytes()
    meta += np.asarray(crcs, np.uint32).tobytes()
    header = _HEADER.pack(
        TRICSRZ_MAGIC, n, col.shape[0], _ORDER_CODE[order], flags,
        nodes_per_block, n_blocks, len(deg_stream.tobytes()), len(payload),
        zlib.crc32(meta), zlib.crc32(payload),
    )
    tmp = os.fspath(path) + ".tmp"
    with open(tmp, "wb") as fh:
        fh.write(header)
        fh.write(meta)
        fh.write(payload)
        fh.flush()
        os.fsync(fh.fileno())
    os.replace(tmp, path)


def load_tricsrz(
    path: str | os.PathLike, *, mmap: bool = True, verify: bool = False
) -> CompressedCSR:
    """Load a ``.tricsrz``; the payload stays memory-mapped unless
    ``mmap=False``.  The meta region (degrees, permutation, block index)
    is always read and crc-checked — corruption there would misdirect
    every later block decode."""
    try:
        with open(path, "rb") as fh:
            raw = fh.read(_HEADER.size)
    except OSError as e:
        raise CacheError(f"cannot read {path}: {e}") from e
    if len(raw) < _HEADER.size:
        raise CacheError(f"{path}: truncated header ({len(raw)} bytes)")
    (magic, n_nodes, n_cols, order_code, flags, nodes_per_block, n_blocks,
     deg_bytes, payload_bytes, crc_meta, crc_payload) = _HEADER.unpack(raw)
    if magic[:6] != TRICSRZ_MAGIC[:6]:
        raise CacheError(f"{path}: not a .tricsrz file (bad magic {magic!r})")
    if magic != TRICSRZ_MAGIC:
        raise CacheError(
            f"{path}: version {magic[6]} != supported {TRICSRZ_VERSION}; "
            "re-ingest to refresh the cache"
        )
    if order_code >= len(ORDERINGS):
        raise CacheError(f"{path}: unknown ordering code {order_code}")
    order = ORDERINGS[order_code]
    has_perm = bool(flags & _FLAG_PERM)
    if nodes_per_block < 1:
        raise CacheError(f"{path}: nodes_per_block must be positive")
    expect_blocks = (n_nodes + nodes_per_block - 1) // nodes_per_block
    if n_blocks != expect_blocks:
        raise CacheError(
            f"{path}: {n_blocks} blocks inconsistent with {n_nodes} nodes "
            f"at {nodes_per_block} nodes/block (expected {expect_blocks})"
        )
    perm_bytes = n_nodes * 4 if has_perm else 0
    index_bytes = (n_blocks + 1) * 8 + n_blocks * 4
    meta_len = deg_bytes + perm_bytes + index_bytes
    expect = _HEADER.size + meta_len + payload_bytes
    actual = os.path.getsize(path)
    if actual != expect:
        raise CacheError(f"{path}: size {actual} != header-implied {expect}")
    with open(path, "rb") as fh:
        fh.seek(_HEADER.size)
        meta = fh.read(meta_len)
    if zlib.crc32(meta) != crc_meta:
        raise CacheError(
            f"{path}: meta-region checksum mismatch (degrees/permutation/"
            "block index) — cache is corrupt, delete it"
        )
    degrees = decode_varints(np.frombuffer(meta, np.uint8, count=deg_bytes), n_nodes)
    row = np.zeros(n_nodes + 1, np.int64)
    np.cumsum(degrees.astype(np.int64), out=row[1:])
    if int(row[-1]) != n_cols:
        raise CacheError(
            f"{path}: degree stream sums to {int(row[-1])}, header says {n_cols}"
        )
    off = deg_bytes
    perm = None
    if has_perm:
        perm = np.frombuffer(meta, np.int32, count=n_nodes, offset=off).astype(np.int64)
        off += perm_bytes
        if not np.array_equal(np.sort(perm), np.arange(n_nodes)):
            raise CacheError(f"{path}: stored permutation is not a permutation")
    block_offsets = np.frombuffer(meta, np.uint64, count=n_blocks + 1, offset=off)
    off += (n_blocks + 1) * 8
    block_crcs = np.frombuffer(meta, np.uint32, count=n_blocks, offset=off)
    if int(block_offsets[0]) != 0 or int(block_offsets[-1]) != payload_bytes or (
        np.diff(block_offsets.astype(np.int64)) < 0
    ).any():
        raise CacheError(f"{path}: block index offsets are inconsistent")
    if mmap and payload_bytes:
        payload = np.memmap(path, dtype=np.uint8, mode="r",
                            offset=_HEADER.size + meta_len, shape=(payload_bytes,))
    else:
        with open(path, "rb") as fh:
            fh.seek(_HEADER.size + meta_len)
            payload = np.frombuffer(fh.read(payload_bytes), np.uint8)
    z = CompressedCSR(os.fspath(path), n_nodes, row, order, perm,
                      block_offsets, block_crcs, payload, nodes_per_block)
    if verify:
        if zlib.crc32(np.asarray(payload).tobytes()) != crc_payload:
            raise CacheError(
                f"{path}: payload checksum mismatch — cache is corrupt, delete it"
            )
        z.to_csr()  # every block decodes cleanly and in-bounds
    return z


# ---------------------------------------------------------------------------
# slab views: the block index doubles as the stripe mechanism
# ---------------------------------------------------------------------------


def csr_stripes_from_compressed(z: CompressedCSR, n_stripes: int) -> list[CSRStripe]:
    """Split a compressed graph into §III-E slab views (decoded per range).

    Same col-count-balanced planning as the flat ``.tricsr.stripe{k}of{N}``
    files, but no sharded files are needed: each stripe decodes only the
    blocks overlapping its node range, so peak host memory per device is
    its own slab plus at most one straddling block — the compressed
    analogue of "each device memmaps only its slab".  The returned
    :class:`CSRStripe` views feed ``oriented_csr_from_slabs`` /
    ``count_triangles_distributed_slabs`` unchanged.
    """
    row = np.asarray(z.row_offsets, dtype=np.int64)
    return [
        CSRStripe(row[lo : hi + 1], z.decode_node_range(lo, hi),
                  z.n_nodes, lo, hi, k, n_stripes)
        for k, (lo, hi) in enumerate(plan_csr_stripes(row, n_stripes))
    ]


def load_tricsrz_stripe(
    path: str | os.PathLike, k: int, n_stripes: int, *, mmap: bool = True
) -> CSRStripe:
    """Load stripe ``k`` of ``n_stripes`` straight from one ``.tricsrz``.

    The flat slab path writes N sharded files; here the block index *is*
    the shard mechanism — every device opens the same compressed file
    (mmap'd, so only touched pages fault in) and decodes its own node
    range.
    """
    z = load_tricsrz(path, mmap=mmap)
    bounds = plan_csr_stripes(z.row_offsets, n_stripes)
    if not 0 <= k < n_stripes:
        raise ValueError(f"stripe {k} of {n_stripes}")
    lo, hi = bounds[k]
    row = np.asarray(z.row_offsets, dtype=np.int64)
    return CSRStripe(row[lo : hi + 1], z.decode_node_range(lo, hi),
                     z.n_nodes, lo, hi, k, n_stripes)
