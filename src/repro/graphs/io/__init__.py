"""Out-of-core graph ingestion: parsers, external canonicalization, CSR cache.

The paper's evaluation graphs (Table I) are on-disk SNAP edge lists far
larger than the raw-edge working set :func:`repro.graphs.canonicalize_edges`
assumes fits in RAM.  This package provides the bounded-memory path from a
file to the engine:

``parsers``
    Chunked streaming parsers for SNAP-style text edge lists (comments,
    whitespace/tab separators, optional gzip) and MatrixMarket coordinate
    files.  Peak host memory is bounded by ``max_chunk_edges``.
``external``
    External-memory canonicalization: per-chunk packed-key dedup (the
    §III-D2 64-bit sort trick), sorted runs spilled to disk, k-way merge
    back into the canonical edge array.
``cache``
    The versioned ``.tricsr`` binary CSR cache — parse/canonicalize once,
    memory-map on every later load — plus per-stripe slab views
    (``.tricsr.stripe{k}of{N}``) so each device of a §III-E mesh memmaps
    only its node-range slab.
``codec``
    The compressed ``.tricsrz`` variant: delta + varint neighbor blocks
    behind a block index (decode individual node ranges on demand), with
    degree-descending / BFS locality relabeling recorded in the header so
    per-node results map back through the inverse permutation.
``registry``
    Named datasets (the paper's Table I graphs) with URLs, checksums and
    deterministic Kronecker/R-MAT fallbacks of matching scale for offline
    CI.
``ingest``
    The orchestrator tying the above together behind one call.
"""
from .parsers import (
    iter_edge_chunks,
    parse_edge_file,
    sniff_format,
    DEFAULT_CHUNK_EDGES,
)
from .external import canonicalize_edges_external, ExternalSortStats
from .cache import (
    CSRGraph,
    CSRStripe,
    save_tricsr,
    load_tricsr,
    plan_csr_stripes,
    stripe_path,
    save_tricsr_stripes,
    load_tricsr_stripe,
    load_tricsr_stripes,
    assemble_stripes,
    TRICSR_MAGIC,
    TRICSR_VERSION,
    TRISLB_MAGIC,
    CacheError,
)
from .codec import (
    CompressedCSR,
    ORDERINGS,
    TRICSRZ_MAGIC,
    TRICSRZ_VERSION,
    csr_stripes_from_compressed,
    load_tricsrz,
    load_tricsrz_stripe,
    order_permutation,
    relabel_csr,
    save_tricsrz,
)
from .ingest import ingest, cache_path_for, IngestStats, STORAGES
from .registry import (
    Dataset,
    DATASETS,
    get_dataset,
    materialize_dataset,
    resolve_to_csr,
)

__all__ = [
    "iter_edge_chunks",
    "parse_edge_file",
    "sniff_format",
    "DEFAULT_CHUNK_EDGES",
    "canonicalize_edges_external",
    "ExternalSortStats",
    "CSRGraph",
    "CSRStripe",
    "save_tricsr",
    "load_tricsr",
    "plan_csr_stripes",
    "stripe_path",
    "save_tricsr_stripes",
    "load_tricsr_stripe",
    "load_tricsr_stripes",
    "assemble_stripes",
    "TRICSR_MAGIC",
    "TRICSR_VERSION",
    "TRISLB_MAGIC",
    "CacheError",
    "CompressedCSR",
    "ORDERINGS",
    "TRICSRZ_MAGIC",
    "TRICSRZ_VERSION",
    "csr_stripes_from_compressed",
    "load_tricsrz",
    "load_tricsrz_stripe",
    "order_permutation",
    "relabel_csr",
    "save_tricsrz",
    "ingest",
    "cache_path_for",
    "IngestStats",
    "STORAGES",
    "Dataset",
    "DATASETS",
    "get_dataset",
    "materialize_dataset",
    "resolve_to_csr",
]
