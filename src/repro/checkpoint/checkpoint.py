"""Sharded npz checkpoints with manifests, async save, resume, resharding.

Layout::

    <dir>/step_000123/
        manifest.json     # format_version, step, tree paths, shapes, dtypes,
                          # crc32 per leaf
        arrays.npz        # one entry per leaf, key = flattened tree path
        COMMIT            # written last; a checkpoint without it is torn

Fault-tolerance contract (the ``.tricsr`` cache's durability bar, which
the serving layer's snapshot/restore path now depends on):

* The manifest carries ``format_version``; a version mismatch (or a
  manifest written before versioning existed) is treated exactly like
  corruption — skipped, never half-read.
* Every leaf is integrity-checked on restore: shape, dtype **and**
  crc32 of the raw bytes must match the manifest.
* ``save`` stages into ``step_X.tmp`` and publishes by rename.
  Overwriting an existing step moves the old directory aside *before*
  the rename and removes it only after the new one is in place — there
  is never a window in which a crash leaves neither (the seed deleted
  the old checkpoint first, so a crash between the delete and the
  rename lost both).
* ``restore_latest`` walks checkpoints newest-first, validating the
  COMMIT marker and the full manifest, and falls back to the previous
  one on any torn/truncated/corrupted/mis-versioned candidate.
* arrays are stored **unsharded** (gathered); ``restore`` takes an
  optional ``shardings`` pytree and ``device_put``s each leaf — restoring
  onto a *different* mesh shape (elastic restart) is therefore free.
* ``CheckpointManager(async_save=True)`` snapshots to host memory
  synchronously and writes in a background thread (double-buffered, one
  in-flight save).  ``save``/``wait`` are thread-safe, background
  errors surface on the next ``save()`` *or* ``wait()``, and the
  retention GC only ever prunes **committed** checkpoints other than
  the one currently in flight — a torn directory from a crashed writer
  (or another process mid-publish) is never counted toward ``keep`` and
  never deleted out from under an in-flight rename.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any

import jax
import numpy as np

__all__ = [
    "FORMAT_VERSION",
    "save_checkpoint",
    "restore_checkpoint",
    "restore_latest",
    "list_checkpoints",
    "CheckpointManager",
]

# bumped from the (implicit, unversioned) seed format: manifests now
# declare themselves, so a future layout change invalidates old
# checkpoints loudly instead of misreading them
FORMAT_VERSION = 2


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(directory: str, step: int, tree: Any, extra: dict | None = None) -> str:
    """Write checkpoint synchronously; returns the final path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    manifest = {
        "format_version": FORMAT_VERSION,
        "step": step,
        "extra": extra or {},
        "leaves": {
            k: {
                "shape": list(v.shape),
                "dtype": str(v.dtype),
                "crc32": zlib.crc32(np.ascontiguousarray(v).tobytes()),
            }
            for k, v in flat.items()
        },
    }
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, "COMMIT"), "w") as f:
        f.write("ok")
    # publish: the old step (if any) moves aside before the rename and is
    # removed only after the new directory holds the name, so at every
    # instant at least one committed copy of this step exists on disk
    old = None
    if os.path.exists(final):
        old = final + ".old"
        if os.path.exists(old):
            shutil.rmtree(old)
        os.rename(final, old)
    os.rename(tmp, final)
    if old is not None:
        shutil.rmtree(old, ignore_errors=True)
    return final


def list_checkpoints(directory: str) -> list[tuple[int, str]]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith((".tmp", ".old")):
            try:
                out.append((int(name[5:]), os.path.join(directory, name)))
            except ValueError:
                continue
    return sorted(out)


def _validate(path: str) -> dict | None:
    """The manifest if ``path`` is a complete, uncorrupted checkpoint."""
    if not os.path.exists(os.path.join(path, "COMMIT")):
        return None
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        if manifest.get("format_version") != FORMAT_VERSION:
            return None
        with np.load(os.path.join(path, "arrays.npz")) as z:
            for key, meta in manifest["leaves"].items():
                arr = z[key]
                if list(arr.shape) != meta["shape"]:
                    return None
                if str(arr.dtype) != meta["dtype"]:
                    return None
                if zlib.crc32(np.ascontiguousarray(arr).tobytes()) != meta["crc32"]:
                    return None
        return manifest
    except Exception:
        # truncated npz, unreadable json, missing leaf — all torn
        return None


def restore_checkpoint(path: str, target: Any, shardings: Any | None = None):
    """Restore into the structure of ``target`` (shapes may re-shard)."""
    manifest = _validate(path)
    if manifest is None:
        raise ValueError(f"checkpoint at {path} is torn or corrupted")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat_target = _flatten(target)
        restored = {}
        for key in flat_target:
            if key not in z:
                raise KeyError(f"leaf {key} missing from checkpoint")
            restored[key] = z[key]
    leaves_t, treedef = jax.tree_util.tree_flatten(target)
    keys = list(_flatten(target).keys())
    new_leaves = [restored[k].astype(np.asarray(l).dtype) for k, l in zip(keys, leaves_t)]
    tree = jax.tree_util.tree_unflatten(treedef, new_leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree, manifest["step"], manifest["extra"]


def restore_latest(directory: str, target: Any, shardings: Any | None = None):
    """Newest valid checkpoint, falling back past torn/corrupted ones."""
    for step, path in reversed(list_checkpoints(directory)):
        if _validate(path) is not None:
            return restore_checkpoint(path, target, shardings)
    return None


class CheckpointManager:
    """Rolling checkpoints with optional async (background-thread) save.

    Thread-safe: concurrent ``save``/``wait`` calls serialize on an
    internal lock (at most one in-flight background write), and the
    retention GC prunes only *committed* checkpoints, never the one the
    in-flight thread is still publishing.
    """

    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        if keep < 1:
            raise ValueError("keep must be >= 1")
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._lock = threading.RLock()
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None
        self._inflight_step: int | None = None

    def save(self, step: int, tree: Any, extra: dict | None = None) -> None:
        with self._lock:
            self.wait()  # one in-flight save max; raises a pending error
            # Snapshot to host synchronously — device buffers may mutate
            # next step.
            host_tree = jax.tree.map(lambda x: np.array(x), tree)
            self._inflight_step = step

            def _do():
                try:
                    save_checkpoint(self.directory, step, host_tree, extra)
                    self._gc(protect=step)
                except Exception as e:  # surfaced on next save()/wait()
                    self._error = e

            if self.async_save:
                self._thread = threading.Thread(target=_do, daemon=True)
                self._thread.start()
            else:
                _do()
                self._inflight_step = None
                if self._error is not None:
                    err, self._error = self._error, None
                    raise err

    def wait(self) -> None:
        """Join any in-flight save; raises its error here if it failed."""
        with self._lock:
            if self._thread is not None:
                self._thread.join()
                self._thread = None
                self._inflight_step = None
            if self._error is not None:
                err, self._error = self._error, None
                raise err

    def _gc(self, protect: int | None = None) -> None:
        # only COMMITted checkpoints count toward (or are pruned by) the
        # retention budget: a torn dir from a crashed writer — or another
        # process mid-publish — is neither trusted nor deleted
        committed = [
            (step, path)
            for step, path in list_checkpoints(self.directory)
            if step != protect and step != self._inflight_step
            and os.path.exists(os.path.join(path, "COMMIT"))
        ]
        survivors = self.keep - (1 if protect is not None else 0)
        doomed = committed[:-survivors] if survivors > 0 else committed
        for _, path in doomed:
            shutil.rmtree(path, ignore_errors=True)

    def restore_latest(self, target: Any, shardings: Any | None = None):
        self.wait()
        return restore_latest(self.directory, target, shardings)
