"""Sharded npz checkpoints with manifests, async save, resume, resharding.

Layout::

    <dir>/step_000123/
        manifest.json     # step, tree paths, shapes, dtypes, crc32 per leaf
        arrays.npz        # one entry per leaf, key = flattened tree path
        COMMIT            # written last; a checkpoint without it is torn

Fault-tolerance contract:

* ``save`` writes into ``step_X.tmp`` and atomically renames, then drops a
  ``COMMIT`` marker — a crash mid-save can never shadow an older valid
  checkpoint.
* ``restore_latest`` walks checkpoints newest-first, validating the COMMIT
  marker and per-leaf CRCs, and falls back to the previous one on
  corruption.
* arrays are stored **unsharded** (gathered); ``restore`` takes an
  optional ``shardings`` pytree and ``device_put``s each leaf — restoring
  onto a *different* mesh shape (elastic restart) is therefore free.
* ``CheckpointManager(async_save=True)`` snapshots to host memory
  synchronously and writes in a background thread (double-buffered, one
  in-flight save).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any

import jax
import numpy as np

__all__ = [
    "save_checkpoint",
    "restore_checkpoint",
    "restore_latest",
    "list_checkpoints",
    "CheckpointManager",
]


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _tree_def(tree):
    return jax.tree_util.tree_structure(tree)


def save_checkpoint(directory: str, step: int, tree: Any, extra: dict | None = None) -> str:
    """Write checkpoint synchronously; returns the final path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    manifest = {
        "step": step,
        "extra": extra or {},
        "leaves": {
            k: {
                "shape": list(v.shape),
                "dtype": str(v.dtype),
                "crc32": zlib.crc32(np.ascontiguousarray(v).tobytes()),
            }
            for k, v in flat.items()
        },
    }
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, "COMMIT"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def list_checkpoints(directory: str) -> list[tuple[int, str]]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                out.append((int(name[5:]), os.path.join(directory, name)))
            except ValueError:
                continue
    return sorted(out)


def _validate(path: str) -> dict | None:
    if not os.path.exists(os.path.join(path, "COMMIT")):
        return None
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(path, "arrays.npz")) as z:
            for key, meta in manifest["leaves"].items():
                arr = z[key]
                if list(arr.shape) != meta["shape"]:
                    return None
                if zlib.crc32(np.ascontiguousarray(arr).tobytes()) != meta["crc32"]:
                    return None
        return manifest
    except Exception:
        return None


def restore_checkpoint(path: str, target: Any, shardings: Any | None = None):
    """Restore into the structure of ``target`` (shapes may re-shard)."""
    manifest = _validate(path)
    if manifest is None:
        raise ValueError(f"checkpoint at {path} is torn or corrupted")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat_target = _flatten(target)
        restored = {}
        for key in flat_target:
            if key not in z:
                raise KeyError(f"leaf {key} missing from checkpoint")
            restored[key] = z[key]
    leaves_t, treedef = jax.tree_util.tree_flatten(target)
    keys = list(_flatten(target).keys())
    new_leaves = [restored[k].astype(np.asarray(l).dtype) for k, l in zip(keys, leaves_t)]
    tree = jax.tree_util.tree_unflatten(treedef, new_leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree, manifest["step"], manifest["extra"]


def restore_latest(directory: str, target: Any, shardings: Any | None = None):
    """Newest valid checkpoint, falling back past torn/corrupted ones."""
    for step, path in reversed(list_checkpoints(directory)):
        if _validate(path) is not None:
            return restore_checkpoint(path, target, shardings)
    return None


class CheckpointManager:
    """Rolling checkpoints with optional async (background-thread) save."""

    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    def save(self, step: int, tree: Any, extra: dict | None = None) -> None:
        self.wait()  # one in-flight save max
        if self._error is not None:
            err, self._error = self._error, None
            raise err
        # Snapshot to host synchronously — device buffers may mutate next step.
        host_tree = jax.tree.map(lambda x: np.array(x), tree)

        def _do():
            try:
                save_checkpoint(self.directory, step, host_tree, extra)
                self._gc()
            except Exception as e:  # surfaced on next save()/wait()
                self._error = e

        if self.async_save:
            self._thread = threading.Thread(target=_do, daemon=True)
            self._thread.start()
        else:
            _do()
            if self._error is not None:
                err, self._error = self._error, None
                raise err

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        ckpts = list_checkpoints(self.directory)
        for _, path in ckpts[: -self.keep]:
            shutil.rmtree(path, ignore_errors=True)

    def restore_latest(self, target: Any, shardings: Any | None = None):
        self.wait()
        return restore_latest(self.directory, target, shardings)
