"""Fault-tolerant checkpointing."""
from .checkpoint import (
    FORMAT_VERSION,
    CheckpointManager,
    save_checkpoint,
    restore_checkpoint,
    restore_latest,
    list_checkpoints,
)

__all__ = [
    "FORMAT_VERSION",
    "CheckpointManager",
    "save_checkpoint",
    "restore_checkpoint",
    "restore_latest",
    "list_checkpoints",
]
