"""Fault-tolerant checkpointing."""
from .checkpoint import (
    CheckpointManager,
    save_checkpoint,
    restore_checkpoint,
    restore_latest,
    list_checkpoints,
)

__all__ = [
    "CheckpointManager",
    "save_checkpoint",
    "restore_checkpoint",
    "restore_latest",
    "list_checkpoints",
]
