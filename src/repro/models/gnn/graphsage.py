"""GraphSAGE (Hamilton et al., arXiv:1706.02216), mean aggregator.

Two entry points:
* :func:`apply` — full-graph layout (edge-index message passing),
* :func:`apply_blocks` — layered minibatch layout fed by the fanout
  sampler in :mod:`repro.graphs.sampling` (the ``minibatch_lg`` shape).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import edge_mask, gather_src, scatter_mean

__all__ = ["SAGEConfig", "init_params", "apply", "apply_blocks"]


@dataclasses.dataclass(frozen=True)
class SAGEConfig:
    name: str = "graphsage-reddit"
    n_layers: int = 2
    d_hidden: int = 128
    d_in: int = 602
    d_out: int = 41
    sample_sizes: tuple[int, ...] = (25, 10)
    dtype: object = jnp.float32


def init_params(key: jax.Array, cfg: SAGEConfig) -> dict:
    sizes = [cfg.d_in] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.d_out]
    layers = []
    for a, b in zip(sizes[:-1], sizes[1:]):
        key, k1, k2 = jax.random.split(key, 3)
        layers.append(
            {
                "w_self": jax.random.normal(k1, (a, b), jnp.float32) * a ** -0.5,
                "w_neigh": jax.random.normal(k2, (a, b), jnp.float32) * a ** -0.5,
                "b": jnp.zeros((b,), jnp.float32),
            }
        )
    return {"layers": layers}


def _combine(layer, h_self, h_neigh, final: bool):
    out = (
        h_self @ layer["w_self"].astype(h_self.dtype)
        + h_neigh @ layer["w_neigh"].astype(h_self.dtype)
        + layer["b"].astype(h_self.dtype)
    )
    return out if final else jax.nn.relu(out)


def apply(
    params: dict,
    cfg: SAGEConfig,
    node_feat: jax.Array,
    positions=None,
    edge_src: jax.Array = None,
    edge_dst: jax.Array = None,
) -> jax.Array:
    n = node_feat.shape[0]
    mask = edge_mask(edge_src, edge_dst)
    x = node_feat.astype(cfg.dtype)
    for i, layer in enumerate(params["layers"]):
        h_neigh = scatter_mean(gather_src(x, edge_src), edge_dst, n, mask)
        x = _combine(layer, x, h_neigh, i == len(params["layers"]) - 1)
    return x


def apply_blocks(params: dict, cfg: SAGEConfig, frontier_feats: list, fanouts) -> jax.Array:
    """Layered minibatch forward.

    ``frontier_feats[l]`` holds features of sampler frontier ``l``
    (seeds first); len == n_layers + 1.  Aggregation runs deepest-first.
    """
    feats = [f.astype(cfg.dtype) for f in frontier_feats]
    n_layers = len(params["layers"])
    # h[l] starts as raw features of frontier l; each GNN layer collapses
    # the deepest remaining frontier into its parent.
    h = list(feats)
    for li, layer in enumerate(params["layers"]):
        new_h = []
        for depth in range(len(h) - 1):
            parent = h[depth]
            child = h[depth + 1].reshape(parent.shape[0], fanouts[depth], -1)
            h_neigh = jnp.mean(child, axis=1)
            new_h.append(_combine(layer, parent, h_neigh, li == n_layers - 1))
        h = new_h
    return h[0]
