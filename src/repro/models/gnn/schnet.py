"""SchNet (Schütt et al., arXiv:1706.08566): continuous-filter convolutions.

Messages are ``h_j ⊙ W(r_ij)`` where the filter ``W`` is an MLP over a
radial-basis expansion of the interatomic distance — the triplet-free
"molecular" regime of the kernel taxonomy.  Non-molecular shapes synthesize
positions (see DESIGN.md §4); the geometry path is identical.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import edge_mask, gather_src, mlp_apply, mlp_init, scatter_sum

__all__ = ["SchNetConfig", "init_params", "apply"]


def _ssp(x):  # shifted softplus, SchNet's activation
    return jax.nn.softplus(x) - jnp.log(2.0)


@dataclasses.dataclass(frozen=True)
class SchNetConfig:
    name: str = "schnet"
    n_interactions: int = 3
    d_hidden: int = 64
    n_rbf: int = 300
    cutoff: float = 10.0
    d_in: int = 16
    d_out: int = 1
    dtype: object = jnp.float32


def init_params(key: jax.Array, cfg: SchNetConfig) -> dict:
    d = cfg.d_hidden
    key, k_embed = jax.random.split(key)
    params = {
        "embed": jax.random.normal(k_embed, (cfg.d_in, d), jnp.float32) * cfg.d_in ** -0.5,
        "interactions": [],
    }
    for _ in range(cfg.n_interactions):
        key, k1, k2, k3 = jax.random.split(key, 4)
        params["interactions"].append(
            {
                "filter": mlp_init(k1, [cfg.n_rbf, d, d]),
                "in_proj": mlp_init(k2, [d, d]),
                "out_mlp": mlp_init(k3, [d, d, d]),
            }
        )
    key, k_out = jax.random.split(key)
    params["readout"] = mlp_init(k_out, [d, d // 2, cfg.d_out])
    return params


def apply(
    params: dict,
    cfg: SchNetConfig,
    node_feat: jax.Array,     # (N, d_in)
    positions: jax.Array,     # (N, 3)
    edge_src: jax.Array = None,
    edge_dst: jax.Array = None,
) -> jax.Array:
    n = node_feat.shape[0]
    mask = edge_mask(edge_src, edge_dst)
    x = (node_feat @ params["embed"]).astype(cfg.dtype)
    ri = gather_src(positions, edge_src)
    rj = gather_src(positions, edge_dst)
    dist = jnp.sqrt(jnp.sum((ri - rj) ** 2, axis=-1) + 1e-12)  # (E,)
    mu = jnp.linspace(0.0, cfg.cutoff, cfg.n_rbf)
    gamma = 10.0 / cfg.cutoff
    rbf = jnp.exp(-gamma * (dist[:, None] - mu[None, :]) ** 2).astype(cfg.dtype)
    # cosine cutoff envelope
    fc = 0.5 * (jnp.cos(jnp.pi * jnp.clip(dist / cfg.cutoff, 0, 1)) + 1.0)
    for inter in params["interactions"]:
        w = mlp_apply(inter["filter"], rbf, act=_ssp) * fc[:, None].astype(cfg.dtype)
        h = mlp_apply(inter["in_proj"], x)
        msg = gather_src(h, edge_src) * w
        agg = scatter_sum(msg, edge_dst, n, mask)
        x = x + mlp_apply(inter["out_mlp"], agg, act=_ssp)
    return mlp_apply(params["readout"], x, act=_ssp)
