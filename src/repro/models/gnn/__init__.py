"""GNN model zoo: GCN, GraphSAGE, SchNet, EGNN on segment-op message passing."""
from . import common, egnn, gcn, graphsage, schnet
from .egnn import EGNNConfig
from .gcn import GCNConfig
from .graphsage import SAGEConfig
from .schnet import SchNetConfig

__all__ = [
    "common",
    "gcn",
    "graphsage",
    "schnet",
    "egnn",
    "GCNConfig",
    "SAGEConfig",
    "SchNetConfig",
    "EGNNConfig",
]
