"""EGNN (Satorras et al., arXiv:2102.09844): E(n)-equivariant GNN.

Scalar messages from invariant distances; coordinates updated along
relative-position vectors — equivariance without spherical harmonics.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import edge_mask, gather_src, mlp_apply, mlp_init, scatter_mean, scatter_sum

__all__ = ["EGNNConfig", "init_params", "apply"]


@dataclasses.dataclass(frozen=True)
class EGNNConfig:
    name: str = "egnn"
    n_layers: int = 4
    d_hidden: int = 64
    d_in: int = 16
    d_out: int = 1
    update_coords: bool = True
    dtype: object = jnp.float32


def init_params(key: jax.Array, cfg: EGNNConfig) -> dict:
    d = cfg.d_hidden
    key, k_in = jax.random.split(key)
    params = {
        "embed": jax.random.normal(k_in, (cfg.d_in, d), jnp.float32) * cfg.d_in ** -0.5,
        "layers": [],
    }
    for _ in range(cfg.n_layers):
        key, k1, k2, k3 = jax.random.split(key, 4)
        params["layers"].append(
            {
                "phi_e": mlp_init(k1, [2 * d + 1, d, d]),
                "phi_x": mlp_init(k2, [d, d, 1]),
                "phi_h": mlp_init(k3, [2 * d, d, d]),
            }
        )
    key, k_out = jax.random.split(key)
    params["readout"] = mlp_init(k_out, [d, d, cfg.d_out])
    return params


def apply(
    params: dict,
    cfg: EGNNConfig,
    node_feat: jax.Array,   # (N, d_in)
    positions: jax.Array,   # (N, 3)
    edge_src: jax.Array = None,
    edge_dst: jax.Array = None,
) -> jax.Array:
    n = node_feat.shape[0]
    mask = edge_mask(edge_src, edge_dst)
    h = (node_feat @ params["embed"]).astype(cfg.dtype)
    x = positions.astype(cfg.dtype)
    for layer in params["layers"]:
        hi = gather_src(h, edge_dst)   # receiving node i
        hj = gather_src(h, edge_src)   # sending node j
        xi = gather_src(x, edge_dst)
        xj = gather_src(x, edge_src)
        diff = xi - xj                 # (E, 3)
        d2 = jnp.sum(diff * diff, axis=-1, keepdims=True)
        m = mlp_apply(layer["phi_e"], jnp.concatenate([hi, hj, d2], axis=-1))  # (E, d)
        if cfg.update_coords:
            coef = jnp.tanh(mlp_apply(layer["phi_x"], m))  # bounded for stability
            x = x + scatter_mean(diff * coef, edge_dst, n, mask)
        agg = scatter_sum(m, edge_dst, n, mask)
        h = h + mlp_apply(layer["phi_h"], jnp.concatenate([h, agg], axis=-1))
    return mlp_apply(params["readout"], h)
