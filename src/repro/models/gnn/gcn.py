"""GCN (Kipf & Welling, arXiv:1609.02907) with symmetric normalization."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import degrees_from_edges, edge_mask, gather_src, scatter_sum

__all__ = ["GCNConfig", "init_params", "apply"]


@dataclasses.dataclass(frozen=True)
class GCNConfig:
    name: str = "gcn-cora"
    n_layers: int = 2
    d_hidden: int = 16
    d_in: int = 1433
    d_out: int = 7
    dtype: object = jnp.float32
    # §Perf: Ã(XW) ≡ (ÃX)W — aggregate in whichever width is narrower.
    # Under the edge-partitioned scheme the psum'd tensor is the aggregated
    # one, so ordering by min(d_in, d_out) directly shrinks the collective.
    smart_order: bool = False
    # §Perf: when set (inside shard_map), per-layer partial aggregates are
    # explicitly psum'd over these axes *in the compute dtype* — GSPMD's
    # implicit all-reduce hoists the loss upcast and rides fp32 otherwise.
    psum_axes: tuple | None = None


def init_params(key: jax.Array, cfg: GCNConfig) -> dict:
    sizes = [cfg.d_in] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.d_out]
    layers = []
    for a, b in zip(sizes[:-1], sizes[1:]):
        key, k = jax.random.split(key)
        layers.append(
            {
                "w": jax.random.normal(k, (a, b), jnp.float32) * a ** -0.5,
                "b": jnp.zeros((b,), jnp.float32),
            }
        )
    return {"layers": layers}


def apply(
    params: dict,
    cfg: GCNConfig,
    node_feat: jax.Array,   # (N, d_in)
    positions=None,         # unused
    edge_src: jax.Array = None,
    edge_dst: jax.Array = None,
) -> jax.Array:
    n = node_feat.shape[0]
    mask = edge_mask(edge_src, edge_dst)
    # Ã = D^{-1/2}(A + I)D^{-1/2}; degrees include the self loop.
    deg = degrees_from_edges(edge_dst, n, mask)
    if cfg.psum_axes:  # edge-partitioned: local histogram → global degrees
        deg = jax.lax.psum(deg, cfg.psum_axes)
    deg = deg + 1.0
    inv_sqrt = jax.lax.rsqrt(deg)
    coef = (gather_src(inv_sqrt, edge_src) * gather_src(inv_sqrt, edge_dst))[:, None]
    x = node_feat.astype(cfg.dtype)
    for i, layer in enumerate(params["layers"]):
        w = layer["w"].astype(x.dtype)
        b = layer["b"].astype(x.dtype)
        transform_first = (not cfg.smart_order) or w.shape[1] <= w.shape[0]
        h = x @ w if transform_first else x
        msg = gather_src(h, edge_src) * coef.astype(x.dtype)
        scat = scatter_sum(msg, edge_dst, n, mask)
        if cfg.psum_axes:  # explicit psum in compute dtype (bf16 on the wire)
            scat = jax.lax.psum(scat, cfg.psum_axes)
        agg = scat + h * (inv_sqrt**2)[:, None].astype(x.dtype)
        if not transform_first:
            agg = agg @ w
        agg = agg + b
        x = agg if i == len(params["layers"]) - 1 else jax.nn.relu(agg)
    return x
