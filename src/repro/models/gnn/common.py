"""Shared message-passing primitives for all GNN architectures.

JAX has no CSR/CSC sparse kernels (BCOO only), so message passing is built
directly on gather → elementwise → ``segment_sum`` scatter over an
edge-index list — the same SoA edge array the triangle-counting core uses.
Padded edges carry ``src == -1`` and are masked out of every reduction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "edge_mask",
    "gather_src",
    "scatter_sum",
    "scatter_mean",
    "scatter_max",
    "degrees_from_edges",
    "mlp_init",
    "mlp_apply",
]


def edge_mask(edge_src: jax.Array, edge_dst: jax.Array) -> jax.Array:
    return (edge_src >= 0) & (edge_dst >= 0)


def gather_src(x: jax.Array, idx: jax.Array) -> jax.Array:
    """Gather rows with −1-safe indices (clamped; caller masks)."""
    return jnp.take(x, jnp.maximum(idx, 0), axis=0)


def scatter_sum(messages: jax.Array, dst: jax.Array, n_nodes: int, mask=None) -> jax.Array:
    if mask is not None:
        messages = messages * mask[..., None].astype(messages.dtype)
    return jax.ops.segment_sum(messages, jnp.maximum(dst, 0), num_segments=n_nodes)


def scatter_mean(messages: jax.Array, dst: jax.Array, n_nodes: int, mask=None) -> jax.Array:
    s = scatter_sum(messages, dst, n_nodes, mask)
    ones = jnp.ones(messages.shape[:1], messages.dtype)
    if mask is not None:
        ones = ones * mask.astype(messages.dtype)
    cnt = jax.ops.segment_sum(ones, jnp.maximum(dst, 0), num_segments=n_nodes)
    return s / jnp.maximum(cnt, 1.0)[:, None]


def scatter_max(messages: jax.Array, dst: jax.Array, n_nodes: int, mask=None) -> jax.Array:
    if mask is not None:
        neg = jnp.full_like(messages, -1e30)
        messages = jnp.where(mask[..., None], messages, neg)
    out = jax.ops.segment_max(messages, jnp.maximum(dst, 0), num_segments=n_nodes)
    return jnp.where(jnp.isfinite(out), out, 0.0)


def degrees_from_edges(dst: jax.Array, n_nodes: int, mask=None) -> jax.Array:
    ones = jnp.ones(dst.shape[0], jnp.float32)
    if mask is not None:
        ones = ones * mask.astype(jnp.float32)
    return jax.ops.segment_sum(ones, jnp.maximum(dst, 0), num_segments=n_nodes)


def mlp_init(key, sizes, param_dtype=jnp.float32):
    params = []
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        k1, key = jax.random.split(key)
        params.append(
            {
                "w": (jax.random.normal(k1, (a, b), jnp.float32) * a ** -0.5).astype(param_dtype),
                "b": jnp.zeros((b,), param_dtype),
            }
        )
    return params


def mlp_apply(params, x, act=jax.nn.silu, final_act=None):
    for i, layer in enumerate(params):
        x = x @ layer["w"].astype(x.dtype) + layer["b"].astype(x.dtype)
        if i < len(params) - 1:
            x = act(x)
        elif final_act is not None:
            x = final_act(x)
    return x
