"""Attention primitives shared by all LM architectures.

* :func:`flash_attention_jnp` — memory-efficient blockwise softmax
  attention (``lax.scan`` over KV blocks with running max/sum).  Same
  schedule as the Pallas kernel; lowers everywhere, never materializes the
  (Sq, Skv) score matrix, and is what the dry-run compiles at 512 devices.
* :func:`decode_attention` — single-token decode against a dense KV cache,
  with *flash-decoding* partial-softmax semantics: when the cache's
  sequence axis is sharded, each shard computes (max, numerator,
  denominator) over its slice and the states merge exactly — XLA turns the
  merge into the psum over the sharded axis.
* :func:`rope` — rotary position embeddings (all assigned archs use RoPE).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = [
    "flash_attention_jnp",
    "decode_attention",
    "decode_attention_int8",
    "quantize_kv_token",
    "rope",
    "apply_rope",
]

_NEG_INF = -1e30


def rope(positions: jax.Array, d_head: int, theta: float = 10000.0) -> tuple[jax.Array, jax.Array]:
    """(sin, cos) tables for rotary embeddings; positions: (..., S)."""
    half = d_head // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    return jnp.sin(angles), jnp.cos(angles)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """Rotate pairs. x: (B, H, S, D); sin/cos: (B, S, D/2) or (S, D/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if sin.ndim == 2:
        sin = sin[None, None]
        cos = cos[None, None]
    else:
        sin = sin[:, None]
        cos = cos[:, None]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(
        x.dtype
    )


@functools.partial(jax.jit, static_argnames=("causal", "sm_scale", "block_k"))
def flash_attention_jnp(
    q: jax.Array,  # (B, Hq, Sq, D)
    k: jax.Array,  # (B, Hkv, Skv, D)
    v: jax.Array,
    causal: bool = True,
    sm_scale: float | None = None,
    block_k: int = 512,
) -> jax.Array:
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    g = hq // hkv
    if sm_scale is None:
        sm_scale = d ** -0.5
    bk = min(block_k, skv)
    nk = -(-skv // bk)
    pad = nk * bk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    # (B, Hkv, nk, bk, D) — scan over nk
    kb = k.reshape(b, hkv, nk, bk, d).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(b, hkv, nk, bk, d).transpose(2, 0, 1, 3, 4)
    qg = q.reshape(b, hkv, g, sq, d)
    q_pos = jnp.arange(sq)

    def step(carry, inputs):
        m_prev, l_prev, acc = carry
        jk, k_blk, v_blk = inputs  # (B, Hkv, bk, D)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k_blk).astype(jnp.float32) * sm_scale
        k_pos = jk * bk + jnp.arange(bk)
        valid = k_pos < skv
        if causal:
            valid = valid[None, :] & (q_pos[:, None] + (skv - sq) >= k_pos[None, :])
            s = jnp.where(valid[None, None, None], s, _NEG_INF)
        else:
            s = jnp.where(valid[None, None, None, None], s, _NEG_INF)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        # `where` (not bare exp) so a fully-masked block contributes 0, not e⁰
        p = jnp.where(s > _NEG_INF / 2, jnp.exp(s - m_new[..., None]), 0.0)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p.astype(v_blk.dtype), v_blk
        ).astype(jnp.float32)
        return (m_new, l_new, acc), None

    init = (
        jnp.full((b, hkv, g, sq), _NEG_INF, jnp.float32),
        jnp.zeros((b, hkv, g, sq), jnp.float32),
        jnp.zeros((b, hkv, g, sq, d), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(step, init, (jnp.arange(nk), kb, vb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, hq, sq, d).astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("sm_scale",))
def decode_attention(
    q: jax.Array,        # (B, Hq, 1, D) — one new token
    k_cache: jax.Array,  # (B, Hkv, S, D)
    v_cache: jax.Array,  # (B, Hkv, S, D)
    cache_len: jax.Array | int,  # valid prefix length (scalar or (B,))
    sm_scale: float | None = None,
) -> jax.Array:
    """Single-step decode. Linear in S; safe under seq-axis sharding.

    The softmax is computed in the numerically-safe (m, l, acc) form so XLA
    can distribute the reductions over a sharded sequence axis (this is
    flash-decoding expressed as sharded reductions instead of a kernel).
    """
    b, hq, _, d = q.shape
    _, hkv, s, _ = k_cache.shape
    g = hq // hkv
    if sm_scale is None:
        sm_scale = d ** -0.5
    qg = q.reshape(b, hkv, g, d)
    scores = jnp.einsum("bhgd,bhsd->bhgs", qg, k_cache).astype(jnp.float32) * sm_scale
    pos = jnp.arange(s)
    cache_len = jnp.asarray(cache_len)
    if cache_len.ndim == 0:
        valid = pos < cache_len
        scores = jnp.where(valid[None, None, None, :], scores, _NEG_INF)
    else:
        valid = pos[None, :] < cache_len[:, None]
        scores = jnp.where(valid[:, None, None, :], scores, _NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhgs,bhsd->bhgd", (p / jnp.maximum(l, 1e-30)).astype(q.dtype), v_cache)
    return out.reshape(b, hq, 1, d)


# ---------------------------------------------------------------------------
# int8 KV-cache decode (§Perf: halves the decode memory term vs bf16)
# ---------------------------------------------------------------------------


def quantize_kv_token(k: jax.Array, v: jax.Array):
    """Quantize one KV token per (batch, head): (B, H, 1, D) → int8 + scale.

    K keeps a per-token scale (it factors out of q·k *after* the dot along
    D); V's per-token scale is folded into the attention probabilities at
    read time, so both dots run int8×int8→int32 on the MXU.
    """
    def one(x):
        s = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True) / 127.0
        s = jnp.maximum(s, 1e-12)
        q = jnp.clip(jnp.round(x.astype(jnp.float32) / s), -127, 127).astype(jnp.int8)
        return q, s[..., 0]  # scale (B, H, 1)

    kq, ks = one(k)
    vq, vs = one(v)
    return kq, ks, vq, vs


@functools.partial(jax.jit, static_argnames=("sm_scale",))
def decode_attention_int8(
    q: jax.Array,         # (B, Hq, 1, D) activations (bf16/f32)
    k_cache: jax.Array,   # (B, Hkv, S, D) int8
    k_scale: jax.Array,   # (B, Hkv, S) f32 per-token scales
    v_cache: jax.Array,   # (B, Hkv, S, D) int8
    v_scale: jax.Array,   # (B, Hkv, S) f32 per-token scales
    cache_len: jax.Array | int,
    sm_scale: float | None = None,
) -> jax.Array:
    """Decode against an int8 KV cache with int8×int8→int32 dots.

    q is quantized per (batch, head) on the fly; the score dequant is
    ``q_scale · k_scale[s]`` (both factor out of the D-contraction).  For
    the value dot, the per-token v scale is folded into the probabilities
    (p'ₛ = pₛ·v_scaleₛ) before *they* are quantized, so the second dot is
    int8 too and dequants by a single per-(b,h,g) scalar.
    """
    b, hq, _, d = q.shape
    _, hkv, s, _ = k_cache.shape
    g = hq // hkv
    if sm_scale is None:
        sm_scale = d ** -0.5
    qf = q.reshape(b, hkv, g, d).astype(jnp.float32)
    q_s = jnp.maximum(jnp.max(jnp.abs(qf), axis=-1, keepdims=True), 1e-12) / 127.0
    q_i8 = jnp.clip(jnp.round(qf / q_s), -127, 127).astype(jnp.int8)
    scores_i32 = jax.lax.dot_general(
        q_i8, k_cache,
        (((3,), (3,)), ((0, 1), (0, 1))),          # contract D, batch (B, Hkv)
        preferred_element_type=jnp.int32,
    )                                              # (B, Hkv, G, S)
    scores = (
        scores_i32.astype(jnp.float32)
        * q_s                                       # (B, Hkv, G, 1)
        * k_scale[:, :, None, :]                    # (B, Hkv, 1, S)
        * sm_scale
    )
    pos = jnp.arange(s)
    cache_len = jnp.asarray(cache_len)
    valid = (
        pos < cache_len if cache_len.ndim == 0 else pos[None, :] < cache_len[:, None]
    )
    valid = valid[None, None, None, :] if valid.ndim == 1 else valid[:, None, None, :]
    scores = jnp.where(valid, scores, _NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    # fold per-token v scales into p, then quantize p for the second dot
    p_eff = p * v_scale[:, :, None, :]              # (B, Hkv, G, S)
    p_s = jnp.maximum(jnp.max(p_eff, axis=-1, keepdims=True), 1e-12) / 127.0
    p_i8 = jnp.clip(jnp.round(p_eff / p_s), 0, 127).astype(jnp.int8)
    out_i32 = jax.lax.dot_general(
        p_i8, v_cache,
        (((3,), (2,)), ((0, 1), (0, 1))),           # contract S
        preferred_element_type=jnp.int32,
    )                                               # (B, Hkv, G, D)
    out = out_i32.astype(jnp.float32) * p_s
    return out.reshape(b, hq, 1, d).astype(q.dtype)
