"""Model zoo: LM transformers (dense + MoE), GNNs, recsys."""
from . import attention, gnn, recsys, transformer
from .transformer import TransformerConfig

__all__ = ["attention", "transformer", "gnn", "recsys", "TransformerConfig"]
