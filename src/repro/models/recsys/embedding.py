"""EmbeddingBag and sparse-table utilities for the recsys stack.

JAX has no native ``nn.EmbeddingBag`` and no CSR sparse ops, so the bag
reduce is built from ``jnp.take`` + ``jax.ops.segment_sum`` — per the
taxonomy, this IS part of the system.  Tables are row-shardable: the
gather lowers to a sharded gather + psum of partials under pjit when the
table carries a ``P("model", None)`` sharding.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["embedding_lookup", "embedding_bag", "hash_bucket"]


def embedding_lookup(table: jax.Array, ids: jax.Array) -> jax.Array:
    """Plain row gather; −1 ids return zero rows (padding)."""
    rows = jnp.take(table, jnp.maximum(ids, 0), axis=0)
    return jnp.where((ids >= 0)[..., None], rows, 0.0)


def embedding_bag(
    table: jax.Array,      # (V, d)
    ids: jax.Array,        # (n_indices,) flat multi-hot indices, −1 padded
    segments: jax.Array,   # (n_indices,) bag id per index
    n_bags: int,
    mode: str = "sum",
) -> jax.Array:
    """torch.nn.EmbeddingBag equivalent: ragged gather + segment reduce."""
    rows = embedding_lookup(table, ids)
    seg = jnp.maximum(segments, 0)
    if mode == "sum":
        return jax.ops.segment_sum(rows, seg, num_segments=n_bags)
    if mode == "mean":
        s = jax.ops.segment_sum(rows, seg, num_segments=n_bags)
        cnt = jax.ops.segment_sum(
            (ids >= 0).astype(table.dtype), seg, num_segments=n_bags
        )
        return s / jnp.maximum(cnt, 1.0)[:, None]
    if mode == "max":
        neg = jnp.where((ids >= 0)[..., None], rows, -1e30)
        out = jax.ops.segment_max(neg, seg, num_segments=n_bags)
        return jnp.where(out > -1e29, out, 0.0)
    raise ValueError(f"unknown mode {mode!r}")


def hash_bucket(raw_ids: jax.Array, n_buckets: int) -> jax.Array:
    """Multiplicative hashing for open-vocabulary ids (QR-trick companion)."""
    h = (raw_ids.astype(jnp.uint32) * jnp.uint32(2654435761)) % jnp.uint32(n_buckets)
    return h.astype(jnp.int32)
