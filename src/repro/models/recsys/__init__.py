"""RecSys stack: DIN + EmbeddingBag built on take/segment_sum."""
from . import din, embedding
from .din import DINConfig
from .embedding import embedding_bag, embedding_lookup, hash_bucket

__all__ = [
    "din",
    "embedding",
    "DINConfig",
    "embedding_bag",
    "embedding_lookup",
    "hash_bucket",
]
