"""Deep Interest Network (Zhou et al., arXiv:1706.06978).

Target attention over the user behaviour sequence: each history item is
scored by an MLP over ``[h, t, h−t, h·t]`` against the candidate item, the
weighted history sum concatenates with the target/profile embeddings into
the prediction MLP.  The million-row item table is the hot path
(row-sharded over the "model" axis in production).

``score_candidates`` broadcasts one user's attended history against a
large candidate set as a single batched einsum — the ``retrieval_cand``
shape (1 user × 10⁶ candidates) with no Python loop.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .embedding import embedding_lookup
from ..gnn.common import mlp_apply, mlp_init

__all__ = ["DINConfig", "init_params", "apply", "score_candidates", "loss_fn"]


@dataclasses.dataclass(frozen=True)
class DINConfig:
    name: str = "din"
    n_items: int = 1_000_000
    n_cates: int = 10_000
    embed_dim: int = 18
    seq_len: int = 100
    attn_mlp: tuple[int, ...] = (80, 40)
    mlp: tuple[int, ...] = (200, 80)
    dtype: object = jnp.float32


def init_params(key: jax.Array, cfg: DINConfig) -> dict:
    d = cfg.embed_dim
    k_item, k_cate, k_attn, k_mlp = jax.random.split(key, 4)
    item_cate = 2 * d  # item ⊕ category embedding
    return {
        "item_table": jax.random.normal(k_item, (cfg.n_items, d), jnp.float32) * 0.01,
        "cate_table": jax.random.normal(k_cate, (cfg.n_cates, d), jnp.float32) * 0.01,
        # attention MLP over [h, t, h−t, h·t]
        "attn": mlp_init(k_attn, [4 * item_cate, *cfg.attn_mlp, 1]),
        # prediction MLP over [hist_sum, target, hist_sum·target]
        "mlp": mlp_init(k_mlp, [3 * item_cate, *cfg.mlp, 1]),
    }


def _embed_items(params, cfg, item_ids, cate_ids):
    it = embedding_lookup(params["item_table"], item_ids)
    ct = embedding_lookup(params["cate_table"], cate_ids)
    return jnp.concatenate([it, ct], axis=-1).astype(cfg.dtype)  # (..., 2d)


def _attend(params, hist, target, hist_mask):
    """hist: (B, S, D); target: (B, D) → attended history (B, D)."""
    t = jnp.broadcast_to(target[:, None, :], hist.shape)
    feats = jnp.concatenate([hist, t, hist - t, hist * t], axis=-1)
    scores = mlp_apply(params["attn"], feats)[..., 0]  # (B, S)
    scores = jnp.where(hist_mask, scores, -1e30)
    # DIN uses un-normalized sigmoid weights rather than softmax
    w = jax.nn.sigmoid(scores) * hist_mask.astype(hist.dtype)
    return jnp.einsum("bs,bsd->bd", w, hist)


def apply(params: dict, cfg: DINConfig, batch: dict) -> jax.Array:
    """batch: hist_items/hist_cates (B,S), target_item/target_cate (B,).

    Returns CTR logits (B,).
    """
    hist = _embed_items(params, cfg, batch["hist_items"], batch["hist_cates"])
    target = _embed_items(params, cfg, batch["target_item"], batch["target_cate"])
    mask = batch["hist_items"] >= 0
    user = _attend(params, hist, target, mask)
    feats = jnp.concatenate([user, target, user * target], axis=-1)
    return mlp_apply(params["mlp"], feats)[..., 0]


def score_candidates(params: dict, cfg: DINConfig, batch: dict) -> jax.Array:
    """One user vs ``C`` candidates: hist (1,S), cand_items/cand_cates (C,).

    Returns (C,) logits as one batched attention+MLP evaluation.
    """
    hist = _embed_items(params, cfg, batch["hist_items"], batch["hist_cates"])  # (1,S,D)
    cands = _embed_items(params, cfg, batch["cand_items"], batch["cand_cates"])  # (C,D)
    mask = batch["hist_items"] >= 0  # (1,S)
    c = cands.shape[0]
    hist_b = jnp.broadcast_to(hist, (c, *hist.shape[1:]))
    mask_b = jnp.broadcast_to(mask, (c, mask.shape[1]))
    user = _attend(params, hist_b, cands, mask_b)  # (C,D)
    feats = jnp.concatenate([user, cands, user * cands], axis=-1)
    return mlp_apply(params["mlp"], feats)[..., 0]


def loss_fn(params: dict, cfg: DINConfig, batch: dict) -> jax.Array:
    logits = apply(params, cfg, batch)
    labels = batch["label"].astype(jnp.float32)
    logits = logits.astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )
