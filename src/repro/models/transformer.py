"""Decoder-only GQA transformer covering all five assigned LM architectures.

Design notes:

* **scan over layers** — layer parameters are stacked along a leading axis
  and the depth loop is a single ``lax.scan``; HLO size and compile time
  are depth-independent (essential for the 62-layer deepseek config at 512
  fake devices).
* **GQA flash attention** — the scan-based blockwise softmax from
  :mod:`repro.models.attention`; the Pallas kernel is the TPU drop-in.
* **MoE** — sort-based token routing through ``jax.lax.ragged_dot``:
  tokens are replicated ``top_k`` times, sorted by expert, processed by a
  single grouped matmul, unsorted, and combined with router weights.  No
  capacity dropping, no (T, E, C) one-hot dispatch tensors.
* **remat** — each layer body is ``jax.checkpoint``'d under the scan, so
  the backward pass stores only per-layer inputs.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from .attention import (
    apply_rope,
    decode_attention,
    decode_attention_int8,
    flash_attention_jnp,
    quantize_kv_token,
    rope,
)

__all__ = [
    "TransformerConfig",
    "init_params",
    "forward",
    "loss_fn",
    "prefill",
    "decode_step",
    "init_kv_cache",
    "init_kv_cache_int8",
]


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    n_experts: int = 0       # 0 → dense FFN
    top_k: int = 0
    norm_eps: float = 1e-5
    vocab_pad: int = 512     # vocab-parallel tables round up to this
    onehot_ce: bool = False  # §Perf: CE via one-hot einsum (vocab-sharding
                             # friendly: no logits all-gather at the loss)
    kv_quant: bool = False   # §Perf: int8 KV cache + int8×int8 decode dots
    dtype: Any = jnp.bfloat16        # activation/compute dtype
    param_dtype: Any = jnp.float32   # master parameter dtype
    remat: bool = True
    remat_policy: str = "full"       # "full" | "dots" (§Perf: save matmul
                                     # outputs, replay only elementwise)
    attn_block_k: int = 512

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Megatron-style vocab padding: tables round up to a multiple of
        ``vocab_pad`` so the vocab-parallel dim divides any mesh axis we
        use; padded logit columns are masked to −∞ before the softmax."""
        return -(-self.vocab_size // self.vocab_pad) * self.vocab_pad

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def bytes_per_param(self) -> int:
        return jnp.dtype(self.param_dtype).itemsize

    def n_params(self) -> int:
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim
        attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
        if self.is_moe:
            mlp = self.n_experts * (3 * d * ff) + d * self.n_experts
        else:
            mlp = 3 * d * ff
        per_layer = attn + mlp + 2 * d
        return self.n_layers * per_layer + 2 * v * d + d

    def n_active_params(self) -> int:
        """Parameters touched per token (MoE: top_k of n_experts)."""
        if not self.is_moe:
            return self.n_params()
        d, ff = self.d_model, self.d_ff
        hd = self.head_dim
        attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
        mlp = self.top_k * (3 * d * ff) + d * self.n_experts
        per_layer = attn + mlp + 2 * d
        return self.n_layers * per_layer + 2 * self.vocab_size * d + d


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _dense_init(key, shape, dtype, scale=None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def init_layer_params(key: jax.Array, cfg: TransformerConfig) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    pd = cfg.param_dtype
    ks = jax.random.split(key, 12)
    p = {
        "rms_attn": jnp.ones((d,), pd),
        "rms_mlp": jnp.ones((d,), pd),
        "wq": _dense_init(ks[0], (d, h * hd), pd),
        "wk": _dense_init(ks[1], (d, kv * hd), pd),
        "wv": _dense_init(ks[2], (d, kv * hd), pd),
        "wo": _dense_init(ks[3], (h * hd, d), pd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), pd)
        p["bk"] = jnp.zeros((kv * hd,), pd)
        p["bv"] = jnp.zeros((kv * hd,), pd)
    if cfg.is_moe:
        e, ff = cfg.n_experts, cfg.d_ff
        p["router"] = _dense_init(ks[4], (d, e), pd)
        p["w_gate"] = _dense_init(ks[5], (e, d, ff), pd)
        p["w_up"] = _dense_init(ks[6], (e, d, ff), pd)
        p["w_down"] = _dense_init(ks[7], (e, ff, d), pd)
    else:
        ff = cfg.d_ff
        p["w_gate"] = _dense_init(ks[5], (d, ff), pd)
        p["w_up"] = _dense_init(ks[6], (d, ff), pd)
        p["w_down"] = _dense_init(ks[7], (ff, d), pd)
    return p


def init_params(key: jax.Array, cfg: TransformerConfig) -> dict:
    k_embed, k_head, k_layers = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(lambda k: init_layer_params(k, cfg))(layer_keys)
    return {
        "embed": _dense_init(k_embed, (cfg.padded_vocab, cfg.d_model), cfg.param_dtype, 1.0),
        "lm_head": _dense_init(k_head, (cfg.d_model, cfg.padded_vocab), cfg.param_dtype),
        "final_norm": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "layers": layers,
    }


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    nrm = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (nrm * w.astype(jnp.float32)).astype(x.dtype)


def _swiglu(h: jax.Array, p: dict, dtype) -> jax.Array:
    g = h @ p["w_gate"].astype(dtype)
    u = h @ p["w_up"].astype(dtype)
    return (jax.nn.silu(g.astype(jnp.float32)).astype(dtype) * u) @ p["w_down"].astype(dtype)


def _moe(h: jax.Array, p: dict, cfg: TransformerConfig) -> jax.Array:
    """Sort-based top-k MoE with a grouped (ragged) matmul.

    h: (T, d) flattened tokens → (T, d).
    """
    t, d = h.shape
    e, k = cfg.n_experts, cfg.top_k
    logits = (h @ p["router"].astype(h.dtype)).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                          # (T, k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)          # renormalize
    flat_e = top_e.reshape(-1)                                      # (T·k,)
    order = jnp.argsort(flat_e)                                     # stable
    token_of = order // k                                           # source token per row
    xs = jnp.take(h, token_of, axis=0)                              # (T·k, d) sorted by expert
    group_sizes = jnp.bincount(flat_e, length=e).astype(jnp.int32)
    g = jax.lax.ragged_dot(xs, p["w_gate"].astype(h.dtype), group_sizes)
    u = jax.lax.ragged_dot(xs, p["w_up"].astype(h.dtype), group_sizes)
    act = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * u
    out = jax.lax.ragged_dot(act, p["w_down"].astype(h.dtype), group_sizes)  # (T·k, d)
    w_sorted = jnp.take(top_w.reshape(-1), order).astype(out.dtype)
    out = out * w_sorted[:, None]
    combined = jnp.zeros((t, d), out.dtype).at[token_of].add(out)
    return combined


def _attention_block(x, p, cfg: TransformerConfig, sin, cos):
    b, s, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = x.dtype
    q = x @ p["wq"].astype(dt)
    k = x @ p["wk"].astype(dt)
    v = x @ p["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = q.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, kv, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, kv, hd).transpose(0, 2, 1, 3)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    o = flash_attention_jnp(q, k, v, causal=True, block_k=cfg.attn_block_k)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, h * hd)
    return o @ p["wo"].astype(dt), k, v


def _layer(x, p, cfg: TransformerConfig, sin, cos):
    attn_out, k, v = _attention_block(rms_norm(x, p["rms_attn"], cfg.norm_eps), p, cfg, sin, cos)
    x = x + attn_out
    hmid = rms_norm(x, p["rms_mlp"], cfg.norm_eps)
    if cfg.is_moe:
        b, s, d = hmid.shape
        mlp = _moe(hmid.reshape(b * s, d), p, cfg).reshape(b, s, d)
    else:
        mlp = _swiglu(hmid, p, x.dtype)
    return x + mlp, (k, v)


# ---------------------------------------------------------------------------
# forward / loss / serving
# ---------------------------------------------------------------------------


def _mask_pad_vocab(logits: jax.Array, cfg: TransformerConfig) -> jax.Array:
    if cfg.padded_vocab == cfg.vocab_size:
        return logits
    pad_col = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
    return jnp.where(pad_col, jnp.asarray(-1e30, logits.dtype), logits)


def forward(
    params: dict, tokens: jax.Array, cfg: TransformerConfig, return_kv: bool = False
):
    """tokens: (B, S) int32 → logits (B, S, V) [+ stacked KV caches]."""
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    sin, cos = rope(jnp.arange(s), cfg.head_dim, cfg.rope_theta)

    def body(x, layer_p):
        x, kvs = _layer(x, layer_p, cfg, sin, cos)
        return x, kvs if return_kv else None

    body_fn = body
    if cfg.remat:
        policy = (
            jax.checkpoint_policies.dots_saveable
            if cfg.remat_policy == "dots"
            else jax.checkpoint_policies.nothing_saveable
        )
        body_fn = jax.checkpoint(body, policy=policy)
    x, kvs = jax.lax.scan(body_fn, x, params["layers"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["lm_head"].astype(cfg.dtype)
    logits = _mask_pad_vocab(logits, cfg)
    if return_kv:
        # kvs: tuple of (L, B, KV, S, hd) arrays → transpose to cache layout
        k = kvs[0].transpose(0, 1, 2, 3, 4)
        v = kvs[1].transpose(0, 1, 2, 3, 4)
        return logits, (k, v)
    return logits


def loss_fn(params: dict, batch: dict, cfg: TransformerConfig) -> jax.Array:
    """Next-token cross entropy; batch = {tokens, labels, mask?}.

    With ``cfg.onehot_ce`` the label log-prob is extracted with a one-hot
    contraction instead of ``take_along_axis``: a gather along a
    vocab-sharded axis forces GSPMD to all-gather the logits, whereas the
    contraction partitions cleanly (each vocab shard contributes its
    partial dot; the psum is one scalar per token).
    """
    logits = forward(params, batch["tokens"], cfg)
    logits = logits.astype(jnp.float32)
    if cfg.onehot_ce:
        m = jnp.max(logits, axis=-1, keepdims=True)
        shifted = logits - jax.lax.stop_gradient(m)
        lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
        onehot = jax.nn.one_hot(batch["labels"], cfg.padded_vocab, dtype=logits.dtype)
        picked = jnp.einsum("bsv,bsv->bs", shifted, onehot)
        ll = picked - lse
    else:
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logp, batch["labels"][..., None], axis=-1)[..., 0]
    mask = batch.get("mask")
    if mask is None:
        return -jnp.mean(ll)
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def prefill(params: dict, tokens: jax.Array, cfg: TransformerConfig):
    """Serving prefill: returns (last-position logits, KV caches)."""
    logits, kv = forward(params, tokens, cfg, return_kv=True)
    return logits[:, -1], kv


def init_kv_cache(cfg: TransformerConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or cfg.dtype
    shape = (cfg.n_layers, batch, cfg.n_kv_heads, max_len, cfg.head_dim)
    return jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)


def init_kv_cache_int8(cfg: TransformerConfig, batch: int, max_len: int):
    """(k int8, k_scale f32, v int8, v_scale f32) — ~2.2× smaller than bf16."""
    shape = (cfg.n_layers, batch, cfg.n_kv_heads, max_len, cfg.head_dim)
    sshape = shape[:-1]
    return (
        jnp.zeros(shape, jnp.int8),
        jnp.zeros(sshape, jnp.float32),
        jnp.zeros(shape, jnp.int8),
        jnp.zeros(sshape, jnp.float32),
    )


def decode_step(
    params: dict,
    token: jax.Array,        # (B,) int32 — the newest token
    pos: jax.Array,          # scalar int32 — its position (= cache length)
    kv_cache,                # (k, v) of (L, B, KV, S_max, hd), or the 4-tuple
                             # (k_i8, k_scale, v_i8, v_scale) when cfg.kv_quant
    cfg: TransformerConfig,
):
    """One greedy decode step; returns (logits (B, V), updated cache)."""
    b = token.shape[0]
    x = jnp.take(params["embed"], token[:, None], axis=0).astype(cfg.dtype)  # (B,1,d)
    sin, cos = rope(jnp.asarray(pos)[None], cfg.head_dim, cfg.rope_theta)

    def body(x, scanned):
        layer_p, cache = scanned[0], scanned[1:]
        h = rms_norm(x, layer_p["rms_attn"], cfg.norm_eps)
        dt = x.dtype
        hd, nh, nkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
        q = h @ layer_p["wq"].astype(dt)
        k = h @ layer_p["wk"].astype(dt)
        v = h @ layer_p["wv"].astype(dt)
        if cfg.qkv_bias:
            q = q + layer_p["bq"].astype(dt)
            k = k + layer_p["bk"].astype(dt)
            v = v + layer_p["bv"].astype(dt)
        q = q.reshape(b, 1, nh, hd).transpose(0, 2, 1, 3)
        k = k.reshape(b, 1, nkv, hd).transpose(0, 2, 1, 3)
        v = v.reshape(b, 1, nkv, hd).transpose(0, 2, 1, 3)
        q = apply_rope(q, sin, cos)
        k = apply_rope(k, sin, cos)
        if cfg.kv_quant:
            k_cache, k_s, v_cache, v_s = cache
            kq, ks_tok, vq, vs_tok = quantize_kv_token(k, v)
            k_cache = jax.lax.dynamic_update_slice(k_cache, kq, (0, 0, pos, 0))
            v_cache = jax.lax.dynamic_update_slice(v_cache, vq, (0, 0, pos, 0))
            k_s = jax.lax.dynamic_update_slice(k_s, ks_tok, (0, 0, pos))
            v_s = jax.lax.dynamic_update_slice(v_s, vs_tok, (0, 0, pos))
            o = decode_attention_int8(q, k_cache, k_s, v_cache, v_s, cache_len=pos + 1)
            new_cache = (k_cache, k_s, v_cache, v_s)
        else:
            k_cache, v_cache = cache
            k_cache = jax.lax.dynamic_update_slice(
                k_cache, k.astype(k_cache.dtype), (0, 0, pos, 0)
            )
            v_cache = jax.lax.dynamic_update_slice(
                v_cache, v.astype(v_cache.dtype), (0, 0, pos, 0)
            )
            o = decode_attention(q, k_cache, v_cache, cache_len=pos + 1)
            new_cache = (k_cache, v_cache)
        o = o.transpose(0, 2, 1, 3).reshape(b, 1, nh * hd)
        x = x + o @ layer_p["wo"].astype(dt)
        hmid = rms_norm(x, layer_p["rms_mlp"], cfg.norm_eps)
        if cfg.is_moe:
            mlp = _moe(hmid.reshape(b, -1), layer_p, cfg).reshape(b, 1, -1)
        else:
            mlp = _swiglu(hmid, layer_p, dt)
        return x + mlp, new_cache

    x, new_cache = jax.lax.scan(body, x, (params["layers"], *kv_cache))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _mask_pad_vocab((x @ params["lm_head"].astype(cfg.dtype))[:, 0], cfg)
    return logits.astype(jnp.float32), new_cache
