"""Deterministic synthetic data pipelines with resumable iterator state."""
from .synthetic import (
    TokenPipeline,
    din_batch,
    graph_node_features,
    lm_batch,
)

__all__ = ["TokenPipeline", "lm_batch", "din_batch", "graph_node_features"]
