"""Deterministic synthetic batch generators.

Every batch is a pure function of ``(seed, step)`` so the pipeline is
(a) resumable from a checkpointed step counter with zero drift, and
(b) identical across hosts — each data-parallel shard slices the same
logical batch, which is how a real multi-host input pipeline behaves.

The LM stream is not uniform noise: it is a Zipf-ish unigram mix with a
copy structure (spans repeated within the sequence) so the cross-entropy
actually decreases during the smoke-train runs and optimizer bugs surface.
"""
from __future__ import annotations

from typing import Iterator

import numpy as np

__all__ = ["TokenPipeline", "lm_batch", "din_batch", "graph_node_features"]


def lm_batch(seed: int, step: int, batch: int, seq_len: int, vocab: int) -> dict:
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    # Zipf unigram distribution over a capped alphabet
    alpha = 1.2
    support = min(vocab, 4096)
    ranks = np.arange(1, support + 1)
    probs = ranks ** -alpha
    probs /= probs.sum()
    toks = rng.choice(support, size=(batch, seq_len + 1), p=probs).astype(np.int32)
    # copy structure: repeat a random span once per row
    span = max(4, seq_len // 16)
    starts = rng.integers(0, seq_len - 2 * span, size=batch)
    for i in range(batch):
        s = starts[i]
        toks[i, s + span : s + 2 * span] = toks[i, s : s + span]
    return {
        "tokens": toks[:, :-1],
        "labels": toks[:, 1:].astype(np.int32),
    }


class TokenPipeline:
    """Stateful wrapper: iteration order is a pure function of (seed, step)."""

    def __init__(self, batch: int, seq_len: int, vocab: int, seed: int = 0, step: int = 0):
        self.batch, self.seq_len, self.vocab = batch, seq_len, vocab
        self.seed, self.step = seed, step

    def state(self) -> dict:
        return {"seed": self.seed, "step": self.step}

    @classmethod
    def from_state(cls, batch, seq_len, vocab, state: dict) -> "TokenPipeline":
        return cls(batch, seq_len, vocab, seed=state["seed"], step=state["step"])

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        b = lm_batch(self.seed, self.step, self.batch, self.seq_len, self.vocab)
        self.step += 1
        return b


def din_batch(seed: int, step: int, batch: int, seq_len: int, n_items: int, n_cates: int) -> dict:
    rng = np.random.default_rng(np.random.SeedSequence([seed, step, 7]))
    hist = rng.zipf(1.3, size=(batch, seq_len)) % n_items
    lengths = rng.integers(1, seq_len + 1, size=batch)
    mask = np.arange(seq_len)[None, :] < lengths[:, None]
    hist = np.where(mask, hist, -1).astype(np.int32)
    target = (rng.zipf(1.3, size=batch) % n_items).astype(np.int32)
    # label correlates with target appearing in history → learnable signal
    label = ((hist == target[:, None]).any(axis=1) | (rng.random(batch) < 0.1)).astype(
        np.float32
    )
    return {
        "hist_items": hist,
        "hist_cates": np.where(hist >= 0, hist % n_cates, -1).astype(np.int32),
        "target_item": target,
        "target_cate": (target % n_cates).astype(np.int32),
        "label": label,
    }


def graph_node_features(seed: int, n_nodes: int, d_feat: int, n_classes: int):
    """Deterministic node features + labels with community structure."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, size=n_nodes)
    centers = rng.normal(size=(n_classes, d_feat)).astype(np.float32)
    feat = centers[labels] + 0.5 * rng.normal(size=(n_nodes, d_feat)).astype(np.float32)
    return feat.astype(np.float32), labels.astype(np.int32)
