"""Optimizers in the (init, update) functional style.

Moment tensors inherit the parameter PartitionSpecs (ZeRO-style: whatever
axis shards a weight shards its moments), so optimizer memory scales down
with the mesh exactly like parameter memory.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["OptState", "adamw", "sgd_momentum", "clip_by_global_norm", "apply_updates"]


class OptState(NamedTuple):
    step: jax.Array
    mu: dict | None
    nu: dict | None


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gnorm


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype), params, updates)


def adamw(
    lr: Callable[[jax.Array], jax.Array] | float,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    max_grad_norm: float | None = 1.0,
):
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params) -> OptState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return OptState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(zeros, params),
            nu=jax.tree.map(zeros, params),
        )

    def update(grads, state: OptState, params):
        gnorm = None
        if max_grad_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        step = state.step + 1
        t = step.astype(jnp.float32)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t
        lr_t = lr_fn(step)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mhat = m / bc1
            vhat = v / bc2
            u = -lr_t * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32))
            return u, m, v

        flat_g, tdef = jax.tree.flatten(grads)
        flat_m = tdef.flatten_up_to(state.mu)
        flat_v = tdef.flatten_up_to(state.nu)
        flat_p = tdef.flatten_up_to(params)
        out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        updates = tdef.unflatten([o[0] for o in out])
        mu = tdef.unflatten([o[1] for o in out])
        nu = tdef.unflatten([o[2] for o in out])
        return updates, OptState(step=step, mu=mu, nu=nu), gnorm

    return init, update


def sgd_momentum(lr, momentum: float = 0.9, nesterov: bool = False):
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params) -> OptState:
        return OptState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            nu=None,
        )

    def update(grads, state: OptState, params):
        del params
        step = state.step + 1
        lr_t = lr_fn(step)

        def upd(g, m):
            g = g.astype(jnp.float32)
            m = momentum * m + g
            d = g + momentum * m if nesterov else m
            return -lr_t * d, m

        flat_g, tdef = jax.tree.flatten(grads)
        flat_m = tdef.flatten_up_to(state.mu)
        out = [upd(g, m) for g, m in zip(flat_g, flat_m)]
        updates = tdef.unflatten([o[0] for o in out])
        mu = tdef.unflatten([o[1] for o in out])
        return updates, OptState(step=step, mu=mu, nu=None), None

    return init, update
