"""Self-contained optimizers + schedules (no external deps)."""
from .optimizers import OptState, adamw, sgd_momentum, clip_by_global_norm, apply_updates
from .schedules import constant, cosine_with_warmup, linear_warmup

__all__ = [
    "OptState",
    "adamw",
    "sgd_momentum",
    "clip_by_global_norm",
    "apply_updates",
    "constant",
    "cosine_with_warmup",
    "linear_warmup",
]
