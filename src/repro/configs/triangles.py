"""The paper's own workload: distributed triangle counting.

Shapes mirror the paper's Table I graphs (§IV).  A dry-run cell lowers the
sharded counting step from :mod:`repro.core.distributed` at production
graph sizes: the CSR arrays (``row_offsets``, ``col``, ``out_degree``)
replicate (the paper replicates them to every GPU), the striped directed
edge list shards over every mesh axis, and per-shard wedge buffers are
sized from the paper-reported wedge workload.

``wedge_factor`` ≈ Σ deg⁺(u)² / m_dir, estimated per graph family from
local measurements at smaller scales (Kronecker wedge load grows with
scale; BA/WS stay near-constant — the same skew effect §III-C discusses).
"""
from __future__ import annotations

import math

import jax.numpy as jnp

from repro.core.distributed import (
    make_distributed_count_fn,
    make_distributed_panel_count_fn,
)

from .base import DryRunSpec, named, rep, sds

ARCH_ID = "triangles"
FAMILY = "graph-analytics"

# n_nodes, undirected edge count (paper Table I), wedge factor, description
TRIANGLE_SHAPES = {
    "kron16": dict(n=1 << 16, m=5_000_000, wedge_factor=40.0),
    "kron18": dict(n=1 << 18, m=21_000_000, wedge_factor=48.0),
    "kron20": dict(n=1 << 20, m=89_000_000, wedge_factor=56.0),
    "kron21": dict(n=1 << 21, m=182_000_000, wedge_factor=60.0),
    "livejournal": dict(n=4_000_000, m=69_000_000, wedge_factor=18.0),
    "orkut": dict(n=3_100_000, m=234_000_000, wedge_factor=24.0),
}
SHAPES = tuple(TRIANGLE_SHAPES)


def full_config() -> dict:
    return dict(TRIANGLE_SHAPES)


def smoke_config() -> dict:
    return dict(n=1 << 10, m=20_000, wedge_factor=20.0)


# Measured on kron12/kron14 (see EXPERIMENTS.md §Perf) and extrapolated up
# the family: fraction of directed edges whose wider endpoint list fits the
# given panel width.  The >256 tail stays on the binary-search schedule —
# the paper's own §VI suggestion (different algorithm for the largest-degree
# vertices), inverted for TPU: panels for the bulk, search for the tail.
_PANEL_MIX = {16: 0.04, 64: 0.26, 256: 0.55}
_TAIL_FRACTION = 0.15


def build_dryrun(shape: str, mesh, variant: str = "baseline"):
    """§Perf variants:

    * ``"opt"``  — enumerate wedge candidates from the *shorter* endpoint
      list (Σ min(d⁺u, d⁺v) probes; measured 0.70× on Kronecker-12/14,
      0.54× on Barabási–Albert — see `ablation/shorter-side/*` rows),
    * ``"opt2"`` — hybrid schedule: ≤256-wide edges stream neighbor
      *panels* once (equality-tile reduction — the Pallas kernel dataflow,
      no per-probe gathers); the heavy tail keeps the shorter-side search.
    """
    spec = TRIANGLE_SHAPES[shape]
    n, m = spec["n"], spec["m"]
    m_dir = m  # paper's edge array holds 2m rows; orientation keeps m
    n_shards = math.prod(mesh.devices.shape)
    all_axes = tuple(mesh.axis_names)
    max_deg = int(math.isqrt(2 * m)) + 1  # forward bound: deg⁺ ≤ √(2m)
    steps = max(1, math.ceil(math.log2(max_deg + 1)))
    csr_args = (
        sds((n + 1,), jnp.int32),            # row_offsets (replicated)
        sds((m_dir,), jnp.int32),            # col (replicated)
        sds((n,), jnp.int32),                # out_degree (replicated)
    )
    csr_sh = (rep(mesh), rep(mesh), rep(mesh))

    if variant == "opt2":
        per_width = {
            w: max(1, -(-int(frac * m_dir) // n_shards))
            for w, frac in _PANEL_MIX.items()
        }
        panel_fn, widths = make_distributed_panel_count_fn(mesh, per_width)
        tail_e_per = max(1, -(-int(_TAIL_FRACTION * m_dir) // n_shards))
        wf_tail = spec["wedge_factor"] * 0.70 * 0.6  # tail carries the fat wedges
        tail_budget = int(wf_tail * tail_e_per / _TAIL_FRACTION * 1.25)
        search_fn = make_distributed_count_fn(
            mesh, tail_budget, steps, shorter_side=True
        )

        def step_fn(*args):
            k = len(widths)
            panel_args = args[: 2 * k]
            tail_src, tail_dst = args[2 * k : 2 * k + 2]
            csr = args[2 * k + 2 :]
            # search_fn emits per-segment partials (…, n_segments); collapse
            # for the combined dry-run output (compile-shape only, never run
            # on real data, so the int32 reduction here is fine)
            return panel_fn(*panel_args, *csr) + search_fn(tail_src, tail_dst, *csr).sum(
                axis=-1
            )

        edge_args = tuple(
            sds((n_shards, per_width[w]), jnp.int32) for w in widths for _ in (0,)
        )
        args = (
            *edge_args, *edge_args,  # src panels then dst panels
            sds((n_shards, tail_e_per), jnp.int32),
            sds((n_shards, tail_e_per), jnp.int32),
            *csr_args,
        )
        in_sh = (
            *([named(mesh, all_axes)] * (2 * len(widths) + 2)),
            *csr_sh,
        )
        total_wedges = spec["wedge_factor"] * 0.70 * m_dir
        return DryRunSpec(
            step_fn=step_fn,
            args=args,
            in_shardings=in_sh,
            description=f"{ARCH_ID} {shape} hybrid panel+search (opt2)",
            model_flops=total_wedges * steps * 8.0,
            tokens_per_step=m_dir,
        )

    e_per = -(-m_dir // n_shards)
    shorter = variant == "opt"
    wf = spec["wedge_factor"] * (0.70 if shorter else 1.0)
    wedge_budget = int(wf * e_per * 1.25)
    count_fn = make_distributed_count_fn(mesh, wedge_budget, steps, shorter_side=shorter)

    args = (
        sds((n_shards, e_per), jnp.int32),   # striped edge src
        sds((n_shards, e_per), jnp.int32),   # striped edge dst
        *csr_args,
    )
    in_sh = (named(mesh, all_axes), named(mesh, all_axes), *csr_sh)
    # useful work: one binary-search probe per wedge ≈ steps · 8 flop-equiv
    total_wedges = wf * m_dir
    return DryRunSpec(
        step_fn=count_fn,
        args=args,
        in_shardings=in_sh,
        description=f"{ARCH_ID} {shape} n={n} m={m} wedges≈{total_wedges:.2e}",
        model_flops=total_wedges * steps * 8.0,
        tokens_per_step=m_dir,
    )
