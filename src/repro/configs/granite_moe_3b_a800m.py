"""granite-moe-3b-a800m [hf:ibm-granite]: 32L, d=1536, 24H (kv=8), MoE 40e top-8.

The assignment line reads "MoE 40e top-8 — 32 experts top-8"; we follow the
primary spec (40 experts, top-8) and note the discrepancy in DESIGN.md §4.
"""
from repro.models.transformer import TransformerConfig

from .lm_common import LM_SHAPES, build_lm_dryrun, lm_smoke_config

ARCH_ID = "granite-moe-3b-a800m"
FAMILY = "lm"
SHAPES = tuple(LM_SHAPES)
MICRO_TARGET = 4


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID,
        n_layers=32,
        d_model=1536,
        n_heads=24,
        n_kv_heads=8,
        d_ff=512,
        vocab_size=49155,
        n_experts=40,
        top_k=8,
    )


def smoke_config() -> TransformerConfig:
    return lm_smoke_config(full_config())


def build_dryrun(shape: str, mesh, variant: str = "baseline"):
    return build_lm_dryrun(full_config(), shape, mesh, MICRO_TARGET, variant=variant)
