"""gcn-cora [arXiv:1609.02907]: 2 layers, d_hidden=16, mean/sym aggregation."""
import functools

from repro.models.gnn import gcn

from .gnn_common import GNN_SHAPES, build_gnn_dryrun

ARCH_ID = "gcn-cora"
FAMILY = "gnn"
SHAPES = tuple(GNN_SHAPES)


def make_cfg(d_in: int, d_out: int) -> gcn.GCNConfig:
    return gcn.GCNConfig(name=ARCH_ID, n_layers=2, d_hidden=16, d_in=d_in, d_out=d_out)


def smoke_config() -> gcn.GCNConfig:
    return gcn.GCNConfig(name=ARCH_ID, n_layers=2, d_hidden=8, d_in=12, d_out=3)


def build_dryrun(shape: str, mesh, variant: str = "baseline"):
    # per-layer ≈ 2·d_in·d_out FLOPs/node (matmul) + 2·d_out FLOPs/edge (agg)
    return build_gnn_dryrun(
        ARCH_ID, gcn, make_cfg, shape, mesh, variant=variant,
        flops_per_edge=2.0 * 16, flops_per_node=2.0 * GNN_SHAPES.get(shape, {}).get("d_feat", 64) * 16,
    )


MODEL = gcn
