"""din [arXiv:1706.06978]: embed_dim=18, seq 100, attn MLP 80-40, MLP 200-80.

Shapes: ``train_batch`` (65 536), ``serve_p99`` (512), ``serve_bulk``
(262 144), ``retrieval_cand`` (1 user × 10⁶ candidates as one batched
einsum — no loop).  Embedding tables are row-sharded over "model"; batches
shard over the data axes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.recsys import din as din_model
from repro.optim import adamw, apply_updates, constant

from .base import DryRunSpec, dp_axes, named, pad_to, rep, sds

ARCH_ID = "din"
FAMILY = "recsys"

DIN_SHAPES = {
    "train_batch": dict(kind="train", batch=65536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262144),
    "retrieval_cand": dict(kind="retrieval", batch=1, n_candidates=1_000_000),
}
SHAPES = tuple(DIN_SHAPES)


def full_config() -> din_model.DINConfig:
    return din_model.DINConfig(
        name=ARCH_ID, n_items=1_000_000, n_cates=10_000, embed_dim=18, seq_len=100,
        attn_mlp=(80, 40), mlp=(200, 80),
    )


def smoke_config() -> din_model.DINConfig:
    return din_model.DINConfig(
        name=ARCH_ID, n_items=1000, n_cates=50, embed_dim=8, seq_len=10,
        attn_mlp=(16, 8), mlp=(24, 12),
    )


def _param_shardings(mesh, params_sds):
    def rule(path_leaf):
        path, leaf = path_leaf
        name = "/".join(str(getattr(p, "key", p)) for p in path)
        if "item_table" in name or "cate_table" in name:
            return NamedSharding(mesh, P("model", None))
        return NamedSharding(mesh, P())

    flat, treedef = jax.tree_util.tree_flatten_with_path(params_sds)
    return jax.tree_util.tree_unflatten(treedef, [rule(x) for x in flat])


def _flops(cfg: din_model.DINConfig, batch: int, seq: int, train: bool) -> float:
    d2 = 2 * cfg.embed_dim
    attn = 2.0 * (4 * d2 * cfg.attn_mlp[0] + cfg.attn_mlp[0] * cfg.attn_mlp[1] + cfg.attn_mlp[1])
    mlp = 2.0 * (3 * d2 * cfg.mlp[0] + cfg.mlp[0] * cfg.mlp[1] + cfg.mlp[1])
    f = batch * (seq * attn + mlp)
    return f * (3.0 if train else 1.0)


def build_dryrun(shape: str, mesh, variant: str = "baseline"):
    """``variant="opt"`` (§Perf, serve/retrieval shapes): replicate the
    embedding tables — they are only ~77 MB, so row-sharding them buys
    nothing at inference while every lookup pays a cross-"model" exchange;
    replication deletes that collective entirely.  Training keeps the
    row-sharded tables (their fp32 moments are what sharding is for)."""
    cfg = full_config()
    spec = DIN_SHAPES[shape]
    dp = dp_axes(mesh)
    dpP = dp if len(dp) > 1 else dp[0]
    params_sds = jax.eval_shape(lambda k: din_model.init_params(k, cfg), jax.random.PRNGKey(0))
    replicate_tables = variant == "opt" and spec["kind"] != "train"
    if replicate_tables:
        param_sh = jax.tree.map(lambda _: rep(mesh), params_sds)
    else:
        param_sh = _param_shardings(mesh, params_sds)
    b = spec["batch"]
    s = cfg.seq_len

    def batch_sds(bsz):
        return {
            "hist_items": sds((bsz, s), jnp.int32),
            "hist_cates": sds((bsz, s), jnp.int32),
            "target_item": sds((bsz,), jnp.int32),
            "target_cate": sds((bsz,), jnp.int32),
            "label": sds((bsz,)),
        }

    def batch_sh(axis):
        return {
            "hist_items": named(mesh, axis, None),
            "hist_cates": named(mesh, axis, None),
            "target_item": named(mesh, axis),
            "target_cate": named(mesh, axis),
            "label": named(mesh, axis),
        }

    if spec["kind"] == "train":
        opt_init, opt_update = adamw(constant(1e-3), weight_decay=0.0)

        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(din_model.loss_fn)(params, cfg, batch)
            updates, opt_state, _ = opt_update(grads, opt_state, params)
            return apply_updates(params, updates), opt_state, {"loss": loss}

        from repro.optim import OptState

        opt_sds = jax.eval_shape(opt_init, params_sds)
        # moments of the tables shard like the tables; step replicates
        opt_sh = OptState(step=rep(mesh), mu=param_sh, nu=param_sh)
        return DryRunSpec(
            step_fn=step,
            args=(params_sds, opt_sds, batch_sds(b)),
            in_shardings=(param_sh, opt_sh, batch_sh(dpP)),
            donate_argnums=(0, 1),
            description=f"{ARCH_ID} train B={b}",
            model_flops=_flops(cfg, b, s, True),
            tokens_per_step=b,
        )

    if spec["kind"] == "serve":
        def step(params, batch):
            return din_model.apply(params, cfg, batch)

        bs = batch_sds(b)
        bs.pop("label")
        bh = batch_sh(dpP)
        bh.pop("label")
        return DryRunSpec(
            step_fn=step,
            args=(params_sds, bs),
            in_shardings=(param_sh, bh),
            description=f"{ARCH_ID} serve B={b}",
            model_flops=_flops(cfg, b, s, False),
            tokens_per_step=b,
        )

    # retrieval: 1 user, 1M candidates sharded over the whole mesh
    c = pad_to(spec["n_candidates"])  # −1-padded tail, masked by embedding_lookup
    all_axes = tuple(mesh.axis_names)

    def step(params, batch):
        return din_model.score_candidates(params, cfg, batch)

    args = (
        params_sds,
        {
            "hist_items": sds((1, s), jnp.int32),
            "hist_cates": sds((1, s), jnp.int32),
            "cand_items": sds((c,), jnp.int32),
            "cand_cates": sds((c,), jnp.int32),
        },
    )
    in_sh = (
        param_sh,
        {
            "hist_items": rep(mesh),
            "hist_cates": rep(mesh),
            "cand_items": named(mesh, all_axes),
            "cand_cates": named(mesh, all_axes),
        },
    )
    return DryRunSpec(
        step_fn=step,
        args=args,
        in_shardings=in_sh,
        out_shardings=named(mesh, all_axes),
        description=f"{ARCH_ID} retrieval C={c}",
        model_flops=_flops(cfg, c, s, False),
        tokens_per_step=c,
    )
