"""llama3.2-3b [hf:meta-llama]: 28L, d=3072, 24H (kv=8), dense, vocab 128256."""
from repro.models.transformer import TransformerConfig

from .lm_common import LM_SHAPES, build_lm_dryrun, lm_smoke_config

ARCH_ID = "llama3.2-3b"
FAMILY = "lm"
SHAPES = tuple(LM_SHAPES)
MICRO_TARGET = 2


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID,
        n_layers=28,
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=128256,
        rope_theta=500000.0,
    )


def smoke_config() -> TransformerConfig:
    return lm_smoke_config(full_config())


def build_dryrun(shape: str, mesh, variant: str = "baseline"):
    return build_lm_dryrun(full_config(), shape, mesh, MICRO_TARGET, variant=variant)
