"""deepseek-coder-33b [arXiv:2401.14196]: 62L, d=7168, 56H (kv=8), dense llama arch."""
from repro.models.transformer import TransformerConfig

from .lm_common import LM_SHAPES, build_lm_dryrun, lm_smoke_config

ARCH_ID = "deepseek-coder-33b"
FAMILY = "lm"
SHAPES = tuple(LM_SHAPES)
MICRO_TARGET = 1  # 33B dense: one 4k sequence per device per micro-step


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID,
        n_layers=62,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_ff=19200,
        vocab_size=32256,
    )


def smoke_config() -> TransformerConfig:
    return lm_smoke_config(full_config())


def build_dryrun(shape: str, mesh, variant: str = "baseline"):
    return build_lm_dryrun(full_config(), shape, mesh, MICRO_TARGET, variant=variant)
