"""egnn [arXiv:2102.09844]: 4 layers, d=64, E(n)-equivariant."""
from repro.models.gnn import egnn

from .gnn_common import GNN_SHAPES, build_gnn_dryrun

ARCH_ID = "egnn"
FAMILY = "gnn"
SHAPES = tuple(GNN_SHAPES)


def make_cfg(d_in: int, d_out: int) -> egnn.EGNNConfig:
    return egnn.EGNNConfig(name=ARCH_ID, n_layers=4, d_hidden=64, d_in=d_in, d_out=d_out)


def smoke_config() -> egnn.EGNNConfig:
    return egnn.EGNNConfig(name=ARCH_ID, n_layers=2, d_hidden=16, d_in=12, d_out=3)


def build_dryrun(shape: str, mesh, variant: str = "baseline"):
    # φ_e + φ_x per edge: ≈ 2·(129·64 + 64·64 + 64·64 + 64) FLOPs × 4 layers
    return build_gnn_dryrun(
        ARCH_ID, egnn, make_cfg, shape, mesh, variant=variant,
        flops_per_edge=4 * 2.0 * (129 * 64 + 2 * 64 * 64),
        flops_per_node=4 * 2.0 * (128 * 64 + 64 * 64),
    )


MODEL = egnn
