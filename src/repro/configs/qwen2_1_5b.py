"""qwen2-1.5b [arXiv:2407.10671]: 28L, d=1536, 12H (kv=2), QKV bias, vocab 151936."""
from repro.models.transformer import TransformerConfig

from .lm_common import LM_SHAPES, build_lm_dryrun, lm_smoke_config

ARCH_ID = "qwen2-1.5b"
FAMILY = "lm"
SHAPES = tuple(LM_SHAPES)
MICRO_TARGET = 4


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID,
        n_layers=28,
        d_model=1536,
        n_heads=12,
        n_kv_heads=2,
        d_ff=8960,
        vocab_size=151936,
        qkv_bias=True,
        rope_theta=1000000.0,
    )


def smoke_config() -> TransformerConfig:
    return lm_smoke_config(full_config())


def build_dryrun(shape: str, mesh, variant: str = "baseline"):
    return build_lm_dryrun(full_config(), shape, mesh, MICRO_TARGET, variant=variant)
