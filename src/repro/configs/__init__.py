"""Architecture registry: ``--arch <id>`` resolution for every launcher."""
from __future__ import annotations

from . import (
    deepseek_coder_33b,
    din,
    egnn,
    gcn_cora,
    granite_moe_3b_a800m,
    graphsage_reddit,
    llama3_2_3b,
    olmoe_1b_7b,
    qwen2_1_5b,
    schnet,
    triangles,
)

ARCH_MODULES = [
    olmoe_1b_7b,
    granite_moe_3b_a800m,
    deepseek_coder_33b,
    llama3_2_3b,
    qwen2_1_5b,
    schnet,
    gcn_cora,
    graphsage_reddit,
    egnn,
    din,
    triangles,
]

REGISTRY = {m.ARCH_ID: m for m in ARCH_MODULES}

# the 40 assigned (arch × shape) cells; the paper's own `triangles` cells
# are additional
ASSIGNED_CELLS = [
    (m.ARCH_ID, s) for m in ARCH_MODULES if m.ARCH_ID != "triangles" for s in m.SHAPES
]
ALL_CELLS = ASSIGNED_CELLS + [("triangles", s) for s in triangles.SHAPES]


def get_arch(arch_id: str):
    if arch_id not in REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[arch_id]


__all__ = ["REGISTRY", "ARCH_MODULES", "ASSIGNED_CELLS", "ALL_CELLS", "get_arch"]
