"""Shared builders for the five LM architectures.

Each LM arch module supplies a :class:`~repro.models.transformer.TransformerConfig`
plus a per-device microbatch target; this module turns (config × shape ×
mesh) into a :class:`~repro.configs.base.DryRunSpec`:

* ``train_4k``    → full train step (grad-accum scan → AdamW update),
* ``prefill_32k`` → prefill returning last-token logits + KV caches,
* ``decode_32k``  → one decode step against a (B, 32k) KV cache,
* ``long_500k``   → one decode step against a 524 288-token cache whose
  sequence axis is sharded over **all** mesh axes (flash-decoding as
  sharded reductions; see DESIGN.md §4 on why the 500k *decode* cell runs
  for full-attention archs while 500k *prefill* does not exist).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.sharding import lm_rules, moe_rules_patch, make_param_shardings, spec_for
from repro.models import transformer as tfm
from repro.optim import adamw, apply_updates, cosine_with_warmup

from .base import DryRunSpec, dp_axes, named, rep, sds

__all__ = ["LM_SHAPES", "build_lm_dryrun", "lm_smoke_config", "make_lm_train_step"]

LM_SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode_long", seq=524288, batch=1),
}


def _rules_for(cfg: tfm.TransformerConfig, mesh, tp_only: bool = False):
    fsdp = dp_axes(mesh)
    rules = lm_rules(fsdp, tp_only=tp_only)
    if cfg.is_moe:
        rules = moe_rules_patch(rules, fsdp, tp_only=tp_only)
    return rules


# fp32 master + 2 fp32 moments must fit in one TP shard's HBM to drop FSDP
_TP_ONLY_BUDGET = 16e9 / 12 * 16  # ≈ params ≤ 21B at TP16… gated at 8B below


def _use_tp_only(cfg: tfm.TransformerConfig, mesh) -> bool:
    tp = mesh.shape["model"]
    bytes_per_dev = cfg.n_params() * 12 / tp  # fp32 master + mu + nu
    return bytes_per_dev < 8e9  # leave ≥8 GB for activations/caches


def _param_specs(cfg, mesh, tp_only: bool = False):
    params_sds = jax.eval_shape(
        lambda k: tfm.init_params(k, cfg), jax.random.PRNGKey(0)
    )
    rules = _rules_for(cfg, mesh, tp_only=tp_only)
    shardings = make_param_shardings(mesh, rules, params_sds)
    return params_sds, shardings, rules


def make_lm_train_step(cfg: tfm.TransformerConfig, accum: int, grad_specs=None, lr=None):
    """Grad-accumulation train step.

    ``grad_specs`` (a pytree of PartitionSpec matching the params) pins the
    accumulated-gradient scan carry to the parameter sharding — without it
    GSPMD tends to replicate the carry, which multiplies per-device temp
    memory by the DP degree.
    """
    opt_init, opt_update = adamw(lr or cosine_with_warmup(3e-4, 2000, 100_000))

    def constrain(tree):
        if grad_specs is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree, grad_specs)

    def train_step(params, opt_state, batch):
        def micro_grads(mb):
            return jax.value_and_grad(tfm.loss_fn)(params, mb, cfg)

        if accum == 1:
            mb = jax.tree.map(lambda x: x[0], batch)
            loss, grads = micro_grads(mb)
            grads = constrain(grads)
        else:
            def body(carry, mb):
                loss_acc, grads_acc = carry
                loss, grads = micro_grads(mb)
                grads_acc = constrain(jax.tree.map(jnp.add, grads_acc, grads))
                return (loss_acc + loss, grads_acc), None

            zeros = constrain(
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            )
            (loss, grads), _ = jax.lax.scan(body, (jnp.float32(0), zeros), batch)
            loss = loss / accum
            grads = jax.tree.map(lambda g: g / accum, grads)
        updates, opt_state, gnorm = opt_update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, {"loss": loss, "gnorm": gnorm}

    return train_step, opt_init


def _opt_state_specs(params_sds, rules, mesh, opt_init):
    opt_sds = jax.eval_shape(opt_init, params_sds)
    shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_for(rules, opt_sds)
    )
    return opt_sds, shardings


def _accum_for(cfg, mesh, shape, micro_target: int):
    dp = 1
    for a in dp_axes(mesh):
        dp *= mesh.shape[a]
    per_dev = shape["batch"] // dp
    if per_dev == 0:
        raise ValueError(f"batch {shape['batch']} smaller than dp={dp}")
    accum = max(1, per_dev // micro_target)
    while shape["batch"] % (dp * accum):
        accum -= 1
    return accum, shape["batch"] // accum


def build_lm_dryrun(
    cfg: tfm.TransformerConfig,
    shape_name: str,
    mesh,
    micro_target: int = 2,
    variant: str = "baseline",
):
    """§Perf variants:

    * ``"opt"``  — one-hot CE (no logits all-gather) + TP-only weights when
      master+moments fit one TP shard (no per-microbatch FSDP gathers),
    * ``"opt2"`` — opt + ``dots_saveable`` remat (matmul outputs kept, only
      elementwise replayed: trades activation memory for the ~2ND replay
      FLOPs that cap MFU at 0.75 under full remat).
    """
    import dataclasses

    shape = LM_SHAPES[shape_name]
    tp_only = variant in ("opt", "opt2") and _use_tp_only(cfg, mesh)
    if variant in ("opt", "opt2"):
        cfg = dataclasses.replace(cfg, onehot_ce=True)
    if variant == "opt2":
        cfg = dataclasses.replace(cfg, remat_policy="dots")
    dp = dp_axes(mesh)
    dpP = dp if len(dp) > 1 else dp[0]
    params_sds, param_sh, rules = _param_specs(cfg, mesh, tp_only=tp_only)
    b, s = shape["batch"], shape["seq"]

    if shape["kind"] == "train":
        accum, micro_total = _accum_for(cfg, mesh, shape, micro_target)
        grad_specs = spec_for(rules, params_sds)
        step, opt_init = make_lm_train_step(cfg, accum, grad_specs=grad_specs)
        opt_sds, opt_sh = _opt_state_specs(params_sds, rules, mesh, opt_init)
        batch_sds = {
            "tokens": sds((accum, micro_total, s), jnp.int32),
            "labels": sds((accum, micro_total, s), jnp.int32),
        }
        batch_sh = {
            "tokens": named(mesh, None, dpP, None),
            "labels": named(mesh, None, dpP, None),
        }
        tokens = b * s
        return DryRunSpec(
            step_fn=step,
            args=(params_sds, opt_sds, batch_sds),
            in_shardings=(param_sh, opt_sh, batch_sh),
            donate_argnums=(0, 1),
            description=f"{cfg.name} train accum={accum}",
            model_flops=6.0 * cfg.n_active_params() * tokens,
            n_params=cfg.n_params(),
            tokens_per_step=tokens,
        )

    if shape["kind"] == "prefill":
        def prefill_step(params, tokens):
            return tfm.prefill(params, tokens, cfg)

        cache_spec = P(None, dpP, None, "model", None)
        out_sh = (
            named(mesh, dpP, "model"),                       # last logits (B, V)
            (NamedSharding(mesh, cache_spec), NamedSharding(mesh, cache_spec)),
        )
        tokens = b * s
        return DryRunSpec(
            step_fn=prefill_step,
            args=(params_sds, sds((b, s), jnp.int32)),
            in_shardings=(param_sh, named(mesh, dpP, None)),
            out_shardings=out_sh,
            description=f"{cfg.name} prefill",
            model_flops=2.0 * cfg.n_active_params() * tokens
            + 4.0 * b * cfg.n_heads * cfg.head_dim * s * s / 2,
            n_params=cfg.n_params(),
            tokens_per_step=tokens,
        )

    # decode kinds
    long = shape["kind"] == "decode_long"
    kv_quant = variant in ("opt", "opt2")
    if kv_quant:
        cfg = dataclasses.replace(cfg, kv_quant=True)
    seq_axes = (*dp, "model") if long else ("model",)
    seq_spec = seq_axes if long else "model"
    batch_axis = None if long else dpP
    cache_sh = NamedSharding(mesh, P(None, batch_axis, None, seq_spec, None))
    scale_sh = NamedSharding(mesh, P(None, batch_axis, None, seq_spec))
    kv_shape = (cfg.n_layers, b, cfg.n_kv_heads, s, cfg.head_dim)
    if kv_quant:
        cache_sds = (
            sds(kv_shape, jnp.int8),
            sds(kv_shape[:-1], jnp.float32),
            sds(kv_shape, jnp.int8),
            sds(kv_shape[:-1], jnp.float32),
        )
        cache_shardings = (cache_sh, scale_sh, cache_sh, scale_sh)
    else:
        cache_sds = (sds(kv_shape, cfg.dtype), sds(kv_shape, cfg.dtype))
        cache_shardings = (cache_sh, cache_sh)

    def decode(params, token, pos, cache):
        return tfm.decode_step(params, token, pos, cache, cfg)

    attn_flops = 4.0 * b * cfg.n_heads * cfg.head_dim * s
    return DryRunSpec(
        step_fn=decode,
        args=(params_sds, sds((b,), jnp.int32), sds((), jnp.int32), cache_sds),
        in_shardings=(
            param_sh,
            named(mesh, batch_axis),
            rep(mesh),
            cache_shardings,
        ),
        out_shardings=(None, cache_shardings),
        donate_argnums=(3,),
        description=f"{cfg.name} decode S={s} B={b} kv_quant={kv_quant}",
        model_flops=2.0 * cfg.n_active_params() * b + attn_flops,
        n_params=cfg.n_params(),
        tokens_per_step=b,
    )


def lm_smoke_config(cfg: tfm.TransformerConfig) -> tfm.TransformerConfig:
    """Same family, tiny dims, fp32 — one train step must run on CPU."""
    import dataclasses

    return dataclasses.replace(
        cfg,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        d_ff=96 if not cfg.is_moe else 32,
        vocab_size=250,   # pads to 256: the vocab-padding path stays covered
        vocab_pad=64,
        n_experts=min(cfg.n_experts, 8),
        top_k=min(cfg.top_k, 2) if cfg.is_moe else 0,
        dtype=jnp.float32,
        remat=False,
    )
