"""OLMoE-1B-7B [arXiv:2409.02060]: 16L, d=2048, 16H (kv=16), MoE 64e top-8."""
from repro.models.transformer import TransformerConfig

from .lm_common import LM_SHAPES, build_lm_dryrun, lm_smoke_config

ARCH_ID = "olmoe-1b-7b"
FAMILY = "lm"
SHAPES = tuple(LM_SHAPES)
MICRO_TARGET = 4


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID,
        n_layers=16,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1024,
        vocab_size=50304,
        n_experts=64,
        top_k=8,
    )


def smoke_config() -> TransformerConfig:
    return lm_smoke_config(full_config())


def build_dryrun(shape: str, mesh, variant: str = "baseline"):
    return build_lm_dryrun(full_config(), shape, mesh, MICRO_TARGET, variant=variant)
