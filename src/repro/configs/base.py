"""Shared plumbing for architecture configs and the dry-run driver."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["DryRunSpec", "sds", "dp_axes", "named", "rep"]


@dataclasses.dataclass
class DryRunSpec:
    """Everything needed to ``jit(...).lower(...).compile()`` one cell."""

    step_fn: Callable
    args: tuple                      # pytrees of ShapeDtypeStruct
    in_shardings: Any                # pytree (prefix) of NamedSharding
    out_shardings: Any = None
    donate_argnums: tuple = ()
    description: str = ""
    model_flops: float = 0.0         # "useful" FLOPs for §Roofline
    n_params: int = 0
    tokens_per_step: int = 0

    def lower(self):
        kwargs = {}
        if self.out_shardings is not None:
            kwargs["out_shardings"] = self.out_shardings
        fn = jax.jit(
            self.step_fn,
            in_shardings=self.in_shardings,
            donate_argnums=self.donate_argnums,
            **kwargs,
        )
        return fn.lower(*self.args)


def sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def pad_to(n: int, multiple: int = 512) -> int:
    """Round a sharded dimension up to the mesh-divisible size.

    Real pipelines pad ragged shards the same way (−1-padded edges /
    candidate ids are masked by every consumer in this codebase).
    """
    return -(-n // multiple) * multiple


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    """Batch-parallel axes = every mesh axis except 'model'."""
    return tuple(a for a in mesh.axis_names if a != "model")


def named(mesh: Mesh, *spec) -> NamedSharding:
    return NamedSharding(mesh, P(*spec))


def rep(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
