"""Shared builders for the four GNN architectures × four graph shapes.

Shapes (assigned):

* ``full_graph_sm``  — Cora-size full-batch training (2 708 / 10 556 / 1433),
* ``minibatch_lg``   — Reddit-size sampled training (232 965 nodes,
  114.6M directed edges, 1 024 seed nodes, fanout 15-10) with the *real*
  fanout sampler from :mod:`repro.graphs.sampling` running inside the step,
* ``ogb_products``   — 2.45M-node / 61.9M-edge full-batch,
* ``molecule``       — 128 × (30-node, 64-edge) batched small graphs,
  regression readout.

Distribution (paper-derived): node features replicated, **edge lists
partitioned** across the whole mesh, partial aggregations reduced — the
multi-GPU scheme of the paper transplanted onto message passing.  For the
minibatch shape the sampler state (seeds) shards over the batch axes.

Non-SAGE archs have no native layered-block formulation, so the sampled
frontiers are linearized into an explicit block *graph* (child→parent
edges) and run through the arch's ordinary edge-list ``apply`` — one code
path serves all four archs on ``minibatch_lg``.  GraphSAGE uses its
faithful ``apply_blocks``.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.graphs.sampling import sample_blocks
from repro.optim import adamw, apply_updates, constant

from .base import DryRunSpec, dp_axes, named, pad_to, rep, sds

__all__ = ["GNN_SHAPES", "build_gnn_dryrun", "block_graph_from_frontiers"]

GNN_SHAPES = {
    "full_graph_sm": dict(
        kind="full", n_nodes=2708, n_edges=10556, d_feat=1433, n_classes=7
    ),
    "minibatch_lg": dict(
        kind="minibatch",
        n_nodes=232965,
        n_edges=114615892,
        batch_nodes=1024,
        fanout=(15, 10),
        d_feat=602,
        n_classes=41,
    ),
    "ogb_products": dict(
        kind="full", n_nodes=2449029, n_edges=61859140, d_feat=100, n_classes=47
    ),
    "molecule": dict(kind="batched", n_nodes=30, n_edges=64, batch=128, d_feat=16),
}


def block_graph_from_frontiers(frontiers, fanouts):
    """Linearize sampled frontiers into one block graph.

    Returns (block_node_ids, edge_src, edge_dst): positions index into the
    concatenated frontier list; edges run child→parent and parent→child.
    """
    offsets = [0]
    for f in frontiers:
        offsets.append(offsets[-1] + f.shape[0])
    nodes = jnp.concatenate(frontiers)
    srcs, dsts = [], []
    for lvl, fanout in enumerate(fanouts):
        n_parent = frontiers[lvl].shape[0]
        parent_pos = offsets[lvl] + jnp.arange(n_parent, dtype=jnp.int32)
        child_pos = offsets[lvl + 1] + jnp.arange(n_parent * fanout, dtype=jnp.int32)
        parent_rep = jnp.repeat(parent_pos, fanout)
        srcs += [child_pos, parent_rep]
        dsts += [parent_rep, child_pos]
    return nodes, jnp.concatenate(srcs), jnp.concatenate(dsts)


def _synth_positions(node_ids: jax.Array) -> jax.Array:
    """Deterministic pseudo-positions for geometric models on non-molecular
    graphs (DESIGN.md §4): a cheap integer hash → 3 floats in [−1, 1]."""
    x = node_ids.astype(jnp.uint32)
    out = []
    for c in (2654435761, 2246822519, 3266489917):
        h = (x * jnp.uint32(c)) ^ (x >> jnp.uint32(13))
        out.append((h % jnp.uint32(65536)).astype(jnp.float32) / 32768.0 - 1.0)
    return jnp.stack(out, axis=1)


def _ce_loss(logits, labels, n_valid=None):
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, jnp.maximum(labels, 0)[:, None], axis=-1)[:, 0]
    valid = (labels >= 0).astype(jnp.float32)  # −1 = padded node
    return -jnp.sum(ll * valid) / jnp.maximum(jnp.sum(valid), 1.0)


def _estimate_flops(arch_flops_per_edge, arch_flops_per_node, n_nodes, n_edges, train=True):
    f = arch_flops_per_edge * n_edges + arch_flops_per_node * n_nodes
    return f * (3.0 if train else 1.0)


def build_gnn_dryrun(
    arch_id: str,
    model_mod,            # repro.models.gnn.<arch> module
    make_cfg: Callable,   # (d_in, d_out) -> config dataclass
    shape_name: str,
    mesh,
    flops_per_edge: float,
    flops_per_node: float,
    variant: str = "baseline",
):
    """§Perf variants (full-graph shapes):

    * ``variant="opt"`` — keep the paper's replicated-nodes /
      partitioned-edges scheme but run aggregation in **bf16**: the
      dominant collective is the per-layer psum of (N, d) partial
      aggregates, whose bytes halve with the dtype.
    * ``variant="nodeshard"`` — node-sharded features (tried first and
      REFUTED: GSPMD cannot halo-exchange an unstructured gather, so it
      all-gathers the sharded features *and* reshards — ~2× worse;
      kept selectable for the record).
    """
    shape = GNN_SHAPES[shape_name]
    dp = dp_axes(mesh)
    dpP = dp if len(dp) > 1 else dp[0]
    all_axes = tuple(mesh.axis_names)
    opt_init, opt_update = adamw(constant(1e-3), weight_decay=0.0)
    node_sharded = variant == "nodeshard" and shape["kind"] == "full"

    if shape["kind"] == "full":
        n, e, f, c = shape["n_nodes"], shape["n_edges"], shape["d_feat"], shape["n_classes"]
        e = pad_to(e)  # −1-padded tail; every consumer masks
        if node_sharded:
            n = pad_to(n)  # padded nodes carry label −1 (masked in the loss)
        cfg = make_cfg(f, c)
        shardmap_psum = variant == "opt2" and hasattr(make_cfg(1, 1), "psum_axes")
        if variant in ("opt", "opt2"):
            import dataclasses

            cfg = dataclasses.replace(cfg, dtype=jnp.bfloat16)
            if hasattr(cfg, "smart_order"):
                cfg = dataclasses.replace(cfg, smart_order=True)
        if shardmap_psum:
            import dataclasses

            # explicit shard_map edge-parallelism: per-layer psums emitted
            # in bf16 (GSPMD's implicit all-reduce hoists the upcast)
            cfg = dataclasses.replace(cfg, psum_axes=all_axes)
        params_sds = jax.eval_shape(lambda k: model_mod.init_params(k, cfg), jax.random.PRNGKey(0))

        if shardmap_psum:
            import inspect

            try:  # jax ≥ 0.6 exports shard_map at top level
                from jax import shard_map
            except ImportError:  # jax 0.4.x keeps it under jax.experimental
                from jax.experimental.shard_map import shard_map
            # jax renamed check_rep → check_vma; pass whichever exists
            _ckw = (
                "check_vma"
                if "check_vma" in inspect.signature(shard_map).parameters
                else "check_rep"
            )

            def shard_loss(p, feat, pos, src, dst, labels):
                out = model_mod.apply(
                    p, cfg, feat, pos, src.reshape(-1), dst.reshape(-1)
                )
                return _ce_loss(out, labels)

            sharded_loss = shard_map(
                shard_loss,
                mesh=mesh,
                in_specs=(P(), P(), P(), P(all_axes), P(all_axes), P()),
                out_specs=P(),
                **{_ckw: False},
            )

            def step(params, opt_state, feat, pos, edge_src, edge_dst, labels):
                l, grads = jax.value_and_grad(
                    lambda p: sharded_loss(p, feat, pos, edge_src, edge_dst, labels)
                )(params)
                updates, opt_state, _ = opt_update(grads, opt_state, params)
                return apply_updates(params, updates), opt_state, {"loss": l}
        else:
            def step(params, opt_state, feat, pos, edge_src, edge_dst, labels):
                def loss(p):
                    out = model_mod.apply(p, cfg, feat, pos, edge_src, edge_dst)
                    if node_sharded:
                        out = jax.lax.with_sharding_constraint(
                            out, NamedSharding(mesh, P(all_axes, None))
                        )
                    return _ce_loss(out, labels)

                l, grads = jax.value_and_grad(loss)(params)
                updates, opt_state, _ = opt_update(grads, opt_state, params)
                return apply_updates(params, updates), opt_state, {"loss": l}

        opt_sds = jax.eval_shape(opt_init, params_sds)
        args = (
            params_sds,
            opt_sds,
            sds((n, f)),
            sds((n, 3)),
            sds((e,), jnp.int32),
            sds((e,), jnp.int32),
            sds((n,), jnp.int32),
        )
        node_sh = named(mesh, all_axes, None) if node_sharded else rep(mesh)
        label_sh = named(mesh, all_axes) if node_sharded else rep(mesh)
        in_sh = (
            rep(mesh),
            rep(mesh),
            node_sh,
            node_sh,
            named(mesh, all_axes),
            named(mesh, all_axes),
            label_sh,
        )
        return DryRunSpec(
            step_fn=step,
            args=args,
            in_shardings=in_sh,
            donate_argnums=(0, 1),
            description=f"{arch_id} full-graph N={n} E={e} ({variant})",
            model_flops=_estimate_flops(flops_per_edge, flops_per_node, n, e),
            n_params=0,
            tokens_per_step=n,
        )

    if shape["kind"] == "minibatch":
        n, e, f, c = shape["n_nodes"], shape["n_edges"], shape["d_feat"], shape["n_classes"]
        b, fanout = shape["batch_nodes"], shape["fanout"]
        cfg = make_cfg(f, c)
        params_sds = jax.eval_shape(lambda k: model_mod.init_params(k, cfg), jax.random.PRNGKey(0))
        use_blocks = hasattr(model_mod, "apply_blocks")

        def step(params, opt_state, key, row_offsets, col, feat, seeds, labels):
            blocks = sample_blocks(key, row_offsets, col, seeds, fanout)

            def loss(p):
                if use_blocks:
                    feats = [jnp.take(feat, fr, axis=0) for fr in blocks.frontiers]
                    out = model_mod.apply_blocks(p, cfg, feats, fanout)
                else:
                    nodes, esrc, edst = block_graph_from_frontiers(blocks.frontiers, fanout)
                    nf = jnp.take(feat, nodes, axis=0)
                    pos = _synth_positions(nodes)
                    out = model_mod.apply(p, cfg, nf, pos, esrc, edst)[: seeds.shape[0]]
                return _ce_loss(out, labels)

            l, grads = jax.value_and_grad(loss)(params)
            updates, opt_state, _ = opt_update(grads, opt_state, params)
            return apply_updates(params, updates), opt_state, {"loss": l}

        opt_sds = jax.eval_shape(opt_init, params_sds)
        args = (
            params_sds,
            opt_sds,
            sds((2,), jnp.uint32),
            sds((n + 1,), jnp.int32),
            sds((e,), jnp.int32),
            sds((n, f)),
            sds((b,), jnp.int32),
            sds((b,), jnp.int32),
        )
        in_sh = (
            rep(mesh),
            rep(mesh),
            rep(mesh),
            rep(mesh),
            rep(mesh),
            rep(mesh),
            named(mesh, dpP),
            named(mesh, dpP),
        )
        sampled_edges = b * (fanout[0] + fanout[0] * fanout[1]) * 2
        sampled_nodes = b * (1 + fanout[0] + fanout[0] * fanout[1])
        return DryRunSpec(
            step_fn=step,
            args=args,
            in_shardings=in_sh,
            donate_argnums=(0, 1),
            description=f"{arch_id} minibatch B={b} fanout={fanout}",
            model_flops=_estimate_flops(flops_per_edge, flops_per_node, sampled_nodes, sampled_edges),
            n_params=0,
            tokens_per_step=b,
        )

    # batched small graphs (molecule): regression readout
    nb, ne, batch, f = shape["n_nodes"], shape["n_edges"], shape["batch"], shape["d_feat"]
    cfg = make_cfg(f, 1)
    params_sds = jax.eval_shape(lambda k: model_mod.init_params(k, cfg), jax.random.PRNGKey(0))

    def step(params, opt_state, node_feat, positions, edge_src, edge_dst, labels):
        def loss(p):
            bsz = node_feat.shape[0]
            flat_feat = node_feat.reshape(bsz * nb, -1)
            flat_pos = positions.reshape(bsz * nb, 3)
            off = (jnp.arange(bsz, dtype=jnp.int32) * nb)[:, None]
            fsrc = jnp.where(edge_src >= 0, edge_src + off, -1).reshape(-1)
            fdst = jnp.where(edge_dst >= 0, edge_dst + off, -1).reshape(-1)
            out = model_mod.apply(p, cfg, flat_feat, flat_pos, fsrc, fdst)  # (B*nb, 1)
            graph_ids = jnp.repeat(jnp.arange(bsz, dtype=jnp.int32), nb)
            pred = jax.ops.segment_sum(out[:, 0], graph_ids, num_segments=bsz)
            return jnp.mean((pred - labels) ** 2)

        l, grads = jax.value_and_grad(loss)(params)
        updates, opt_state, _ = opt_update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state, {"loss": l}

    opt_sds = jax.eval_shape(opt_init, params_sds)
    args = (
        params_sds,
        opt_sds,
        sds((batch, nb, f)),
        sds((batch, nb, 3)),
        sds((batch, ne), jnp.int32),
        sds((batch, ne), jnp.int32),
        sds((batch,)),
    )
    in_sh = (
        rep(mesh),
        rep(mesh),
        named(mesh, dpP, None, None),
        named(mesh, dpP, None, None),
        named(mesh, dpP, None),
        named(mesh, dpP, None),
        named(mesh, dpP),
    )
    return DryRunSpec(
        step_fn=step,
        args=args,
        in_shardings=in_sh,
        donate_argnums=(0, 1),
        description=f"{arch_id} molecule batch={batch}",
        model_flops=_estimate_flops(flops_per_edge, flops_per_node, batch * nb, batch * ne),
        n_params=0,
        tokens_per_step=batch,
    )
