"""schnet [arXiv:1706.08566]: 3 interactions, d=64, 300 RBF, cutoff 10."""
from repro.models.gnn import schnet

from .gnn_common import GNN_SHAPES, build_gnn_dryrun

ARCH_ID = "schnet"
FAMILY = "gnn"
SHAPES = tuple(GNN_SHAPES)


def make_cfg(d_in: int, d_out: int) -> schnet.SchNetConfig:
    return schnet.SchNetConfig(
        name=ARCH_ID, n_interactions=3, d_hidden=64, n_rbf=300, cutoff=10.0,
        d_in=d_in, d_out=d_out,
    )


def smoke_config() -> schnet.SchNetConfig:
    return schnet.SchNetConfig(
        name=ARCH_ID, n_interactions=2, d_hidden=16, n_rbf=24, d_in=12, d_out=3
    )


def build_dryrun(shape: str, mesh, variant: str = "baseline"):
    # filter MLP dominates: ≈ 2·(300·64 + 64·64) FLOPs per edge per interaction
    return build_gnn_dryrun(
        ARCH_ID, schnet, make_cfg, shape, mesh, variant=variant,
        flops_per_edge=3 * 2.0 * (300 * 64 + 64 * 64),
        flops_per_node=3 * 4.0 * 64 * 64,
    )


MODEL = schnet
