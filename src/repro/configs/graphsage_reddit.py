"""graphsage-reddit [arXiv:1706.02216]: 2 layers, d=128, mean agg, samples 25-10."""
from repro.models.gnn import graphsage

from .gnn_common import GNN_SHAPES, build_gnn_dryrun

ARCH_ID = "graphsage-reddit"
FAMILY = "gnn"
SHAPES = tuple(GNN_SHAPES)


def make_cfg(d_in: int, d_out: int) -> graphsage.SAGEConfig:
    return graphsage.SAGEConfig(
        name=ARCH_ID, n_layers=2, d_hidden=128, d_in=d_in, d_out=d_out,
        sample_sizes=(25, 10),
    )


def smoke_config() -> graphsage.SAGEConfig:
    return graphsage.SAGEConfig(name=ARCH_ID, n_layers=2, d_hidden=16, d_in=12, d_out=3)


def build_dryrun(shape: str, mesh, variant: str = "baseline"):
    return build_gnn_dryrun(
        ARCH_ID, graphsage, make_cfg, shape, mesh, variant=variant,
        flops_per_edge=2.0 * 128,
        flops_per_node=4.0 * GNN_SHAPES.get(shape, {}).get("d_feat", 64) * 128,
    )


MODEL = graphsage
