"""Pallas TPU flash attention (forward) with GQA head sharing.

Canonical revisited-block schedule: grid ``(B·Hq, n_q_blocks,
n_kv_blocks)`` with running (m, l, acc) softmax state in VMEM scratch,
initialized at the first kv block and finalized at the last.  The kv-block
index maps for K/V divide the head index by the GQA group size, so grouped
queries read the same K/V tiles without materializing repeats.

MXU alignment: q/k/v tiles are (TQ, D) / (TK, D) with TQ=TK=128 by default
and D the head dim (128 for every assigned LM arch) — all contraction dims
are multiples of the 128-lane systolic array.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU scratch memory spaces; the interpreter accepts them too
    from jax.experimental.pallas import tpu as pltpu

    _SCRATCH = pltpu.VMEM
except Exception:  # pragma: no cover
    _SCRATCH = None

__all__ = ["flash_attention_pallas"]

_NEG_INF = -1e30


def _kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *, causal, sm_scale, tq, tk, nk, sq, skv
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)  # (TQ, D)
    k = k_ref[0].astype(jnp.float32)  # (TK, D)
    v = v_ref[0].astype(jnp.float32)
    # Sanitize block-padding rows past the true kv length: out-of-bounds
    # tile reads are undefined, and 0·garbage must stay 0 in p @ v.
    row_valid = ik * tk + jax.lax.broadcasted_iota(jnp.int32, (tk, 1), 0) < skv
    k = jnp.where(row_valid, k, 0.0)
    v = jnp.where(row_valid, v, 0.0)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * sm_scale  # (TQ, TK)
    qi = iq * tq + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 0)
    kj = ik * tk + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)
    valid = kj < skv  # mask block padding past the true kv length
    if causal:
        valid &= qi + (skv - sq) >= kj
    s = jnp.where(valid, s, _NEG_INF)
    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    # `where` (not bare exp) so a fully-masked block contributes 0, not e⁰
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
    l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ik == nk - 1)
    def _fin():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "sm_scale", "block_q", "block_k", "interpret")
)
def flash_attention_pallas(
    q: jax.Array,  # (B, Hq, Sq, D)
    k: jax.Array,  # (B, Hkv, Skv, D)
    v: jax.Array,
    causal: bool = True,
    sm_scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    g = hq // hkv
    if sm_scale is None:
        sm_scale = d ** -0.5
    tq = min(block_q, sq)
    tk = min(block_k, skv)
    nq = pl.cdiv(sq, tq)
    nk = pl.cdiv(skv, tk)
    qf = q.reshape(b * hq, sq, d)
    kf = k.reshape(b * hkv, skv, d)
    vf = v.reshape(b * hkv, skv, d)

    kernel = functools.partial(
        _kernel, causal=causal, sm_scale=sm_scale, tq=tq, tk=tk, nk=nk, sq=sq, skv=skv
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, tq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, tk, d), lambda h, i, j, g=g: (h // g, j, 0)),
            pl.BlockSpec((1, tk, d), lambda h, i, j, g=g: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, tq, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        scratch_shapes=[
            _SCRATCH((tq, 1), jnp.float32),
            _SCRATCH((tq, 1), jnp.float32),
            _SCRATCH((tq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, sq, d)
