"""Jit'd attention entry point with backend dispatch.

``attention(..., backend="auto")`` picks the Pallas kernel on TPU and the
memory-efficient jnp scan elsewhere; models call this so the same model
code lowers on CPU (tests / dry-run) and TPU (production).
"""
from __future__ import annotations

import jax

from repro.models.attention import flash_attention_jnp
from .flash_attention import flash_attention_pallas

__all__ = ["attention"]


def attention(q, k, v, causal: bool = True, sm_scale: float | None = None, backend: str = "auto"):
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if backend == "pallas":
        return flash_attention_pallas(q, k, v, causal=causal, sm_scale=sm_scale)
    if backend == "jnp":
        return flash_attention_jnp(q, k, v, causal=causal, sm_scale=sm_scale)
    raise ValueError(f"unknown backend {backend!r}")
