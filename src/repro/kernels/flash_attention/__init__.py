"""Pallas TPU flash-attention kernel (LM training/prefill hot spot)."""
from . import ops, ref
from .flash_attention import flash_attention_pallas

__all__ = ["ops", "ref", "flash_attention_pallas"]
