"""Pure-jnp oracle for flash attention (GQA-aware)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["attention_ref"]


def attention_ref(
    q: jax.Array,  # (B, Hq, Sq, D)
    k: jax.Array,  # (B, Hkv, Skv, D)
    v: jax.Array,  # (B, Hkv, Skv, D)
    causal: bool = True,
    sm_scale: float | None = None,
) -> jax.Array:
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    g = hq // hkv
    if sm_scale is None:
        sm_scale = d ** -0.5
    k = jnp.repeat(k, g, axis=1)
    v = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * sm_scale
    if causal:
        mask = jnp.tril(jnp.ones((sq, skv), bool), k=skv - sq)
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(q.dtype), v)
