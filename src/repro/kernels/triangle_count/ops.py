"""Jit'd public wrappers for the triangle-intersection kernel family.

Dispatches to the Pallas kernels (native on TPU, ``interpret=True`` on
CPU) with the signatures the engine's panel/pallas backend expects
(:mod:`repro.core.engine`).  ``tiles=(block_edges, tlv)`` overrides the
static tile heuristic — the hook the :mod:`repro.core.tuning` autotuner
plugs its per-shape grid-search picks into.
"""
from __future__ import annotations

import jax

from .triangle_count import (
    intersect_count_pallas,
    intersect_per_node_pallas,
    intersect_support_pallas,
)

__all__ = ["intersect_count", "intersect_per_node", "intersect_support"]


def intersect_count(
    a: jax.Array,
    b: jax.Array,
    a_len: jax.Array | None = None,
    b_len: jax.Array | None = None,
    tiles=None,
) -> jax.Array:
    """Per-row sorted-intersection sizes; lengths are implied by −1 padding."""
    del a_len, b_len  # panels are −1 padded; masks are implicit
    return intersect_count_pallas(a, b, tiles=tiles)


def intersect_per_node(a: jax.Array, b: jax.Array, tiles=None):
    """(count, arm) per-row intersection with u-side match attribution."""
    return intersect_per_node_pallas(a, b, tiles=tiles)


def intersect_support(a: jax.Array, b: jax.Array, tiles=None):
    """(count, arm, closure) — the full per-edge support attribution."""
    return intersect_support_pallas(a, b, tiles=tiles)
