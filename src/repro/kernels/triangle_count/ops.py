"""Jit'd public wrapper for the triangle-intersection kernel.

Dispatches to the Pallas kernel (native on TPU, ``interpret=True`` on CPU)
with the signature expected by ``repro.core.count._count_panel``.
"""
from __future__ import annotations

import jax

from .triangle_count import intersect_count_pallas

__all__ = ["intersect_count"]


def intersect_count(
    a: jax.Array, b: jax.Array, a_len: jax.Array | None = None, b_len: jax.Array | None = None
) -> jax.Array:
    """Per-row sorted-intersection sizes; lengths are implied by −1 padding."""
    del a_len, b_len  # panels are −1 padded; masks are implicit
    return intersect_count_pallas(a, b)
