"""Pallas TPU kernel: bucketed sorted-set intersection counting.

This is the TPU re-blocking of the paper's ``CountTriangles`` CUDA kernel
(§III-C).  The CUDA version runs one serial two-pointer merge per thread;
on a TPU that shape starves the 8×128 VPU, so instead each grid step loads
an *edge-block panel pair* into VMEM

    a : (TB, Lu)   out-neighbors of the u endpoints   (−1 padded)
    b : (TB, TLv)  a tile of out-neighbors of the v endpoints

and counts equal pairs with a broadcast equality reduction — every lane
does useful work every cycle, and the intersection of a block of edges
completes in ``Lu·Lv / (8·128)`` VPU ops instead of a data-dependent loop.

Design choices mirroring the paper's optimizations:

* the paper's *unzipping* (SoA layout, §III-D1) → panels are gathered from
  the SoA CSR by XLA before the kernel, so the kernel streams dense tiles;
* the paper's texture-cache reliance (§III-D4) → explicit VMEM staging via
  ``BlockSpec`` (HBM→VMEM copies are software-managed, so "cache hit rate"
  becomes a compile-time property);
* the paper's warp sizing (§III-D5) → the ``block_edges`` (TB) tile height;
  swept in EXPERIMENTS.md §Perf exactly like the paper's grid search;
* degree skew (the reason the paper picked *forward*) → callers bucket
  edges by panel width (`repro.core.count.bucketize_edges`), so padding
  waste is bounded and each bucket compiles a tight fixed-shape kernel;
* the paper's memory ceiling (§III-E, 89M edges on 3 GB) → the engine
  (:class:`repro.core.engine.TriangleCounter`) slices each bucket under a
  ``max_wedge_chunk`` element budget before invoking this kernel, padding
  every slice to one static shape so chunk count never drives compiles.

The v-side is tiled (``TLv``) and accumulated across the innermost grid
dimension so wide buckets never exceed the VMEM budget; the output block
index map is independent of that dimension, making the partial-sum
accumulation a standard revisited-block reduction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["intersect_count_pallas"]


def _kernel(a_ref, b_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]  # (TB, Lu)
    b = b_ref[...]  # (TB, TLv)
    eq = (a[:, :, None] == b[:, None, :]) & (a[:, :, None] >= 0) & (b[:, None, :] >= 0)
    o_ref[...] += jnp.sum(eq, axis=(1, 2), dtype=jnp.int32)


def _pick_tiles(n_edges: int, lu: int, lv: int) -> tuple[int, int]:
    """Choose (TB, TLv) so the equality cube stays inside the VMEM budget.

    Budget: TB·Lu·TLv ≤ 2²¹ elements (≈8 MiB of int32 compares), TLv a
    multiple of 128 where possible (VPU lane width).
    """
    budget = 1 << 21
    tlv = min(lv, 512)
    tb = max(1, budget // max(lu * tlv, 1))
    tb = min(tb, n_edges, 256)
    # shrink tlv if even tb=1 overflows
    while tb == 1 and lu * tlv > budget and tlv > 128:
        tlv //= 2
    return tb, tlv


@functools.partial(jax.jit, static_argnames=("interpret",))
def _run(a, b, *, interpret: bool):
    n, lu = a.shape
    _, lv = b.shape
    tb, tlv = _pick_tiles(n, lu, lv)
    grid = (pl.cdiv(n, tb), pl.cdiv(lv, tlv))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, lu), lambda i, j: (i, 0)),
            pl.BlockSpec((tb, tlv), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((tb,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=interpret,
    )(a, b)


def intersect_count_pallas(a: jax.Array, b: jax.Array, interpret: bool | None = None):
    """Count matches between −1-padded sorted rows. a:(B,Lu) b:(B,Lv)→(B,)int32."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    return _run(a, b, interpret=interpret)
