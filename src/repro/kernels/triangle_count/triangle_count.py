"""Pallas TPU kernel family: bucketed sorted-set intersection.

This is the TPU re-blocking of the paper's ``CountTriangles`` CUDA kernel
(§III-C).  The CUDA version runs one serial two-pointer merge per thread;
on a TPU that shape starves the 8×128 VPU, so instead each grid step loads
an *edge-block panel pair* into VMEM

    a : (TB, Lu)   out-neighbors of the u endpoints   (−1 padded)
    b : (TB, TLv)  a tile of out-neighbors of the v endpoints

and counts equal pairs with a broadcast equality reduction — every lane
does useful work every cycle, and the intersection of a block of edges
completes in ``Lu·Lv / (8·128)`` VPU ops instead of a data-dependent loop.

The family shares that one equality tile and differs only in which axis
reductions leave the kernel — no extra memory traffic is read to produce
the richer outputs:

``intersect_count_pallas``
    ``Σ_{j,k} eq`` per edge — the scalar per-edge match count.
``intersect_per_node_pallas``
    adds the *arm* attribution ``Σ_k eq`` (one slot per u-neighbor):
    how many triangles each wedge arm ``(u, w)`` closes.  Scattering the
    per-edge count to ``u``/``v`` and the arm counts to the ``w`` values
    yields exact per-node triangle incidences.
``intersect_support_pallas``
    adds the *closure* attribution ``Σ_j eq`` (one slot per v-neighbor)
    on top, so every hit can be billed to all three directed edges of
    its triangle — base ``(u, v)``, arm ``(u, w)``, closure ``(v, w)`` —
    which is exactly the per-edge support scatter k-truss peels on.

Design choices mirroring the paper's optimizations:

* the paper's *unzipping* (SoA layout, §III-D1) → panels are gathered from
  the SoA CSR by XLA before the kernel, so the kernel streams dense tiles;
* the paper's texture-cache reliance (§III-D4) → explicit VMEM staging via
  ``BlockSpec`` (HBM→VMEM copies are software-managed, so "cache hit rate"
  becomes a compile-time property);
* the paper's warp sizing (§III-D5) → the ``block_edges`` (TB) tile height
  and the v-tile width (TLv); the static heuristic lives in
  :func:`_pick_tiles` and the measured per-shape grid search in
  :mod:`repro.core.tuning` (pass ``tiles=(TB, TLv)`` to override);
* degree skew (the reason the paper picked *forward*) → callers bucket
  edges by panel width (`repro.core.count.bucketize_edges`), so padding
  waste is bounded and each bucket compiles a tight fixed-shape kernel;
* the paper's memory ceiling (§III-E, 89M edges on 3 GB) → the engine
  (:class:`repro.core.engine.TriangleCounter`) slices each bucket under a
  ``max_wedge_chunk`` element budget before invoking this kernel, padding
  every slice to one static shape so chunk count never drives compiles.

The v-side is tiled (``TLv``) and accumulated across the innermost grid
dimension so wide buckets never exceed the VMEM budget; the count/arm
output block index maps are independent of that dimension, making their
partial-sum accumulation a standard revisited-block reduction, while the
closure output block *is* indexed by it and is written exactly once.
Every kernel runs ``interpret=True`` off-TPU, so the CPU CI exercises the
identical code path the TPU compiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "intersect_count_pallas",
    "intersect_per_node_pallas",
    "intersect_support_pallas",
]


def _eq_tile(a, b):
    """The shared broadcast-equality cube: (TB, Lu, TLv) boolean."""
    return (a[:, :, None] == b[:, None, :]) & (a[:, :, None] >= 0) & (b[:, None, :] >= 0)


def _kernel_count(a_ref, b_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    eq = _eq_tile(a_ref[...], b_ref[...])
    o_ref[...] += jnp.sum(eq, axis=(1, 2), dtype=jnp.int32)


def _kernel_per_node(a_ref, b_ref, cnt_ref, arm_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)
        arm_ref[...] = jnp.zeros_like(arm_ref)

    eq = _eq_tile(a_ref[...], b_ref[...])
    arm = jnp.sum(eq, axis=2, dtype=jnp.int32)   # (TB, Lu)
    arm_ref[...] += arm
    cnt_ref[...] += jnp.sum(arm, axis=1, dtype=jnp.int32)


def _kernel_support(a_ref, b_ref, cnt_ref, arm_ref, clo_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)
        arm_ref[...] = jnp.zeros_like(arm_ref)

    eq = _eq_tile(a_ref[...], b_ref[...])
    arm = jnp.sum(eq, axis=2, dtype=jnp.int32)   # (TB, Lu) — accumulated over j
    arm_ref[...] += arm
    cnt_ref[...] += jnp.sum(arm, axis=1, dtype=jnp.int32)
    # the closure block is indexed by j: each (i, j) tile is visited once,
    # so it is written (not accumulated) — no init needed
    clo_ref[...] = jnp.sum(eq, axis=1, dtype=jnp.int32)  # (TB, TLv)


def _pick_tiles(n_edges: int, lu: int, lv: int) -> tuple[int, int]:
    """Choose (TB, TLv) so the equality cube stays inside the VMEM budget.

    Budget: TB·Lu·TLv ≤ 2²¹ elements (≈8 MiB of int32 compares), TLv a
    multiple of 128 where possible (VPU lane width).  This is the static
    heuristic; :mod:`repro.core.tuning` grid-searches the same space per
    pow2 bucket shape and its picks are passed back in via ``tiles=``.
    """
    budget = 1 << 21
    tlv = min(lv, 512)
    tb = max(1, budget // max(lu * tlv, 1))
    tb = min(tb, n_edges, 256)
    # shrink tlv if even tb=1 overflows
    while tb == 1 and lu * tlv > budget and tlv > 128:
        tlv //= 2
    return tb, tlv


def _clamp_tiles(tiles, n, lv):
    """Clamp an explicit (TB, TLv) override to the panel's real extents."""
    tb, tlv = tiles
    return max(1, min(int(tb), n)), max(1, min(int(tlv), lv))


def _specs(tb: int, lu: int, tlv: int):
    """Input BlockSpecs shared by every kernel in the family."""
    return [
        pl.BlockSpec((tb, lu), lambda i, j: (i, 0)),
        pl.BlockSpec((tb, tlv), lambda i, j: (i, j)),
    ]


@functools.partial(jax.jit, static_argnames=("interpret", "tiles"))
def _run_count(a, b, *, interpret: bool, tiles=None):
    n, lu = a.shape
    _, lv = b.shape
    tb, tlv = _clamp_tiles(tiles, n, lv) if tiles else _pick_tiles(n, lu, lv)
    grid = (pl.cdiv(n, tb), pl.cdiv(lv, tlv))
    return pl.pallas_call(
        _kernel_count,
        grid=grid,
        in_specs=_specs(tb, lu, tlv),
        out_specs=pl.BlockSpec((tb,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=interpret,
    )(a, b)


@functools.partial(jax.jit, static_argnames=("interpret", "tiles"))
def _run_per_node(a, b, *, interpret: bool, tiles=None):
    n, lu = a.shape
    _, lv = b.shape
    tb, tlv = _clamp_tiles(tiles, n, lv) if tiles else _pick_tiles(n, lu, lv)
    grid = (pl.cdiv(n, tb), pl.cdiv(lv, tlv))
    return pl.pallas_call(
        _kernel_per_node,
        grid=grid,
        in_specs=_specs(tb, lu, tlv),
        out_specs=[
            pl.BlockSpec((tb,), lambda i, j: (i,)),
            pl.BlockSpec((tb, lu), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n, lu), jnp.int32),
        ],
        interpret=interpret,
    )(a, b)


@functools.partial(jax.jit, static_argnames=("interpret", "tiles"))
def _run_support(a, b, *, interpret: bool, tiles=None):
    n, lu = a.shape
    _, lv = b.shape
    tb, tlv = _clamp_tiles(tiles, n, lv) if tiles else _pick_tiles(n, lu, lv)
    grid = (pl.cdiv(n, tb), pl.cdiv(lv, tlv))
    return pl.pallas_call(
        _kernel_support,
        grid=grid,
        in_specs=_specs(tb, lu, tlv),
        out_specs=[
            pl.BlockSpec((tb,), lambda i, j: (i,)),
            pl.BlockSpec((tb, lu), lambda i, j: (i, 0)),
            pl.BlockSpec((tb, tlv), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n, lu), jnp.int32),
            jax.ShapeDtypeStruct((n, lv), jnp.int32),
        ],
        interpret=interpret,
    )(a, b)


def _norm(interpret, tiles):
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    if tiles is not None:
        tiles = (int(tiles[0]), int(tiles[1]))
    return interpret, tiles


def intersect_count_pallas(
    a: jax.Array, b: jax.Array, interpret: bool | None = None, tiles=None
):
    """Count matches between −1-padded sorted rows. a:(B,Lu) b:(B,Lv)→(B,)int32."""
    interpret, tiles = _norm(interpret, tiles)
    return _run_count(a, b, interpret=interpret, tiles=tiles)


def intersect_per_node_pallas(
    a: jax.Array, b: jax.Array, interpret: bool | None = None, tiles=None
):
    """Per-edge counts + arm attribution.

    Returns ``(count, arm)`` with ``count: (B,) int32`` the per-row match
    total and ``arm: (B, Lu) int32`` the per-u-neighbor match count
    (``count == arm.sum(axis=1)``; padding slots are always 0).
    """
    interpret, tiles = _norm(interpret, tiles)
    return _run_per_node(a, b, interpret=interpret, tiles=tiles)


def intersect_support_pallas(
    a: jax.Array, b: jax.Array, interpret: bool | None = None, tiles=None
):
    """Per-edge counts + arm + closure attributions.

    Returns ``(count, arm, closure)`` where ``closure: (B, Lv) int32``
    counts matches per v-neighbor slot (``count == closure.sum(axis=1)``).
    Together the three outputs bill every triangle to its three directed
    edges — the per-edge support primitive.
    """
    interpret, tiles = _norm(interpret, tiles)
    return _run_support(a, b, interpret=interpret, tiles=tiles)
