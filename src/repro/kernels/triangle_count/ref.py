"""Pure-jnp oracles for the panel intersection kernel family."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["intersect_count_ref", "intersect_per_node_ref", "intersect_support_ref"]


def _eq(a: jax.Array, b: jax.Array) -> jax.Array:
    """(B, Lu, Lv) masked equality cube; padding (−1) never matches."""
    eq = a[:, :, None] == b[:, None, :]
    valid = (a[:, :, None] >= 0) & (b[:, None, :] >= 0)
    return eq & valid


def intersect_count_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Count matching entries between −1-padded sorted rows of a and b.

    a: (B, Lu), b: (B, Lv) — any integer (or exactly-representable float)
    dtype.  Returns (B,) int32.  Padding slots are −1 and never match
    because valid vertex ids are ≥ 0.
    """
    return jnp.sum(_eq(a, b), axis=(1, 2), dtype=jnp.int32)


def intersect_per_node_ref(a: jax.Array, b: jax.Array):
    """(count (B,), arm (B, Lu)) — the per-node kernel's axis reductions."""
    eq = _eq(a, b)
    arm = jnp.sum(eq, axis=2, dtype=jnp.int32)
    return jnp.sum(arm, axis=1, dtype=jnp.int32), arm


def intersect_support_ref(a: jax.Array, b: jax.Array):
    """(count (B,), arm (B, Lu), closure (B, Lv)) — the support reductions."""
    eq = _eq(a, b)
    arm = jnp.sum(eq, axis=2, dtype=jnp.int32)
    closure = jnp.sum(eq, axis=1, dtype=jnp.int32)
    return jnp.sum(arm, axis=1, dtype=jnp.int32), arm, closure
