"""Pure-jnp oracle for the panel intersection kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["intersect_count_ref"]


def intersect_count_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Count matching entries between −1-padded sorted rows of a and b.

    a: (B, Lu), b: (B, Lv) — any integer (or exactly-representable float)
    dtype.  Returns (B,) int32.  Padding slots are −1 and never match
    because valid vertex ids are ≥ 0.
    """
    eq = a[:, :, None] == b[:, None, :]
    valid = (a[:, :, None] >= 0) & (b[:, None, :] >= 0)
    return jnp.sum(eq & valid, axis=(1, 2), dtype=jnp.int32)
