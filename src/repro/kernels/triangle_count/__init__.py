"""Pallas TPU kernel family for the triangle-counting intersection hot spot."""
from . import ops, ref
from .triangle_count import (
    intersect_count_pallas,
    intersect_per_node_pallas,
    intersect_support_pallas,
)

__all__ = [
    "ops",
    "ref",
    "intersect_count_pallas",
    "intersect_per_node_pallas",
    "intersect_support_pallas",
]
