"""Pallas TPU kernel for the triangle-counting intersection hot spot."""
from . import ops, ref
from .triangle_count import intersect_count_pallas

__all__ = ["ops", "ref", "intersect_count_pallas"]
