"""Per-edge triangle support (chunked, memory-bounded, backend-routed).

The *support* of an undirected edge ``{u, v}`` is the number of
triangles that contain it — the per-edge analogue of the engine's
per-node incidences, and the quantity k-truss decomposition peels on.
Under the forward orientation every triangle appears as exactly one
closed wedge, whose three participating **directed edges** are the
triangle's three edges: the base ``(u, v)``, the wedge arm ``(u, w)``
and the closing edge ``(v, w)``.  Each kernel backend bills every hit
to those three edge slots — the wedge backend from the binary search's
match indices (:func:`repro.core.engine.chunk_support_kernel`), the
panel/Pallas backends from the equality tile's arm/closure axis
reductions — so ``support.sum() == 3 × triangle_count`` bit-exactly at
any budget **for every backend**.

Everything routes through the engine's backend registry
(:func:`repro.core.engine.resolve_backend` / ``run_workload``): the
``method`` knob selects ``wedge_bsearch`` / ``panel`` / ``pallas``
exactly as on :class:`repro.core.engine.TriangleCounter`, edge chunks
honor ``max_wedge_chunk``, device partials stay int32 (per-edge support
is bounded by the max degree ≤ √(2m), far below 2³¹), and the running
per-edge totals accumulate on host in int64.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import numpy as np

from repro.core.engine import (
    TriangleCounter,
    chunk_support_kernel,
    make_workload,
    prepare_oriented,
    resolve_backend,
    resolve_method,
    run_workload,
)

__all__ = [
    "EdgeSupport",
    "SupportRun",
    "chunk_support_kernel",  # re-export: the kernel now lives in the engine
    "edge_support",
    "support_on_arrays",
]


class SupportRun(NamedTuple):
    """Result + launch stats of one raw-arrays support computation."""

    support: np.ndarray        # (m,) int64, aligned with the src/col arrays
    n_chunks: int
    peak_wedge_buffer: int
    total_wedges: int
    method: str                # backend that actually executed
    fallback_reason: str | None


def support_on_arrays(
    row_offsets,
    src,
    col,
    out_degree,
    *,
    max_wedge_chunk: int | None = None,
    n_steps: int | None = None,
    bucket_pow2: bool = False,
    method: str = "wedge_bsearch",
    tuner=None,
    mesh=None,
    shorter_side: bool = False,
) -> SupportRun:
    """Per-directed-edge support over raw oriented-CSR arrays.

    The low-level entry the truss peeler drives round after round:
    ``src``/``col`` may carry a −1-padded tail (pow2 shape bucketing —
    padded slots produce zero support and are sliced off by the caller).
    ``method`` picks the kernel backend (``"auto"`` resolves against the
    out-degree histogram, and routes to the §III-E striped backend when
    a multi-device ``mesh`` is given); planning, padding and pow2
    bucketing are the backend's — this function only adds the int64
    accumulation.
    """
    src_np = np.asarray(src)
    if src_np.shape[0] == 0:
        return SupportRun(np.zeros((0,), np.int64), 0, 0, 0, "wedge_bsearch", None)
    resolved = resolve_method(method, out_degree, mesh=mesh)
    backend, executed, reason = resolve_backend(
        resolved, "support", tuner=tuner, mesh=mesh, shorter_side=shorter_side
    )
    work = make_workload(row_offsets, col, out_degree, src, col, n_steps=n_steps)
    sup, plan = run_workload(
        backend, "support", work, budget=max_wedge_chunk, bucket_pow2=bucket_pow2
    )
    return SupportRun(
        sup, plan.n_chunks, plan.peak_buffer, plan.total_wedges, executed, reason
    )


@dataclasses.dataclass(frozen=True)
class EdgeSupport:
    """Per-edge triangle support over the forward-oriented edge list.

    ``(u[i], v[i])`` is directed edge ``i`` of the oriented CSR (one
    entry per undirected edge); ``support[i]`` is the number of
    triangles containing it.  The trailing fields mirror
    :class:`repro.core.engine.EngineStats` for tuning/benchmarks —
    ``method`` is the backend that actually executed (never "auto"),
    with ``fallback_reason`` set iff a capability gap forced a
    substitution.
    """

    u: np.ndarray              # (m,) int32 forward-edge sources
    v: np.ndarray              # (m,) int32 forward-edge targets
    support: np.ndarray        # (m,) int64 triangles through each edge
    n_nodes: int
    n_chunks: int
    peak_wedge_buffer: int
    wedge_budget: int | None
    total_wedges: int
    method: str = "wedge_bsearch"
    fallback_reason: str | None = None

    @property
    def n_edges(self) -> int:
        return self.support.shape[0]

    def total_triangles(self) -> int:
        """Global triangle count implied by the support (Σ support / 3)."""
        return int(self.support.sum(dtype=np.int64)) // 3

    def top_k(self, k: int = 10):
        """The ``k`` most triangle-dense edges as ``(u, v, support)``."""
        k = min(int(k), self.n_edges)
        if k <= 0:
            return (np.zeros(0, np.int32),) * 2 + (np.zeros(0, np.int64),)
        order = np.argsort(-self.support, kind="stable")[:k]
        return self.u[order], self.v[order], self.support[order]


def edge_support(
    edges,
    n_nodes: int | None = None,
    *,
    max_wedge_chunk: int | None = None,
    method: str = "auto",
    counter: TriangleCounter | None = None,
    mesh=None,
) -> EdgeSupport:
    """Per-edge triangle support for any engine-accepted graph input.

    ``edges`` may be a canonical edge array, an ``OrientedCSR``, or a
    cached undirected CSR (``repro.graphs.io.CSRGraph``) — the same
    front door as :meth:`repro.core.engine.TriangleCounter.count`, via
    :func:`repro.core.engine.prepare_oriented`.  ``method`` selects the
    kernel backend exactly as on the engine; pass ``counter=`` to reuse
    a configured :class:`TriangleCounter` (its ``last_stats`` reflect
    the call).  ``counter=`` carries its own method/budget, so combining
    it with an explicit ``method``/``max_wedge_chunk`` is rejected
    rather than silently ignored.
    """
    if counter is not None and (
        method != "auto" or max_wedge_chunk is not None or mesh is not None
    ):
        raise ValueError(
            "pass either counter= (which carries its own method/budget/mesh) "
            "or method=/max_wedge_chunk=/mesh=, not both"
        )
    tc = counter if counter is not None else TriangleCounter(
        method=method, max_wedge_chunk=max_wedge_chunk, mesh=mesh
    )
    csr = prepare_oriented(edges, n_nodes)
    if csr is None:
        n = n_nodes if n_nodes is not None else getattr(edges, "n_nodes", 0) or 0
        empty32 = np.zeros((0,), np.int32)
        return EdgeSupport(
            u=empty32, v=empty32, support=np.zeros((0,), np.int64), n_nodes=n,
            n_chunks=0, peak_wedge_buffer=0, wedge_budget=tc.max_wedge_chunk,
            total_wedges=0,
        )
    sup = tc.edge_support(csr)
    st = tc.last_stats
    return EdgeSupport(
        u=np.asarray(csr.src, dtype=np.int32),
        v=np.asarray(csr.col, dtype=np.int32),
        support=sup,
        n_nodes=csr.n_nodes,
        n_chunks=st.n_chunks,
        peak_wedge_buffer=st.peak_wedge_buffer,
        wedge_budget=st.wedge_budget,
        total_wedges=st.total_wedges,
        method=st.method,
        fallback_reason=st.fallback_reason,
    )
