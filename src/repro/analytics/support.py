"""Per-edge triangle support (chunked, memory-bounded).

The *support* of an undirected edge ``{u, v}`` is the number of
triangles that contain it — the per-edge analogue of the engine's
per-node incidences, and the quantity k-truss decomposition peels on.
Under the forward orientation every triangle appears as exactly one
closed wedge, whose three participating **directed edges** are the
triangle's three edges: the base ``(u, v)``, the wedge arm ``(u, w)``
and the closing edge ``(v, w)`` found by the binary search.  The
support kernel therefore scatters each hit back to those three edge
slots (:func:`repro.core.count.expand_and_close_wedges_indexed`), so
``support.sum() == 3 × triangle_count`` bit-exactly at any budget.

The kernel is jitted alongside the engine's
:func:`repro.core.engine.chunk_count_kernel` /
:func:`~repro.core.engine.chunk_per_node_kernel` and consumes the same
chunk plan (:func:`repro.core.engine.plan_edge_chunks`): edge chunks
honor ``max_wedge_chunk``, device partials stay int32 (per-edge support
is bounded by the max degree ≤ √(2m), far below 2³¹), and the running
per-edge totals accumulate on host in int64.
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.count import expand_and_close_wedges_indexed
from repro.core.engine import iter_wedge_chunks, prepare_oriented, search_steps
from repro.core.preprocess import OrientedCSR

__all__ = ["EdgeSupport", "chunk_support_kernel", "edge_support", "support_on_arrays"]


@functools.partial(jax.jit, static_argnames=("wedge_budget", "n_steps"))
def chunk_support_kernel(
    src_e, dst_e, edge_offset, row_offsets, col, out_deg, *, wedge_budget, n_steps
):
    """Per-directed-edge support contributed by one −1-padded edge chunk.

    ``edge_offset`` (traced scalar — no recompile per chunk) is the
    chunk's start index in the global directed edge list; the base
    edge's local id shifts by it, while the arm (``uw``) and closure
    (``vw``) indices from the wedge expansion are global already.
    Returns an int32 vector over the full ``col`` axis.
    """
    hit, edge_id, uw_idx, vw_idx = expand_and_close_wedges_indexed(
        src_e, dst_e, row_offsets, col, out_deg, wedge_budget, n_steps
    )
    inc = hit.astype(jnp.int32)
    m_dir = col.shape[0]
    uv_idx = jnp.clip(edge_offset + edge_id, 0, m_dir - 1)
    out = jnp.zeros((m_dir,), jnp.int32)
    out = out.at[uv_idx].add(inc)
    out = out.at[uw_idx].add(inc)
    out = out.at[vw_idx].add(inc)
    return out


def support_on_arrays(
    row_offsets,
    src,
    col,
    out_degree,
    *,
    max_wedge_chunk: int | None = None,
    n_steps: int | None = None,
    bucket_pow2: bool = False,
):
    """Per-directed-edge support over raw oriented-CSR arrays.

    The low-level entry the truss peeler drives round after round:
    ``src``/``col`` may carry a −1-padded tail (pow2 shape bucketing —
    padded slots produce zero support and are sliced off by the caller).
    Chunk planning, padding and pow2 bucketing are all the engine's
    (:func:`repro.core.engine.iter_wedge_chunks`) — this function only
    adds the per-chunk support scatter and the int64 accumulation.

    Returns ``(support, n_chunks, peak_wedge_buffer, total_wedges)``
    with ``support`` an int64 host array aligned with ``src``.
    """
    src_np = np.asarray(src)
    m = src_np.shape[0]
    if m == 0:
        return np.zeros((0,), np.int64), 0, 0, 0
    out_deg_np = np.asarray(out_degree)
    if n_steps is None:
        max_deg = int(out_deg_np.max()) if out_deg_np.size else 0
        n_steps = max(1, math.ceil(math.log2(max_deg + 1))) if max_deg else 1
    # OrientedCSR as a plain array container; `degree` (undirected) is
    # not meaningful for a peeled subgraph and unused by the chunker and
    # the kernel, so the out-degree stands in
    chunk_csr = OrientedCSR(
        row_offsets=np.asarray(row_offsets), src=src_np,
        col=np.asarray(col), out_degree=out_deg_np, degree=out_deg_np,
    )
    chunks, n_chunks, peak, total_wedges = iter_wedge_chunks(
        chunk_csr, max_wedge_chunk, bucket_pow2=bucket_pow2
    )
    ro_dev = jnp.asarray(chunk_csr.row_offsets)
    col_dev = jnp.asarray(chunk_csr.col)
    od_dev = jnp.asarray(out_deg_np)
    total = np.zeros((m,), np.int64)
    for s, d, start in chunks:
        part = chunk_support_kernel(
            jnp.asarray(s), jnp.asarray(d), np.int32(start),
            ro_dev, col_dev, od_dev,
            wedge_budget=peak, n_steps=n_steps,
        )
        total += np.asarray(part, dtype=np.int64)
    return total, n_chunks, peak, total_wedges


@dataclasses.dataclass(frozen=True)
class EdgeSupport:
    """Per-edge triangle support over the forward-oriented edge list.

    ``(u[i], v[i])`` is directed edge ``i`` of the oriented CSR (one
    entry per undirected edge); ``support[i]`` is the number of
    triangles containing it.  The trailing fields mirror
    :class:`repro.core.engine.EngineStats` for tuning/benchmarks.
    """

    u: np.ndarray              # (m,) int32 forward-edge sources
    v: np.ndarray              # (m,) int32 forward-edge targets
    support: np.ndarray        # (m,) int64 triangles through each edge
    n_nodes: int
    n_chunks: int
    peak_wedge_buffer: int
    wedge_budget: int | None
    total_wedges: int

    @property
    def n_edges(self) -> int:
        return self.support.shape[0]

    def total_triangles(self) -> int:
        """Global triangle count implied by the support (Σ support / 3)."""
        return int(self.support.sum()) // 3

    def top_k(self, k: int = 10):
        """The ``k`` most triangle-dense edges as ``(u, v, support)``."""
        k = min(int(k), self.n_edges)
        if k <= 0:
            return (np.zeros(0, np.int32),) * 2 + (np.zeros(0, np.int64),)
        order = np.argsort(-self.support, kind="stable")[:k]
        return self.u[order], self.v[order], self.support[order]


def edge_support(edges, n_nodes: int | None = None, *, max_wedge_chunk: int | None = None) -> EdgeSupport:
    """Per-edge triangle support for any engine-accepted graph input.

    ``edges`` may be a canonical edge array, an ``OrientedCSR``, or a
    cached undirected CSR (``repro.graphs.io.CSRGraph``) — the same
    front door as :meth:`repro.core.engine.TriangleCounter.count`, via
    :func:`repro.core.engine.prepare_oriented`.
    """
    csr = prepare_oriented(edges, n_nodes)
    if csr is None:
        n = n_nodes if n_nodes is not None else getattr(edges, "n_nodes", 0) or 0
        empty32 = np.zeros((0,), np.int32)
        return EdgeSupport(
            u=empty32, v=empty32, support=np.zeros((0,), np.int64), n_nodes=n,
            n_chunks=0, peak_wedge_buffer=0, wedge_budget=max_wedge_chunk,
            total_wedges=0,
        )
    sup, n_chunks, peak, total = support_on_arrays(
        csr.row_offsets, csr.src, csr.col, csr.out_degree,
        max_wedge_chunk=max_wedge_chunk, n_steps=search_steps(csr),
    )
    return EdgeSupport(
        u=np.asarray(csr.src, dtype=np.int32),
        v=np.asarray(csr.col, dtype=np.int32),
        support=sup,
        n_nodes=csr.n_nodes,
        n_chunks=n_chunks,
        peak_wedge_buffer=peak,
        wedge_budget=max_wedge_chunk,
        total_wedges=total,
    )
