"""Clustering / transitivity / density metrics routed through the engine.

These are the paper's motivating applications (§I) — implemented over
:class:`repro.core.engine.TriangleCounter` rather than raw kernel
primitives, so every metric (a) honors ``max_wedge_chunk`` memory
bounding, (b) accepts raw canonical edge arrays, pre-built
``OrientedCSR`` objects and cached/mmap'd ``CSRGraph`` files alike, and
(c) benefits from ``method="auto"`` schedule dispatch.  The thin
``repro.core.clustering`` wrappers re-export from here.

Every function takes either a ``counter=`` (a configured
:class:`~repro.core.engine.TriangleCounter` to reuse — its
``last_stats`` reflect the call) or ``method=`` / ``max_wedge_chunk=``
to build one.  To amortize preprocessing across several metrics, call
:func:`repro.core.engine.prepare_oriented` once and pass the CSR — that
is exactly what :func:`graph_report` does.
"""
from __future__ import annotations

import time

import numpy as np

from repro import obs
from repro.core.engine import TriangleCounter, degree_histogram, prepare_oriented

from .support import edge_support
from .truss import k_truss_decomposition

__all__ = [
    "clustering_from_counts",
    "transitivity_from_counts",
    "per_node_triangle_counts",
    "profile_from_counts",
    "local_clustering",
    "average_clustering",
    "transitivity",
    "node_triangle_features",
    "clustering_profile",
    "top_triangle_nodes",
    "top_support_edges",
    "graph_report",
]


# ---------------------------------------------------------------------------
# host formulas (shared with repro.core.clustering and the engine)
# ---------------------------------------------------------------------------


def clustering_from_counts(tri: np.ndarray, deg: np.ndarray) -> np.ndarray:
    """c(v) = 2·T(v) / (deg(v)·(deg(v)−1)) from host count/degree arrays."""
    pairs = deg * (deg - 1)
    return np.where(pairs > 0, 2.0 * tri / np.maximum(pairs, 1), 0.0)


def transitivity_from_counts(n_triangles: int, deg: np.ndarray) -> float:
    """3·#triangles / #wedges from a host count and degree array."""
    wedges = int((deg.astype(np.int64) * (deg.astype(np.int64) - 1) // 2).sum())
    return 3.0 * n_triangles / wedges if wedges else 0.0


# ---------------------------------------------------------------------------
# engine-routed metrics
# ---------------------------------------------------------------------------


def _counter(counter, method, max_wedge_chunk) -> TriangleCounter:
    if counter is not None:
        return counter
    return TriangleCounter(method=method, max_wedge_chunk=max_wedge_chunk)


def per_node_triangle_counts(
    edges,
    n_nodes: int | None = None,
    *,
    counter: TriangleCounter | None = None,
    method: str = "auto",
    max_wedge_chunk: int | None = None,
) -> np.ndarray:
    """Per-vertex triangle incidences T(v), int64 host array."""
    return _counter(counter, method, max_wedge_chunk).per_node(edges, n_nodes)


def local_clustering(
    edges,
    n_nodes: int | None = None,
    *,
    counter: TriangleCounter | None = None,
    method: str = "auto",
    max_wedge_chunk: int | None = None,
) -> np.ndarray:
    """Local clustering coefficients c(v); 0 where degree < 2."""
    deg, n_nodes = degree_histogram(edges, n_nodes)
    if deg.size == 0:
        return np.zeros((n_nodes,), np.float64)
    tri = per_node_triangle_counts(
        edges, n_nodes, counter=counter, method=method, max_wedge_chunk=max_wedge_chunk
    )
    return clustering_from_counts(tri, deg)


def average_clustering(
    edges,
    n_nodes: int | None = None,
    *,
    counter: TriangleCounter | None = None,
    method: str = "auto",
    max_wedge_chunk: int | None = None,
) -> float:
    """Mean of the local clustering coefficients (Watts–Strogatz C̄)."""
    cc = local_clustering(
        edges, n_nodes, counter=counter, method=method, max_wedge_chunk=max_wedge_chunk
    )
    return float(cc.mean()) if cc.size else 0.0


def transitivity(
    edges,
    n_nodes: int | None = None,
    *,
    counter: TriangleCounter | None = None,
    method: str = "auto",
    max_wedge_chunk: int | None = None,
) -> float:
    """Global transitivity ratio 3·#triangles / #wedges."""
    deg, n_nodes = degree_histogram(edges, n_nodes)
    if deg.size == 0:
        return 0.0
    t = _counter(counter, method, max_wedge_chunk).count(edges, n_nodes)
    return transitivity_from_counts(t, deg)


def node_triangle_features(
    edges,
    n_nodes: int | None = None,
    *,
    counter: TriangleCounter | None = None,
    method: str = "auto",
    max_wedge_chunk: int | None = None,
) -> np.ndarray:
    """(n, 3) float32 per-node feature block [degree, triangles, clustering].

    The hook by which the paper's technique feeds the GNN stack: any
    graph arch config may prepend these features to its node inputs.
    """
    deg, n_nodes = degree_histogram(edges, n_nodes)
    tri = (
        per_node_triangle_counts(
            edges, n_nodes, counter=counter, method=method,
            max_wedge_chunk=max_wedge_chunk,
        )
        if deg.size
        else np.zeros((n_nodes,), np.int64)
    )
    cc = clustering_from_counts(tri, deg) if deg.size else np.zeros((n_nodes,))
    return np.stack(
        [deg.astype(np.float32), tri.astype(np.float32), cc.astype(np.float32)], axis=1
    )


def clustering_profile(
    edges,
    n_nodes: int | None = None,
    *,
    counter: TriangleCounter | None = None,
    method: str = "auto",
    max_wedge_chunk: int | None = None,
) -> dict:
    """Degree-binned clustering profile (pow2 degree bins).

    Returns ``{"bins": [lo, ...], "n_nodes": [...], "mean_clustering":
    [...], "mean_triangles": [...]}`` where bin ``i`` covers degrees in
    ``[bins[i], bins[i+1])`` (last bin open-ended).  The c(d) profile is
    the standard skew diagnostic: heavy-tailed graphs show the falling
    c(d) ~ d^-1 the paper's Kronecker family is built to exhibit.
    """
    deg, n_nodes = degree_histogram(edges, n_nodes)
    if deg.size == 0 or int(deg.max()) < 1:
        return _EMPTY_PROFILE.copy()
    tri = per_node_triangle_counts(
        edges, n_nodes, counter=counter, method=method, max_wedge_chunk=max_wedge_chunk
    )
    return profile_from_counts(tri, deg)


_EMPTY_PROFILE = {"bins": [], "n_nodes": [], "mean_clustering": [], "mean_triangles": []}


def profile_from_counts(tri: np.ndarray, deg: np.ndarray) -> dict:
    """Pow2-degree-bin the per-node counts already in hand."""
    if deg.size == 0 or int(deg.max()) < 1:
        return _EMPTY_PROFILE.copy()
    cc = clustering_from_counts(tri, deg)
    n_bins = max(int(deg.max()).bit_length(), 1)
    lo = 2 ** np.arange(n_bins)          # bins [1,2), [2,4), [4,8), ...
    which = np.digitize(deg, lo) - 1     # degree-0 nodes land in bin -1: drop
    keep = which >= 0
    out = {"bins": lo.tolist(), "n_nodes": [], "mean_clustering": [], "mean_triangles": []}
    for b in range(n_bins):
        m = keep & (which == b)
        cnt = int(m.sum(dtype=np.int64))
        out["n_nodes"].append(cnt)
        out["mean_clustering"].append(float(cc[m].mean()) if cnt else 0.0)
        out["mean_triangles"].append(float(tri[m].mean()) if cnt else 0.0)
    return out


def top_triangle_nodes(
    edges,
    k: int = 10,
    n_nodes: int | None = None,
    *,
    counter: TriangleCounter | None = None,
    method: str = "auto",
    max_wedge_chunk: int | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """The ``k`` most triangle-dense vertices as ``(nodes, counts)``."""
    tri = per_node_triangle_counts(
        edges, n_nodes, counter=counter, method=method, max_wedge_chunk=max_wedge_chunk
    )
    k = min(int(k), tri.shape[0])
    if k <= 0:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    order = np.argsort(-tri, kind="stable")[:k]
    return order, tri[order]


def top_support_edges(
    edges,
    k: int = 10,
    n_nodes: int | None = None,
    *,
    method: str = "auto",
    max_wedge_chunk: int | None = None,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """The ``k`` most triangle-dense edges as ``(u, v, support)``."""
    return edge_support(
        edges, n_nodes, method=method, max_wedge_chunk=max_wedge_chunk
    ).top_k(k)


# ---------------------------------------------------------------------------
# one-stop report (the CLI's --json payload)
# ---------------------------------------------------------------------------


def graph_report(
    graph,
    n_nodes: int | None = None,
    *,
    method: str = "auto",
    max_wedge_chunk: int | None = None,
    include_truss: bool = True,
    top_k: int = 5,
) -> dict:
    """Full analytics report, preprocessing the graph exactly once.

    The input is normalized to an ``OrientedCSR`` up front
    (:func:`repro.core.engine.prepare_oriented`) and every stage —
    count, per-node scatter, per-edge support, truss peel — consumes
    that CSR, so ingestion/preprocessing is never repeated.  ``method``
    selects the kernel backend for *every* stage (support and truss
    included — the panel/Pallas schedules are full citizens).  Returns a
    JSON-ready dict (plain ints/floats/lists) with per-stage timings.
    """
    t0 = time.perf_counter()
    with obs.span("report.preprocess", cat="analytics"):
        deg, n_from_input = degree_histogram(graph, n_nodes)
        csr = prepare_oriented(graph, n_nodes)
    prep_s = time.perf_counter() - t0
    tc = TriangleCounter(method=method, max_wedge_chunk=max_wedge_chunk)
    report: dict = {
        "n_nodes": int(csr.n_nodes) if csr is not None else n_from_input,
        "n_edges": int(csr.n_directed_edges) if csr is not None else 0,
        "max_degree": int(deg.max()) if deg.size else 0,
    }
    timings = {"preprocess": prep_s}

    t0 = time.perf_counter()
    with obs.span("report.count", cat="analytics"):
        triangles = tc.count(csr if csr is not None else np.zeros((0, 2), np.int32))
    timings["count"] = time.perf_counter() - t0
    es = tc.last_stats
    report["triangles"] = triangles
    report["transitivity"] = transitivity_from_counts(triangles, deg)
    report["engine"] = {
        "method": es.method,
        "resolved_method": es.resolved_method,
        "n_chunks": es.n_chunks,
        "peak_wedge_buffer": es.peak_wedge_buffer,
        "wedge_budget": es.wedge_budget,
        "total_wedges": es.total_wedges,
        "fallback_reason": es.fallback_reason,
        "timings": es.timings,
    }

    t0 = time.perf_counter()
    with obs.span("report.clustering", cat="analytics"):
        tri = (
            tc.per_node(csr)
            if csr is not None
            else np.zeros((report["n_nodes"],), np.int64)
        )
        cc = clustering_from_counts(tri, deg) if deg.size else np.zeros((0,))
    timings["clustering"] = time.perf_counter() - t0
    # one per-node pass feeds average, profile and top-k alike
    order = np.argsort(-tri, kind="stable")[: min(top_k, tri.shape[0])]
    report["clustering"] = {
        "average": float(cc.mean()) if cc.size else 0.0,
        "profile": profile_from_counts(tri, deg),
        "top_nodes": [
            {"node": int(nd), "triangles": int(tri[nd])} for nd in order
        ],
    }

    t0 = time.perf_counter()
    with obs.span("report.support", cat="analytics"):
        sup = edge_support(
            csr if csr is not None else np.zeros((0, 2), np.int32),
            method=method,
            max_wedge_chunk=max_wedge_chunk,
        )
    timings["support"] = time.perf_counter() - t0
    su, sv, ss = sup.top_k(top_k)
    report["support"] = {
        "sum": int(sup.support.sum(dtype=np.int64)),
        "max": int(sup.support.max()) if sup.n_edges else 0,
        "n_chunks": sup.n_chunks,
        "method": sup.method,
        "top_edges": [
            {"u": int(a), "v": int(b), "support": int(s)}
            for a, b, s in zip(su, sv, ss)
        ],
    }

    if include_truss:
        t0 = time.perf_counter()
        with obs.span("report.truss", cat="analytics"):
            dec = k_truss_decomposition(
                csr if csr is not None else np.zeros((0, 2), np.int32),
                max_wedge_chunk=max_wedge_chunk,
                method=method,
            )
        timings["truss"] = time.perf_counter() - t0
        report["truss"] = {
            "max_k": dec.max_k,
            "spectrum": {str(k): c for k, c in dec.spectrum().items()},
            "truss_sizes": {str(k): c for k, c in dec.truss_sizes().items()},
            "rounds": dec.rounds,
            "method": dec.method,
        }

    # Compressed inputs count in relabeled (locality-ordered) ids; map every
    # node id in the report back through the stored inverse permutation so
    # callers always see the original graph's ids.
    new_to_old = getattr(graph, "new_to_old", None)
    if new_to_old is not None:
        for d in report["clustering"]["top_nodes"]:
            d["node"] = int(new_to_old[d["node"]])
        for d in report["support"]["top_edges"]:
            d["u"] = int(new_to_old[d["u"]])
            d["v"] = int(new_to_old[d["v"]])

    report["timings_s"] = timings
    return report
