"""Graph-structure analytics on top of the triangle-counting engine.

The paper's stated motivation (§I) is computing clustering coefficients
and the transitivity ratio; the canonical workloads layered on a fast
triangle kernel beyond bare counts are per-edge triangle *support* and
*k-truss* decomposition (Wang et al., arXiv:1804.06926; Arifuzzaman et
al., arXiv:1706.05151).  This package is that analytics stack:

``support``
    Chunked per-edge triangle-support kernel — jitted alongside the
    engine's chunk kernels, honoring ``max_wedge_chunk``, int32 device
    partials + int64 host accumulation, scattering each closed wedge
    back to the three directed edges of its triangle.
``truss``
    Exact k-truss decomposition by iterative support-peeling on the
    oriented CSR (recompute rounds, pow2 shape bucketing for compile
    stability), per-edge trussness + max-k subgraph extraction.
``metrics``
    Local/average clustering, transitivity, degree-binned clustering
    profiles and top-k triangle-dense nodes/edges — all routed through
    :class:`repro.core.engine.TriangleCounter`, so they accept raw edge
    arrays, an ``OrientedCSR``, or a cached/mmap'd
    :class:`repro.graphs.io.CSRGraph` alike.

Everything builds on the engine's stable internal API
(:func:`repro.core.engine.prepare_oriented`,
:func:`repro.core.engine.iter_wedge_chunks`, the chunk kernels) — the
subsystem adds no second copy of the chunking or accumulation discipline.

NOTE on import order: modules here import ``repro.core.engine`` /
``repro.core.count`` / ``repro.core.preprocess`` directly (never the
``repro.core`` package root), so ``repro.core.clustering`` can re-export
:mod:`repro.analytics.metrics` without a cycle.  The ``repro.core``
import below must stay FIRST: when ``repro.analytics`` is imported
before ``repro.core``, it drives the core package (and its re-entrant
``clustering`` → ``analytics.metrics`` hop) to completion before any
analytics submodule starts loading, which keeps both import orders
cycle-safe.
"""
import repro.core  # noqa: F401  (see note above — load order matters)

from .support import (
    EdgeSupport,
    SupportRun,
    chunk_support_kernel,
    edge_support,
    support_on_arrays,
)
from .truss import TrussDecomposition, k_truss_decomposition, k_truss_subgraph
from .metrics import (
    average_clustering,
    clustering_from_counts,
    clustering_profile,
    graph_report,
    local_clustering,
    node_triangle_features,
    per_node_triangle_counts,
    profile_from_counts,
    top_support_edges,
    top_triangle_nodes,
    transitivity,
    transitivity_from_counts,
)

__all__ = [
    "EdgeSupport",
    "SupportRun",
    "chunk_support_kernel",
    "edge_support",
    "support_on_arrays",
    "TrussDecomposition",
    "k_truss_decomposition",
    "k_truss_subgraph",
    "average_clustering",
    "clustering_from_counts",
    "clustering_profile",
    "graph_report",
    "local_clustering",
    "node_triangle_features",
    "per_node_triangle_counts",
    "profile_from_counts",
    "top_support_edges",
    "top_triangle_nodes",
    "transitivity",
    "transitivity_from_counts",
]
