"""Exact k-truss decomposition by iterative support-peeling.

The *k-truss* of a graph is the maximal subgraph in which every edge is
supported by at least ``k − 2`` triangles; the *trussness* of an edge is
the largest ``k`` whose truss contains it.  Wang et al.
(arXiv:1804.06926) treat truss decomposition as the canonical workload
layered on a fast triangle kernel, and that is exactly how it is built
here: every peeling round recomputes per-edge support with the chunked
support kernel (:mod:`repro.analytics.support`) on the surviving edge
subset and removes the under-supported edges, until the k-truss is
stable; then ``k`` advances.

Two engine-minded details:

* **Orientation is computed once.**  A subgraph of an acyclic
  orientation stays acyclic, and the oriented CSR is sorted by
  ``(src, dst)``, so each round's sub-CSR is a boolean filter of the
  original arrays — no re-canonicalization, no re-sort, and trivially
  stable edge ids for the trussness output.
* **pow2 shape bucketing.**  Shrinking subgraphs would otherwise
  recompile the jitted kernel every round; the edge axis, the chunk
  width and the wedge budget all round up to powers of two
  (``support_on_arrays(bucket_pow2=True)``), so a full decomposition
  compiles O(log m) kernels regardless of round count.  The chunk plan
  still honors ``max_wedge_chunk`` within each round.
* **backend-routed support.**  Every peel round's support recompute
  runs through the engine's kernel backend registry, so ``method=``
  selects wedge / panel / Pallas for the heaviest repeated-support
  workload in the repo.  The spectrum is backend-independent bit-exactly
  (each backend bills the identical three edges per triangle).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro import obs
from repro.core.engine import next_pow2, prepare_oriented, resolve_method, search_steps

from .support import support_on_arrays

__all__ = ["TrussDecomposition", "k_truss_decomposition", "k_truss_subgraph"]


@dataclasses.dataclass(frozen=True)
class TrussDecomposition:
    """Per-edge trussness over the forward-oriented edge list.

    ``(u[i], v[i])`` is directed edge ``i`` of the oriented CSR;
    ``trussness[i] ≥ 2`` always (every edge is trivially in the
    2-truss), and ``max_k`` is the largest non-empty truss.
    """

    u: np.ndarray           # (m,) int32 forward-edge sources
    v: np.ndarray           # (m,) int32 forward-edge targets
    trussness: np.ndarray   # (m,) int32
    max_k: int              # largest k with a non-empty k-truss (0 if no edges)
    n_nodes: int
    rounds: int             # support-recompute rounds the peel ran
    n_support_launches: int  # chunk-kernel launches across all rounds
    method: str = "wedge_bsearch"  # backend the support recomputes executed

    @property
    def n_edges(self) -> int:
        return self.trussness.shape[0]

    def spectrum(self) -> dict[int, int]:
        """``{k: number of edges with trussness exactly k}`` (sorted)."""
        ks, counts = np.unique(self.trussness, return_counts=True)
        return {int(k): int(c) for k, c in zip(ks, counts)}

    def truss_sizes(self) -> dict[int, int]:
        """``{k: number of edges in the k-truss}`` for k = 2..max_k."""
        if self.n_edges == 0:
            return {}
        return {
            k: int((self.trussness >= k).sum(dtype=np.int64))
            for k in range(2, self.max_k + 1)
        }

    def edges_at_least(self, k: int) -> np.ndarray:
        """Canonical edge array (both directions) of the k-truss."""
        mask = self.trussness >= k
        u, v = self.u[mask], self.v[mask]
        both = np.stack(
            [np.concatenate([u, v]), np.concatenate([v, u])], axis=1
        ).astype(np.int32)
        order = np.lexsort((both[:, 1], both[:, 0]))
        return both[order]


def _empty_result(n_nodes: int) -> TrussDecomposition:
    empty32 = np.zeros((0,), np.int32)
    return TrussDecomposition(
        u=empty32, v=empty32, trussness=empty32.copy(), max_k=0,
        n_nodes=n_nodes, rounds=0, n_support_launches=0,
    )


def k_truss_decomposition(
    edges,
    n_nodes: int | None = None,
    *,
    max_wedge_chunk: int | None = None,
    method: str = "auto",
    mesh=None,
) -> TrussDecomposition:
    """Full truss decomposition (per-edge trussness) of a graph.

    Accepts the engine's input kinds (edge array / ``OrientedCSR`` /
    cached ``CSRGraph``); ``max_wedge_chunk`` bounds every support
    recomputation's device wedge buffer exactly as in the engine, and
    ``method`` picks the kernel backend every peel round's support runs
    on (``"auto"`` resolves once, against the *full* graph's degrees, so
    the whole peel shares one backend and its compiled kernels).  With a
    multi-device ``mesh``, every round's support recompute runs the
    §III-E striped distributed kernels; pow2 bucketing still bounds the
    peel to O(log m) compiles because the striped kernel cache keys on
    the bucketed shapes.
    """
    csr = prepare_oriented(edges, n_nodes)
    if csr is None:
        n = n_nodes if n_nodes is not None else getattr(edges, "n_nodes", 0) or 0
        return _empty_result(n)
    n = csr.n_nodes
    src0 = np.asarray(csr.src, dtype=np.int32)
    col0 = np.asarray(csr.col, dtype=np.int32)
    m = src0.shape[0]
    # binary-search depth fixed from the full graph: degrees only shrink
    # under peeling and extra steps are harmless, so every round shares
    # one static n_steps (compile stability)
    steps = search_steps(csr)
    method = resolve_method(method, csr.out_degree, mesh=mesh)
    trussness = np.full(m, 2, np.int32)
    idx = np.arange(m)
    with obs.span("truss.round", cat="analytics",
                  args={"round": 1, "k": 3, "alive": int(idx.size)}):
        sup, launches, executed = _alive_support(
            src0, col0, idx, n, steps, max_wedge_chunk, method, mesh
        )
    rounds = 1
    k = 3
    while idx.size:
        peel = sup < (k - 2)
        if peel.any():
            # edges that survived the (k-1)-peel but not this one are in
            # the (k-1)-truss and no denser one
            trussness[idx[peel]] = k - 1
            idx = idx[~peel]
            if idx.size == 0:
                break
            # removal may cascade: recompute support on the shrunk graph
            with obs.span("truss.round", cat="analytics",
                          args={"round": rounds + 1, "k": k,
                                "alive": int(idx.size)}):
                sup, n_chunks, executed = _alive_support(
                    src0, col0, idx, n, steps, max_wedge_chunk, method, mesh
                )
            rounds += 1
            launches += n_chunks
        else:
            k += 1  # k-truss stable — the same support serves the next k
    return TrussDecomposition(
        u=src0, v=col0, trussness=trussness,
        max_k=int(trussness.max()) if m else 0,
        n_nodes=n, rounds=rounds, n_support_launches=launches,
        method=executed,
    )


def _alive_support(src0, col0, idx, n, steps, max_wedge_chunk, method, mesh=None):
    """Support of the surviving edges, on the filtered (pow2-padded) CSR."""
    sub_src = src0[idx]
    sub_col = col0[idx]
    sub_out = np.bincount(sub_src, minlength=n).astype(np.int32)
    sub_row = np.zeros((n + 1,), np.int32)
    np.cumsum(sub_out, out=sub_row[1:])
    m_pad = next_pow2(idx.shape[0])
    if m_pad > idx.shape[0]:
        fill = np.full(m_pad - idx.shape[0], -1, np.int32)
        sub_src = np.concatenate([sub_src, fill])
        sub_col = np.concatenate([sub_col, fill])
    run = support_on_arrays(
        sub_row, sub_src, sub_col, sub_out,
        max_wedge_chunk=max_wedge_chunk, n_steps=steps, bucket_pow2=True,
        method=method, mesh=mesh,
    )
    return run.support[: idx.shape[0]], run.n_chunks, run.method


def k_truss_subgraph(
    edges,
    k: int | None = None,
    n_nodes: int | None = None,
    *,
    max_wedge_chunk: int | None = None,
    method: str = "auto",
    mesh=None,
) -> tuple[np.ndarray, int]:
    """Extract the k-truss as a canonical edge array.

    ``k=None`` extracts the densest non-empty truss (``max_k``).
    Returns ``(canonical_edges, k)`` — the edge array is in the same
    both-directions canonical form the engine consumes, so the result
    can be counted, served or decomposed again directly.
    """
    dec = (
        edges
        if isinstance(edges, TrussDecomposition)
        else k_truss_decomposition(
            edges, n_nodes, max_wedge_chunk=max_wedge_chunk, method=method,
            mesh=mesh,
        )
    )
    if dec.n_edges == 0:
        return np.zeros((0, 2), np.int32), 0
    kk = dec.max_k if k is None else int(k)
    return dec.edges_at_least(kk), kk
