"""Regex-path → PartitionSpec sharding-rule engine.

Megatron-pattern tensor parallelism + FSDP over the data axis:

* column-parallel weights (QKV, FFN up/gate, router→experts' ff) shard
  their *output* feature dim over ``model``,
* row-parallel weights (attention O, FFN down) shard their *input*
  feature dim over ``model``,
* the surviving large dim additionally shards over the FSDP axes
  (``("pod", "data")``) — ZeRO-3: XLA all-gathers weights at use,
* vocab-parallel embedding / lm_head shard the vocab dim over ``model``,
* 1-D params (norms, biases) replicate.

Optimizer moments reuse the same specs (ZeRO optimizer-state sharding).
"""
from __future__ import annotations

import re
from typing import Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["ShardingRules", "make_param_shardings", "spec_for", "LM_RULES"]


class ShardingRules:
    """Ordered (regex, PartitionSpec-builder) rules over tree paths."""

    def __init__(self, rules: Sequence[tuple[str, tuple]], fsdp_axes=("data",)):
        self.rules = [(re.compile(pat), spec) for pat, spec in rules]
        self.fsdp_axes = fsdp_axes

    def spec(self, path: str, ndim: int) -> P:
        for pat, spec in self.rules:
            if pat.search(path):
                spec = spec[-ndim:] if len(spec) > ndim else spec
                return P(*spec, *([None] * (ndim - len(spec))))
        return P(*([None] * ndim))


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def spec_for(rules: ShardingRules, tree):
    """Pytree of PartitionSpecs matching ``tree``'s structure."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    specs = [rules.spec(_path_str(p), getattr(l, "ndim", 0)) for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def make_param_shardings(mesh: Mesh, rules: ShardingRules, tree):
    specs = spec_for(rules, tree)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


def _fsdp(*names):
    """FSDP axis group placeholder substituted at rule build time."""
    return names


def lm_rules(fsdp: tuple[str, ...] = ("data",), tp_only: bool = False) -> ShardingRules:
    """Sharding rules for the transformer parameter tree.

    Layer params carry a leading stacked-layer dim (from the scan), hence
    the leading ``None`` in the 3-entry specs; the engine right-aligns
    specs shorter than the array rank.

    ``tp_only`` (§Perf): drop the FSDP axis from the weights — for models
    whose fp32 master+moments fit in HBM/TP_degree, per-microbatch weight
    all-gathers are pure overhead; the only DP collective left is the
    gradient all-reduce.
    """
    f = None if tp_only else (fsdp if len(fsdp) > 1 else fsdp[0])
    return ShardingRules(
        [
            # attention — column parallel
            (r"layers/w[qkv]$", (None, f, "model")),
            # attention output — row parallel
            (r"layers/wo$", (None, "model", f)),
            # dense FFN
            (r"layers/w_(gate|up)$", (None, f, "model")),
            (r"layers/w_down$", (None, "model", f)),
            # router (L, d, E): E is tiny (#experts) — never sharded
            (r"layers/router$", (None, f)),
            # vocab parallel
            (r"^embed$", ("model", f)),
            (r"^lm_head$", (f, "model")),
            # everything else (norms, biases) replicated
        ],
        fsdp_axes=fsdp,
    )


LM_RULES = lm_rules()


def moe_rules_patch(
    rules: ShardingRules, fsdp: tuple[str, ...] = ("data",), tp_only: bool = False
) -> ShardingRules:
    """Extra specs for 4-D MoE expert weights (L, E, d, ff): expert-TP —
    the per-expert ff dim shards over model, d over FSDP."""
    f = None if tp_only else (fsdp if len(fsdp) > 1 else fsdp[0])
    extra = [
        (r"layers/w_(gate|up)$", (None, None, f, "model")),
        (r"layers/w_down$", (None, None, "model", f)),
    ]
    merged = [(p.pattern, s) for p, s in rules.rules]
    return ShardingRules(extra + merged, fsdp_axes=fsdp)
