"""Distributed runtime: sharding rules, compression, straggler handling."""
from .sharding import ShardingRules, make_param_shardings, LM_RULES, spec_for
from .compression import compressed_psum, make_error_feedback_state, compress_grads
from .straggler import StragglerMonitor

__all__ = [
    "ShardingRules",
    "make_param_shardings",
    "spec_for",
    "LM_RULES",
    "compressed_psum",
    "make_error_feedback_state",
    "compress_grads",
    "StragglerMonitor",
]
