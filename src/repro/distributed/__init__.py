"""Distributed runtime: sharding rules, compression, straggler handling."""
from .sharding import ShardingRules, make_param_shardings, LM_RULES, spec_for
from .compression import (
    compressed_psum,
    make_error_feedback_state,
    compress_grads,
    zigzag_encode,
    zigzag_decode,
    can_narrow_int32,
    ensure_fits_int32,
    compressed_all_gather_int32,
)
from .straggler import StragglerMonitor, StripeSkewReport, stripe_skew_report

__all__ = [
    "ShardingRules",
    "make_param_shardings",
    "spec_for",
    "LM_RULES",
    "compressed_psum",
    "make_error_feedback_state",
    "compress_grads",
    "zigzag_encode",
    "zigzag_decode",
    "can_narrow_int32",
    "ensure_fits_int32",
    "compressed_all_gather_int32",
    "StragglerMonitor",
    "StripeSkewReport",
    "stripe_skew_report",
]
