"""Int8 gradient compression with error feedback for the DP all-reduce.

``compressed_psum`` quantizes a tensor to int8 with a per-tensor scale,
psums the int8 payload (8.5× less ICI traffic than fp32 + fp32 scale
exchange), and dequantizes.  ``compress_grads`` adds error-feedback
residuals (Karimireddy et al., 2019) so the quantization error is carried
into the next step instead of lost — convergence-neutral in expectation.

Used inside ``shard_map`` train steps on the ``("pod", "data")`` axes; the
tensor-parallel axis keeps exact reductions (its activations collectives
are latency-critical and small).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["compressed_psum", "make_error_feedback_state", "compress_grads"]


def _shared_scale(x: jax.Array, axis_name) -> jax.Array:
    """One scalar scale shared by every shard (a scalar pmax on the wire —
    negligible next to the int8 payload, and required for exactness: a sum
    of int8 payloads quantized with *different* scales cannot be dequantized)."""
    local = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32))), 1e-12) / 127.0
    return jax.lax.pmax(local, axis_name)


def compressed_psum(x: jax.Array, axis_name) -> jax.Array:
    """psum(x) with int8 payload; returns fp32."""
    xf = x.astype(jnp.float32)
    scale = _shared_scale(xf, axis_name)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    # int8 sums can overflow int8; accumulate in int32 on the wire-out side
    q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return q_sum.astype(jnp.float32) * scale


def make_error_feedback_state(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_grads(grads, ef_state, axis_name):
    """Error-feedback compressed gradient all-reduce.

    Returns (synchronized grads, new error-feedback state).
    """

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        scale = _shared_scale(gf, axis_name)
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        new_e = gf - q.astype(jnp.float32) * scale  # local quantization error
        q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        g_sync = q_sum.astype(jnp.float32) * scale / n
        return g_sync.astype(g.dtype), new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(ef_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return tdef.unflatten([o[0] for o in out]), tdef.unflatten([o[1] for o in out])
