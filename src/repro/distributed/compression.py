"""Wire compression for cross-device collectives.

Two families live here:

* **Lossy int8 gradient compression** for the DP all-reduce:
  ``compressed_psum`` quantizes a tensor to int8 with a per-tensor scale,
  psums the int8 payload (8.5× less ICI traffic than fp32 + fp32 scale
  exchange), and dequantizes.  ``compress_grads`` adds error-feedback
  residuals (Karimireddy et al., 2019) so the quantization error is
  carried into the next step instead of lost — convergence-neutral in
  expectation.  Used inside ``shard_map`` train steps on the
  ``("pod", "data")`` axes.

* **Lossless int32 delta compression** for the triangle engine's
  distributed support merge (:mod:`repro.core.distributed`):
  ``compressed_all_gather_int32`` delta-transforms each shard's per-edge
  support partials (``jnp.diff`` + zigzag), narrows the wire payload to
  uint16 when the value bound allows (per-chunk per-edge support is
  bounded by the max out-degree ≤ √(2m), so 2·bound < 2¹⁶ holds for any
  graph under ~2³⁰ edges), all-gathers the narrow payload, and decodes
  with a cumulative sum — **bit-exact** by construction, halving the
  all-gather bytes on the support hot path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "compressed_psum",
    "make_error_feedback_state",
    "compress_grads",
    "zigzag_encode",
    "zigzag_decode",
    "can_narrow_int32",
    "compressed_all_gather_int32",
]


def _shared_scale(x: jax.Array, axis_name) -> jax.Array:
    """One scalar scale shared by every shard (a scalar pmax on the wire —
    negligible next to the int8 payload, and required for exactness: a sum
    of int8 payloads quantized with *different* scales cannot be dequantized)."""
    local = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32))), 1e-12) / 127.0
    return jax.lax.pmax(local, axis_name)


def compressed_psum(x: jax.Array, axis_name) -> jax.Array:
    """psum(x) with int8 payload; returns fp32."""
    xf = x.astype(jnp.float32)
    scale = _shared_scale(xf, axis_name)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    # int8 sums can overflow int8; accumulate in int32 on the wire-out side
    q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return q_sum.astype(jnp.float32) * scale


def make_error_feedback_state(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_grads(grads, ef_state, axis_name):
    """Error-feedback compressed gradient all-reduce.

    Returns (synchronized grads, new error-feedback state).
    """

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        scale = _shared_scale(gf, axis_name)
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        new_e = gf - q.astype(jnp.float32) * scale  # local quantization error
        q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        g_sync = q_sum.astype(jnp.float32) * scale / n
        return g_sync.astype(g.dtype), new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(ef_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return tdef.unflatten([o[0] for o in out]), tdef.unflatten([o[1] for o in out])


# ---------------------------------------------------------------------------
# lossless int32 delta compression (distributed support all-gather)
# ---------------------------------------------------------------------------


def zigzag_encode(d: jax.Array) -> jax.Array:
    """Map signed int32 deltas to non-negative ints (0,−1,1,−2 → 0,1,2,3)."""
    d = d.astype(jnp.int32)
    return ((d << 1) ^ (d >> 31)).astype(jnp.int32)


def zigzag_decode(z: jax.Array) -> jax.Array:
    """Inverse of :func:`zigzag_encode`."""
    z = z.astype(jnp.int32)
    return (z >> 1) ^ -(z & 1)


INT32_MAX = 2**31 - 1


def ensure_fits_int32(value: int, what: str = "value") -> int:
    """Loud bound check before narrowing an index-scale value to int32.

    The ingest/plan layers store edge indices and CSR offsets as int32 for
    device-side compactness; ``.astype(np.int32)`` alone *wraps* once the
    graph crosses 2³¹ directed edges.  Every such narrowing must route
    through this guard (trilint pass ``overflow``/``O3-narrow`` enforces
    it) so m >= 2³¹ fails with a diagnosis instead of corrupting counts.
    """
    v = int(value)
    if not 0 <= v <= INT32_MAX:
        raise OverflowError(
            f"{what} = {v} does not fit int32 (max {INT32_MAX}); this graph "
            "needs the int64 index path, narrowing would wrap silently"
        )
    return v


def can_narrow_int32(bound: int) -> bool:
    """Can values in ``[0, bound]`` ride a uint16 wire after delta+zigzag?

    Deltas of such values lie in ``[-bound, bound]``; zigzag maps them to
    ``[0, 2·bound]``, so the narrow wire is lossless iff ``2·bound < 2¹⁶``.
    """
    return 0 <= 2 * int(bound) <= 0xFFFF


def compressed_all_gather_int32(x: jax.Array, axis_names, *, narrow: bool = True):
    """Lossless delta-compressed ``all_gather`` of int32 partials.

    Inside ``shard_map``: each shard's rank-1 int32 vector is
    delta-transformed (``jnp.diff`` with the first element kept),
    zigzag-encoded, narrowed to uint16 on the wire when ``narrow``, and
    gathered over ``axis_names``; the ``(n_shards, n)`` result is decoded
    by a cumulative sum.  Callers must establish the narrowing bound
    host-side via :func:`can_narrow_int32` — with ``narrow=False`` this
    is a plain int32 ``all_gather`` (identical results, wider wire).
    """
    x = x.astype(jnp.int32)
    if not narrow:
        return jax.lax.all_gather(x, axis_names, tiled=False)
    d = jnp.diff(x, prepend=jnp.zeros((1,), jnp.int32))
    wire = zigzag_encode(d).astype(jnp.uint16)
    z = jax.lax.all_gather(wire, axis_names, tiled=False).astype(jnp.int32)
    return jnp.cumsum(zigzag_decode(z), axis=-1, dtype=jnp.int32)
