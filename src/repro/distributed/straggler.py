"""Straggler detection: training-step timing and engine stripe skew.

On a real pod a straggling host shows up as a slow step for *everyone*
(collectives are synchronous).  :class:`StragglerMonitor` keeps a robust
running estimate (median + MAD over a sliding window) of step wall time
and flags anomalies; the train loop's hook decides what to do with a
flag — log-and-continue, checkpoint-now (before a suspected failing host
dies), or trigger an elastic re-mesh.

:func:`stripe_skew_report` is the triangle engine's counterpart for the
§III-E striped edge partition: because the distributed kernels are
synchronous collectives, a stripe with an outsized wedge load *is* the
straggler — wall time per launch is the max over stripes — so load skew
measured host-side from the plan equals the timing skew a profiler would
see.  The report surfaces in ``EngineStats`` after every distributed
call.  Both pieces are host-side and fully unit-testable without
hardware.
"""
from __future__ import annotations

import collections
import dataclasses
import statistics
import time
from typing import Callable, Sequence

__all__ = [
    "StragglerMonitor",
    "StripeSkewReport",
    "skew_disagreement_note",
    "stripe_skew_report",
]


class StragglerMonitor:
    def __init__(
        self,
        window: int = 50,
        threshold: float = 3.0,
        min_samples: int = 10,
        on_straggle: Callable[[int, float, float], None] | None = None,
    ):
        self.window = window
        self.threshold = threshold
        self.min_samples = min_samples
        self.on_straggle = on_straggle
        self.times: collections.deque[float] = collections.deque(maxlen=window)
        self.flags: list[tuple[int, float]] = []
        self._t0: float | None = None
        self._step = 0

    def start_step(self) -> None:
        self._t0 = time.monotonic()

    def end_step(self) -> bool:
        """Record a step duration; returns True if the step straggled."""
        assert self._t0 is not None, "start_step() not called"
        dt = time.monotonic() - self._t0
        self._t0 = None
        return self.observe(dt)

    def observe(self, dt: float) -> bool:
        """Pure observation API (used by tests with synthetic timings)."""
        self._step += 1
        straggled = False
        if len(self.times) >= self.min_samples:
            med = statistics.median(self.times)
            mad = statistics.median(abs(t - med) for t in self.times) or (0.05 * med)
            if dt > med + self.threshold * 1.4826 * mad and dt > 1.2 * med:
                straggled = True
                self.flags.append((self._step, dt))
                if self.on_straggle is not None:
                    self.on_straggle(self._step, dt, med)
        # straggler steps do not poison the baseline window
        if not straggled:
            self.times.append(dt)
        return straggled

    @property
    def median(self) -> float:
        return statistics.median(self.times) if self.times else float("nan")


@dataclasses.dataclass(frozen=True)
class StripeSkewReport:
    """Wedge-load imbalance across the §III-E edge stripes of one workload.

    ``skew`` is ``max_load / mean_load`` (1.0 = perfectly balanced; the
    launch wall time tracks the max, so skew is the slowdown factor vs a
    perfect partition).  ``straggler_stripe`` is the index of the stripe
    flagged by the same median+MAD rule :class:`StragglerMonitor` applies
    to step timings — ``None`` when no stripe is anomalous (round-robin
    striping keeps skew near 1 on most graphs).
    """

    n_stripes: int
    loads: tuple[int, ...]        # wedge slots per stripe
    mean_load: float
    max_load: int
    skew: float
    straggler_stripe: int | None


def stripe_skew_report(
    loads: Sequence[int], threshold: float = 3.0
) -> StripeSkewReport:
    """Build a :class:`StripeSkewReport` from per-stripe wedge loads."""
    loads = tuple(int(x) for x in loads)
    n = len(loads)
    if n == 0 or max(loads) == 0:
        return StripeSkewReport(n, loads, 0.0, 0, 1.0, None)
    mean = sum(loads) / n
    mx = max(loads)
    skew = mx / mean if mean > 0 else 1.0
    straggler = None
    if n >= 2:
        med = statistics.median(loads)
        mad = statistics.median(abs(x - med) for x in loads) or (0.05 * med)
        if mx > med + threshold * 1.4826 * mad and mx > 1.2 * med:
            straggler = loads.index(mx)
    return StripeSkewReport(n, loads, mean, mx, skew, straggler)


def skew_disagreement_note(
    load_report: StripeSkewReport, measured_report: StripeSkewReport
) -> "str | None":
    """Loud note when load-inferred and measured stragglers disagree.

    The engine's ``stripe_skew`` assumes wedge load is a faithful proxy
    for stripe time ("the collectives are synchronous, so load skew *is*
    timing skew").  Under tracing the per-stripe probe measures actual
    times, and this is the tripwire for the proxy breaking — e.g. one
    stripe's edges hitting a pathological search depth, or a device-side
    imbalance invisible to the planner.  Returns ``None`` when both
    reports agree (including both finding no straggler).
    """
    if load_report.straggler_stripe == measured_report.straggler_stripe:
        return None
    return (
        "stripe skew disagreement: wedge-load inference flags stripe "
        f"{load_report.straggler_stripe} (skew {load_report.skew:.2f}) but "
        f"measured stripe times flag stripe {measured_report.straggler_stripe} "
        f"(skew {measured_report.skew:.2f}); load is a proxy — trust the "
        "measured times"
    )
