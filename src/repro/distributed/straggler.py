"""Straggler detection for the training loop.

On a real pod a straggling host shows up as a slow step for *everyone*
(collectives are synchronous).  The monitor keeps a robust running
estimate (median + MAD over a sliding window) of step wall time and flags
anomalies; the train loop's hook decides what to do with a flag —
log-and-continue, checkpoint-now (before a suspected failing host dies),
or trigger an elastic re-mesh.  The decision logic is host-side and fully
unit-testable without hardware.
"""
from __future__ import annotations

import collections
import statistics
import time
from typing import Callable

__all__ = ["StragglerMonitor"]


class StragglerMonitor:
    def __init__(
        self,
        window: int = 50,
        threshold: float = 3.0,
        min_samples: int = 10,
        on_straggle: Callable[[int, float, float], None] | None = None,
    ):
        self.window = window
        self.threshold = threshold
        self.min_samples = min_samples
        self.on_straggle = on_straggle
        self.times: collections.deque[float] = collections.deque(maxlen=window)
        self.flags: list[tuple[int, float]] = []
        self._t0: float | None = None
        self._step = 0

    def start_step(self) -> None:
        self._t0 = time.monotonic()

    def end_step(self) -> bool:
        """Record a step duration; returns True if the step straggled."""
        assert self._t0 is not None, "start_step() not called"
        dt = time.monotonic() - self._t0
        self._t0 = None
        return self.observe(dt)

    def observe(self, dt: float) -> bool:
        """Pure observation API (used by tests with synthetic timings)."""
        self._step += 1
        straggled = False
        if len(self.times) >= self.min_samples:
            med = statistics.median(self.times)
            mad = statistics.median(abs(t - med) for t in self.times) or (0.05 * med)
            if dt > med + self.threshold * 1.4826 * mad and dt > 1.2 * med:
                straggled = True
                self.flags.append((self._step, dt))
                if self.on_straggle is not None:
                    self.on_straggle(self._step, dt, med)
        # straggler steps do not poison the baseline window
        if not straggled:
            self.times.append(dt)
        return straggled

    @property
    def median(self) -> float:
        return statistics.median(self.times) if self.times else float("nan")
