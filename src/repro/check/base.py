"""trilint core: finding model, module loading, suppression, allowlists.

trilint is a repo-specific static-analysis suite enforcing the engine's
correctness invariants (see README "Invariants").  Each pass is a function
``(module: ModuleInfo) -> list[Finding]`` registered in ``PASSES``; the
driver walks every ``*.py`` under a root (default ``src/repro``), runs the
selected passes, and applies two suppression channels:

* inline: a ``# trilint: ok[rule]`` comment on the flagged line (or the
  line directly above it) suppresses findings for that rule;
  ``# trilint: ok`` suppresses all rules on that line.
* allowlist file: lines of the form ``<path-glob> <rule|*> <substring|*>``
  (``#`` starts a comment).  A finding matches when its repo-relative path
  matches the glob, the rule matches, and the substring occurs in the
  message.

Passes are pure ``ast`` + stdlib so the lint CLI runs without jax/numpy
installed (the runtime sanitizer in ``repro.check.runtime`` is separate).
"""

from __future__ import annotations

import ast
import fnmatch
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Iterable, Optional

# ---------------------------------------------------------------------------
# Finding model


@dataclass
class Finding:
    """One diagnostic emitted by a lint pass."""

    rule: str  # pass name, e.g. "overflow"
    code: str  # stable rule code, e.g. "O1-sum-dtype"
    path: str  # repo-relative posix path
    line: int
    col: int
    message: str
    snippet: str = ""
    suppressed: bool = False
    suppression: str = ""  # "inline" | "allowlist:<line>" when suppressed

    def to_dict(self) -> dict:
        return {
            "rule": self.rule,
            "code": self.code,
            "path": self.path,
            "line": self.line,
            "col": self.col,
            "message": self.message,
            "snippet": self.snippet,
            "suppressed": self.suppressed,
            "suppression": self.suppression,
        }

    def render(self) -> str:
        mark = " (suppressed)" if self.suppressed else ""
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}/{self.code}]{mark} {self.message}"


@dataclass
class ModuleInfo:
    """A parsed source module handed to each pass."""

    path: Path  # absolute
    rel: str  # posix path relative to the scan root's parent (e.g. "core/engine.py")
    source: str
    lines: list[str] = field(default_factory=list)
    tree: Optional[ast.AST] = None

    def snippet(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""

    def finding(self, rule: str, code: str, node: ast.AST, message: str) -> Finding:
        line = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0)
        return Finding(
            rule=rule,
            code=code,
            path=self.rel,
            line=line,
            col=col,
            message=message,
            snippet=self.snippet(line),
        )


# ---------------------------------------------------------------------------
# Pass registry

PassFn = Callable[[ModuleInfo], "list[Finding]"]

PASSES: "dict[str, PassFn]" = {}


def register_pass(name: str) -> Callable[[PassFn], PassFn]:
    def deco(fn: PassFn) -> PassFn:
        PASSES[name] = fn
        return fn

    return deco


def load_passes() -> "dict[str, PassFn]":
    """Import the pass modules so their ``register_pass`` decorators run."""
    from . import backend_protocol  # noqa: F401
    from . import codec  # noqa: F401
    from . import collectives  # noqa: F401
    from . import obs_discipline  # noqa: F401
    from . import overflow  # noqa: F401
    from . import recompile  # noqa: F401
    from . import stats_lifecycle  # noqa: F401

    return dict(PASSES)


# ---------------------------------------------------------------------------
# Module walking


def load_module(path: Path, rel: str) -> Optional[ModuleInfo]:
    try:
        source = path.read_text()
    except OSError:
        return None
    mod = ModuleInfo(path=path, rel=rel, source=source, lines=source.splitlines())
    try:
        mod.tree = ast.parse(source, filename=str(path))
    except SyntaxError:
        mod.tree = None
    return mod


def iter_modules(root: Path) -> Iterable[ModuleInfo]:
    """Yield every parseable ``*.py`` under ``root`` (sorted, skipping caches)."""
    root = root.resolve()
    for path in sorted(root.rglob("*.py")):
        if "__pycache__" in path.parts:
            continue
        rel = path.relative_to(root).as_posix()
        mod = load_module(path, rel)
        if mod is not None:
            yield mod


# ---------------------------------------------------------------------------
# Inline suppression

_SUPPRESS_RE = re.compile(r"#\s*trilint:\s*ok(?:\[([a-z0-9_,\s-]+)\])?")


def _suppressed_rules(line: str) -> Optional[set]:
    """Return the rule set suppressed by ``line`` (empty set = all rules)."""
    m = _SUPPRESS_RE.search(line)
    if not m:
        return None
    if m.group(1) is None:
        return set()
    return {r.strip() for r in m.group(1).split(",") if r.strip()}


def apply_inline_suppressions(mod: ModuleInfo, findings: "list[Finding]") -> None:
    for f in findings:
        for lineno in (f.line, f.line - 1):
            if not (1 <= lineno <= len(mod.lines)):
                continue
            rules = _suppressed_rules(mod.lines[lineno - 1])
            if rules is None:
                continue
            if not rules or f.rule in rules or f.code in rules:
                f.suppressed = True
                f.suppression = "inline"
                break


# ---------------------------------------------------------------------------
# Allowlist

@dataclass
class AllowRule:
    path_glob: str
    rule: str  # pass name, code, or "*"
    substring: str  # substring of message, or "*"
    lineno: int  # line in the allowlist file (for provenance)

    def matches(self, f: Finding) -> bool:
        if not fnmatch.fnmatch(f.path, self.path_glob):
            return False
        if self.rule not in ("*", f.rule, f.code):
            return False
        if self.substring != "*" and self.substring not in f.message:
            return False
        return True


def parse_allowlist(text: str) -> "list[AllowRule]":
    rules = []
    for i, raw in enumerate(text.splitlines(), start=1):
        line = raw.split("#", 1)[0].strip()
        if not line:
            continue
        parts = line.split(None, 2)
        while len(parts) < 3:
            parts.append("*")
        rules.append(AllowRule(parts[0], parts[1], parts[2], i))
    return rules


def apply_allowlist(findings: "list[Finding]", rules: "list[AllowRule]") -> None:
    for f in findings:
        if f.suppressed:
            continue
        for r in rules:
            if r.matches(f):
                f.suppressed = True
                f.suppression = f"allowlist:{r.lineno}"
                break


# ---------------------------------------------------------------------------
# Driver


def run_checks(
    root: Path,
    allowlist_path: Optional[Path] = None,
    select: Optional[Iterable[str]] = None,
) -> "list[Finding]":
    """Run the selected passes over every module under ``root``.

    Returns all findings with suppression flags already applied; callers
    decide what to do with suppressed ones (the CLI only fails on
    unsuppressed findings).
    """
    passes = load_passes()
    if select:
        wanted = set(select)
        unknown = wanted - set(passes)
        if unknown:
            raise ValueError(f"unknown pass(es): {sorted(unknown)}; have {sorted(passes)}")
        passes = {k: v for k, v in passes.items() if k in wanted}

    allow_rules: "list[AllowRule]" = []
    if allowlist_path is not None and Path(allowlist_path).exists():
        allow_rules = parse_allowlist(Path(allowlist_path).read_text())

    findings: "list[Finding]" = []
    for mod in iter_modules(Path(root)):
        if mod.tree is None:
            findings.append(
                Finding(
                    rule="parse",
                    code="P0-syntax",
                    path=mod.rel,
                    line=1,
                    col=0,
                    message="file does not parse; all passes skipped",
                )
            )
            continue
        mod_findings: "list[Finding]" = []
        for fn in passes.values():
            mod_findings.extend(fn(mod))
        apply_inline_suppressions(mod, mod_findings)
        findings.extend(mod_findings)

    apply_allowlist(findings, allow_rules)
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.code))
    return findings


# ---------------------------------------------------------------------------
# Shared AST helpers used by several passes


def call_name(node: ast.Call) -> str:
    """Dotted name of a call target: ``jnp.sum`` -> "jnp.sum", ``f`` -> "f"."""
    return dotted_name(node.func)


def dotted_name(node: ast.AST) -> str:
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def has_keyword(node: ast.Call, name: str) -> bool:
    return any(kw.arg == name for kw in node.keywords)


def walk_calls(tree: ast.AST) -> "Iterable[ast.Call]":
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            yield node


def enclosing_function_stack(tree: ast.AST, target: ast.AST) -> "list[ast.AST]":
    """Return the stack of FunctionDef/AsyncFunctionDef nodes enclosing target.

    Innermost last.  Linear walk with a parent map; fine at repo scale.
    """
    parents: "dict[ast.AST, ast.AST]" = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            parents[child] = node
    stack: "list[ast.AST]" = []
    cur = target
    while cur in parents:
        cur = parents[cur]
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            stack.append(cur)
    stack.reverse()
    return stack


def build_parent_map(tree: ast.AST) -> "dict[ast.AST, ast.AST]":
    parents: "dict[ast.AST, ast.AST]" = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            parents[child] = node
    return parents


def function_calls(fn: ast.AST) -> "set[str]":
    """All dotted call-target names appearing in a function body."""
    names = set()
    for call in walk_calls(fn):
        name = call_name(call)
        if name:
            names.add(name)
            names.add(name.rsplit(".", 1)[-1])
    return names
