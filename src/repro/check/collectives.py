"""trilint pass: collective hygiene under the striped mesh.

The distributed path (SIII-E striping) relies on three conventions:
collectives name a mesh axis that is actually declared; striped-kernel
outputs stay *replicated* (reconstructed from gathered row indices, never
from ``axis_index`` — the PR 6 parity bug); and every ``shard_map`` states
its specs explicitly so sharding is visible at the call site.

* ``C1-axis-undeclared`` — a string-literal axis name passed to
  ``psum``/``all_gather``/... that does not appear in any ``Mesh``/
  ``PartitionSpec`` declaration (or ``*AXIS*`` constant) in the module.
* ``C2-axis-index-in-core`` — ``axis_index`` used in a ``core/`` counting
  module; striped outputs must be replicated, not rank-dependent.
* ``C3-shardmap-specs`` — ``shard_map`` call missing explicit
  ``in_specs``/``out_specs``.
"""

from __future__ import annotations

import ast

from .base import (
    Finding,
    ModuleInfo,
    call_name,
    has_keyword,
    register_pass,
)

_COLLECTIVES = {
    "psum", "pmean", "pmax", "pmin", "all_gather", "ppermute",
    "all_to_all", "axis_index", "pbroadcast",
}

# Calls whose string-constant arguments declare axis names.
_DECLARING_CALLS = {"Mesh", "make_mesh", "P", "PartitionSpec", "NamedSharding"}


def _declared_axes(tree: ast.AST) -> "set[str]":
    axes: "set[str]" = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            name = call_name(node).rsplit(".", 1)[-1]
            if name in _DECLARING_CALLS:
                for sub in ast.walk(node):
                    if isinstance(sub, ast.Constant) and isinstance(sub.value, str):
                        axes.add(sub.value)
        elif isinstance(node, ast.Assign):
            # module constants like STRIPE_AXIS = "stripe"
            for tgt in node.targets:
                if (
                    isinstance(tgt, ast.Name)
                    and "AXIS" in tgt.id.upper()
                    and isinstance(node.value, ast.Constant)
                    and isinstance(node.value.value, str)
                ):
                    axes.add(node.value.value)
    return axes


def _axis_literals(call: ast.Call) -> "list[str]":
    """String literals passed as axis name(s) to a collective call."""
    out = []
    cands: "list[ast.AST]" = []
    # positional: psum(x, "axis") / all_gather(x, "axis", ...)
    if len(call.args) >= 2:
        cands.append(call.args[1])
    if call_name(call).rsplit(".", 1)[-1] == "axis_index" and call.args:
        cands.append(call.args[0])
    for kw in call.keywords:
        if kw.arg in ("axis_name", "axis"):
            cands.append(kw.value)
    for c in cands:
        if isinstance(c, ast.Constant) and isinstance(c.value, str):
            out.append(c.value)
        elif isinstance(c, (ast.Tuple, ast.List)):
            for el in c.elts:
                if isinstance(el, ast.Constant) and isinstance(el.value, str):
                    out.append(el.value)
    return out


@register_pass("collectives")
def check_collectives(mod: ModuleInfo) -> "list[Finding]":
    findings: "list[Finding]" = []
    tree = mod.tree
    declared = _declared_axes(tree)

    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = call_name(node)
        short = name.rsplit(".", 1)[-1]

        if short in _COLLECTIVES:
            for axis in _axis_literals(node):
                if axis not in declared:
                    findings.append(
                        mod.finding(
                            "collectives",
                            "C1-axis-undeclared",
                            node,
                            f"collective `{short}` names axis '{axis}' but no "
                            "Mesh/PartitionSpec/*AXIS* declaration in this module "
                            "declares it",
                        )
                    )

        if short == "axis_index" and mod.rel.startswith("core/"):
            findings.append(
                mod.finding(
                    "collectives",
                    "C2-axis-index-in-core",
                    node,
                    "axis_index in a core counting module: striped kernel outputs "
                    "must stay replicated (reconstruct positions from gathered row "
                    "indices instead)",
                )
            )

        if short == "shard_map":
            if not (has_keyword(node, "in_specs") and has_keyword(node, "out_specs")):
                findings.append(
                    mod.finding(
                        "collectives",
                        "C3-shardmap-specs",
                        node,
                        "shard_map without explicit in_specs/out_specs; sharding "
                        "must be visible at the call site",
                    )
                )
    return findings
