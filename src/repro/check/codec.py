"""trilint pass: decode-path narrowing discipline for the ``.tricsrz`` codec.

Varint/delta decoding works in uint64/int64 — zigzag deltas are signed and
a 10-byte varint can carry a full 64-bit value — but the kernels consume
int32 column ids.  The narrowing point is where a corrupt or adversarial
payload turns into silent id aliasing: a decoded value >= 2^31 wraps to a
negative int32 and indexes some *other* node's adjacency.  One rule:

* ``Z1-unchecked-decode-narrow`` — a function that consumes a decode-family
  producer (``decode_varints`` / ``decode_block`` / ``decode_node_range`` /
  ``_decode_rows`` / ``_unzigzag``) and narrows a value to int32
  (``.astype(int32)``, ``np.int32(...)``, or an ``np.asarray(..., int32)``
  dtype argument) without calling a bound guard (``ensure_fits_int32`` /
  ``can_narrow_int32`` / ``validate_node_ids``) in the same function.
  Unlike overflow's O3 (index-scale producers, repo-wide), this rule keys
  on the codec's decode surface, where the values are attacker-controlled
  file bytes rather than self-generated indices.
"""

from __future__ import annotations

import ast

from .base import (
    Finding,
    ModuleInfo,
    call_name,
    dotted_name,
    function_calls,
    register_pass,
)

# Callables whose return values originate in the varint/delta byte stream.
_DECODE_PRODUCERS = {
    "decode_varints",
    "decode_block",
    "decode_node_range",
    "_decode_rows",
    "_unzigzag",
}

# Calling any of these in the same function counts as a loud bound check.
_NARROW_GUARDS = {"ensure_fits_int32", "can_narrow_int32", "validate_node_ids"}

_INT32_NAMES = {"np.int32", "jnp.int32", "numpy.int32", "jax.numpy.int32"}


def _is_int32_expr(node: ast.AST) -> bool:
    if dotted_name(node) in _INT32_NAMES:
        return True
    return isinstance(node, ast.Constant) and node.value == "int32"


def _narrowing_calls(fn: ast.AST):
    """Yield (call, description) for every int32 narrowing inside ``fn``."""
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        # x.astype(np.int32) / x.astype("int32")
        if isinstance(node.func, ast.Attribute) and node.func.attr == "astype":
            if any(_is_int32_expr(a) for a in node.args):
                yield node, ".astype(int32)"
                continue
        name = call_name(node)
        # np.int32(x) scalar cast
        if name in _INT32_NAMES and node.args:
            yield node, "np.int32(...) cast"
            continue
        # np.asarray(x, np.int32) / np.array(x, dtype=np.int32) etc.
        if name.rsplit(".", 1)[-1] in ("asarray", "array", "empty", "zeros_like"):
            for a in node.args[1:]:
                if _is_int32_expr(a):
                    yield node, f"{name} with int32 dtype"
                    break
            else:
                for kw in node.keywords:
                    if kw.arg == "dtype" and _is_int32_expr(kw.value):
                        yield node, f"{name} with dtype=int32"
                        break


@register_pass("codec")
def check_codec(mod: ModuleInfo) -> "list[Finding]":
    findings: "list[Finding]" = []
    for fn in ast.walk(mod.tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        calls = function_calls(fn)  # includes both dotted and bare names
        if not _DECODE_PRODUCERS & calls:
            continue
        if _NARROW_GUARDS & calls:
            continue
        for call, how in _narrowing_calls(fn):
            findings.append(
                mod.finding(
                    "codec",
                    "Z1-unchecked-decode-narrow",
                    call,
                    f"`{fn.name}` narrows decoded varint/delta data via {how} "
                    "with no ensure_fits_int32/can_narrow_int32 guard in the "
                    "function; a corrupt payload wraps to a negative id and "
                    "aliases another node's adjacency",
                )
            )
    return findings
