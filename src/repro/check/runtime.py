"""Runtime audit layer: the ``REPRO_CHECK=1`` sanitizer and CompileAuditor.

Static passes (``python -m repro.check``) catch patterns; this module
checks the two invariants that only hold *dynamically*:

* **int32 partial headroom** — with ``REPRO_CHECK=1`` in the environment,
  ``run_workload`` routes every device partial through
  :func:`check_partial` before the host fold, asserting it is a narrow
  integer (int32-or-smaller, the device accumulator contract) whose values
  retain headroom below 2^30.  A partial at 2^30 means one more doubling
  overflows int32 *on device*, before any host fold can widen it.
* **O(log m) compilations** — :class:`CompileAuditor` snapshots the jit
  trace-cache sizes of the engine's kernel entry points around a workload
  and asserts no kernel traced more than O(log m) new shapes (the pow2
  bucketing guarantee behind truss peeling and incremental sessions).

Overhead of the sanitizer is a device->host sync per chunk (min/max of the
partial); see EXPERIMENTS.md for the measured cost on the kron-13 count.
"""

from __future__ import annotations

import math
import os

import numpy as np

REPRO_CHECK_ENV = "REPRO_CHECK"

# Values at/above this lack doubling headroom inside int32.
PARTIAL_HEADROOM = 1 << 30


class RuntimeCheckError(AssertionError):
    """An engine correctness invariant failed at runtime."""


def enabled() -> bool:
    """True when the ``REPRO_CHECK`` env var is set to a truthy value."""
    return os.environ.get(REPRO_CHECK_ENV, "").strip().lower() not in (
        "", "0", "false", "off", "no",
    )


def check_partial(part, *, kind: str, context: str = "") -> None:
    """Assert one device partial honors the int32-accumulator contract.

    ``part`` is whatever a backend's ``count_chunk`` / ``per_node_chunk``
    / ``support_chunk`` returned, *before* the host fold widens it.
    """
    a = np.asarray(part)
    where = f" ({context})" if context else ""
    if a.size == 0:
        return
    if a.dtype.kind == "b":
        return
    if a.dtype.kind not in "iu":
        raise RuntimeCheckError(
            f"REPRO_CHECK: {kind} partial{where} has non-integer dtype {a.dtype}; "
            "device kernels must emit integer counts"
        )
    if a.dtype.itemsize > 4:
        raise RuntimeCheckError(
            f"REPRO_CHECK: {kind} partial{where} arrived as {a.dtype}; the device "
            "accumulator contract is int32 — a 64-bit device dtype hides exactly "
            "the overflow the host fold exists to absorb"
        )
    lo = int(a.min())
    hi = int(a.max())
    if lo < 0:
        raise RuntimeCheckError(
            f"REPRO_CHECK: {kind} partial{where} contains negative count {lo}; "
            "likely an int32 wraparound on device"
        )
    if hi >= PARTIAL_HEADROOM:
        raise RuntimeCheckError(
            f"REPRO_CHECK: {kind} partial{where} peaks at {hi} >= 2^30; no "
            "doubling headroom left in the int32 device accumulator — shrink "
            "the chunk budget"
        )


def check_partials(partials, *, kind: str, context: str = "") -> None:
    for i, p in enumerate(partials):
        check_partial(p, kind=kind, context=context or f"chunk {i}")


# ---------------------------------------------------------------------------
# CompileAuditor


def _default_kernel_table():
    """Name -> jitted fn for the repo's kernel entry points (lazy imports)."""
    from repro.core import count as _count
    from repro.core import engine as _engine

    jitted = {
        "chunk_count_kernel": _engine.chunk_count_kernel,
        "chunk_per_node_kernel": _engine.chunk_per_node_kernel,
        "chunk_support_kernel": _engine.chunk_support_kernel,
        "gather_panels": _count.gather_panels,
        "gather_panels_arrays": _count.gather_panels_arrays,
    }
    try:
        from repro.kernels.triangle_count import triangle_count as _tc

        jitted["pallas_run_count"] = _tc._run_count
        jitted["pallas_run_per_node"] = _tc._run_per_node
        jitted["pallas_run_support"] = _tc._run_support
    except Exception:  # pallas layer optional at audit time
        pass
    lru = {}
    try:
        from repro.core import distributed as _dist

        lru["striped_workload_fn"] = _dist.striped_workload_fn
    except Exception:
        pass
    return jitted, lru


class CompileAuditor:
    """Counts actual jit tracings per kernel across a ``with`` block.

    Uses the trace-cache sizes jax maintains per jitted callable (and
    ``lru_cache`` stats for the striped shard_map factory), so it measures
    *real* compilations, not estimates.  ``assert_log_bound(m)`` then
    enforces the engine's O(log m) promise: with pow2 bucketing, a full
    truss decomposition or incremental session over an m-edge graph may
    trace at most ``factor * log2(m) + slack`` distinct shapes per kernel.
    """

    def __init__(self, extra_jitted=None):
        self._jitted, self._lru = _default_kernel_table()
        if extra_jitted:
            self._jitted.update(extra_jitted)
        self._start = None
        self._end = None

    def _snapshot(self) -> "dict[str, int]":
        sizes: "dict[str, int]" = {}
        for name, fn in self._jitted.items():
            try:
                sizes[name] = int(fn._cache_size())
            except Exception:
                sizes[name] = 0
        for name, fn in self._lru.items():
            sizes[name] = int(fn.cache_info().currsize)
        return sizes

    def __enter__(self) -> "CompileAuditor":
        self._start = self._snapshot()
        self._end = None
        return self

    def __exit__(self, *exc) -> bool:
        self._end = self._snapshot()
        return False

    @property
    def new_traces(self) -> "dict[str, int]":
        """Per-kernel count of traces minted inside the block."""
        if self._start is None:
            raise RuntimeCheckError("CompileAuditor used outside a with block")
        end = self._end if self._end is not None else self._snapshot()
        return {
            name: max(0, end.get(name, 0) - self._start.get(name, 0))
            for name in end
        }

    @property
    def total_new_traces(self) -> int:
        return sum(self.new_traces.values())

    def assert_log_bound(self, m: int, *, factor: float = 4.0, slack: int = 6) -> int:
        """Assert every kernel traced <= ``factor*log2(m) + slack`` shapes.

        Returns the bound so callers can log it.  ``factor`` covers the
        independent static axes that legitimately multiply the shape
        buckets (wedge budget x bisection depth), ``slack`` the one-off
        warmup shapes.
        """
        bound = int(factor * math.log2(max(int(m), 2)) + slack)
        offenders = {k: v for k, v in self.new_traces.items() if v > bound}
        if offenders:
            raise RuntimeCheckError(
                f"REPRO_CHECK: compile-count bound exceeded for m={m} "
                f"(bound {bound}): {offenders}; pow2 bucketing is not reaching "
                "these kernels (see trilint pass `recompile`)"
            )
        return bound
