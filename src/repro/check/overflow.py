"""trilint pass: overflow discipline on counting paths.

The engine's contract (README "Invariants") is: device kernels accumulate in
int32 (fast on GPU, enough headroom per bounded chunk), and every host-side
fold widens explicitly to int64/uint64 before totals are formed — the
paper's headline graph has 3.8B triangles, ~2x past int32.  Three rules:

* ``O1-sum-dtype`` — ``jnp.sum``/``np.sum`` (and ``.sum()`` method calls
  inside jit-compiled functions) without an explicit ``dtype=`` on a
  counting path.  ``jnp.sum`` of int32 stays int32; silent.
* ``O2-host-fold`` — ``int(... .sum() ...)`` where the reduction neither
  passes ``dtype=`` nor widens via ``.astype(int64/uint64)`` first.  On a
  jnp array this folds through an int32 accumulator before ``int()`` sees
  it.
* ``O3-narrow`` — ``.astype(int32)`` applied to index-scale values produced
  by ``nonzero``/``searchsorted``/``cumsum``/``argsort`` with no enclosing
  bound guard (``ensure_fits_int32`` / ``can_narrow_int32`` /
  ``validate_node_ids``).  Wraps silently at m >= 2^31.
"""

from __future__ import annotations

import ast

from .base import (
    Finding,
    ModuleInfo,
    build_parent_map,
    call_name,
    dotted_name,
    function_calls,
    has_keyword,
    register_pass,
)

# Modules on the triangle-counting data path, where integer reductions are
# edge/wedge/triangle-scale and must be dtype-disciplined.  Float kernels
# (flash_attention etc.) are out of scope for O1/O2; O3 applies repo-wide.
COUNTING_PREFIXES = ("core/", "analytics/", "distributed/", "kernels/triangle_count/")

# Qualified reduction callables covered by O1.
_SUM_CALLS = {"jnp.sum", "np.sum", "numpy.sum", "jax.numpy.sum"}

# Producers whose outputs are index/offset-scale (can exceed int32 once the
# array they index has >= 2^31 entries).
_INDEX_PRODUCERS = {"nonzero", "searchsorted", "cumsum", "argsort", "flatnonzero"}

# Calling any of these in an enclosing scope counts as a loud bound check.
_NARROW_GUARDS = {"ensure_fits_int32", "can_narrow_int32", "validate_node_ids"}

_INT32_NAMES = {"np.int32", "jnp.int32", "numpy.int32", "jax.numpy.int32"}
_WIDE_NAMES = {
    "np.int64", "jnp.int64", "numpy.int64",
    "np.uint64", "jnp.uint64", "numpy.uint64",
}

_JIT_DECORATORS = {"jit", "jax.jit", "pl.pallas_call", "pallas_call"}


def _on_counting_path(rel: str) -> bool:
    return rel.startswith(COUNTING_PREFIXES)


def _is_jit_decorated(fn: ast.AST) -> bool:
    for deco in getattr(fn, "decorator_list", []):
        target = deco.func if isinstance(deco, ast.Call) else deco
        name = dotted_name(target)
        if name in _JIT_DECORATORS:
            return True
        # functools.partial(jax.jit, ...) style
        if isinstance(deco, ast.Call) and name.endswith("partial"):
            for arg in deco.args:
                if dotted_name(arg) in _JIT_DECORATORS:
                    return True
    return False


def _widened(node: ast.AST) -> bool:
    """True if the subtree already widens: dtype= kw or astype(int64/uint64)."""
    for sub in ast.walk(node):
        if not isinstance(sub, ast.Call):
            continue
        if has_keyword(sub, "dtype"):
            return True
        if isinstance(sub.func, ast.Attribute) and sub.func.attr == "astype":
            for arg in sub.args:
                name = dotted_name(arg)
                if name in _WIDE_NAMES:
                    return True
                if isinstance(arg, ast.Constant) and arg.value in ("int64", "uint64"):
                    return True
    return False


def _contains_sum(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            name = call_name(sub)
            if name in _SUM_CALLS:
                return True
            if isinstance(sub.func, ast.Attribute) and sub.func.attr == "sum":
                return True
    return False


def _narrows_to_int32(call: ast.Call) -> bool:
    if not (isinstance(call.func, ast.Attribute) and call.func.attr == "astype"):
        return False
    for arg in call.args:
        if dotted_name(arg) in _INT32_NAMES:
            return True
        if isinstance(arg, ast.Constant) and arg.value == "int32":
            return True
    return False


def _produces_index_scale(node: ast.AST, assigns: "dict[str, ast.AST]") -> bool:
    """Does this subtree (with one level of Name substitution) come from an
    index-scale producer?"""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            name = call_name(sub)
            if name.rsplit(".", 1)[-1] in _INDEX_PRODUCERS:
                return True
    # One-level substitution: `idx = np.nonzero(...)[0]; ... idx.astype(int32)`
    if isinstance(node, ast.Name) and node.id in assigns:
        src = assigns[node.id]
        for sub in ast.walk(src):
            if isinstance(sub, ast.Call):
                name = call_name(sub)
                if name.rsplit(".", 1)[-1] in _INDEX_PRODUCERS:
                    return True
    return False


def _collect_assigns(scope: ast.AST) -> "dict[str, ast.AST]":
    """Map simple ``name = expr`` assignments in a scope (last one wins)."""
    assigns: "dict[str, ast.AST]" = {}
    for node in ast.walk(scope):
        if isinstance(node, ast.Assign) and len(node.targets) == 1:
            tgt = node.targets[0]
            if isinstance(tgt, ast.Name):
                assigns[tgt.id] = node.value
    return assigns


@register_pass("overflow")
def check_overflow(mod: ModuleInfo) -> "list[Finding]":
    findings: "list[Finding]" = []
    tree = mod.tree
    parents = build_parent_map(tree)
    counting = _on_counting_path(mod.rel)

    def fn_stack(node: ast.AST) -> "list[ast.AST]":
        stack = []
        cur = node
        while cur in parents:
            cur = parents[cur]
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                stack.append(cur)
        return stack

    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue

        # --- O1: dtype-less reductions ---------------------------------
        if counting and not has_keyword(node, "dtype"):
            name = call_name(node)
            flagged = False
            if name in _SUM_CALLS:
                flagged = True
            elif (
                isinstance(node.func, ast.Attribute)
                and node.func.attr == "sum"
                and any(_is_jit_decorated(fn) for fn in fn_stack(node))
            ):
                # .sum() on a traced array keeps int32; require dtype= in jit.
                flagged = True
            if flagged:
                # int()/float() wrapping is handled (more precisely) by O2;
                # don't double-report the same reduction.
                parent = parents.get(node)
                while parent is not None and isinstance(parent, (ast.Subscript, ast.Attribute)):
                    parent = parents.get(parent)
                wrapped_by_int = (
                    isinstance(parent, ast.Call)
                    and dotted_name(parent.func) in ("int", "float")
                )
                if not wrapped_by_int and not _widened(node):
                    findings.append(
                        mod.finding(
                            "overflow",
                            "O1-sum-dtype",
                            node,
                            f"`{name or node.func.attr}` reduction without explicit dtype= on a "
                            "counting path; jnp.sum of int32 accumulates in int32",
                        )
                    )

        # --- O2: host folds through int() ------------------------------
        if counting and dotted_name(node.func) == "int" and len(node.args) == 1:
            arg = node.args[0]
            if _contains_sum(arg) and not _widened(arg):
                findings.append(
                    mod.finding(
                        "overflow",
                        "O2-host-fold",
                        node,
                        "host fold `int(....sum())` without dtype=/astype widening; "
                        "on a jnp array the accumulator is int32 before int() sees it",
                    )
                )

        # --- O3: unguarded narrowing to int32 ---------------------------
        if _narrows_to_int32(node):
            stack = fn_stack(node)
            guarded = any(_NARROW_GUARDS & function_calls(fn) for fn in stack)
            if not stack:
                # module level: look at the whole module for a guard call
                guarded = bool(_NARROW_GUARDS & function_calls(tree))
            if not guarded:
                scope = stack[0] if stack else tree
                assigns = _collect_assigns(scope)
                operand = node.func.value
                if _produces_index_scale(operand, assigns):
                    findings.append(
                        mod.finding(
                            "overflow",
                            "O3-narrow",
                            node,
                            "index-scale value narrowed with .astype(int32) and no "
                            "ensure_fits_int32/can_narrow_int32 guard in scope; "
                            "wraps silently at m >= 2^31",
                        )
                    )

    return findings
