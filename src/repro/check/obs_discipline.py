"""trilint pass: observability spans over device work must sync.

The PR 8 bug class: JAX dispatch is asynchronous, so a span that wraps a
kernel launch but closes without a synchronization point records the
*enqueue* time (microseconds) instead of the device compute time — the
trace looks implausibly fast and every derived number (stripe skew,
overhead tables, EXPERIMENTS.md rows) is garbage.  The invariant: any
``with ...span(...)`` block whose body launches device work must call a
sync point (``Span.sync``/``obs.sync``/``jax.block_until_ready``) before
the span closes.

* ``D1-unsynced-span`` — a span context manager whose body calls a
  device-work entry point but contains no sync call.

"Device work" is recognized by call-name convention, matching the
engine's kernel vocabulary: a last dotted segment that starts with
``chunk_`` or ``intersect_``, ends with ``_chunk``, or is one of the
known launch wrappers (``pallas_call``, ``shard_map``,
``striped_workload_fn``).  Spans around pure-host work (parsing, CSR
assembly, numpy folds) are exempt — host calls return only when done, so
the span is honest without a sync.
"""

from __future__ import annotations

import ast

from .base import Finding, ModuleInfo, call_name, register_pass, walk_calls

# Launch wrappers that dispatch device work without the kernel naming
# convention (kept in sync with repro.kernels / repro.distributed).
LAUNCH_WRAPPERS = frozenset({"pallas_call", "shard_map", "striped_workload_fn"})

# Call names that prove the span waited for the device.
SYNC_NAMES = frozenset({"sync", "block_until_ready"})


def _is_span_call(node: ast.expr) -> bool:
    """True for ``obs.span(...)`` / ``trc.span(...)`` / ``tracer.span(...)``."""
    if not isinstance(node, ast.Call):
        return False
    name = call_name(node)
    return name == "span" or name.endswith(".span")


def _is_device_work(name: str) -> bool:
    last = name.rsplit(".", 1)[-1]
    return (
        last.startswith("chunk_")
        or last.startswith("intersect_")
        or last.endswith("_chunk")
        or last in LAUNCH_WRAPPERS
    )


@register_pass("obs_discipline")
def check_obs_discipline(mod: ModuleInfo) -> "list[Finding]":
    findings: "list[Finding]" = []

    for node in ast.walk(mod.tree):
        if not isinstance(node, (ast.With, ast.AsyncWith)):
            continue
        if not any(_is_span_call(item.context_expr) for item in node.items):
            continue

        device_calls: "list[str]" = []
        synced = False
        for call in walk_calls(ast.Module(body=node.body, type_ignores=[])):
            name = call_name(call)
            if not name:
                continue
            if name.rsplit(".", 1)[-1] in SYNC_NAMES:
                synced = True
            elif _is_device_work(name):
                device_calls.append(name)

        if device_calls and not synced:
            launches = ", ".join(sorted(set(device_calls)))
            findings.append(
                mod.finding(
                    "obs_discipline",
                    "D1-unsynced-span",
                    node,
                    f"span wraps device work ({launches}) but closes without "
                    "a sync point; JAX dispatch is async, so the span records "
                    "enqueue latency, not device time — call `sp.sync(...)` "
                    "or `jax.block_until_ready` before the span exits",
                )
            )
    return findings
