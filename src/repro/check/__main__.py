"""CLI driver: ``python -m repro.check``.

Exit status: 0 when no unsuppressed findings, 1 otherwise, 2 on usage
errors.  ``--json`` emits a machine-readable report for CI.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from .base import load_passes, run_checks

_PKG_DIR = Path(__file__).resolve().parent  # src/repro/check
_DEFAULT_ROOT = _PKG_DIR.parent  # src/repro
_REPO_ROOT = _DEFAULT_ROOT.parent.parent  # repo checkout


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.check",
        description="trilint: repo-specific static analysis for the triangle engine",
    )
    parser.add_argument(
        "--root",
        type=Path,
        default=_DEFAULT_ROOT,
        help="directory tree to scan (default: the repro package)",
    )
    parser.add_argument(
        "--allowlist",
        type=Path,
        default=None,
        help="allowlist file (default: <repo>/trilint.allow when present)",
    )
    parser.add_argument(
        "--no-allowlist",
        action="store_true",
        help="ignore any allowlist file",
    )
    parser.add_argument(
        "--select",
        default=None,
        help="comma-separated pass names (default: all)",
    )
    parser.add_argument("--json", action="store_true", help="emit a JSON report")
    parser.add_argument(
        "--show-suppressed",
        action="store_true",
        help="also print suppressed findings in text mode",
    )
    parser.add_argument(
        "--list-passes", action="store_true", help="list registered passes and exit"
    )
    args = parser.parse_args(argv)

    if args.list_passes:
        for name in sorted(load_passes()):
            print(name)
        return 0

    allowlist = None
    if not args.no_allowlist:
        allowlist = args.allowlist
        if allowlist is None:
            cand = _REPO_ROOT / "trilint.allow"
            allowlist = cand if cand.exists() else None

    select = [s.strip() for s in args.select.split(",") if s.strip()] if args.select else None

    try:
        findings = run_checks(args.root, allowlist_path=allowlist, select=select)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    unsuppressed = [f for f in findings if not f.suppressed]
    suppressed = [f for f in findings if f.suppressed]

    if args.json:
        report = {
            "root": str(args.root),
            "allowlist": str(allowlist) if allowlist else None,
            "passes": select or sorted(load_passes()),
            "counts": {
                "total": len(findings),
                "unsuppressed": len(unsuppressed),
                "suppressed": len(suppressed),
            },
            "findings": [f.to_dict() for f in findings],
        }
        print(json.dumps(report, indent=2))
    else:
        for f in unsuppressed:
            print(f.render())
        if args.show_suppressed:
            for f in suppressed:
                print(f.render())
        print(
            f"trilint: {len(unsuppressed)} finding(s), "
            f"{len(suppressed)} suppressed"
        )

    return 1 if unsuppressed else 0


if __name__ == "__main__":
    sys.exit(main())
