"""repro.check — trilint static passes + runtime audit layer.

Static analysis (stdlib-only, runs without jax/numpy):

    python -m repro.check [--json] [--select overflow,recompile,...]

Passes: ``overflow`` (O1-O3), ``recompile`` (R1), ``collectives`` (C1-C3),
``backend_protocol`` (B1-B4), ``stats_lifecycle`` (S1) — each documented in
its module and in the README "Invariants" section.  Suppress inline with
``# trilint: ok[rule]`` or via the repo-root ``trilint.allow`` file.

Runtime audit (needs numpy/jax): ``repro.check.runtime`` provides the
``REPRO_CHECK=1`` partial-headroom sanitizer hooked into
``engine.run_workload`` and the ``CompileAuditor`` trace counter.
"""

from .base import (  # noqa: F401
    Finding,
    ModuleInfo,
    PASSES,
    load_passes,
    run_checks,
)

__all__ = ["Finding", "ModuleInfo", "PASSES", "load_passes", "run_checks"]
