"""trilint pass: recompile hazards at jit/pallas boundaries.

The engine promises O(log m) distinct compilations per workload: every
shape that reaches a jitted kernel or ``pallas_call`` is first rounded to a
pow2 bucket (``next_pow2`` via the chunk planners), so truss peeling and
incremental probe sessions reuse a logarithmic number of cache entries
instead of tracing once per round.  ``CompileAuditor`` (repro.check.runtime)
verifies the bound dynamically; this pass catches the static pattern that
breaks it:

* ``R1-unbucketed-shape`` — a call to a known jit entry point where an
  argument is derived from a runtime shape (``.shape`` / ``len()`` /
  ``.size``, with one level of local-variable substitution) inside a
  function that never invokes a bucket helper.  Each distinct data size
  then mints a fresh cache key: the cache-key-explosion pattern.
"""

from __future__ import annotations

import ast

from .base import (
    Finding,
    ModuleInfo,
    build_parent_map,
    call_name,
    function_calls,
    register_pass,
)

# Call targets that hit the jit trace cache.  Names, not objects: this is a
# repo-specific lint and these are the repo's kernel entry points.
JIT_ENTRY_POINTS = {
    "chunk_count_kernel",
    "chunk_per_node_kernel",
    "chunk_support_kernel",
    "gather_panels",
    "gather_panels_arrays",
    "striped_workload_fn",
    "count_wedges_found",
    "pallas_call",
    "pl.pallas_call",
}

# Helpers that quantize shapes to a bounded bucket set.  Calling any of
# these in the enclosing function means shape-derived arguments are assumed
# bucketed (the planners bake pow2 rounding into the chunk objects).
BUCKET_HELPERS = {
    "next_pow2",
    "_next_pow2",
    "round_up_pow2",
    "plan_edge_chunks",
    "plan_striped_chunks",
    "make_wedge_plan",
    "bucketize_edges",
    "search_steps",
    "candidate_tiles",
    "_pick_tiles",
    "_clamp_tiles",
    "pad_to_bucket",
}


def _shape_derived(node: ast.AST, assigns: "dict[str, ast.AST]") -> bool:
    def direct(n: ast.AST) -> bool:
        for sub in ast.walk(n):
            if isinstance(sub, ast.Attribute) and sub.attr in ("shape", "size"):
                return True
            if isinstance(sub, ast.Call) and call_name(sub) == "len":
                return True
        return False

    if direct(node):
        return True
    # one-level substitution: `n, lu = a.shape` handled below; `k = len(x)`
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name) and sub.id in assigns and direct(assigns[sub.id]):
            return True
    return False


def _collect_assigns(scope: ast.AST) -> "dict[str, ast.AST]":
    assigns: "dict[str, ast.AST]" = {}
    for node in ast.walk(scope):
        if not isinstance(node, ast.Assign):
            continue
        for tgt in node.targets:
            if isinstance(tgt, ast.Name):
                assigns[tgt.id] = node.value
            elif isinstance(tgt, (ast.Tuple, ast.List)):
                # `n, lu = a.shape`: every unpacked name derives from the RHS
                for el in tgt.elts:
                    if isinstance(el, ast.Name):
                        assigns[el.id] = node.value
    return assigns


@register_pass("recompile")
def check_recompile(mod: ModuleInfo) -> "list[Finding]":
    findings: "list[Finding]" = []
    tree = mod.tree
    parents = build_parent_map(tree)

    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = call_name(node)
        short = name.rsplit(".", 1)[-1]
        if name not in JIT_ENTRY_POINTS and short not in JIT_ENTRY_POINTS:
            continue

        # Enclosing function stack.
        stack = []
        cur = node
        while cur in parents:
            cur = parents[cur]
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                stack.append(cur)
        if any(BUCKET_HELPERS & function_calls(fn) for fn in stack):
            continue

        scope = stack[0] if stack else tree
        assigns = _collect_assigns(scope)
        shapey = [
            arg for arg in list(node.args) + [kw.value for kw in node.keywords]
            if _shape_derived(arg, assigns)
        ]
        if shapey:
            findings.append(
                mod.finding(
                    "recompile",
                    "R1-unbucketed-shape",
                    node,
                    f"shape-derived argument reaches jit entry `{short}` in a function "
                    "with no pow2 bucket helper; each data size mints a new trace "
                    "(cache-key explosion)",
                )
            )
    return findings
