"""trilint pass: backend-protocol conformance.

PR 5's registry contract: a ``register_backend`` target either implements
the full ``KernelBackend`` surface, or the gap is *declared* in its
``capabilities`` frozenset — that declaration is the capability-gap table
``resolve_backend`` consults to produce a loud ``fallback_reason``.  A
backend that implements less than it declares (or declares nothing) can
reintroduce the PR 5 silent-per_node-fallback bug.

* ``B1-capability-unimplemented`` — capability declared in
  ``capabilities`` but the matching method is missing or still the
  protocol stub (``raise NotImplementedError``) across the in-module
  inheritance chain.
* ``B2-no-capability-table`` — registered backend with no resolvable
  ``capabilities`` declaration; the fallback machinery cannot see its
  gaps.
* ``B3-undeclared-capability`` — method implemented but capability not
  declared: the engine will route around a backend that actually works.
* ``B4-missing-plan`` — registered backend with no ``plan`` anywhere in
  its chain.
"""

from __future__ import annotations

import ast
from typing import Optional

from .base import Finding, ModuleInfo, call_name, register_pass

CAPABILITY_METHODS = {
    "count": "count_chunk",
    "per_node": "per_node_chunk",
    "support": "support_chunk",
}

PROTOCOL_ROOT = "KernelBackend"


def _is_stub(fn: ast.AST) -> bool:
    """Body is (docstring +) a bare ``raise NotImplementedError``."""
    body = list(fn.body)
    if body and isinstance(body[0], ast.Expr) and isinstance(body[0].value, ast.Constant):
        body = body[1:]
    if len(body) != 1 or not isinstance(body[0], ast.Raise):
        return False
    exc = body[0].exc
    target = exc.func if isinstance(exc, ast.Call) else exc
    return isinstance(target, ast.Name) and target.id == "NotImplementedError"


def _string_elts(node: ast.AST) -> Optional["set[str]"]:
    if isinstance(node, (ast.Set, ast.Tuple, ast.List)):
        out = set()
        for el in node.elts:
            if isinstance(el, ast.Constant) and isinstance(el.value, str):
                out.add(el.value)
            else:
                return None
        return out
    return None


def _resolve_capabilities(
    value: ast.AST, module_consts: "dict[str, ast.AST]"
) -> Optional["set[str]"]:
    """Resolve a ``capabilities = ...`` RHS to a set of strings, or None."""
    node = value
    if isinstance(node, ast.Call) and call_name(node).rsplit(".", 1)[-1] == "frozenset":
        if not node.args:
            return set()
        node = node.args[0]
    if isinstance(node, ast.Name) and node.id in module_consts:
        return _resolve_capabilities(module_consts[node.id], {})
    lits = _string_elts(node)
    if lits is not None:
        return lits
    if isinstance(node, ast.Constant) and node.value is None:
        return None
    return None


def _class_map(tree: ast.AST) -> "dict[str, ast.ClassDef]":
    return {n.name: n for n in ast.walk(tree) if isinstance(n, ast.ClassDef)}


def _module_consts(tree: ast.AST) -> "dict[str, ast.AST]":
    consts: "dict[str, ast.AST]" = {}
    for node in tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1:
            tgt = node.targets[0]
            if isinstance(tgt, ast.Name):
                consts[tgt.id] = node.value
    return consts


def _chain(cls: ast.ClassDef, classes: "dict[str, ast.ClassDef]") -> "list[ast.ClassDef]":
    """The class plus its in-module base chain, derived-first."""
    chain, seen, frontier = [], set(), [cls]
    while frontier:
        cur = frontier.pop(0)
        if cur.name in seen:
            continue
        seen.add(cur.name)
        chain.append(cur)
        for base in cur.bases:
            name = base.id if isinstance(base, ast.Name) else None
            if name and name in classes:
                frontier.append(classes[name])
    return chain


def _registered_class_names(tree: ast.AST) -> "set[str]":
    """Class names reachable from ``register_backend(name, factory)`` calls."""
    out: "set[str]" = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        if call_name(node).rsplit(".", 1)[-1] != "register_backend":
            continue
        if len(node.args) < 2:
            continue
        factory = node.args[1]
        # register_backend("wedge", WedgeBackend)
        if isinstance(factory, ast.Name):
            out.add(factory.id)
        # register_backend("wedge", lambda **kw: WedgeBackend(**kw))
        elif isinstance(factory, ast.Lambda):
            for sub in ast.walk(factory.body):
                if isinstance(sub, ast.Call) and isinstance(sub.func, ast.Name):
                    out.add(sub.func.id)
    return out


@register_pass("backend_protocol")
def check_backend_protocol(mod: ModuleInfo) -> "list[Finding]":
    findings: "list[Finding]" = []
    tree = mod.tree
    classes = _class_map(tree)
    consts = _module_consts(tree)

    registered = _registered_class_names(tree)
    # Also audit unregistered subclasses of the protocol root defined here:
    # they are one register_backend call away from the dispatch path.
    candidates = set(registered)
    for name, cls in classes.items():
        if any(isinstance(b, ast.Name) and b.id == PROTOCOL_ROOT for b in cls.bases):
            candidates.add(name)

    for name in sorted(candidates):
        cls = classes.get(name)
        if cls is None or name == PROTOCOL_ROOT:
            continue

        chain = _chain(cls, classes)
        # Effective method table: derived-most definition wins.
        methods: "dict[str, ast.AST]" = {}
        caps: Optional["set[str]"] = None
        caps_node: Optional[ast.AST] = None
        for c in chain:
            for item in c.body:
                if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    methods.setdefault(item.name, item)
                elif isinstance(item, ast.Assign):
                    for tgt in item.targets:
                        if isinstance(tgt, ast.Name) and tgt.id == "capabilities" and caps_node is None:
                            caps_node = item.value
                elif isinstance(item, ast.AnnAssign):
                    if (
                        isinstance(item.target, ast.Name)
                        and item.target.id == "capabilities"
                        and item.value is not None
                        and caps_node is None
                    ):
                        caps_node = item.value
        if caps_node is not None:
            caps = _resolve_capabilities(caps_node, consts)

        implemented = {
            m for m, fn in methods.items() if not _is_stub(fn)
        }

        if caps is None:
            findings.append(
                mod.finding(
                    "backend_protocol",
                    "B2-no-capability-table",
                    cls,
                    f"backend `{name}` has no resolvable `capabilities` frozenset; "
                    "resolve_backend cannot report its gaps loudly",
                )
            )
            caps = set()

        if "plan" not in implemented:
            findings.append(
                mod.finding(
                    "backend_protocol",
                    "B4-missing-plan",
                    cls,
                    f"backend `{name}` never implements `plan`",
                )
            )

        for cap, method in CAPABILITY_METHODS.items():
            if cap in caps and method not in implemented:
                findings.append(
                    mod.finding(
                        "backend_protocol",
                        "B1-capability-unimplemented",
                        cls,
                        f"backend `{name}` declares capability '{cap}' but "
                        f"`{method}` is missing or still the protocol stub",
                    )
                )
            if cap not in caps and method in implemented:
                findings.append(
                    mod.finding(
                        "backend_protocol",
                        "B3-undeclared-capability",
                        cls,
                        f"backend `{name}` implements `{method}` but does not "
                        f"declare capability '{cap}'; the engine will fall back "
                        "around a working backend",
                    )
                )
    return findings
