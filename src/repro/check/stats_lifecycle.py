"""trilint pass: stats lifecycle at workload entry points.

The PR 6 bug class: ``EngineStats``/``last_stats``-style fields written in
one code path leak into the next call's observation if an entry point
forgets to clear them (``edge_support`` once reported the *previous*
workload's ``fallback_reason``).  The invariant: every public entry point
that can (transitively, through private helpers) write a ``last_*stats``
attribute must reset that attribute to ``None`` in its own body first.

* ``S1-stale-stats`` — public method reaches a ``self.last_*stats = ...``
  writer through private-method calls but never executes
  ``self.<attr> = None`` itself.

A public method that only reaches writers through *other public methods*
is compliant (the callee performs the reset).  ``__init__``/dunders and
``@property`` getters are exempt.
"""

from __future__ import annotations

import ast
import re

from .base import Finding, ModuleInfo, dotted_name, register_pass

_STAT_ATTR = re.compile(r"^last_\w*stats$")


def _self_attr_assigns(fn: ast.AST) -> "list[tuple[str, bool]]":
    """(attr, is_none_clear) for every ``self.<attr> = ...`` in the method."""
    out = []
    for node in ast.walk(fn):
        targets = []
        if isinstance(node, ast.Assign):
            targets = node.targets
            value = node.value
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            targets = [node.target]
            value = node.value
        else:
            continue
        for tgt in targets:
            if (
                isinstance(tgt, ast.Attribute)
                and isinstance(tgt.value, ast.Name)
                and tgt.value.id == "self"
                and _STAT_ATTR.match(tgt.attr)
            ):
                is_none = isinstance(value, ast.Constant) and value.value is None
                out.append((tgt.attr, is_none))
    return out


def _self_calls(fn: ast.AST) -> "set[str]":
    out = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            name = dotted_name(node.func)
            if name.startswith("self."):
                out.add(name.split(".", 1)[1].split(".", 1)[0])
    return out


def _is_property(fn: ast.AST) -> bool:
    for deco in fn.decorator_list:
        name = dotted_name(deco if not isinstance(deco, ast.Call) else deco.func)
        if name in ("property", "cached_property", "functools.cached_property"):
            return True
    return False


@register_pass("stats_lifecycle")
def check_stats_lifecycle(mod: ModuleInfo) -> "list[Finding]":
    findings: "list[Finding]" = []

    for cls in ast.walk(mod.tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        methods = {
            item.name: item
            for item in cls.body
            if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef))
        }

        writes: "dict[str, set]" = {}
        clears: "dict[str, set]" = {}
        for name, fn in methods.items():
            w, c = set(), set()
            for attr, is_none in _self_attr_assigns(fn):
                (c if is_none else w).add(attr)
            writes[name] = w
            clears[name] = c

        if not any(writes.values()):
            continue  # class has no stats lifecycle

        # Fixpoint: attrs each method can write, propagating ONLY through
        # private callees (public callees reset on their own entry).
        reach = {name: set(w) for name, w in writes.items()}
        changed = True
        while changed:
            changed = False
            for name, fn in methods.items():
                for callee in _self_calls(fn):
                    if callee in methods and callee.startswith("_"):
                        extra = reach[callee] - reach[name]
                        if extra:
                            reach[name] |= extra
                            changed = True

        for name, fn in methods.items():
            if name.startswith("_") or _is_property(fn):
                continue  # private helpers and read-only views are exempt
            stale = reach[name] - clears[name]
            if stale:
                attrs = ", ".join(sorted(stale))
                findings.append(
                    mod.finding(
                        "stats_lifecycle",
                        "S1-stale-stats",
                        fn,
                        f"public entry point `{cls.name}.{name}` can write "
                        f"`{attrs}` via private helpers but never clears "
                        "it/them to None on entry; a failed or divergent path "
                        "leaves the previous workload's stats observable",
                    )
                )
    return findings
