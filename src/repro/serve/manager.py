"""Multi-graph residency: LRU-evicted ``.tricsr``/``.tricsrz`` graphs under a byte budget.

A service instance hosts many tenants' graphs but the machine hosts one
address space.  The manager keeps each attached graph's memory-mapped
CSR resident only while it earns its keep: graphs load lazily on first
lease (through :func:`repro.graphs.io.resolve_to_csr`, so the `.tricsr`
binary cache absorbs the parse cost), every lease bumps recency, and
admitting a graph that would push the resident set past
``memory_budget_bytes`` evicts least-recently-used *unpinned* graphs
first.  Eviction drops only the mmap — the `.tricsr` file stays on
disk, so re-admission is an ``mmap()`` away, and a lease pins its graph
for exactly the duration of the dispatch executing against it.

The manager also owns the service's single shared
:class:`repro.core.tuning.AutoTuner`: every engine the dispatchers
build consults (and feeds) one tile cache, so a shape tuned while
serving tenant A is a cache hit when tenant B's graph launches the same
pow2 bucket.  The cache file itself is concurrency-safe (read-merge-
write in :meth:`TileCache.save`), so multiple service processes can
share it too.
"""
from __future__ import annotations

import itertools
import os
import threading
from typing import Mapping

import numpy as np

from repro import obs
from repro.core.tuning import AutoTuner
from repro.graphs.io import resolve_to_csr

__all__ = ["GraphEntry", "GraphManager"]


class GraphEntry:
    """One attached graph: its source spec plus residency bookkeeping."""

    __slots__ = ("name", "source", "options", "csr", "meta", "nbytes",
                 "pins", "last_used", "n_loads")

    def __init__(self, name: str, source, options: dict):
        self.name = name
        self.source = source
        self.options = options
        self.csr = None          # CSRGraph while resident, else None
        self.meta: dict | None = None  # provenance from resolve_to_csr
        self.nbytes = 0
        self.pins = 0
        self.last_used = 0
        self.n_loads = 0

    @property
    def resident(self) -> bool:
        return self.csr is not None


def _resident_nbytes(csr) -> int:
    """Bytes this graph actually holds resident, not its logical CSR size.

    A :class:`~repro.graphs.io.CompressedCSR` reports materialized
    metadata plus the compressed payload (``resident_nbytes()``) —
    charging its *decompressed* size would evict neighbors to make room
    for memory that is never allocated (and ``.col`` does not even exist
    on the compressed form).  Flat CSRs are charged by their array
    buffers, which for the mmap path is the mapped region the page cache
    can fault in.
    """
    fn = getattr(csr, "resident_nbytes", None)
    if callable(fn):
        return int(fn())
    return int(np.asarray(csr.row_offsets).nbytes + np.asarray(csr.col).nbytes)


class _Lease:
    """Context manager pinning one entry for the duration of a dispatch."""

    __slots__ = ("_mgr", "entry")

    def __init__(self, mgr: "GraphManager", entry: GraphEntry):
        self._mgr = mgr
        self.entry = entry

    def __enter__(self) -> GraphEntry:
        return self.entry

    def __exit__(self, *exc):
        self._mgr._unpin(self.entry)
        return False


class GraphManager:
    """Attached-graph table with LRU residency under a memory budget.

    ``memory_budget_bytes=None`` disables eviction (everything stays
    resident); ``max_resident`` optionally bounds the *count* of
    resident graphs regardless of bytes.  Pinned graphs (an active
    lease) are never evicted — if every resident graph is pinned the
    budget overshoots rather than failing the query, and the
    ``serve.budget_overcommit`` counter records that the budget was too
    tight for the offered concurrency.
    """

    def __init__(
        self,
        cache_dir: str | os.PathLike = ".tricsr-cache",
        *,
        memory_budget_bytes: int | None = None,
        max_resident: int | None = None,
        allow_download: bool | None = None,
        tile_cache_path: str | os.PathLike | None = None,
        tune_on_miss: bool = False,
    ):
        if memory_budget_bytes is not None and memory_budget_bytes < 1:
            raise ValueError("memory_budget_bytes must be >= 1 (or None)")
        if max_resident is not None and max_resident < 1:
            raise ValueError("max_resident must be >= 1 (or None)")
        self.cache_dir = os.fspath(cache_dir)
        self.memory_budget_bytes = memory_budget_bytes
        self.max_resident = max_resident
        self.allow_download = allow_download
        self.tuner = AutoTuner(tile_cache_path, tune_on_miss=tune_on_miss)
        self._entries: dict[str, GraphEntry] = {}
        self._lock = threading.RLock()
        self._clock = itertools.count(1)

    # -- attachment ----------------------------------------------------------

    def attach(
        self,
        name: str,
        source,
        *,
        fallback_scale: int | None = None,
        max_chunk_edges: int | None = None,
        storage: str | None = None,
        order: str | None = None,
    ) -> GraphEntry:
        """Register a graph under ``name``; loading is deferred to first lease.

        ``source`` is anything :func:`resolve_to_csr` accepts — a dataset
        registry name or an edge-list path.  ``storage="compressed"``
        (optionally with ``order`` natural/degree/bfs) loads the graph
        as a block-decoding ``.tricsrz`` :class:`CompressedCSR`, whose
        residency cost is its compressed payload — the budget charges
        what is actually held, so tenants on compressed graphs pack
        several-fold denser than their flat footprint would allow.
        Re-attaching an existing name with the same source is a no-op;
        with a different source it is an error (evict/detach first).
        """
        with self._lock:
            ent = self._entries.get(name)
            if ent is not None:
                if ent.source != source:
                    raise ValueError(
                        f"graph {name!r} already attached to {ent.source!r}"
                    )
                return ent
            opts = {}
            if fallback_scale is not None:
                opts["fallback_scale"] = fallback_scale
            if max_chunk_edges is not None:
                opts["max_chunk_edges"] = max_chunk_edges
            if storage is not None:
                opts["storage"] = storage
            if order is not None:
                opts["order"] = order
            ent = GraphEntry(name, source, opts)
            self._entries[name] = ent
            return ent

    def detach(self, name: str) -> None:
        with self._lock:
            ent = self._entries.pop(name, None)
            if ent is not None and ent.pins:
                self._entries[name] = ent
                raise RuntimeError(f"graph {name!r} has {ent.pins} active lease(s)")

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._entries)

    def resident_names(self) -> list[str]:
        with self._lock:
            return sorted(n for n, e in self._entries.items() if e.resident)

    def resident_bytes(self) -> int:
        with self._lock:
            return sum(e.nbytes for e in self._entries.values() if e.resident)

    # -- residency -----------------------------------------------------------

    def lease(self, name: str) -> _Lease:
        """Pin ``name`` resident and return a context-manager lease.

        Loads the CSR if evicted/never-loaded (evicting LRU victims
        first to make room), bumps recency, and increments the pin
        count; exiting the lease unpins.
        """
        with self._lock:
            ent = self._entries.get(name)
            if ent is None:
                raise KeyError(f"graph {name!r} is not attached")
            if not ent.resident:
                self._load(ent)
            else:
                obs.counter("serve.graph_hits").add()
            ent.last_used = next(self._clock)
            ent.pins += 1
            return _Lease(self, ent)

    def _unpin(self, ent: GraphEntry) -> None:
        with self._lock:
            ent.pins = max(ent.pins - 1, 0)

    def _load(self, ent: GraphEntry) -> None:
        # resolve outside any budget math first: we need nbytes to budget
        with obs.span("serve.graph_load", cat="serve", args={"graph": ent.name}):
            csr, meta = resolve_to_csr(
                ent.source,
                self.cache_dir,
                allow_download=self.allow_download,
                **ent.options,
            )
        nbytes = _resident_nbytes(csr)
        self._make_room(nbytes)
        ent.csr, ent.meta, ent.nbytes = csr, meta, nbytes
        ent.n_loads += 1
        obs.counter("serve.graph_loads").add()

    def _make_room(self, incoming_nbytes: int) -> None:
        """Evict LRU unpinned residents until ``incoming_nbytes`` fits."""
        def over_budget() -> bool:
            resident = [e for e in self._entries.values() if e.resident]
            if self.max_resident is not None and len(resident) + 1 > self.max_resident:
                return True
            if self.memory_budget_bytes is None:
                return False
            return sum(e.nbytes for e in resident) + incoming_nbytes > self.memory_budget_bytes

        while over_budget():
            victims = sorted(
                (e for e in self._entries.values() if e.resident and not e.pins),
                key=lambda e: e.last_used,
            )
            if not victims:
                obs.counter("serve.budget_overcommit").add()
                return
            self._evict(victims[0])

    def _evict(self, ent: GraphEntry) -> None:
        ent.csr = None
        ent.nbytes = 0
        obs.counter("serve.graph_evictions").add()

    def evict(self, name: str) -> bool:
        """Explicitly drop ``name``'s mmap (False if pinned/not resident)."""
        with self._lock:
            ent = self._entries.get(name)
            if ent is None or not ent.resident or ent.pins:
                return False
            self._evict(ent)
            return True

    # -- introspection -------------------------------------------------------

    def stats(self) -> Mapping[str, object]:
        with self._lock:
            return {
                "attached": len(self._entries),
                "resident": sum(e.resident for e in self._entries.values()),
                "resident_bytes": sum(
                    e.nbytes for e in self._entries.values() if e.resident
                ),
                "memory_budget_bytes": self.memory_budget_bytes,
                "graphs": {
                    n: {
                        "resident": e.resident,
                        "nbytes": e.nbytes,
                        "pins": e.pins,
                        "loads": e.n_loads,
                    }
                    for n, e in sorted(self._entries.items())
                },
            }
