"""Admission control: bounded per-class queues, tickets, window batching.

The service classifies every request into a **traffic class** (point
lookups, per-node scans, heavy per-edge workloads, mutations) and each
class gets its own bounded FIFO with its own :class:`ClassPolicy` —
queue-depth bound (admission rejects with :class:`QueueOverflow` when
full), maximum queue wait (requests that sat longer complete with
:class:`QueryTimeout` instead of executing), and a per-dispatch batch
cap.  A slow truss/support request therefore cannot starve point
lookups: heavies queue, time out, and overflow on their own budget
while the point class keeps draining.

Batching follows the offline-inference shape (collect a window,
dispatch once, scatter answers back to waiters): a dispatcher blocks in
:meth:`AdmissionQueue.collect` until its lane has work, then drains
everything admissible right now — up to each class's ``max_batch``,
lingering at most ``batch_window_s`` for stragglers.  The default
window is **zero**: batches form naturally from whatever queued while
the previous dispatch was executing (continuous batching), so an idle
service adds no artificial latency to a lone request.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Any, Mapping

from repro import obs

__all__ = [
    "ClassPolicy",
    "QueueOverflow",
    "QueryTimeout",
    "Ticket",
    "Request",
    "AdmissionQueue",
]


class QueueOverflow(RuntimeError):
    """Admission rejected: the request's class queue is at max_queue."""


class QueryTimeout(TimeoutError):
    """The request waited in the queue longer than its class allows."""


@dataclasses.dataclass(frozen=True)
class ClassPolicy:
    """Per-traffic-class admission and batching knobs."""

    max_queue: int = 1024          # pending requests before admission rejects
    timeout_s: float | None = None  # max queue wait; None = wait forever
    max_batch: int = 64            # requests fused per dispatch window
    batch_window_s: float = 0.0    # linger after the first request arrives

    def __post_init__(self):
        if self.max_queue < 1:
            raise ValueError("max_queue must be >= 1")
        if self.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if self.batch_window_s < 0:
            raise ValueError("batch_window_s must be >= 0")
        if self.timeout_s is not None and self.timeout_s < 0:
            raise ValueError("timeout_s must be >= 0 (or None)")


class Ticket:
    """A waiter's handle on one submitted request.

    ``result()`` blocks until the dispatcher resolves or rejects the
    request; rejection re-raises the stored exception in the waiter's
    thread (the dispatcher never dies on a request error).
    """

    __slots__ = ("kind", "traffic_class", "t_submit", "t_done",
                 "_event", "_value", "_error")

    def __init__(self, kind: str, traffic_class: str):
        self.kind = kind
        self.traffic_class = traffic_class
        self.t_submit = time.monotonic()
        self.t_done: float | None = None
        self._event = threading.Event()
        self._value: Any = None
        self._error: BaseException | None = None

    def done(self) -> bool:
        return self._event.is_set()

    @property
    def wait_s(self) -> float:
        """Queue+execute latency (submit → resolution), once done."""
        return (self.t_done or time.monotonic()) - self.t_submit

    def result(self, timeout: float | None = None):
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"{self.kind} ticket not resolved within {timeout}s "
                "(service stopped, or dispatch is wedged)"
            )
        if self._error is not None:
            raise self._error
        return self._value

    def exception(self, timeout: float | None = None) -> BaseException | None:
        """The stored rejection, without raising (None once resolved OK)."""
        if not self._event.wait(timeout):
            raise TimeoutError(f"{self.kind} ticket not resolved within {timeout}s")
        return self._error

    # dispatcher side --------------------------------------------------------

    def resolve(self, value) -> None:
        self._value = value
        self.t_done = time.monotonic()
        self._event.set()

    def reject(self, error: BaseException) -> None:
        self._error = error
        self.t_done = time.monotonic()
        self._event.set()


@dataclasses.dataclass
class Request:
    """One admitted request, queued until a dispatch window collects it."""

    graph: str
    kind: str
    params: dict
    traffic_class: str
    ticket: Ticket

    @property
    def t_submit(self) -> float:
        return self.ticket.t_submit


class AdmissionQueue:
    """Per-class bounded FIFOs with window collection for dispatchers.

    One condition variable covers every class: dispatchers collect over
    a *lane* (a tuple of class names) and are woken by any submit into
    one of their classes.  ``close()`` wakes everything; a closing
    queue still drains — ``collect`` keeps returning batches until its
    lane is empty, then returns ``[]`` forever.
    """

    def __init__(self, policies: Mapping[str, ClassPolicy]):
        if not policies:
            raise ValueError("at least one traffic class is required")
        self._policies = dict(policies)
        self._queues: dict[str, collections.deque[Request]] = {
            c: collections.deque() for c in self._policies
        }
        self._cond = threading.Condition()
        self._closed = False

    @property
    def classes(self) -> tuple[str, ...]:
        return tuple(self._policies)

    def policy(self, traffic_class: str) -> ClassPolicy:
        return self._policies[traffic_class]

    def depth(self, traffic_class: str) -> int:
        return len(self._queues[traffic_class])

    def submit(self, req: Request) -> None:
        """Admit ``req`` or raise :class:`QueueOverflow` / RuntimeError."""
        with self._cond:
            if self._closed:
                raise RuntimeError("service is shut down; request rejected")
            pol = self._policies[req.traffic_class]
            q = self._queues[req.traffic_class]
            if len(q) >= pol.max_queue:
                obs.counter("serve.overflows").add()
                raise QueueOverflow(
                    f"class {req.traffic_class!r}: {len(q)} pending >= "
                    f"max_queue={pol.max_queue}"
                )
            q.append(req)
            self._cond.notify_all()

    def close(self) -> None:
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    def reject_pending(self, error: BaseException) -> int:
        """Fail every queued request (shutdown path); returns how many."""
        with self._cond:
            n = 0
            for q in self._queues.values():
                while q:
                    q.popleft().ticket.reject(error)
                    n += 1
            return n

    def _drain(self, lane: tuple[str, ...], taken: dict[str, int]) -> list[Request]:
        out = []
        for c in lane:
            pol, q = self._policies[c], self._queues[c]
            while q and taken[c] < pol.max_batch:
                out.append(q.popleft())
                taken[c] += 1
        return out

    def collect(self, lane: tuple[str, ...]) -> list[Request]:
        """Block for the lane's next dispatch window; ``[]`` = shut down.

        Returns as soon as the window closes: immediately when every
        lane class has ``batch_window_s == 0`` (continuous batching),
        otherwise after lingering up to the lane's largest window for
        stragglers, and always as soon as every class hits its
        ``max_batch``.
        """
        window = max(self._policies[c].batch_window_s for c in lane)
        taken = {c: 0 for c in lane}
        with self._cond:
            while True:
                if any(self._queues[c] for c in lane):
                    break
                if self._closed:
                    return []
                self._cond.wait()
            batch = self._drain(lane, taken)
            deadline = time.monotonic() + window
            while not all(taken[c] >= self._policies[c].max_batch for c in lane):
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                if not any(self._queues[c] for c in lane):
                    self._cond.wait(remaining)
                batch.extend(self._drain(lane, taken))
            return batch
