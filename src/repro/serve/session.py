"""Streaming tenant sessions: incremental state + stream cursor + drive loop.

A :class:`StreamSession` owns one tenant's
:class:`repro.core.IncrementalTriangleCounter` plus the **stream
cursor** — how many update batches the session has consumed.  The repo's
streams (:mod:`repro.graphs.streams`) are deterministic given their
seed, so the cursor is the whole resume story: snapshot the maintained
state and the cursor, and a restarted process rebuilds the exact
mid-stream session by restoring the arrays and skipping ``cursor``
batches of the regenerated stream.  No replay of applied updates, no
divergence — the restored per-node incidences are the bytes that were
checkpointed, and every batch after the cursor is bit-identical to what
the uninterrupted session would have seen.

All mutation and state reads go through ``session.lock`` so the
service's update lane (applying batches) and read lanes (serving
count/per-node/clustering off the maintained state) interleave safely
with a well-defined order.

:func:`drive_stream` is the single-tenant drive loop the
``serve_graph`` CLI fronts — batches interleaved with queries, pow2
latency histograms per traffic class, rolling-window interval reports,
and (new) periodic snapshots through a :class:`~repro.serve.snapshot.
SnapshotStore` so a killed process resumes mid-stream.
"""
from __future__ import annotations

import threading
import time

import numpy as np

from repro import obs
from repro.core import IncrementalTriangleCounter
from repro.obs import RollingHistogram

__all__ = ["StreamSession", "drive_stream", "QUERY_KINDS"]

QUERY_KINDS = ("count", "per_node", "clustering", "transitivity")


class StreamSession:
    """One streaming tenant: maintained counter state + stream cursor."""

    def __init__(
        self,
        name: str,
        *,
        n_nodes: int | None = None,
        max_wedge_chunk: int | None = None,
        method: str = "auto",
        mesh=None,
        counter: IncrementalTriangleCounter | None = None,
        cursor: int = 0,
    ):
        if cursor < 0:
            raise ValueError("cursor must be >= 0")
        self.name = name
        self.lock = threading.RLock()
        self.counter = counter if counter is not None else IncrementalTriangleCounter(
            n_nodes=n_nodes, max_wedge_chunk=max_wedge_chunk, method=method, mesh=mesh
        )
        self.cursor = cursor        # update batches consumed so far
        self.n_applied = 0          # batches applied by THIS process

    # -- mutation ------------------------------------------------------------

    def apply(self, insert=None, delete=None) -> dict:
        """Apply one update batch; returns a JSON-ready result summary."""
        with self.lock:
            delta = self.counter.apply(insert=insert, delete=delete)
            self.cursor += 1
            self.n_applied += 1
            return {
                "count": int(self.counter.count),
                "n_edges": int(self.counter.n_edges),
                "delta": int(delta),
                "cursor": self.cursor,
            }

    # -- reads (cheap: maintained state) -------------------------------------

    def read(self, kind: str):
        """Serve one maintained-state query under the session lock."""
        with self.lock:
            if kind == "count":
                return int(self.counter.count)
            if kind == "per_node":
                return self.counter.per_node()
            if kind == "clustering":
                return self.counter.clustering()
            if kind == "transitivity":
                return self.counter.transitivity()
            raise ValueError(f"unknown session query kind {kind!r}")

    def edges_snapshot(self) -> tuple[np.ndarray, int]:
        """(live undirected edges, n_nodes) — for heavy engine passes."""
        with self.lock:
            return self.counter.current_edges(), self.counter.n_nodes

    # -- snapshot / restore ---------------------------------------------------

    def state_tree(self) -> dict[str, np.ndarray]:
        """The checkpointable pytree: counter state + stream cursor."""
        with self.lock:
            tree = self.counter.state_dict()
            tree["cursor"] = np.asarray(self.cursor, np.int64)
            return tree

    @classmethod
    def from_state(
        cls,
        name: str,
        tree: dict,
        *,
        max_wedge_chunk: int | None = None,
        method: str = "auto",
        mesh=None,
    ) -> "StreamSession":
        """Rebuild a session from a restored :meth:`state_tree` pytree."""
        counter = IncrementalTriangleCounter.from_state(
            {k: v for k, v in tree.items() if k != "cursor"},
            max_wedge_chunk=max_wedge_chunk,
            method=method,
            mesh=mesh,
        )
        return cls(name, counter=counter, cursor=int(np.asarray(tree["cursor"])))


def _interval_snapshot(kind, interval, n_batches, elapsed_s, update_hist, query_hists):
    """One JSON-ready latency snapshot (``kind`` = "interval" | "final")."""
    return {
        "kind": kind,
        "interval": interval,
        "batches": n_batches,
        "elapsed_s": elapsed_s,
        "update": update_hist.snapshot_ms(),
        "queries": {k: h.snapshot_ms() for k, h in query_hists.items()},
    }


def drive_stream(
    stream,
    *,
    n_nodes: int,
    max_batches: int | None = None,
    queries_per_batch: int = 4,
    max_wedge_chunk: int | None = None,
    method: str = "auto",
    mesh=None,
    report_every: int | None = None,
    window_intervals: int = 8,
    metrics_sink=None,
    log=None,
    session: StreamSession | None = None,
    snapshot_store=None,
    snapshot_every: int | None = None,
):
    """Apply ``stream`` batches interleaved with queries; return a report.

    The single-tenant serving loop: latencies land in per-traffic-class
    pow2 histograms; every ``report_every`` batches the current interval
    is sealed (snapshot to ``metrics_sink``, rolling-window percentiles
    to ``log``).  The returned report keeps the historical flat keys
    (``update_p50_ms`` … ``updates_per_s``) plus per-kind and
    rolling-window detail under ``"latency"``.

    Resume semantics: pass a restored ``session`` — its ``cursor``
    batches are *skipped* (consumed without applying; the deterministic
    generators re-derive them identically) before applying resumes.
    ``max_batches`` bounds the **absolute** stream position, so an
    uninterrupted ``max_batches=N`` run and a kill-at-k/resume run end
    on exactly the same state.  With ``snapshot_store`` set, the session
    is checkpointed every ``snapshot_every`` applied batches and once
    more at exit.

    Returns ``(counter, report)`` — the counter for oracle verification.
    """
    if session is None:
        session = StreamSession(
            "stream", n_nodes=n_nodes, max_wedge_chunk=max_wedge_chunk,
            method=method, mesh=mesh,
        )
    skip = session.cursor
    if skip and log is not None:
        log(f"resume: skipping {skip} already-applied batches (cursor)")
    update_hist = RollingHistogram(window_intervals)
    query_hists = {k: RollingHistogram(window_intervals) for k in QUERY_KINDS}
    n_batches = n_inserted = n_deleted = n_queries = 0
    qi = 0
    interval = 0
    position = 0  # absolute stream position (batches generated)
    t_start = time.perf_counter()

    def seal_interval():
        nonlocal interval
        interval += 1
        sealed_update = update_hist.rotate()
        sealed_queries = {k: h.rotate() for k, h in query_hists.items()}
        if metrics_sink is not None:
            metrics_sink(_interval_snapshot(
                "interval", interval, n_batches,
                time.perf_counter() - t_start, sealed_update, sealed_queries,
            ))
        if log is not None:
            win = update_hist.windowed()
            qwin = {k: h.windowed() for k, h in query_hists.items()}
            qp99 = max((h.percentile(99) for h in qwin.values() if h.n), default=0.0)
            log(f"[interval {interval}] {n_batches} batches; rolling "
                f"update p50 {win.percentile(50)*1e3:.2f} ms / "
                f"p99 {win.percentile(99)*1e3:.2f} ms; "
                f"worst query-kind p99 {qp99*1e3:.3f} ms")

    n_snapshots = 0
    for batch in stream:
        position += 1
        if position <= skip:
            continue  # already applied before the snapshot we resumed from
        if max_batches is not None and position > max_batches:
            break
        t0 = time.perf_counter()
        with obs.span("serve.update", cat="serve",
                      args={"batch": position - 1,
                            "insert": int(batch.insert.shape[0]),
                            "delete": int(batch.delete.shape[0])}):
            session.apply(insert=batch.insert, delete=batch.delete)
        update_hist.observe(time.perf_counter() - t0)
        n_batches += 1
        n_inserted += batch.insert.shape[0]
        n_deleted += batch.delete.shape[0]
        for _ in range(queries_per_batch):
            kind = QUERY_KINDS[qi % len(QUERY_KINDS)]
            qi += 1
            t0 = time.perf_counter()
            with obs.span("serve.query", cat="serve", args={"kind": kind}):
                _ = session.read(kind)
            query_hists[kind].observe(time.perf_counter() - t0)
            n_queries += 1
        if (snapshot_store is not None and snapshot_every is not None
                and n_batches % snapshot_every == 0):
            snapshot_store.save(session)
            n_snapshots += 1
        if report_every is not None and n_batches % report_every == 0:
            seal_interval()

    if snapshot_store is not None and session.n_applied:
        snapshot_store.save(session)
        snapshot_store.wait()
        n_snapshots += 1

    if metrics_sink is not None:
        metrics_sink(_interval_snapshot(
            "final", interval, n_batches, time.perf_counter() - t_start,
            update_hist.lifetime,
            {k: h.lifetime for k, h in query_hists.items()},
        ))

    # whole-run percentiles: merge the per-kind lifetime histograms for
    # the aggregate query figures the historical report shape exposes
    query_all = update_hist.lifetime.__class__()
    for h in query_hists.values():
        query_all.merge(h.lifetime)
    up = update_hist.lifetime
    report = dict(
        n_batches=n_batches,
        n_inserted=n_inserted,
        n_deleted=n_deleted,
        n_queries=n_queries,
        update_p50_ms=up.percentile(50) * 1e3 if up.n else 0.0,
        update_p99_ms=up.percentile(99) * 1e3 if up.n else 0.0,
        query_p50_ms=query_all.percentile(50) * 1e3 if query_all.n else 0.0,
        query_p99_ms=query_all.percentile(99) * 1e3 if query_all.n else 0.0,
        updates_per_s=(n_inserted + n_deleted) / max(up.total_ns / 1e9, 1e-12),
        latency=dict(
            intervals=interval,
            update=up.snapshot_ms(),
            queries={k: h.lifetime.snapshot_ms() for k, h in query_hists.items()},
            window=dict(
                intervals=min(interval + 1, window_intervals),
                update=update_hist.windowed().snapshot_ms(),
                queries={k: h.windowed().snapshot_ms()
                         for k, h in query_hists.items()},
            ),
        ),
    )
    if skip or snapshot_store is not None:
        report["resume"] = dict(
            skipped_batches=skip,
            cursor=session.cursor,
            snapshots_written=n_snapshots,
        )
    return session.counter, report
