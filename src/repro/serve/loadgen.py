"""Load generator: concurrent clients against a :class:`GraphService`.

Drives mixed query (and optionally update) traffic from N client
threads, records per-traffic-class latency in lock-protected pow2
histograms (:class:`repro.obs.ConcurrentHistogram` — many observers,
one instrument), and reports p50/p99 per class plus throughput and the
service's fusion counters, so "did batching actually happen" is a field
in the report rather than a belief.

Two entry points:

:func:`run_load`
    Library API the serving benchmark suite sweeps over client counts
    and admission policies (batched vs sequential arms).
``python -m repro.serve.loadgen``
    CLI for CI smoke: stand up a service on one graph, run a quick
    mixed workload, print a machine-readable ``--json`` report.  With
    ``--attest-fusion`` it first runs a *deterministic* fusion proof —
    queue K point/node queries against a stopped service, start it, and
    require that they all resolve from a single engine pass.
"""
from __future__ import annotations

import argparse
import json
import sys
import threading
import time

import numpy as np

from repro import obs
from repro.obs import ConcurrentHistogram

from .admission import QueryTimeout, QueueOverflow
from .manager import GraphManager
from .service import DEFAULT_POLICIES, GraphService

__all__ = ["DEFAULT_MIX", "run_load", "main"]

# weights roughly matching a lookup-heavy tenant population
DEFAULT_MIX = {"count": 0.5, "transitivity": 0.1, "per_node": 0.25, "clustering": 0.15}

_FUSION_COUNTERS = (
    "serve.requests",
    "serve.fused_batches",
    "serve.fused_queries",
    "serve.engine_passes",
    "serve.timeouts",
    "serve.overflows",
)


def _counters() -> dict[str, int]:
    snap = obs.metrics_snapshot()["counters"]
    return {k: int(snap.get(k, 0)) for k in _FUSION_COUNTERS}


def run_load(
    service: GraphService,
    graph: str,
    *,
    clients: int = 4,
    requests_per_client: int = 50,
    mix: dict[str, float] | None = None,
    seed: int = 0,
    update_stream=None,
    max_updates: int | None = None,
    result_timeout: float = 300.0,
) -> dict:
    """Run a closed-loop mixed workload; returns a JSON-ready report.

    ``clients`` threads each issue ``requests_per_client`` queries drawn
    from ``mix`` (a kind→weight map, deterministic per client seed) and
    block for each answer before issuing the next (closed loop — the
    offered concurrency *is* the client count).  With ``update_stream``
    (an iterator of :class:`repro.graphs.streams.StreamBatch`), one
    extra updater thread applies batches to ``graph``'s stream session
    concurrently, exercising the update lane under read load.
    """
    if clients < 1:
        raise ValueError("clients must be >= 1")
    mix = dict(mix or DEFAULT_MIX)
    kinds = sorted(mix)
    weights = np.asarray([mix[k] for k in kinds], np.float64)
    weights = weights / weights.sum()

    hists: dict[str, ConcurrentHistogram] = {}
    hists_lock = threading.Lock()

    def hist(traffic_class: str) -> ConcurrentHistogram:
        with hists_lock:
            h = hists.get(traffic_class)
            if h is None:
                h = hists[traffic_class] = ConcurrentHistogram()
            return h

    errors = {"timeouts": 0, "overflows": 0, "other": 0}
    errors_lock = threading.Lock()
    n_ok = [0]

    def client(idx: int) -> None:
        rng = np.random.default_rng(seed * 1_000_003 + idx)
        for _ in range(requests_per_client):
            kind = kinds[int(rng.choice(len(kinds), p=weights))]
            t0 = time.perf_counter()
            try:
                ticket = service.submit(graph, kind)
                ticket.result(result_timeout)
            except QueueOverflow:
                with errors_lock:
                    errors["overflows"] += 1
                continue
            except QueryTimeout:
                with errors_lock:
                    errors["timeouts"] += 1
                continue
            except Exception:
                with errors_lock:
                    errors["other"] += 1
                continue
            hist(ticket.traffic_class).observe(time.perf_counter() - t0)
            with errors_lock:
                n_ok[0] += 1

    n_updates = [0]

    def updater() -> None:
        for i, batch in enumerate(update_stream):
            if max_updates is not None and i >= max_updates:
                break
            t0 = time.perf_counter()
            try:
                service.update(graph, insert=batch.insert,
                               delete=batch.delete).result(result_timeout)
            except Exception:
                with errors_lock:
                    errors["other"] += 1
                continue
            hist("update").observe(time.perf_counter() - t0)
            n_updates[0] += 1

    before = _counters()
    threads = [
        threading.Thread(target=client, args=(i,), name=f"loadgen-{i}")
        for i in range(clients)
    ]
    if update_stream is not None:
        threads.append(threading.Thread(target=updater, name="loadgen-updater"))
    t_start = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    elapsed = time.perf_counter() - t_start
    delta = {k: v - before[k] for k, v in _counters().items()}

    total_ok = n_ok[0] + n_updates[0]
    return {
        "graph": graph,
        "clients": clients,
        "requests_per_client": requests_per_client,
        "n_ok": n_ok[0],
        "n_updates": n_updates[0],
        "elapsed_s": elapsed,
        "qps": total_ok / elapsed if elapsed > 0 else 0.0,
        "latency": {c: h.snapshot_ms() for c, h in sorted(hists.items())},
        "errors": errors,
        "counters": delta,
    }


def attest_fusion(service: GraphService, graph: str, n: int = 16) -> dict:
    """Deterministic fusion proof on a *stopped* service.

    Queues ``n`` point/node queries while no dispatcher runs, then
    starts the service: the whole backlog lands in one collect window,
    so a correctly-fusing read lane answers all of them from **one**
    engine pass (count and transitivity derive from the per-node
    artifact).  Returns the pass/query accounting plus the answers'
    internal consistency check.
    """
    if service._started:
        raise RuntimeError("attest_fusion needs a service built with start=False")
    before = _counters()
    kinds = ["count", "per_node", "clustering", "transitivity"]
    tickets = [service.submit(graph, kinds[i % len(kinds)]) for i in range(n)]
    service.start()
    answers = [t.result(300.0) for t in tickets]
    delta = {k: v - before[k] for k, v in _counters().items()}
    count = next(a for t, a in zip(tickets, answers) if t.kind == "count")
    per_node = next(a for t, a in zip(tickets, answers) if t.kind == "per_node")
    return {
        "n_queries": n,
        "engine_passes": delta["serve.engine_passes"],
        "fused_queries": delta["serve.fused_queries"],
        "fused_batches": delta["serve.fused_batches"],
        "count": int(count),
        "consistent": int(per_node.sum(dtype=np.int64)) // 3 == int(count),
        "fused": delta["serve.engine_passes"] == 1 and delta["serve.fused_queries"] == n,
    }


def main() -> None:
    from repro.graphs.io import DATASETS

    ap = argparse.ArgumentParser(
        description="mixed-traffic load generator for repro.serve")
    ap.add_argument("--dataset", default="karate", choices=sorted(DATASETS))
    ap.add_argument("--cache-dir", default=".tricsr-cache")
    ap.add_argument("--fallback-scale", type=int, default=None)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--requests", type=int, default=50,
                    help="requests per client (default: %(default)s)")
    ap.add_argument("--method", default="auto",
                    choices=["auto", "wedge_bsearch", "panel", "pallas"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--memory-budget", type=int, default=None, metavar="BYTES",
                    help="graph residency budget (default: unbounded)")
    ap.add_argument("--attest-fusion", action="store_true",
                    help="run the deterministic fusion proof first")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    log = (lambda *a: print(*a, file=sys.stderr)) if args.json else print

    manager = GraphManager(args.cache_dir, memory_budget_bytes=args.memory_budget)
    out: dict = {"dataset": args.dataset}

    if args.attest_fusion:
        with GraphService(manager, method=args.method, start=False) as svc:
            svc.attach(args.dataset, args.dataset,
                       fallback_scale=args.fallback_scale)
            out["fusion"] = attest_fusion(svc, args.dataset)
        log(f"fusion: {out['fusion']['n_queries']} queries -> "
            f"{out['fusion']['engine_passes']} engine pass(es), "
            f"consistent={out['fusion']['consistent']}")

    with GraphService(manager, method=args.method) as svc:
        svc.attach(args.dataset, args.dataset, fallback_scale=args.fallback_scale)
        out["triangles"] = svc.query(args.dataset, "count", timeout=300.0)
        report = run_load(
            svc, args.dataset,
            clients=args.clients,
            requests_per_client=args.requests,
            seed=args.seed,
        )
    out["load"] = report
    log(f"{report['n_ok']} queries ok in {report['elapsed_s']:.2f}s "
        f"({report['qps']:.0f} q/s); fused {report['counters']['serve.fused_queries']} "
        f"into {report['counters']['serve.fused_batches']} batches; "
        f"T = {out['triangles']}")
    for cls, snap in report["latency"].items():
        log(f"  {cls:7s} n={snap['n']:<6d} p50 {snap['p50_ms']:.3f} ms, "
            f"p99 {snap['p99_ms']:.3f} ms")
    if args.json:
        print(json.dumps(out, sort_keys=True))


if __name__ == "__main__":
    main()
