"""Session snapshot/restore through the checkpoint subsystem.

A :class:`SnapshotStore` is a thin typed wrapper over
:class:`repro.checkpoint.CheckpointManager` for serving state: the
checkpointed pytree is a :meth:`StreamSession.state_tree` (canonical
directed adjacency, global count, per-node incidences, degrees, node
count, stream cursor) and the checkpoint *step* is the stream cursor —
so ``step_000000128/`` literally reads "state after 128 batches".

All of the checkpoint layer's durability guarantees apply: versioned
manifests with per-array crc32, COMMIT markers, atomic publish, and a
``restore_latest`` that silently skips torn/truncated/corrupted
candidates — killing a serving process mid-snapshot can cost at most
the batches since the last *committed* snapshot, never the store.
"""
from __future__ import annotations

import os

import numpy as np

from repro import obs
from repro.checkpoint import CheckpointManager, restore_latest

__all__ = ["SnapshotStore", "session_template", "load_latest_state"]


def session_template() -> dict[str, np.ndarray]:
    """Dtype/structure template for restoring a session state tree.

    ``restore_checkpoint`` takes shapes from the file and dtypes/keys
    from the target, so zero-length arrays of the right dtype suffice.
    """
    z = np.zeros(0, np.int64)
    return {
        "adj": z,
        "per_node": z,
        "deg": z,
        "count": np.asarray(0, np.int64),
        "n_nodes": np.asarray(0, np.int64),
        "cursor": np.asarray(0, np.int64),
    }


def load_latest_state(directory: str | os.PathLike):
    """``(state_tree, cursor, extra)`` of the newest valid snapshot, or None."""
    hit = restore_latest(os.fspath(directory), session_template())
    if hit is None:
        return None
    tree, step, extra = hit
    return tree, int(step), extra


class SnapshotStore:
    """Rolling session snapshots in one directory (cursor = step)."""

    def __init__(
        self,
        directory: str | os.PathLike,
        *,
        keep: int = 3,
        async_save: bool = False,
    ):
        self.directory = os.fspath(directory)
        self._mgr = CheckpointManager(self.directory, keep=keep, async_save=async_save)

    def save(self, session, extra: dict | None = None) -> int:
        """Checkpoint ``session`` at its current cursor; returns the cursor."""
        tree = session.state_tree()
        cursor = int(np.asarray(tree["cursor"]))
        meta = {"session": session.name,
                "n_edges": int(session.counter.n_edges),
                "count": int(session.counter.count)}
        if extra:
            meta.update(extra)
        with obs.span("serve.snapshot", cat="serve",
                      args={"session": session.name, "cursor": cursor}):
            self._mgr.save(cursor, tree, extra=meta)
        obs.counter("serve.snapshots").add()
        return cursor

    def wait(self) -> None:
        """Join any in-flight async save (surfacing its error here)."""
        self._mgr.wait()

    def load_latest(self):
        """``(state_tree, cursor, extra)`` of the newest valid snapshot, or None."""
        self._mgr.wait()
        return load_latest_state(self.directory)

    def restore_session(
        self,
        name: str,
        *,
        max_wedge_chunk: int | None = None,
        method: str = "auto",
        mesh=None,
    ):
        """Rebuild a :class:`StreamSession` from the newest valid snapshot.

        Returns ``(session, extra)`` or ``None`` when the directory holds
        no restorable snapshot (fresh start).
        """
        from .session import StreamSession

        hit = self.load_latest()
        if hit is None:
            return None
        tree, cursor, extra = hit
        session = StreamSession.from_state(
            name, tree, max_wedge_chunk=max_wedge_chunk, method=method, mesh=mesh
        )
        obs.counter("serve.restores").add()
        return session, extra
