"""GraphService: admission, lane dispatchers, and fused query execution.

The multi-tenant front door.  Clients :meth:`~GraphService.submit`
requests against attached graphs (static ``.tricsr``-backed tenants) or
open stream sessions (incremental tenants); every request is classified
into a traffic class, admitted through the per-class bounded queues of
:class:`~repro.serve.admission.AdmissionQueue`, and executed by one of
three lane dispatcher threads:

``read``  (classes ``point`` + ``node``)
    count / transitivity / per_node / clustering.  Concurrent queries on
    the same graph **fuse into one engine pass**: a window holding 12
    ``count`` and 3 ``clustering`` requests for graph G runs a single
    per-node pass, derives the count as ``per_node.sum() // 3`` (exact —
    every triangle contributes exactly one incidence to each of its
    three corners) and the clustering/transitivity values through the
    *same* host-side helpers the engine's own methods call, so fused
    answers are bit-identical to sequential ones.
``heavy`` (class ``heavy``)
    edge support / k-truss.  A separate lane with its own (small) queue
    bound and timeout, so a minutes-long truss decomposition queues and
    expires on its own budget while point lookups keep draining — the
    starvation-protection half of the admission design.
``update`` (class ``update``)
    mutations and snapshots for stream sessions, serialized per session
    under the session lock (reads interleave at batch granularity).

Batching is *continuous* by default (``batch_window_s = 0``): a lone
request dispatches immediately; batches form from whatever queued while
the previous pass executed — exactly the offline-inference batching
shape, applied to graph queries.
"""
from __future__ import annotations

import threading
import time

import numpy as np

from repro import obs
from repro.core.engine import TriangleCounter, degree_histogram

from .admission import (
    AdmissionQueue,
    ClassPolicy,
    QueryTimeout,
    Request,
    Ticket,
)
from .manager import GraphManager
from .session import StreamSession
from .snapshot import SnapshotStore

__all__ = [
    "KIND_TO_CLASS",
    "READ_LANE",
    "HEAVY_LANE",
    "UPDATE_LANE",
    "DEFAULT_POLICIES",
    "GraphService",
]

KIND_TO_CLASS = {
    "count": "point",
    "transitivity": "point",
    "per_node": "node",
    "clustering": "node",
    "support": "heavy",
    "truss": "heavy",
    "update": "update",
    "snapshot": "update",
}

READ_LANE = ("point", "node")
HEAVY_LANE = ("heavy",)
UPDATE_LANE = ("update",)

DEFAULT_POLICIES = {
    # point lookups: deep queue, generous fusion — they're O(1)-ish reads
    # or share one engine pass with the node class
    "point": ClassPolicy(max_queue=4096, timeout_s=None, max_batch=256),
    "node": ClassPolicy(max_queue=1024, timeout_s=None, max_batch=64),
    # heavies: shallow queue + timeout so they shed load instead of
    # building an unbounded backlog behind a slow truss
    "heavy": ClassPolicy(max_queue=16, timeout_s=120.0, max_batch=4),
    "update": ClassPolicy(max_queue=1024, timeout_s=None, max_batch=32),
}

_LANES = {"read": READ_LANE, "heavy": HEAVY_LANE, "update": UPDATE_LANE}


class GraphService:
    """Multi-tenant graph-query service over one :class:`GraphManager`.

    Parameters
    ----------
    manager:
        Graph residency layer (owns the shared autotuner).  A plain
        ``cache_dir`` string is accepted and wrapped.
    policies:
        Per-traffic-class overrides merged over :data:`DEFAULT_POLICIES`.
    method / max_wedge_chunk / mesh:
        Engine configuration; every lane gets its own
        :class:`TriangleCounter` (engine stats are per-instance mutable
        state) but all of them share the manager's tuner/tile cache.
    start:
        ``False`` defers dispatcher threads — requests queue but nothing
        executes until :meth:`start`.  The tests use this to build a
        known multi-request window deterministically.
    """

    def __init__(
        self,
        manager: GraphManager | str,
        *,
        policies: dict[str, ClassPolicy] | None = None,
        method: str = "auto",
        max_wedge_chunk: int | None = None,
        mesh=None,
        start: bool = True,
    ):
        if not isinstance(manager, GraphManager):
            manager = GraphManager(manager)
        self.manager = manager
        merged = dict(DEFAULT_POLICIES)
        if policies:
            unknown = set(policies) - set(merged)
            if unknown:
                raise ValueError(f"unknown traffic classes: {sorted(unknown)}")
            merged.update(policies)
        self.queue = AdmissionQueue(merged)
        self.method = method
        self.max_wedge_chunk = max_wedge_chunk
        self.mesh = mesh
        self._sessions: dict[str, StreamSession] = {}
        self._sessions_lock = threading.Lock()
        self._threads: list[threading.Thread] = []
        self._started = False
        self._closed = False
        if start:
            self.start()

    def _new_engine(self) -> TriangleCounter:
        return TriangleCounter(
            method=self.method,
            max_wedge_chunk=self.max_wedge_chunk,
            mesh=self.mesh,
            tuner=self.manager.tuner,
        )

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> None:
        if self._started:
            return
        if self._closed:
            raise RuntimeError("service already closed")
        self._started = True
        for lane_name, lane in _LANES.items():
            t = threading.Thread(
                target=self._lane_loop,
                args=(lane, self._new_engine()),
                name=f"serve-{lane_name}",
                daemon=True,
            )
            t.start()
            self._threads.append(t)

    def close(self, timeout: float | None = 30.0) -> None:
        """Drain queued work, stop dispatchers, reject anything left."""
        if self._closed:
            return
        self._closed = True
        self.queue.close()
        for t in self._threads:
            t.join(timeout)
        self._threads.clear()
        self.queue.reject_pending(RuntimeError("service closed"))

    def __enter__(self) -> "GraphService":
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -- tenants -------------------------------------------------------------

    def attach(self, name: str, source, **kwargs):
        """Attach a static graph tenant (see :meth:`GraphManager.attach`)."""
        return self.manager.attach(name, source, **kwargs)

    def open_session(
        self,
        name: str,
        *,
        n_nodes: int | None = None,
        snapshot_dir: str | None = None,
        resume: bool = False,
    ) -> StreamSession:
        """Open (or resume) a streaming tenant named ``name``.

        With ``resume=True`` and a ``snapshot_dir`` holding a valid
        snapshot, the session restores mid-stream (count, per-node state
        and cursor all recovered); otherwise it starts empty.
        """
        with self._sessions_lock:
            if name in self._sessions:
                raise ValueError(f"session {name!r} already open")
            session = None
            if resume and snapshot_dir is not None:
                store = SnapshotStore(snapshot_dir)
                hit = store.restore_session(
                    name,
                    max_wedge_chunk=self.max_wedge_chunk,
                    method=self.method,
                    mesh=self.mesh,
                )
                if hit is not None:
                    session = hit[0]
            if session is None:
                session = StreamSession(
                    name,
                    n_nodes=n_nodes,
                    max_wedge_chunk=self.max_wedge_chunk,
                    method=self.method,
                    mesh=self.mesh,
                )
            self._sessions[name] = session
            return session

    def session(self, name: str) -> StreamSession | None:
        with self._sessions_lock:
            return self._sessions.get(name)

    def close_session(self, name: str) -> None:
        with self._sessions_lock:
            self._sessions.pop(name, None)

    # -- request surface -----------------------------------------------------

    def submit(self, graph: str, kind: str, **params) -> Ticket:
        """Admit one request; returns its :class:`Ticket` immediately.

        Raises :class:`QueueOverflow` when the kind's class queue is
        full — admission control is synchronous so callers can shed load
        (retry, degrade, or error out) instead of queueing blindly.
        """
        try:
            cls = KIND_TO_CLASS[kind]
        except KeyError:
            raise ValueError(
                f"unknown query kind {kind!r}; expected one of "
                f"{sorted(KIND_TO_CLASS)}"
            ) from None
        ticket = Ticket(kind, cls)
        obs.counter("serve.requests").add()
        self.queue.submit(Request(graph, kind, params, cls, ticket))
        return ticket

    def query(self, graph: str, kind: str, *, timeout: float | None = None, **params):
        """Submit and block for the answer (convenience wrapper)."""
        return self.submit(graph, kind, **params).result(timeout)

    def update(self, graph: str, insert=None, delete=None) -> Ticket:
        """Enqueue a mutation batch for ``graph``'s stream session."""
        return self.submit(graph, "update", insert=insert, delete=delete)

    def snapshot(self, graph: str, store: SnapshotStore) -> Ticket:
        """Enqueue a snapshot of ``graph``'s session, ordered with updates."""
        return self.submit(graph, "snapshot", store=store)

    def stats(self) -> dict:
        """JSON-ready service state: queue depths + residency + counters."""
        return {
            "queues": {c: self.queue.depth(c) for c in self.queue.classes},
            "sessions": sorted(self._sessions),
            "manager": self.manager.stats(),
            "counters": {
                k: v
                for k, v in obs.metrics_snapshot()["counters"].items()
                if k.startswith("serve.")
            },
        }

    # -- dispatch ------------------------------------------------------------

    def _lane_loop(self, lane: tuple[str, ...], engine: TriangleCounter) -> None:
        while True:
            batch = self.queue.collect(lane)
            if not batch:
                return
            self._dispatch(batch, engine)

    def _dispatch(self, batch: list[Request], engine: TriangleCounter) -> None:
        now = time.monotonic()
        live: list[Request] = []
        for req in batch:
            pol = self.queue.policy(req.traffic_class)
            if pol.timeout_s is not None and now - req.t_submit > pol.timeout_s:
                obs.counter("serve.timeouts").add()
                req.ticket.reject(QueryTimeout(
                    f"{req.kind} on {req.graph!r} waited "
                    f"{now - req.t_submit:.3f}s > "
                    f"timeout_s={pol.timeout_s} for class {req.traffic_class!r}"
                ))
            else:
                live.append(req)
        groups: dict[str, list[Request]] = {}
        for req in live:
            groups.setdefault(req.graph, []).append(req)
        for graph, reqs in groups.items():
            if len(reqs) > 1:
                obs.counter("serve.fused_batches").add()
                obs.counter("serve.fused_queries").add(len(reqs))
            try:
                with obs.span("serve.dispatch", cat="serve",
                              args={"graph": graph, "n": len(reqs),
                                    "kinds": sorted({r.kind for r in reqs})}):
                    self._execute(graph, reqs, engine)
            except BaseException as e:
                for req in reqs:
                    if not req.ticket.done():
                        req.ticket.reject(e)

    def _execute(self, graph: str, reqs: list[Request], engine: TriangleCounter):
        session = self.session(graph)
        if session is not None:
            self._execute_session(session, reqs, engine)
        else:
            if any(r.kind in ("update", "snapshot") for r in reqs):
                raise KeyError(f"graph {graph!r} has no open stream session")
            self._execute_static(graph, reqs, engine)

    # one engine pass per fused window, at the maximal artifact level the
    # window needs; cheaper answers derive from it exactly
    def _execute_static(self, graph: str, reqs: list[Request],
                        engine: TriangleCounter) -> None:
        kinds = {r.kind for r in reqs}
        with self.manager.lease(graph) as ent:
            csr = ent.csr
            per_node = support = None
            count: int | None = None
            if kinds & {"per_node", "clustering"}:
                per_node = engine.per_node(csr)
                if hasattr(csr, "map_per_node"):
                    # compressed graphs count in relabeled ids; answer in
                    # the tenant's original ids
                    per_node = csr.map_per_node(per_node)
                obs.counter("serve.engine_passes").add()
            if "support" in kinds:
                support = engine.edge_support(csr)
                obs.counter("serve.engine_passes").add()
            if kinds & {"count", "transitivity"}:
                if per_node is not None:
                    count = int(per_node.sum(dtype=np.int64)) // 3
                elif support is not None:
                    count = int(support.sum(dtype=np.int64)) // 3
                else:
                    count = engine.count(csr)
                    obs.counter("serve.engine_passes").add()
            deg = None
            if kinds & {"clustering", "transitivity"}:
                deg, _ = degree_histogram(csr)
                if hasattr(csr, "map_per_node"):
                    deg = csr.map_per_node(deg)
            truss = None
            if "truss" in kinds:
                from repro.analytics import k_truss_decomposition

                truss = k_truss_decomposition(
                    csr,
                    max_wedge_chunk=self.max_wedge_chunk,
                    method=self.method,
                    mesh=self.mesh,
                )
                obs.counter("serve.engine_passes").add()
        from repro.analytics.metrics import (
            clustering_from_counts,
            transitivity_from_counts,
        )

        for req in reqs:
            if req.kind == "count":
                req.ticket.resolve(count)
            elif req.kind == "per_node":
                req.ticket.resolve(per_node)
            elif req.kind == "clustering":
                req.ticket.resolve(clustering_from_counts(per_node, deg))
            elif req.kind == "transitivity":
                req.ticket.resolve(transitivity_from_counts(count, deg))
            elif req.kind == "support":
                req.ticket.resolve(support)
            elif req.kind == "truss":
                req.ticket.resolve(truss)
            else:
                req.ticket.reject(ValueError(f"unknown kind {req.kind!r}"))

    def _execute_session(self, session: StreamSession, reqs: list[Request],
                         engine: TriangleCounter) -> None:
        # updates/snapshots run in submit order; reads serve the
        # maintained state under the same lock (one acquisition per window)
        heavies = [r for r in reqs if r.kind in ("support", "truss")]
        rest = [r for r in reqs if r.kind not in ("support", "truss")]
        if rest:
            with session.lock:
                for req in rest:
                    if req.kind == "update":
                        req.ticket.resolve(session.apply(
                            insert=req.params.get("insert"),
                            delete=req.params.get("delete"),
                        ))
                    elif req.kind == "snapshot":
                        cursor = req.params["store"].save(session)
                        req.ticket.resolve({"cursor": cursor,
                                            "directory": req.params["store"].directory})
                    else:
                        req.ticket.resolve(session.read(req.kind))
        if heavies:
            edges, n_nodes = session.edges_snapshot()
            kinds = {r.kind for r in heavies}
            support = truss = None
            if "support" in kinds:
                support = engine.edge_support(edges, n_nodes)
                obs.counter("serve.engine_passes").add()
            if "truss" in kinds:
                from repro.analytics import k_truss_decomposition

                truss = k_truss_decomposition(
                    edges, n_nodes,
                    max_wedge_chunk=self.max_wedge_chunk,
                    method=self.method,
                    mesh=self.mesh,
                )
                obs.counter("serve.engine_passes").add()
            for req in heavies:
                req.ticket.resolve(support if req.kind == "support" else truss)
