"""Multi-tenant graph serving: admission, batching, residency, snapshots.

The ROADMAP's serving north star as a subsystem.  Layering::

    loadgen  ──►  GraphService  ──►  TriangleCounter / IncrementalTriangleCounter
                   │    │    │
        AdmissionQueue  │   StreamSession ──► SnapshotStore ──► repro.checkpoint
                 GraphManager ──► repro.graphs.io (.tricsr mmaps)

* :mod:`~repro.serve.admission` — per-traffic-class bounded queues,
  timeout/overflow policies, window batching.
* :mod:`~repro.serve.manager` — multi-graph LRU residency under a byte
  budget; one shared autotuner tile cache for every engine.
* :mod:`~repro.serve.service` — lane dispatchers fusing concurrent
  queries on a graph into one engine pass (answers bit-identical to
  sequential execution).
* :mod:`~repro.serve.session` — streaming tenants: incremental counter
  state + stream cursor; the single-tenant ``drive_stream`` loop behind
  ``python -m repro.launch.serve_graph``.
* :mod:`~repro.serve.snapshot` — kill-safe snapshot/restore of session
  state through the checkpoint subsystem.
* :mod:`~repro.serve.loadgen` — concurrent-client load generator and CI
  fusion attestation.
"""
from .admission import (
    AdmissionQueue,
    ClassPolicy,
    QueryTimeout,
    QueueOverflow,
    Request,
    Ticket,
)
from .manager import GraphEntry, GraphManager
from .service import (
    DEFAULT_POLICIES,
    HEAVY_LANE,
    KIND_TO_CLASS,
    READ_LANE,
    UPDATE_LANE,
    GraphService,
)
from .session import QUERY_KINDS, StreamSession, drive_stream
from .snapshot import SnapshotStore, load_latest_state, session_template
from .loadgen import DEFAULT_MIX, attest_fusion, run_load

__all__ = [
    "AdmissionQueue",
    "ClassPolicy",
    "QueryTimeout",
    "QueueOverflow",
    "Request",
    "Ticket",
    "GraphEntry",
    "GraphManager",
    "DEFAULT_POLICIES",
    "KIND_TO_CLASS",
    "READ_LANE",
    "HEAVY_LANE",
    "UPDATE_LANE",
    "GraphService",
    "QUERY_KINDS",
    "StreamSession",
    "drive_stream",
    "SnapshotStore",
    "load_latest_state",
    "session_template",
    "DEFAULT_MIX",
    "attest_fusion",
    "run_load",
]
