"""Reference baselines the paper compares against.

* :func:`count_triangles_sequential` — the paper's own baseline: a faithful
  single-threaded *forward* algorithm with a two-pointer merge.  Pure
  Python; use only on small graphs (tests / small benchmark rows).
* :func:`count_triangles_numpy` — an "optimized CPU implementation" in
  vectorized NumPy, the realistic CPU contender for the speedup tables.
* :func:`count_triangles_bruteforce` — O(n³) dense oracle for tests.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "count_triangles_sequential",
    "count_triangles_numpy",
    "count_triangles_bruteforce",
]


def _orient_numpy(edges: np.ndarray):
    edges = np.asarray(edges)
    n = int(edges.max()) + 1 if edges.size else 0
    deg = np.bincount(edges[:, 0], minlength=n)
    u, v = edges[:, 0], edges[:, 1]
    keep = (deg[u] < deg[v]) | ((deg[u] == deg[v]) & (u < v))
    d = edges[keep]
    order = np.lexsort((d[:, 1], d[:, 0]))
    d = d[order]
    offsets = np.searchsorted(d[:, 0], np.arange(n + 1))
    return offsets, d[:, 0].copy(), d[:, 1].copy()


def count_triangles_sequential(edges: np.ndarray) -> int:
    """Single-threaded forward algorithm, two-pointer merge (paper §II-B)."""
    offsets, src, col = _orient_numpy(edges)
    count = 0
    for p in range(src.shape[0]):
        u, v = int(src[p]), int(col[p])
        i, i_end = int(offsets[u]), int(offsets[u + 1])
        j, j_end = int(offsets[v]), int(offsets[v + 1])
        while i < i_end and j < j_end:
            d = int(col[i]) - int(col[j])
            if d <= 0:
                i += 1
            if d >= 0:
                j += 1
            if d == 0:
                count += 1
    return count


def count_triangles_numpy(edges: np.ndarray) -> int:
    """Vectorized NumPy forward count (wedge expansion + searchsorted)."""
    offsets, src, col = _orient_numpy(edges)
    out_deg = np.diff(offsets)
    reps = out_deg[src]
    edge_id = np.repeat(np.arange(src.shape[0]), reps)
    starts = np.cumsum(reps) - reps
    pos = np.arange(edge_id.shape[0]) - starts[edge_id]
    u = src[edge_id]
    v = col[edge_id]
    w = col[offsets[u] + pos]
    count = 0
    # chunk to bound peak memory on large graphs
    chunk = 1 << 24
    for s in range(0, w.shape[0], chunk):
        vv, ww = v[s : s + chunk], w[s : s + chunk]
        # col is sorted within each CSR segment; binary-search per segment.
        lo = offsets[vv]
        hi = offsets[vv + 1]
        # vectorized binary search
        while True:
            active = lo < hi
            if not active.any():
                break
            mid = (lo + hi) >> 1
            below = col[np.minimum(mid, col.shape[0] - 1)] < ww
            go = active & below
            stay = active & ~below
            lo = np.where(go, mid + 1, lo)
            hi = np.where(stay, mid, hi)
        found = (lo < offsets[vv + 1]) & (col[np.minimum(lo, col.shape[0] - 1)] == ww)
        count += int(found.sum(dtype=np.int64))
    return count


def count_triangles_bruteforce(edges: np.ndarray, n_nodes: int | None = None) -> int:
    """Dense O(n³) oracle: trace(A³)/6.  Tests only."""
    edges = np.asarray(edges)
    if edges.size == 0:
        return 0
    n = n_nodes or int(edges.max()) + 1
    a = np.zeros((n, n), dtype=np.int64)
    a[edges[:, 0], edges[:, 1]] = 1
    a = np.maximum(a, a.T)
    np.fill_diagonal(a, 0)
    return int(np.trace(a @ a @ a)) // 6
