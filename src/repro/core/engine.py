"""Unified triangle-counting engine with memory-bounded edge partitioning.

:class:`TriangleCounter` puts the four counting schedules that used to be
siloed across :mod:`repro.core.count` and :mod:`repro.core.distributed`
behind one front door::

    from repro.core import TriangleCounter

    tc = TriangleCounter(method="auto", max_wedge_chunk=1 << 22)
    t  = tc.count(edges)          # exact global count (host int, uint64-safe)
    pn = tc.per_node(edges)       # per-vertex triangle incidences
    es = tc.edge_support(edges)   # per-directed-edge triangle support
    cc = tc.clustering(edges)     # local clustering coefficients

Kernel backend registry
=======================

Every workload — global count, per-node incidences, per-edge support —
executes through a :class:`KernelBackend` registered per schedule name
(:func:`register_backend` / :func:`make_backend`).  A backend owns its
*planning* (how a query edge list is cut into budget-obeying chunks) and
its three chunk kernels:

``count_chunk``     → int32 device partials (uint64-accumulated on host)
``per_node_chunk``  → per-vertex int32 scatter for one chunk
``support_chunk``   → per-directed-edge int32 scatter for one chunk

:class:`WedgeBackend` plans fan-out-bounded contiguous edge chunks and
runs the batched-binary-search wedge kernels; :class:`PanelBackend`
(``"panel"``) buckets edges by neighbor-panel width and runs the jnp
equality-tile reductions; :class:`PallasBackend` (``"pallas"``) is the
same plan driving the Pallas kernel family
(:mod:`repro.kernels.triangle_count`), optionally steered by a
:class:`repro.core.tuning.AutoTuner`; :class:`DistributedBackend`
(``"distributed"``) plans §III-E round-robin edge stripes over every
mesh device and merges the striped kernels' partials with collectives —
``psum`` for per-node incidences, a stripe-offset (delta-compressed)
``all_gather`` for per-edge support — so every workload, including the
truss peel and the incremental probes, executes genuinely multi-device.
A backend asked for a workload outside its capability set falls back to
the wedge backend with an explicit ``EngineStats.fallback_reason`` and a
one-time ``RuntimeWarning`` instead of a silent substitution.

The shared driver (:func:`run_workload`) is what the analytics
subsystem (per-edge support, k-truss peeling) and the incremental
service route through as well, so the Pallas fast path serves every
workload, not just scalar counts.

The headline capability is **memory-bounded edge partitioning** — the
reproduction of the paper's "larger than device memory" discipline.  The
paper (§III-C) assigns one CUDA thread per directed edge; the device-side
working set of our TPU rendition is instead the *wedge buffer* of
``Σ deg⁺(u)`` candidate slots, which for an 89M-edge Kronecker graph is
billions of slots — far beyond HBM if materialized at once.  The engine
splits the directed edge list into contiguous chunks whose wedge buffers
fit a static budget, pads every chunk to that budget, and reuses **one**
jitted kernel across all chunks, so the number of *compiles* is constant
while the number of *launches* scales with graph size.  Partial counts
leave the device as int32 and are accumulated on host in uint64
(:func:`accumulate_partials`), so counts like the paper's 3.8B triangles
never overflow 32-bit device arithmetic.

Knob → paper-section map
========================

``method``
    ``"wedge_bsearch"`` / ``"panel"`` / ``"pallas"`` are the TPU-native
    renditions of the paper's ``CountTriangles`` kernel (§II-C forward
    algorithm, §III-C counting phase); ``"distributed"`` is the multi-GPU
    scheme of §III-E (replicated CSR, striped edge list, reduced
    partials); ``"auto"`` picks from graph stats (:func:`choose_method`).
``max_wedge_chunk``
    The per-launch wedge-buffer budget, in candidate slots.  This is the
    engine's analogue of the paper's per-GPU memory ceiling that forces
    the edge list to be processed in passes (§III-E, Table I's 89M-edge
    graph on a 3 GB C2050).  ``None`` materializes one full-size buffer
    (single chunk).  A budget smaller than one edge's fan-out is bumped
    to the max fan-out — a chunk must hold at least one whole edge.
``widths``
    Panel bucket boundaries for the ``panel``/``pallas`` schedules — the
    TPU analogue of the paper's warp-size tuning (§III-D5).  Wedge chunking
    wraps the bucket loop: each bucket is processed in slices of
    ``max_wedge_chunk // width`` edges so panel gathers respect the same
    budget.  Degrees beyond the last rung extend the ladder instead of
    failing.
``mesh``
    A ``jax.sharding.Mesh`` enabling the §III-E multi-device scheme; the
    edge chunking composes with the round-robin striping in
    :mod:`repro.core.distributed` (chunks slice the striped per-shard
    edge axis, so every device's buffer stays within budget).
``tuner``
    A :class:`repro.core.tuning.AutoTuner` steering the Pallas kernels'
    ``(block_edges, TLv)`` tiles from its per-shape grid-search cache —
    the persisted form of the paper's §III-D5 sweep.

Scheduling heuristics (``method="auto"``) follow §III-C's skew
discussion: low max out-degree and low skew favor the panel equality
reduction, heavy tails favor the binary-search schedule, and a multi-chip
mesh always routes to the distributed striping.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import os
import time
import warnings
from typing import Iterator, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs

from .count import (
    expand_and_close_wedges,
    expand_and_close_wedges_indexed,
    gather_panels_arrays,
    panel_intersect_count,
    panel_intersect_per_node,
    panel_intersect_support,
    segmented_int32_sum,
)
from .preprocess import (
    OrientedCSR,
    oriented_from_compressed,
    oriented_from_undirected_csr,
    preprocess,
)
from repro.distributed.compression import ensure_fits_int32

__all__ = [
    "TriangleCounter",
    "EngineStats",
    "choose_method",
    "plan_edge_chunks",
    "accumulate_partials",
    "prepare_oriented",
    "degree_histogram",
    "search_steps",
    "next_pow2",
    "iter_wedge_chunks",
    "chunk_count_kernel",
    "chunk_per_node_kernel",
    "chunk_support_kernel",
    "KernelBackend",
    "WedgeBackend",
    "PanelBackend",
    "PallasBackend",
    "DistributedBackend",
    "register_backend",
    "make_backend",
    "resolve_backend",
    "Workload",
    "make_workload",
    "workload_from_csr",
    "WorkPlan",
    "StripedChunk",
    "run_workload",
    "METHODS",
    "CAPABILITIES",
]

METHODS = ("auto", "wedge_bsearch", "panel", "pallas", "distributed")

CAPABILITIES = ("count", "per_node", "support")

DEFAULT_WIDTHS = (16, 64, 256, 1024, 4096)


# ---------------------------------------------------------------------------
# host-side planning + accumulation
# ---------------------------------------------------------------------------


def accumulate_partials(partials) -> int:
    """uint64 host accumulation of device partial counts.

    Device partials are int32 scalars or vectors, each element bounded by
    its reduction segment (2²⁰ slots in the chunk kernels); the *sum*
    over partials can exceed 2³¹ — the paper's Table I counts reach
    3.8B — so the running total lives in uint64 on host.
    """
    total = np.uint64(0)
    for p in partials:
        arr = np.asarray(p)
        if arr.size == 0:
            continue
        total += np.uint64(arr.astype(np.uint64).sum())
    return int(total)


def plan_edge_chunks(reps: np.ndarray, budget: int | None):
    """Greedy contiguous partition of the directed edge list.

    ``reps[i]`` is the wedge fan-out of directed edge ``i``.  Returns
    ``(bounds, effective_budget)`` where every ``[start, end)`` chunk in
    ``bounds`` satisfies ``reps[start:end].sum() <= effective_budget``.
    The effective budget is ``max(budget, reps.max())`` — a chunk must
    hold at least one whole edge's fan-out, so a sub-fan-out budget is
    bumped rather than splitting an adjacency list.
    """
    reps = np.asarray(reps, dtype=np.int64)
    m = reps.shape[0]
    if m == 0:
        return [(0, 0)], 1
    total = int(reps.sum(dtype=np.int64))
    max_fan = int(reps.max())
    if budget is None or budget >= total:
        return [(0, m)], max(total, 1)
    eff = max(int(budget), max_fan, 1)
    cum = np.cumsum(reps)
    bounds = []
    start = 0
    while start < m:
        base = int(cum[start - 1]) if start else 0
        end = int(np.searchsorted(cum, base + eff, side="right"))
        end = max(end, start + 1)
        bounds.append((start, end))
        start = end
    return bounds, eff


@dataclasses.dataclass(frozen=True)
class EngineStats:
    """What the last engine call actually did (for tests and tuning).

    ``resolved_method`` is what configuration + ``"auto"`` dispatch chose;
    ``method`` is what actually executed.  They differ only when the
    resolved backend lacks the requested workload capability — e.g. a
    custom-registered count-only backend asked for per-node — in which
    case the engine runs the wedge backend and says so:
    ``fallback_reason`` holds the human-readable why (and a one-time
    ``RuntimeWarning`` fires), so capability gaps are never silent.
    Stats are cleared at the start of every public engine call, so a
    stale ``fallback_reason`` never outlives the invocation that earned
    it.  ``peak_wedge_buffer`` is the largest buffer a launch actually
    materialized (the max chunk load) — not the requested budget, which
    lives in ``wedge_budget``.

    The stripe fields describe the §III-E partition when the distributed
    backend executed (``n_stripes > 1``): ``stripe_skew`` is
    ``max/mean`` wedge load over stripes (the distributed collectives
    are synchronous, so load skew *is* timing skew — see
    :func:`repro.distributed.straggler.stripe_skew_report`), and
    ``straggler_stripe`` the stripe the median+MAD rule flags (usually
    ``None``: round-robin striping balances skewed degree
    distributions).

    ``timings`` breaks the call's wall clock into phases (seconds):
    ``preprocess`` / ``plan`` / ``execute`` / ``fold``.  Without an
    active tracer the kernels stay async-dispatched, so device compute
    bills to whichever phase first blocks on the result (``fold``);
    under ``repro.obs`` tracing each chunk is synced as it completes and
    ``execute`` is genuine device time.  The phases always sum to the
    call's wall clock either way.

    The ``measured_*`` fields exist only for traced distributed runs:
    per-stripe span-measured seconds (``stripe_times``) beside the
    load-inferred skew, with ``skew_note`` set (and a ``RuntimeWarning``
    raised) when the two disagree about which stripe straggles — load is
    a proxy, the measurement wins.
    """

    method: str                  # executed schedule, never "auto"
    resolved_method: str         # configured/dispatched schedule, never "auto"
    n_chunks: int                # device launches for the counting phase
    peak_wedge_buffer: int       # largest buffer materialized per launch
    wedge_budget: int | None     # requested budget (None = unbounded)
    total_wedges: int            # Σ fan-out over all directed edges
    n_directed_edges: int
    fallback_reason: str | None = None  # why method != resolved_method
    n_stripes: int = 1                  # §III-E stripes (1 = single device)
    stripe_skew: float | None = None    # max/mean stripe wedge load
    straggler_stripe: int | None = None  # stripe flagged by the MAD rule
    timings: dict | None = None          # phase → seconds (see above)
    stripe_times: tuple[float, ...] | None = None  # measured s/stripe (traced)
    measured_stripe_skew: float | None = None      # max/mean measured time
    measured_straggler_stripe: int | None = None   # MAD rule on measured times
    skew_note: str | None = None         # loud load-vs-measured disagreement


# ---------------------------------------------------------------------------
# chunk kernels (compiled once per (shape-budget, steps) pair, reused
# across every chunk — chunk count drives launches, not compiles)
#
# These, together with `iter_wedge_chunks` / `search_steps` /
# `prepare_oriented` below, are the engine's *stable internal API*: the
# plumbing other subsystems (repro.core.incremental, repro.analytics)
# build chunked wedge workloads from, instead of growing private copies.
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("wedge_budget", "n_steps"))
def chunk_count_kernel(src_e, dst_e, row_offsets, col, out_deg, *, wedge_budget, n_steps):
    """Count triangles closed by one −1-padded edge chunk.

    Returns a *vector* of int32 partials, one per 2²⁰-slot segment of the
    wedge buffer (:func:`repro.core.count.segmented_int32_sum`): int32 is
    safe even for an unbounded (``max_wedge_chunk=None``) launch whose
    total hits exceed 2³¹ — the final uint64 reduction happens on host.
    """
    hit, _, _, _ = expand_and_close_wedges(
        src_e, dst_e, row_offsets, col, out_deg, wedge_budget, n_steps
    )
    return segmented_int32_sum(hit)


@functools.partial(jax.jit, static_argnames=("wedge_budget", "n_steps"))
def chunk_per_node_kernel(src_e, dst_e, row_offsets, col, out_deg, *, wedge_budget, n_steps):
    """Per-vertex triangle incidences contributed by one edge chunk."""
    hit, u, v, w = expand_and_close_wedges(
        src_e, dst_e, row_offsets, col, out_deg, wedge_budget, n_steps
    )
    inc = hit.astype(jnp.int32)
    n = row_offsets.shape[0] - 1
    out = jnp.zeros((n,), jnp.int32)
    out = out.at[u].add(inc)
    out = out.at[v].add(inc)
    out = out.at[w].add(inc)
    return out


@functools.partial(jax.jit, static_argnames=("wedge_budget", "n_steps"))
def chunk_support_kernel(
    src_e, dst_e, edge_offset, row_offsets, col, out_deg, *, wedge_budget, n_steps
):
    """Per-directed-edge support contributed by one −1-padded edge chunk.

    ``edge_offset`` (traced scalar — no recompile per chunk) is the
    chunk's start index in the global directed edge list; the base
    edge's local id shifts by it, while the arm (``uw``) and closure
    (``vw``) indices from the wedge expansion are global already.
    Returns an int32 vector over the full ``col`` axis.
    """
    hit, edge_id, uw_idx, vw_idx = expand_and_close_wedges_indexed(
        src_e, dst_e, row_offsets, col, out_deg, wedge_budget, n_steps
    )
    inc = hit.astype(jnp.int32)
    m_dir = col.shape[0]
    uv_idx = jnp.clip(edge_offset + edge_id, 0, m_dir - 1)
    out = jnp.zeros((m_dir,), jnp.int32)
    out = out.at[uv_idx].add(inc)
    out = out.at[uw_idx].add(inc)
    out = out.at[vw_idx].add(inc)
    return out


# legacy underscore names (pre-analytics); new code uses the public ones
_chunk_count_kernel = chunk_count_kernel
_chunk_per_node_kernel = chunk_per_node_kernel


@functools.partial(jax.jit, static_argnames=("n_out",))
def _panel_scatter_per_node(u, v, a, count, arm, *, n_out):
    """Scatter a panel chunk's (count, arm) outputs to per-vertex slots.

    ``count`` bills each hit to the edge endpoints ``u``/``v``; ``arm``
    bills it to the third vertex — the *values* of the ``a`` panel.  All
    padding contributes zeros (count/arm are 0 there), so clipped
    indices never corrupt real slots.
    """
    out = jnp.zeros((n_out,), jnp.int32)
    out = out.at[jnp.clip(u, 0, n_out - 1)].add(jnp.where(u >= 0, count, 0))
    out = out.at[jnp.clip(v, 0, n_out - 1)].add(jnp.where(v >= 0, count, 0))
    out = out.at[jnp.clip(a, 0, n_out - 1)].add(arm)
    return out


@functools.partial(jax.jit, static_argnames=("m_out",))
def _panel_scatter_support(edge_idx, u, v, row_offsets, count, arm, closure, *, m_out):
    """Scatter (count, arm, closure) to the three directed-edge slots.

    Base ``(u, v)`` is the chunk's global query id; arm slot ``j`` is
    directed edge ``row_offsets[u] + j`` (the wedge arm ``(u, w)``);
    closure slot ``k`` is ``row_offsets[v] + k`` (the closing edge
    ``(v, w)``).  Lanes past a row's true length carry zero counts, so
    their clipped indices are harmless.
    """
    out = jnp.zeros((m_out,), jnp.int32)
    out = out.at[jnp.clip(edge_idx, 0, m_out - 1)].add(
        jnp.where(edge_idx >= 0, count, 0)
    )
    lane_u = jnp.arange(arm.shape[1], dtype=jnp.int32)
    base_u = row_offsets[jnp.maximum(u, 0)][:, None]
    out = out.at[jnp.clip(base_u + lane_u[None, :], 0, m_out - 1)].add(arm)
    lane_v = jnp.arange(closure.shape[1], dtype=jnp.int32)
    base_v = row_offsets[jnp.maximum(v, 0)][:, None]
    out = out.at[jnp.clip(base_v + lane_v[None, :], 0, m_out - 1)].add(closure)
    return out


def search_steps(csr: OrientedCSR) -> int:
    """⌈log₂(max out-degree + 1)⌉ — the binary-search depth the chunk
    kernels need for this CSR (static argument, shared by all chunks)."""
    max_deg = int(np.asarray(csr.out_degree).max()) if csr.n_nodes else 0
    return max(1, math.ceil(math.log2(max_deg + 1))) if max_deg else 1


def prepare_oriented(edges, n_nodes: int | None = None) -> OrientedCSR | None:
    """Normalize any accepted graph input to an :class:`OrientedCSR`.

    Accepts a pre-built :class:`OrientedCSR` (returned as-is), a
    compressed CSR (anything with ``decode_block``, e.g.
    ``repro.graphs.io.CompressedCSR`` — oriented block-by-block without
    ever materializing the flat ``col``; note per-node/support results
    are then in *relabeled* ids, map back with
    ``CompressedCSR.map_per_node`` / ``new_to_old``), a cached undirected
    CSR (anything with ``row_offsets``/``col``/``n_nodes``, e.g.
    ``repro.graphs.io.CSRGraph`` — oriented by a host-side filter, never
    re-canonicalized), or a canonical edge array (full preprocessing).
    Returns ``None`` for an empty graph.  This is the shared input front
    door of :class:`TriangleCounter` and the analytics subsystem — call
    it once and pass the CSR around to avoid repeated preprocessing.
    """
    if isinstance(edges, OrientedCSR):
        csr = edges
    elif hasattr(edges, "decode_block"):
        csr = oriented_from_compressed(edges)
    elif hasattr(edges, "row_offsets") and hasattr(edges, "col"):
        csr = oriented_from_undirected_csr(
            edges.row_offsets, edges.col, getattr(edges, "n_nodes", None)
        )
    else:
        edges = np.asarray(edges)
        if edges.size == 0:
            return None
        if n_nodes is None:
            n_nodes = int(edges.max()) + 1
        csr = preprocess(jnp.asarray(edges), n_nodes=n_nodes)
    if csr.n_directed_edges > 0:
        return csr
    return None


def degree_histogram(edges, n_nodes: int | None = None) -> tuple[np.ndarray, int]:
    """Undirected degrees + node count for any accepted graph input kind."""
    if isinstance(edges, OrientedCSR):
        return np.asarray(edges.degree, dtype=np.int64), edges.n_nodes
    if hasattr(edges, "decode_block"):
        # compressed CSR: degrees come off the flat row offsets, no decode
        return np.diff(np.asarray(edges.row_offsets)).astype(np.int64), int(
            edges.n_nodes
        )
    if hasattr(edges, "row_offsets") and hasattr(edges, "col"):
        return np.diff(np.asarray(edges.row_offsets)).astype(np.int64), int(
            getattr(edges, "n_nodes", np.asarray(edges.row_offsets).shape[0] - 1)
        )
    edges = np.asarray(edges)
    if edges.size == 0:
        return np.zeros((n_nodes or 0,), np.int64), n_nodes or 0
    if n_nodes is None:
        n_nodes = int(edges.max()) + 1
    return np.bincount(edges[:, 0], minlength=n_nodes).astype(np.int64), n_nodes


def next_pow2(x: int) -> int:
    """Smallest power of two ≥ x (pow2 shape bucketing helper)."""
    return 1 << max(int(x) - 1, 0).bit_length() if x > 1 else 1


# ---------------------------------------------------------------------------
# workloads: the uniform "query edges vs adjacency" view every backend plans
# ---------------------------------------------------------------------------


class Workload(NamedTuple):
    """One edge-query workload: query pairs closed against an adjacency.

    ``(src_e[i], dst_e[i])`` is query edge ``i`` — the directed edge list
    itself for the engine's count/per-node/support calls, a filtered
    sub-CSR for the truss peel, or probe pairs against an *undirected*
    packed adjacency for the incremental service.  −1 slots are padding.
    ``row_offsets``/``col``/``out_degree`` describe the adjacency rows
    the queries intersect.  The ``*_host`` fields are NumPy views used
    for planning (the originals may live on device and are fed to the
    kernels untouched).
    """

    row_offsets: object
    col: object
    out_degree: object
    src_e: object
    dst_e: object
    src_host: np.ndarray
    dst_host: np.ndarray
    deg_host: np.ndarray
    n_steps: int


def make_workload(row_offsets, col, out_degree, src_e, dst_e, n_steps: int | None = None) -> Workload:
    """Build a :class:`Workload` from raw (host or device) arrays."""
    deg_host = np.asarray(out_degree)
    if n_steps is None:
        max_deg = int(deg_host.max()) if deg_host.size else 0
        n_steps = max(1, math.ceil(math.log2(max_deg + 1))) if max_deg else 1
    return Workload(
        row_offsets, col, out_degree, src_e, dst_e,
        np.asarray(src_e), np.asarray(dst_e), deg_host, n_steps,
    )


def workload_from_csr(csr: OrientedCSR) -> Workload:
    """The engine's standard workload: every directed edge queries its CSR."""
    return make_workload(
        csr.row_offsets, csr.col, csr.out_degree, csr.src, csr.col,
        n_steps=search_steps(csr),
    )


class _DeviceAdj(NamedTuple):
    """Device-resident adjacency arrays shared by every chunk launch."""

    row_offsets: jax.Array
    col: jax.Array
    out_degree: jax.Array
    n_steps: int


class WedgeChunk(NamedTuple):
    """One −1-padded contiguous slice of the query edge list."""

    src: object
    dst: object
    start: int    # offset into the global query list (support scatter)
    buffer: int   # static wedge-buffer length for this launch


class PanelChunk(NamedTuple):
    """One width-bucket slice of the query edge list (−1 padded)."""

    edge_idx: np.ndarray  # global query ids
    u: np.ndarray
    v: np.ndarray
    width: int


class StripedChunk(NamedTuple):
    """One −1-padded column slice of the §III-E striped edge axis."""

    src: np.ndarray   # (n_stripes, cols) round-robin striped sources
    dst: np.ndarray
    start: int        # starting column in the striped axis
    buffer: int       # static per-shard wedge-buffer length


class WorkPlan(NamedTuple):
    """A backend's chunking decision for one workload.

    ``timings`` and ``stripe_times`` are filled in by ``run_workload``
    on the plan it returns (backends leave them at the defaults):
    phase → seconds, and — traced distributed runs only — measured
    per-stripe seconds from the span probe.
    """

    chunks: Iterator
    n_chunks: int
    peak_buffer: int   # largest per-launch buffer (slots/elements)
    total_wedges: int  # Σ fan-out over the query edges
    n_stripes: int = 1                        # §III-E stripes (distributed)
    stripe_loads: tuple[int, ...] | None = None  # wedge slots per stripe
    timings: dict | None = None                  # filled by run_workload
    stripe_times: tuple[float, ...] | None = None  # filled when traced


# ---------------------------------------------------------------------------
# the backends
# ---------------------------------------------------------------------------


class KernelBackend:
    """Protocol each registered schedule implements.

    A backend owns chunk planning (:meth:`plan`) and the three chunk
    kernels.  ``capabilities`` declares which workloads it can execute;
    :func:`resolve_backend` substitutes the wedge backend (recording an
    explicit fallback reason) for anything outside that set.
    """

    name: str = "abstract"
    capabilities: frozenset = frozenset()

    def plan(self, work: Workload, budget: int | None, *, bucket_pow2: bool = False) -> WorkPlan:
        raise NotImplementedError

    def count_chunk(self, adj: _DeviceAdj, chunk):
        raise NotImplementedError

    def per_node_chunk(self, adj: _DeviceAdj, chunk, n_out: int):
        raise NotImplementedError

    def support_chunk(self, adj: _DeviceAdj, chunk, m_out: int):
        raise NotImplementedError


class WedgeBackend(KernelBackend):
    """The batched-binary-search wedge schedule (§II-C forward algorithm).

    Plans greedy contiguous edge chunks whose wedge fan-out totals obey
    the budget (:func:`plan_edge_chunks`); every chunk launches the same
    jitted kernel at one static buffer shape.
    """

    name = "wedge_bsearch"
    capabilities = frozenset(CAPABILITIES)

    def plan(self, work: Workload, budget: int | None, *, bucket_pow2: bool = False) -> WorkPlan:
        src, dst = work.src_host, work.dst_host
        reps = np.where(
            src >= 0, work.deg_host[np.maximum(src, 0)], 0
        ).astype(np.int64)
        bounds, _ = plan_edge_chunks(reps, budget)
        cum = np.concatenate([[0], np.cumsum(reps)])
        peak = max(int(cum[end] - cum[start]) for start, end in bounds)
        peak = max(peak, 1)
        edges_per_chunk = max(end - start for start, end in bounds)
        if bucket_pow2:
            peak = next_pow2(peak)
            edges_per_chunk = next_pow2(edges_per_chunk)

        def gen():
            if len(bounds) == 1 and edges_per_chunk == src.shape[0]:
                # single full chunk: feed the (possibly device-resident)
                # arrays directly — no host round-trip, no copies
                yield WedgeChunk(work.src_e, work.dst_e, 0, peak)
                return
            for start, end in bounds:
                pad = edges_per_chunk - (end - start)
                s, d = src[start:end], dst[start:end]
                if pad:
                    fill = np.full(pad, -1, np.int32)
                    s = np.concatenate([s, fill])
                    d = np.concatenate([d, fill])
                yield WedgeChunk(
                    s.astype(np.int32, copy=False),
                    d.astype(np.int32, copy=False),
                    start, peak,
                )

        return WorkPlan(gen(), len(bounds), peak, int(reps.sum(dtype=np.int64)))

    def count_chunk(self, adj, chunk):
        return chunk_count_kernel(
            jnp.asarray(chunk.src), jnp.asarray(chunk.dst),
            adj.row_offsets, adj.col, adj.out_degree,
            wedge_budget=chunk.buffer, n_steps=adj.n_steps,
        )

    def per_node_chunk(self, adj, chunk, n_out):
        return chunk_per_node_kernel(
            jnp.asarray(chunk.src), jnp.asarray(chunk.dst),
            adj.row_offsets, adj.col, adj.out_degree,
            wedge_budget=chunk.buffer, n_steps=adj.n_steps,
        )

    def support_chunk(self, adj, chunk, m_out):
        return chunk_support_kernel(
            jnp.asarray(chunk.src), jnp.asarray(chunk.dst), np.int32(chunk.start),
            adj.row_offsets, adj.col, adj.out_degree,
            wedge_budget=chunk.buffer, n_steps=adj.n_steps,
        )


class PanelBackend(KernelBackend):
    """The bucketed fixed-width panel schedule (jnp equality tiles).

    Plans width buckets (paper §III-D5 warp-size analogue) sliced under
    ``budget // width`` rows each; chunk kernels gather neighbor panels
    with XLA and reduce the broadcast-equality cube.  Degrees beyond the
    configured ladder extend it by ×4 rungs instead of failing, so any
    adjacency — including the incremental service's unoriented probe
    rows — is servable.
    """

    name = "panel"
    capabilities = frozenset(CAPABILITIES)

    def __init__(self, widths=DEFAULT_WIDTHS, tuner=None):
        self.widths = tuple(widths)
        self.tuner = tuner

    # intersect flavors — PallasBackend overrides with the kernel family
    def intersect_count(self, a, b):
        return panel_intersect_count(a, b)

    def intersect_per_node(self, a, b):
        return panel_intersect_per_node(a, b)

    def intersect_support(self, a, b):
        return panel_intersect_support(a, b)

    def _ladder(self, max_need: int):
        ws = list(self.widths)
        while ws and ws[-1] < max_need:
            ws.append(ws[-1] * 4)
        return tuple(ws)

    def plan(self, work: Workload, budget: int | None, *, bucket_pow2: bool = False) -> WorkPlan:
        src, dst, deg = work.src_host, work.dst_host, work.deg_host
        ensure_fits_int32(src.shape[0], "panel query edge count")
        valid = (src >= 0) & (dst >= 0)
        du = np.where(valid, deg[np.maximum(src, 0)], 0).astype(np.int64)
        dv = np.where(valid, deg[np.maximum(dst, 0)], 0).astype(np.int64)
        need = np.maximum(du, dv)
        total_wedges = int(du.sum(dtype=np.int64))

        def take(arr, sl):
            return np.where(sl >= 0, arr[np.maximum(sl, 0)], -1).astype(np.int32)

        chunks: list[PanelChunk] = []
        peak = 0
        lo = 0
        for w in self._ladder(int(need.max()) if need.size else 0):
            mask = (need > lo) & (need <= w)
            lo = w
            idx = np.nonzero(mask)[0].astype(np.int32)
            if not idx.size:
                continue
            per = len(idx) if budget is None else max(1, int(budget) // w)
            n_slices = -(-len(idx) // per)
            for s in range(0, len(idx), per):
                sl = idx[s : s + per]
                rows = per if n_slices > 1 else len(sl)
                if bucket_pow2:
                    rows = next_pow2(rows)
                pad = rows - len(sl)
                if pad:
                    sl = np.concatenate([sl, np.full(pad, -1, np.int32)])
                chunks.append(PanelChunk(sl, take(src, sl), take(dst, sl), w))
                peak = max(peak, rows * w)

        return WorkPlan(iter(chunks), len(chunks), peak, total_wedges)

    def _gather(self, adj, chunk):
        return gather_panels_arrays(
            adj.row_offsets, adj.col, adj.out_degree,
            jnp.asarray(chunk.u), jnp.asarray(chunk.v), chunk.width,
        )

    def count_chunk(self, adj, chunk):
        a, b, _, _ = self._gather(adj, chunk)
        return self.intersect_count(a, b)

    def per_node_chunk(self, adj, chunk, n_out):
        a, b, _, _ = self._gather(adj, chunk)
        count, arm = self.intersect_per_node(a, b)
        return _panel_scatter_per_node(
            jnp.asarray(chunk.u), jnp.asarray(chunk.v), a, count, arm, n_out=n_out
        )

    def support_chunk(self, adj, chunk, m_out):
        a, b, _, _ = self._gather(adj, chunk)
        count, arm, closure = self.intersect_support(a, b)
        return _panel_scatter_support(
            jnp.asarray(chunk.edge_idx), jnp.asarray(chunk.u), jnp.asarray(chunk.v),
            adj.row_offsets, count, arm, closure, m_out=m_out,
        )


class PallasBackend(PanelBackend):
    """The panel plan driving the Pallas kernel family.

    Identical planning and scatters to :class:`PanelBackend`; the
    equality-tile reductions run inside
    :mod:`repro.kernels.triangle_count` (interpret mode off-TPU), with
    tile shapes steered per pow2 bucket by the optional ``tuner``.
    """

    name = "pallas"

    def _tiles(self, a, b):
        if self.tuner is None:
            return None
        return self.tuner.tiles(a.shape[0], a.shape[1], b.shape[1])

    def intersect_count(self, a, b):
        from repro.kernels.triangle_count import ops as tc_ops

        return tc_ops.intersect_count(a, b, tiles=self._tiles(a, b))

    def intersect_per_node(self, a, b):
        from repro.kernels.triangle_count import ops as tc_ops

        return tc_ops.intersect_per_node(a, b, tiles=self._tiles(a, b))

    def intersect_support(self, a, b):
        from repro.kernels.triangle_count import ops as tc_ops

        return tc_ops.intersect_support(a, b, tiles=self._tiles(a, b))


class DistributedBackend(KernelBackend):
    """The §III-E striped multi-device schedule — every workload.

    :meth:`plan` round-robin stripes the query edge list over every mesh
    device (edge ``i`` on stripe ``i mod S`` — the paper's
    thread-striping lifted to devices) and cuts the striped axis into
    column chunks whose *worst stripe* obeys the wedge budget
    (:func:`repro.core.distributed.plan_striped_chunks`,
    shorter-side-aware).  The chunk kernels are the ``shard_map``
    wedge kernels from :func:`repro.core.distributed.striped_workload_fn`:
    count returns per-shard segmented partials (host uint64 reduce),
    per-node merges by ``psum``, support merges arm/closure by ``psum``
    and the stripe-local base by a stripe-offset ``all_gather`` whose
    int32 payload rides a lossless delta-compressed uint16 wire when the
    graph's degree bound allows (``compress=True``, the default).

    All three are bit-identical to the wedge backend at any budget and
    any device count — the tests' simulated-mesh parity wall enforces
    this.  Results come back replicated, so the shared
    :func:`run_workload` driver accumulates them exactly like any other
    backend's.
    """

    name = "distributed"
    capabilities = frozenset(CAPABILITIES)

    def __init__(self, mesh=None, *, shorter_side: bool = False, compress: bool = True):
        self.mesh = mesh
        self.shorter_side = shorter_side
        self.compress = compress
        self.n_shards = (
            int(np.prod(mesh.devices.shape)) if mesh is not None else 0
        )
        self._adj_key = None
        self._adj_dev = None
        self._adj_bound = 0

    def _require_mesh(self):
        if self.mesh is None:
            raise ValueError(
                "the distributed backend needs a jax.sharding.Mesh; "
                "construct it via make_backend('distributed', mesh=...) or "
                "TriangleCounter(method='distributed', mesh=...)"
            )

    def plan(self, work: Workload, budget: int | None, *, bucket_pow2: bool = False) -> WorkPlan:
        from .distributed import plan_striped_chunks

        self._require_mesh()
        src, dst, deg = work.src_host, work.dst_host, work.deg_host
        m = src.shape[0]
        S = self.n_shards
        e_per = max(1, -(-m // S))
        pad = e_per * S - m
        src_p = np.concatenate([src.astype(np.int32, copy=False),
                                np.full(pad, -1, np.int32)])
        dst_p = np.concatenate([dst.astype(np.int32, copy=False),
                                np.full(pad, -1, np.int32)])
        # reshape(e_per, S).T puts edge i on stripe i % S
        src_sh = np.ascontiguousarray(src_p.reshape(e_per, S).T)
        dst_sh = np.ascontiguousarray(dst_p.reshape(e_per, S).T)
        reps = np.where(src_p >= 0, deg[np.maximum(src_p, 0)], 0).astype(np.int64)
        if self.shorter_side:
            reps_v = np.where(dst_p >= 0, deg[np.maximum(dst_p, 0)], 0).astype(np.int64)
            reps = np.minimum(reps, reps_v)
        stripe_loads = tuple(
            int(x) for x in reps.reshape(e_per, S).sum(axis=0)
        )
        bounds, eff = plan_striped_chunks(
            src_sh, deg, budget, dst_sh=dst_sh if self.shorter_side else None
        )
        cols_per_chunk = max(end - start for start, end in bounds)
        if bucket_pow2:
            eff = next_pow2(eff)
            cols_per_chunk = next_pow2(cols_per_chunk)

        def gen():
            for start, end in bounds:
                pad_c = cols_per_chunk - (end - start)
                s = src_sh[:, start:end]
                d = dst_sh[:, start:end]
                if pad_c:
                    fill = np.full((S, pad_c), -1, np.int32)
                    s = np.concatenate([s, fill], axis=1)
                    d = np.concatenate([d, fill], axis=1)
                yield StripedChunk(
                    np.ascontiguousarray(s), np.ascontiguousarray(d), start, eff
                )

        return WorkPlan(
            gen(), len(bounds), eff, int(reps.sum(dtype=np.int64)),
            n_stripes=S, stripe_loads=stripe_loads,
        )

    # -- chunk launch plumbing ---------------------------------------------

    def _device_adj(self, adj: _DeviceAdj):
        """Replicate the adjacency once per workload (cached by identity)."""
        from jax.sharding import NamedSharding, PartitionSpec

        key = (id(adj.row_offsets), id(adj.col), id(adj.out_degree))
        if self._adj_key != key:
            rep = NamedSharding(self.mesh, PartitionSpec())
            deg_np = np.asarray(adj.out_degree)
            self._adj_dev = tuple(
                jax.device_put(np.asarray(a), rep)
                for a in (adj.row_offsets, adj.col, adj.out_degree)
            )
            self._adj_bound = int(deg_np.max()) if deg_np.size else 0
            self._adj_key = key
        return self._adj_dev

    def _put_chunk(self, chunk: StripedChunk):
        from jax.sharding import NamedSharding, PartitionSpec

        sh = NamedSharding(self.mesh, PartitionSpec(self.mesh.axis_names))
        return jax.device_put(chunk.src, sh), jax.device_put(chunk.dst, sh)

    def _fn(self, kind: str, adj: _DeviceAdj, chunk: StripedChunk, n_out: int):
        from repro.distributed.compression import can_narrow_int32

        from .distributed import striped_workload_fn

        narrow = (
            kind == "support" and self.compress and can_narrow_int32(self._adj_bound)
        )
        return striped_workload_fn(
            self.mesh, kind, chunk.buffer, adj.n_steps,
            n_out=n_out, shorter_side=self.shorter_side, narrow_wire=narrow,
        )

    def count_chunk(self, adj, chunk):
        self._require_mesh()
        row, col, deg = self._device_adj(adj)
        s, d = self._put_chunk(chunk)
        fn = self._fn("count", adj, chunk, 0)
        return fn(s, d, jnp.int32(chunk.start), row, col, deg)

    def per_node_chunk(self, adj, chunk, n_out):
        self._require_mesh()
        row, col, deg = self._device_adj(adj)
        s, d = self._put_chunk(chunk)
        fn = self._fn("per_node", adj, chunk, n_out)
        return fn(s, d, jnp.int32(chunk.start), row, col, deg)

    def support_chunk(self, adj, chunk, m_out):
        self._require_mesh()
        if m_out != int(adj.col.shape[0]):
            raise ValueError(
                f"distributed support needs the query list aligned with the "
                f"adjacency edge list (m_out={m_out} != |col|={int(adj.col.shape[0])})"
            )
        row, col, deg = self._device_adj(adj)
        s, d = self._put_chunk(chunk)
        fn = self._fn("support", adj, chunk, m_out)
        return fn(s, d, jnp.int32(chunk.start), row, col, deg)


_BACKEND_FACTORIES: dict[str, object] = {}


def register_backend(name: str, factory) -> None:
    """Register a backend factory under ``name``.

    The factory is called with keyword arguments
    ``factory(widths=..., tuner=..., mesh=..., shorter_side=...)`` and
    must return a :class:`KernelBackend`; accept ``**_`` for the knobs
    the backend does not use.  A registered name is directly usable as
    ``TriangleCounter(method=name)``.
    """
    _BACKEND_FACTORIES[name] = factory


register_backend("wedge_bsearch", lambda **_: WedgeBackend())
register_backend("panel", lambda widths=DEFAULT_WIDTHS, **_: PanelBackend(widths=widths))
register_backend(
    "pallas",
    lambda widths=DEFAULT_WIDTHS, tuner=None, **_: PallasBackend(
        widths=widths, tuner=tuner
    ),
)
register_backend(
    "distributed",
    lambda mesh=None, shorter_side=False, **_: DistributedBackend(
        mesh, shorter_side=shorter_side
    ),
)


def make_backend(
    name: str,
    *,
    widths=DEFAULT_WIDTHS,
    tuner=None,
    mesh=None,
    shorter_side: bool = False,
) -> KernelBackend:
    """Instantiate the backend registered under ``name``."""
    try:
        factory = _BACKEND_FACTORIES[name]
    except KeyError:
        raise ValueError(
            f"unknown kernel backend {name!r}; registered: "
            f"{sorted(_BACKEND_FACTORIES)}"
        ) from None
    return factory(widths=widths, tuner=tuner, mesh=mesh, shorter_side=shorter_side)


_warned_fallbacks: set = set()


def resolve_backend(
    method: str,
    kind: str,
    *,
    widths=DEFAULT_WIDTHS,
    tuner=None,
    mesh=None,
    shorter_side: bool = False,
):
    """Pick the backend for (schedule, workload) by capability.

    Returns ``(backend, executed_name, fallback_reason)``.  When the
    requested backend lacks ``kind`` — or the distributed schedule is
    requested without a mesh — the wedge backend substitutes and the
    reason is returned (plus a one-time ``RuntimeWarning`` per
    (method, kind) pair per process) — capability gaps are loud.
    """
    if kind not in CAPABILITIES:
        raise ValueError(f"unknown workload kind {kind!r}; expected one of {CAPABILITIES}")
    reason = None
    if method == "distributed" and mesh is None:
        reason = (
            "backend 'distributed' needs a mesh and none was configured; "
            "fell back to 'wedge_bsearch'"
        )
    else:
        backend = make_backend(
            method, widths=widths, tuner=tuner, mesh=mesh, shorter_side=shorter_side
        )
        if kind in backend.capabilities:
            return backend, method, None
        reason = (
            f"backend {method!r} has no {kind!r} kernel; fell back to 'wedge_bsearch'"
        )
    obs.counter("engine.capability_fallbacks").add()
    key = (method, kind)
    if key not in _warned_fallbacks:
        _warned_fallbacks.add(key)
        warnings.warn(reason, RuntimeWarning, stacklevel=3)
    return make_backend("wedge_bsearch", widths=widths, tuner=tuner), "wedge_bsearch", reason


def _sanitizer():
    """The ``REPRO_CHECK=1`` runtime audit module, or None when disabled.

    Checked per call (not cached) so tests can toggle the env var; the
    import cost is one dict lookup after the first load.
    """
    flag = os.environ.get("REPRO_CHECK", "").strip().lower()
    if flag in ("", "0", "false", "off", "no"):
        return None
    from repro.check import runtime as _rt

    return _rt


def run_workload(
    backend: KernelBackend,
    kind: str,
    work: Workload,
    *,
    budget: int | None = None,
    n_out: int | None = None,
    bucket_pow2: bool = False,
):
    """Plan → launch → accumulate one workload through a backend.

    The single driver every caller shares (engine methods, analytics
    support, truss peel rounds, incremental probes).  Returns
    ``(value, plan)`` where ``value`` is the host-accumulated result —
    ``int`` for ``"count"``, int64 ``(n_out,)`` for ``"per_node"``,
    int64 per-query-edge for ``"support"`` — and ``plan`` carries the
    launch stats (``n_chunks``, ``peak_buffer``, ``total_wedges``) plus
    the phase ``timings``.

    Observability: phase wall clocks (plan/execute/fold) are always
    recorded — they are two ``perf_counter`` reads per phase.  Under an
    active :mod:`repro.obs` tracer each chunk launch additionally gets a
    span that *syncs* the partial before closing (``execute`` then
    measures device compute, not async dispatch), and §III-E striped
    chunks get a per-stripe timing probe (measured straggler detection).
    """
    trc = obs.active()
    t0 = time.perf_counter()
    plan = backend.plan(work, budget, bucket_pow2=bucket_pow2)
    timings = {"plan": time.perf_counter() - t0, "execute": 0.0, "fold": 0.0}
    adj = _DeviceAdj(
        jnp.asarray(work.row_offsets), jnp.asarray(work.col),
        jnp.asarray(work.out_degree), work.n_steps,
    )
    san = _sanitizer()
    obs.counter("engine.workloads").add()
    obs.counter("engine.wedges_planned").add(plan.total_wedges)
    obs.counter("engine.chunks_launched").add(plan.n_chunks)
    obs.gauge("engine.peak_wedge_buffer").set(plan.peak_buffer)
    stripe_acc: list | None = None

    def launch(fn, chunk, i, *extra):
        """One chunk launch, span-wrapped (and synced) when tracing."""
        nonlocal stripe_acc
        if trc is None:
            return fn(adj, chunk, *extra)
        with trc.span(f"{kind}.chunk", cat="engine",
                      args={"chunk": i,
                            "buffer": int(getattr(chunk, "buffer", 0))}) as sp:
            part = sp.sync(fn(adj, chunk, *extra))
        if isinstance(chunk, StripedChunk):
            times = _probe_stripe_times(trc, adj, chunk)
            if stripe_acc is None:
                stripe_acc = [0.0] * len(times)
            for s, dt in enumerate(times):
                stripe_acc[s] += dt
        return part

    def done(value):
        return value, plan._replace(
            timings=timings,
            stripe_times=tuple(stripe_acc) if stripe_acc else None,
        )

    if kind == "count":
        # collect device partials first, accumulate once: launches stay
        # async-dispatched instead of syncing host-side per chunk (under
        # tracing each launch IS synced — that is the point of the span)
        t0 = time.perf_counter()
        partials = [
            launch(backend.count_chunk, chunk, i)
            for i, chunk in enumerate(plan.chunks)
        ]
        if san is not None:
            san.check_partials(partials, kind="count")
        timings["execute"] = time.perf_counter() - t0
        t0 = time.perf_counter()
        total = accumulate_partials(partials)
        timings["fold"] = time.perf_counter() - t0
        return done(total)
    if kind == "per_node":
        if n_out is None:
            n_out = adj.row_offsets.shape[0] - 1
        out = np.zeros((n_out,), np.int64)
        t_loop = time.perf_counter()
        for i, chunk in enumerate(plan.chunks):
            part = launch(backend.per_node_chunk, chunk, i, n_out)
            if san is not None:
                san.check_partial(part, kind="per_node", context=f"chunk {i}")
            t0 = time.perf_counter()
            out += np.asarray(part, dtype=np.int64)
            timings["fold"] += time.perf_counter() - t0
        timings["execute"] = time.perf_counter() - t_loop - timings["fold"]
        return done(out)
    if kind == "support":
        m_out = int(work.src_host.shape[0])
        out = np.zeros((m_out,), np.int64)
        t_loop = time.perf_counter()
        for i, chunk in enumerate(plan.chunks):
            part = launch(backend.support_chunk, chunk, i, m_out)
            if san is not None:
                san.check_partial(part, kind="support", context=f"chunk {i}")
            t0 = time.perf_counter()
            out += np.asarray(part, dtype=np.int64)
            timings["fold"] += time.perf_counter() - t0
        timings["execute"] = time.perf_counter() - t_loop - timings["fold"]
        return done(out)
    raise ValueError(f"unknown workload kind {kind!r}")


def _probe_stripe_times(trc, adj: _DeviceAdj, chunk: StripedChunk) -> "list[float]":
    """Measured per-stripe seconds for one §III-E striped chunk.

    The striped collective executes all stripes in one fused dispatch, so
    individual stripes are not separately observable from the host.  Under
    tracing we therefore *re-run* the wedge-count kernel over each
    stripe's −1-padded edge slice on the default device, synced, and
    report those wall times — measured per-stripe cost beside the
    load-inferred skew (Arifuzzaman et al. make load-vs-timing skew a
    first-order concern; load is only a proxy).  One warm-up launch keeps
    the (buffer, steps) compile out of the timed region.  Costs roughly
    one extra pass over the chunk, paid only while a tracer is active.
    """
    src = np.asarray(chunk.src)
    dst = np.asarray(chunk.dst)
    warm = chunk_count_kernel(
        jnp.asarray(src[0]), jnp.asarray(dst[0]),
        adj.row_offsets, adj.col, adj.out_degree,
        wedge_budget=chunk.buffer, n_steps=adj.n_steps,
    )
    jax.block_until_ready(warm)
    times = []
    for s in range(src.shape[0]):
        t0 = time.perf_counter()
        with trc.span("stripe.probe", cat="engine.stripes",
                      args={"stripe": s}) as sp:
            sp.sync(chunk_count_kernel(
                jnp.asarray(src[s]), jnp.asarray(dst[s]),
                adj.row_offsets, adj.col, adj.out_degree,
                wedge_budget=chunk.buffer, n_steps=adj.n_steps,
            ))
        times.append(time.perf_counter() - t0)
    return times


def iter_wedge_chunks(csr: OrientedCSR, max_wedge_chunk: int | None, *, bucket_pow2: bool = False):
    """Lazily yield −1-padded fixed-shape ``(src, dst, start)`` chunks.

    The historical edge-chunk iterator, now a thin view over
    :meth:`WedgeBackend.plan`.  ``start`` is each chunk's offset into the
    directed edge list — add it to a kernel's local edge ids to recover
    global edge indices (the per-edge support scatter needs this).
    ``csr.src``/``csr.col`` may carry a −1-padded tail (padded slots
    contribute no wedges), and ``bucket_pow2`` rounds the chunk width and
    the peak buffer up to powers of two — together these let
    shape-churning callers (the truss peel's shrinking subgraphs) reuse
    O(log m) kernel compilations.

    Returns ``(generator, n_chunks, peak, total_wedges)`` where ``peak``
    is the per-launch buffer: the largest chunk's wedge load (pow2-rounded
    when bucketing), which the kernels materialize exactly — it can
    undercut the planner's effective budget when no greedy chunk fills
    it.  Only one padded chunk copy is resident at a time, so host
    overhead stays O(chunk) in the larger-than-memory regime the budget
    targets.
    """
    plan = WedgeBackend().plan(
        workload_from_csr(csr), max_wedge_chunk, bucket_pow2=bucket_pow2
    )
    gen = ((c.src, c.dst, c.start) for c in plan.chunks)
    return gen, plan.n_chunks, plan.peak_buffer, plan.total_wedges


# ---------------------------------------------------------------------------
# auto dispatch
# ---------------------------------------------------------------------------


def choose_method(
    *,
    max_out_degree: int,
    mean_out_degree: float,
    mesh=None,
    widths: tuple[int, ...] = DEFAULT_WIDTHS,
    backend: str | None = None,
) -> str:
    """Pick a counting schedule from graph statistics (§III-C skew logic).

    * a multi-device mesh always wins — the §III-E striping scales and is
      exact regardless of skew;
    * on TPU, panels that fit the largest bucket go to the Pallas kernel
      (equality tiles saturate the VPU; the texture-cache role is played
      by explicit VMEM staging);
    * low degree + low skew favors the jnp panel schedule (padding waste
      bounded, O(L²) constant small);
    * heavy tails — Kronecker-style skew — favor ``wedge_bsearch``, whose
      log-factor cost is immune to padding waste.
    """
    if mesh is not None and int(np.prod(mesh.devices.shape)) > 1:
        return "distributed"
    backend = backend or jax.default_backend()
    skew = max_out_degree / max(mean_out_degree, 1e-9)
    if backend == "tpu" and max_out_degree <= widths[-1]:
        return "pallas"
    if max_out_degree <= 64 and skew <= 16.0:
        return "panel"
    return "wedge_bsearch"


def resolve_method(method: str, out_degree, *, mesh=None, widths=DEFAULT_WIDTHS) -> str:
    """Resolve ``"auto"`` against an out-degree histogram (never "auto")."""
    if method != "auto":
        return method
    out_deg = np.asarray(out_degree)
    max_deg = int(out_deg.max()) if out_deg.size else 0
    mean_deg = float(out_deg.mean()) if out_deg.size else 0.0
    return choose_method(
        max_out_degree=max_deg, mean_out_degree=mean_deg, mesh=mesh, widths=widths
    )


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------


class TriangleCounter:
    """Unified, memory-bounded triangle counting over every schedule.

    Parameters
    ----------
    method:
        One of ``"auto"``, ``"wedge_bsearch"``, ``"panel"``, ``"pallas"``,
        ``"distributed"``.
    max_wedge_chunk:
        Wedge-buffer budget per device launch (slots).  ``None`` runs a
        single full-size launch.
    widths:
        Panel bucket boundaries for the panel/Pallas schedules.
    mesh:
        ``jax.sharding.Mesh`` for the distributed schedule (required when
        ``method="distributed"``; enables it under ``"auto"``).
    shorter_side:
        Distributed only — enumerate wedge candidates from the smaller
        endpoint list (§Perf "opt" variant in EXPERIMENTS.md).
    tuner:
        Optional :class:`repro.core.tuning.AutoTuner` steering the Pallas
        kernels' tile shapes from its on-disk grid-search cache.

    After any call, :attr:`last_stats` holds an :class:`EngineStats`
    describing what ran (resolved method, executed method, chunk count,
    peak buffer, and any capability-fallback reason).
    """

    def __init__(
        self,
        method: str = "auto",
        max_wedge_chunk: int | None = None,
        widths: tuple[int, ...] = DEFAULT_WIDTHS,
        mesh=None,
        shorter_side: bool = False,
        tuner=None,
    ):
        if method not in METHODS and method not in _BACKEND_FACTORIES:
            raise ValueError(
                f"unknown method {method!r}; expected one of {METHODS} "
                f"or a registered backend ({sorted(_BACKEND_FACTORIES)})"
            )
        if method == "distributed" and mesh is None:
            raise ValueError("method='distributed' requires a mesh")
        if max_wedge_chunk is not None and max_wedge_chunk < 1:
            raise ValueError("max_wedge_chunk must be positive")
        self.method = method
        self.max_wedge_chunk = max_wedge_chunk
        self.widths = tuple(widths)
        self.mesh = mesh
        self.shorter_side = shorter_side
        self.tuner = tuner
        self.last_stats: EngineStats | None = None

    # -- public API ---------------------------------------------------------

    def count(self, edges, n_nodes: int | None = None) -> int:
        """Exact global triangle count.

        ``edges`` may be a canonical edge array, a pre-built
        :class:`OrientedCSR` (preprocessing skipped entirely), or a cached
        undirected CSR (anything with ``row_offsets``/``col``/``n_nodes``,
        e.g. ``repro.graphs.io.CSRGraph`` loaded from a ``.tricsr`` file —
        oriented by a host-side filter, never re-canonicalized).
        """
        self.last_stats = None
        with obs.span("engine.count", cat="engine"):
            csr, prep_s = self._prepare_timed(edges, n_nodes)
            if csr is None:
                return 0
            return self._run(csr, "count", self._resolve(csr), prep_s)

    def per_node(self, edges, n_nodes: int | None = None) -> np.ndarray:
        """Per-vertex triangle incidences, int64 host array.

        Runs whichever backend the configured/dispatched schedule
        registers — the panel and Pallas backends scatter their arm
        attributions natively, and the distributed backend psum-merges
        per-stripe scatters — so ``method="pallas"`` genuinely executes
        the Pallas kernels here and ``method="distributed"`` genuinely
        executes on every mesh device.
        """
        self.last_stats = None
        with obs.span("engine.per_node", cat="engine"):
            csr, prep_s = self._prepare_timed(edges, n_nodes)
            if csr is None:
                n = n_nodes if n_nodes is not None else getattr(edges, "n_nodes", 0) or 0
                return np.zeros((n,), np.int64)
            return self._run(csr, "per_node", self._resolve(csr), prep_s)

    def edge_support(self, edges, n_nodes: int | None = None) -> np.ndarray:
        """Per-directed-edge triangle support, int64 host array.

        Aligned with the oriented CSR's ``(src, col)`` edge list; the sum
        is exactly ``3 × count``.  The richer dataclass wrapper (top-k,
        totals) lives in :func:`repro.analytics.support.edge_support`,
        which routes through this method.
        """
        self.last_stats = None
        with obs.span("engine.support", cat="engine"):
            csr, prep_s = self._prepare_timed(edges, n_nodes)
            if csr is None:
                return np.zeros((0,), np.int64)
            return self._run(csr, "support", self._resolve(csr), prep_s)

    def per_node_counts(self, edges, n_nodes: int | None = None) -> np.ndarray:
        """Alias of :meth:`per_node` (clearer name for analytics callers)."""
        return self.per_node(edges, n_nodes)

    @staticmethod
    def _degree_hist(edges, n_nodes: int | None):
        """Undirected degrees + node count for any accepted input kind."""
        return degree_histogram(edges, n_nodes)

    def clustering(self, edges, n_nodes: int | None = None) -> np.ndarray:
        """Local clustering coefficients c(v) = 2·T(v) / (deg(v)·(deg(v)−1))."""
        from .clustering import clustering_from_counts

        deg, n_nodes = self._degree_hist(edges, n_nodes)
        if deg.size == 0:
            return np.zeros((n_nodes,), np.float64)
        tri = self.per_node(edges, n_nodes)
        return clustering_from_counts(tri, deg)

    def transitivity(self, edges, n_nodes: int | None = None) -> float:
        """Global transitivity ratio 3·#triangles / #wedges."""
        from .clustering import transitivity_from_counts

        deg, n_nodes = self._degree_hist(edges, n_nodes)
        if deg.size == 0:
            return 0.0
        t = self.count(edges, n_nodes)
        return transitivity_from_counts(t, deg)

    # -- shared plumbing ----------------------------------------------------

    def _prepare_timed(self, edges, n_nodes: int | None):
        """``(_prepare result, preprocess seconds)`` under a span."""
        t0 = time.perf_counter()
        with obs.span("engine.preprocess", cat="engine"):
            csr = self._prepare(edges, n_nodes)
        return csr, time.perf_counter() - t0

    def _prepare(self, edges, n_nodes: int | None) -> OrientedCSR | None:
        csr = prepare_oriented(edges, n_nodes)
        if csr is not None:
            return csr
        # empty graph: no CSR to resolve "auto" against; record the
        # trivial schedule
        resolved = self.method if self.method != "auto" else "wedge_bsearch"
        self.last_stats = EngineStats(
            method=resolved, resolved_method=resolved, n_chunks=0,
            peak_wedge_buffer=0, wedge_budget=self.max_wedge_chunk,
            total_wedges=0, n_directed_edges=0,
        )
        return None

    def _resolve(self, csr: OrientedCSR) -> str:
        return resolve_method(
            self.method, csr.out_degree, mesh=self.mesh, widths=self.widths
        )

    @staticmethod
    def _search_steps(csr: OrientedCSR) -> int:
        return search_steps(csr)

    def _record(self, method, n_chunks, peak, total_wedges, m_dir,
                resolved=None, fallback_reason=None, stripe_loads=None,
                n_stripes=1, timings=None, stripe_times=None):
        skew = straggler = None
        measured_skew = measured_straggler = None
        note = None
        load_rep = None
        if stripe_loads is not None:
            from repro.distributed.straggler import stripe_skew_report

            load_rep = stripe_skew_report(stripe_loads)
            skew = load_rep.skew
            straggler = load_rep.straggler_stripe
        if stripe_times:
            from repro.distributed.straggler import (
                skew_disagreement_note,
                stripe_skew_report,
            )

            # the MAD rule works on integer loads; nanoseconds keep the
            # measured resolution through the int coercion
            time_rep = stripe_skew_report([int(t * 1e9) for t in stripe_times])
            measured_skew = time_rep.skew
            measured_straggler = time_rep.straggler_stripe
            if load_rep is not None:
                note = skew_disagreement_note(load_rep, time_rep)
                if note is not None:
                    obs.counter("engine.skew_disagreements").add()
                    warnings.warn(note, RuntimeWarning, stacklevel=3)
        self.last_stats = EngineStats(
            method=method,
            resolved_method=resolved or method,
            n_chunks=n_chunks,
            peak_wedge_buffer=peak,
            wedge_budget=self.max_wedge_chunk,
            total_wedges=total_wedges,
            n_directed_edges=m_dir,
            fallback_reason=fallback_reason,
            n_stripes=n_stripes,
            stripe_skew=skew,
            straggler_stripe=straggler,
            timings=timings,
            stripe_times=tuple(stripe_times) if stripe_times else None,
            measured_stripe_skew=measured_skew,
            measured_straggler_stripe=measured_straggler,
            skew_note=note,
        )

    def _run(self, csr: OrientedCSR, kind: str, resolved: str,
             prep_s: float = 0.0):
        """Dispatch one workload through the capability-resolved backend."""
        backend, executed, reason = resolve_backend(
            resolved, kind, widths=self.widths, tuner=self.tuner,
            mesh=self.mesh, shorter_side=self.shorter_side,
        )
        work = workload_from_csr(csr)
        value, plan = run_workload(
            backend, kind, work,
            budget=self.max_wedge_chunk,
            n_out=csr.n_nodes if kind == "per_node" else None,
        )
        self._record(
            executed, plan.n_chunks, plan.peak_buffer, plan.total_wedges,
            csr.n_directed_edges, resolved=resolved, fallback_reason=reason,
            stripe_loads=plan.stripe_loads, n_stripes=plan.n_stripes,
            timings={"preprocess": prep_s, **(plan.timings or {})},
            stripe_times=plan.stripe_times,
        )
        return value
