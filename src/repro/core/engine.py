"""Unified triangle-counting engine with memory-bounded edge partitioning.

:class:`TriangleCounter` puts the four counting schedules that used to be
siloed across :mod:`repro.core.count` and :mod:`repro.core.distributed`
behind one front door::

    from repro.core import TriangleCounter

    tc = TriangleCounter(method="auto", max_wedge_chunk=1 << 22)
    t  = tc.count(edges)          # exact global count (host int, uint64-safe)
    pn = tc.per_node(edges)       # per-vertex triangle incidences
    cc = tc.clustering(edges)     # local clustering coefficients

The headline capability is **memory-bounded edge partitioning** — the
reproduction of the paper's "larger than device memory" discipline.  The
paper (§III-C) assigns one CUDA thread per directed edge; the device-side
working set of our TPU rendition is instead the *wedge buffer* of
``Σ deg⁺(u)`` candidate slots, which for an 89M-edge Kronecker graph is
billions of slots — far beyond HBM if materialized at once.  The engine
splits the directed edge list into contiguous chunks whose wedge buffers
fit a static budget, pads every chunk to that budget, and reuses **one**
jitted kernel across all chunks, so the number of *compiles* is constant
while the number of *launches* scales with graph size.  Partial counts
leave the device as int32 and are accumulated on host in uint64
(:func:`accumulate_partials`), so counts like the paper's 3.8B triangles
never overflow 32-bit device arithmetic.

Knob → paper-section map
========================

``method``
    ``"wedge_bsearch"`` / ``"panel"`` / ``"pallas"`` are the TPU-native
    renditions of the paper's ``CountTriangles`` kernel (§II-C forward
    algorithm, §III-C counting phase); ``"distributed"`` is the multi-GPU
    scheme of §III-E (replicated CSR, striped edge list, reduced
    partials); ``"auto"`` picks from graph stats (:func:`choose_method`).
``max_wedge_chunk``
    The per-launch wedge-buffer budget, in candidate slots.  This is the
    engine's analogue of the paper's per-GPU memory ceiling that forces
    the edge list to be processed in passes (§III-E, Table I's 89M-edge
    graph on a 3 GB C2050).  ``None`` materializes one full-size buffer
    (single chunk).  A budget smaller than one edge's fan-out is bumped
    to the max fan-out — a chunk must hold at least one whole edge.
``widths``
    Panel bucket boundaries for the ``panel``/``pallas`` schedules — the
    TPU analogue of the paper's warp-size tuning (§III-D5).  Wedge chunking
    wraps the bucket loop: each bucket is processed in slices of
    ``max_wedge_chunk // width`` edges so panel gathers respect the same
    budget.
``mesh``
    A ``jax.sharding.Mesh`` enabling the §III-E multi-device scheme; the
    edge chunking composes with the round-robin striping in
    :mod:`repro.core.distributed` (chunks slice the striped per-shard
    edge axis, so every device's buffer stays within budget).
``block_edges``
    (Pallas kernel tile height, chosen inside
    :mod:`repro.kernels.triangle_count`) — the §III-D5 thread-block
    sizing; see EXPERIMENTS.md §Perf for the sweep.

Scheduling heuristics (``method="auto"``) follow §III-C's skew
discussion: low max out-degree and low skew favor the panel equality
reduction, heavy tails favor the binary-search schedule, and a multi-chip
mesh always routes to the distributed striping.
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from .count import (
    bucketize_edges,
    expand_and_close_wedges,
    gather_panels,
    panel_intersect_count,
    segmented_int32_sum,
)
from .preprocess import OrientedCSR, oriented_from_undirected_csr, preprocess

__all__ = [
    "TriangleCounter",
    "EngineStats",
    "choose_method",
    "plan_edge_chunks",
    "accumulate_partials",
    "prepare_oriented",
    "degree_histogram",
    "search_steps",
    "next_pow2",
    "iter_wedge_chunks",
    "chunk_count_kernel",
    "chunk_per_node_kernel",
    "METHODS",
]

METHODS = ("auto", "wedge_bsearch", "panel", "pallas", "distributed")

DEFAULT_WIDTHS = (16, 64, 256, 1024, 4096)


# ---------------------------------------------------------------------------
# host-side planning + accumulation
# ---------------------------------------------------------------------------


def accumulate_partials(partials) -> int:
    """uint64 host accumulation of device partial counts.

    Device partials are int32 scalars or vectors, each element bounded by
    its reduction segment (2²⁰ slots in the chunk kernels); the *sum*
    over partials can exceed 2³¹ — the paper's Table I counts reach
    3.8B — so the running total lives in uint64 on host.
    """
    total = np.uint64(0)
    for p in partials:
        arr = np.asarray(p)
        if arr.size == 0:
            continue
        total += np.uint64(arr.astype(np.uint64).sum())
    return int(total)


def plan_edge_chunks(reps: np.ndarray, budget: int | None):
    """Greedy contiguous partition of the directed edge list.

    ``reps[i]`` is the wedge fan-out of directed edge ``i``.  Returns
    ``(bounds, effective_budget)`` where every ``[start, end)`` chunk in
    ``bounds`` satisfies ``reps[start:end].sum() <= effective_budget``.
    The effective budget is ``max(budget, reps.max())`` — a chunk must
    hold at least one whole edge's fan-out, so a sub-fan-out budget is
    bumped rather than splitting an adjacency list.
    """
    reps = np.asarray(reps, dtype=np.int64)
    m = reps.shape[0]
    if m == 0:
        return [(0, 0)], 1
    total = int(reps.sum())
    max_fan = int(reps.max())
    if budget is None or budget >= total:
        return [(0, m)], max(total, 1)
    eff = max(int(budget), max_fan, 1)
    cum = np.cumsum(reps)
    bounds = []
    start = 0
    while start < m:
        base = int(cum[start - 1]) if start else 0
        end = int(np.searchsorted(cum, base + eff, side="right"))
        end = max(end, start + 1)
        bounds.append((start, end))
        start = end
    return bounds, eff


@dataclasses.dataclass(frozen=True)
class EngineStats:
    """What the last engine call actually did (for tests and tuning).

    ``resolved_method`` is what configuration + ``"auto"`` dispatch chose;
    ``method`` is what actually executed.  They differ only where the
    engine has a single implementation and silently falls back — e.g.
    :meth:`TriangleCounter.per_node` always runs the wedge schedule, so a
    ``method="panel"`` counter reports ``resolved_method="panel"``,
    ``method="wedge_bsearch"`` there.  ``peak_wedge_buffer`` is the
    largest buffer a launch actually materialized (the max chunk load) —
    not the requested budget, which lives in ``wedge_budget``.
    """

    method: str                  # executed schedule, never "auto"
    resolved_method: str         # configured/dispatched schedule, never "auto"
    n_chunks: int                # device launches for the counting phase
    peak_wedge_buffer: int       # largest buffer materialized per launch
    wedge_budget: int | None     # requested budget (None = unbounded)
    total_wedges: int            # Σ fan-out over all directed edges
    n_directed_edges: int


# ---------------------------------------------------------------------------
# chunk kernels (compiled once per (shape-budget, steps) pair, reused
# across every chunk — chunk count drives launches, not compiles)
#
# These, together with `iter_wedge_chunks` / `search_steps` /
# `prepare_oriented` below, are the engine's *stable internal API*: the
# plumbing other subsystems (repro.core.incremental, repro.analytics)
# build chunked wedge workloads from, instead of growing private copies.
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("wedge_budget", "n_steps"))
def chunk_count_kernel(src_e, dst_e, row_offsets, col, out_deg, *, wedge_budget, n_steps):
    """Count triangles closed by one −1-padded edge chunk.

    Returns a *vector* of int32 partials, one per 2²⁰-slot segment of the
    wedge buffer (:func:`repro.core.count.segmented_int32_sum`): int32 is
    safe even for an unbounded (``max_wedge_chunk=None``) launch whose
    total hits exceed 2³¹ — the final uint64 reduction happens on host.
    """
    hit, _, _, _ = expand_and_close_wedges(
        src_e, dst_e, row_offsets, col, out_deg, wedge_budget, n_steps
    )
    return segmented_int32_sum(hit)


@functools.partial(jax.jit, static_argnames=("wedge_budget", "n_steps"))
def chunk_per_node_kernel(src_e, dst_e, row_offsets, col, out_deg, *, wedge_budget, n_steps):
    """Per-vertex triangle incidences contributed by one edge chunk."""
    hit, u, v, w = expand_and_close_wedges(
        src_e, dst_e, row_offsets, col, out_deg, wedge_budget, n_steps
    )
    inc = hit.astype(jnp.int32)
    n = row_offsets.shape[0] - 1
    out = jnp.zeros((n,), jnp.int32)
    out = out.at[u].add(inc)
    out = out.at[v].add(inc)
    out = out.at[w].add(inc)
    return out


# legacy underscore names (pre-analytics); new code uses the public ones
_chunk_count_kernel = chunk_count_kernel
_chunk_per_node_kernel = chunk_per_node_kernel


def search_steps(csr: OrientedCSR) -> int:
    """⌈log₂(max out-degree + 1)⌉ — the binary-search depth the chunk
    kernels need for this CSR (static argument, shared by all chunks)."""
    max_deg = int(np.asarray(csr.out_degree).max()) if csr.n_nodes else 0
    return max(1, math.ceil(math.log2(max_deg + 1))) if max_deg else 1


def prepare_oriented(edges, n_nodes: int | None = None) -> OrientedCSR | None:
    """Normalize any accepted graph input to an :class:`OrientedCSR`.

    Accepts a pre-built :class:`OrientedCSR` (returned as-is), a cached
    undirected CSR (anything with ``row_offsets``/``col``/``n_nodes``,
    e.g. ``repro.graphs.io.CSRGraph`` — oriented by a host-side filter,
    never re-canonicalized), or a canonical edge array (full
    preprocessing).  Returns ``None`` for an empty graph.  This is the
    shared input front door of :class:`TriangleCounter` and the analytics
    subsystem — call it once and pass the CSR around to avoid repeated
    preprocessing.
    """
    if isinstance(edges, OrientedCSR):
        csr = edges
    elif hasattr(edges, "row_offsets") and hasattr(edges, "col"):
        csr = oriented_from_undirected_csr(
            edges.row_offsets, edges.col, getattr(edges, "n_nodes", None)
        )
    else:
        edges = np.asarray(edges)
        if edges.size == 0:
            return None
        if n_nodes is None:
            n_nodes = int(edges.max()) + 1
        csr = preprocess(jnp.asarray(edges), n_nodes=n_nodes)
    if csr.n_directed_edges > 0:
        return csr
    return None


def degree_histogram(edges, n_nodes: int | None = None) -> tuple[np.ndarray, int]:
    """Undirected degrees + node count for any accepted graph input kind."""
    if isinstance(edges, OrientedCSR):
        return np.asarray(edges.degree, dtype=np.int64), edges.n_nodes
    if hasattr(edges, "row_offsets") and hasattr(edges, "col"):
        return np.diff(np.asarray(edges.row_offsets)).astype(np.int64), int(
            getattr(edges, "n_nodes", np.asarray(edges.row_offsets).shape[0] - 1)
        )
    edges = np.asarray(edges)
    if edges.size == 0:
        return np.zeros((n_nodes or 0,), np.int64), n_nodes or 0
    if n_nodes is None:
        n_nodes = int(edges.max()) + 1
    return np.bincount(edges[:, 0], minlength=n_nodes).astype(np.int64), n_nodes


def next_pow2(x: int) -> int:
    """Smallest power of two ≥ x (pow2 shape bucketing helper)."""
    return 1 << max(int(x) - 1, 0).bit_length() if x > 1 else 1


def iter_wedge_chunks(csr: OrientedCSR, max_wedge_chunk: int | None, *, bucket_pow2: bool = False):
    """Lazily yield −1-padded fixed-shape ``(src, dst, start)`` chunks.

    ``start`` is each chunk's offset into the directed edge list — add it
    to a kernel's local edge ids to recover global edge indices (the
    per-edge support scatter needs this).  ``csr.src``/``csr.col`` may
    carry a −1-padded tail (padded slots contribute no wedges), and
    ``bucket_pow2`` rounds the chunk width and the peak buffer up to
    powers of two — together these let shape-churning callers (the truss
    peel's shrinking subgraphs) reuse O(log m) kernel compilations.

    Returns ``(generator, n_chunks, peak, total_wedges)`` where ``peak``
    is the per-launch buffer: the largest chunk's wedge load (pow2-rounded
    when bucketing), which the kernels materialize exactly — it can
    undercut the planner's effective budget when no greedy chunk fills
    it.  Only one padded chunk copy is resident at a time, so host
    overhead stays O(chunk) in the larger-than-memory regime the budget
    targets.
    """
    src = np.asarray(csr.src)
    out_deg = np.asarray(csr.out_degree)
    reps = np.where(src >= 0, out_deg[np.maximum(src, 0)], 0).astype(np.int64)
    bounds, _ = plan_edge_chunks(reps, max_wedge_chunk)
    cum = np.concatenate([[0], np.cumsum(reps)])
    peak = max(int(cum[end] - cum[start]) for start, end in bounds)
    peak = max(peak, 1)
    edges_per_chunk = max(end - start for start, end in bounds)
    if bucket_pow2:
        peak = next_pow2(peak)
        edges_per_chunk = next_pow2(edges_per_chunk)

    def gen():
        if len(bounds) == 1 and edges_per_chunk == src.shape[0]:
            # single full chunk: feed the (possibly device-resident) CSR
            # arrays directly — no host round-trip, no copies
            yield csr.src, csr.col, 0
            return
        dst = np.asarray(csr.col)
        for start, end in bounds:
            pad = edges_per_chunk - (end - start)
            s, d = src[start:end], dst[start:end]
            if pad:
                fill = np.full(pad, -1, np.int32)
                s = np.concatenate([s, fill])
                d = np.concatenate([d, fill])
            yield s.astype(np.int32, copy=False), d.astype(np.int32, copy=False), start

    return gen(), len(bounds), peak, int(reps.sum())


# ---------------------------------------------------------------------------
# auto dispatch
# ---------------------------------------------------------------------------


def choose_method(
    *,
    max_out_degree: int,
    mean_out_degree: float,
    mesh=None,
    widths: tuple[int, ...] = DEFAULT_WIDTHS,
    backend: str | None = None,
) -> str:
    """Pick a counting schedule from graph statistics (§III-C skew logic).

    * a multi-device mesh always wins — the §III-E striping scales and is
      exact regardless of skew;
    * on TPU, panels that fit the largest bucket go to the Pallas kernel
      (equality tiles saturate the VPU; the texture-cache role is played
      by explicit VMEM staging);
    * low degree + low skew favors the jnp panel schedule (padding waste
      bounded, O(L²) constant small);
    * heavy tails — Kronecker-style skew — favor ``wedge_bsearch``, whose
      log-factor cost is immune to padding waste.
    """
    if mesh is not None and int(np.prod(mesh.devices.shape)) > 1:
        return "distributed"
    backend = backend or jax.default_backend()
    skew = max_out_degree / max(mean_out_degree, 1e-9)
    if backend == "tpu" and max_out_degree <= widths[-1]:
        return "pallas"
    if max_out_degree <= 64 and skew <= 16.0:
        return "panel"
    return "wedge_bsearch"


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------


class TriangleCounter:
    """Unified, memory-bounded triangle counting over every schedule.

    Parameters
    ----------
    method:
        One of ``"auto"``, ``"wedge_bsearch"``, ``"panel"``, ``"pallas"``,
        ``"distributed"``.
    max_wedge_chunk:
        Wedge-buffer budget per device launch (slots).  ``None`` runs a
        single full-size launch.
    widths:
        Panel bucket boundaries for the panel/Pallas schedules.
    mesh:
        ``jax.sharding.Mesh`` for the distributed schedule (required when
        ``method="distributed"``; enables it under ``"auto"``).
    shorter_side:
        Distributed only — enumerate wedge candidates from the smaller
        endpoint list (§Perf "opt" variant in EXPERIMENTS.md).

    After any call, :attr:`last_stats` holds an :class:`EngineStats`
    describing what ran (resolved method, chunk count, peak buffer).
    """

    def __init__(
        self,
        method: str = "auto",
        max_wedge_chunk: int | None = None,
        widths: tuple[int, ...] = DEFAULT_WIDTHS,
        mesh=None,
        shorter_side: bool = False,
    ):
        if method not in METHODS:
            raise ValueError(f"unknown method {method!r}; expected one of {METHODS}")
        if method == "distributed" and mesh is None:
            raise ValueError("method='distributed' requires a mesh")
        if max_wedge_chunk is not None and max_wedge_chunk < 1:
            raise ValueError("max_wedge_chunk must be positive")
        self.method = method
        self.max_wedge_chunk = max_wedge_chunk
        self.widths = tuple(widths)
        self.mesh = mesh
        self.shorter_side = shorter_side
        self.last_stats: EngineStats | None = None

    # -- public API ---------------------------------------------------------

    def count(self, edges, n_nodes: int | None = None) -> int:
        """Exact global triangle count.

        ``edges`` may be a canonical edge array, a pre-built
        :class:`OrientedCSR` (preprocessing skipped entirely), or a cached
        undirected CSR (anything with ``row_offsets``/``col``/``n_nodes``,
        e.g. ``repro.graphs.io.CSRGraph`` loaded from a ``.tricsr`` file —
        oriented by a host-side filter, never re-canonicalized).
        """
        csr = self._prepare(edges, n_nodes)
        if csr is None:
            return 0
        method = self._resolve(csr)
        if method == "wedge_bsearch":
            return self._count_wedge(csr)
        if method in ("panel", "pallas"):
            return self._count_panel(csr, pallas=(method == "pallas"))
        if method == "distributed":
            return self._count_distributed(csr)
        raise AssertionError(method)

    def per_node(self, edges, n_nodes: int | None = None) -> np.ndarray:
        """Per-vertex triangle incidences, int64 host array.

        Always runs the (chunked) wedge schedule — the panel and
        distributed schedules produce global partials only; per-node
        scatter is the wedge kernel's native output.  ``last_stats``
        records this fallback honestly: ``resolved_method`` is what the
        configuration/dispatch chose, ``method`` is ``"wedge_bsearch"``.
        """
        csr = self._prepare(edges, n_nodes)
        if csr is None:
            n = n_nodes if n_nodes is not None else getattr(edges, "n_nodes", 0) or 0
            return np.zeros((n,), np.int64)
        return self._per_node_wedge(csr, resolved=self._resolve(csr))

    @staticmethod
    def _degree_hist(edges, n_nodes: int | None):
        """Undirected degrees + node count for any accepted input kind."""
        return degree_histogram(edges, n_nodes)

    def clustering(self, edges, n_nodes: int | None = None) -> np.ndarray:
        """Local clustering coefficients c(v) = 2·T(v) / (deg(v)·(deg(v)−1))."""
        from .clustering import clustering_from_counts

        deg, n_nodes = self._degree_hist(edges, n_nodes)
        if deg.size == 0:
            return np.zeros((n_nodes,), np.float64)
        tri = self.per_node(edges, n_nodes)
        return clustering_from_counts(tri, deg)

    def transitivity(self, edges, n_nodes: int | None = None) -> float:
        """Global transitivity ratio 3·#triangles / #wedges."""
        from .clustering import transitivity_from_counts

        deg, n_nodes = self._degree_hist(edges, n_nodes)
        if deg.size == 0:
            return 0.0
        t = self.count(edges, n_nodes)
        return transitivity_from_counts(t, deg)

    # -- shared plumbing ----------------------------------------------------

    def _prepare(self, edges, n_nodes: int | None) -> OrientedCSR | None:
        csr = prepare_oriented(edges, n_nodes)
        if csr is not None:
            return csr
        # empty graph: no CSR to resolve "auto" against; record the
        # trivial schedule
        resolved = self.method if self.method != "auto" else "wedge_bsearch"
        self.last_stats = EngineStats(
            method=resolved, resolved_method=resolved, n_chunks=0,
            peak_wedge_buffer=0, wedge_budget=self.max_wedge_chunk,
            total_wedges=0, n_directed_edges=0,
        )
        return None

    def _resolve(self, csr: OrientedCSR) -> str:
        if self.method != "auto":
            return self.method
        out_deg = np.asarray(csr.out_degree)
        max_deg = int(out_deg.max()) if out_deg.size else 0
        mean_deg = float(out_deg.mean()) if out_deg.size else 0.0
        return choose_method(
            max_out_degree=max_deg,
            mean_out_degree=mean_deg,
            mesh=self.mesh,
            widths=self.widths,
        )

    @staticmethod
    def _search_steps(csr: OrientedCSR) -> int:
        return search_steps(csr)

    def _wedge_chunks(self, csr: OrientedCSR):
        """(src, dst) chunk stream under this counter's budget — the
        engine-internal view of :func:`iter_wedge_chunks` (offsets
        dropped; the global count/per-node scatters don't need them)."""
        chunks, n_chunks, peak, total = iter_wedge_chunks(csr, self.max_wedge_chunk)
        return ((s, d) for s, d, _ in chunks), n_chunks, peak, total

    def _record(self, method, n_chunks, peak, total_wedges, m_dir, resolved=None):
        self.last_stats = EngineStats(
            method=method,
            resolved_method=resolved or method,
            n_chunks=n_chunks,
            peak_wedge_buffer=peak,
            wedge_budget=self.max_wedge_chunk,
            total_wedges=total_wedges,
            n_directed_edges=m_dir,
        )

    # -- wedge_bsearch schedule ---------------------------------------------

    def _count_wedge(self, csr: OrientedCSR) -> int:
        chunks, n_chunks, peak, total = self._wedge_chunks(csr)
        steps = self._search_steps(csr)
        running = np.uint64(0)
        for s, d in chunks:
            partial = chunk_count_kernel(
                jnp.asarray(s), jnp.asarray(d),
                csr.row_offsets, csr.col, csr.out_degree,
                wedge_budget=peak, n_steps=steps,
            )
            running += np.uint64(accumulate_partials([partial]))
        self._record("wedge_bsearch", n_chunks, peak, total, csr.n_directed_edges)
        return int(running)

    def _per_node_wedge(self, csr: OrientedCSR, resolved: str) -> np.ndarray:
        chunks, n_chunks, peak, total = self._wedge_chunks(csr)
        steps = self._search_steps(csr)
        out = np.zeros((csr.n_nodes,), np.int64)
        for s, d in chunks:
            part = chunk_per_node_kernel(
                jnp.asarray(s), jnp.asarray(d),
                csr.row_offsets, csr.col, csr.out_degree,
                wedge_budget=peak, n_steps=steps,
            )
            out += np.asarray(part, dtype=np.int64)
        self._record("wedge_bsearch", n_chunks, peak, total,
                     csr.n_directed_edges, resolved=resolved)
        return out

    # -- panel / pallas schedules -------------------------------------------

    def _count_panel(self, csr: OrientedCSR, *, pallas: bool) -> int:
        if pallas:
            from repro.kernels.triangle_count import ops as tc_ops

            intersect = lambda a, b: tc_ops.intersect_count(a, b)
        else:
            intersect = panel_intersect_count
        budget = self.max_wedge_chunk
        buckets = bucketize_edges(csr, self.widths)
        partials = []
        n_chunks = 0
        peak = 0
        for width, idx in buckets.items():
            per = len(idx) if budget is None else max(1, int(budget) // width)
            n_slices = -(-len(idx) // per)
            for s in range(0, len(idx), per):
                sl = idx[s : s + per]
                pad = per - len(sl) if n_slices > 1 else 0
                padded = np.concatenate([sl, np.full(pad, -1, np.int32)]) if pad else sl
                a, b, _, _ = gather_panels(
                    csr, jnp.asarray(padded.astype(np.int32)), width
                )
                partials.append(intersect(a, b))
                n_chunks += 1
                peak = max(peak, a.shape[0] * width)
        out_deg = np.asarray(csr.out_degree)
        total = int(out_deg[np.asarray(csr.src)].astype(np.int64).sum())
        self._record("pallas" if pallas else "panel", n_chunks, peak, total,
                     csr.n_directed_edges)
        return accumulate_partials(partials)

    # -- distributed schedule -----------------------------------------------

    def _count_distributed(self, csr: OrientedCSR) -> int:
        from .distributed import count_triangles_distributed_csr

        stats: dict = {}
        total = count_triangles_distributed_csr(
            csr, self.mesh,
            shorter_side=self.shorter_side,
            max_wedge_chunk=self.max_wedge_chunk,
            stats_out=stats,
        )
        out_deg = np.asarray(csr.out_degree)
        total_wedges = int(out_deg[np.asarray(csr.src)].astype(np.int64).sum())
        self._record(
            "distributed",
            stats.get("n_chunks", 1),
            stats.get("peak_wedge_buffer", 0),
            total_wedges,
            csr.n_directed_edges,
        )
        return total
