"""Distributed triangle counting (paper §III-E scaled to a 512-chip mesh).

The paper's multi-GPU scheme: preprocess once, replicate the CSR arrays to
every device, partition the *edge list*, reduce partial counts.  We keep
that exact structure under ``shard_map``:

* the oriented CSR (``row_offsets``, ``col``, ``out_degree``) is replicated
  (it is the read-only "texture" data of the kernel),
* the directed edge list is **striped round-robin** across every mesh axis
  — the same modulo-striping the paper uses to assign edges to threads
  (§III-C), which statistically balances the wedge workload under skewed
  degree distributions,
* each shard expands its edges into wedge candidates and closes them with
  the batched binary search from :mod:`repro.core.count`,
* partial counts meet in a single ``psum`` (the paper's final
  ``thrust::reduce``).

The counting step is Amdahl-free; preprocessing is replicated (as in the
paper, where it runs on one GPU).  §Perf in EXPERIMENTS.md quantifies the
preprocessing fraction exactly as the paper's §III-E does.
"""
from __future__ import annotations

import functools
import inspect
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
try:  # jax ≥ 0.6 exports shard_map at top level
    from jax import shard_map
except ImportError:  # jax 0.4.x keeps it under jax.experimental
    from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .count import _batched_contains, _batched_search, segmented_int32_sum
from .preprocess import OrientedCSR, preprocess
from repro.distributed.compression import ensure_fits_int32

__all__ = [
    "stripe_edges",
    "plan_striped_chunks",
    "make_distributed_count_fn",
    "make_distributed_panel_count_fn",
    "striped_workload_fn",
    "count_triangles_distributed",
    "count_triangles_distributed_csr",
    "count_triangles_distributed_slabs",
    "count_triangles_distributed_panel",
    "oriented_csr_from_slabs",
]

# jax renamed shard_map's replication-check kwarg; the support kernel's
# all_gather defeats static replication inference either way, so pass
# whichever this version accepts with False
_CHECK_KW = (
    "check_vma"
    if "check_vma" in inspect.signature(shard_map).parameters
    else "check_rep"
)


def stripe_edges(csr: OrientedCSR, n_shards: int, shorter_side: bool = False):
    """Round-robin stripe directed edges into ``(n_shards, e_per_shard)``.

    Shard ``s`` receives directed edges ``s, s + S, s + 2S, …`` (−1 padded),
    mirroring the paper's thread-striping.  Returns host arrays
    ``(src_sh, dst_sh, wedges_per_shard_max)``.

    ``shorter_side`` sizes the wedge buffer for the §Perf variant that
    enumerates candidates from the *smaller* endpoint list.
    """
    src = np.asarray(csr.src)
    dst = np.asarray(csr.col)
    out_deg = np.asarray(csr.out_degree)
    m = src.shape[0]
    e_per = -(-m // n_shards)
    pad = e_per * n_shards - m
    src_p = np.concatenate([src, np.full(pad, -1, np.int32)])
    dst_p = np.concatenate([dst, np.full(pad, -1, np.int32)])
    # reshape(e_per, S).T puts edge i on shard i % S — round-robin striping
    src_sh = np.ascontiguousarray(src_p.reshape(e_per, n_shards).T)
    dst_sh = np.ascontiguousarray(dst_p.reshape(e_per, n_shards).T)
    reps = np.where(src_p >= 0, out_deg[np.maximum(src_p, 0)], 0)
    if shorter_side:
        reps_v = np.where(dst_p >= 0, out_deg[np.maximum(dst_p, 0)], 0)
        reps = np.minimum(reps, reps_v)
    w_per_shard = reps.reshape(e_per, n_shards).sum(axis=0)
    return src_sh, dst_sh, int(w_per_shard.max()) if m else 1


def plan_striped_chunks(
    src_sh: np.ndarray,
    out_deg: np.ndarray,
    budget: int | None,
    dst_sh: np.ndarray | None = None,
):
    """Partition the striped per-shard edge axis under a wedge budget.

    ``src_sh`` is the ``(n_shards, e_per)`` striped source array from
    :func:`stripe_edges` (−1 padded).  Returns ``(bounds, eff)`` where
    each column slice ``[start, end)`` in ``bounds`` keeps *every*
    shard's wedge-buffer requirement ≤ ``eff``, and
    ``eff = max(budget, max single-edge fan-out)`` (a chunk must hold at
    least one whole edge per shard).  With ``budget=None`` the whole axis
    is one chunk sized to the worst shard — the unchunked behavior.

    Pass ``dst_sh`` for the shorter-side variant: fan-outs are then
    ``min(deg⁺(u), deg⁺(v))``, matching what the kernel enumerates, so
    the budget is not over-reserved from the src side alone.
    """
    out_deg = np.asarray(out_deg)
    reps = np.where(src_sh >= 0, out_deg[np.maximum(src_sh, 0)], 0).astype(np.int64)
    if dst_sh is not None:
        reps_v = np.where(dst_sh >= 0, out_deg[np.maximum(dst_sh, 0)], 0).astype(np.int64)
        reps = np.minimum(reps, reps_v)
    e_per = src_sh.shape[1]
    per_shard_total = reps.sum(axis=1)
    if e_per == 0:
        return [(0, 0)], 1
    if budget is None or budget >= int(per_shard_total.max()):
        return [(0, e_per)], max(int(per_shard_total.max()), 1)
    eff = max(int(budget), int(reps.max()), 1)
    cum = np.cumsum(reps, axis=1)  # (S, e_per) per-shard running wedge load
    bounds = []
    start = 0
    while start < e_per:
        base = cum[:, start - 1] if start else np.zeros(cum.shape[0], np.int64)
        # furthest end each shard tolerates; the chunk ends at the minimum
        ends = np.array(
            [np.searchsorted(cum[s], base[s] + eff, side="right") for s in range(cum.shape[0])]
        )
        end = max(int(ends.min()), start + 1)
        bounds.append((start, end))
        start = end
    return bounds, eff


def make_distributed_count_fn(
    mesh: Mesh,
    wedge_budget: int,
    n_search_steps: int,
    axis_names: Sequence[str] | None = None,
    shorter_side: bool = False,
):
    """Build the jitted sharded counting step.

    ``wedge_budget`` is the per-shard wedge-buffer length (static), computed
    by :func:`stripe_edges`; ``n_search_steps`` bounds the binary search.
    Edge shards live on the product of every mesh axis; the CSR is
    replicated.  Returns ``f(src_sh, dst_sh, row_offsets, col, out_degree)
    -> per-shard partial counts, (n_shards, n_segments) int32`` where each
    partial covers one 2²⁰-slot segment of the shard's wedge buffer — a
    segment sum never exceeds 2²⁰, so int32 stays safe even when a shard
    closes ≥ 2³¹ wedges in one launch; callers reduce in uint64 on host.

    ``shorter_side`` (§Perf): enumerate wedge candidates from the *smaller*
    of N⁺(u), N⁺(v) and binary-search the larger — |N⁺(u) ∩ N⁺(v)| is
    symmetric, so the count is identical while the probe count drops from
    Σ deg⁺(u) to Σ min(deg⁺(u), deg⁺(v)).
    """
    axes = tuple(axis_names or mesh.axis_names)

    def shard_body(src_e, dst_e, row_offsets, col, out_deg):
        src_e = src_e.reshape(-1)
        dst_e = dst_e.reshape(-1)
        m_local = src_e.shape[0]
        valid_e = src_e >= 0
        safe_src = jnp.maximum(src_e, 0)
        safe_dst = jnp.maximum(dst_e, 0)
        if shorter_side:
            du = out_deg[safe_src]
            dv = out_deg[safe_dst]
            swap = dv < du
            enum_v = jnp.where(swap, safe_dst, safe_src)   # enumerate this list
            probe_v = jnp.where(swap, safe_src, safe_dst)  # search in this one
            reps = jnp.where(valid_e, jnp.minimum(du, dv), 0)
        else:
            enum_v = safe_src
            probe_v = safe_dst
            reps = jnp.where(valid_e, out_deg[safe_src], 0)
        starts = jnp.cumsum(reps) - reps
        edge_id = jnp.repeat(
            jnp.arange(m_local, dtype=jnp.int32),
            reps,
            total_repeat_length=wedge_budget,
        )
        pos = jnp.arange(wedge_budget, dtype=jnp.int32) - starts[edge_id]
        valid = (pos >= 0) & (pos < reps[edge_id])
        u = enum_v[edge_id]
        v = probe_v[edge_id]
        w_idx = jnp.clip(row_offsets[u] + pos, 0, col.shape[0] - 1)
        w = col[w_idx]
        found = _batched_contains(
            col, row_offsets[v], row_offsets[v + 1], w, n_search_steps
        )
        partial = segmented_int32_sum(found & valid)
        return partial.reshape((1,) * len(axes) + (-1,))

    edge_spec = P(axes)  # edge-shard dim split over the flattened mesh
    rep = P()
    f = shard_map(
        shard_body,
        mesh=mesh,
        in_specs=(edge_spec, edge_spec, rep, rep, rep),
        out_specs=P(*axes, None),
    )
    return jax.jit(f)


@functools.lru_cache(maxsize=None)
def striped_workload_fn(
    mesh: Mesh,
    kind: str,
    wedge_budget: int,
    n_search_steps: int,
    n_out: int = 0,
    shorter_side: bool = False,
    narrow_wire: bool = False,
):
    """Build (and cache) the jitted striped kernel for one workload kind.

    The §III-E scheme generalized beyond the scalar count: every shard
    expands/closes wedges for its round-robin edge stripe, then the
    partials merge by the collective each workload needs —

    ``"count"``
        per-shard segmented int32 partials, no collective (the host
        reduces in uint64, as in :func:`make_distributed_count_fn`);
    ``"per_node"``
        each shard scatters its hits to the triangle's three vertices in
        a local ``(n_out,)`` array and the shards ``psum`` — the output
        is the replicated exact per-node incidence of the chunk;
    ``"support"``
        two merges.  Arm ``(u, w)`` and closure ``(v, w)`` contributions
        land on *global* directed-edge (``col``) indices, so they psum
        like per-node.  The base ``(u, v)`` contribution belongs to the
        shard's own stripe: each shard reduces it per local edge column,
        the ``(cols,)`` vectors ride a delta-compressed ``all_gather``
        (:func:`repro.distributed.compression.compressed_all_gather_int32`,
        uint16 wire when ``narrow_wire``), and the gathered ``(S, cols)``
        block scatters onto stable global edge ids
        ``(chunk_start + c)·S + s`` — the inverse of the round-robin
        striping, independent of which device computed what.

    Signature of the returned jitted fn::

        f(src_sh, dst_sh, chunk_start, row_offsets, col, out_degree)

    with ``src_sh``/``dst_sh`` the −1-padded ``(S, cols)`` striped chunk
    (sharded over every mesh axis), ``chunk_start`` a traced int32 column
    offset (no recompile per chunk) and the CSR replicated.  Results are
    bit-identical to the single-device kernels: same wedge enumeration,
    same closure, integer scatters are order-free.

    Cached by ``functools.lru_cache`` so shape-stable callers (the truss
    peel's pow2-bucketed rounds, the incremental probes) reuse one
    compiled kernel per (kind, budget, steps, n_out) across backend
    instances — compiles stay O(log m) per decomposition.
    """
    if kind not in ("count", "per_node", "support"):
        raise ValueError(f"unknown striped workload kind {kind!r}")
    from repro.distributed.compression import compressed_all_gather_int32

    axes = tuple(mesh.axis_names)
    n_shards = int(np.prod(mesh.devices.shape))

    def shard_body(src_e, dst_e, chunk_start, row_offsets, col, out_deg):
        src_e = src_e.reshape(-1)
        dst_e = dst_e.reshape(-1)
        cols = src_e.shape[0]
        valid_e = src_e >= 0
        safe_src = jnp.maximum(src_e, 0)
        safe_dst = jnp.maximum(dst_e, 0)
        if shorter_side:
            du = out_deg[safe_src]
            dv = out_deg[safe_dst]
            swap = dv < du
            enum_v = jnp.where(swap, safe_dst, safe_src)
            probe_v = jnp.where(swap, safe_src, safe_dst)
            reps = jnp.where(valid_e, jnp.minimum(du, dv), 0)
        else:
            enum_v = safe_src
            probe_v = safe_dst
            reps = jnp.where(valid_e, out_deg[safe_src], 0)
        starts = jnp.cumsum(reps) - reps
        edge_id = jnp.repeat(
            jnp.arange(cols, dtype=jnp.int32), reps,
            total_repeat_length=wedge_budget,
        )
        pos = jnp.arange(wedge_budget, dtype=jnp.int32) - starts[edge_id]
        valid = (pos >= 0) & (pos < reps[edge_id])
        u = enum_v[edge_id]
        v = probe_v[edge_id]
        w_idx = jnp.clip(row_offsets[u] + pos, 0, col.shape[0] - 1)
        w = col[w_idx]
        found, vw_idx = _batched_search(
            col, row_offsets[v], row_offsets[v + 1], w, n_search_steps
        )
        hit = found & valid
        if kind == "count":
            partial = segmented_int32_sum(hit)
            return partial.reshape((1,) * len(axes) + (-1,))
        inc = hit.astype(jnp.int32)
        if kind == "per_node":
            # w may read a padded/sentinel col slot on non-hit lanes; its
            # inc is 0 and out-of-range scatter indices drop under jit
            out = jnp.zeros((n_out,), jnp.int32)
            out = out.at[u].add(inc, mode="drop")
            out = out.at[v].add(inc, mode="drop")
            out = out.at[w].add(inc, mode="drop")
            return jax.lax.psum(out, axes)
        # support: arm/closure hit global col indices — psum them; the
        # base contribution stays stripe-local until the all_gather
        ac = jnp.zeros((n_out,), jnp.int32)
        ac = ac.at[w_idx].add(inc, mode="drop")
        ac = ac.at[vw_idx].add(inc, mode="drop")
        ac = jax.lax.psum(ac, axes)
        base = jnp.zeros((cols,), jnp.int32).at[edge_id].add(inc, mode="drop")
        base_all = compressed_all_gather_int32(base, axes, narrow=narrow_wire)
        # stripe-offset scatter: column c of gathered stripe s is global
        # query edge (chunk_start + c)·S + s (inverse round-robin); padded
        # tail ids land past n_out with zero base and drop
        c = jnp.arange(cols, dtype=jnp.int32)
        s = jnp.arange(n_shards, dtype=jnp.int32)
        gid = (chunk_start + c)[None, :] * n_shards + s[:, None]
        return ac.at[gid.reshape(-1)].add(base_all.reshape(-1), mode="drop")

    edge_spec = P(axes)
    rep = P()
    out_spec = P(*axes, None) if kind == "count" else P()
    f = shard_map(
        shard_body,
        mesh=mesh,
        in_specs=(edge_spec, edge_spec, rep, rep, rep, rep),
        out_specs=out_spec,
        **{_CHECK_KW: False},
    )
    return jax.jit(f)


def make_distributed_panel_count_fn(
    mesh: Mesh,
    edges_per_shard_by_width: dict[int, int],
    axis_names: Sequence[str] | None = None,
):
    """§Perf: distributed *panel* schedule — the Pallas kernel's dataflow.

    Instead of ``log₂(deg_max)`` random gathers per wedge probe, each edge
    streams both endpoint neighbor panels exactly once and closes the
    intersection with an equality-tile reduction (compares stay in
    registers/VMEM).  Edges are bucketed by panel width; the per-shard
    bucket sizes are static.  Takes per-width striped ``(n_shards, e_w)``
    src/dst arrays + the replicated CSR; returns per-shard int32 partials.
    """
    axes = tuple(axis_names or mesh.axis_names)
    widths = sorted(edges_per_shard_by_width)

    def shard_body(*args):
        n_w = len(widths)
        srcs = args[:n_w]
        dsts = args[n_w : 2 * n_w]
        row_offsets, col, out_deg = args[2 * n_w :]
        total = jnp.int32(0)
        m_dir = col.shape[0]
        for width, src_e, dst_e in zip(widths, srcs, dsts):
            src_e = src_e.reshape(-1)
            dst_e = dst_e.reshape(-1)
            valid_e = src_e >= 0
            u = jnp.maximum(src_e, 0)
            v = jnp.maximum(dst_e, 0)
            lane = jnp.arange(width, dtype=jnp.int32)

            def panel(base, length):
                idx = jnp.clip(base[:, None] + lane[None, :], 0, m_dir - 1)
                vals = col[idx]
                return jnp.where(lane[None, :] < length[:, None], vals, -1)

            a = panel(row_offsets[u], out_deg[u])   # (E_w, width)
            b = panel(row_offsets[v], out_deg[v])
            eq = (a[:, :, None] == b[:, None, :]) & (a[:, :, None] >= 0)
            counts = jnp.sum(eq, axis=(1, 2), dtype=jnp.int32)
            total = total + jnp.sum(
                jnp.where(valid_e, counts, 0), dtype=jnp.int32
            )
        return total.reshape((1,) * len(axes))

    edge_spec = P(axes)
    rep = P()
    in_specs = tuple([edge_spec] * (2 * len(widths)) + [rep, rep, rep])
    f = shard_map(shard_body, mesh=mesh, in_specs=in_specs, out_specs=P(*axes))
    return jax.jit(f), widths


def count_triangles_distributed_csr(
    csr: OrientedCSR,
    mesh: Mesh,
    shorter_side: bool = False,
    max_wedge_chunk: int | None = None,
    stats_out: dict | None = None,
) -> int:
    """Sharded count from a prebuilt CSR (stripe → chunk → sharded count).

    ``max_wedge_chunk`` bounds every shard's wedge buffer: the striped
    edge axis is sliced into column chunks (:func:`plan_striped_chunks`),
    each padded to a fixed width so one jitted ``shard_map`` kernel is
    reused across chunks.  This is the engine's memory-bounded
    partitioning composed with the paper's §III-E striping.  Partial
    counts accumulate on host in uint64.
    """
    n_shards = int(np.prod(mesh.devices.shape))
    src_sh, dst_sh, _ = stripe_edges(csr, n_shards, shorter_side=shorter_side)
    max_deg = int(np.asarray(csr.out_degree).max()) if csr.n_nodes else 0
    steps = max(1, int(np.ceil(np.log2(max_deg + 1)))) if max_deg else 1
    bounds, eff = plan_striped_chunks(
        src_sh,
        np.asarray(csr.out_degree),
        max_wedge_chunk,
        dst_sh=dst_sh if shorter_side else None,
    )
    cols_per_chunk = max(end - start for start, end in bounds)
    count_fn = make_distributed_count_fn(mesh, eff, steps, shorter_side=shorter_side)
    rep_sharding = NamedSharding(mesh, P())
    edge_sharding = NamedSharding(mesh, P(mesh.axis_names))
    csr_dev = (
        jax.device_put(np.asarray(csr.row_offsets), rep_sharding),
        jax.device_put(np.asarray(csr.col), rep_sharding),
        jax.device_put(np.asarray(csr.out_degree), rep_sharding),
    )
    total = np.uint64(0)
    for start, end in bounds:
        pad = cols_per_chunk - (end - start)
        s = src_sh[:, start:end]
        d = dst_sh[:, start:end]
        if pad:
            fill = np.full((n_shards, pad), -1, np.int32)
            s = np.concatenate([s, fill], axis=1)
            d = np.concatenate([d, fill], axis=1)
        partials = count_fn(
            jax.device_put(np.ascontiguousarray(s), edge_sharding),
            jax.device_put(np.ascontiguousarray(d), edge_sharding),
            *csr_dev,
        )
        total += np.uint64(np.asarray(partials).astype(np.uint64).sum())
    if stats_out is not None:
        stats_out["n_chunks"] = len(bounds)
        stats_out["peak_wedge_buffer"] = eff
        stats_out["cols_per_chunk"] = cols_per_chunk
    return int(total)


def count_triangles_distributed(
    edges,
    mesh: Mesh,
    n_nodes: int | None = None,
    shorter_side: bool = False,
    max_wedge_chunk: int | None = None,
) -> int:
    """End-to-end distributed count (preprocess → stripe → sharded count)."""
    edges = np.asarray(edges)
    if edges.size == 0:
        return 0
    if n_nodes is None:
        n_nodes = int(edges.max()) + 1
    csr = preprocess(jnp.asarray(edges), n_nodes=n_nodes)
    return count_triangles_distributed_csr(
        csr, mesh, shorter_side=shorter_side, max_wedge_chunk=max_wedge_chunk
    )


def count_triangles_distributed_panel(
    edges,
    mesh: Mesh,
    n_nodes: int | None = None,
    widths: tuple[int, ...] = (16, 64, 256, 1024, 4096, 16384),
) -> int:
    """End-to-end distributed count via the panel (Pallas-dataflow) schedule."""
    edges = np.asarray(edges)
    if edges.size == 0:
        return 0
    if n_nodes is None:
        n_nodes = int(edges.max()) + 1
    csr = preprocess(jnp.asarray(edges), n_nodes=n_nodes)
    n_shards = int(np.prod(mesh.devices.shape))
    src = np.asarray(csr.src)
    dst = np.asarray(csr.col)
    out_deg = np.asarray(csr.out_degree)
    need = np.maximum(out_deg[src], out_deg[dst])
    per_width_arrays = {}
    lo = 0
    for w in widths:
        idx = np.nonzero((need > lo) & (need <= w))[0]
        lo = w
        e_per = max(1, -(-idx.size // n_shards))
        pad = e_per * n_shards - idx.size
        s = np.concatenate([src[idx], np.full(pad, -1, np.int32)])
        d = np.concatenate([dst[idx], np.full(pad, -1, np.int32)])
        per_width_arrays[w] = (
            np.ascontiguousarray(s.reshape(e_per, n_shards).T.astype(np.int32)),
            np.ascontiguousarray(d.reshape(e_per, n_shards).T.astype(np.int32)),
        )
    if int(need.max() if need.size else 0) > widths[-1]:
        raise ValueError("widths too small for max out-degree")
    fn, ws = make_distributed_panel_count_fn(
        mesh, {w: per_width_arrays[w][0].shape[1] for w in widths}
    )
    rep_sh = NamedSharding(mesh, P())
    edge_sh = NamedSharding(mesh, P(mesh.axis_names))
    args = [jax.device_put(per_width_arrays[w][0], edge_sh) for w in ws]
    args += [jax.device_put(per_width_arrays[w][1], edge_sh) for w in ws]
    args += [
        jax.device_put(np.asarray(csr.row_offsets), rep_sh),
        jax.device_put(np.asarray(csr.col), rep_sh),
        jax.device_put(np.asarray(csr.out_degree), rep_sh),
    ]
    partials = fn(*args)
    return int(np.asarray(partials).astype(np.uint64).sum())


def oriented_csr_from_slabs(slabs) -> OrientedCSR:
    """Orient a sharded ``.tricsr`` cache (per-stripe slab views) host-side.

    ``slabs`` are :class:`repro.graphs.io.CSRStripe` views (duck-typed:
    anything with ``row_offsets``/``col``/``node_lo``/``node_hi``/
    ``stripe_index``), each memory-mapping only its node-range slab of
    the undirected CSR.  Degrees come from the concatenated row offsets
    (tiny — one int64 per node); each slab is then oriented independently
    with the engine's forward rule ``(du < dv) | ((du == dv) & (u < v))``
    and the kept edges concatenated.  Because slabs cover contiguous
    node ranges and each slab's CSR is (src, dst)-sorted, the concat *is*
    the globally sorted oriented edge list — bit-identical to
    ``oriented_from_undirected_csr`` of the assembled CSR, without ever
    materializing the full ``col`` array on one host.
    """
    slabs = sorted(slabs, key=lambda s: int(s.stripe_index))
    if not slabs:
        raise ValueError("no slabs given")
    lo = 0
    for s in slabs:
        if int(s.node_lo) != lo:
            raise ValueError(
                f"slab {s.stripe_index} starts at node {s.node_lo}, expected {lo}"
            )
        lo = int(s.node_hi)
    n = lo
    row_full = np.concatenate(
        [np.asarray(s.row_offsets[:-1]) for s in slabs]
        + [np.asarray(slabs[-1].row_offsets[-1:])]
    ).astype(np.int64)
    deg = np.diff(row_full).astype(np.int32)
    src_parts, col_parts = [], []
    for s in slabs:
        lens = np.diff(np.asarray(s.row_offsets)).astype(np.int64)
        u = np.repeat(
            np.arange(int(s.node_lo), int(s.node_hi), dtype=np.int32), lens
        )
        v = np.asarray(s.col, dtype=np.int32)
        du, dv = deg[u], deg[v]
        keep = (du < dv) | ((du == dv) & (u < v))
        src_parts.append(u[keep])
        col_parts.append(v[keep])
    src = np.concatenate(src_parts) if src_parts else np.zeros(0, np.int32)
    col = np.concatenate(col_parts) if col_parts else np.zeros(0, np.int32)
    ensure_fits_int32(src.shape[0], "directed edge count (slab assembly offsets)")
    row = np.searchsorted(src, np.arange(n + 1, dtype=np.int64)).astype(np.int32)
    out_degree = (row[1:] - row[:-1]).astype(np.int32)
    return OrientedCSR(
        row_offsets=jnp.asarray(row),
        src=jnp.asarray(src),
        col=jnp.asarray(col),
        out_degree=jnp.asarray(out_degree),
        degree=jnp.asarray(deg),
    )


def count_triangles_distributed_slabs(
    slabs,
    mesh: Mesh,
    *,
    shorter_side: bool = False,
    max_wedge_chunk: int | None = None,
    stats_out: dict | None = None,
) -> int:
    """§III-E count straight from sharded ``.tricsr`` slab views.

    Each device's host memmaps only its slab during orientation
    (:func:`oriented_csr_from_slabs`); the oriented CSR is then
    replicated — the paper's scheme — and counted with the striped
    kernels under the usual wedge budget.
    """
    csr = oriented_csr_from_slabs(slabs)
    if int(np.asarray(csr.src).shape[0]) == 0:
        if stats_out is not None:
            stats_out.update(n_chunks=0, peak_wedge_buffer=0, cols_per_chunk=0)
        return 0
    return count_triangles_distributed_csr(
        csr, mesh,
        shorter_side=shorter_side,
        max_wedge_chunk=max_wedge_chunk,
        stats_out=stats_out,
    )
