"""Distributed triangle counting (paper §III-E scaled to a 512-chip mesh).

The paper's multi-GPU scheme: preprocess once, replicate the CSR arrays to
every device, partition the *edge list*, reduce partial counts.  We keep
that exact structure under ``shard_map``:

* the oriented CSR (``row_offsets``, ``col``, ``out_degree``) is replicated
  (it is the read-only "texture" data of the kernel),
* the directed edge list is **striped round-robin** across every mesh axis
  — the same modulo-striping the paper uses to assign edges to threads
  (§III-C), which statistically balances the wedge workload under skewed
  degree distributions,
* each shard expands its edges into wedge candidates and closes them with
  the batched binary search from :mod:`repro.core.count`,
* partial counts meet in a single ``psum`` (the paper's final
  ``thrust::reduce``).

The counting step is Amdahl-free; preprocessing is replicated (as in the
paper, where it runs on one GPU).  §Perf in EXPERIMENTS.md quantifies the
preprocessing fraction exactly as the paper's §III-E does.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .count import _batched_contains
from .preprocess import OrientedCSR, preprocess

__all__ = [
    "stripe_edges",
    "make_distributed_count_fn",
    "make_distributed_panel_count_fn",
    "count_triangles_distributed",
    "count_triangles_distributed_panel",
]


def stripe_edges(csr: OrientedCSR, n_shards: int, shorter_side: bool = False):
    """Round-robin stripe directed edges into ``(n_shards, e_per_shard)``.

    Shard ``s`` receives directed edges ``s, s + S, s + 2S, …`` (−1 padded),
    mirroring the paper's thread-striping.  Returns host arrays
    ``(src_sh, dst_sh, wedges_per_shard_max)``.

    ``shorter_side`` sizes the wedge buffer for the §Perf variant that
    enumerates candidates from the *smaller* endpoint list.
    """
    src = np.asarray(csr.src)
    dst = np.asarray(csr.col)
    out_deg = np.asarray(csr.out_degree)
    m = src.shape[0]
    e_per = -(-m // n_shards)
    pad = e_per * n_shards - m
    src_p = np.concatenate([src, np.full(pad, -1, np.int32)])
    dst_p = np.concatenate([dst, np.full(pad, -1, np.int32)])
    # reshape(e_per, S).T puts edge i on shard i % S — round-robin striping
    src_sh = np.ascontiguousarray(src_p.reshape(e_per, n_shards).T)
    dst_sh = np.ascontiguousarray(dst_p.reshape(e_per, n_shards).T)
    reps = np.where(src_p >= 0, out_deg[np.maximum(src_p, 0)], 0)
    if shorter_side:
        reps_v = np.where(dst_p >= 0, out_deg[np.maximum(dst_p, 0)], 0)
        reps = np.minimum(reps, reps_v)
    w_per_shard = reps.reshape(e_per, n_shards).sum(axis=0)
    return src_sh, dst_sh, int(w_per_shard.max()) if m else 1


def make_distributed_count_fn(
    mesh: Mesh,
    wedge_budget: int,
    n_search_steps: int,
    axis_names: Sequence[str] | None = None,
    shorter_side: bool = False,
):
    """Build the jitted sharded counting step.

    ``wedge_budget`` is the per-shard wedge-buffer length (static), computed
    by :func:`stripe_edges`; ``n_search_steps`` bounds the binary search.
    Edge shards live on the product of every mesh axis; the CSR is
    replicated.  Returns ``f(src_sh, dst_sh, row_offsets, col, out_degree)
    -> per-shard partial counts (n_shards,) int32``.

    ``shorter_side`` (§Perf): enumerate wedge candidates from the *smaller*
    of N⁺(u), N⁺(v) and binary-search the larger — |N⁺(u) ∩ N⁺(v)| is
    symmetric, so the count is identical while the probe count drops from
    Σ deg⁺(u) to Σ min(deg⁺(u), deg⁺(v)).
    """
    axes = tuple(axis_names or mesh.axis_names)

    def shard_body(src_e, dst_e, row_offsets, col, out_deg):
        src_e = src_e.reshape(-1)
        dst_e = dst_e.reshape(-1)
        m_local = src_e.shape[0]
        valid_e = src_e >= 0
        safe_src = jnp.maximum(src_e, 0)
        safe_dst = jnp.maximum(dst_e, 0)
        if shorter_side:
            du = out_deg[safe_src]
            dv = out_deg[safe_dst]
            swap = dv < du
            enum_v = jnp.where(swap, safe_dst, safe_src)   # enumerate this list
            probe_v = jnp.where(swap, safe_src, safe_dst)  # search in this one
            reps = jnp.where(valid_e, jnp.minimum(du, dv), 0)
        else:
            enum_v = safe_src
            probe_v = safe_dst
            reps = jnp.where(valid_e, out_deg[safe_src], 0)
        starts = jnp.cumsum(reps) - reps
        edge_id = jnp.repeat(
            jnp.arange(m_local, dtype=jnp.int32),
            reps,
            total_repeat_length=wedge_budget,
        )
        pos = jnp.arange(wedge_budget, dtype=jnp.int32) - starts[edge_id]
        valid = (pos >= 0) & (pos < reps[edge_id])
        u = enum_v[edge_id]
        v = probe_v[edge_id]
        w_idx = jnp.clip(row_offsets[u] + pos, 0, col.shape[0] - 1)
        w = col[w_idx]
        found = _batched_contains(
            col, row_offsets[v], row_offsets[v + 1], w, n_search_steps
        )
        partial = jnp.sum(found & valid, dtype=jnp.int32)
        return partial.reshape((1,) * len(axes))

    edge_spec = P(axes)  # edge-shard dim split over the flattened mesh
    rep = P()
    f = shard_map(
        shard_body,
        mesh=mesh,
        in_specs=(edge_spec, edge_spec, rep, rep, rep),
        out_specs=P(*axes),
    )
    return jax.jit(f)


def make_distributed_panel_count_fn(
    mesh: Mesh,
    edges_per_shard_by_width: dict[int, int],
    axis_names: Sequence[str] | None = None,
):
    """§Perf: distributed *panel* schedule — the Pallas kernel's dataflow.

    Instead of ``log₂(deg_max)`` random gathers per wedge probe, each edge
    streams both endpoint neighbor panels exactly once and closes the
    intersection with an equality-tile reduction (compares stay in
    registers/VMEM).  Edges are bucketed by panel width; the per-shard
    bucket sizes are static.  Takes per-width striped ``(n_shards, e_w)``
    src/dst arrays + the replicated CSR; returns per-shard int32 partials.
    """
    axes = tuple(axis_names or mesh.axis_names)
    widths = sorted(edges_per_shard_by_width)

    def shard_body(*args):
        n_w = len(widths)
        srcs = args[:n_w]
        dsts = args[n_w : 2 * n_w]
        row_offsets, col, out_deg = args[2 * n_w :]
        total = jnp.int32(0)
        m_dir = col.shape[0]
        for width, src_e, dst_e in zip(widths, srcs, dsts):
            src_e = src_e.reshape(-1)
            dst_e = dst_e.reshape(-1)
            valid_e = src_e >= 0
            u = jnp.maximum(src_e, 0)
            v = jnp.maximum(dst_e, 0)
            lane = jnp.arange(width, dtype=jnp.int32)

            def panel(base, length):
                idx = jnp.clip(base[:, None] + lane[None, :], 0, m_dir - 1)
                vals = col[idx]
                return jnp.where(lane[None, :] < length[:, None], vals, -1)

            a = panel(row_offsets[u], out_deg[u])   # (E_w, width)
            b = panel(row_offsets[v], out_deg[v])
            eq = (a[:, :, None] == b[:, None, :]) & (a[:, :, None] >= 0)
            counts = jnp.sum(eq, axis=(1, 2), dtype=jnp.int32)
            total = total + jnp.sum(
                jnp.where(valid_e, counts, 0), dtype=jnp.int32
            )
        return total.reshape((1,) * len(axes))

    edge_spec = P(axes)
    rep = P()
    in_specs = tuple([edge_spec] * (2 * len(widths)) + [rep, rep, rep])
    f = shard_map(shard_body, mesh=mesh, in_specs=in_specs, out_specs=P(*axes))
    return jax.jit(f), widths


def count_triangles_distributed(
    edges, mesh: Mesh, n_nodes: int | None = None, shorter_side: bool = False
) -> int:
    """End-to-end distributed count (preprocess → stripe → sharded count)."""
    edges = np.asarray(edges)
    if edges.size == 0:
        return 0
    if n_nodes is None:
        n_nodes = int(edges.max()) + 1
    csr = preprocess(jnp.asarray(edges), n_nodes=n_nodes)
    n_shards = int(np.prod(mesh.devices.shape))
    src_sh, dst_sh, w_max = stripe_edges(csr, n_shards, shorter_side=shorter_side)
    max_deg = int(np.asarray(csr.out_degree).max()) if n_nodes else 0
    steps = max(1, int(np.ceil(np.log2(max_deg + 1)))) if max_deg else 1
    count_fn = make_distributed_count_fn(
        mesh, max(w_max, 1), steps, shorter_side=shorter_side
    )
    rep_sharding = NamedSharding(mesh, P())
    partials = count_fn(
        jax.device_put(src_sh, NamedSharding(mesh, P(mesh.axis_names))),
        jax.device_put(dst_sh, NamedSharding(mesh, P(mesh.axis_names))),
        jax.device_put(np.asarray(csr.row_offsets), rep_sharding),
        jax.device_put(np.asarray(csr.col), rep_sharding),
        jax.device_put(np.asarray(csr.out_degree), rep_sharding),
    )
    return int(np.asarray(partials).astype(np.uint64).sum())


def count_triangles_distributed_panel(
    edges,
    mesh: Mesh,
    n_nodes: int | None = None,
    widths: tuple[int, ...] = (16, 64, 256, 1024, 4096, 16384),
) -> int:
    """End-to-end distributed count via the panel (Pallas-dataflow) schedule."""
    edges = np.asarray(edges)
    if edges.size == 0:
        return 0
    if n_nodes is None:
        n_nodes = int(edges.max()) + 1
    csr = preprocess(jnp.asarray(edges), n_nodes=n_nodes)
    n_shards = int(np.prod(mesh.devices.shape))
    src = np.asarray(csr.src)
    dst = np.asarray(csr.col)
    out_deg = np.asarray(csr.out_degree)
    need = np.maximum(out_deg[src], out_deg[dst])
    per_width_arrays = {}
    lo = 0
    for w in widths:
        idx = np.nonzero((need > lo) & (need <= w))[0]
        lo = w
        e_per = max(1, -(-idx.size // n_shards))
        pad = e_per * n_shards - idx.size
        s = np.concatenate([src[idx], np.full(pad, -1, np.int32)])
        d = np.concatenate([dst[idx], np.full(pad, -1, np.int32)])
        per_width_arrays[w] = (
            np.ascontiguousarray(s.reshape(e_per, n_shards).T.astype(np.int32)),
            np.ascontiguousarray(d.reshape(e_per, n_shards).T.astype(np.int32)),
        )
    if int(need.max() if need.size else 0) > widths[-1]:
        raise ValueError("widths too small for max out-degree")
    fn, ws = make_distributed_panel_count_fn(
        mesh, {w: per_width_arrays[w][0].shape[1] for w in widths}
    )
    rep_sh = NamedSharding(mesh, P())
    edge_sh = NamedSharding(mesh, P(mesh.axis_names))
    args = [jax.device_put(per_width_arrays[w][0], edge_sh) for w in ws]
    args += [jax.device_put(per_width_arrays[w][1], edge_sh) for w in ws]
    args += [
        jax.device_put(np.asarray(csr.row_offsets), rep_sh),
        jax.device_put(np.asarray(csr.col), rep_sh),
        jax.device_put(np.asarray(csr.out_degree), rep_sh),
    ]
    partials = fn(*args)
    return int(np.asarray(partials).astype(np.uint64).sum())
