"""Incremental triangle counting over edge streams (batched delta updates).

The engine in :mod:`repro.core.engine` is one-shot: canonicalize, orient,
count.  A serving workload over a *changing* graph cannot afford to
recount 89M edges per update, so :class:`IncrementalTriangleCounter`
maintains the global triangle count and the per-node incidences under
batched ``insert(edges)`` / ``delete(edges)``, touching only the
triangles incident to the updated edges — the batched delta-counting
discipline surveyed by Wang et al. (*A Comparative Study on Exact
Triangle Counting Algorithms on the GPU*, 2018).

How a batch is counted
======================

Let Δ be the batch's undirected edges (deduplicated, self loops dropped,
already-present inserts / never-present deletes filtered out), and let
``G⁻`` / ``G⁺`` be the graph without / with Δ.  A triangle *touched* by
the batch contains ``k ∈ {1, 2, 3}`` Δ-edges, and probing each Δ-edge
``(u, v)`` for common neighbors ``|N(u) ∩ N(v)|`` counts it once per
Δ-edge it contains.  Three probe passes over the same Δ edge list —
against the adjacency of ``G⁺`` (``S⁺``, counts each triangle ``k``
times), of ``G⁻`` (``S⁻``, counts only the ``k = 1`` triangles), and of
Δ alone (``S^Δ``, counts the all-new ``k = 3`` triangles three times) —
pin down the touched-triangle total exactly:

    ΔT  =  S⁻  +  (S⁺ − S⁻ − S^Δ) / 2  +  S^Δ / 3

(the middle term is the ``k = 2`` count — the standard new–new
double-count correction; both divisions are exact).  The identical
combination applied to the per-node scatter outputs yields the per-node
incidence delta, because a touched triangle contributes ``k`` to each of
its three vertices in the ``S⁺`` scatter, ``[k = 1]`` in ``S⁻`` and
``3·[k = 3]`` in ``S^Δ``.  Insertions add ΔT; deletions subtract the
same quantity computed with the roles of ``G⁻``/``G⁺`` swapped.

Every probe pass runs the engine's own chunk kernel
(:func:`repro.core.engine.chunk_per_node_kernel`; each closed wedge
scatters +1 to exactly three vertices, so the hit total that
``chunk_count_kernel`` would compute falls out of the same launch as
``Σ per_node / 3``) on just the **delta wedge workload** —
``Σ_{(u,v) ∈ Δ} min(deg u, deg v)`` candidate slots (shorter-side
enumeration) instead of the full graph's ``Σ deg⁺`` — and honors
``max_wedge_chunk`` through the same
:func:`repro.core.engine.plan_edge_chunks` partitioning, so update
batches obey the same per-launch memory budget as full counts.

Compile stability
=================

A dynamic graph changes array shapes every batch, which would recompile
the jitted chunk kernels on every update.  Shapes fed to the kernels are
therefore bucketed: the adjacency ``col`` array and the node axis pad to
the next power of two, the probe-edge axis pads to the chunk plan's
width rounded to a power of two, and with no explicit budget the wedge
buffer itself rounds up to a power of two (with a budget, the buffer is
the budget — stable by construction).  Steady-state serving therefore
reuses a handful of compiled kernels (see ``launch/serve_graph.py``).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro import obs

from .engine import (
    TriangleCounter,
    WedgeChunk,
    make_backend,
    make_workload,
    next_pow2 as _next_pow2,
    plan_edge_chunks,
    run_workload,
    _DeviceAdj,
)
from repro.distributed.compression import ensure_fits_int32
from repro.graphs.formats import validate_node_ids

__all__ = ["IncrementalTriangleCounter", "UpdateStats"]

# schedules the probe passes can execute; anything else ("auto") keeps
# the wedge chunk kernels, whose shape-stability properties are the
# serving default.  "distributed" additionally needs a mesh — the three
# probes then run the §III-E striped kernels with psum-merged per-node
# partials.
_PROBE_METHODS = ("wedge_bsearch", "panel", "pallas", "distributed")

_MASK32 = np.int64(0xFFFFFFFF)
_COL_PAD = np.int32(2**31 - 1)  # sorted-tail sentinel; never inside a row


def _pack(u: np.ndarray, v: np.ndarray) -> np.ndarray:
    """Directed edge key u<<32|v (the §III-D2 packed-key representation)."""
    return u.astype(np.int64) << np.int64(32) | v.astype(np.int64)


@dataclasses.dataclass(frozen=True)
class UpdateStats:
    """What the last ``insert``/``delete`` actually did."""

    op: str                  # "insert" | "delete" | "noop"
    n_batch_edges: int       # undirected edges actually applied (post-filter)
    n_probe_launches: int    # chunk-kernel launches across the three probes
    peak_wedge_buffer: int   # largest wedge buffer materialized per launch
    wedge_budget: int | None  # the configured max_wedge_chunk
    delta: int               # signed change in the global triangle count
    probe_method: str = "wedge_bsearch"  # kernel backend the probes ran


class IncrementalTriangleCounter:
    """Exact triangle counts over a dynamic graph, updated in batches.

    Parameters
    ----------
    edges:
        Optional initial edges (any mix of directions/duplicates; self
        loops dropped).  The bootstrap count runs through the batch
        engine (:class:`repro.core.engine.TriangleCounter`), so it is
        memory-bounded exactly like a standalone full count.
    n_nodes:
        Optional node-count floor; the id space also grows automatically
        when a batch introduces larger vertex ids.
    max_wedge_chunk:
        Per-launch wedge-buffer budget (slots) applied to the bootstrap
        *and* to every update batch's probe workload.
    method:
        Engine schedule for the bootstrap count and — when it names one
        of the probe-capable backends (``"wedge_bsearch"``, ``"panel"``,
        ``"pallas"``, ``"distributed"``) — for the three probe passes of
        every update batch as well.  ``"auto"`` keeps the probes on the
        wedge chunk kernels (the serving default: their buffer shapes
        are the most compile-stable under a fixed budget); the
        panel/Pallas backends pow2-pad their bucket slices so
        steady-state serving still reuses a bounded set of compiled
        kernels.
    mesh:
        Device mesh for ``method="distributed"`` (required then,
        ignored otherwise): each probe pass stripes the delta workload
        §III-E-style across the mesh and psum-merges the per-node
        partials — bit-identical to the single-device probes.

    After any update, :attr:`last_update_stats` describes what ran.

    Invariant (the oracle property the tests enforce): after any
    interleaving of ``insert``/``delete`` batches, :attr:`count` equals
    ``TriangleCounter(method="auto").count(self.current_edges())``.
    """

    def __init__(
        self,
        edges=None,
        n_nodes: int | None = None,
        max_wedge_chunk: int | None = None,
        method: str = "auto",
        mesh=None,
    ):
        if max_wedge_chunk is not None and max_wedge_chunk < 1:
            raise ValueError("max_wedge_chunk must be positive")
        if method == "distributed" and mesh is None:
            raise ValueError(
                "method='distributed' needs a mesh= over the participating "
                "devices"
            )
        self.max_wedge_chunk = max_wedge_chunk
        self.mesh = mesh
        self.probe_method = method if method in _PROBE_METHODS else "wedge_bsearch"
        self._backend = make_backend(self.probe_method, mesh=mesh)
        self._n = int(n_nodes) if n_nodes else 0
        self._adj = np.empty(0, np.int64)  # sorted directed keys, both dirs
        self._count = 0
        self._per_node = np.zeros(self._n, np.int64)
        self._deg = np.zeros(self._n, np.int64)
        self.last_update_stats: UpdateStats | None = None
        if hasattr(edges, "decode_block"):
            # compressed CSR bootstrap: decode once, mapped back to
            # *original* ids, so the caller's insert/delete stream keeps
            # speaking its own node names regardless of the on-disk order
            edges = edges.edge_array(original_ids=True)
        elif hasattr(edges, "edge_array"):
            edges = edges.edge_array()  # cached flat CSRGraph
        if edges is not None and np.asarray(edges).size:
            und = self._normalize_batch(edges)
            if und.shape[0]:
                self._grow(int(und.max()) + 1)
                self._adj = np.sort(
                    np.concatenate([_pack(und[:, 0], und[:, 1]),
                                    _pack(und[:, 1], und[:, 0])])
                )
                np.add.at(self._deg, und[:, 0], 1)
                np.add.at(self._deg, und[:, 1], 1)
                tc = TriangleCounter(
                    method=method, max_wedge_chunk=max_wedge_chunk, mesh=mesh
                )
                canon = self.current_edges()
                self._count = tc.count(canon, n_nodes=self._n)
                self._per_node = tc.per_node(canon, n_nodes=self._n).astype(np.int64)

    # -- read API (the serving queries) -------------------------------------

    @property
    def count(self) -> int:
        """Current global triangle count (maintained, O(1) to read)."""
        return self._count

    @property
    def n_nodes(self) -> int:
        return self._n

    @property
    def n_edges(self) -> int:
        """Current undirected edge count."""
        return self._adj.shape[0] // 2

    def per_node(self) -> np.ndarray:
        """Per-vertex triangle incidences (maintained, copied out)."""
        return self._per_node.copy()

    def degrees(self) -> np.ndarray:
        """Current undirected degree histogram (maintained, copied out)."""
        return self._deg.copy()

    def clustering(self) -> np.ndarray:
        """Local clustering coefficients from the maintained state."""
        from .clustering import clustering_from_counts

        return clustering_from_counts(self._per_node, self._deg)

    def transitivity(self) -> float:
        """Global transitivity ratio from the maintained state."""
        from .clustering import transitivity_from_counts

        return transitivity_from_counts(self._count, self._deg)

    def current_edges(self) -> np.ndarray:
        """The live graph as a canonical edge array (both directions)."""
        src = (self._adj >> np.int64(32)).astype(np.int32)
        dst = (self._adj & _MASK32).astype(np.int32)
        return np.stack([src, dst], axis=1)

    # -- snapshot/restore (the serving layer's durability hook) -------------

    def state_dict(self) -> dict[str, np.ndarray]:
        """The complete maintained state as a flat array tree.

        Everything an exact resume needs: the canonical directed-key
        adjacency, the global count, the per-node incidences and the
        degree histogram.  The arrays are copies —
        :class:`repro.checkpoint.CheckpointManager` can write them from
        a background thread while updates keep mutating ``self``.
        """
        return {
            "adj": self._adj.copy(),
            "per_node": self._per_node.copy(),
            "deg": self._deg.copy(),
            "count": np.asarray(self._count, np.int64),
            "n_nodes": np.asarray(self._n, np.int64),
        }

    @classmethod
    def from_state(
        cls,
        state: dict,
        *,
        max_wedge_chunk: int | None = None,
        method: str = "auto",
        mesh=None,
    ):
        """Rebuild a counter from :meth:`state_dict` output, validated.

        The kernel-facing knobs (``max_wedge_chunk``, ``method``,
        ``mesh``) are *not* part of the state — a snapshot taken by a
        wedge-probe service restores cleanly into a pallas-probe one.
        Cross-field consistency is checked (sorted unique adjacency,
        matching array lengths, degrees that re-derive from the
        adjacency) so a logically inconsistent snapshot fails loudly
        here instead of corrupting every later delta.
        """
        n = int(np.asarray(state["n_nodes"]))
        self = cls(
            n_nodes=n or None, max_wedge_chunk=max_wedge_chunk,
            method=method, mesh=mesh,
        )
        adj = np.array(state["adj"], np.int64, copy=True).reshape(-1)
        per_node = np.array(state["per_node"], np.int64, copy=True).reshape(-1)
        deg = np.array(state["deg"], np.int64, copy=True).reshape(-1)
        count = int(np.asarray(state["count"]))
        if adj.shape[0] % 2:
            raise ValueError("adjacency holds both directions: length must be even")
        if adj.shape[0] and np.any(np.diff(adj) <= 0):
            raise ValueError("adjacency keys must be strictly increasing")
        if per_node.shape[0] != n or deg.shape[0] != n:
            raise ValueError(
                f"per_node/deg length ({per_node.shape[0]}/{deg.shape[0]}) "
                f"!= n_nodes ({n})"
            )
        if count < 0:
            raise ValueError(f"negative triangle count {count}")
        src = (adj >> np.int64(32)).astype(np.int64)
        if adj.shape[0] and (src.min() < 0 or src.max() >= n):
            raise ValueError("adjacency source ids outside [0, n_nodes)")
        rederived = np.bincount(src, minlength=n).astype(np.int64)
        if not np.array_equal(rederived, deg):
            raise ValueError("degree histogram does not match the adjacency")
        self._adj = adj
        self._per_node = per_node
        self._deg = deg
        self._count = count
        return self

    # -- update API ---------------------------------------------------------

    def insert(self, edges) -> int:
        """Insert a batch of undirected edges; returns the count delta (≥ 0).

        Self loops, in-batch duplicates and already-present edges are
        ignored, so inserts are idempotent.
        """
        # stats lifecycle: never let a failed update leave the previous
        # batch's stats observable (trilint stats_lifecycle/S1)
        self.last_update_stats = None
        und = self._normalize_batch(edges)
        und = und[~self._member(und)]
        if und.shape[0] == 0:
            self._record("noop", 0, 0, 0, 0)
            return 0
        self._grow(int(und.max()) + 1)
        delta_dir = np.sort(
            np.concatenate([_pack(und[:, 0], und[:, 1]), _pack(und[:, 1], und[:, 0])])
        )
        adj_new = np.insert(self._adj, np.searchsorted(self._adj, delta_dir), delta_dir)
        d_count, d_pn, launches, peak = self._delta_triangles(
            und, adj_without=self._adj, adj_with=adj_new, adj_delta=delta_dir
        )
        self._adj = adj_new
        self._count += d_count
        self._per_node += d_pn
        np.add.at(self._deg, und[:, 0], 1)
        np.add.at(self._deg, und[:, 1], 1)
        self._record("insert", und.shape[0], launches, peak, d_count)
        return d_count

    def delete(self, edges) -> int:
        """Delete a batch of undirected edges; returns the count delta (≤ 0).

        Edges not currently present (including never-inserted ones) are
        ignored, so deletes are idempotent.
        """
        self.last_update_stats = None
        und = self._normalize_batch(edges)
        und = und[self._member(und)]
        if und.shape[0] == 0:
            self._record("noop", 0, 0, 0, 0)
            return 0
        delta_dir = np.sort(
            np.concatenate([_pack(und[:, 0], und[:, 1]), _pack(und[:, 1], und[:, 0])])
        )
        keep = np.ones(self._adj.shape[0], bool)
        keep[np.searchsorted(self._adj, delta_dir)] = False
        adj_rem = self._adj[keep]
        d_count, d_pn, launches, peak = self._delta_triangles(
            und, adj_without=adj_rem, adj_with=self._adj, adj_delta=delta_dir
        )
        self._adj = adj_rem
        self._count -= d_count
        self._per_node -= d_pn
        np.subtract.at(self._deg, und[:, 0], 1)
        np.subtract.at(self._deg, und[:, 1], 1)
        self._record("delete", und.shape[0], launches, peak, -d_count)
        return -d_count

    def apply(self, insert=None, delete=None) -> int:
        """Apply one stream batch (arrivals first, then evictions)."""
        delta = 0
        if insert is not None and np.asarray(insert).size:
            delta += self.insert(insert)
        if delete is not None and np.asarray(delete).size:
            delta += self.delete(delete)
        return delta

    # -- internals ----------------------------------------------------------

    def _record(self, op, n_batch, launches, peak, delta):
        self.last_update_stats = UpdateStats(
            op=op, n_batch_edges=n_batch, n_probe_launches=launches,
            peak_wedge_buffer=peak, wedge_budget=self.max_wedge_chunk,
            delta=delta, probe_method=self.probe_method,
        )

    def _grow(self, n: int) -> None:
        if n > self._n:
            pad = np.zeros(n - self._n, np.int64)
            self._per_node = np.concatenate([self._per_node, pad])
            self._deg = np.concatenate([self._deg, pad])
            self._n = n

    @staticmethod
    def _normalize_batch(edges) -> np.ndarray:
        """Unique undirected (lo, hi) pairs; self loops and dups dropped."""
        edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
        validate_node_ids(edges)  # packed-key adjacency wraps outside [0, 2**31)
        edges = edges[edges[:, 0] != edges[:, 1]]
        if edges.shape[0] == 0:
            return np.empty((0, 2), np.int64)
        lo = np.minimum(edges[:, 0], edges[:, 1])
        hi = np.maximum(edges[:, 0], edges[:, 1])
        keys = np.unique(_pack(lo, hi))
        return np.stack([keys >> np.int64(32), keys & _MASK32], axis=1)

    def _member(self, und: np.ndarray) -> np.ndarray:
        """Membership mask of undirected (lo, hi) pairs in the live graph."""
        if und.shape[0] == 0 or self._adj.shape[0] == 0:
            return np.zeros(und.shape[0], bool)
        keys = _pack(und[:, 0], und[:, 1])
        idx = np.searchsorted(self._adj, keys)
        present = np.zeros(und.shape[0], bool)
        inb = idx < self._adj.shape[0]
        present[inb] = self._adj[idx[inb]] == keys[inb]
        return present

    def _delta_triangles(self, und, *, adj_without, adj_with, adj_delta):
        """Touched-triangle total + per-node deltas via the three probes."""
        pu = und[:, 0].astype(np.int32)
        pv = und[:, 1].astype(np.int32)
        probes = int(pu.shape[0])
        with obs.span("probe.without", cat="incremental", args={"edges": probes}):
            s_wo, p_wo, l1, k1 = self._probe(pu, pv, adj_without)
        with obs.span("probe.with", cat="incremental", args={"edges": probes}):
            s_wi, p_wi, l2, k2 = self._probe(pu, pv, adj_with)
        with obs.span("probe.delta", cat="incremental", args={"edges": probes}):
            s_dl, p_dl, l3, k3 = self._probe(pu, pv, adj_delta)
        two_new = s_wi - s_wo - s_dl
        assert two_new >= 0 and two_new % 2 == 0, (s_wi, s_wo, s_dl)
        assert s_dl % 3 == 0, s_dl
        d_count = s_wo + two_new // 2 + s_dl // 3
        d_pn = p_wo + (p_wi - p_wo - p_dl) // 2 + p_dl // 3
        return d_count, d_pn, l1 + l2 + l3, max(k1, k2, k3)

    def _probe(self, pu, pv, adj):
        """Σ |N(u) ∩ N(v)| over probe edges + its per-node scatter.

        ``adj`` is a sorted directed-key array (the adjacency to close
        wedges against).  Enumerates candidates from the shorter endpoint
        list and closes with the configured kernel backend under the
        ``max_wedge_chunk`` budget.  Returns
        ``(hits, per_node, n_launches, peak_buffer)``.
        """
        n = self._n
        if pu.shape[0] == 0 or adj.shape[0] == 0:
            return 0, np.zeros(n, np.int64), 0, 0
        ensure_fits_int32(adj.shape[0], "probe adjacency size (row offsets)")
        src_k = (adj >> np.int64(32)).astype(np.int64)
        col = (adj & _MASK32).astype(np.int32)
        # node axis pads to a power of two: extra rows are empty, so the
        # kernels see a handful of stable shapes as the graph grows
        n_pad = _next_pow2(n)
        row = np.searchsorted(src_k, np.arange(n_pad + 1, dtype=np.int64)).astype(
            np.int32
        )
        deg = row[1:] - row[:-1]
        # shorter-side enumeration: |N(u) ∩ N(v)| is symmetric, so expand
        # the smaller list and binary-search the larger (§Perf "opt")
        swap = deg[pv] < deg[pu]
        eu = np.where(swap, pv, pu).astype(np.int32)
        ev = np.where(swap, pu, pv).astype(np.int32)
        m_valid = col.shape[0]
        col_pad = _next_pow2(m_valid)
        if col_pad > m_valid:
            col = np.concatenate([col, np.full(col_pad - m_valid, _COL_PAD)])
        if self.probe_method != "wedge_bsearch":
            # panel/pallas/distributed probe: the backend buckets (or
            # stripes) the probe pairs itself and pow2-pads its launch
            # shapes — its own compile-stability discipline
            work = make_workload(row, col, deg, eu, ev)
            per_node, plan = run_workload(
                self._backend, "per_node", work,
                budget=self.max_wedge_chunk, n_out=n_pad, bucket_pow2=True,
            )
            total = int(per_node.sum(dtype=np.int64))
            assert total % 3 == 0, total
            return total // 3, per_node[:n], plan.n_chunks, plan.peak_buffer
        reps = deg[eu].astype(np.int64)
        bounds, eff = plan_edge_chunks(reps, self.max_wedge_chunk)
        if self.max_wedge_chunk is None:
            # no budget to honor → round the one-shot buffer up for
            # compile stability across growing batches
            eff = _next_pow2(eff)
        elif len(bounds) == 1 and eff < self.max_wedge_chunk:
            # same stability trick, capped so the budget stays honored
            eff = min(self.max_wedge_chunk, _next_pow2(eff))
        edges_per_chunk = _next_pow2(max(end - start for start, end in bounds))
        # padded length bounds every row, so the step count is stable per
        # col bucket; overshooting the true ⌈log₂ deg_max⌉ is harmless
        n_steps = max(1, int(np.ceil(np.log2(col_pad + 1))))
        dev_adj = _DeviceAdj(
            jnp.asarray(row), jnp.asarray(col), jnp.asarray(deg), n_steps
        )
        per_node = np.zeros(n_pad, np.int64)
        for start, end in bounds:
            pad = edges_per_chunk - (end - start)
            s, d = eu[start:end], ev[start:end]
            if pad:
                fill = np.full(pad, -1, np.int32)
                s = np.concatenate([s, fill])
                d = np.concatenate([d, fill])
            pn = self._backend.per_node_chunk(
                dev_adj, WedgeChunk(s, d, start, eff), n_pad
            )
            per_node += np.asarray(pn, dtype=np.int64)
        # every hit scatters +1 to exactly u, v and w, so the per-node
        # output carries the hit total — one kernel per chunk does both jobs
        total = int(per_node.sum(dtype=np.int64))
        assert total % 3 == 0, total
        return total // 3, per_node[:n], len(bounds), eff
