"""DOULION-style approximate triangle counting (paper §V comparison).

The paper positions its exact GPU counter against sampling approximations
such as DOULION (Tsourakakis et al., KDD'09): keep every undirected edge
with probability ``p`` and rescale the sparsified count by ``1/p³``.  We
implement it on top of the same exact engine so the accuracy/speed
tradeoff in the paper's §V can be reproduced as a benchmark — and so the
estimator inherits every engine capability: ``method="auto"`` dispatch,
memory-bounded edge partitioning via ``max_wedge_chunk``, uint64-safe
accumulation.  It is also the documented overload fallback for the
streaming service (see ``launch/serve_graph.py``): when update traffic
outruns the exact incremental path, sparsified recounts bound the work.
"""
from __future__ import annotations

import numpy as np

from repro.graphs.formats import validate_node_ids

__all__ = ["count_triangles_doulion"]


def count_triangles_doulion(
    edges: np.ndarray,
    p: float = 0.25,
    seed: int = 0,
    method: str = "auto",
    max_wedge_chunk: int | None = None,
) -> float | int:
    """DOULION estimate of the triangle count.

    Routes through :class:`repro.core.engine.TriangleCounter`, so
    ``method`` accepts every engine schedule (``"auto"`` included) and
    ``max_wedge_chunk`` bounds the device wedge buffer of the sparsified
    count exactly as for a full count.  ``p == 1.0`` keeps every edge:
    the result is the exact count, returned as an ``int``.
    """
    from .engine import TriangleCounter  # late import: engine imports count

    if not 0.0 < p <= 1.0:
        raise ValueError("p must be in (0, 1]")
    edges = np.asarray(edges)
    if edges.size == 0:
        return 0 if p == 1.0 else 0.0
    validate_node_ids(edges)  # wrapped packed keys / int32 casts corrupt silently
    tc = TriangleCounter(method=method, max_wedge_chunk=max_wedge_chunk)
    n_nodes = int(edges.max()) + 1
    if p == 1.0:  # no sparsification — exact count, exact type
        return tc.count(edges, n_nodes=n_nodes)
    rng = np.random.default_rng(seed)
    lo = np.minimum(edges[:, 0], edges[:, 1])
    hi = np.maximum(edges[:, 0], edges[:, 1])
    key = lo.astype(np.int64) << 32 | hi.astype(np.int64)
    uniq, inverse = np.unique(key, return_inverse=True)
    keep_undirected = rng.random(uniq.shape[0]) < p
    kept = edges[keep_undirected[inverse]]
    if kept.size == 0:
        return 0.0
    t = tc.count(kept, n_nodes=n_nodes)
    return float(t) / p**3
