"""DOULION-style approximate triangle counting (paper §V comparison).

The paper positions its exact GPU counter against sampling approximations
such as DOULION (Tsourakakis et al., KDD'09): keep every undirected edge
with probability ``p`` and rescale the sparsified count by ``1/p³``.  We
implement it on top of the same exact core so the accuracy/speed tradeoff
in the paper's §V can be reproduced as a benchmark.
"""
from __future__ import annotations

import numpy as np

from .count import count_triangles

__all__ = ["count_triangles_doulion"]


def count_triangles_doulion(
    edges: np.ndarray, p: float = 0.25, seed: int = 0, method: str = "wedge_bsearch"
) -> float:
    if not 0.0 < p <= 1.0:
        raise ValueError("p must be in (0, 1]")
    edges = np.asarray(edges)
    if edges.size == 0:
        return 0.0
    rng = np.random.default_rng(seed)
    lo = np.minimum(edges[:, 0], edges[:, 1])
    hi = np.maximum(edges[:, 0], edges[:, 1])
    key = lo.astype(np.int64) << 32 | hi.astype(np.int64)
    uniq, inverse = np.unique(key, return_inverse=True)
    keep_undirected = rng.random(uniq.shape[0]) < p
    kept = edges[keep_undirected[inverse]]
    if kept.size == 0:
        return 0.0
    t = count_triangles(kept, n_nodes=int(edges.max()) + 1, method=method)
    return float(t) / p**3
