"""Forward-algorithm preprocessing (paper §II-B, §III-B) in JAX.

Steps (mirroring the paper's eight-step preprocessing, adapted to TPU):

1.  vertex count        — max-reduce over both endpoint columns,
2.  degree histogram    — ``segment_sum`` of ones (the paper reads degrees
                          off the node array; a histogram is the
                          scatter-free TPU equivalent),
3.  forward orientation — keep edge ``(u, v)`` iff ``(deg u, u) ≺ (deg v, v)``
                          lexicographically; exactly ``m/2`` edges survive,
                          which keeps every shape static under ``jit``,
4.  edge sort           — ``jnp.lexsort`` on (dst, src).  XLA lowers this to
                          one variadic sort, the analogue of the paper's
                          packed 64-bit-key ``thrust::sort`` trick (§III-D2),
5.  node array          — ``searchsorted`` of row ids against the sorted
                          sources (replaces the paper's adjacent-difference
                          scatter kernel, which is write-irregular),
6.  unzip               — we keep SoA layout (separate ``src``/``col``
                          arrays) throughout; on TPU SoA is not an
                          optimization but the only sane layout (§III-D1
                          becomes a no-op by construction).

After orientation every out-adjacency list has length ≤ √(2m); this bound
is what makes the fixed-width bucketed kernels in :mod:`repro.core.count`
efficient.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.compression import ensure_fits_int32

__all__ = [
    "OrientedCSR",
    "preprocess",
    "preprocess_host_offload",
    "oriented_from_undirected_csr",
    "oriented_from_compressed",
    "degrees",
]


class OrientedCSR(NamedTuple):
    """Forward-oriented graph in CSR (SoA) layout.

    ``row_offsets[u] : row_offsets[u+1]`` indexes the sorted out-neighbors
    of ``u`` inside ``col``; ``src`` is the repeated row index (the paper's
    "unzipped" edge array: ``(src[p], col[p])`` is directed edge ``p``).
    """

    row_offsets: jax.Array  # (n+1,) int32
    src: jax.Array          # (m_dir,) int32
    col: jax.Array          # (m_dir,) int32
    out_degree: jax.Array   # (n,)   int32
    degree: jax.Array       # (n,)   int32, undirected degrees

    @property
    def n_nodes(self) -> int:
        return self.row_offsets.shape[0] - 1

    @property
    def n_directed_edges(self) -> int:
        return self.col.shape[0]


def degrees(edges: jax.Array, n_nodes: int) -> jax.Array:
    """Undirected degree histogram from a canonical edge array."""
    return jnp.zeros((n_nodes,), jnp.int32).at[edges[:, 0]].add(1)


@functools.partial(jax.jit, static_argnames=("n_nodes",))
def preprocess(edges: jax.Array, n_nodes: int) -> OrientedCSR:
    """Run the full preprocessing phase on device.

    ``edges`` must be a canonical edge array (each undirected edge twice),
    so exactly ``m // 2`` edges survive orientation and all shapes are
    static.
    """
    edges = edges.astype(jnp.int32)
    m = edges.shape[0]
    if m % 2 != 0:
        raise ValueError("canonical edge array must have even length")
    # static shape at trace time: the int32 CSR offsets below must hold m//2
    ensure_fits_int32(m, "canonical edge count (CSR offsets)")
    u, v = edges[:, 0], edges[:, 1]
    deg = degrees(edges, n_nodes)
    # Forward orientation: low (degree, id) endpoint -> high endpoint.
    du, dv = deg[u], deg[v]
    keep = (du < dv) | ((du == dv) & (u < v))
    idx = jnp.nonzero(keep, size=m // 2, fill_value=0)[0]
    su, sv = u[idx], v[idx]
    # Lexicographic sort (dst minor, src major) in one variadic XLA sort —
    # the TPU rendition of the paper's 64-bit packed-key radix sort.
    order = jnp.lexsort((sv, su))
    src = su[order]
    col = sv[order]
    row_offsets = jnp.searchsorted(src, jnp.arange(n_nodes + 1, dtype=jnp.int32)).astype(
        jnp.int32
    )
    out_degree = row_offsets[1:] - row_offsets[:-1]
    return OrientedCSR(row_offsets, src, col, out_degree, deg)


def oriented_from_undirected_csr(row_offsets, col, n_nodes: int | None = None) -> OrientedCSR:
    """Forward-orient a canonical *undirected* CSR without re-sorting.

    This is the ingestion fast path: a cached ``.tricsr`` CSR
    (:class:`repro.graphs.io.CSRGraph`) is already sorted by (src, dst),
    and forward orientation is order-preserving, so the oriented CSR is a
    single boolean filter — no lexsort, no edge-array materialization, no
    re-canonicalization.  Output is bit-identical to
    ``preprocess(csr_to_edge_array(row_offsets, col))``.
    """
    row_offsets = np.asarray(row_offsets)
    col = np.asarray(col)
    ensure_fits_int32(col.shape[0], "undirected CSR edge slots (oriented offsets)")
    if n_nodes is None:
        n_nodes = row_offsets.shape[0] - 1
    deg = np.diff(row_offsets).astype(np.int32)
    u = np.repeat(np.arange(n_nodes, dtype=np.int32), deg)
    v = col.astype(np.int32, copy=False)
    du, dv = deg[u], deg[v]
    keep = (du < dv) | ((du == dv) & (u < v))
    src = np.ascontiguousarray(u[keep])
    out_col = np.ascontiguousarray(v[keep])
    out_row = np.searchsorted(src, np.arange(n_nodes + 1, dtype=np.int32)).astype(
        np.int32
    )
    out_degree = out_row[1:] - out_row[:-1]
    return OrientedCSR(out_row, src, out_col, out_degree, deg)


def oriented_from_compressed(z) -> OrientedCSR:
    """Forward-orient a compressed CSR block-by-block, never decoding it all.

    ``z`` is duck-typed (anything with ``row_offsets`` / ``n_nodes`` /
    ``n_blocks`` / ``block_node_range`` / ``decode_block``, i.e. a
    :class:`repro.graphs.io.CompressedCSR`).  Degrees come from the flat
    row offsets alone; each neighbor block is then decoded, filtered by
    the engine's forward rule ``(du < dv) | ((du == dv) & (u < v))``, and
    the kept slices concatenated.  Blocks cover contiguous node ranges in
    order and the filter preserves order, so the concatenation is
    bit-identical to ``oriented_from_undirected_csr`` of the full decode
    — while peak extra host memory is one decoded block, not the whole
    4-byte-per-neighbor ``col``.
    """
    row = np.asarray(z.row_offsets, dtype=np.int64)
    n_nodes = int(z.n_nodes)
    ensure_fits_int32(int(row[-1]), "compressed CSR edge slots (oriented offsets)")
    deg = np.diff(row).astype(np.int32)
    src_parts, col_parts = [], []
    for k in range(z.n_blocks):
        lo, hi = z.block_node_range(k)
        v = np.asarray(z.decode_block(k), dtype=np.int32)
        u = np.repeat(np.arange(lo, hi, dtype=np.int32),
                      np.diff(row[lo : hi + 1]))
        du, dv = deg[u], deg[v]
        keep = (du < dv) | ((du == dv) & (u < v))
        src_parts.append(u[keep])
        col_parts.append(v[keep])
    src = (np.ascontiguousarray(np.concatenate(src_parts))
           if src_parts else np.zeros(0, np.int32))
    out_col = (np.ascontiguousarray(np.concatenate(col_parts))
               if col_parts else np.zeros(0, np.int32))
    out_row = np.searchsorted(src, np.arange(n_nodes + 1, dtype=np.int32)).astype(
        np.int32
    )
    out_degree = out_row[1:] - out_row[:-1]
    return OrientedCSR(out_row, src, out_col, out_degree, deg)


def preprocess_host_offload(edges: np.ndarray, n_nodes: int | None = None) -> OrientedCSR:
    """Host-side degree + orientation, device-side sort (paper §III-D6).

    For graphs whose full (both-direction) edge array does not fit on the
    device, the paper computes degrees and drops backward edges on the CPU,
    halving what must be transferred; the sort and node-array build then
    run on the accelerator.  Identical output to :func:`preprocess`.

    Accepts either a canonical edge array or a pre-built undirected CSR
    (anything with ``row_offsets``/``col``/``n_nodes`` attributes, e.g. a
    cached :class:`repro.graphs.io.CSRGraph`) — the CSR path skips the
    device sort entirely via :func:`oriented_from_undirected_csr`.
    """
    if isinstance(edges, OrientedCSR):
        return edges  # already oriented — re-filtering would drop edges
    if hasattr(edges, "decode_block"):
        return oriented_from_compressed(edges)
    if hasattr(edges, "row_offsets") and hasattr(edges, "col"):
        return oriented_from_undirected_csr(
            edges.row_offsets, edges.col, getattr(edges, "n_nodes", None)
        )
    edges = np.asarray(edges)
    if n_nodes is None:
        n_nodes = int(edges.max()) + 1 if edges.size else 0
    deg = np.bincount(edges[:, 0], minlength=n_nodes).astype(np.int32)
    u, v = edges[:, 0], edges[:, 1]
    du, dv = deg[u], deg[v]
    keep = (du < dv) | ((du == dv) & (u < v))
    ensure_fits_int32(edges.shape[0], "canonical edge count (host-offload offsets)")
    directed = edges[keep].astype(np.int32)  # m/2 rows cross the PCIe link

    @functools.partial(jax.jit, static_argnames=("n",))
    def _device_tail(directed: jax.Array, deg: jax.Array, n: int) -> OrientedCSR:
        su, sv = directed[:, 0], directed[:, 1]
        order = jnp.lexsort((sv, su))
        src, col = su[order], sv[order]
        row_offsets = jnp.searchsorted(
            src, jnp.arange(n + 1, dtype=jnp.int32)
        ).astype(jnp.int32)
        return OrientedCSR(row_offsets, src, col, row_offsets[1:] - row_offsets[:-1], deg)

    return _device_tail(jnp.asarray(directed), jnp.asarray(deg), n=n_nodes)
