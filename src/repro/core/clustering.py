"""Clustering coefficient and transitivity ratio (the paper's motivating
applications, §I) — thin wrappers over :mod:`repro.analytics.metrics`.

Historically these called the wedge-plan primitives directly, bypassing
the :class:`repro.core.engine.TriangleCounter` engine — which meant no
``max_wedge_chunk`` memory bounding and no cached-CSR inputs, so the
motivating application could not run on the very graphs the ingestion
subsystem can load.  They now route through the engine via the
analytics subsystem; the public signatures are unchanged (with new
optional ``method``/``max_wedge_chunk`` knobs), and every function
accepts raw canonical edge arrays, ``OrientedCSR`` objects and cached
:class:`repro.graphs.io.CSRGraph` files alike.
"""
from __future__ import annotations

import numpy as np

from repro.analytics.metrics import (
    average_clustering,
    clustering_from_counts,
    local_clustering,
    node_triangle_features as _node_triangle_features,
    transitivity as _transitivity,
    transitivity_from_counts,
)

__all__ = [
    "clustering_from_counts",
    "transitivity_from_counts",
    "local_clustering_coefficient",
    "average_clustering_coefficient",
    "transitivity",
    "node_triangle_features",
]


def local_clustering_coefficient(
    edges,
    n_nodes: int | None = None,
    *,
    method: str = "auto",
    max_wedge_chunk: int | None = None,
) -> np.ndarray:
    """c(v) = 2·T(v) / (deg(v)·(deg(v)−1)); 0 where degree < 2."""
    return local_clustering(
        edges, n_nodes, method=method, max_wedge_chunk=max_wedge_chunk
    )


def average_clustering_coefficient(
    edges,
    n_nodes: int | None = None,
    *,
    method: str = "auto",
    max_wedge_chunk: int | None = None,
) -> float:
    return average_clustering(
        edges, n_nodes, method=method, max_wedge_chunk=max_wedge_chunk
    )


def transitivity(
    edges,
    n_nodes: int | None = None,
    *,
    method: str = "auto",
    max_wedge_chunk: int | None = None,
) -> float:
    """3·#triangles / #wedges (the transitivity ratio)."""
    return _transitivity(edges, n_nodes, method=method, max_wedge_chunk=max_wedge_chunk)


def node_triangle_features(
    edges,
    n_nodes: int | None = None,
    *,
    method: str = "auto",
    max_wedge_chunk: int | None = None,
) -> np.ndarray:
    """(n, 3) per-node feature block [degree, triangles, clustering coeff].

    This is the hook by which the paper's technique feeds the GNN stack:
    any graph arch config may prepend these features to its node inputs.
    """
    return _node_triangle_features(
        edges, n_nodes, method=method, max_wedge_chunk=max_wedge_chunk
    )
