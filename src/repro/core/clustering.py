"""Clustering coefficient and transitivity ratio (the paper's motivating
applications, §I) computed from the triangle-counting core.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .count import make_wedge_plan, per_node_triangles
from .preprocess import preprocess

__all__ = [
    "clustering_from_counts",
    "transitivity_from_counts",
    "local_clustering_coefficient",
    "average_clustering_coefficient",
    "transitivity",
    "node_triangle_features",
]


def clustering_from_counts(tri: np.ndarray, deg: np.ndarray) -> np.ndarray:
    """c(v) = 2·T(v) / (deg(v)·(deg(v)−1)) from host count/degree arrays.

    Shared formula for this module and the engine
    (:meth:`repro.core.engine.TriangleCounter.clustering`).
    """
    pairs = deg * (deg - 1)
    return np.where(pairs > 0, 2.0 * tri / np.maximum(pairs, 1), 0.0)


def transitivity_from_counts(n_triangles: int, deg: np.ndarray) -> float:
    """3·#triangles / #wedges from a host count and degree array."""
    wedges = int((deg.astype(np.int64) * (deg.astype(np.int64) - 1) // 2).sum())
    return 3.0 * n_triangles / wedges if wedges else 0.0


def _csr(edges, n_nodes=None):
    edges = np.asarray(edges)
    if n_nodes is None:
        n_nodes = int(edges.max()) + 1 if edges.size else 0
    return preprocess(jnp.asarray(edges), n_nodes=n_nodes)


def local_clustering_coefficient(edges, n_nodes: int | None = None) -> jax.Array:
    """c(v) = 2·T(v) / (deg(v)·(deg(v)−1)); 0 where degree < 2."""
    csr = _csr(edges, n_nodes)
    tri = per_node_triangles(csr, make_wedge_plan(csr))
    deg = csr.degree
    pairs = deg * (deg - 1)
    return jnp.where(pairs > 0, 2.0 * tri / pairs, 0.0)


def average_clustering_coefficient(edges, n_nodes: int | None = None) -> float:
    return float(jnp.mean(local_clustering_coefficient(edges, n_nodes)))


def transitivity(edges, n_nodes: int | None = None) -> float:
    """3·#triangles / #wedges (the transitivity ratio)."""
    csr = _csr(edges, n_nodes)
    tri = per_node_triangles(csr, make_wedge_plan(csr))
    n_tri = int(np.asarray(tri, dtype=np.int64).sum()) // 3
    return transitivity_from_counts(n_tri, np.asarray(csr.degree))


def node_triangle_features(edges, n_nodes: int | None = None) -> jax.Array:
    """(n, 3) per-node feature block [degree, triangles, clustering coeff].

    This is the hook by which the paper's technique feeds the GNN stack:
    any graph arch config may prepend these features to its node inputs.
    """
    csr = _csr(edges, n_nodes)
    tri = per_node_triangles(csr, make_wedge_plan(csr))
    deg = csr.degree
    pairs = deg * (deg - 1)
    cc = jnp.where(pairs > 0, 2.0 * tri / pairs, 0.0)
    return jnp.stack([deg.astype(jnp.float32), tri.astype(jnp.float32), cc], axis=1)
