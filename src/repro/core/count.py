"""Counting phase of the parallel forward algorithm (paper §II-C, §III-C).

The paper assigns one CUDA thread per directed edge and runs a serial
two-pointer merge over the two sorted adjacency lists.  A serial merge is
the wrong shape for a TPU (data-dependent control flow starves the VPU), so
we provide two TPU-native *exact* schedules:

``wedge_bsearch``
    Expand each directed edge ``(u, v)`` into its wedge candidates
    ``w ∈ N⁺(u)`` and test ``w ∈ N⁺(v)`` with a *batched* branch-free binary
    search (``⌈log₂ L_max⌉`` vectorized steps, all lanes active).  Work is
    ``Σ_u deg⁺(u)² · log`` — the log factor buys full vectorization.

``panel``
    Bucket edges by intersection width, gather fixed-width neighbor panels
    ``A ∈ (B, L_u)``, ``B ∈ (B, L_v)`` and count equal pairs with a tiled
    all-pairs equality reduction — a masked "equality matmul" that saturates
    the 8×128 VPU lanes.  This is the schedule the Pallas kernel
    (:mod:`repro.kernels.triangle_count`) implements; the jnp version here
    is its oracle and CPU fallback.

Both count each triangle exactly once (forward orientation guarantees a
unique apex with two out-edges).

This module holds the *primitives* (wedge expansion, batched binary
search, bucketing, panel gathers).  Orchestration — schedule selection,
memory-bounded edge chunking, uint64 host accumulation, the distributed
composition — lives in :mod:`repro.core.engine`; ``count_triangles``
below is a thin facade over :class:`repro.core.engine.TriangleCounter`.
Measured schedule trade-offs are tabulated in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .preprocess import OrientedCSR, preprocess
from repro.distributed.compression import ensure_fits_int32

__all__ = [
    "WedgePlan",
    "make_wedge_plan",
    "expand_and_close_wedges",
    "expand_and_close_wedges_indexed",
    "segmented_int32_sum",
    "count_wedges_found",
    "count_triangles_csr",
    "count_triangles",
    "per_node_triangles",
    "bucketize_edges",
    "gather_panels",
    "gather_panels_arrays",
    "panel_intersect_count",
    "panel_intersect_per_node",
    "panel_intersect_support",
]


# ---------------------------------------------------------------------------
# wedge_bsearch schedule
# ---------------------------------------------------------------------------


class WedgePlan(NamedTuple):
    """Static sizing for the wedge expansion (host-computed)."""

    total_wedges: int       # padded wedge-buffer length
    n_search_steps: int     # ⌈log2(max out-degree + 1)⌉


def make_wedge_plan(csr: OrientedCSR, pad_to: int | None = None) -> WedgePlan:
    """Compute concrete wedge-buffer sizing from a (host-resident) CSR."""
    out_deg = np.asarray(csr.out_degree)
    src = np.asarray(csr.src)
    total = int(out_deg[src].sum(dtype=np.int64)) if src.size else 0
    max_deg = int(out_deg.max()) if out_deg.size else 0
    steps = max(1, math.ceil(math.log2(max_deg + 1))) if max_deg else 1
    if pad_to is not None:
        total = max(total, pad_to)
    return WedgePlan(total_wedges=max(total, 1), n_search_steps=steps)


def _batched_search(
    col: jax.Array, lo: jax.Array, hi: jax.Array, target: jax.Array, n_steps: int
) -> tuple[jax.Array, jax.Array]:
    """Branch-free batched binary search over ``col[lo:hi]``.

    All of ``lo``/``hi``/``target`` are rank-1 and processed in lockstep;
    each of the ``n_steps`` iterations is one vectorized gather + compare,
    so the VPU stays full regardless of degree skew.  Returns
    ``(found, pos)`` where ``pos`` is the insertion index — the global
    ``col`` index of the match whenever ``found`` is true, which is what
    per-edge attribution (triangle support) scatters against.
    """
    end = hi

    def body(_, carry):
        lo, hi = carry
        active = lo < hi
        mid = (lo + hi) >> 1
        below = col[jnp.clip(mid, 0, col.shape[0] - 1)] < target
        lo = jnp.where(active & below, mid + 1, lo)
        hi = jnp.where(active & ~below, mid, hi)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, n_steps, body, (lo, hi))
    safe = jnp.clip(lo, 0, col.shape[0] - 1)
    return (lo < end) & (col[safe] == target), safe


def _batched_contains(
    col: jax.Array, lo: jax.Array, hi: jax.Array, target: jax.Array, n_steps: int
) -> jax.Array:
    """Is ``target`` in ``col[lo:hi]``? (membership-only view of the search)."""
    found, _ = _batched_search(col, lo, hi, target, n_steps)
    return found


def _expand_close_body(src_e, dst_e, row_offsets, col, out_deg, wedge_budget, n_steps):
    """Shared wedge expansion + closure; returns every per-slot artifact.

    ``(hit, edge_id, u, v, w, w_idx, vw_idx)``: ``edge_id`` is the slot's
    originating edge (local to this chunk), ``w_idx`` the global directed
    edge index of ``(u, w)`` (the wedge arm inside ``col``), ``vw_idx``
    the global index of the closing edge ``(v, w)`` found by the search.
    Index values on non-``hit`` slots are clipped-safe garbage.
    """
    m_local = src_e.shape[0]
    valid_e = src_e >= 0
    safe_src = jnp.maximum(src_e, 0)
    safe_dst = jnp.maximum(dst_e, 0)
    reps = jnp.where(valid_e, out_deg[safe_src], 0)
    starts = jnp.cumsum(reps) - reps
    edge_id = jnp.repeat(
        jnp.arange(m_local, dtype=jnp.int32), reps, total_repeat_length=wedge_budget
    )
    pos = jnp.arange(wedge_budget, dtype=jnp.int32) - starts[edge_id]
    valid = (pos >= 0) & (pos < reps[edge_id])
    u = safe_src[edge_id]
    v = safe_dst[edge_id]
    w_idx = jnp.clip(row_offsets[u] + pos, 0, col.shape[0] - 1)
    w = col[w_idx]
    found, vw_idx = _batched_search(col, row_offsets[v], row_offsets[v + 1], w, n_steps)
    return found & valid, edge_id, u, v, w, w_idx, vw_idx


def expand_and_close_wedges(src_e, dst_e, row_offsets, col, out_deg, wedge_budget, n_steps):
    """Expand a (possibly −1-padded) directed-edge array into wedges and
    close them with the batched binary search.

    The single shared implementation of the wedge schedule's inner body —
    used unchunked here (:func:`count_wedges_found`) and per budget-sized
    chunk by :mod:`repro.core.engine`.  Returns ``(hit, u, v, w)`` where
    ``hit[i]`` marks wedge slot ``i`` as a closed, non-padding triangle.
    ``wedge_budget`` (static) is the buffer length; padding slots and −1
    edge slots contribute ``hit = False``.
    """
    hit, _, u, v, w, _, _ = _expand_close_body(
        src_e, dst_e, row_offsets, col, out_deg, wedge_budget, n_steps
    )
    return hit, u, v, w


def expand_and_close_wedges_indexed(
    src_e, dst_e, row_offsets, col, out_deg, wedge_budget, n_steps
):
    """Wedge closure with *edge-index* attribution (per-edge support).

    Like :func:`expand_and_close_wedges`, but instead of the triangle's
    vertices each hit slot reports the three **directed edge indices** of
    the triangle it closes: ``(hit, edge_id, uw_idx, vw_idx)`` where
    ``edge_id`` is the originating edge ``(u, v)`` local to this chunk
    (add the chunk's global offset before scattering), ``uw_idx`` is the
    global ``col`` index of the wedge arm ``(u, w)`` and ``vw_idx`` the
    global index of the closing edge ``(v, w)``.  This is the primitive
    under :mod:`repro.analytics.support` — every closed wedge contributes
    one unit of support to exactly those three edges.
    """
    hit, edge_id, _, _, _, w_idx, vw_idx = _expand_close_body(
        src_e, dst_e, row_offsets, col, out_deg, wedge_budget, n_steps
    )
    return hit, edge_id, w_idx, vw_idx


def segmented_int32_sum(hits: jax.Array, seg: int = 1 << 20) -> jax.Array:
    """Reduce a boolean hit buffer to per-``seg``-slot int32 partials.

    A segment sum never exceeds ``seg`` (default 2²⁰), so int32 stays safe
    even when the whole buffer holds ≥ 2³¹ hits; the final reduction runs
    on host in uint64 (:func:`repro.core.engine.accumulate_partials`).
    Shared by the unchunked, chunked, and distributed counting paths.
    """
    n = hits.shape[0]
    pad = (-n) % seg
    padded = jnp.concatenate([hits, jnp.zeros((pad,), hits.dtype)]) if pad else hits
    return jnp.sum(padded.reshape(-1, seg).astype(jnp.int32), axis=1, dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=("plan",))
def count_wedges_found(csr: OrientedCSR, plan: WedgePlan) -> tuple[jax.Array, jax.Array]:
    """Return (found mask over the wedge buffer, wedge endpoints (u,v,w)).

    The wedge buffer enumerates, for each directed edge ``(u, v)``, every
    candidate ``w ∈ N⁺(u)``; ``found[i]`` says wedge ``i`` closes into a
    triangle.  Padding slots are masked off.
    """
    found, u, v, w = expand_and_close_wedges(
        csr.src, csr.col, csr.row_offsets, csr.col, csr.out_degree,
        plan.total_wedges, plan.n_search_steps,
    )
    return found, (u, v, w)


def count_triangles_csr(csr: OrientedCSR, plan: WedgePlan | None = None) -> int:
    """Total triangle count from an oriented CSR (host-orchestrated)."""
    if plan is None:
        plan = make_wedge_plan(csr)
    found, _ = count_wedges_found(csr, plan)
    # Per-2^20-segment int32 partials; the final accumulation happens on
    # host in uint64, so counts like the paper's 8.8e9 (Kronecker-21) do
    # not overflow 32-bit device arithmetic.
    partials = segmented_int32_sum(found)
    return int(np.asarray(partials).astype(np.uint64).sum())


def per_node_triangles(csr: OrientedCSR, plan: WedgePlan | None = None) -> jax.Array:
    """Number of triangles each vertex participates in (for clustering)."""
    if plan is None:
        plan = make_wedge_plan(csr)
    found, (u, v, w) = count_wedges_found(csr, plan)
    inc = found.astype(jnp.int32)
    n = csr.n_nodes
    out = jnp.zeros((n,), jnp.int32)
    out = out.at[u].add(inc)
    out = out.at[v].add(inc)
    out = out.at[w].add(inc)
    return out


# ---------------------------------------------------------------------------
# panel schedule (bucketed fixed-width intersection)
# ---------------------------------------------------------------------------


def bucketize_edges(
    csr: OrientedCSR, widths: tuple[int, ...] = (16, 64, 256, 1024, 4096)
) -> dict[int, np.ndarray]:
    """Group directed edges by the padded width of the *longer* endpoint list.

    Host-side: returns ``{width: edge_indices}``.  Widths are the TPU
    analogue of the paper's warp-size tuning — each bucket compiles to a
    fixed-tile kernel with bounded padding waste.
    """
    out_deg = np.asarray(csr.out_degree)
    src = np.asarray(csr.src)
    col = np.asarray(csr.col)
    # bucket indices are stored int32: fail loudly at m >= 2^31 instead of
    # letting .astype wrap them (satellite of the overflow-discipline pass)
    ensure_fits_int32(src.shape[0], "directed edge count (panel bucket indices)")
    need = np.maximum(out_deg[src], out_deg[col])
    buckets: dict[int, np.ndarray] = {}
    lo = 0
    for w in widths:
        mask = (need > lo) & (need <= w)
        idx = np.nonzero(mask)[0]
        if idx.size:
            buckets[w] = idx.astype(np.int32)
        lo = w
    if (need > widths[-1]).any():
        raise ValueError(
            f"max out-degree {int(need.max())} exceeds largest bucket {widths[-1]}; "
            "widen `widths` (forward orientation bounds it by sqrt(2m))"
        )
    return buckets


@functools.partial(jax.jit, static_argnames=("width",))
def gather_panels(csr: OrientedCSR, edge_idx: jax.Array, width: int):
    """Gather fixed-width neighbor panels for a bucket of edges.

    Returns ``(a, b, a_len, b_len)`` with ``a: (B, width)`` the out-neighbors
    of each edge's ``u`` (−1 padded) and ``b`` likewise for ``v``.  The
    gathers run as XLA ops *outside* the kernel — the TPU replacement for
    the paper's reliance on the GPU texture cache inside the merge loop.

    ``edge_idx`` slots holding −1 (budget-chunk padding from the engine)
    yield all-(−1) panel rows with zero lengths, which every intersect
    kernel counts as zero.
    """
    valid = edge_idx >= 0
    safe = jnp.maximum(edge_idx, 0)
    u = csr.src[safe]
    v = csr.col[safe]
    lane = jnp.arange(width, dtype=jnp.int32)
    m_dir = csr.col.shape[0]

    def panel(base, length):
        idx = jnp.clip(base[:, None] + lane[None, :], 0, m_dir - 1)
        vals = csr.col[idx]
        return jnp.where(lane[None, :] < length[:, None], vals, -1)

    a_len = jnp.where(valid, csr.out_degree[u], 0)
    b_len = jnp.where(valid, csr.out_degree[v], 0)
    a = panel(csr.row_offsets[u], a_len)
    b = panel(csr.row_offsets[v], b_len)
    return a, b, a_len, b_len


@functools.partial(jax.jit, static_argnames=("width",))
def gather_panels_arrays(row_offsets, col, out_degree, u, v, width: int):
    """Gather fixed-width neighbor panels for arbitrary ``(u, v)`` pairs.

    The raw-arrays generalization of :func:`gather_panels`: instead of
    indexing a CSR's own directed edge list, the query endpoints are
    given directly, so the same gather serves the engine's directed-edge
    workload, the truss peel's filtered sub-CSRs and the incremental
    service's probe pairs against an *undirected* adjacency.  ``u``/``v``
    slots holding −1 (chunk padding) yield all-(−1) panel rows with zero
    lengths, which every intersect kernel counts as zero.
    """
    valid = (u >= 0) & (v >= 0)
    safe_u = jnp.maximum(u, 0)
    safe_v = jnp.maximum(v, 0)
    lane = jnp.arange(width, dtype=jnp.int32)
    m_dir = col.shape[0]

    def panel(base, length):
        idx = jnp.clip(base[:, None] + lane[None, :], 0, m_dir - 1)
        vals = col[idx]
        return jnp.where(lane[None, :] < length[:, None], vals, -1)

    a_len = jnp.where(valid, out_degree[safe_u], 0)
    b_len = jnp.where(valid, out_degree[safe_v], 0)
    a = panel(row_offsets[safe_u], a_len)
    b = panel(row_offsets[safe_v], b_len)
    return a, b, a_len, b_len


@jax.jit
def panel_intersect_count(a: jax.Array, b: jax.Array) -> jax.Array:
    """Sorted-set intersection sizes via all-pairs equality (jnp oracle).

    ``a: (B, Lu)``, ``b: (B, Lv)``, −1 padding.  O(Lu·Lv) compares but every
    compare is a full-width VPU op; with √(2m)-bounded lists and bucketing
    the constant is small.  The Pallas kernel computes exactly this.
    """
    eq = a[:, :, None] == b[:, None, :]
    valid = (a[:, :, None] >= 0) & (b[:, None, :] >= 0)
    return jnp.sum(eq & valid, axis=(1, 2), dtype=jnp.int32)


@jax.jit
def panel_intersect_per_node(a: jax.Array, b: jax.Array):
    """(count, arm) jnp oracle — the per-node reduction of the eq cube.

    ``arm[i, j]`` counts matches of ``a[i, j]`` inside row ``b[i]`` (0 on
    padding), so scattering ``count`` to the edge endpoints and ``arm``
    to the ``a`` *values* yields per-node triangle incidences.  The
    Pallas rendition is ``intersect_per_node_pallas``.
    """
    eq = a[:, :, None] == b[:, None, :]
    valid = (a[:, :, None] >= 0) & (b[:, None, :] >= 0)
    arm = jnp.sum(eq & valid, axis=2, dtype=jnp.int32)
    return jnp.sum(arm, axis=1, dtype=jnp.int32), arm


@jax.jit
def panel_intersect_support(a: jax.Array, b: jax.Array):
    """(count, arm, closure) jnp oracle — the full support attribution.

    ``closure[i, k]`` counts matches of ``b[i, k]`` inside row ``a[i]``;
    together with ``arm`` every hit is billed to the triangle's three
    directed edges.  The Pallas rendition is ``intersect_support_pallas``.
    """
    eq = a[:, :, None] == b[:, None, :]
    valid = (a[:, :, None] >= 0) & (b[:, None, :] >= 0)
    masked = eq & valid
    arm = jnp.sum(masked, axis=2, dtype=jnp.int32)
    closure = jnp.sum(masked, axis=1, dtype=jnp.int32)
    return jnp.sum(arm, axis=1, dtype=jnp.int32), arm, closure


def _count_panel(csr: OrientedCSR, kernel=None) -> int:
    """Bucketed panel counting; `kernel` overrides the per-bucket intersect.

    Retained for direct-CSR callers; the chunked production path lives in
    :class:`repro.core.engine.TriangleCounter`, which wraps this same
    bucket loop under a wedge-buffer budget.
    """
    intersect = kernel or (lambda a, b, al, bl: panel_intersect_count(a, b))
    total = np.uint64(0)
    for width, idx in bucketize_edges(csr).items():
        a, b, al, bl = gather_panels(csr, jnp.asarray(idx), width)
        counts = intersect(a, b, al, bl)
        total += np.asarray(counts).astype(np.uint64).sum()
    return int(total)


# ---------------------------------------------------------------------------
# public entry point (thin facade over the unified engine)
# ---------------------------------------------------------------------------


def count_triangles(
    edges,
    n_nodes: int | None = None,
    method: str = "wedge_bsearch",
    max_wedge_chunk: int | None = None,
) -> int:
    """Count triangles in a canonical edge array.

    ``method`` ∈ {"auto", "wedge_bsearch", "panel", "pallas"}.  Routes
    through :class:`repro.core.engine.TriangleCounter`; pass
    ``max_wedge_chunk`` to bound the device wedge buffer (memory-bounded
    edge partitioning — see the engine docstring).
    """
    from .engine import TriangleCounter  # late import: engine uses this module

    return TriangleCounter(method=method, max_wedge_chunk=max_wedge_chunk).count(
        edges, n_nodes=n_nodes
    )
