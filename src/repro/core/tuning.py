"""Kernel tile autotuner — the paper's §III-D5 grid search, persisted.

The paper tunes its CUDA kernel by sweeping threads-per-edge warp sizes
per graph and keeping the fastest; the TPU analogue of that knob is the
Pallas kernel's ``(block_edges, TLv)`` tile pair (edge-block height ×
v-panel tile width).  :func:`autotune_tiles` reruns exactly that sweep —
time every admissible candidate on synthetic panels of the *shape* being
tuned (shapes, not data, determine kernel runtime) and keep the argmin —
and :class:`TileCache` persists the winners in a versioned on-disk JSON
so the sweep is paid once per shape per machine, not once per run.

Shapes are keyed pow2-bucketed (``B`` rounded up, ``Lu``/``Lv`` taken
verbatim — the engine's bucket ladder already makes them powers of two),
matching the compile-stability bucketing used everywhere else, so a
handful of cache entries covers every chunk the engine ever launches.

::

    tuner = AutoTuner(cache_path="tiles.json", tune_on_miss=True)
    tc = TriangleCounter(method="pallas", tuner=tuner)
    tc.count(edges)        # cold: sweeps + writes cache; warm: cache hits

The cache file carries a format version and the jax backend it was
measured on; a mismatch on either discards it (stale picks are worse
than the heuristic).  Writes are atomic (tmp + rename).
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import tempfile
import time

import numpy as np

from repro import obs

from .engine import next_pow2

__all__ = [
    "TileConfig",
    "TileCache",
    "AutoTuner",
    "candidate_tiles",
    "autotune_tiles",
    "shape_key",
    "CACHE_VERSION",
]

CACHE_VERSION = 1

# the Pallas kernel's VMEM ceiling for the eq cube (elements) — candidates
# are generated under the same budget `_pick_tiles` respects
_VMEM_BUDGET = 1 << 21

_TB_LADDER = (8, 16, 32, 64, 128, 256)
_TLV_LADDER = (128, 256, 512)


@dataclasses.dataclass(frozen=True)
class TileConfig:
    """One (block_edges, TLv) tile pick, plus the time that earned it."""

    block_edges: int
    tlv: int
    us: float = 0.0  # measured µs per call (0 when untimed/heuristic)

    @property
    def tiles(self) -> tuple[int, int]:
        """The kwarg form the kernels accept (``tiles=cfg.tiles``)."""
        return (self.block_edges, self.tlv)


def shape_key(n_edges: int, lu: int, lv: int) -> str:
    """Cache key: pow2-bucketed edge count × the exact panel widths."""
    return f"B{next_pow2(max(int(n_edges), 1))}xLu{int(lu)}xLv{int(lv)}"


def candidate_tiles(n_edges: int, lu: int, lv: int) -> list[TileConfig]:
    """The §III-D5 sweep grid for one panel shape.

    Every (TB, TLv) with TB in the pow2 ladder (clamped to the edge
    count), TLv in the lane-width ladder (clamped to Lv), whose equality
    cube fits the VMEM budget; the static heuristic's pick is always
    included so tuning can never do worse than not tuning.
    """
    from repro.kernels.triangle_count.triangle_count import _pick_tiles

    n_edges = max(int(n_edges), 1)
    seen: dict[tuple[int, int], None] = {}
    for tb in _TB_LADDER:
        if tb > n_edges and tb != next_pow2(n_edges):
            continue
        tb_c = min(tb, n_edges)
        for tlv in _TLV_LADDER:
            tlv_c = min(tlv, lv)
            if tb_c * lu * tlv_c <= _VMEM_BUDGET:
                seen[(tb_c, tlv_c)] = None
    seen[_pick_tiles(n_edges, lu, lv)] = None
    return [TileConfig(tb, tlv) for tb, tlv in seen]


def _synthetic_panels(rng: np.random.Generator, b: int, l: int) -> np.ndarray:
    """Sorted, −1-padded panels with ~half-full rows (the typical bucket)."""
    out = np.full((b, l), -1, np.int32)
    for i in range(b):
        n = int(rng.integers(l // 2, l + 1)) if l > 1 else 1
        out[i, :n] = np.sort(rng.choice(4 * l + 8, size=n, replace=False))
    return out


def autotune_tiles(
    n_edges: int,
    lu: int,
    lv: int,
    *,
    iters: int = 2,
    warmup: int = 1,
    seed: int = 0,
) -> TileConfig:
    """Grid-search the count kernel's tiles for one pow2 bucket shape.

    Times :func:`repro.kernels.triangle_count.intersect_count_pallas`
    (the cheapest family member — tile behavior is shared) on synthetic
    sorted panels and returns the fastest admissible config.  The
    measured shape uses the pow2-bucketed edge count, so the result is
    valid for every chunk that maps to the same cache key.
    """
    import jax.numpy as jnp

    from repro.kernels.triangle_count import intersect_count_pallas

    b = next_pow2(max(int(n_edges), 1))
    rng = np.random.default_rng(seed)
    a = jnp.asarray(_synthetic_panels(rng, b, lu))
    c = jnp.asarray(_synthetic_panels(rng, b, lv))
    best: TileConfig | None = None
    for cand in candidate_tiles(b, lu, lv):
        for _ in range(warmup):
            intersect_count_pallas(a, c, tiles=cand.tiles).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(iters):
            intersect_count_pallas(a, c, tiles=cand.tiles).block_until_ready()
        us = (time.perf_counter() - t0) / max(iters, 1) * 1e6
        if best is None or us < best.us:
            best = TileConfig(cand.block_edges, cand.tlv, us)
    assert best is not None
    return best


@contextlib.contextmanager
def _cache_write_lock(path: str):
    """Advisory exclusive lock serializing read-merge-write cycles.

    ``fcntl.flock`` on a ``.lock`` sidecar where available (POSIX); on
    platforms without it the merge still runs — the window shrinks to
    the read→replace gap instead of disappearing, and the write itself
    stays atomic either way.
    """
    try:
        import fcntl
    except ImportError:  # non-POSIX: atomic replace only
        yield
        return
    fd = os.open(path + ".lock", os.O_CREAT | os.O_RDWR, 0o644)
    try:
        fcntl.flock(fd, fcntl.LOCK_EX)
        yield
    finally:
        fcntl.flock(fd, fcntl.LOCK_UN)
        os.close(fd)


class TileCache:
    """Versioned on-disk store of per-shape tile picks.

    The JSON payload is ``{"version", "backend", "entries": {key: {...}}}``;
    loading discards the file on a version or jax-backend mismatch so a
    cache tuned on TPU never steers a CPU run (or vice versa).

    Safe for **concurrent use**: two engines tuning different shapes
    into the same cache file cannot lose each other's entries —
    :meth:`save` is an atomic read-merge-write (under an advisory file
    lock where the platform has one) with last-writer-wins per *key*,
    not per file.  The seed wrote the instance's in-memory view over the
    whole file, so whichever engine saved last erased the other's picks.
    """

    def __init__(self, path: str | os.PathLike | None = None):
        self.path = os.fspath(path) if path is not None else None
        self.entries: dict[str, TileConfig] = {}
        self.loaded_from_disk = False
        if self.path is not None and os.path.exists(self.path):
            self.entries = self._read_disk_entries()
            self.loaded_from_disk = bool(self.entries)

    @staticmethod
    def _backend() -> str:
        import jax

        return jax.default_backend()

    def _read_disk_entries(self) -> dict[str, TileConfig]:
        """Current on-disk entries; {} on missing/corrupt/mismatched file."""
        try:
            with open(self.path) as f:
                payload = json.load(f)
        except (OSError, json.JSONDecodeError):
            return {}
        if (
            payload.get("version") != CACHE_VERSION
            or payload.get("backend") != self._backend()
        ):
            return {}
        out: dict[str, TileConfig] = {}
        for key, ent in payload.get("entries", {}).items():
            try:
                out[key] = TileConfig(
                    int(ent["block_edges"]), int(ent["tlv"]), float(ent.get("us", 0.0))
                )
            except (KeyError, TypeError, ValueError):
                continue
        return out

    def get(self, key: str) -> TileConfig | None:
        return self.entries.get(key)

    def put(self, key: str, cfg: TileConfig) -> None:
        self.entries[key] = cfg

    def save(self) -> None:
        """Atomic read-merge-write: disk entries ∪ ours, ours win per key."""
        if self.path is None:
            return
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        with _cache_write_lock(self.path):
            merged = {**self._read_disk_entries(), **self.entries}
            payload = {
                "version": CACHE_VERSION,
                "backend": self._backend(),
                "entries": {
                    k: {"block_edges": c.block_edges, "tlv": c.tlv, "us": c.us}
                    for k, c in sorted(merged.items())
                },
            }
            fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
            try:
                with os.fdopen(fd, "w") as f:
                    json.dump(payload, f, indent=1, sort_keys=True)
                os.replace(tmp, self.path)
            except BaseException:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
                raise
            self.entries = merged


class AutoTuner:
    """Policy layer the engine's pallas backend consults per panel shape.

    ``tune_on_miss=True`` runs the grid search (and persists it) the
    first time a shape is seen; ``False`` only serves already-cached
    picks and leaves unknown shapes to the kernel's static heuristic —
    the safe default for latency-sensitive callers.
    """

    def __init__(
        self,
        cache_path: str | os.PathLike | None = None,
        *,
        tune_on_miss: bool = False,
        iters: int = 2,
        seed: int = 0,
    ):
        self.cache = TileCache(cache_path)
        self.tune_on_miss = tune_on_miss
        self.iters = iters
        self.seed = seed
        self.n_hits = 0
        self.n_tuned = 0

    def tiles(self, n_edges: int, lu: int, lv: int) -> tuple[int, int] | None:
        """The (block_edges, tlv) pick for a shape, or None → heuristic."""
        key = shape_key(n_edges, lu, lv)
        cfg = self.cache.get(key)
        if cfg is not None:
            self.n_hits += 1
            obs.counter("tiles.cache_hits").add()
            return cfg.tiles
        obs.counter("tiles.cache_misses").add()
        if not self.tune_on_miss:
            return None
        cfg = autotune_tiles(n_edges, lu, lv, iters=self.iters, seed=self.seed)
        self.cache.put(key, cfg)
        self.cache.save()
        self.n_tuned += 1
        obs.counter("tiles.tuned").add()
        return cfg.tiles
