"""Core library: the paper's parallel *forward* triangle-counting algorithm.

Public API::

    from repro.core import count_triangles, transitivity, preprocess

    t = count_triangles(edge_array)                     # exact, on device
    t = count_triangles(edge_array, method="pallas")    # Pallas kernel path
    t = count_triangles_distributed(edge_array, mesh)   # multi-pod
"""
from .preprocess import OrientedCSR, preprocess, preprocess_host_offload, degrees
from .count import (
    WedgePlan,
    make_wedge_plan,
    count_wedges_found,
    count_triangles_csr,
    count_triangles,
    per_node_triangles,
    bucketize_edges,
    gather_panels,
    panel_intersect_count,
)
from .clustering import (
    local_clustering_coefficient,
    average_clustering_coefficient,
    transitivity,
    node_triangle_features,
)
from .baseline import (
    count_triangles_sequential,
    count_triangles_numpy,
    count_triangles_bruteforce,
)
from .approx import count_triangles_doulion
from .distributed import (
    stripe_edges,
    make_distributed_count_fn,
    count_triangles_distributed,
)

__all__ = [
    "OrientedCSR",
    "preprocess",
    "preprocess_host_offload",
    "degrees",
    "WedgePlan",
    "make_wedge_plan",
    "count_wedges_found",
    "count_triangles_csr",
    "count_triangles",
    "per_node_triangles",
    "bucketize_edges",
    "gather_panels",
    "panel_intersect_count",
    "local_clustering_coefficient",
    "average_clustering_coefficient",
    "transitivity",
    "node_triangle_features",
    "count_triangles_sequential",
    "count_triangles_numpy",
    "count_triangles_bruteforce",
    "count_triangles_doulion",
    "stripe_edges",
    "make_distributed_count_fn",
    "count_triangles_distributed",
]
