"""Core library: the paper's parallel *forward* triangle-counting algorithm.

Public API::

    from repro.core import TriangleCounter, count_triangles, transitivity

    tc = TriangleCounter(method="auto", max_wedge_chunk=1 << 22)
    t  = tc.count(edge_array)                           # memory-bounded, exact
    t = count_triangles(edge_array)                     # one-shot facade
    t = count_triangles(edge_array, method="pallas")    # Pallas kernel path
    t = count_triangles_distributed(edge_array, mesh)   # multi-pod (§III-E)

    itc = IncrementalTriangleCounter(edge_array)        # dynamic graphs
    itc.insert(new_edges); itc.delete(old_edges)        # batched deltas
    itc.count                                           # maintained, O(1)

:class:`TriangleCounter` (:mod:`repro.core.engine`) unifies the four
schedules — ``wedge_bsearch``, ``panel``, ``pallas``, ``distributed`` —
behind one API with memory-bounded edge partitioning; the per-schedule
primitives live in :mod:`repro.core.count` / :mod:`repro.core.distributed`.
"""
from .preprocess import (
    OrientedCSR,
    preprocess,
    preprocess_host_offload,
    oriented_from_undirected_csr,
    oriented_from_compressed,
    degrees,
)
from .engine import (
    TriangleCounter,
    EngineStats,
    choose_method,
    resolve_method,
    plan_edge_chunks,
    accumulate_partials,
    prepare_oriented,
    degree_histogram,
    search_steps,
    iter_wedge_chunks,
    chunk_count_kernel,
    chunk_per_node_kernel,
    chunk_support_kernel,
    KernelBackend,
    WedgeBackend,
    PanelBackend,
    PallasBackend,
    DistributedBackend,
    register_backend,
    make_backend,
    resolve_backend,
    make_workload,
    workload_from_csr,
    run_workload,
)
from .tuning import AutoTuner, TileCache
from .count import (
    WedgePlan,
    make_wedge_plan,
    count_wedges_found,
    count_triangles_csr,
    count_triangles,
    per_node_triangles,
    bucketize_edges,
    gather_panels,
    panel_intersect_count,
)
from .clustering import (
    local_clustering_coefficient,
    average_clustering_coefficient,
    transitivity,
    node_triangle_features,
)
from .baseline import (
    count_triangles_sequential,
    count_triangles_numpy,
    count_triangles_bruteforce,
)
from .approx import count_triangles_doulion
from .incremental import IncrementalTriangleCounter, UpdateStats
from .distributed import (
    stripe_edges,
    plan_striped_chunks,
    make_distributed_count_fn,
    count_triangles_distributed,
    count_triangles_distributed_csr,
)

__all__ = [
    "TriangleCounter",
    "EngineStats",
    "choose_method",
    "resolve_method",
    "plan_edge_chunks",
    "accumulate_partials",
    "prepare_oriented",
    "degree_histogram",
    "search_steps",
    "iter_wedge_chunks",
    "chunk_count_kernel",
    "chunk_per_node_kernel",
    "chunk_support_kernel",
    "KernelBackend",
    "WedgeBackend",
    "PanelBackend",
    "PallasBackend",
    "DistributedBackend",
    "register_backend",
    "make_backend",
    "resolve_backend",
    "make_workload",
    "workload_from_csr",
    "run_workload",
    "AutoTuner",
    "TileCache",
    "OrientedCSR",
    "preprocess",
    "preprocess_host_offload",
    "oriented_from_undirected_csr",
    "oriented_from_compressed",
    "degrees",
    "WedgePlan",
    "make_wedge_plan",
    "count_wedges_found",
    "count_triangles_csr",
    "count_triangles",
    "per_node_triangles",
    "bucketize_edges",
    "gather_panels",
    "panel_intersect_count",
    "local_clustering_coefficient",
    "average_clustering_coefficient",
    "transitivity",
    "node_triangle_features",
    "count_triangles_sequential",
    "count_triangles_numpy",
    "count_triangles_bruteforce",
    "count_triangles_doulion",
    "IncrementalTriangleCounter",
    "UpdateStats",
    "stripe_edges",
    "plan_striped_chunks",
    "make_distributed_count_fn",
    "count_triangles_distributed",
    "count_triangles_distributed_csr",
]
