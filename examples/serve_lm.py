"""Batched LM serving: prefill a batch of prompts, decode continuations,
report per-phase latency/throughput.  (The smoke-size model keeps this
snappy on CPU; the identical decode path lowers at 512 chips in the
dry-run `decode_32k` / `long_500k` cells.)

    PYTHONPATH=src python examples/serve_lm.py
"""
import subprocess
import sys
import os

if __name__ == "__main__":
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    sys.exit(subprocess.call(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "qwen2-1.5b",
         "--batch", "8", "--prompt-len", "64", "--gen", "32"],
        env=env,
    ))
