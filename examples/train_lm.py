"""End-to-end LM training driver: a ~20M-parameter qwen2-family model
trained for a few hundred steps with the full production substrate live —
deterministic resumable data, AdamW + cosine schedule, grad clipping,
async checkpointing (kill it mid-run and re-launch: it resumes), and
straggler monitoring.

(The container is a single CPU core; the model is sized so a few hundred
steps finish in minutes.  The same step function, sharding rules and
launcher drive the 512-chip dry-run configs.)

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import REGISTRY
from repro.configs.lm_common import make_lm_train_step
from repro.data import TokenPipeline
from repro.distributed import StragglerMonitor
from repro.models import transformer as tfm
from repro.optim import cosine_with_warmup


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~20M params: qwen2 family, narrow width
    cfg = dataclasses.replace(
        REGISTRY["qwen2-1.5b"].full_config(),
        n_layers=4, d_model=256, n_heads=4, n_kv_heads=2, d_ff=1024,
        vocab_size=32768, dtype=jnp.float32, remat=False,
    )
    print(f"model: {cfg.n_params()/1e6:.1f}M params")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    step_fn, opt_init = make_lm_train_step(
        cfg, accum=1, lr=cosine_with_warmup(3e-4, 20, args.steps)
    )
    opt_state = opt_init(params)
    pipe = TokenPipeline(args.batch, args.seq, cfg.vocab_size, seed=0)
    mgr = CheckpointManager(args.ckpt, keep=2, async_save=True)
    start = 0
    restored = mgr.restore_latest({"params": params, "opt": opt_state})
    if restored is not None:
        tree, start, extra = restored
        params, opt_state = tree["params"], tree["opt"]
        pipe = TokenPipeline.from_state(args.batch, args.seq, cfg.vocab_size,
                                        extra["data_state"])
        print(f"resumed from step {start}")

    jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
    mon = StragglerMonitor()
    t0 = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v)[None] for k, v in next(pipe).items()}
        mon.start_step()
        params, opt_state, m = jit_step(params, opt_state, batch)
        mon.end_step()
        if step % 20 == 0 or step == args.steps - 1:
            tok_s = args.batch * args.seq / max(mon.median, 1e-9)
            print(f"step {step:4d}  loss {float(m['loss']):.4f}  "
                  f"gnorm {float(m['gnorm']):.2f}  {tok_s:,.0f} tok/s")
        if (step + 1) % 50 == 0:
            mgr.save(step + 1, {"params": params, "opt": opt_state},
                     {"data_state": pipe.state()})
    mgr.save(args.steps, {"params": params, "opt": opt_state},
             {"data_state": pipe.state()})
    mgr.wait()
    print(f"trained {args.steps - start} steps in {time.time()-t0:.0f}s; "
          f"checkpoints in {args.ckpt}")


if __name__ == "__main__":
    main()
