"""Quickstart: count triangles in a graph, three ways, plus clustering stats.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

from repro.core import (
    average_clustering_coefficient,
    count_triangles,
    count_triangles_numpy,
    transitivity,
)
from repro.graphs import kronecker_rmat


def main():
    edges = kronecker_rmat(scale=12, seed=0)
    n, m = int(edges.max()) + 1, edges.shape[0] // 2
    print(f"Kronecker scale-12: {n} nodes, {m} edges")

    for method in ("wedge_bsearch", "panel", "pallas"):
        t0 = time.perf_counter()
        t = count_triangles(edges, method=method)
        print(f"  {method:14s}: {t} triangles in {(time.perf_counter()-t0)*1e3:7.1f} ms")

    t0 = time.perf_counter()
    t = count_triangles_numpy(edges)
    print(f"  {'numpy baseline':14s}: {t} triangles in {(time.perf_counter()-t0)*1e3:7.1f} ms")

    print(f"transitivity          = {transitivity(edges):.4f}")
    print(f"avg clustering coeff  = {average_clustering_coefficient(edges):.4f}")


if __name__ == "__main__":
    main()
