"""The paper's technique feeding the GNN stack: per-node triangle counts
and clustering coefficients (computed by the counting core) prepended to
node features measurably improve a GCN on a community-structured graph.

    PYTHONPATH=src python examples/gnn_triangle_features.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import REGISTRY
from repro.core import node_triangle_features
from repro.data import graph_node_features
from repro.graphs import watts_strogatz, erdos_renyi
from repro.models.gnn import gcn
from repro.optim import adamw, apply_updates, constant


def train(cfg, feat, labels, src, dst, steps=80, seed=0):
    params = gcn.init_params(jax.random.PRNGKey(seed), cfg)
    opt_init, opt_update = adamw(constant(2e-2), weight_decay=0.0)
    opt = opt_init(params)

    @jax.jit
    def step(params, opt):
        def loss(p):
            out = gcn.apply(p, cfg, feat, None, src, dst)
            lp = jax.nn.log_softmax(out, axis=-1)
            return -jnp.mean(jnp.take_along_axis(lp, labels[:, None], axis=-1))

        l, g = jax.value_and_grad(loss)(params)
        u, opt, _ = opt_update(g, opt, params)
        return apply_updates(params, u), opt, l

    for _ in range(steps):
        params, opt, l = step(params, opt)
    out = gcn.apply(params, cfg, feat, None, src, dst)
    acc = float(jnp.mean(jnp.argmax(out, -1) == labels))
    return float(l), acc


def main():
    # mix a clustered small-world graph with random noise edges: triangle
    # density now carries label signal the raw features don't have
    e1 = watts_strogatz(1200, 10, 0.05, seed=0)
    e2 = erdos_renyi(1200, 2000, seed=1)
    edges = np.concatenate([e1, e2])
    n = 1200
    base_feat, _ = graph_node_features(0, n, 8, 3)
    # labels from triangle density terciles — the structure to be learned
    tri_feats = np.asarray(node_triangle_features(edges, n))
    labels = jnp.asarray(np.digitize(tri_feats[:, 2], np.quantile(tri_feats[:, 2], [1/3, 2/3])))
    src, dst = jnp.asarray(edges[:, 0]), jnp.asarray(edges[:, 1])

    cfg = dataclasses.replace(REGISTRY["gcn-cora"].smoke_config(), d_in=8, d_out=3)
    l0, acc0 = train(cfg, jnp.asarray(base_feat), labels, src, dst)
    print(f"GCN without triangle features: loss={l0:.3f} acc={acc0:.3f}")

    aug = jnp.concatenate([jnp.asarray(base_feat),
                           jnp.asarray(tri_feats / (tri_feats.max(0) + 1e-9))], axis=1)
    cfg_aug = dataclasses.replace(cfg, d_in=aug.shape[1])
    l1, acc1 = train(cfg_aug, aug, labels, src, dst)
    print(f"GCN with    triangle features: loss={l1:.3f} acc={acc1:.3f}")
    print(f"accuracy gain from the paper's technique: +{(acc1-acc0)*100:.1f} pts")


if __name__ == "__main__":
    main()
