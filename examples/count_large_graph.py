"""The paper's large-graph scenario: host-offloaded preprocessing
(§III-D6) for graphs that stress device memory, exact vs sampled counting
(§V), and the multi-device edge-partitioned count (§III-E) when more than
one device is available.

    PYTHONPATH=src python examples/count_large_graph.py [--scale 13]
"""
import argparse
import time

import jax

from repro.core import (
    count_triangles,
    count_triangles_distributed,
    count_triangles_doulion,
    count_triangles_csr,
    preprocess_host_offload,
)
from repro.graphs import kronecker_rmat


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=13)
    args = ap.parse_args()

    edges = kronecker_rmat(args.scale, seed=0)
    n, m = int(edges.max()) + 1, edges.shape[0] // 2
    print(f"Kronecker scale-{args.scale}: {n} nodes, {m} edges")

    # paper §III-D6: degrees + orientation on host, sort on device —
    # halves the device-resident footprint for too-large graphs
    t0 = time.perf_counter()
    csr = preprocess_host_offload(edges, n_nodes=n)
    t = count_triangles_csr(csr)
    print(f"host-offload preprocess + count: T={t} "
          f"({(time.perf_counter()-t0)*1e3:.0f} ms)")

    t0 = time.perf_counter()
    assert count_triangles(edges) == t
    print(f"all-device path agrees          ({(time.perf_counter()-t0)*1e3:.0f} ms)")

    for p in (0.25, 0.1):
        t0 = time.perf_counter()
        est = count_triangles_doulion(edges, p=p, seed=0)
        err = abs(est - t) / t * 100
        print(f"DOULION p={p:<4}: T≈{est:,.0f} err={err:.1f}% "
              f"({(time.perf_counter()-t0)*1e3:.0f} ms)")

    if len(jax.devices()) > 1:
        from repro.launch.mesh import make_local_mesh

        mesh = make_local_mesh()
        t0 = time.perf_counter()
        td = count_triangles_distributed(edges, mesh)
        print(f"distributed over {len(jax.devices())} devices: T={td} "
              f"({(time.perf_counter()-t0)*1e3:.0f} ms)")


if __name__ == "__main__":
    main()
