"""Incremental counter: delta updates must match the from-scratch oracle.

The acceptance contract (ISSUE 2): after ANY interleaving of insert and
delete batches, ``IncrementalTriangleCounter.count`` equals
``TriangleCounter(method="auto").count(current_edges)`` — including under
a ``max_wedge_chunk`` budget — and the per-node incidences match the
engine's.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image has no hypothesis; use the local stub
    from _hypothesis_stub import given, settings, st

from repro.core import IncrementalTriangleCounter, TriangleCounter
from repro.graphs import (
    barabasi_albert,
    kronecker_rmat,
    sliding_window_stream,
    temporal_edge_stream,
    undirected_pairs,
    watts_strogatz,
)
from repro.graphs.formats import canonicalize_edges


@pytest.fixture(scope="module")
def stream_graphs():
    return {
        "kron8": kronecker_rmat(8, seed=0),
        "barabasi_albert": barabasi_albert(300, 5, seed=0),
        "watts_strogatz": watts_strogatz(400, 8, 0.1, seed=0),
    }


def oracle(counter: IncrementalTriangleCounter) -> int:
    return TriangleCounter(method="auto").count(
        counter.current_edges(), n_nodes=counter.n_nodes
    )


def oracle_per_node(counter: IncrementalTriangleCounter) -> np.ndarray:
    return TriangleCounter(method="auto").per_node(
        counter.current_edges(), n_nodes=counter.n_nodes
    )


# ---------------------------------------------------------------------------
# stream replay vs oracle (all generators)
# ---------------------------------------------------------------------------


def test_temporal_stream_matches_oracle_all_generators(stream_graphs):
    for name, e in stream_graphs.items():
        expect = TriangleCounter(method="auto").count(e)
        ctr = IncrementalTriangleCounter()
        for batch in temporal_edge_stream(e, batch_size=700, seed=1):
            ctr.apply(insert=batch.insert, delete=batch.delete)
        assert ctr.count == expect, name
        np.testing.assert_array_equal(
            ctr.per_node(), TriangleCounter().per_node(e, n_nodes=ctr.n_nodes)
        )


def test_sliding_window_stream_matches_oracle(stream_graphs):
    e = stream_graphs["kron8"]
    live = set()
    ctr = IncrementalTriangleCounter(max_wedge_chunk=4096)
    for batch in sliding_window_stream(e, window=900, batch_size=300, seed=2):
        ctr.apply(insert=batch.insert, delete=batch.delete)
        live |= {tuple(r) for r in batch.insert}
        live -= {tuple(r) for r in batch.delete}
        assert ctr.n_edges == len(live)
    # deletes actually happened, and the final state matches the oracle
    assert len(live) == 900
    assert ctr.count == oracle(ctr)
    np.testing.assert_array_equal(ctr.per_node(), oracle_per_node(ctr))
    # live edge set round-trips exactly (compare as packed directed keys)
    expect_edges = canonicalize_edges(np.array(sorted(live)))
    key = lambda a: np.sort(a[:, 0].astype(np.int64) << 32 | a[:, 1].astype(np.int64))
    np.testing.assert_array_equal(key(ctr.current_edges()), key(expect_edges))


def test_bootstrap_matches_engine(stream_graphs):
    for name, e in stream_graphs.items():
        ctr = IncrementalTriangleCounter(e)
        tc = TriangleCounter(method="auto")
        assert ctr.count == tc.count(e), name
        np.testing.assert_array_equal(
            ctr.per_node(), tc.per_node(e, n_nodes=ctr.n_nodes)
        )


# ---------------------------------------------------------------------------
# property: arbitrary interleavings
# ---------------------------------------------------------------------------


@st.composite
def op_sequences(draw):
    n = draw(st.integers(4, 12))
    n_ops = draw(st.integers(1, 4))
    ops = []
    for _ in range(n_ops):
        kind = draw(st.sampled_from(["insert", "delete"]))
        k = draw(st.integers(0, 10))
        pairs = draw(
            st.lists(
                st.tuples(st.integers(0, n - 1), st.integers(0, n - 1)),
                min_size=k,
                max_size=k,
            )
        )
        ops.append((kind, np.array(pairs, np.int64).reshape(-1, 2)))
    return ops


@settings(max_examples=8, deadline=None)
@given(op_sequences())
def test_property_interleavings_match_oracle(ops):
    ctr = IncrementalTriangleCounter()
    live = set()
    for kind, batch in ops:
        if kind == "insert":
            ctr.insert(batch)
            live |= {(min(a, b), max(a, b)) for a, b in batch if a != b}
        else:
            ctr.delete(batch)
            live -= {(min(a, b), max(a, b)) for a, b in batch if a != b}
    assert ctr.n_edges == len(live)
    if not live:
        assert ctr.count == 0
        return
    edges = canonicalize_edges(np.array(sorted(live)))
    tc = TriangleCounter(method="auto")
    assert ctr.count == tc.count(edges, n_nodes=ctr.n_nodes)
    np.testing.assert_array_equal(
        ctr.per_node(), tc.per_node(edges, n_nodes=ctr.n_nodes)
    )


# ---------------------------------------------------------------------------
# edge cases
# ---------------------------------------------------------------------------


def test_empty_batch_is_noop():
    ctr = IncrementalTriangleCounter([[0, 1], [1, 2], [0, 2]])
    assert ctr.count == 1
    assert ctr.insert(np.empty((0, 2))) == 0
    assert ctr.delete(np.empty((0, 2))) == 0
    assert ctr.apply() == 0
    assert ctr.count == 1
    assert ctr.last_update_stats.op == "noop"


def test_duplicates_and_self_loops_in_batch():
    ctr = IncrementalTriangleCounter()
    # self loops dropped, duplicates (both orders) collapse to one edge each
    delta = ctr.insert([[0, 0], [0, 1], [1, 0], [1, 2], [1, 2], [2, 0], [5, 5]])
    assert ctr.n_edges == 3
    assert delta == 1 and ctr.count == 1
    # re-inserting present edges is a no-op
    assert ctr.insert([[0, 1], [2, 1]]) == 0
    assert ctr.count == 1


def test_delete_never_inserted_edge():
    ctr = IncrementalTriangleCounter([[0, 1], [1, 2], [0, 2]])
    assert ctr.delete([[3, 7]]) == 0          # never inserted
    assert ctr.delete([[0, 3]]) == 0          # touches a live node, absent edge
    assert ctr.count == 1 and ctr.n_edges == 3
    # a mixed batch removes only what exists
    assert ctr.delete([[1, 2], [8, 9]]) == -1
    assert ctr.count == 0 and ctr.n_edges == 2


def test_probe_backend_method_axis(stream_graphs):
    """The acceptance criterion for streams: incremental deltas are
    bit-identical across wedge/panel/pallas probe backends at ≥2 chunk
    budgets, with the stats proving which backend ran the probes."""
    e = stream_graphs["kron8"]
    for budget in (None, 2048):
        ctrs = {
            m: IncrementalTriangleCounter(max_wedge_chunk=budget, method=m)
            for m in ("wedge_bsearch", "panel", "pallas")
        }
        for batch in sliding_window_stream(e, window=500, batch_size=250, seed=7):
            deltas = {
                m: c.apply(insert=batch.insert, delete=batch.delete)
                for m, c in ctrs.items()
            }
            assert len(set(deltas.values())) == 1, (budget, deltas)
        for m, c in ctrs.items():
            assert c.last_update_stats.probe_method == m
            assert c.count == ctrs["wedge_bsearch"].count
            np.testing.assert_array_equal(
                c.per_node(), ctrs["wedge_bsearch"].per_node()
            )
        assert ctrs["wedge_bsearch"].count == oracle(ctrs["wedge_bsearch"])


def test_auto_method_keeps_wedge_probes():
    """method="auto" (the serving default) probes on the wedge backend."""
    ctr = IncrementalTriangleCounter(method="auto")
    ctr.insert([[0, 1], [1, 2], [0, 2]])
    assert ctr.last_update_stats.probe_method == "wedge_bsearch"
    assert ctr.count == 1


def test_budget_below_single_delta_fanout(stream_graphs):
    """max_wedge_chunk=1 cannot split one edge's adjacency: the probe
    buffer is bumped to the max fan-out and the count stays exact."""
    e = stream_graphs["kron8"]
    expect = TriangleCounter(method="auto").count(e)
    max_deg = int(np.bincount(e[:, 0]).max())
    ctr = IncrementalTriangleCounter(max_wedge_chunk=1)
    for batch in temporal_edge_stream(e, batch_size=400, seed=4):
        ctr.apply(insert=batch.insert, delete=batch.delete)
        st_ = ctr.last_update_stats
        assert st_.n_probe_launches >= 3          # three probes, chunked
        # bumped to (at most) the worst single fan-out — the shorter-side
        # probe is bounded by the max degree — never the whole workload
        assert st_.peak_wedge_buffer <= max_deg
    assert ctr.count == expect


def test_budget_honored_and_exact(stream_graphs):
    e = stream_graphs["watts_strogatz"]
    budget = 2048
    ctr = IncrementalTriangleCounter(max_wedge_chunk=budget)
    for batch in sliding_window_stream(e, window=800, batch_size=250, seed=5):
        ctr.apply(insert=batch.insert, delete=batch.delete)
        # WS degrees are far below the budget, so it must be obeyed exactly
        assert ctr.last_update_stats.peak_wedge_buffer <= budget
    assert ctr.count == oracle(ctr)


def test_node_growth_and_queries():
    ctr = IncrementalTriangleCounter([[0, 1], [1, 2], [0, 2]])
    assert ctr.n_nodes == 3
    ctr.insert([[2, 50], [0, 50]])
    assert ctr.n_nodes == 51
    assert ctr.count == 2
    cc = ctr.clustering()
    assert cc.shape == (51,)
    assert (cc >= 0).all() and (cc <= 1).all()
    edges = ctr.current_edges()
    from repro.core import transitivity

    assert abs(ctr.transitivity() - transitivity(edges)) < 1e-12
    assert ctr.degrees().sum() == edges.shape[0]


def test_rejects_bad_args():
    with pytest.raises(ValueError):
        IncrementalTriangleCounter(max_wedge_chunk=0)
    ctr = IncrementalTriangleCounter()
    with pytest.raises(ValueError):
        ctr.insert([[-1, 2]])


# ---------------------------------------------------------------------------
# streams
# ---------------------------------------------------------------------------


def test_streams_are_reproducible_and_cover(stream_graphs):
    e = stream_graphs["kron8"]
    und = undirected_pairs(e)
    a = list(temporal_edge_stream(e, batch_size=128, seed=9))
    b = list(temporal_edge_stream(e, batch_size=128, seed=9))
    assert len(a) == len(b) == -(-und.shape[0] // 128)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x.insert, y.insert)
        assert x.delete.shape[0] == 0
    total = np.concatenate([x.insert for x in a])
    assert total.shape[0] == und.shape[0]
    # sliding window keeps the live set at exactly `window` once saturated
    sizes = []
    live = 0
    for batch in sliding_window_stream(e, window=300, batch_size=100, seed=9):
        live += batch.insert.shape[0] - batch.delete.shape[0]
        sizes.append(live)
    assert max(sizes) == 300 and sizes[-1] == 300


def test_stream_rejects_bad_args(stream_graphs):
    e = stream_graphs["kron8"]
    with pytest.raises(ValueError):
        next(temporal_edge_stream(e, batch_size=0))
    with pytest.raises(ValueError):
        next(sliding_window_stream(e, window=0, batch_size=10))
