"""Flash-attention Pallas kernel + jnp scan vs dense oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref
from repro.models.attention import decode_attention, flash_attention_jnp


def qkv(rng, b, hq, hkv, sq, skv, d, dtype=jnp.float32):
    mk = lambda *s: jnp.asarray(rng.normal(size=s), dtype)
    return mk(b, hq, sq, d), mk(b, hkv, skv, d), mk(b, hkv, skv, d)


CASES = [
    (2, 4, 4, 128, 128, 64, True),
    (1, 8, 2, 256, 256, 128, True),   # GQA 4×
    (2, 4, 1, 64, 192, 32, False),    # MQA, non-divisible kv blocks
    (1, 2, 2, 100, 100, 64, True),    # ragged tiles
    (1, 4, 4, 96, 320, 64, True),     # kv longer than q (chunked prefill)
]


@pytest.mark.parametrize("b,hq,hkv,sq,skv,d,causal", CASES)
def test_pallas_matches_ref(b, hq, hkv, sq, skv, d, causal, rng):
    q, k, v = qkv(rng, b, hq, hkv, sq, skv, d)
    ref = attention_ref(q, k, v, causal=causal)
    got = flash_attention_pallas(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(got), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("b,hq,hkv,sq,skv,d,causal", CASES)
def test_jnp_scan_matches_ref(b, hq, hkv, sq, skv, d, causal, rng):
    q, k, v = qkv(rng, b, hq, hkv, sq, skv, d)
    ref = attention_ref(q, k, v, causal=causal)
    got = flash_attention_jnp(q, k, v, causal=causal, block_k=64)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(got), rtol=2e-5, atol=2e-5)


def test_bf16_tolerance(rng):
    q, k, v = qkv(rng, 1, 4, 2, 128, 128, 64, jnp.bfloat16)
    ref = attention_ref(q, k, v).astype(jnp.float32)
    got = flash_attention_pallas(q, k, v).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(got), rtol=3e-2, atol=3e-2)


def test_block_size_independence(rng):
    q, k, v = qkv(rng, 1, 2, 2, 256, 256, 32)
    outs = [
        np.asarray(flash_attention_pallas(q, k, v, block_q=bq, block_k=bk))
        for bq, bk in [(64, 64), (128, 256), (256, 128)]
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, rtol=2e-5, atol=2e-5)


def test_decode_matches_last_row_of_prefill(rng):
    b, hq, hkv, s, d = 2, 8, 2, 96, 64
    q, k, v = qkv(rng, b, hq, hkv, s, s, d)
    full = attention_ref(q, k, v, causal=True)
    dec = decode_attention(q[:, :, -1:], k, v, cache_len=s)
    np.testing.assert_allclose(
        np.asarray(full[:, :, -1:]), np.asarray(dec), rtol=2e-5, atol=2e-5
    )


def test_decode_grad_free_and_jittable(rng):
    q, k, v = qkv(rng, 1, 4, 4, 32, 32, 16)
    f = jax.jit(lambda q, k, v: decode_attention(q[:, :, -1:], k, v, cache_len=20))
    out = f(q, k, v)
    assert bool(jnp.isfinite(out).all())
