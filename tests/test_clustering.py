"""Clustering coefficient / transitivity."""
import numpy as np

from repro.core import (
    average_clustering_coefficient,
    local_clustering_coefficient,
    node_triangle_features,
    transitivity,
)
from repro.graphs import canonicalize_edges


def complete_graph(n):
    pairs = [(i, j) for i in range(n) for j in range(i + 1, n)]
    return canonicalize_edges(np.array(pairs))


def test_complete_graph_is_fully_clustered():
    e = complete_graph(6)
    cc = np.asarray(local_clustering_coefficient(e))
    np.testing.assert_allclose(cc, 1.0)
    assert abs(transitivity(e) - 1.0) < 1e-6


def test_star_graph_has_zero_clustering():
    e = canonicalize_edges(np.array([(0, i) for i in range(1, 7)]))
    assert average_clustering_coefficient(e) == 0.0
    assert transitivity(e) == 0.0


def test_bounds(small_graphs):
    for e in small_graphs.values():
        cc = np.asarray(local_clustering_coefficient(e))
        assert (cc >= 0).all() and (cc <= 1.0 + 1e-6).all()
        t = transitivity(e)
        assert 0.0 <= t <= 1.0


def test_triangle_features_shape(small_graphs):
    e = small_graphs["er"]
    n = int(e.max()) + 1
    f = np.asarray(node_triangle_features(e))
    assert f.shape == (n, 3)
    # degree column matches histogram
    deg = np.bincount(e[:, 0], minlength=n)
    np.testing.assert_array_equal(f[:, 0], deg)
