"""repro.serve: fusion bit-identity, snapshots, residency, admission.

The subsystem's correctness bar:

* a fused multi-query window answers **bit-identically** to sequential
  execution of the same queries, on every kernel backend;
* kill → restore → resume equals the uninterrupted session (global
  count, per-node incidences, live edge set, and pending work);
* eviction under a tight memory budget round-trips (re-admission gives
  the same answers);
* the per-class timeout and queue-overflow policies actually fire.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import IncrementalTriangleCounter, TriangleCounter
from repro.core.engine import degree_histogram
from repro.graphs import STREAM_GENERATORS
from repro.graphs.generators import kronecker_rmat
from repro.serve import (
    AdmissionQueue,
    ClassPolicy,
    GraphManager,
    GraphService,
    QueryTimeout,
    QueueOverflow,
    SnapshotStore,
    StreamSession,
    attest_fusion,
    drive_stream,
)

KARATE = "karate"


@pytest.fixture
def manager(tmp_path):
    return GraphManager(str(tmp_path / "cache"))


def _service(manager, **kw):
    kw.setdefault("method", "wedge_bsearch")
    return GraphService(manager, **kw)


# ---------------------------------------------------------------------------
# fused == sequential, bit-identical, across backends
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", ["wedge_bsearch", "panel", "pallas"])
def test_fused_batch_bit_identical_to_sequential(manager, method):
    """One fused window's answers == one-at-a-time answers, per backend."""
    kinds = ["count", "per_node", "clustering", "transitivity",
             "count", "clustering"]
    # sequential oracle: fresh engine per query, no fusion possible
    engine = TriangleCounter(method=method)
    manager.attach(KARATE, KARATE)
    with manager.lease(KARATE) as ent:
        csr = ent.csr
        deg, _ = degree_histogram(csr)
        seq = {
            "count": engine.count(csr),
            "per_node": engine.per_node(csr),
            "clustering": engine.clustering(csr),
            "transitivity": engine.transitivity(csr),
        }

    # fused: queue the whole window against a stopped service, then start
    with GraphService(manager, method=method, start=False) as svc:
        tickets = [svc.submit(KARATE, k) for k in kinds]
        before = _engine_passes()
        svc.start()
        answers = [t.result(120.0) for t in tickets]
        assert _engine_passes() - before == 1  # the whole window: one pass

    for kind, got in zip(kinds, answers):
        want = seq[kind]
        if kind in ("per_node",):
            assert got.dtype == want.dtype and np.array_equal(got, want)
        elif kind == "clustering":
            # identical helper on the identical per-node artifact: bit-equal
            assert np.array_equal(got, want)
        else:
            assert got == want  # exact ints / identical float derivation


def _engine_passes() -> int:
    from repro import obs

    return int(obs.metrics_snapshot()["counters"].get("serve.engine_passes", 0))


def test_support_matches_engine(manager):
    manager.attach(KARATE, KARATE)
    with _service(manager) as svc:
        got = svc.query(KARATE, "support", timeout=120.0)
    engine = TriangleCounter(method="wedge_bsearch")
    with manager.lease(KARATE) as ent:
        want = engine.edge_support(ent.csr)
    assert np.array_equal(got, want)
    assert int(got.sum(dtype=np.int64)) == 3 * 45


def test_attest_fusion_helper(manager):
    manager.attach(KARATE, KARATE)
    with _service(manager, start=False) as svc:
        rep = attest_fusion(svc, KARATE, n=12)
    assert rep["fused"] and rep["consistent"] and rep["count"] == 45
    assert rep["engine_passes"] == 1 and rep["fused_queries"] == 12


# ---------------------------------------------------------------------------
# snapshot → restart → resume == uninterrupted
# ---------------------------------------------------------------------------


def _stream(edges, **kw):
    kw.setdefault("window", 300)
    kw.setdefault("batch_size", 64)
    kw.setdefault("seed", 5)
    return STREAM_GENERATORS["sliding_window"](edges, **kw)


def test_snapshot_restore_resume_equals_uninterrupted(tmp_path):
    edges = kronecker_rmat(7, edge_factor=8, seed=3)
    n_nodes = int(edges.max()) + 1

    oracle, _ = drive_stream(_stream(edges), n_nodes=n_nodes, max_batches=9,
                             queries_per_batch=1)

    store = SnapshotStore(str(tmp_path / "snap"), keep=2)
    killed, rep1 = drive_stream(_stream(edges), n_nodes=n_nodes, max_batches=5,
                                queries_per_batch=1, snapshot_store=store,
                                snapshot_every=2)
    assert rep1["resume"]["snapshots_written"] >= 2
    # "restart": a brand-new store+session restored from disk
    sess, extra = SnapshotStore(str(tmp_path / "snap")).restore_session("s")
    assert sess.cursor == 5 and extra["count"] == killed.count
    resumed, rep2 = drive_stream(_stream(edges), n_nodes=n_nodes, max_batches=9,
                                 queries_per_batch=1, session=sess)
    assert rep2["resume"]["skipped_batches"] == 5
    assert resumed.count == oracle.count
    assert np.array_equal(resumed.per_node(), oracle.per_node())
    assert np.array_equal(resumed.current_edges(), oracle.current_edges())


def test_snapshot_restore_preserves_pending_batches(tmp_path):
    """Queued updates submitted before a snapshot are ordered with it:
    the snapshot lands *after* everything ahead of it in the update lane,
    so restore + the post-snapshot tail equals the uninterrupted run."""
    edges = kronecker_rmat(6, edge_factor=8, seed=11)
    n_nodes = int(edges.max()) + 1
    batches = list(_stream(edges, window=200, batch_size=32, seed=2))
    assert len(batches) >= 6
    store = SnapshotStore(str(tmp_path / "snap"))

    mgr = GraphManager(str(tmp_path / "cache"))
    with _service(mgr, start=False) as svc:
        svc.open_session("g", n_nodes=n_nodes)
        pre = [svc.update("g", insert=b.insert, delete=b.delete)
               for b in batches[:4]]
        snap_ticket = svc.snapshot("g", store)
        post = [svc.update("g", insert=b.insert, delete=b.delete)
                for b in batches[4:6]]
        svc.start()
        for t in pre + [snap_ticket] + post:
            t.result(120.0)
        final_live = svc.session("g").counter

    # uninterrupted oracle over all 6 batches
    oracle = IncrementalTriangleCounter(n_nodes=n_nodes)
    for b in batches[:6]:
        oracle.apply(insert=b.insert, delete=b.delete)
    assert final_live.count == oracle.count

    # restore the snapshot (taken at cursor 4) and replay the tail
    sess, _ = SnapshotStore(str(tmp_path / "snap")).restore_session("g2")
    assert sess.cursor == 4
    for b in batches[4:6]:
        sess.apply(insert=b.insert, delete=b.delete)
    assert sess.counter.count == oracle.count
    assert np.array_equal(sess.counter.per_node(), oracle.per_node())


def test_session_state_roundtrip_rejects_tampering():
    sess = StreamSession("s", n_nodes=8)
    sess.apply(insert=np.array([[0, 1], [1, 2], [0, 2], [2, 3]], np.int64))
    tree = sess.state_tree()
    back = StreamSession.from_state("s", tree)
    assert back.counter.count == sess.counter.count == 1
    bad = dict(tree)
    bad["deg"] = tree["deg"].copy()
    bad["deg"][0] += 1  # inconsistent with adjacency
    with pytest.raises(ValueError):
        StreamSession.from_state("s", bad)


# ---------------------------------------------------------------------------
# residency: eviction + re-admission under a tight budget
# ---------------------------------------------------------------------------


def test_eviction_readmission_roundtrip(tmp_path):
    mgr = GraphManager(str(tmp_path / "cache"), memory_budget_bytes=1)
    mgr.attach("a", KARATE)
    mgr.attach("b", KARATE, fallback_scale=None)
    with _service(mgr) as svc:
        first = svc.query("a", "count", timeout=120.0)
        assert mgr.resident_names() == ["a"]
        svc.query("b", "count", timeout=120.0)  # budget forces "a" out
        assert "a" not in mgr.resident_names()
        again = svc.query("a", "count", timeout=120.0)  # re-admission
    assert first == again == 45
    st = mgr.stats()
    assert st["graphs"]["a"]["loads"] == 2  # loaded, evicted, reloaded
    from repro import obs

    assert obs.metrics_snapshot()["counters"].get("serve.graph_evictions", 0) >= 1


def test_pinned_graphs_never_evicted(tmp_path):
    mgr = GraphManager(str(tmp_path / "cache"), memory_budget_bytes=1)
    mgr.attach("a", KARATE)
    mgr.attach("b", KARATE)
    with mgr.lease("a") as ent:
        assert ent.resident
        with mgr.lease("b"):
            pass  # "a" is pinned: budget overshoots instead of evicting it
        assert "a" in mgr.resident_names()
    assert mgr.evict("a")  # unpinned now


def test_budget_charges_resident_not_decompressed_bytes(tmp_path):
    # The budget must charge what a graph actually holds resident: a
    # compressed attachment admits under a budget its decompressed CSR
    # would blow, and answers identically (per-node in original ids).
    rng = np.random.default_rng(7)
    e = rng.integers(0, 400, size=(6000, 2))
    e = e[e[:, 0] != e[:, 1]]
    src = tmp_path / "g.txt"
    np.savetxt(src, e, fmt="%d")

    sizer = GraphManager(str(tmp_path / "cache"))
    sizer.attach("flat", str(src))
    sizer.attach("z", str(src), storage="compressed", order="degree")
    with sizer.lease("flat") as ent:
        flat_bytes = ent.nbytes
        flat_count = TriangleCounter(method="wedge_bsearch").count(ent.csr)
        flat_pn = TriangleCounter(method="wedge_bsearch").per_node(ent.csr)
    with sizer.lease("z") as ent:
        z_bytes = ent.nbytes
    assert z_bytes < flat_bytes / 2  # compressed residency is the small one

    # a budget only the compressed form fits
    budget = z_bytes + (flat_bytes - z_bytes) // 4
    mgr = GraphManager(str(tmp_path / "cache"), memory_budget_bytes=budget)
    mgr.attach("z", str(src), storage="compressed", order="degree")
    with _service(mgr) as svc:
        assert svc.query("z", "count", timeout=120.0) == flat_count
        pn = svc.query("z", "per_node", timeout=120.0)
    assert np.array_equal(pn, flat_pn)  # mapped back through the perm
    assert mgr.resident_bytes() <= budget

    # the decompressed size would NOT have fit: a flat attachment under
    # the same budget loads but overshoots (recorded, not failed)
    mgr.attach("flat", str(src))
    with mgr.lease("flat"):
        from repro import obs

        over = obs.metrics_snapshot()["counters"].get(
            "serve.budget_overcommit", 0)
    assert over >= 0  # flat load either evicted z or overcommitted
    assert "flat" in mgr.resident_names()


def test_unattached_graph_rejects(manager):
    with _service(manager) as svc:
        with pytest.raises(KeyError):
            svc.query("nope", "count", timeout=30.0)


# ---------------------------------------------------------------------------
# admission: timeouts + overflow
# ---------------------------------------------------------------------------


def test_timeout_policy_expires_stale_requests(manager):
    manager.attach(KARATE, KARATE)
    policies = {"point": ClassPolicy(max_queue=64, timeout_s=0.0, max_batch=8)}
    with _service(manager, policies=policies, start=False) as svc:
        tickets = [svc.submit(KARATE, "count") for _ in range(3)]
        time.sleep(0.01)  # any positive queue wait exceeds timeout_s=0
        svc.start()
        for t in tickets:
            with pytest.raises(QueryTimeout):
                t.result(60.0)
    from repro import obs

    assert obs.metrics_snapshot()["counters"]["serve.timeouts"] >= 3


def test_queue_overflow_rejects_at_admission(manager):
    manager.attach(KARATE, KARATE)
    policies = {"point": ClassPolicy(max_queue=2, timeout_s=None, max_batch=8)}
    with _service(manager, policies=policies, start=False) as svc:
        svc.submit(KARATE, "count")
        svc.submit(KARATE, "count")
        with pytest.raises(QueueOverflow):
            svc.submit(KARATE, "count")
        svc.start()  # drain the two admitted ones cleanly


def test_heavy_lane_does_not_block_point_lane(manager):
    """A slow heavy request must not delay point lookups (separate lanes)."""
    manager.attach(KARATE, KARATE)
    with _service(manager) as svc:
        heavy = svc.submit(KARATE, "truss")  # slowest kind in the repo
        t0 = time.perf_counter()
        got = svc.query(KARATE, "count", timeout=60.0)
        point_latency = time.perf_counter() - t0
        assert got == 45
        heavy.result(300.0)
    # the point query must not have waited for the truss decomposition;
    # generous bound — it shares a GIL, not a queue
    assert point_latency < 30.0


def test_close_rejects_pending(manager):
    manager.attach(KARATE, KARATE)
    svc = _service(manager, start=False)
    t = svc.submit(KARATE, "count")
    svc.close()
    with pytest.raises(RuntimeError):
        t.result(10.0)
    with pytest.raises(RuntimeError):
        svc.submit(KARATE, "count")


# ---------------------------------------------------------------------------
# admission queue unit behavior
# ---------------------------------------------------------------------------


def test_collect_respects_max_batch_and_order():
    q = AdmissionQueue({"point": ClassPolicy(max_queue=16, max_batch=3)})
    from repro.serve.admission import Request, Ticket

    for i in range(5):
        q.submit(Request("g", "count", {"i": i}, "point", Ticket("count", "point")))
    first = q.collect(("point",))
    assert [r.params["i"] for r in first] == [0, 1, 2]
    second = q.collect(("point",))
    assert [r.params["i"] for r in second] == [3, 4]


def test_collect_blocks_until_submit_or_close():
    q = AdmissionQueue({"point": ClassPolicy()})
    got = []

    def worker():
        got.append(q.collect(("point",)))

    t = threading.Thread(target=worker)
    t.start()
    time.sleep(0.05)
    assert t.is_alive()  # blocked: nothing queued
    q.close()
    t.join(10.0)
    assert got == [[]]


def test_concurrent_load_fuses_and_stays_correct(manager):
    """Many threads hammering one graph: every answer right, fewer passes
    than queries (continuous batching under concurrency)."""
    manager.attach(KARATE, KARATE)
    results = []
    lock = threading.Lock()
    with _service(manager) as svc:
        def client():
            for _ in range(5):
                c = svc.query(KARATE, "count", timeout=120.0)
                with lock:
                    results.append(c)

        threads = [threading.Thread(target=client) for _ in range(6)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    assert results and all(c == 45 for c in results)
