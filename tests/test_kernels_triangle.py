"""Pallas triangle-intersection kernel vs jnp oracle: shape/dtype sweep."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image has no hypothesis; use the local stub
    from _hypothesis_stub import given, settings, st

from repro.core import bucketize_edges, count_triangles, gather_panels, preprocess
from repro.kernels.triangle_count import intersect_count_pallas
from repro.kernels.triangle_count.ref import intersect_count_ref


def random_panels(rng, b, l, dtype):
    rows = []
    for _ in range(b):
        n = int(rng.integers(0, l + 1))
        vals = np.sort(rng.choice(4 * l + 8, size=n, replace=False))
        rows.append(np.concatenate([vals, -np.ones(l - n)]).astype(dtype))
    return jnp.asarray(np.stack(rows))


@pytest.mark.parametrize("dtype", [np.int32, np.int16])
@pytest.mark.parametrize(
    "b,lu,lv",
    [(1, 8, 8), (5, 16, 64), (32, 128, 128), (9, 256, 1024), (2, 2048, 128), (64, 64, 32)],
)
def test_kernel_matches_ref(b, lu, lv, dtype, rng):
    a = random_panels(rng, b, lu, dtype)
    c = random_panels(rng, b, lv, dtype)
    ref = intersect_count_ref(a, c)
    got = intersect_count_pallas(a, c)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 17), st.sampled_from([8, 32, 96]), st.sampled_from([8, 48, 128]),
       st.integers(0, 2**31 - 1))
def test_kernel_property(b, lu, lv, seed):
    rng = np.random.default_rng(seed)
    a = random_panels(rng, b, lu, np.int32)
    c = random_panels(rng, b, lv, np.int32)
    np.testing.assert_array_equal(
        np.asarray(intersect_count_ref(a, c)), np.asarray(intersect_count_pallas(a, c))
    )


def test_degree_skew_bucketing(small_graphs):
    """Adversarial skew: star + clique mix exercises multiple buckets."""
    import jax.numpy as jnp

    e = small_graphs["kron"]
    csr = preprocess(jnp.asarray(e), n_nodes=int(e.max()) + 1)
    buckets = bucketize_edges(csr)
    assert sum(len(v) for v in buckets.values()) == csr.col.shape[0]
    total = 0
    for width, idx in buckets.items():
        a, b, al, bl = gather_panels(csr, jnp.asarray(idx), width)
        total += int(np.asarray(intersect_count_pallas(a, b)).sum())
    assert total == count_triangles(e)


def test_empty_rows():
    a = jnp.full((4, 16), -1, jnp.int32)
    b = jnp.full((4, 8), -1, jnp.int32)
    assert (np.asarray(intersect_count_pallas(a, b)) == 0).all()
