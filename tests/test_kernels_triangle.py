"""Pallas triangle-intersection kernel family vs jnp oracles.

Interpret-mode parity for every member — scalar count, per-node
(count + arm) and support (count + arm + closure) — across bucket
widths, including all-padding tiles, empty buckets and explicit tile
overrides (the autotuner's hook).
"""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image has no hypothesis; use the local stub
    from _hypothesis_stub import given, settings, st

from repro.core import bucketize_edges, count_triangles, gather_panels, preprocess
from repro.kernels.triangle_count import (
    intersect_count_pallas,
    intersect_per_node_pallas,
    intersect_support_pallas,
)
from repro.kernels.triangle_count.ref import (
    intersect_count_ref,
    intersect_per_node_ref,
    intersect_support_ref,
)


def random_panels(rng, b, l, dtype):
    rows = []
    for _ in range(b):
        n = int(rng.integers(0, l + 1))
        vals = np.sort(rng.choice(4 * l + 8, size=n, replace=False))
        rows.append(np.concatenate([vals, -np.ones(l - n)]).astype(dtype))
    return jnp.asarray(np.stack(rows))


def assert_family_matches_ref(a, c):
    """All three kernels agree with their oracles on one panel pair."""
    ref_cnt, ref_arm, ref_clo = intersect_support_ref(a, c)
    np.testing.assert_array_equal(
        np.asarray(intersect_count_pallas(a, c)), np.asarray(ref_cnt)
    )
    cnt, arm = intersect_per_node_pallas(a, c)
    np.testing.assert_array_equal(np.asarray(cnt), np.asarray(ref_cnt))
    np.testing.assert_array_equal(np.asarray(arm), np.asarray(ref_arm))
    cnt, arm, clo = intersect_support_pallas(a, c)
    np.testing.assert_array_equal(np.asarray(cnt), np.asarray(ref_cnt))
    np.testing.assert_array_equal(np.asarray(arm), np.asarray(ref_arm))
    np.testing.assert_array_equal(np.asarray(clo), np.asarray(ref_clo))


@pytest.mark.parametrize("dtype", [np.int32, np.int16])
@pytest.mark.parametrize(
    "b,lu,lv",
    [(1, 8, 8), (5, 16, 64), (32, 128, 128), (9, 256, 1024), (2, 2048, 128), (64, 64, 32)],
)
def test_kernel_matches_ref(b, lu, lv, dtype, rng):
    a = random_panels(rng, b, lu, dtype)
    c = random_panels(rng, b, lv, dtype)
    ref = intersect_count_ref(a, c)
    got = intersect_count_pallas(a, c)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


@pytest.mark.parametrize(
    "b,lu,lv",
    [(1, 8, 8), (5, 16, 64), (32, 128, 128), (9, 256, 1024), (2, 2048, 128), (64, 64, 32)],
)
def test_attribution_kernels_match_ref(b, lu, lv, rng):
    """Per-node and support kernels: every axis reduction matches the
    oracle across bucket widths (incl. v-tiling past TLv=512)."""
    a = random_panels(rng, b, lu, np.int32)
    c = random_panels(rng, b, lv, np.int32)
    assert_family_matches_ref(a, c)


def test_arm_closure_consistency(rng):
    """count == Σ arm == Σ closure row-wise — the 3-edge billing identity."""
    a = random_panels(rng, 17, 96, np.int32)
    c = random_panels(rng, 17, 160, np.int32)
    cnt, arm, clo = intersect_support_pallas(a, c)
    np.testing.assert_array_equal(
        np.asarray(cnt), np.asarray(arm).sum(axis=1)
    )
    np.testing.assert_array_equal(
        np.asarray(cnt), np.asarray(clo).sum(axis=1)
    )


def test_explicit_tile_override_parity(rng):
    """tiles=(TB, TLv) overrides (the autotuner hook) never change results."""
    a = random_panels(rng, 23, 64, np.int32)
    c = random_panels(rng, 23, 640, np.int32)
    ref_cnt, ref_arm, ref_clo = intersect_support_ref(a, c)
    for tiles in [(1, 128), (8, 256), (64, 512), (256, 4096)]:
        cnt, arm, clo = intersect_support_pallas(a, c, tiles=tiles)
        np.testing.assert_array_equal(np.asarray(cnt), np.asarray(ref_cnt)), tiles
        np.testing.assert_array_equal(np.asarray(arm), np.asarray(ref_arm))
        np.testing.assert_array_equal(np.asarray(clo), np.asarray(ref_clo))
        np.testing.assert_array_equal(
            np.asarray(intersect_count_pallas(a, c, tiles=tiles)),
            np.asarray(ref_cnt),
        )


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 17), st.sampled_from([8, 32, 96]), st.sampled_from([8, 48, 128]),
       st.integers(0, 2**31 - 1))
def test_kernel_property(b, lu, lv, seed):
    rng = np.random.default_rng(seed)
    a = random_panels(rng, b, lu, np.int32)
    c = random_panels(rng, b, lv, np.int32)
    assert_family_matches_ref(a, c)


def test_degree_skew_bucketing(small_graphs):
    """Adversarial skew: star + clique mix exercises multiple buckets."""
    import jax.numpy as jnp

    e = small_graphs["kron"]
    csr = preprocess(jnp.asarray(e), n_nodes=int(e.max()) + 1)
    buckets = bucketize_edges(csr)
    assert sum(len(v) for v in buckets.values()) == csr.col.shape[0]
    total = 0
    total_arm = 0
    for width, idx in buckets.items():
        a, b, al, bl = gather_panels(csr, jnp.asarray(idx), width)
        total += int(np.asarray(intersect_count_pallas(a, b)).sum())
        _, arm = intersect_per_node_pallas(a, b)
        total_arm += int(np.asarray(arm).sum())
    assert total == count_triangles(e)
    assert total_arm == total  # each hit has exactly one arm slot


def test_empty_rows():
    """All-padding tiles: every kernel sees only −1 and yields zeros."""
    a = jnp.full((4, 16), -1, jnp.int32)
    b = jnp.full((4, 8), -1, jnp.int32)
    assert (np.asarray(intersect_count_pallas(a, b)) == 0).all()
    cnt, arm = intersect_per_node_pallas(a, b)
    assert (np.asarray(cnt) == 0).all() and (np.asarray(arm) == 0).all()
    cnt, arm, clo = intersect_support_pallas(a, b)
    assert (np.asarray(cnt) == 0).all()
    assert (np.asarray(arm) == 0).all() and (np.asarray(clo) == 0).all()
