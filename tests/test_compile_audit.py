"""Compile-count regression tests: the O(log m) bucketing guarantee.

PR 4/5 promised in prose that pow2 bucketing keeps the trace-cache
population logarithmic across truss peel rounds and incremental probe
sessions; these tests assert it with `CompileAuditor` against the real jit
caches, so a planner change that leaks raw shapes to a kernel fails here
instead of as a silent recompile storm.
"""

import numpy as np
import pytest

from repro.check.runtime import CompileAuditor, RuntimeCheckError


def test_truss_kron10_traces_o_log_m():
    from repro.analytics import k_truss_decomposition
    from repro.graphs import kronecker_rmat

    e = kronecker_rmat(10, edge_factor=8, seed=5)
    with CompileAuditor() as aud:
        dec = k_truss_decomposition(e, max_wedge_chunk=1 << 14)
    # every peel round shrinks the live subgraph; bucketing must cap the
    # distinct shapes each kernel sees at ~log2(m) (empirically 16 at
    # m=6081, vs one-trace-per-round without bucketing)
    bound = aud.assert_log_bound(dec.n_edges, factor=2.0, slack=4)
    assert aud.total_new_traces > 0, "auditor observed no tracing at all"
    assert bound >= max(aud.new_traces.values())


@pytest.mark.slow
def test_incremental_session_traces_o_log_m():
    from repro.core.incremental import IncrementalTriangleCounter
    from repro.graphs import kronecker_rmat

    e = kronecker_rmat(10, edge_factor=8, seed=5)
    half = len(e) // 2
    tc = IncrementalTriangleCounter(e[:half], max_wedge_chunk=4096)
    with CompileAuditor() as aud:
        for i in range(6):
            lo = half + i * 200
            tc.insert(e[lo : lo + 200])
            tc.delete(e[lo : lo + 60])
    m = tc.current_edges().shape[0]
    aud.assert_log_bound(m, factor=2.0, slack=4)


def test_auditor_flags_unbucketed_shapes():
    """A kernel fed raw (unbucketed) shapes must blow the log bound."""
    import jax
    import jax.numpy as jnp

    @jax.jit
    def toy_kernel(x):
        return x.sum(dtype=jnp.int32)

    with CompileAuditor(extra_jitted={"toy_kernel": toy_kernel}) as aud:
        for n in range(1, 40):  # 39 distinct shapes, m=64 -> bound 16
            toy_kernel(jnp.zeros((n,), jnp.int32))
    with pytest.raises(RuntimeCheckError, match="compile-count bound"):
        aud.assert_log_bound(64, factor=2.0, slack=4)


def test_auditor_counts_are_deltas():
    import jax
    import jax.numpy as jnp

    @jax.jit
    def toy(x):
        return x + 1

    toy(jnp.zeros(3))  # traced before the block: must not be counted
    with CompileAuditor(extra_jitted={"toy": toy}) as aud:
        toy(jnp.zeros(3))  # cache hit
        toy(jnp.zeros(4))  # one new trace
    assert aud.new_traces["toy"] == 1
