"""Tile autotuner: candidate grid, cache round-trip, engine integration.

The acceptance smoke: a cold tune writes the versioned cache, a warm
load picks the *identical* tiles without re-measuring, and tuned tiles
never change kernel results (only their speed).
"""
import json
import os

import numpy as np
import pytest

from repro.core.tuning import (
    CACHE_VERSION,
    AutoTuner,
    TileCache,
    TileConfig,
    autotune_tiles,
    candidate_tiles,
    shape_key,
)


def test_candidate_grid_respects_vmem_budget():
    for (n, lu, lv) in [(32, 16, 16), (512, 256, 1024), (8, 4096, 4096)]:
        cands = candidate_tiles(n, lu, lv)
        assert cands, (n, lu, lv)
        for c in cands:
            assert c.block_edges * lu * min(c.tlv, lv) <= (1 << 21), (c, n, lu, lv)
            assert 1 <= c.block_edges <= max(n, 256)


def test_shape_key_pow2_buckets():
    assert shape_key(33, 64, 64) == shape_key(64, 64, 64)
    assert shape_key(64, 64, 64) != shape_key(65, 64, 64)
    assert shape_key(1, 16, 32) == "B1xLu16xLv32"


def test_cold_tune_then_warm_load_identical_tiles(tmp_path):
    """The CI acceptance smoke: cold tune → cache write → warm load picks
    the identical tiles (no re-measure, hit counted)."""
    path = tmp_path / "tiles.json"
    tuner = AutoTuner(path, tune_on_miss=True, iters=1)
    tiles_cold = tuner.tiles(24, 16, 16)
    assert tiles_cold is not None
    assert tuner.n_tuned == 1 and tuner.n_hits == 0
    assert path.exists()
    payload = json.loads(path.read_text())
    assert payload["version"] == CACHE_VERSION
    assert shape_key(24, 16, 16) in payload["entries"]
    # warm: a fresh tuner that may NOT tune must serve the same pick
    warm = AutoTuner(path, tune_on_miss=False)
    assert warm.cache.loaded_from_disk
    tiles_warm = warm.tiles(24, 16, 16)
    assert tiles_warm == tiles_cold
    assert warm.n_hits == 1 and warm.n_tuned == 0
    # same pow2 bucket (24 and 17 both round to B32) → same entry, no
    # new tuning even with tune_on_miss enabled
    again = AutoTuner(path, tune_on_miss=True, iters=1)
    assert again.tiles(17, 16, 16) == tiles_cold
    assert again.n_tuned == 0


def test_cache_discards_version_mismatch(tmp_path):
    path = tmp_path / "tiles.json"
    cache = TileCache(path)
    cache.put(shape_key(8, 16, 16), TileConfig(4, 128, 1.0))
    cache.save()
    payload = json.loads(path.read_text())
    payload["version"] = CACHE_VERSION + 1
    path.write_text(json.dumps(payload))
    stale = TileCache(path)
    assert not stale.loaded_from_disk and not stale.entries


def test_cache_discards_backend_mismatch(tmp_path):
    path = tmp_path / "tiles.json"
    cache = TileCache(path)
    cache.put(shape_key(8, 16, 16), TileConfig(4, 128, 1.0))
    cache.save()
    payload = json.loads(path.read_text())
    payload["backend"] = "not-a-backend"
    path.write_text(json.dumps(payload))
    stale = TileCache(path)
    assert not stale.loaded_from_disk and not stale.entries


def test_cache_survives_corrupt_file(tmp_path):
    path = tmp_path / "tiles.json"
    path.write_text("{ this is not json")
    cache = TileCache(path)  # must not raise
    assert not cache.entries
    cache.put("k", TileConfig(8, 128))
    cache.save()
    assert TileCache(path).get("k") == TileConfig(8, 128, 0.0)


def test_autotune_result_is_admissible():
    cfg = autotune_tiles(8, 16, 16, iters=1, warmup=0)
    assert cfg.block_edges * 16 * min(cfg.tlv, 16) <= (1 << 21)
    assert cfg.us > 0.0


def test_tuned_engine_matches_untuned(tmp_path, small_graphs):
    """A tuner-steered pallas counter is bit-identical to the untuned one
    and actually consults the cache."""
    from repro.core import TriangleCounter

    e = small_graphs["kron"]
    base = TriangleCounter(method="pallas")
    expect = base.count(e)
    pn0 = base.per_node(e)
    tuner = AutoTuner(tmp_path / "tiles.json", tune_on_miss=True, iters=1)
    tc = TriangleCounter(method="pallas", tuner=tuner)
    assert tc.count(e) == expect
    np.testing.assert_array_equal(tc.per_node(e), pn0)
    assert tuner.n_tuned + tuner.n_hits > 0
    # warm run, fresh process-level state: cache hits only
    warm_tuner = AutoTuner(tmp_path / "tiles.json", tune_on_miss=False)
    tc2 = TriangleCounter(method="pallas", tuner=warm_tuner)
    assert tc2.count(e) == expect
    assert warm_tuner.n_hits > 0 and warm_tuner.n_tuned == 0


def test_concurrent_caches_merge_instead_of_clobber(tmp_path):
    """Two engines sharing one cache file must not lose each other's
    entries: save() is read-merge-write, last-writer-wins per *key*.

    The seed wrote the in-memory view over the whole file, so whichever
    instance saved last erased the other's picks — the regression this
    pins down."""
    path = tmp_path / "tiles.json"
    a = TileCache(path)
    b = TileCache(path)
    ka, kb = shape_key(8, 16, 16), shape_key(64, 32, 32)
    a.put(ka, TileConfig(4, 128, 1.0))
    a.save()
    b.put(kb, TileConfig(16, 256, 2.0))
    b.save()  # seed behavior: would erase ka
    merged = TileCache(path)
    assert merged.get(ka) == TileConfig(4, 128, 1.0)
    assert merged.get(kb) == TileConfig(16, 256, 2.0)
    # per-key last-writer-wins: a re-save of ka with a new pick prevails
    a.put(ka, TileConfig(8, 256, 0.5))
    a.save()
    assert TileCache(path).get(ka) == TileConfig(8, 256, 0.5)
    assert TileCache(path).get(kb) == TileConfig(16, 256, 2.0)


def test_contended_saves_union_survives(tmp_path):
    """Many threads interleaving put+save on one file: the union of every
    thread's keys survives (no lost updates under contention)."""
    import threading

    path = tmp_path / "tiles.json"
    n_threads, keys_per = 6, 5
    errs = []

    def writer(tid):
        try:
            cache = TileCache(path)
            for i in range(keys_per):
                cache.put(f"t{tid}k{i}", TileConfig(8, 128, float(tid)))
                cache.save()
        except Exception as e:  # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=writer, args=(t,)) for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    final = TileCache(path)
    expect = {f"t{t}k{i}" for t in range(n_threads) for i in range(keys_per)}
    assert expect <= set(final.entries)
