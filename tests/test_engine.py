"""TriangleCounter engine: schedule unification + memory-bounded chunking.

The acceptance contract: every schedule agrees with the NumPy oracle on
the paper's graph families, and chunked counting (any `max_wedge_chunk`)
is bit-identical to the unchunked path while the materialized wedge
buffer never exceeds the budget.
"""
import numpy as np
import pytest

from repro.core import (
    TriangleCounter,
    accumulate_partials,
    choose_method,
    count_triangles,
    count_triangles_numpy,
    plan_edge_chunks,
    transitivity,
)
from repro.core.engine import METHODS
from repro.graphs import barabasi_albert, kronecker_rmat, watts_strogatz


@pytest.fixture(scope="module")
def family_graphs():
    """The acceptance-criteria graphs: kron10 / BA / WS."""
    return {
        "kron10": kronecker_rmat(10, seed=0),
        "barabasi_albert": barabasi_albert(2_000, 6, seed=0),
        "watts_strogatz": watts_strogatz(3_000, 10, 0.1, seed=0),
    }


@pytest.fixture(scope="module")
def family_oracle(family_graphs):
    return {name: count_triangles_numpy(e) for name, e in family_graphs.items()}


# ---------------------------------------------------------------------------
# schedule unification
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", ["wedge_bsearch", "panel", "pallas", "auto"])
def test_all_methods_match_numpy_oracle(family_graphs, family_oracle, method):
    for name, e in family_graphs.items():
        tc = TriangleCounter(method=method)
        assert tc.count(e) == family_oracle[name], (name, method)
        assert tc.last_stats is not None
        assert tc.last_stats.method in METHODS[1:]  # resolved, never "auto"


@pytest.mark.slow
def test_distributed_method_matches_oracle_multidevice(family_oracle):
    from conftest import run_multidevice

    out = run_multidevice("""
import jax
mesh = jax.make_mesh((2, 4), ("data", "model"))
from repro.core import TriangleCounter, count_triangles_numpy
from repro.graphs import kronecker_rmat, barabasi_albert, watts_strogatz
graphs = {
    "kron10": kronecker_rmat(10, seed=0),
    "barabasi_albert": barabasi_albert(2_000, 6, seed=0),
    "watts_strogatz": watts_strogatz(3_000, 10, 0.1, seed=0),
}
for name, e in graphs.items():
    expect = count_triangles_numpy(e)
    tc = TriangleCounter(method="distributed", mesh=mesh)
    assert tc.count(e) == expect, (name, tc.count(e), expect)
    # chunking composes with the striping: force several column chunks
    total = tc.last_stats.total_wedges
    tcc = TriangleCounter(method="distributed", mesh=mesh,
                          max_wedge_chunk=max(total // 64, 1))
    assert tcc.count(e) == expect, name
    assert tcc.last_stats.n_chunks >= 4, (name, tcc.last_stats)
print("OK")
""")
    assert "OK" in out


def test_distributed_single_device_mesh(family_graphs, family_oracle):
    """method="distributed" on the 1-device default mesh is still exact."""
    import jax

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    e = family_graphs["kron10"]
    tc = TriangleCounter(method="distributed", mesh=mesh)
    assert tc.count(e) == family_oracle["kron10"]


# ---------------------------------------------------------------------------
# memory-bounded chunking
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("divisor", [4, 16, 64])
def test_chunked_equals_unchunked_all_generators(family_graphs, family_oracle, divisor):
    for name, e in family_graphs.items():
        base = TriangleCounter(method="wedge_bsearch")
        expect = base.count(e)
        assert expect == family_oracle[name]
        total = base.last_stats.total_wedges
        budget = max(total // divisor, 1)
        tc = TriangleCounter(method="wedge_bsearch", max_wedge_chunk=budget)
        assert tc.count(e) == expect, (name, divisor)
        st = tc.last_stats
        assert st.n_chunks >= min(divisor, 2), (name, st)
        # budget respected: these budgets all exceed the forward-bound
        # max fan-out (≤ √(2m)), so the peak buffer must obey them exactly
        assert st.peak_wedge_buffer <= budget, (name, st)


def test_budget_forces_four_chunks_and_stays_bounded(family_graphs):
    e = family_graphs["kron10"]
    base = TriangleCounter(method="wedge_bsearch")
    expect = base.count(e)
    total = base.last_stats.total_wedges
    budget = total // 5
    tc = TriangleCounter(method="wedge_bsearch", max_wedge_chunk=budget)
    assert tc.count(e) == expect
    st = tc.last_stats
    assert st.n_chunks >= 4
    assert st.peak_wedge_buffer <= budget


def test_budget_below_single_edge_fanout(family_graphs, family_oracle):
    """A budget of 1 slot cannot split an adjacency list: the engine bumps
    the buffer to the max fan-out and still counts exactly."""
    for name, e in family_graphs.items():
        tc = TriangleCounter(method="wedge_bsearch", max_wedge_chunk=1)
        assert tc.count(e) == family_oracle[name], name
        st = tc.last_stats
        assert st.n_chunks >= 4
        # the effective buffer is bumped to (exactly) the largest
        # single-edge fan-out — far below the full wedge total
        assert st.peak_wedge_buffer < st.total_wedges
        assert st.peak_wedge_buffer <= int(np.sqrt(e.shape[0])) + 1


def test_panel_and_pallas_chunked(family_graphs, family_oracle):
    e = family_graphs["kron10"]
    for method in ["panel", "pallas"]:
        un = TriangleCounter(method=method)
        assert un.count(e) == family_oracle["kron10"]
        ck = TriangleCounter(method=method, max_wedge_chunk=512)
        assert ck.count(e) == family_oracle["kron10"], method
        assert ck.last_stats.n_chunks > un.last_stats.n_chunks, method
        # every panel gather stays within ~budget elements (one bucket row
        # may exceed it only when a single width-`w` row does)
        assert ck.last_stats.peak_wedge_buffer <= max(512, max(ck.widths))


def test_facade_kwarg_routes_chunking(family_graphs, family_oracle):
    e = family_graphs["kron10"]
    assert count_triangles(e, max_wedge_chunk=333) == family_oracle["kron10"]


def test_plan_edge_chunks_invariants():
    rng = np.random.default_rng(0)
    reps = rng.integers(0, 50, size=500)
    for budget in [None, 10_000, 1_000, 120, 49, 1]:
        bounds, eff = plan_edge_chunks(reps, budget)
        # exact cover, in order, no overlap
        assert bounds[0][0] == 0 and bounds[-1][1] == len(reps)
        for (a0, a1), (b0, b1) in zip(bounds, bounds[1:]):
            assert a1 == b0
        # every chunk within the effective budget
        for s, t in bounds:
            assert reps[s:t].sum() <= eff
        if budget is not None:
            assert eff >= min(budget, int(reps.max()))


# ---------------------------------------------------------------------------
# uint64 accumulation
# ---------------------------------------------------------------------------


def test_uint64_accumulation_regression():
    """Partial sums near int32 max must not wrap when combined on host —
    the paper's Table I counts (3.8B) exceed 2³¹."""
    near_max = np.int32(2**31 - 1)
    partials = [near_max] * 4
    expect = 4 * (2**31 - 1)  # 8589934588 > 2**32
    assert accumulate_partials(partials) == expect
    # mixed arrays and scalars, including empty
    parts = [np.array([near_max, near_max], np.int32), np.int32(7), np.array([], np.int32)]
    assert accumulate_partials(parts) == 2 * (2**31 - 1) + 7


def test_accumulation_matches_over_many_chunks(family_graphs, family_oracle):
    """Many tiny chunks exercise the host accumulation path end to end."""
    e = family_graphs["watts_strogatz"]
    tc = TriangleCounter(method="wedge_bsearch", max_wedge_chunk=64)
    assert tc.count(e) == family_oracle["watts_strogatz"]
    assert tc.last_stats.n_chunks > 100


# ---------------------------------------------------------------------------
# per-node / clustering / auto dispatch
# ---------------------------------------------------------------------------


def test_per_node_and_clustering_chunked(family_graphs, family_oracle):
    e = family_graphs["kron10"]
    tc = TriangleCounter(max_wedge_chunk=1_000)
    pn = tc.per_node(e)
    assert int(pn.sum()) // 3 == family_oracle["kron10"]
    cc = tc.clustering(e)
    assert cc.shape == pn.shape
    assert (cc >= 0).all() and (cc <= 1).all()
    assert abs(tc.transitivity(e) - transitivity(e)) < 1e-12


def test_auto_dispatch_stats():
    assert choose_method(max_out_degree=10, mean_out_degree=5.0, backend="cpu") == "panel"
    assert (
        choose_method(max_out_degree=4000, mean_out_degree=8.0, backend="cpu")
        == "wedge_bsearch"
    )
    assert (
        choose_method(max_out_degree=100, mean_out_degree=50.0, backend="tpu")
        == "pallas"
    )


def test_per_node_executes_configured_backend(family_graphs):
    """per_node now runs the configured backend natively — the stats must
    prove the non-wedge backend actually executed (no silent fallback)."""
    e = family_graphs["kron10"]
    for configured in ["panel", "pallas"]:
        tc = TriangleCounter(method=configured)
        tc.per_node(e)
        assert tc.last_stats.method == configured
        assert tc.last_stats.resolved_method == configured
        assert tc.last_stats.fallback_reason is None
    # auto dispatch: resolved is whatever choose_method picked, never "auto"
    tc = TriangleCounter(method="auto")
    tc.per_node(e)
    assert tc.last_stats.resolved_method in METHODS[1:]
    # count paths execute what they resolve
    tc2 = TriangleCounter(method="panel")
    tc2.count(e)
    assert tc2.last_stats.method == tc2.last_stats.resolved_method == "panel"


def test_per_node_and_support_bit_identical_across_backends(family_graphs):
    """The acceptance criterion: per-node and per-edge-support outputs are
    bit-identical across wedge/panel/pallas at ≥2 budgets, with
    EngineStats.method proving the non-wedge backend executed."""
    e = family_graphs["kron10"]
    base = TriangleCounter(method="wedge_bsearch")
    pn0 = base.per_node(e)
    sup0 = base.edge_support(e)
    assert int(sup0.sum()) == 3 * base.count(e)
    total = base.last_stats.total_wedges
    for method in ["panel", "pallas"]:
        for budget in [max(total // 4, 1), max(total // 16, 1)]:
            tc = TriangleCounter(method=method, max_wedge_chunk=budget)
            np.testing.assert_array_equal(tc.per_node(e), pn0)
            assert tc.last_stats.method == method
            assert tc.last_stats.n_chunks > 1
            np.testing.assert_array_equal(tc.edge_support(e), sup0)
            assert tc.last_stats.method == method


def test_distributed_runs_every_workload(family_graphs):
    """distributed now carries per_node/support kernels: on a 1×1 mesh every
    workload executes the striped schedule bit-identically — no fallback."""
    import jax

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    e = family_graphs["kron10"]
    base = TriangleCounter(method="wedge_bsearch")
    expect_count = base.count(e)
    pn0 = base.per_node(e)
    sup0 = base.edge_support(e)
    tc = TriangleCounter(method="distributed", mesh=mesh)
    assert tc.count(e) == expect_count
    assert tc.last_stats.method == "distributed"
    assert tc.last_stats.fallback_reason is None
    np.testing.assert_array_equal(tc.per_node(e), pn0)
    st = tc.last_stats
    assert st.method == "distributed"
    assert st.resolved_method == "distributed"
    assert st.fallback_reason is None
    assert st.n_stripes == 1
    np.testing.assert_array_equal(tc.edge_support(e), sup0)
    assert tc.last_stats.method == "distributed"
    assert tc.last_stats.fallback_reason is None


def test_capability_fallback_is_loud_and_not_sticky(family_graphs):
    """A backend lacking a kernel falls back loudly — and the recorded
    fallback_reason must not leak into the next (clean) call on the same
    reused counter."""
    import warnings

    from repro.core.engine import (
        WedgeBackend,
        register_backend,
        _BACKEND_FACTORIES,
        _warned_fallbacks,
    )

    class CountOnly(WedgeBackend):
        name = "count_only"
        capabilities = frozenset({"count"})

    e = family_graphs["kron10"]
    base = TriangleCounter(method="wedge_bsearch")
    pn0 = base.per_node(e)
    expect_count = base.count(e)
    register_backend("count_only", lambda **_: CountOnly())
    try:
        _warned_fallbacks.clear()
        tc = TriangleCounter(method="count_only")
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            pn = tc.per_node(e)
        assert [w for w in caught if issubclass(w.category, RuntimeWarning)]
        np.testing.assert_array_equal(pn, pn0)
        st = tc.last_stats
        assert st.method == "wedge_bsearch"
        assert st.resolved_method == "count_only"
        assert st.fallback_reason and "per_node" in st.fallback_reason
        # the warning is one-time per (method, kind) pair
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            tc.per_node(e)
        assert not [w for w in caught if issubclass(w.category, RuntimeWarning)]
        # regression: stats are per-invocation — a subsequent clean call on
        # the same counter must not report the stale fallback_reason
        assert tc.count(e) == expect_count
        assert tc.last_stats.method == "count_only"
        assert tc.last_stats.fallback_reason is None
    finally:
        del _BACKEND_FACTORIES["count_only"]


def test_backend_registry_roundtrip():
    """make_backend resolves registered names; unknown names fail loudly;
    custom registrations are honored."""
    from repro.core.engine import (
        CAPABILITIES,
        WedgeBackend,
        make_backend,
        register_backend,
        resolve_backend,
        _BACKEND_FACTORIES,
    )

    for name, expected in [
        ("wedge_bsearch", "wedge_bsearch"),
        ("panel", "panel"),
        ("pallas", "pallas"),
        ("distributed", "distributed"),
    ]:
        assert make_backend(name).name == expected
    with pytest.raises(ValueError):
        make_backend("nope")
    with pytest.raises(ValueError):
        resolve_backend("wedge_bsearch", "frobnicate")
    assert set(CAPABILITIES) == {"count", "per_node", "support"}
    register_backend("test_custom", lambda **_: WedgeBackend())
    try:
        assert make_backend("test_custom").name == "wedge_bsearch"
    finally:
        del _BACKEND_FACTORIES["test_custom"]


def test_peak_buffer_is_true_chunk_load(family_graphs):
    """peak_wedge_buffer reports the largest buffer actually materialized
    (the max chunk load), not the requested budget."""
    e = family_graphs["kron10"]
    base = TriangleCounter(method="wedge_bsearch")
    expect = base.count(e)
    total = base.last_stats.total_wedges
    # unchunked: the whole workload is the buffer
    assert base.last_stats.peak_wedge_buffer == total
    budget = total // 3
    tc = TriangleCounter(method="wedge_bsearch", max_wedge_chunk=budget)
    assert tc.count(e) == expect
    st = tc.last_stats
    # the greedy plan rarely fills the budget exactly: the true peak is
    # what the kernels saw, and it must match the plan's chunk loads
    import jax.numpy as jnp

    from repro.core import preprocess

    csr = preprocess(jnp.asarray(e), n_nodes=int(e.max()) + 1)
    out_deg = np.asarray(csr.out_degree)
    reps = out_deg[np.asarray(csr.src)].astype(np.int64)
    bounds, _ = plan_edge_chunks(reps, budget)
    true_peak = max(int(reps[s:t].sum()) for s, t in bounds)
    assert st.peak_wedge_buffer == true_peak
    assert st.peak_wedge_buffer <= budget


def test_engine_rejects_bad_args():
    with pytest.raises(ValueError):
        TriangleCounter(method="nope")
    with pytest.raises(ValueError):
        TriangleCounter(method="distributed")  # no mesh
    with pytest.raises(ValueError):
        TriangleCounter(max_wedge_chunk=0)


def test_empty_graph():
    tc = TriangleCounter()
    assert tc.count(np.zeros((0, 2), np.int32)) == 0
    assert tc.per_node(np.zeros((0, 2), np.int32), n_nodes=5).shape == (5,)
