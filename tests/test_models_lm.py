"""Per-LM-arch smoke tests (reduced configs) + decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY
from repro.models import transformer as tfm

LM_ARCHS = [a for a, m in REGISTRY.items() if m.FAMILY == "lm"]


@pytest.fixture(scope="module")
def toks():
    return jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, 250)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_smoke_forward_and_train_step(arch, toks):
    cfg = REGISTRY[arch].smoke_config()
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    logits = tfm.forward(params, toks, cfg)
    assert logits.shape == (2, 24, cfg.padded_vocab)
    real = logits[..., : cfg.vocab_size]
    assert bool(jnp.isfinite(real).all()), arch
    # padded vocab columns are masked and can never win an argmax
    assert bool((jnp.argmax(logits, -1) < cfg.vocab_size).all()), arch
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    loss, grads = jax.value_and_grad(tfm.loss_fn)(params, batch, cfg)
    assert np.isfinite(float(loss))
    assert all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads)), arch


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "olmoe-1b-7b"])
def test_decode_matches_full_forward(arch, toks):
    cfg = REGISTRY[arch].smoke_config()
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    last, kv = tfm.prefill(params, toks, cfg)
    k0, v0 = tfm.init_kv_cache(cfg, 2, 40, dtype=jnp.float32)
    k0 = jax.lax.dynamic_update_slice(k0, kv[0].astype(k0.dtype), (0, 0, 0, 0, 0))
    v0 = jax.lax.dynamic_update_slice(v0, kv[1].astype(v0.dtype), (0, 0, 0, 0, 0))
    nxt = jnp.argmax(last, -1).astype(jnp.int32)
    dl, _ = tfm.decode_step(params, nxt, jnp.int32(toks.shape[1]), (k0, v0), cfg)
    full = tfm.forward(params, jnp.concatenate([toks, nxt[:, None]], 1), cfg)[:, -1]
    np.testing.assert_allclose(np.asarray(dl), np.asarray(full), rtol=3e-4, atol=3e-4)


def test_moe_routes_to_multiple_experts():
    cfg = REGISTRY["olmoe-1b-7b"].smoke_config()
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (64, cfg.d_model))
    layer0 = jax.tree.map(lambda p: p[0], params["layers"])
    logits = x @ layer0["router"]
    top = jax.lax.top_k(jax.nn.softmax(logits), cfg.top_k)[1]
    assert len(np.unique(np.asarray(top))) > 1  # routing actually spreads


def test_moe_matches_dense_expert_sum():
    """MoE with identical experts == dense FFN with the shared weights."""
    from repro.models.transformer import TransformerConfig, _moe, _swiglu

    cfg = TransformerConfig(
        name="t", n_layers=1, d_model=16, n_heads=2, n_kv_heads=2, d_ff=24,
        vocab_size=32, n_experts=4, top_k=2, dtype=jnp.float32,
    )
    key = jax.random.PRNGKey(0)
    w_g = jax.random.normal(key, (16, 24)) * 0.1
    w_u = jax.random.normal(jax.random.PRNGKey(1), (16, 24)) * 0.1
    w_d = jax.random.normal(jax.random.PRNGKey(2), (24, 16)) * 0.1
    p_moe = {
        "router": jax.random.normal(jax.random.PRNGKey(3), (16, 4)),
        "w_gate": jnp.tile(w_g[None], (4, 1, 1)),
        "w_up": jnp.tile(w_u[None], (4, 1, 1)),
        "w_down": jnp.tile(w_d[None], (4, 1, 1)),
    }
    x = jax.random.normal(jax.random.PRNGKey(4), (8, 16))
    got = _moe(x, p_moe, cfg)
    want = _swiglu(x, {"w_gate": w_g, "w_up": w_u, "w_down": w_d}, jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_training_reduces_loss():
    """A few steps on the copy-structured stream must reduce CE."""
    from repro.configs.lm_common import make_lm_train_step
    from repro.data import lm_batch

    from repro.optim import constant

    cfg = REGISTRY["qwen2-1.5b"].smoke_config()
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    step_fn, opt_init = make_lm_train_step(cfg, accum=1, lr=constant(2e-3))
    opt_state = opt_init(params)
    jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
    losses = []
    for i in range(30):
        b = lm_batch(0, i, 8, 64, cfg.vocab_size)
        batch = {k: jnp.asarray(v)[None] for k, v in b.items()}
        params, opt_state, m = jit_step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses


def test_int8_kv_decode_matches_fp(toks):
    """int8 KV-cache decode (§Perf) must track the fp path closely."""
    import dataclasses

    from repro.models.attention import quantize_kv_token

    cfg = REGISTRY["llama3.2-3b"].smoke_config()
    cfgq = dataclasses.replace(cfg, kv_quant=True)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    last, kv = tfm.prefill(params, toks, cfg)
    nxt = jnp.argmax(last, -1).astype(jnp.int32)
    s = toks.shape[1]

    k0, v0 = tfm.init_kv_cache(cfg, 2, s + 8, dtype=jnp.float32)
    k0 = jax.lax.dynamic_update_slice(k0, kv[0], (0, 0, 0, 0, 0))
    v0 = jax.lax.dynamic_update_slice(v0, kv[1], (0, 0, 0, 0, 0))
    lf, _ = tfm.decode_step(params, nxt, jnp.int32(s), (k0, v0), cfg)

    cache = tfm.init_kv_cache_int8(cfgq, 2, s + 8)
    kq, ks, vq, vs = quantize_kv_token(kv[0], kv[1])
    cache = (
        jax.lax.dynamic_update_slice(cache[0], kq, (0, 0, 0, 0, 0)),
        jax.lax.dynamic_update_slice(cache[1], ks, (0, 0, 0, 0)),
        jax.lax.dynamic_update_slice(cache[2], vq, (0, 0, 0, 0, 0)),
        jax.lax.dynamic_update_slice(cache[3], vs, (0, 0, 0, 0)),
    )
    lq, newc = tfm.decode_step(params, nxt, jnp.int32(s), cache, cfgq)
    assert newc[0].dtype == jnp.int8
    rel = float(jnp.abs(lf - lq).max() / jnp.abs(lf).max())
    assert rel < 0.08, rel
    # greedy next-token choice is preserved
    assert bool((jnp.argmax(lf, -1) == jnp.argmax(lq, -1)).all())
