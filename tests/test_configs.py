"""Registry completeness + dry-run spec construction (no 512-dev compile;
full-mesh compilation is exercised by ``repro.launch.dryrun`` and recorded
in EXPERIMENTS.md — plus one subprocess cell here to keep it honest)."""
import jax
import pytest

from conftest import run_multidevice
from repro.configs import ALL_CELLS, ASSIGNED_CELLS, REGISTRY, get_arch

EXPECTED_ARCHS = {
    "olmoe-1b-7b", "granite-moe-3b-a800m", "deepseek-coder-33b", "llama3.2-3b",
    "qwen2-1.5b", "schnet", "gcn-cora", "graphsage-reddit", "egnn", "din",
    "triangles",
}


def test_registry_complete():
    assert set(REGISTRY) == EXPECTED_ARCHS
    assert len(ASSIGNED_CELLS) == 40  # 5 LM × 4 + 4 GNN × 4 + 1 recsys × 4
    assert len(ALL_CELLS) == 40 + len(REGISTRY["triangles"].SHAPES)


def test_unknown_arch_raises():
    with pytest.raises(KeyError):
        get_arch("nope")


def test_lm_full_configs_match_assignment():
    c = REGISTRY["deepseek-coder-33b"].full_config()
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab_size) == (
        62, 7168, 56, 8, 19200, 32256)
    c = REGISTRY["olmoe-1b-7b"].full_config()
    assert (c.n_experts, c.top_k, c.d_ff, c.vocab_size) == (64, 8, 1024, 50304)
    c = REGISTRY["qwen2-1.5b"].full_config()
    assert c.qkv_bias and c.n_kv_heads == 2 and c.vocab_size == 151936
    c = REGISTRY["granite-moe-3b-a800m"].full_config()
    assert (c.n_layers, c.n_experts, c.top_k) == (32, 40, 8)
    c = REGISTRY["llama3.2-3b"].full_config()
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab_size) == (28, 3072, 8192, 128256)


def test_param_count_sanity():
    assert abs(REGISTRY["deepseek-coder-33b"].full_config().n_params() - 33e9) / 33e9 < 0.1
    olmoe = REGISTRY["olmoe-1b-7b"].full_config()
    assert abs(olmoe.n_params() - 6.9e9) / 6.9e9 < 0.25       # ~7B total
    assert abs(olmoe.n_active_params() - 1.3e9) / 1.3e9 < 0.35  # ~1B active


@pytest.mark.slow
def test_one_cell_lowers_and_compiles_on_8_devices():
    out = run_multidevice("""
import jax
from repro.configs import get_arch
mesh = jax.make_mesh((2, 4), ("data", "model"))
spec = get_arch("qwen2-1.5b").build_dryrun("decode_32k", mesh)
with mesh:
    compiled = spec.lower().compile()
print("OK", compiled.cost_analysis() is not None)
""")
    assert "OK" in out
