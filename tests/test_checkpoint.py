"""Checkpoint durability: versioning, crc32, atomic publish, async GC.

The serving layer's snapshot/restore path (repro.serve.snapshot) leans
on these guarantees — a torn/corrupted/mis-versioned checkpoint must be
*skipped*, never half-read, and overwriting a step must never pass
through a state where no committed copy exists.
"""
import json
import os
import threading

import numpy as np
import pytest

from repro.checkpoint import (
    FORMAT_VERSION,
    CheckpointManager,
    list_checkpoints,
    restore_checkpoint,
    restore_latest,
    save_checkpoint,
)


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": rng.standard_normal((4, 3)).astype(np.float32),
        "b": rng.standard_normal(3).astype(np.float32),
        "step": np.asarray(seed, np.int64),
    }


def _template():
    return {
        "w": np.zeros((4, 3), np.float32),
        "b": np.zeros(3, np.float32),
        "step": np.asarray(0, np.int64),
    }


def test_roundtrip_with_extra(tmp_path):
    d = str(tmp_path)
    tree = _tree(1)
    save_checkpoint(d, 7, tree, extra={"note": "x"})
    got, step, extra = restore_latest(d, _template())
    assert step == 7 and extra == {"note": "x"}
    for k in tree:
        assert np.array_equal(np.asarray(got[k]), tree[k])


def test_manifest_carries_format_version(tmp_path):
    path = save_checkpoint(str(tmp_path), 1, _tree())
    with open(os.path.join(path, "manifest.json")) as f:
        m = json.load(f)
    assert m["format_version"] == FORMAT_VERSION
    assert set(m["leaves"]) == {"w", "b", "step"}
    for meta in m["leaves"].values():
        assert {"shape", "dtype", "crc32"} <= set(meta)


def test_version_mismatch_is_skipped(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, _tree(1))
    path2 = save_checkpoint(d, 2, _tree(2))
    mpath = os.path.join(path2, "manifest.json")
    with open(mpath) as f:
        m = json.load(f)
    m["format_version"] = FORMAT_VERSION + 1
    with open(mpath, "w") as f:
        json.dump(m, f)
    # restore_latest falls back to step 1; direct restore of step 2 raises
    _, step, _ = restore_latest(d, _template())
    assert step == 1
    with pytest.raises(ValueError):
        restore_checkpoint(path2, _template())


def test_unversioned_seed_manifest_is_skipped(tmp_path):
    d = str(tmp_path)
    path = save_checkpoint(d, 1, _tree())
    mpath = os.path.join(path, "manifest.json")
    with open(mpath) as f:
        m = json.load(f)
    del m["format_version"]  # pre-versioning manifest
    with open(mpath, "w") as f:
        json.dump(m, f)
    assert restore_latest(d, _template()) is None


def test_truncated_arrays_are_skipped(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, _tree(1))
    path2 = save_checkpoint(d, 2, _tree(2))
    npz = os.path.join(path2, "arrays.npz")
    with open(npz, "r+b") as f:
        f.truncate(os.path.getsize(npz) // 2)
    _, step, _ = restore_latest(d, _template())
    assert step == 1


def test_bitflip_corruption_detected_by_crc(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, _tree(1))
    path2 = save_checkpoint(d, 2, _tree(2))
    npz = os.path.join(path2, "arrays.npz")
    import zipfile

    with zipfile.ZipFile(npz) as z:
        payload = z.read("w.npy")  # stored uncompressed: bytes appear verbatim
    blob = bytearray(open(npz, "rb").read())
    idx = blob.find(payload)
    assert idx >= 0
    blob[idx + len(payload) - 4] ^= 0xFF  # flip a byte of the float data
    with open(npz, "wb") as f:
        f.write(bytes(blob))
    got = restore_latest(d, _template())
    assert got is not None and got[1] == 1  # fell back past the corrupt one


def test_missing_commit_marker_is_torn(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, _tree(1))
    path2 = save_checkpoint(d, 2, _tree(2))
    os.unlink(os.path.join(path2, "COMMIT"))
    _, step, _ = restore_latest(d, _template())
    assert step == 1


def test_overwrite_same_step_is_atomic(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 3, _tree(1))
    save_checkpoint(d, 3, _tree(2))  # moves the old aside, never deletes first
    got, step, _ = restore_latest(d, _template())
    assert step == 3 and np.array_equal(np.asarray(got["w"]), _tree(2)["w"])
    assert not os.path.exists(os.path.join(d, "step_000000003.old"))


def test_tmp_and_old_dirs_invisible_to_listing(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, _tree())
    os.makedirs(os.path.join(d, "step_000000002.tmp"))
    os.makedirs(os.path.join(d, "step_000000009.old"))
    assert [s for s, _ in list_checkpoints(d)] == [1]


def test_manager_retention_keeps_newest(tmp_path):
    d = str(tmp_path)
    mgr = CheckpointManager(d, keep=2, async_save=False)
    for s in range(5):
        mgr.save(s, _tree(s))
    steps = [s for s, _ in list_checkpoints(d)]
    assert steps == [3, 4]


def test_manager_gc_never_deletes_torn_dirs(tmp_path):
    """A torn dir (crashed writer, another process mid-publish) is neither
    counted toward keep nor pruned."""
    d = str(tmp_path)
    torn = os.path.join(d, "step_000000000")
    os.makedirs(torn)  # no COMMIT
    mgr = CheckpointManager(d, keep=1, async_save=False)
    for s in range(1, 4):
        mgr.save(s, _tree(s))
    assert os.path.isdir(torn)  # survived every GC
    _, step, _ = restore_latest(d, _template())
    assert step == 3


def test_async_save_is_safe_against_gc_race(tmp_path):
    """Rapid async saves: every wait() returns cleanly, the retention
    budget holds, and the newest checkpoint is always restorable."""
    d = str(tmp_path)
    mgr = CheckpointManager(d, keep=2, async_save=True)
    for s in range(8):
        mgr.save(s, _tree(s))
    mgr.wait()
    steps = [s for s, _ in list_checkpoints(d)]
    assert steps == [6, 7]
    got, step, _ = restore_latest(d, _template())
    assert step == 7 and np.array_equal(np.asarray(got["w"]), _tree(7)["w"])


def test_async_save_surfaces_background_errors(tmp_path):
    d = str(tmp_path / "sub")
    mgr = CheckpointManager(d, keep=2, async_save=True)
    mgr.save(1, _tree(1))
    mgr.wait()
    # make the directory unwritable so the background save fails
    os.chmod(d, 0o500)
    try:
        if os.access(os.path.join(d, "probe"), os.W_OK):
            pytest.skip("running as a user unaffected by chmod (root)")
        try:
            open(os.path.join(d, "probe"), "w").close()
            pytest.skip("chmod not enforced (root / permissive fs)")
        except OSError:
            pass
        mgr.save(2, _tree(2))
        with pytest.raises(Exception):
            mgr.wait()
    finally:
        os.chmod(d, 0o700)


def test_concurrent_saves_serialize(tmp_path):
    d = str(tmp_path)
    mgr = CheckpointManager(d, keep=4, async_save=True)
    errs = []

    def writer(base):
        try:
            for s in range(base, base + 4):
                mgr.save(s, _tree(s))
        except Exception as e:  # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=writer, args=(b,)) for b in (0, 10)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    mgr.wait()
    assert not errs
    assert restore_latest(d, _template()) is not None
    assert len(list_checkpoints(d)) <= 4 + 1  # keep + possible in-flight slack
