"""trilint tests: seeded-violation fixtures, repo cleanliness, suppression
channels, the CLI, and the REPRO_CHECK runtime sanitizer."""

import json
import os
import subprocess
import sys
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.check import run_checks
from repro.check.base import parse_allowlist
from repro.check.runtime import (
    PARTIAL_HEADROOM,
    RuntimeCheckError,
    check_partial,
    enabled,
)

REPO = Path(__file__).resolve().parents[1]
SRC_REPRO = REPO / "src" / "repro"
FIXTURES = Path(__file__).resolve().parent / "fixtures" / "trilint"
ALLOWLIST = REPO / "trilint.allow"


def codes(findings):
    return {f.code for f in findings}


# ---------------------------------------------------------------------------
# each pass catches its seeded fixture


@pytest.mark.parametrize(
    "passname,fixture,expected_codes",
    [
        ("overflow", "core/bad_overflow.py", {"O1-sum-dtype", "O2-host-fold", "O3-narrow"}),
        ("recompile", "core/bad_recompile.py", {"R1-unbucketed-shape"}),
        (
            "collectives",
            "core/bad_collectives.py",
            {"C1-axis-undeclared", "C2-axis-index-in-core", "C3-shardmap-specs"},
        ),
        (
            "backend_protocol",
            "core/bad_backend_protocol.py",
            {
                "B1-capability-unimplemented",
                "B2-no-capability-table",
                "B3-undeclared-capability",
                "B4-missing-plan",
            },
        ),
        ("stats_lifecycle", "core/bad_stats_lifecycle.py", {"S1-stale-stats"}),
        ("codec", "core/bad_codec.py", {"Z1-unchecked-decode-narrow"}),
        ("obs_discipline", "core/bad_obs_discipline.py", {"D1-unsynced-span"}),
    ],
)
def test_pass_flags_seeded_fixture(passname, fixture, expected_codes):
    findings = run_checks(FIXTURES, select=[passname])
    in_fixture = [f for f in findings if f.path == fixture and not f.suppressed]
    assert expected_codes <= codes(in_fixture), (
        f"{passname} missed codes {expected_codes - codes(in_fixture)}; "
        f"got {[f.render() for f in findings]}"
    )


def test_obs_discipline_synced_and_host_spans_not_flagged():
    findings = run_checks(FIXTURES, select=["obs_discipline"])
    in_fixture = [
        f for f in findings
        if f.path == "core/bad_obs_discipline.py" and not f.suppressed
    ]
    # exactly the one unsynced span: the synced and host-only spans pass
    assert len(in_fixture) == 1, [f.render() for f in in_fixture]
    assert "chunk_count_kernel" in in_fixture[0].message


def test_stats_lifecycle_compliant_method_not_flagged():
    findings = run_checks(FIXTURES, select=["stats_lifecycle"])
    flagged = {f.message.split("`")[1] for f in findings}
    assert "LeakyEngine.query" in flagged
    assert "LeakyEngine.count" not in flagged


def test_codec_guarded_narrowing_not_flagged():
    findings = run_checks(FIXTURES, select=["codec"])
    in_fixture = [
        f for f in findings
        if f.path == "core/bad_codec.py" and not f.suppressed
    ]
    # exactly the two unguarded narrows; the ensure_fits_int32 twin passes
    assert len(in_fixture) == 2, [f.render() for f in in_fixture]
    flagged = {f.message.split("`")[1] for f in in_fixture}
    assert flagged == {"unguarded_block_cols", "unguarded_scalar_cast"}


# ---------------------------------------------------------------------------
# the real tree is clean (modulo the reviewed allowlist)


def test_src_repro_clean_modulo_allowlist():
    findings = run_checks(SRC_REPRO, allowlist_path=ALLOWLIST)
    unsuppressed = [f for f in findings if not f.suppressed]
    assert not unsuppressed, "\n".join(f.render() for f in unsuppressed)


# ---------------------------------------------------------------------------
# suppression channels


def test_inline_suppression(tmp_path):
    bad = tmp_path / "core"
    bad.mkdir()
    (bad / "mod.py").write_text(
        "import jax.numpy as jnp\n"
        "def f(x):\n"
        "    return jnp.sum(x)  # trilint: ok[overflow]\n"
        "def g(x):\n"
        "    return jnp.sum(x)\n"
    )
    findings = run_checks(tmp_path, select=["overflow"])
    by_line = {f.line: f for f in findings}
    assert by_line[3].suppressed and by_line[3].suppression == "inline"
    assert not by_line[5].suppressed


def test_allowlist_matching(tmp_path):
    bad = tmp_path / "core"
    bad.mkdir()
    (bad / "mod.py").write_text("import numpy as np\ndef f(x):\n    return int(x.sum())\n")
    allow = tmp_path / "allow.txt"
    allow.write_text("# reviewed\ncore/*.py O2-host-fold *\n")
    findings = run_checks(tmp_path, allowlist_path=allow, select=["overflow"])
    assert findings and all(f.suppressed for f in findings)
    assert findings[0].suppression.startswith("allowlist:")


def test_parse_allowlist_shapes():
    rules = parse_allowlist("# c\ncore/x.py overflow substr\ncore/y.py\n")
    assert len(rules) == 2
    assert rules[0].substring == "substr"
    assert rules[1].rule == "*" and rules[1].substring == "*"


# ---------------------------------------------------------------------------
# CLI


def test_cli_json_clean_on_repo():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-m", "repro.check", "--json"],
        capture_output=True,
        text=True,
        env=env,
        timeout=120,
    )
    assert r.returncode == 0, r.stdout + r.stderr
    report = json.loads(r.stdout)
    assert report["counts"]["unsuppressed"] == 0
    assert set(report["passes"]) == {
        "overflow", "recompile", "collectives", "backend_protocol",
        "stats_lifecycle", "obs_discipline", "codec",
    }


def test_cli_fails_on_fixtures():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [
            sys.executable, "-m", "repro.check",
            "--root", str(FIXTURES), "--no-allowlist", "--json",
        ],
        capture_output=True,
        text=True,
        env=env,
        timeout=120,
    )
    assert r.returncode == 1
    report = json.loads(r.stdout)
    assert report["counts"]["unsuppressed"] >= 5


# ---------------------------------------------------------------------------
# runtime sanitizer


def test_enabled_reads_env(monkeypatch):
    monkeypatch.delenv("REPRO_CHECK", raising=False)
    assert not enabled()
    monkeypatch.setenv("REPRO_CHECK", "1")
    assert enabled()
    monkeypatch.setenv("REPRO_CHECK", "0")
    assert not enabled()


def test_check_partial_accepts_contract():
    check_partial(np.zeros(4, np.int32), kind="count")
    check_partial(jnp.ones(3, jnp.int32), kind="per_node")
    check_partial(np.zeros(0, np.int64), kind="count")  # empty: vacuous


def test_check_partial_rejects_wide_dtype():
    with pytest.raises(RuntimeCheckError, match="int32"):
        check_partial(np.ones(3, np.int64), kind="count")


def test_check_partial_rejects_negative_and_headroom():
    with pytest.raises(RuntimeCheckError, match="negative"):
        check_partial(np.array([-1], np.int32), kind="count")
    with pytest.raises(RuntimeCheckError, match="2\\^30"):
        check_partial(np.array([PARTIAL_HEADROOM], np.int32), kind="support")


def test_run_workload_sanitizer_integration(monkeypatch, small_graphs):
    from repro.core.engine import (
        TriangleCounter,
        WedgeBackend,
        preprocess,
        run_workload,
        workload_from_csr,
    )
    from repro.graphs import canonicalize_edges

    edges = canonicalize_edges(small_graphs["kron"])
    monkeypatch.setenv("REPRO_CHECK", "1")

    # healthy path: identical result with the sanitizer on
    tc = TriangleCounter(method="wedge_bsearch")
    with_check = tc.count(edges)
    monkeypatch.delenv("REPRO_CHECK")
    assert TriangleCounter(method="wedge_bsearch").count(edges) == with_check

    class WideBackend(WedgeBackend):
        """Violates the device contract: emits int64 partials."""

        def count_chunk(self, adj, chunk):
            return np.asarray(super().count_chunk(adj, chunk)).astype(np.int64)

    csr = preprocess(jnp.asarray(edges), int(edges.max()) + 1)
    work = workload_from_csr(csr)
    # without REPRO_CHECK the wide partial folds silently
    run_workload(WideBackend(), "count", work, budget=None)
    monkeypatch.setenv("REPRO_CHECK", "1")
    with pytest.raises(RuntimeCheckError, match="int32"):
        run_workload(WideBackend(), "count", work, budget=None)


def test_incremental_clears_stats_on_entry(small_graphs):
    from repro.core.incremental import IncrementalTriangleCounter

    tc = IncrementalTriangleCounter(small_graphs["triangle"])
    tc.insert(np.array([[0, 9], [9, 1]]))
    assert tc.last_update_stats is not None
    # a batch that raises must not leave the previous batch's stats visible
    with pytest.raises(ValueError):
        tc.insert(np.array([[-5, 2]]))
    assert tc.last_update_stats is None
