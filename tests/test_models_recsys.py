"""DIN + EmbeddingBag tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import REGISTRY
from repro.data import din_batch
from repro.models.recsys import din, embedding_bag, embedding_lookup, hash_bucket


def _batch(cfg, b=6, seed=0):
    return {k: jnp.asarray(v) for k, v in din_batch(seed, 0, b, cfg.seq_len, cfg.n_items, cfg.n_cates).items()}


def test_apply_and_grads():
    cfg = REGISTRY["din"].smoke_config()
    params = din.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    logits = din.apply(params, cfg, batch)
    assert logits.shape == (6,)
    loss, grads = jax.value_and_grad(din.loss_fn)(params, cfg, batch)
    assert np.isfinite(float(loss))
    assert all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads))


def test_history_padding_is_masked():
    cfg = REGISTRY["din"].smoke_config()
    params = din.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    # replacing padded (−1) history slots with arbitrary ids must not matter
    junk = jnp.where(batch["hist_items"] < 0, 7, batch["hist_items"])
    batch2 = dict(batch, hist_items=jnp.where(batch["hist_items"] < 0, -1, junk))
    np.testing.assert_allclose(
        np.asarray(din.apply(params, cfg, batch)),
        np.asarray(din.apply(params, cfg, batch2)),
        rtol=1e-5, atol=1e-5,
    )


def test_score_candidates_matches_apply():
    cfg = REGISTRY["din"].smoke_config()
    params = din.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, b=1)
    c = 32
    cands = {
        "hist_items": batch["hist_items"],
        "hist_cates": batch["hist_cates"],
        "cand_items": jnp.arange(c, dtype=jnp.int32),
        "cand_cates": jnp.arange(c, dtype=jnp.int32) % cfg.n_cates,
    }
    scores = din.score_candidates(params, cfg, cands)
    # candidate i must equal apply() with target=i
    batch_rep = {
        "hist_items": jnp.tile(batch["hist_items"], (c, 1)),
        "hist_cates": jnp.tile(batch["hist_cates"], (c, 1)),
        "target_item": cands["cand_items"],
        "target_cate": cands["cand_cates"],
    }
    np.testing.assert_allclose(
        np.asarray(scores), np.asarray(din.apply(params, cfg, batch_rep)),
        rtol=1e-4, atol=1e-4,
    )


def test_embedding_bag_modes_match_manual(rng):
    table = jnp.asarray(rng.normal(size=(50, 6)).astype(np.float32))
    ids = jnp.array([3, 4, 5, -1, 9, 9, 2])
    segs = jnp.array([0, 0, 0, 1, 1, 2, 2])
    t = np.asarray(table)
    want_sum = np.stack([
        t[3] + t[4] + t[5], t[9], t[9] + t[2],
    ])
    np.testing.assert_allclose(np.asarray(embedding_bag(table, ids, segs, 3, "sum")), want_sum, rtol=1e-6)
    want_mean = np.stack([(t[3] + t[4] + t[5]) / 3, t[9], (t[9] + t[2]) / 2])
    np.testing.assert_allclose(np.asarray(embedding_bag(table, ids, segs, 3, "mean")), want_mean, rtol=1e-6)
    want_max = np.stack([
        np.maximum(np.maximum(t[3], t[4]), t[5]), t[9], np.maximum(t[9], t[2]),
    ])
    np.testing.assert_allclose(np.asarray(embedding_bag(table, ids, segs, 3, "max")), want_max, rtol=1e-6)


def test_lookup_padding_and_hash():
    table = jnp.ones((10, 4))
    out = embedding_lookup(table, jnp.array([-1, 3]))
    assert (np.asarray(out[0]) == 0).all() and (np.asarray(out[1]) == 1).all()
    h = hash_bucket(jnp.arange(1000), 32)
    assert h.min() >= 0 and h.max() < 32
    assert len(np.unique(np.asarray(h))) == 32  # spreads


def test_din_training_reduces_loss():
    from repro.optim import adamw, apply_updates, constant

    cfg = REGISTRY["din"].smoke_config()
    params = din.init_params(jax.random.PRNGKey(0), cfg)
    opt_init, opt_update = adamw(constant(3e-3), weight_decay=0.0)
    opt = opt_init(params)

    @jax.jit
    def step(params, opt, batch):
        l, g = jax.value_and_grad(din.loss_fn)(params, cfg, batch)
        u, opt, _ = opt_update(g, opt, params)
        return apply_updates(params, u), opt, l

    losses = []
    for i in range(40):
        b = {k: jnp.asarray(v) for k, v in din_batch(0, i, 64, cfg.seq_len, cfg.n_items, cfg.n_cates).items()}
        params, opt, l = step(params, opt, b)
        losses.append(float(l))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.02, losses
