"""Correctness of the counting core against the O(n³) oracle + properties."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image has no hypothesis; use the local stub
    from _hypothesis_stub import given, settings, st

from repro.core import (
    count_triangles,
    count_triangles_bruteforce,
    count_triangles_doulion,
    count_triangles_numpy,
    count_triangles_sequential,
    preprocess,
    preprocess_host_offload,
)
from repro.graphs import canonicalize_edges, validate_edge_array

METHODS = ["wedge_bsearch", "panel", "pallas"]


@st.composite
def edge_arrays(draw):
    n = draw(st.integers(2, 20))
    n_raw = draw(st.integers(0, 60))
    pairs = draw(
        st.lists(
            st.tuples(st.integers(0, n - 1), st.integers(0, n - 1)),
            min_size=n_raw,
            max_size=n_raw,
        )
    )
    edges = canonicalize_edges(np.array(pairs + [(0, 1)], dtype=np.int64))
    return edges


@pytest.mark.parametrize("method", METHODS)
def test_matches_bruteforce_fixed(small_graphs, method):
    for name, e in small_graphs.items():
        expect = count_triangles_bruteforce(e)
        got = count_triangles(e, method=method)
        assert got == expect, (name, method, got, expect)


def test_cpu_baselines_match(small_graphs):
    for name, e in small_graphs.items():
        expect = count_triangles_bruteforce(e)
        assert count_triangles_sequential(e) == expect, name
        assert count_triangles_numpy(e) == expect, name


@settings(max_examples=30, deadline=None)
@given(edge_arrays())
def test_property_matches_bruteforce(edges):
    validate_edge_array(edges)
    expect = count_triangles_bruteforce(edges)
    assert count_triangles(edges, method="wedge_bsearch") == expect
    assert count_triangles(edges, method="panel") == expect


@settings(max_examples=15, deadline=None)
@given(edge_arrays(), st.randoms())
def test_property_row_permutation_invariant(edges, rnd):
    perm = np.array(rnd.sample(range(edges.shape[0]), edges.shape[0]))
    assert count_triangles(edges[perm]) == count_triangles(edges)


@settings(max_examples=15, deadline=None)
@given(edge_arrays(), st.integers(0, 2**31 - 1))
def test_property_relabel_invariant(edges, seed):
    n = int(edges.max()) + 1
    perm = np.random.default_rng(seed).permutation(n)
    assert count_triangles(perm[edges]) == count_triangles(edges)


@settings(max_examples=10, deadline=None)
@given(edge_arrays())
def test_property_disjoint_triangle_adds_one(edges):
    n = int(edges.max()) + 1
    tri = canonicalize_edges(np.array([(n, n + 1), (n + 1, n + 2), (n, n + 2)]))
    combined = np.concatenate([edges, tri])
    assert count_triangles(combined) == count_triangles(edges) + 1


def test_host_offload_preprocess_equals_device(small_graphs):
    import jax.numpy as jnp

    for name, e in small_graphs.items():
        n = int(e.max()) + 1
        a = preprocess(jnp.asarray(e), n_nodes=n)
        b = preprocess_host_offload(e, n_nodes=n)
        np.testing.assert_array_equal(np.asarray(a.row_offsets), np.asarray(b.row_offsets))
        np.testing.assert_array_equal(np.asarray(a.col), np.asarray(b.col))


def test_forward_orientation_invariants(small_graphs):
    import jax.numpy as jnp

    for e in small_graphs.values():
        n = int(e.max()) + 1
        csr = preprocess(jnp.asarray(e), n_nodes=n)
        src = np.asarray(csr.src)
        col = np.asarray(csr.col)
        deg = np.asarray(csr.degree)
        # exactly half the rows survive
        assert src.shape[0] == e.shape[0] // 2
        # every directed edge goes low→high in (degree, id) order
        low = (deg[src] < deg[col]) | ((deg[src] == deg[col]) & (src < col))
        assert low.all()
        # adjacency sorted within rows
        off = np.asarray(csr.row_offsets)
        for u in range(n):
            row = col[off[u]:off[u + 1]]
            assert (np.diff(row) > 0).all()
        # forward bound: out-degree ≤ sqrt(2m)
        assert np.asarray(csr.out_degree).max() <= int(np.sqrt(e.shape[0])) + 1


def test_doulion_p1_exact(small_graphs):
    e = small_graphs["er"]
    t = count_triangles_doulion(e, p=1.0)
    assert t == count_triangles(e)
    # p=1 keeps every edge: the result is the exact count, as an int
    assert isinstance(t, int)


def test_doulion_routes_through_engine(small_graphs):
    """The approximate path must reach auto dispatch and the memory
    budget, not bypass the engine with a hardcoded schedule."""
    e = small_graphs["kron"]
    exact = count_triangles(e)
    assert count_triangles_doulion(e, p=1.0, method="auto") == exact
    # chunked and unchunked sparsified counts agree (same seed → same sample)
    a = count_triangles_doulion(e, p=0.5, seed=3)
    b = count_triangles_doulion(e, p=0.5, seed=3, max_wedge_chunk=512)
    assert a == b
    assert isinstance(a, float)
    # every engine schedule is reachable from the approximate path
    for method in METHODS:
        assert count_triangles_doulion(e, p=1.0, method=method) == exact


def test_doulion_estimates(small_graphs):
    e = small_graphs["kron"]
    exact = count_triangles(e)
    ests = [count_triangles_doulion(e, p=0.5, seed=s) for s in range(8)]
    assert abs(np.mean(ests) - exact) / exact < 0.35


def test_empty_and_tiny():
    assert count_triangles(np.zeros((0, 2), np.int32)) == 0
    two = canonicalize_edges(np.array([(0, 1)]))
    assert count_triangles(two) == 0
