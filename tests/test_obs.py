"""repro.obs tests: span tracer, counters, histograms, exporters, and the
engine/analytics integration (spans measure phases, timings reconcile)."""

import json

import numpy as np
import pytest

from repro import obs
from repro.obs.tracer import _NoopSpan


@pytest.fixture(autouse=True)
def _clean_obs_state():
    """Every test starts with tracing off and fresh metrics."""
    obs.reset_metrics()
    yield
    if obs.enabled():
        obs.stop_tracing()
    obs.reset_metrics()


# ---------------------------------------------------------------------------
# span tracer


def test_nested_spans_record_close_order_and_depth():
    with obs.tracing() as t:
        with obs.span("outer", cat="t"):
            with obs.span("inner_a", cat="t"):
                pass
            with obs.span("inner_b", cat="t"):
                pass
    names = [e["name"] for e in t.events]
    assert names == ["inner_a", "inner_b", "outer"]  # children close first
    depths = {e["name"]: e["depth"] for e in t.events}
    assert depths == {"outer": 0, "inner_a": 1, "inner_b": 1}
    outer = t.events[-1]
    for child in t.events[:-1]:
        assert child["ts_ns"] >= outer["ts_ns"]
        assert child["ts_ns"] + child["dur_ns"] <= outer["ts_ns"] + outer["dur_ns"]


def test_span_exception_safety_records_event_and_restores_depth():
    with obs.tracing() as t:
        with pytest.raises(ValueError):
            with obs.span("boom"):
                raise ValueError("x")
        # depth restored: the next span is a sibling at depth 0
        with obs.span("after"):
            pass
    boom, after = t.events
    assert boom["name"] == "boom" and boom["error"] == "ValueError"
    assert boom["depth"] == 0 and after["depth"] == 0


def test_span_set_attaches_args():
    with obs.tracing() as t:
        with obs.span("s", args={"a": 1}) as sp:
            sp.set(b=2)
    assert t.events[0]["args"] == {"a": 1, "b": 2}


def test_disabled_span_is_the_shared_noop_singleton():
    # the near-zero-overhead contract: disabled span() allocates nothing —
    # every call returns the same module-level singleton
    assert not obs.enabled()
    s1 = obs.span("anything", cat="x", args={"k": 1})
    s2 = obs.span("else")
    assert s1 is s2 is obs.NOOP_SPAN
    assert isinstance(s1, _NoopSpan)
    with s1 as sp:
        obj = object()
        assert sp.sync(obj) is obj  # identity, no jax import
        assert sp.set(x=1) is sp


def test_disabled_sync_is_identity():
    obj = object()
    assert obs.sync(obj) is obj


def test_nested_start_tracing_raises():
    obs.start_tracing()
    with pytest.raises(RuntimeError, match="already active"):
        obs.start_tracing()
    t = obs.stop_tracing()
    assert t is not None and obs.active() is None


def test_instant_records_zero_duration_marker():
    with obs.tracing() as t:
        t.instant("mark", cat="x", args={"n": 3})
    (ev,) = t.events
    assert ev["dur_ns"] == 0 and ev["args"] == {"n": 3}


# ---------------------------------------------------------------------------
# counters / gauges


def test_counter_and_gauge_round_trip():
    obs.counter("a.hits").add()
    obs.counter("a.hits").add(2)
    obs.gauge("a.level").set(7)
    obs.gauge("a.level").set(11)  # last write wins
    snap = obs.metrics_snapshot()
    assert snap["counters"]["a.hits"] == 3
    assert snap["gauges"]["a.level"] == 11
    obs.reset_metrics()
    assert obs.metrics_snapshot() == {"counters": {}, "gauges": {}}


def test_registry_isolated_instances():
    r = obs.MetricsRegistry()
    r.counter("x").add(5)
    assert r.snapshot()["counters"]["x"] == 5
    assert "x" not in obs.metrics_snapshot()["counters"]


# ---------------------------------------------------------------------------
# pow2 histograms (serve_graph latency satellite)


def test_pow2_histogram_percentiles_bracket_observations():
    h = obs.Pow2Histogram()
    for ms in (1.0, 2.0, 4.0, 8.0, 100.0):
        h.observe(ms / 1e3)
    assert h.n == 5
    p50 = h.percentile(50)
    assert 2e-3 <= p50 <= 8e-3  # seconds: median is in the 2–8ms range
    assert h.percentile(99) <= 2 * 100e-3  # p99 within bucket of the max
    snap = h.snapshot_ms()
    assert snap["n"] == 5 and snap["p99_ms"] >= snap["p50_ms"] > 0


def test_pow2_histogram_merge_adds_counts():
    a, b = obs.Pow2Histogram(), obs.Pow2Histogram()
    a.observe_ns(1000)
    b.observe_ns(1000)
    b.observe_ns(2000)
    a.merge(b)
    assert a.n == 3 and a.total_ns == 4000


def test_rolling_histogram_window_vs_lifetime():
    rh = obs.RollingHistogram(window=2)
    rh.observe(0.001)
    rh.rotate()
    rh.observe(0.002)
    rh.rotate()
    rh.observe(0.004)
    # lifetime keeps everything; the 2-interval window dropped the first
    assert rh.lifetime.n == 3
    assert rh.windowed().n == 2


# ---------------------------------------------------------------------------
# exporters + validators


def _traced_sample():
    with obs.tracing() as t:
        with obs.span("outer", cat="t"):
            with obs.span("inner", cat="t", args={"k": 1}):
                pass
    return t


def test_chrome_trace_round_trip_validates(tmp_path):
    t = _traced_sample()
    obj = obs.to_chrome_trace(t, metrics=obs.metrics_snapshot(), meta={"m": 1})
    assert obs.validate_chrome_trace(obj) == 2
    assert obj["otherData"]["schema"] == obs.SCHEMA
    assert obj["otherData"]["meta"] == {"m": 1}
    # file round trip via extension dispatch
    path = tmp_path / "trace.json"
    obs.write_trace(str(path), t)
    assert obs.validate_chrome_trace(json.loads(path.read_text())) == 2


def test_jsonl_trace_round_trip_validates(tmp_path):
    t = _traced_sample()
    path = tmp_path / "trace.jsonl"
    obs.write_trace(str(path), t, meta={"cli": "test"})
    records = [json.loads(line) for line in path.read_text().splitlines()]
    assert obs.validate_jsonl_records(records) == 2
    assert records[0]["meta"] == {"cli": "test"}
    assert records[-1]["kind"] == "metrics"


def test_validate_chrome_trace_rejects_malformed():
    with pytest.raises(ValueError, match="traceEvents"):
        obs.validate_chrome_trace({})
    with pytest.raises(ValueError, match="negative"):
        obs.validate_chrome_trace(
            {"traceEvents": [{"name": "x", "ph": "X", "ts": -1, "dur": 1,
                              "pid": 0, "tid": 0, "args": {"depth": 0}}]}
        )
    with pytest.raises(ValueError, match="depth"):
        obs.validate_chrome_trace(
            {"traceEvents": [{"name": "x", "ph": "X", "ts": 0, "dur": 1,
                              "pid": 0, "tid": 0}]}
        )
    # a depth-1 span whose would-be parent doesn't contain it
    bad_nest = {
        "traceEvents": [
            {"name": "child", "ph": "X", "ts": 100.0, "dur": 50.0,
             "pid": 0, "tid": 0, "args": {"depth": 1}},
            {"name": "parent", "ph": "X", "ts": 0.0, "dur": 60.0,
             "pid": 0, "tid": 0, "args": {"depth": 0}},
        ]
    }
    with pytest.raises(ValueError, match="not contained"):
        obs.validate_chrome_trace(bad_nest)


def test_validate_jsonl_rejects_missing_header_or_tail():
    t = _traced_sample()
    records = obs.to_jsonl_records(t)
    with pytest.raises(ValueError, match="meta header"):
        obs.validate_jsonl_records(records[1:])
    with pytest.raises(ValueError, match="metrics"):
        obs.validate_jsonl_records(records[:-1])


def test_trace_to_file_none_is_noop_scope():
    with obs.trace_to_file(None) as t:
        assert t is None and not obs.enabled()


def test_trace_to_file_writes_artifact_with_counters(tmp_path):
    path = tmp_path / "t.json"
    with obs.trace_to_file(str(path), meta={"cli": "unit"}):
        obs.counter("unit.ticks").add(4)
        with obs.span("work"):
            pass
    obj = json.loads(path.read_text())
    assert obs.validate_chrome_trace(obj) == 1
    assert obj["otherData"]["metrics"]["counters"]["unit.ticks"] == 4
    assert obj["otherData"]["meta"]["cli"] == "unit"


def test_env_fingerprint_has_stdlib_and_jax_fields():
    fp = obs.env_fingerprint()
    assert fp["python"] and fp["platform"]
    assert fp["jax"] is not None  # jax is installed in the test env
    assert fp["device_count"] >= 1


# ---------------------------------------------------------------------------
# measured stripe skew satellite: disagreement note


def test_skew_disagreement_note():
    from repro.distributed.straggler import (
        skew_disagreement_note,
        stripe_skew_report,
    )

    load = stripe_skew_report([100, 100, 100, 400])
    agree = stripe_skew_report([10, 10, 10, 40])
    disagree = stripe_skew_report([400, 100, 100, 100])
    assert skew_disagreement_note(load, agree) is None
    note = skew_disagreement_note(load, disagree)
    assert note is not None and "disagreement" in note
    assert "stripe 3" in note and "stripe 0" in note


# ---------------------------------------------------------------------------
# engine + analytics integration


def test_engine_count_emits_spans_and_timings(small_graphs):
    from repro.core import TriangleCounter

    edges = small_graphs["kron"]
    tc = TriangleCounter(method="wedge_bsearch")
    t_plain = tc.count(edges)  # warm the jit cache untraced

    with obs.tracing() as t:
        t_traced = tc.count(edges)
    assert t_traced == t_plain

    names = [e["name"] for e in t.events]
    assert "engine.count" in names
    assert "engine.preprocess" in names
    assert "count.chunk" in names

    es = tc.last_stats
    assert es.timings is not None
    assert set(es.timings) == {"preprocess", "plan", "execute", "fold"}
    assert all(v >= 0 for v in es.timings.values())
    # the phase breakdown must reconcile with the span-measured wall
    span_wall = next(e for e in t.events if e["name"] == "engine.count")
    wall_s = span_wall["dur_ns"] / 1e9
    total = sum(es.timings.values())
    assert abs(total - wall_s) <= max(0.1 * wall_s, 0.005), (total, wall_s)


def test_untraced_count_still_fills_timings(small_graphs):
    from repro.core import TriangleCounter

    tc = TriangleCounter(method="wedge_bsearch")
    tc.count(small_graphs["kron"])
    assert not obs.enabled()
    assert tc.last_stats.timings is not None
    assert tc.last_stats.timings["preprocess"] >= 0


def test_graph_report_traces_all_stages(small_graphs):
    from repro.analytics import graph_report

    graph_report(small_graphs["kron"])  # warm untraced
    with obs.tracing() as t:
        rep = graph_report(small_graphs["kron"])
    names = {e["name"] for e in t.events}
    for stage in ("report.preprocess", "report.count", "report.clustering",
                  "report.support", "report.truss"):
        assert stage in names, (stage, sorted(names))
    assert "truss.round" in names
    # exported form of the full analytics run validates
    assert obs.validate_chrome_trace(obs.to_chrome_trace(t)) == len(t.events)
    assert rep["engine"]["timings"] is not None


def test_engine_counters_accumulate(small_graphs):
    from repro.core import TriangleCounter

    TriangleCounter(method="wedge_bsearch").count(small_graphs["kron"])
    counters = obs.metrics_snapshot()["counters"]
    assert counters["engine.workloads"] == 1
    assert counters["engine.chunks_launched"] >= 1
    assert counters["engine.wedges_planned"] > 0


def test_incremental_probe_spans(small_graphs):
    from repro.core.incremental import IncrementalTriangleCounter

    tc = IncrementalTriangleCounter(small_graphs["triangle"])
    with obs.tracing() as t:
        tc.insert(np.array([[0, 9], [9, 1]]))
    names = [e["name"] for e in t.events]
    for n in ("probe.without", "probe.with", "probe.delta"):
        assert n in names, names
