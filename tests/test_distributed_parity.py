"""§III-E distributed parity: every workload, bit-identical on a simulated mesh.

The acceptance contract of the distributed backend is not "close" — it is
**bit-identical** to the single-device wedge schedule for every workload
it claims: count, per-node incidences, per-edge support, the full truss
spectrum, and the incremental engine's delta probes.  These tests prove
it on simulated meshes of 2 / 4 / 8 CPU devices
(``--xla_force_host_platform_device_count``, via ``conftest.run_multidevice``
subprocesses — the parent process must keep its real single-device world),
at multiple ``max_wedge_chunk`` budgets, with the delta-compressed and
uncompressed support wires, and from sharded ``.tricsr`` slab views.

A hypothesis(-stub) property test fuzzes random small graphs × random
device counts, including the degenerate stripes (more devices than
edges, empty graphs, single edges) where striping logic dies first.
"""
import os
import sys

import numpy as np
import pytest

from conftest import run_multidevice

pytestmark = pytest.mark.distributed

# ---------------------------------------------------------------------------
# subprocess preamble shared by the mesh tests
# ---------------------------------------------------------------------------

_PRELUDE = """
import numpy as np
import jax
from jax.sharding import Mesh

from repro.core import TriangleCounter
from repro.graphs.generators import kronecker_rmat
from repro.graphs.io.registry import karate_edges

K = {k}
mesh = Mesh(np.array(jax.devices()[:K]), ("edges",))
"""


@pytest.mark.slow
@pytest.mark.parametrize("k", [2, 4, 8])
def test_mesh_parity_count_per_node_support(k):
    """count / per_node / edge_support bit-identical to wedge on karate and
    kron-10, at two budgets, with EngineStats attesting the striped run."""
    out = run_multidevice(_PRELUDE.format(k=k) + """
graphs = {"karate": karate_edges(), "kron10": kronecker_rmat(10, seed=0)}
for name, e in graphs.items():
    wedge = TriangleCounter(method="wedge_bsearch")
    for budget in (None, 2048):
        dist = TriangleCounter(method="distributed", mesh=mesh,
                               max_wedge_chunk=budget)
        ref = TriangleCounter(method="wedge_bsearch", max_wedge_chunk=budget)
        assert dist.count(e) == ref.count(e), (name, budget)
        st = dist.last_stats
        assert st.method == "distributed" and st.fallback_reason is None
        assert st.n_stripes == K, st
        assert np.array_equal(dist.per_node(e), ref.per_node(e)), (name, budget)
        assert dist.last_stats.method == "distributed"
        assert np.array_equal(dist.edge_support(e), ref.edge_support(e))
        st = dist.last_stats
        assert st.method == "distributed" and st.fallback_reason is None
        assert st.n_stripes == K and st.stripe_skew is not None and st.stripe_skew >= 1.0
print("PARITY_OK")
""")
    assert "PARITY_OK" in out


@pytest.mark.slow
@pytest.mark.parametrize("k", [2, 8])
def test_mesh_parity_truss_and_incremental(k):
    """Truss spectrum and incremental insert/delete deltas bit-identical,
    with the probes attesting probe_method == "distributed"."""
    out = run_multidevice(_PRELUDE.format(k=k) + """
from repro.analytics.truss import k_truss_decomposition
from repro.core.incremental import IncrementalTriangleCounter

graphs = {"karate": karate_edges(),
          "kron9": kronecker_rmat(9, edge_factor=8, seed=2)}
for name, e in graphs.items():
    for budget in (None, 1024):
        td = k_truss_decomposition(e, max_wedge_chunk=budget,
                                   method="distributed", mesh=mesh)
        tw = k_truss_decomposition(e, max_wedge_chunk=budget,
                                   method="wedge_bsearch")
        assert td.method == "distributed", td.method
        assert np.array_equal(td.trussness, tw.trussness), (name, budget)
        assert td.spectrum() == tw.spectrum() and td.max_k == tw.max_k

        canon = np.asarray(e, np.int64).reshape(-1, 2)
        half = canon[: canon.shape[0] // 2]
        rest = canon[canon.shape[0] // 2:]
        inc_d = IncrementalTriangleCounter(half, max_wedge_chunk=budget,
                                           method="distributed", mesh=mesh)
        inc_w = IncrementalTriangleCounter(half, max_wedge_chunk=budget,
                                           method="wedge_bsearch")
        assert inc_d.probe_method == "distributed"
        assert inc_d.insert(rest) == inc_w.insert(rest), (name, budget)
        assert inc_d.last_update_stats.probe_method == "distributed"
        assert inc_d.count == inc_w.count
        assert np.array_equal(inc_d.per_node(), inc_w.per_node())
        assert inc_d.delete(rest[:40]) == inc_w.delete(rest[:40])
        assert inc_d.count == inc_w.count
        assert np.array_equal(inc_d.per_node(), inc_w.per_node())
print("TRUSS_INC_OK")
""")
    assert "TRUSS_INC_OK" in out


@pytest.mark.slow
def test_mesh_parity_kron12_8way():
    """The big graph: kron-12 count/per-node/support on the full 8-mesh."""
    out = run_multidevice(_PRELUDE.format(k=8) + """
e = kronecker_rmat(12, seed=0)
dist = TriangleCounter(method="distributed", mesh=mesh,
                       max_wedge_chunk=1 << 20)
ref = TriangleCounter(method="wedge_bsearch", max_wedge_chunk=1 << 20)
assert dist.count(e) == ref.count(e)
assert dist.last_stats.method == "distributed"
assert dist.last_stats.n_stripes == 8
assert np.array_equal(dist.per_node(e), ref.per_node(e))
assert np.array_equal(dist.edge_support(e), ref.edge_support(e))
print("KRON12_OK")
""")
    assert "KRON12_OK" in out


@pytest.mark.slow
def test_support_compression_bit_identity():
    """The delta-compressed (uint16-wire) support all-gather and the plain
    int32 wire produce the same bits, and both match wedge."""
    out = run_multidevice(_PRELUDE.format(k=8) + """
from repro.core.engine import (DistributedBackend, make_workload,
                               prepare_oriented, run_workload)

e = kronecker_rmat(10, seed=0)
csr = prepare_oriented(e, None)
work = make_workload(csr.row_offsets, csr.col, csr.out_degree, csr.src, csr.col)
ref = TriangleCounter(method="wedge_bsearch").edge_support(e)
for budget in (None, 4096):
    for compress in (True, False):
        bk = DistributedBackend(mesh, compress=compress)
        sup, plan = run_workload(bk, "support", work, budget=budget)
        assert np.array_equal(sup, ref), (budget, compress)
print("COMPRESS_OK")
""")
    assert "COMPRESS_OK" in out


@pytest.mark.slow
def test_slab_views_feed_distributed_count():
    """Sharded .tricsr slab views orient and count on the mesh with no full
    col array ever assembled — same count as the single-device oracle."""
    out = run_multidevice(_PRELUDE.format(k=8) + """
import tempfile, os
from repro.graphs.formats import canonicalize_edges, edge_array_to_csr
from repro.graphs.io import CSRGraph, save_tricsr_stripes, load_tricsr_stripes
from repro.core.distributed import count_triangles_distributed_slabs

e = kronecker_rmat(10, seed=0)
canon = canonicalize_edges(e)
row, col = edge_array_to_csr(canon)
csr = CSRGraph(row, col, row.shape[0] - 1)
expect = TriangleCounter(method="wedge_bsearch").count(e)
with tempfile.TemporaryDirectory() as d:
    base = os.path.join(d, "g.tricsr")
    save_tricsr_stripes(base, csr, 8)
    slabs = load_tricsr_stripes(base, 8, verify=True)
    stats = {}
    got = count_triangles_distributed_slabs(slabs, mesh, stats_out=stats)
assert got == expect, (got, expect)
assert stats.get("n_chunks", 1) >= 1
print("SLAB_COUNT_OK")
""")
    assert "SLAB_COUNT_OK" in out


@pytest.mark.slow
def test_property_striped_equals_oracle_random_graphs():
    """Hypothesis(-stub) fuzz: random small graphs × random device counts
    (1–8) — striped per_node/support == the single-device oracle, including
    the degenerate stripes (devices > edges, empty stripes, one edge)."""
    tests_dir = os.path.dirname(os.path.abspath(__file__))
    out = run_multidevice(f"""
import sys
sys.path.insert(0, {tests_dir!r})
""" + """
import numpy as np
import jax
from jax.sharding import Mesh

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    from _hypothesis_stub import given, settings, st

from repro.core import TriangleCounter
from repro.graphs import canonicalize_edges

DEVS = jax.devices()


def random_edges(rnd, n, m):
    if m == 0:
        return np.zeros((0, 2), np.int32)
    u = np.array([rnd.randrange(n) for _ in range(m)], np.int32)
    v = np.array([rnd.randrange(n) for _ in range(m)], np.int32)
    return canonicalize_edges(np.stack([u, v], axis=1))


@settings(max_examples=20)
@given(st.randoms(), st.integers(2, 40), st.integers(0, 120),
       st.integers(1, 8), st.sampled_from([None, 1, 64]))
def check(rnd, n, m, k, budget):
    e = random_edges(rnd, n, m)
    mesh = Mesh(np.array(DEVS[:k]), ("edges",))
    ref = TriangleCounter(method="wedge_bsearch", max_wedge_chunk=budget)
    dist = TriangleCounter(method="distributed", mesh=mesh,
                           max_wedge_chunk=budget)
    assert dist.count(e) == ref.count(e)
    assert np.array_equal(dist.per_node(e), ref.per_node(e))
    assert np.array_equal(dist.edge_support(e), ref.edge_support(e))
    if e.shape[0]:
        assert dist.last_stats.method == "distributed"
        assert dist.last_stats.n_stripes == k


check()

# pinned degenerate stripes: devices > edges, a single edge, empty graph
mesh8 = Mesh(np.array(DEVS), ("edges",))
for e in [np.zeros((0, 2), np.int32),
          np.array([[0, 1], [1, 0]], np.int32),
          np.array([[0, 1], [1, 2], [0, 2], [1, 0], [2, 1], [2, 0]], np.int32)]:
    ref = TriangleCounter(method="wedge_bsearch")
    dist = TriangleCounter(method="distributed", mesh=mesh8)
    assert dist.count(e) == ref.count(e)
    assert np.array_equal(dist.per_node(e), ref.per_node(e))
    assert np.array_equal(dist.edge_support(e), ref.edge_support(e))
print("PROPERTY_OK")
""", timeout=560)
    assert "PROPERTY_OK" in out


@pytest.mark.slow
def test_serve_graph_distributed_smoke():
    """serve_graph --method distributed serves karate and its final oracle
    recount agrees (exits 0, prints the distributed probe backend)."""
    import subprocess

    from conftest import SRC

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    fixture = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "data", "karate.txt")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve_graph",
         "--input", fixture, "--batch-size", "16",
         "--queries-per-batch", "1", "--method", "distributed"],
        capture_output=True, text=True, env=env, timeout=480,
    )
    assert r.returncode == 0, r.stdout + r.stderr
    assert "probe backend: distributed" in r.stdout
    assert "verify: from-scratch recount agrees" in r.stdout


# ---------------------------------------------------------------------------
# in-process pieces (single real device — no subprocess needed)
# ---------------------------------------------------------------------------


def test_single_device_mesh_parity_in_process(small_graphs):
    """A 1×1 mesh exercises the full striped path in-process: every
    workload bit-identical, stats attesting the distributed schedule."""
    import jax
    from jax.sharding import Mesh

    from repro.core import TriangleCounter

    mesh = Mesh(np.array(jax.devices()[:1]), ("edges",))
    e = small_graphs["kron"]
    for budget in (None, 256):
        dist = TriangleCounter(method="distributed", mesh=mesh,
                               max_wedge_chunk=budget)
        base = TriangleCounter(method="wedge_bsearch", max_wedge_chunk=budget)
        assert dist.count(e) == base.count(e)
        assert dist.last_stats.method == "distributed"
        assert dist.last_stats.n_stripes == 1
        np.testing.assert_array_equal(dist.per_node(e), base.per_node(e))
        np.testing.assert_array_equal(dist.edge_support(e), base.edge_support(e))
        st = dist.last_stats
        assert st.stripe_skew == 1.0 and st.straggler_stripe is None


def test_stripe_skew_report_flags_outlier():
    """The median+MAD rule flags a grossly overloaded stripe and reports
    the max/mean skew factor."""
    from repro.distributed.straggler import stripe_skew_report

    rep = stripe_skew_report([10, 10, 10, 100])
    assert rep.n_stripes == 4
    assert rep.max_load == 100 and rep.straggler_stripe == 3
    assert rep.skew == pytest.approx(100 / 32.5)
    balanced = stripe_skew_report([50, 51, 49, 50])
    assert balanced.straggler_stripe is None
    assert balanced.skew == pytest.approx(51 / 50.0)
    empty = stripe_skew_report([])
    assert empty.straggler_stripe is None and empty.skew == 1.0


def test_stale_fallback_reason_not_reported_by_distributed(small_graphs):
    """Regression: EngineStats are per-invocation — after a capability
    fallback on one counter, a distributed call must report a clean
    fallback_reason, and a later clean call on the *same* fallen-back
    counter must too."""
    import jax
    from jax.sharding import Mesh

    from repro.core import TriangleCounter
    from repro.core.engine import (
        WedgeBackend,
        register_backend,
        _BACKEND_FACTORIES,
    )

    class CountOnly(WedgeBackend):
        name = "count_only"
        capabilities = frozenset({"count"})

    e = small_graphs["kron"]
    register_backend("count_only", lambda **_: CountOnly())
    try:
        crippled = TriangleCounter(method="count_only")
        crippled.per_node(e)  # falls back to wedge
        assert crippled.last_stats.fallback_reason is not None
        mesh = Mesh(np.array(jax.devices()[:1]), ("edges",))
        dist = TriangleCounter(method="distributed", mesh=mesh)
        dist.per_node(e)
        assert dist.last_stats.method == "distributed"
        assert dist.last_stats.fallback_reason is None
        # the crippled counter's next capable call is clean too
        crippled.count(e)
        assert crippled.last_stats.fallback_reason is None
    finally:
        del _BACKEND_FACTORIES["count_only"]
