"""Analytics subsystem: per-edge support, k-truss, engine-routed metrics.

The acceptance contract: per-edge support matches an O(m·dmax) NumPy
reference and sums to 3×the triangle count bit-exactly at any
``max_wedge_chunk`` budget; k-truss decomposition matches a naive
recompute-peeling oracle on every test graph; the clustering/transitivity
metrics agree with the historical ``repro.core.clustering`` results while
now accepting cached CSRs and honoring the memory budget.
"""
import os
import tempfile

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image has no hypothesis; use the local stub
    from _hypothesis_stub import given, settings, st

from repro.analytics import (
    average_clustering,
    clustering_profile,
    edge_support,
    graph_report,
    k_truss_decomposition,
    k_truss_subgraph,
    local_clustering,
    top_support_edges,
    top_triangle_nodes,
    transitivity,
)
from repro.core import (
    TriangleCounter,
    count_triangles_numpy,
    local_clustering_coefficient,
    node_triangle_features,
    prepare_oriented,
)
from repro.core import transitivity as core_transitivity
from repro.graphs import canonicalize_edges, kronecker_rmat, watts_strogatz
from repro.graphs.io import ingest
from repro.graphs.io.registry import karate_edges

DATA = os.path.join(os.path.dirname(os.path.abspath(__file__)), "data")


# ---------------------------------------------------------------------------
# brute-force oracles
# ---------------------------------------------------------------------------


def brute_support(edges):
    """O(m·dmax) per-edge support on the forward orientation.

    Returns {(u, v): support} keyed by the forward-oriented edge — the
    same (deg, id)-lexicographic orientation the engine preprocessing
    uses, so keys align with ``EdgeSupport.u``/``.v`` directly.
    """
    edges = np.asarray(edges)
    n = int(edges.max()) + 1
    deg = np.bincount(edges[:, 0], minlength=n)
    adj = [set() for _ in range(n)]
    for u, v in edges:
        adj[u].add(int(v))
    out = {}
    for u, v in edges:
        u, v = int(u), int(v)
        if (deg[u], u) < (deg[v], v):
            out[(u, v)] = len(adj[u] & adj[v])
    return out


def brute_truss(edges):
    """Naive k-truss decomposition: recompute supports, peel, repeat."""
    edges = np.asarray(edges)
    alive = {(min(int(u), int(v)), max(int(u), int(v))) for u, v in edges}
    truss = {}
    k = 3
    while alive:
        while True:
            adj = {}
            for u, v in alive:
                adj.setdefault(u, set()).add(v)
                adj.setdefault(v, set()).add(u)
            peel = [(u, v) for u, v in alive if len(adj[u] & adj[v]) < k - 2]
            if not peel:
                break
            for e in peel:
                truss[e] = k - 1
                alive.discard(e)
            if not alive:
                break
        k += 1
    return truss


def support_as_dict(sup):
    return {(int(u), int(v)): int(s) for u, v, s in zip(sup.u, sup.v, sup.support)}


def truss_as_dict(dec):
    return {
        (min(int(u), int(v)), max(int(u), int(v))): int(t)
        for u, v, t in zip(dec.u, dec.v, dec.trussness)
    }


def complete_graph(n):
    return canonicalize_edges(
        np.array([(i, j) for i in range(n) for j in range(i + 1, n)])
    )


@pytest.fixture(scope="module")
def karate():
    return canonicalize_edges(karate_edges())


# ---------------------------------------------------------------------------
# per-edge support
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("budget", [None, 256, 17])
def test_support_matches_bruteforce(small_graphs, budget):
    for name, e in small_graphs.items():
        sup = edge_support(e, max_wedge_chunk=budget)
        assert support_as_dict(sup) == brute_support(e), (name, budget)


def test_support_sum_is_three_times_count_at_two_budgets():
    """The acceptance identity, chunked and unchunked, on a skewed graph."""
    e = kronecker_rmat(10, seed=0)
    expect = count_triangles_numpy(e)
    unchunked = edge_support(e)
    assert int(unchunked.support.sum()) == 3 * expect
    total = unchunked.total_wedges
    chunked = edge_support(e, max_wedge_chunk=max(total // 7, 1))
    assert int(chunked.support.sum()) == 3 * expect
    assert chunked.n_chunks >= 4
    assert chunked.peak_wedge_buffer <= max(total // 7, 1)
    np.testing.assert_array_equal(chunked.support, unchunked.support)


def test_support_acceptance_identity_kron13_every_backend():
    """The PR acceptance criterion, verbatim: on Kronecker-13 the support
    sum equals 3× the engine count bit-exactly at two different budgets —
    for every kernel backend, with the stats proving which one ran."""
    e = kronecker_rmat(13, seed=0)
    csr = prepare_oriented(e)
    tc = TriangleCounter()
    expect = tc.count(csr)
    total = tc.last_stats.total_wedges
    for method in ("wedge_bsearch", "panel", "pallas"):
        for budget in (max(total // 4, 1), max(total // 16, 1)):
            sup = edge_support(csr, max_wedge_chunk=budget, method=method)
            assert int(sup.support.sum()) == 3 * expect, (method, budget)
            assert sup.n_chunks > 1
            assert sup.method == method
            assert sup.fallback_reason is None


def test_support_bit_identical_across_backends(small_graphs):
    """Per-edge support arrays (not just their sums) agree bit-exactly
    across wedge/panel/pallas at two budgets."""
    for name, e in small_graphs.items():
        base = edge_support(e, method="wedge_bsearch")
        for method in ("panel", "pallas"):
            for budget in (None, 48):
                sup = edge_support(e, max_wedge_chunk=budget, method=method)
                np.testing.assert_array_equal(
                    sup.support, base.support
                ), (name, method, budget)
                assert sup.method == method


def test_truss_bit_identical_across_backends(karate, small_graphs):
    """The k-truss spectrum — the heaviest repeated-support workload —
    is backend-independent bit-exactly."""
    for e in [karate, small_graphs["kron"]]:
        base = k_truss_decomposition(e, method="wedge_bsearch")
        for method in ("panel", "pallas"):
            for budget in (None, 101):
                dec = k_truss_decomposition(
                    e, max_wedge_chunk=budget, method=method
                )
                np.testing.assert_array_equal(dec.trussness, base.trussness)
                assert dec.method == method
                assert dec.spectrum() == base.spectrum()


def test_graph_report_method_axis(karate):
    """graph_report(method=...) drives every stage through that backend."""
    rep = graph_report(karate, method="pallas", top_k=2)
    assert rep["engine"]["method"] == "pallas"
    assert rep["support"]["method"] == "pallas"
    assert rep["truss"]["method"] == "pallas"
    assert rep["triangles"] == 45
    assert rep["support"]["sum"] == 135
    assert rep["truss"]["max_k"] == 5


def test_support_karate_fixture(karate):
    sup = edge_support(karate)
    assert sup.n_edges == 78
    assert sup.total_triangles() == 45
    assert int(sup.support.sum()) == 135
    assert int(sup.support.max()) == 10  # edge (32, 33) closes 10 triangles
    u, v, s = sup.top_k(1)
    assert (int(u[0]), int(v[0])) == (32, 33) and int(s[0]) == 10


def test_support_accepts_cached_csr(karate):
    """A .tricsr-cached CSRGraph and the raw edge array agree exactly."""
    with tempfile.TemporaryDirectory() as tmp:
        csr, _ = ingest(os.path.join(DATA, "karate.txt"), cache_dir=tmp)
        from_cache = edge_support(csr)
    from_edges = edge_support(karate)
    assert support_as_dict(from_cache) == support_as_dict(from_edges)


def test_support_accepts_oriented_csr(small_graphs):
    e = small_graphs["er"]
    csr = prepare_oriented(e)
    np.testing.assert_array_equal(
        edge_support(csr).support, edge_support(e).support
    )


def test_support_empty_graph():
    sup = edge_support(np.zeros((0, 2), np.int32))
    assert sup.n_edges == 0 and sup.total_triangles() == 0
    assert sup.top_k(3)[0].shape == (0,)


# ---------------------------------------------------------------------------
# k-truss
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("budget", [None, 64])
def test_truss_matches_naive_oracle(small_graphs, budget):
    for name, e in small_graphs.items():
        dec = k_truss_decomposition(e, max_wedge_chunk=budget)
        assert truss_as_dict(dec) == brute_truss(e), (name, budget)


def test_truss_karate_fixture(karate):
    dec = k_truss_decomposition(karate)
    assert truss_as_dict(dec) == brute_truss(karate)
    assert dec.max_k == 5  # the karate club's densest truss is the 5-truss
    spectrum = dec.spectrum()
    assert sum(spectrum.values()) == 78
    assert spectrum[5] == 14
    sizes = dec.truss_sizes()
    assert sizes[2] == 78 and sizes[5] == 14
    # monotone: the k-truss shrinks as k grows
    assert all(sizes[k] >= sizes[k + 1] for k in range(2, dec.max_k))


def test_truss_complete_graph():
    """K_n is its own n-truss: every edge has support n-2."""
    dec = k_truss_decomposition(complete_graph(6))
    assert dec.max_k == 6
    assert (dec.trussness == 6).all()


def test_truss_triangle_free():
    star = canonicalize_edges(np.array([(0, i) for i in range(1, 7)]))
    dec = k_truss_decomposition(star)
    assert dec.max_k == 2 and (dec.trussness == 2).all()
    sub, k = k_truss_subgraph(star)
    assert k == 2 and sub.shape[0] == 12  # the whole (canonical) graph


def test_truss_budget_independent(karate):
    base = truss_as_dict(k_truss_decomposition(karate))
    for budget in [32, 101]:
        assert truss_as_dict(k_truss_decomposition(karate, max_wedge_chunk=budget)) == base


def test_truss_subgraph_extraction(karate):
    sub, k = k_truss_subgraph(karate)
    assert k == 5
    # canonical form: both directions, sorted, and counting it stands alone
    assert sub.shape == (28, 2)
    tc = TriangleCounter()
    assert tc.count(sub) == count_triangles_numpy(sub)
    # every edge of the 5-truss has support >= 3 inside the subgraph
    sup = edge_support(sub)
    assert int(sup.support.min()) >= 3
    # explicit k: the 4-truss contains the 5-truss
    sub4, k4 = k_truss_subgraph(karate, k=4)
    assert k4 == 4 and sub4.shape[0] >= sub.shape[0]


def test_truss_accepts_cached_csr(karate):
    with tempfile.TemporaryDirectory() as tmp:
        csr, _ = ingest(os.path.join(DATA, "karate.txt"), cache_dir=tmp)
        dec = k_truss_decomposition(csr)
    assert truss_as_dict(dec) == brute_truss(karate)


def test_truss_empty_graph():
    dec = k_truss_decomposition(np.zeros((0, 2), np.int32))
    assert dec.max_k == 0 and dec.n_edges == 0 and dec.spectrum() == {}


# ---------------------------------------------------------------------------
# property tests (hypothesis / stub)
# ---------------------------------------------------------------------------


def _random_graph(rnd, n_max=24, m_max=60):
    n = rnd.randint(3, n_max)
    m = rnd.randint(0, m_max)
    pairs = [(rnd.randint(0, n - 1), rnd.randint(0, n - 1)) for _ in range(m)]
    pairs = [(u, v) for u, v in pairs if u != v]
    if not pairs:
        return np.zeros((0, 2), np.int32)
    return canonicalize_edges(np.array(pairs, np.int32))


@settings(max_examples=20, deadline=None)
@given(st.randoms())
def test_property_support_identity_and_truss_oracle(rnd):
    """Random small graphs: Σ support = 3·T at two budgets; the engine
    peel matches the naive oracle (peel order cannot matter)."""
    e = _random_graph(rnd)
    if e.shape[0] == 0:
        assert edge_support(e).n_edges == 0
        return
    expect = count_triangles_numpy(e)
    for budget in [None, 16]:
        sup = edge_support(e, max_wedge_chunk=budget)
        assert int(sup.support.sum()) == 3 * expect
        assert support_as_dict(sup) == brute_support(e)
    dec = k_truss_decomposition(e, max_wedge_chunk=16)
    assert truss_as_dict(dec) == brute_truss(e)


# ---------------------------------------------------------------------------
# metrics routed through the engine
# ---------------------------------------------------------------------------


def test_metrics_match_core_wrappers(small_graphs):
    for e in small_graphs.values():
        np.testing.assert_allclose(
            local_clustering(e), np.asarray(local_clustering_coefficient(e))
        )
        assert abs(transitivity(e) - core_transitivity(e)) < 1e-12


def test_core_clustering_now_honors_budget(small_graphs):
    """The historical signatures accept max_wedge_chunk and still agree."""
    e = small_graphs["kron"]
    base = np.asarray(local_clustering_coefficient(e))
    chunked = np.asarray(local_clustering_coefficient(e, max_wedge_chunk=64))
    np.testing.assert_allclose(base, chunked)
    assert abs(
        core_transitivity(e, max_wedge_chunk=64) - core_transitivity(e)
    ) < 1e-12


def test_core_clustering_accepts_cached_csr(karate):
    with tempfile.TemporaryDirectory() as tmp:
        csr, _ = ingest(os.path.join(DATA, "karate.txt"), cache_dir=tmp)
        from_cache = np.asarray(local_clustering_coefficient(csr))
        feats = np.asarray(node_triangle_features(csr))
    np.testing.assert_allclose(
        from_cache, np.asarray(local_clustering_coefficient(karate))
    )
    assert feats.shape == (34, 3)
    assert abs(core_transitivity(karate) - 135.0 / 528.0) < 1e-12


def test_transitivity_karate_exact(karate):
    # 45 triangles, 528 wedges -> 135/528
    assert abs(transitivity(karate) - 135.0 / 528.0) < 1e-12
    assert abs(average_clustering(karate) - 0.5706384782076823) < 1e-9


def test_clustering_profile_partitions_nodes(small_graphs):
    e = small_graphs["ws"]
    n = int(e.max()) + 1
    prof = clustering_profile(e)
    assert sum(prof["n_nodes"]) == n  # WS has no isolated nodes
    assert len(prof["bins"]) == len(prof["mean_clustering"])
    assert all(0.0 <= c <= 1.0 + 1e-9 for c in prof["mean_clustering"])


def test_top_k_metrics(karate):
    nodes, counts = top_triangle_nodes(karate, k=2)
    assert list(nodes) == [0, 33] and list(counts) == [18, 15]
    u, v, s = top_support_edges(karate, k=1)
    assert (int(u[0]), int(v[0]), int(s[0])) == (32, 33, 10)


def test_metrics_reuse_counter_stats(small_graphs):
    """Passing counter= reuses the instance; last_stats reflects the call."""
    e = small_graphs["kron"]
    tc = TriangleCounter(method="wedge_bsearch", max_wedge_chunk=128)
    local_clustering(e, counter=tc)
    assert tc.last_stats is not None
    assert tc.last_stats.wedge_budget == 128
    assert tc.last_stats.n_chunks > 1


def test_graph_report_shape_and_consistency(karate):
    rep = graph_report(karate, top_k=3, max_wedge_chunk=128)
    assert rep["triangles"] == 45
    assert rep["support"]["sum"] == 3 * rep["triangles"]
    assert abs(rep["transitivity"] - 135.0 / 528.0) < 1e-12
    assert rep["truss"]["max_k"] == 5
    assert sum(rep["truss"]["spectrum"].values()) == rep["n_edges"] == 78
    assert len(rep["clustering"]["top_nodes"]) == 3
    assert set(rep["timings_s"]) >= {"preprocess", "count", "clustering", "support", "truss"}
    # no-truss variant skips the peel
    rep2 = graph_report(karate, include_truss=False)
    assert "truss" not in rep2
