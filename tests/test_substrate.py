"""Optimizer, schedules, checkpoint, data pipeline, straggler monitor."""
import glob
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_latest, save_checkpoint
from repro.data import TokenPipeline, lm_batch
from repro.distributed import StragglerMonitor
from repro.optim import (
    adamw,
    apply_updates,
    clip_by_global_norm,
    constant,
    cosine_with_warmup,
    linear_warmup,
    sgd_momentum,
)


def test_adamw_matches_reference_step():
    """One AdamW step vs hand-computed update."""
    init, update = adamw(lr=0.1, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
                         max_grad_norm=None)
    p = {"w": jnp.array([1.0])}
    g = {"w": jnp.array([0.5])}
    st = init(p)
    u, st, _ = update(g, st, p)
    m = 0.1 * 0.5
    v = 0.001 * 0.25
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.999)
    want = -0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(u["w"]), [want], rtol=2e-5)


def test_weight_decay_decoupled():
    init, update = adamw(lr=0.1, weight_decay=0.1, max_grad_norm=None)
    p = {"w": jnp.array([2.0])}
    st = init(p)
    u, st, _ = update({"w": jnp.array([0.0])}, st, p)
    np.testing.assert_allclose(np.asarray(u["w"]), [-0.1 * 0.1 * 2.0], rtol=1e-6)


def test_clip_by_global_norm():
    g = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 5.0) < 1e-6
    total = np.sqrt(sum(float(jnp.sum(x**2)) for x in jax.tree.leaves(clipped)))
    assert abs(total - 1.0) < 1e-5


def test_sgd_momentum_converges():
    init, update = sgd_momentum(lr=0.05, momentum=0.9)
    p = {"w": jnp.array([4.0])}
    st = init(p)
    for _ in range(160):
        g = jax.grad(lambda q: jnp.sum(q["w"] ** 2))(p)
        u, st, _ = update(g, st, p)
        p = apply_updates(p, u)
    assert abs(float(p["w"][0])) < 1e-2


def test_schedules():
    warm = linear_warmup(1.0, 10)
    assert float(warm(jnp.int32(5))) == pytest.approx(0.5)
    cos = cosine_with_warmup(1.0, 10, 110, floor=0.1)
    assert float(cos(jnp.int32(10))) == pytest.approx(1.0, abs=1e-3)
    assert float(cos(jnp.int32(110))) == pytest.approx(0.1, abs=1e-3)
    assert float(constant(0.3)(jnp.int32(7))) == pytest.approx(0.3)


def test_checkpoint_roundtrip_and_fallback(tmp_path):
    d = str(tmp_path)
    tree = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones(4)}}
    mgr = CheckpointManager(d, keep=2, async_save=True)
    mgr.save(1, tree, {"data_state": {"step": 1, "seed": 0}})
    mgr.save(2, jax.tree.map(lambda x: x * 2, tree), {"data_state": {"step": 2, "seed": 0}})
    mgr.wait()
    got, step, extra = restore_latest(d, tree)
    assert step == 2 and extra["data_state"]["step"] == 2
    np.testing.assert_allclose(np.asarray(got["a"]), np.asarray(tree["a"]) * 2)
    # torn checkpoint (missing COMMIT) falls back
    latest = sorted(glob.glob(os.path.join(d, "step_*")))[-1]
    os.remove(os.path.join(latest, "COMMIT"))
    got, step, _ = restore_latest(d, tree)
    assert step == 1
    # corrupted arrays (CRC mismatch) also fall back
    save_checkpoint(d, 3, tree)
    path3 = sorted(glob.glob(os.path.join(d, "step_*")))[-1]
    npz = os.path.join(path3, "arrays.npz")
    with open(npz, "r+b") as f:
        f.seek(os.path.getsize(npz) // 2)
        f.write(b"\xde\xad\xbe\xef")
    got, step, _ = restore_latest(d, tree)
    assert step == 1


def test_checkpoint_gc_keeps_n(tmp_path):
    d = str(tmp_path)
    tree = {"a": jnp.zeros(3)}
    mgr = CheckpointManager(d, keep=2, async_save=False)
    for s in range(5):
        mgr.save(s, tree)
    steps = [s for s, _ in __import__("repro.checkpoint", fromlist=["list_checkpoints"]).list_checkpoints(d)]
    assert steps == [3, 4]


def test_data_pipeline_determinism_and_resume():
    p1 = TokenPipeline(4, 32, 1000, seed=7)
    batches = [next(p1) for _ in range(5)]
    state = p1.state()
    p2 = TokenPipeline.from_state(4, 32, 1000, state)
    nxt1, nxt2 = next(p1), next(p2)
    np.testing.assert_array_equal(nxt1["tokens"], nxt2["tokens"])
    # pure function of (seed, step)
    again = lm_batch(7, 0, 4, 32, 1000)
    np.testing.assert_array_equal(batches[0]["tokens"], again["tokens"])
    # labels are next-token shifted
    b = lm_batch(3, 0, 2, 16, 500)
    assert b["tokens"].shape == (2, 16) and b["labels"].shape == (2, 16)


def test_straggler_monitor_flags_and_recovers():
    events = []
    mon = StragglerMonitor(min_samples=5, on_straggle=lambda s, dt, med: events.append(s))
    for i in range(10):
        assert not mon.observe(0.10 + 0.002 * (i % 3))
    assert mon.observe(0.5)
    assert events and abs(mon.median - 0.102) < 0.01
    # baseline not poisoned: next normal step is not flagged
    assert not mon.observe(0.105)
