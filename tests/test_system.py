"""End-to-end behaviour tests for the paper's system."""
import numpy as np

from repro.core import (
    count_triangles,
    count_triangles_numpy,
    transitivity,
)
from repro.graphs import barabasi_albert, kronecker_rmat, watts_strogatz


def test_paper_pipeline_kronecker():
    """The paper's headline workload end-to-end at reduced scale:
    generate a Kronecker graph, count exactly, agree with the CPU baseline."""
    e = kronecker_rmat(10, seed=42)
    t = count_triangles(e)
    assert t == count_triangles_numpy(e)
    assert t > 0


def test_paper_pipeline_ba_ws():
    for e in [barabasi_albert(500, 6, seed=1), watts_strogatz(800, 10, 0.1, seed=1)]:
        assert count_triangles(e) == count_triangles_numpy(e)
        assert 0.0 <= transitivity(e) <= 1.0


def test_kronecker_triangle_growth():
    """Paper Fig. 1: triangle count grows superlinearly with scale."""
    t = [count_triangles(kronecker_rmat(s, edge_factor=8, seed=0)) for s in (8, 9, 10)]
    assert t[0] < t[1] < t[2]
    assert t[2] / t[1] > 1.5
