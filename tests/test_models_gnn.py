"""Per-GNN-arch smoke tests across all four shape kinds (reduced sizes)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY
from repro.configs.gnn_common import block_graph_from_frontiers
from repro.graphs import random_molecule_batch, sample_blocks
from repro.graphs.formats import edge_array_to_csr
from repro.graphs import erdos_renyi

GNN_ARCHS = [a for a, m in REGISTRY.items() if m.FAMILY == "gnn"]


@pytest.fixture(scope="module")
def graph():
    e = erdos_renyi(50, 200, seed=0)
    n = int(e.max()) + 1
    rng = np.random.default_rng(0)
    return {
        "edges": e,
        "n": n,
        "feat": jnp.asarray(rng.normal(size=(n, 12)).astype(np.float32)),
        "pos": jnp.asarray(rng.normal(size=(n, 3)).astype(np.float32)),
    }


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_full_graph_forward_and_grad(arch, graph):
    mod = REGISTRY[arch]
    cfg = mod.smoke_config()
    model = mod.MODEL
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    src = jnp.asarray(graph["edges"][:, 0])
    dst = jnp.asarray(graph["edges"][:, 1])
    out = model.apply(params, cfg, graph["feat"], graph["pos"], src, dst)
    assert out.shape == (graph["n"], cfg.d_out)
    assert bool(jnp.isfinite(out).all()), arch

    def loss(p):
        o = model.apply(p, cfg, graph["feat"], graph["pos"], src, dst)
        return jnp.mean(o**2)

    g = jax.grad(loss)(params)
    assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(g)), arch


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_padded_edges_are_ignored(arch, graph):
    mod = REGISTRY[arch]
    cfg = mod.smoke_config()
    model = mod.MODEL
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    src = jnp.asarray(graph["edges"][:, 0])
    dst = jnp.asarray(graph["edges"][:, 1])
    pad = jnp.full((37,), -1, jnp.int32)
    out1 = model.apply(params, cfg, graph["feat"], graph["pos"], src, dst)
    out2 = model.apply(
        params, cfg, graph["feat"], graph["pos"],
        jnp.concatenate([src, pad]), jnp.concatenate([dst, pad]),
    )
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_minibatch_block_path(arch, graph):
    mod = REGISTRY[arch]
    cfg = mod.smoke_config()
    model = mod.MODEL
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    row, col = edge_array_to_csr(graph["edges"], graph["n"])
    seeds = jnp.arange(8, dtype=jnp.int32)
    blocks = sample_blocks(
        jax.random.PRNGKey(1), jnp.asarray(row, jnp.int32), jnp.asarray(col, jnp.int32),
        seeds, (3, 2),
    )
    if hasattr(model, "apply_blocks"):
        feats = [jnp.take(graph["feat"], f, axis=0) for f in blocks.frontiers]
        out = model.apply_blocks(params, cfg, feats, (3, 2))
    else:
        nodes, esrc, edst = block_graph_from_frontiers(blocks.frontiers, (3, 2))
        nf = jnp.take(graph["feat"], nodes, axis=0)
        npos = jnp.take(graph["pos"], nodes, axis=0)
        out = model.apply(params, cfg, nf, npos, esrc, edst)[:8]
    assert out.shape == (8, cfg.d_out)
    assert bool(jnp.isfinite(out).all()), arch


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_molecule_batched(arch):
    mod = REGISTRY[arch]
    cfg = mod.smoke_config()
    model = mod.MODEL
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    gb = random_molecule_batch(4, 10, 18, cfg.d_in, seed=0)
    b, nb = 4, 10
    flat_feat = jnp.asarray(gb.node_feat.reshape(b * nb, -1))
    flat_pos = jnp.asarray(gb.positions.reshape(b * nb, 3))
    off = (np.arange(b, dtype=np.int32) * nb)[:, None]
    fsrc = jnp.asarray(np.where(gb.edge_src >= 0, gb.edge_src + off, -1).reshape(-1))
    fdst = jnp.asarray(np.where(gb.edge_dst >= 0, gb.edge_dst + off, -1).reshape(-1))
    out = model.apply(params, cfg, flat_feat, flat_pos, fsrc, fdst)
    assert out.shape == (b * nb, cfg.d_out)
    assert bool(jnp.isfinite(out).all()), arch


def test_egnn_equivariance(graph):
    from repro.models.gnn import egnn

    cfg = REGISTRY["egnn"].smoke_config()
    params = egnn.init_params(jax.random.PRNGKey(0), cfg)
    src = jnp.asarray(graph["edges"][:, 0])
    dst = jnp.asarray(graph["edges"][:, 1])
    out1 = egnn.apply(params, cfg, graph["feat"], graph["pos"], src, dst)
    th = 1.1
    R = jnp.array(
        [[np.cos(th), -np.sin(th), 0], [np.sin(th), np.cos(th), 0], [0, 0, 1.0]]
    )
    out2 = egnn.apply(
        params, cfg, graph["feat"], graph["pos"] @ R.T + jnp.array([3.0, -1.0, 2.0]),
        src, dst,
    )
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=2e-3, atol=2e-3)


def test_gcn_training_reduces_loss(graph):
    from repro.data import graph_node_features
    from repro.models.gnn import gcn
    from repro.optim import adamw, apply_updates, constant

    feat, labels = graph_node_features(0, graph["n"], 12, 3)
    cfg = REGISTRY["gcn-cora"].smoke_config()
    params = gcn.init_params(jax.random.PRNGKey(0), cfg)
    opt_init, opt_update = adamw(constant(3e-2), weight_decay=0.0)
    opt = opt_init(params)
    src = jnp.asarray(graph["edges"][:, 0])
    dst = jnp.asarray(graph["edges"][:, 1])
    feat, labels = jnp.asarray(feat), jnp.asarray(labels)

    @jax.jit
    def step(params, opt):
        def loss(p):
            o = gcn.apply(p, cfg, feat, None, src, dst)
            lp = jax.nn.log_softmax(o, axis=-1)
            return -jnp.mean(jnp.take_along_axis(lp, labels[:, None], axis=-1))

        l, g = jax.value_and_grad(loss)(params)
        u, opt, _ = opt_update(g, opt, params)
        return apply_updates(params, u), opt, l

    losses = []
    for _ in range(60):
        params, opt, l = step(params, opt)
        losses.append(float(l))
    assert losses[-1] < losses[0] - 0.3, losses
