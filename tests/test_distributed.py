"""Multi-device semantics via 8-fake-device subprocesses."""
import pytest

from conftest import run_multidevice

pytestmark = pytest.mark.distributed


@pytest.mark.slow
def test_distributed_count_matches_single():
    out = run_multidevice("""
import jax, numpy as np
mesh = jax.make_mesh((2, 4), ("data", "model"))
from repro.graphs import kronecker_rmat
from repro.core import count_triangles, count_triangles_distributed
e = kronecker_rmat(10, seed=3)
a = count_triangles(e)
b = count_triangles_distributed(e, mesh)
assert a == b, (a, b)
print("OK", a)
""")
    assert "OK" in out


@pytest.mark.slow
def test_distributed_count_shorter_side_variant_exact():
    out = run_multidevice("""
import jax
mesh = jax.make_mesh((2, 4), ("data", "model"))
from repro.graphs import kronecker_rmat, watts_strogatz
from repro.core import count_triangles, count_triangles_distributed
for e in [kronecker_rmat(10, seed=3), watts_strogatz(2000, 12, 0.2, seed=1)]:
    a = count_triangles(e)
    b = count_triangles_distributed(e, mesh, shorter_side=True)
    assert a == b, (a, b)
print("OK")
""")
    assert "OK" in out


@pytest.mark.slow
def test_sharded_lm_train_step_matches_single_device():
    out = run_multidevice("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import REGISTRY
from repro.configs.lm_common import make_lm_train_step, _rules_for
from repro.distributed.sharding import make_param_shardings, spec_for
from repro.models import transformer as tfm
from repro.data import lm_batch

cfg = REGISTRY["qwen2-1.5b"].smoke_config()
mesh = jax.make_mesh((2, 4), ("data", "model"))
params = tfm.init_params(jax.random.PRNGKey(0), cfg)
step_fn, opt_init = make_lm_train_step(cfg, accum=2)
opt = opt_init(params)
raw = lm_batch(0, 0, 8, 32, cfg.vocab_size)
batch = {k: jnp.asarray(v).reshape(2, 4, 32) for k, v in raw.items()}

p1, o1, m1 = jax.jit(step_fn)(params, opt, batch)

rules = _rules_for(cfg, mesh)
psh = make_param_shardings(mesh, rules, params)
osh = jax.tree.map(lambda s: NamedSharding(mesh, s), spec_for(rules, opt))
bsh = {k: NamedSharding(mesh, P(None, "data", None)) for k in batch}
with mesh:
    sharded = jax.jit(step_fn, in_shardings=(psh, osh, bsh))
    p2, o2, m2 = sharded(jax.device_put(params, psh), jax.device_put(opt, osh),
                         jax.device_put(batch, bsh))
# The loss *metric* reduction reorders under GSPMD on some jax versions
# (observed 0.019 absolute on jax 0.4.37 CPU) while grads/params stay
# bit-close; params below are the strict equivalence check.  The bound
# leaves little headroom over the observed drift so real metric
# regressions (≳1%) still fail.
assert abs(float(m1["loss"]) - float(m2["loss"])) < 2.5e-2, (m1["loss"], m2["loss"])
for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-3)
print("OK", float(m2["loss"]))
""")
    assert "OK" in out


@pytest.mark.slow
def test_compressed_psum_and_error_feedback():
    out = run_multidevice("""
import inspect, jax, jax.numpy as jnp, numpy as np
try:
    from jax import shard_map
except ImportError:
    from jax.experimental.shard_map import shard_map
# jax renamed check_rep -> check_vma; pass whichever this version accepts
_ckw = ("check_vma" if "check_vma" in inspect.signature(shard_map).parameters
        else "check_rep")
from jax.sharding import Mesh, PartitionSpec as P
from repro.distributed.compression import compress_grads, make_error_feedback_state

mesh = jax.make_mesh((8,), ("data",))
g = {"w": jnp.arange(32.0).reshape(8, 4) / 7.0}
ef = make_error_feedback_state({"w": g["w"][0]})

def body(g_shard, e_shard):
    gs = {"w": g_shard.reshape(4)}
    es = {"w": e_shard.reshape(4)}
    sync, new_e = compress_grads(gs, es, "data")
    return sync["w"], new_e["w"]

f = shard_map(body, mesh=mesh, in_specs=(P("data"), P(None)),
              out_specs=(P(None), P(None)), **{_ckw: False})
sync, new_e = f(g["w"].reshape(32), ef["w"])
exact = np.asarray(g["w"]).reshape(8, 4).mean(0)
got = np.asarray(sync)
rel = np.abs(got - exact).max() / (np.abs(exact).max() + 1e-9)
assert rel < 0.02, (got, exact)
print("OK", rel)
""")
    assert "OK" in out


@pytest.mark.slow
def test_elastic_restore_to_different_mesh():
    out = run_multidevice("""
import jax, jax.numpy as jnp, numpy as np, tempfile
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import CheckpointManager

tree = {"w": jnp.arange(64.0).reshape(8, 8)}
with tempfile.TemporaryDirectory() as d:
    mgr = CheckpointManager(d, async_save=False)
    mgr.save(5, tree)
    # restore onto a 2×4 mesh then a 4×2 mesh (elastic restart)
    for shape in [(2, 4), (4, 2)]:
        mesh = jax.make_mesh(shape, ("data", "model"))
        sh = {"w": NamedSharding(mesh, P("data", "model"))}
        got, step, _ = mgr.restore_latest(tree, shardings=sh)
        assert step == 5
        np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))
        assert got["w"].sharding.mesh.devices.shape == shape
print("OK")
""")
    assert "OK" in out


@pytest.mark.slow
def test_gnn_edge_partitioned_matches_replicated():
    out = run_multidevice("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import REGISTRY
from repro.graphs import erdos_renyi

mod = REGISTRY["gcn-cora"]
cfg = mod.smoke_config()
model = mod.MODEL
e = erdos_renyi(40, 160, seed=0)
n = int(e.max()) + 1
rng = np.random.default_rng(0)
feat = jnp.asarray(rng.normal(size=(n, cfg.d_in)).astype(np.float32))
params = model.init_params(jax.random.PRNGKey(0), cfg)
pad = (-e.shape[0]) % 8
src = jnp.asarray(np.concatenate([e[:,0], -np.ones(pad)]).astype(np.int32))
dst = jnp.asarray(np.concatenate([e[:,1], -np.ones(pad)]).astype(np.int32))
single = model.apply(params, cfg, feat, None, src, dst)
mesh = jax.make_mesh((8,), ("data",))
with mesh:
    f = jax.jit(lambda p, x, s, d: model.apply(p, cfg, x, None, s, d),
                in_shardings=(None, None, NamedSharding(mesh, P("data")),
                              NamedSharding(mesh, P("data"))))
    sharded = f(params, feat, src, dst)
np.testing.assert_allclose(np.asarray(single), np.asarray(sharded), rtol=1e-4, atol=1e-4)
print("OK")
""")
    assert "OK" in out


@pytest.mark.slow
def test_gcn_shardmap_psum_matches_single_device():
    out = run_multidevice("""
import dataclasses, inspect, jax, jax.numpy as jnp, numpy as np
try:
    from jax import shard_map
except ImportError:
    from jax.experimental.shard_map import shard_map
_ckw = ("check_vma" if "check_vma" in inspect.signature(shard_map).parameters
        else "check_rep")
from jax.sharding import PartitionSpec as P
from repro.models.gnn import gcn
from repro.graphs import erdos_renyi
mesh = jax.make_mesh((8,), ("data",))
e = erdos_renyi(48, 200, seed=0); n = int(e.max())+1
pad = (-e.shape[0]) % 8
src = jnp.asarray(np.concatenate([e[:,0], -np.ones(pad)]).astype(np.int32))
dst = jnp.asarray(np.concatenate([e[:,1], -np.ones(pad)]).astype(np.int32))
cfg = gcn.GCNConfig(d_in=12, d_hidden=16, d_out=5, smart_order=True)
cfg_ps = dataclasses.replace(cfg, psum_axes=("data",))
p = gcn.init_params(jax.random.PRNGKey(0), cfg)
feat = jax.random.normal(jax.random.PRNGKey(1), (n, 12))
single = gcn.apply(p, cfg, feat, None, src, dst)
f = shard_map(lambda p, x, s, d: gcn.apply(p, cfg_ps, x, None, s, d),
              mesh=mesh, in_specs=(P(), P(), P("data"), P("data")),
              out_specs=P(), **{_ckw: False})
with mesh:
    sharded = jax.jit(f)(p, feat, src, dst)
np.testing.assert_allclose(np.asarray(single), np.asarray(sharded), rtol=2e-4, atol=2e-4)
g = jax.grad(lambda p: jnp.sum(f(p, feat, src, dst)**2))(p)
assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(g))
print("OK")
""")
    assert "OK" in out


@pytest.mark.slow
def test_distributed_panel_schedule_exact():
    out = run_multidevice("""
import jax
mesh = jax.make_mesh((2, 4), ("data", "model"))
from repro.graphs import kronecker_rmat
from repro.core import count_triangles
from repro.core.distributed import count_triangles_distributed_panel
e = kronecker_rmat(10, seed=3)
a = count_triangles(e)
b = count_triangles_distributed_panel(e, mesh, widths=(16, 64, 256, 1024))
assert a == b, (a, b)
print("OK", a)
""")
    assert "OK" in out
