"""Deterministic fallback for the slice of the `hypothesis` API we use.

The container image does not ship `hypothesis`; rather than lose the
property tests entirely, this stub replays each ``@given`` test over a
fixed number of deterministically seeded random draws.  It implements
only what the test files need: ``given``, ``settings`` and the
``integers`` / ``lists`` / ``tuples`` / ``sampled_from`` / ``randoms`` /
``composite`` strategies.  No shrinking, no example database — failures
print the drawn values so they can be replayed by seed.

When the real `hypothesis` is installed (e.g. in CI), the test modules
import it instead and this file is inert.
"""
from __future__ import annotations

import random

DEFAULT_MAX_EXAMPLES = 25
_SEED_BASE = 0x5EED_BA5E


class _Strategy:
    """A strategy is just a draw function over a `random.Random`."""

    def __init__(self, draw_fn):
        self._draw_fn = draw_fn

    def draw(self, rnd: random.Random):
        return self._draw_fn(rnd)


class st:
    """Namespace mirroring ``hypothesis.strategies``."""

    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rnd: rnd.randint(min_value, max_value))

    @staticmethod
    def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10) -> _Strategy:
        def draw(rnd):
            size = rnd.randint(min_size, max_size)
            return [elements.draw(rnd) for _ in range(size)]

        return _Strategy(draw)

    @staticmethod
    def tuples(*elements: _Strategy) -> _Strategy:
        return _Strategy(lambda rnd: tuple(e.draw(rnd) for e in elements))

    @staticmethod
    def sampled_from(seq) -> _Strategy:
        seq = list(seq)
        return _Strategy(lambda rnd: seq[rnd.randrange(len(seq))])

    @staticmethod
    def randoms() -> _Strategy:
        return _Strategy(lambda rnd: random.Random(rnd.getrandbits(64)))

    @staticmethod
    def composite(fn):
        def make(*args, **kwargs):
            def draw_outer(rnd):
                return fn(lambda strategy: strategy.draw(rnd), *args, **kwargs)

            return _Strategy(draw_outer)

        return make


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    """Record ``max_examples`` on the (already ``given``-wrapped) test."""

    def deco(fn):
        fn._max_examples = max_examples
        return fn

    return deco


def given(*strategies: _Strategy):
    """Run the test once per deterministically seeded example."""

    def deco(fn):
        # Deliberately NOT functools.wraps: the wrapper must expose a
        # (*args, **kwargs) signature so pytest does not mistake the
        # strategy parameters for fixture names.
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_max_examples", DEFAULT_MAX_EXAMPLES)
            for example in range(n):
                rnd = random.Random(_SEED_BASE ^ example)
                drawn = [s.draw(rnd) for s in strategies]
                try:
                    fn(*args, *drawn, **kwargs)
                except Exception:
                    print(f"falsifying example #{example}: {drawn!r}")
                    raise

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return deco
