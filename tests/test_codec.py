"""Compressed ``.tricsrz`` codec: varint/zigzag primitives, locality
relabeling, save/load round trips, chunk-wise engine parity across every
backend and workload, corruption detection, and the stripe view."""
import os

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image has no hypothesis; use the local stub
    from _hypothesis_stub import given, settings, st

from repro.core import IncrementalTriangleCounter, TriangleCounter
from repro.graphs import canonicalize_edges, kronecker_rmat
from repro.graphs.io import (
    CacheError,
    ORDERINGS,
    assemble_stripes,
    csr_stripes_from_compressed,
    ingest,
    load_tricsrz,
    load_tricsrz_stripe,
    order_permutation,
    relabel_csr,
    save_tricsrz,
)
from repro.graphs.io.codec import decode_varints, encode_varints
from repro.graphs.io.ingest import csr_from_edge_array

METHODS = ["wedge_bsearch", "panel", "pallas"]


def _csr(edges):
    return csr_from_edge_array(canonicalize_edges(np.asarray(edges)))


def _random_csr(seed, n_max=60, ef=4):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, n_max))
    m = int(rng.integers(1, ef * n))
    e = rng.integers(0, n, size=(m, 2))
    e = e[e[:, 0] != e[:, 1]]
    if not e.size:
        e = np.array([[0, 1]])
    return _csr(e)


# ---------------------------------------------------------------------------
# varint primitives
# ---------------------------------------------------------------------------


def test_varint_roundtrip_edge_values():
    vals = np.array([0, 1, 127, 128, 129, 2**14 - 1, 2**14, 2**32,
                     2**63, 2**64 - 1], dtype=np.uint64)
    buf = encode_varints(vals)
    np.testing.assert_array_equal(decode_varints(buf, vals.size), vals)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_varint_roundtrip_random(seed):
    rng = np.random.default_rng(seed)
    # mix of small deltas (the common case) and full-width values
    vals = np.concatenate([
        rng.integers(0, 100, size=200).astype(np.uint64),
        rng.integers(0, 2**63, size=20, dtype=np.int64).astype(np.uint64),
    ])
    buf = encode_varints(vals)
    np.testing.assert_array_equal(decode_varints(buf, vals.size), vals)


def test_varint_strict_decode_rejects_garbage():
    buf = encode_varints(np.array([5, 6], dtype=np.uint64))
    with pytest.raises(CacheError):
        decode_varints(buf, 3)  # more codes than the buffer holds
    with pytest.raises(CacheError):
        decode_varints(buf[:-1], 2)  # unterminated final code
    with pytest.raises(CacheError):
        # continuation bits forever: no terminator within 10 bytes
        decode_varints(np.full(16, 0x80, np.uint8), 1)


# ---------------------------------------------------------------------------
# relabeling
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("order", ORDERINGS)
def test_order_permutation_is_permutation(order):
    csr = _random_csr(11)
    perm = order_permutation(csr, order)
    assert np.array_equal(np.sort(perm), np.arange(csr.n_nodes))
    if order == "degree":
        deg = np.diff(csr.row_offsets)
        assert np.all(np.diff(deg[perm]) <= 0)  # degree-descending
    if order == "natural":
        assert np.array_equal(perm, np.arange(csr.n_nodes))


@pytest.mark.parametrize("order", ["degree", "bfs"])
def test_relabel_preserves_edge_set(order):
    csr = _random_csr(12)
    perm = order_permutation(csr, order)
    rel = relabel_csr(csr, perm)
    # map relabeled edges back and compare as sets of original-id pairs
    def undirected_set(c, back=None):
        out = set()
        for u in range(c.n_nodes):
            for v in c.col[c.row_offsets[u]:c.row_offsets[u + 1]]:
                a, b = (u, int(v)) if back is None else (int(back[u]), int(back[v]))
                out.add((min(a, b), max(a, b)))
        return out
    assert undirected_set(rel, back=perm) == undirected_set(csr)


# ---------------------------------------------------------------------------
# save/load round trip
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("order", ORDERINGS)
@pytest.mark.parametrize("npb", [3, 4096])
def test_roundtrip_bit_identical(tmp_path, order, npb):
    csr = _random_csr(13, n_max=100, ef=6)
    path = tmp_path / "g.tricsrz"
    save_tricsrz(path, csr, order=order, nodes_per_block=npb)
    z = load_tricsrz(path, verify=True)
    ref = relabel_csr(csr, order_permutation(csr, order))
    dec = z.to_csr()
    np.testing.assert_array_equal(dec.row_offsets, ref.row_offsets)
    np.testing.assert_array_equal(dec.col, ref.col)
    assert z.n_nodes == csr.n_nodes and z.n_edges == csr.n_edges
    # block-wise and range decode agree with the full decode
    for k in range(z.n_blocks):
        lo, hi = z.block_node_range(k)
        np.testing.assert_array_equal(
            z.decode_block(k), ref.col[ref.row_offsets[lo]:ref.row_offsets[hi]])
    # compressed form actually holds fewer resident bytes than flat
    flat_bytes = csr.row_offsets.nbytes + csr.col.nbytes
    assert z.compressed_nbytes() < flat_bytes


@pytest.mark.parametrize("order", ORDERINGS)
def test_degenerate_graphs(tmp_path, order):
    star = np.array([[0, i] for i in range(1, 9)])
    cases = {
        "single-edge": _csr(np.array([[0, 1]])),
        "star": _csr(star),
        "isolated-tail": csr_from_edge_array(
            canonicalize_edges(np.array([[0, 1], [1, 2]]))),
    }
    from repro.graphs.io.cache import CSRGraph
    cases["empty"] = CSRGraph(np.zeros(1, np.int64), np.zeros(0, np.int32), 0)
    for name, csr in cases.items():
        path = tmp_path / f"{name}-{order}.tricsrz"
        save_tricsrz(path, csr, order=order, nodes_per_block=3)
        z = load_tricsrz(path, verify=True)
        ref = relabel_csr(csr, order_permutation(csr, order))
        dec = z.to_csr()
        np.testing.assert_array_equal(dec.row_offsets, ref.row_offsets, err_msg=name)
        np.testing.assert_array_equal(dec.col, ref.col, err_msg=name)


# ---------------------------------------------------------------------------
# engine parity: every workload, every backend, ids mapped back
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", METHODS)
@pytest.mark.parametrize("order", ["degree", "bfs"])
def test_engine_parity_all_workloads(tmp_path, method, order):
    from repro.analytics import k_truss_decomposition

    csr = _csr(kronecker_rmat(8, edge_factor=8, seed=5))
    path = tmp_path / "g.tricsrz"
    save_tricsrz(path, csr, order=order, nodes_per_block=64)
    z = load_tricsrz(path)

    tc = TriangleCounter(method=method)
    assert tc.count(z) == tc.count(csr)
    np.testing.assert_array_equal(z.map_per_node(tc.per_node(z)),
                                  tc.per_node(csr))
    # support values are label-invariant per edge: multiset must match
    sup_flat = np.sort(tc.edge_support(csr))
    sup_z = np.sort(tc.edge_support(z))
    np.testing.assert_array_equal(sup_z, sup_flat)
    dec_flat = k_truss_decomposition(csr, method=method)
    dec_z = k_truss_decomposition(z, method=method)
    assert dec_z.spectrum() == dec_flat.spectrum()
    assert dec_z.max_k == dec_flat.max_k


@pytest.mark.parametrize("order", ["degree", "bfs"])
def test_incremental_bootstrap_parity(tmp_path, order):
    csr = _csr(kronecker_rmat(7, edge_factor=6, seed=8))
    path = tmp_path / "g.tricsrz"
    save_tricsrz(path, csr, order=order, nodes_per_block=32)
    z = load_tricsrz(path)

    itc_f = IncrementalTriangleCounter(csr.edge_array())
    itc_z = IncrementalTriangleCounter(z)  # decodes to original ids
    assert itc_z.count == itc_f.count
    np.testing.assert_array_equal(itc_z.per_node(), itc_f.per_node())
    # updates arrive in original ids and must stay in lockstep
    batch = np.array([[0, 5], [5, 9], [9, 0], [1, 7]])
    itc_f.insert(batch)
    itc_z.insert(batch)
    assert itc_z.count == itc_f.count
    np.testing.assert_array_equal(itc_z.per_node(), itc_f.per_node())


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from(list(ORDERINGS)))
def test_property_file_to_engine_roundtrip(seed, order):
    """file -> canonicalize -> relabel -> compress -> decode must match the
    flat path bit-identically on count, mapped per-node, and support."""
    import tempfile

    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 40))
    e = rng.integers(0, n, size=(int(rng.integers(1, 5 * n)), 2))
    e = e[e[:, 0] != e[:, 1]]
    if not e.size:
        e = np.array([[0, 1]])
    with tempfile.TemporaryDirectory(prefix="codec-prop-") as tmp:
        src = os.path.join(tmp, "g.txt")
        np.savetxt(src, e, fmt="%d")
        flat, _ = ingest(src, cache_dir=os.path.join(tmp, "c1"))
        z, stats = ingest(src, cache_dir=os.path.join(tmp, "c2"),
                          storage="compressed", order=order)
        assert stats.storage == "compressed" and stats.order == order
        tc = TriangleCounter(method="wedge_bsearch")
        assert tc.count(z) == tc.count(flat)
        np.testing.assert_array_equal(z.map_per_node(tc.per_node(z)),
                                      tc.per_node(flat))
        np.testing.assert_array_equal(np.sort(tc.edge_support(z)),
                                      np.sort(tc.edge_support(flat)))


# ---------------------------------------------------------------------------
# corruption detection
# ---------------------------------------------------------------------------


def _flip_bit(path, offset):
    with open(path, "r+b") as f:
        f.seek(offset)
        b = f.read(1)
        f.seek(offset)
        f.write(bytes([b[0] ^ 0x40]))


def test_truncation_detected(tmp_path):
    csr = _random_csr(21)
    path = tmp_path / "g.tricsrz"
    save_tricsrz(path, csr, order="degree")
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(size - 1)
    with pytest.raises(CacheError):
        load_tricsrz(path)


def test_block_index_bitflip_detected_at_load(tmp_path):
    csr = _random_csr(22, n_max=200, ef=6)
    path = tmp_path / "g.tricsrz"
    save_tricsrz(path, csr, order="degree", nodes_per_block=16)
    import struct
    with open(path, "rb") as f:
        header = f.read(64)
    payload_bytes = struct.unpack("<Q", header[48:56])[0]
    # last byte of the metadata region (block index) — meta crc must trip
    _flip_bit(path, os.path.getsize(path) - payload_bytes - 1)
    with pytest.raises(CacheError):
        load_tricsrz(path)


def test_payload_bitflip_detected_at_decode(tmp_path):
    csr = _random_csr(23, n_max=200, ef=6)
    path = tmp_path / "g.tricsrz"
    save_tricsrz(path, csr, order="degree", nodes_per_block=16)
    _flip_bit(path, os.path.getsize(path) - 1)  # inside the last block
    z = load_tricsrz(path)  # metadata intact: load succeeds
    with pytest.raises(CacheError):
        z.to_csr()  # ... but the per-block crc trips on decode
    with pytest.raises(CacheError):
        load_tricsrz(path, verify=True)  # full verify catches it up front


def test_bad_magic_detected(tmp_path):
    csr = _random_csr(24)
    path = tmp_path / "g.tricsrz"
    save_tricsrz(path, csr)
    _flip_bit(path, 0)
    with pytest.raises(CacheError):
        load_tricsrz(path)


# ---------------------------------------------------------------------------
# stripe view: the block index doubles as the slab mechanism
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_stripes", [1, 3, 4])
def test_stripes_reassemble_and_orient(tmp_path, n_stripes):
    from repro.core.distributed import oriented_csr_from_slabs
    from repro.core.preprocess import oriented_from_undirected_csr

    csr = _csr(kronecker_rmat(7, edge_factor=8, seed=2))
    path = tmp_path / "g.tricsrz"
    save_tricsrz(path, csr, order="degree", nodes_per_block=16)
    z = load_tricsrz(path)

    stripes = csr_stripes_from_compressed(z, n_stripes)
    assert len(stripes) == n_stripes
    whole = assemble_stripes(stripes)
    ref = z.to_csr()
    np.testing.assert_array_equal(whole.row_offsets, ref.row_offsets)
    np.testing.assert_array_equal(whole.col, ref.col)

    # loading stripes straight off the file matches the in-memory view
    for k, s in enumerate(stripes):
        s2 = load_tricsrz_stripe(path, k, n_stripes)
        np.testing.assert_array_equal(s2.col, s.col)
        np.testing.assert_array_equal(s2.row_offsets, s.row_offsets)
        assert (s2.node_lo, s2.node_hi) == (s.node_lo, s.node_hi)

    oc = oriented_csr_from_slabs(stripes)
    want = oriented_from_undirected_csr(ref.row_offsets, ref.col)
    np.testing.assert_array_equal(np.asarray(oc.row_offsets),
                                  np.asarray(want.row_offsets))
    np.testing.assert_array_equal(np.asarray(oc.col), np.asarray(want.col))
